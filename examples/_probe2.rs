use sparse_dp_emb::models::ParamStore;
use sparse_dp_emb::runtime::{HostTensor, Runtime};
fn main() -> anyhow::Result<()> {
    let rt = Runtime::new("artifacts")?;
    let model = rt.manifest.model("criteo-small")?;
    let store = ParamStore::init(model, 3)?;
    let b = 128usize; let nf = 26usize;
    // every example activates bucket 3 of every feature
    let cat = vec![3i32; b*nf];
    let num = vec![0f32; b*13];
    let y = vec![1f32; b];
    let mut inputs = store.tensors();
    inputs.push(HostTensor::i32(vec![b,nf], cat));
    inputs.push(HostTensor::f32(vec![b,13], num));
    inputs.push(HostTensor::f32(vec![b], y));
    inputs.push(HostTensor::f32(vec![1], vec![1.0]));
    inputs.push(HostTensor::f32(vec![1], vec![0.5]));
    let outs = rt.execute_named("pctr_grads", &inputs)?;
    let counts = outs["counts"].as_f32()?;
    let nz: Vec<(usize, f32)> = counts.iter().enumerate().filter(|(_,&v)| v!=0.0).map(|(i,&v)|(i,v)).collect();
    println!("nnz={} first 30: {:?}", nz.len(), &nz[..nz.len().min(30)]);
    let offsets = model.attr_usize_list("row_offsets")?;
    let expect: Vec<usize> = offsets.iter().map(|o| o+3).collect();
    println!("expect: {:?}", expect);
    Ok(())
}
