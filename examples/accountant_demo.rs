//! Privacy-accounting walkthrough (paper §3.3 / Appendix C).
//!
//! Demonstrates the PLD accountant: ε(δ) of the Poisson-subsampled Gaussian
//! mechanism, σ calibration for a target budget, and the DP-AdaFEST
//! two-noise decomposition σ_eff = (σ₁⁻² + σ₂⁻²)^(−1/2).
//!
//! Run with: `cargo run --release --example accountant_demo`

use anyhow::Result;

use sparse_dp_emb::accounting::{
    calibrate_sigma, calibrate_sigma_pair, compose_sigmas, gaussian_delta, Accountant,
};

fn main() -> Result<()> {
    println!("== 1. single Gaussian mechanism: PLD vs closed form ==");
    for sigma in [0.8, 1.5, 3.0] {
        let acct = Accountant::new(sigma, 1.0, 1);
        let pld = acct.delta(1.0);
        let exact = gaussian_delta(1.0, sigma);
        println!("  sigma={sigma:>4}: delta(eps=1) PLD {pld:.6e}  closed-form {exact:.6e}");
    }

    println!("\n== 2. subsampling amplification (sigma=1, T=1000, delta=1e-6) ==");
    for q in [1.0, 0.1, 0.01, 0.001] {
        let eps = Accountant::new(1.0, q, 1000).epsilon(1e-6);
        println!("  q={q:>6}: eps = {eps:.4}");
    }

    println!("\n== 3. composition growth (sigma=1, q=0.01, delta=1e-6) ==");
    for t in [10u64, 100, 1000, 10000] {
        let eps = Accountant::new(1.0, 0.01, t).epsilon(1e-6);
        println!("  T={t:>6}: eps = {eps:.4}");
    }

    println!("\n== 4. calibration: smallest sigma for (eps, delta) ==");
    let (q, t, delta) = (2048.0 / 45e6, 10_000u64, 1.0 / 45e6);
    println!("  Criteo-Kaggle-like: q={q:.2e}, T={t}, delta={delta:.2e}");
    for eps in [1.0, 3.0, 8.0] {
        let sigma = calibrate_sigma(eps, delta, q, t)?;
        let achieved = Accountant::new(sigma, q, t).epsilon(delta);
        println!("  eps={eps}: sigma={sigma:.4} (achieved eps {achieved:.4})");
    }

    println!("\n== 5. DP-AdaFEST noise split (eps=1, ratio sweep) ==");
    println!("  one step = Gaussian(sigma1) o Gaussian(sigma2) == Gaussian(sigma_eff)");
    for ratio in [0.5, 1.0, 5.0, 10.0] {
        let pair = calibrate_sigma_pair(1.0, delta, q, t, ratio)?;
        let eff = compose_sigmas(pair.sigma1, pair.sigma2);
        println!(
            "  ratio={ratio:>4}: sigma1={:>8.4} sigma2={:>7.4} -> sigma_eff={eff:.4}",
            pair.sigma1, pair.sigma2
        );
    }
    println!(
        "\n  larger sigma1/sigma2 spends less budget on the contribution map,\n\
         so sigma2 approaches the single-mechanism sigma (paper §4.5)."
    );
    Ok(())
}
