//! Language fine-tuning — DP fine-tuning of the RoBERTa-stand-in
//! transformer with trainable word embeddings (paper §4.4 / Tables 1 & 6).
//!
//! Shows three configurations on a synthetic SST-2-like task:
//!   1. DP-SGD with trainable embeddings   (dense noise — the baseline)
//!   2. DP-SGD with frozen embeddings      (Table 6's comparison)
//!   3. DP-AdaFEST on the embedding table  (sparsity-preserving)
//! plus the LoRA-on-embedding baseline (r = 16) with its analytic gradient
//! size (Table 1's comparison).
//!
//! Run with: `cargo run --release --example language_finetune`

use anyhow::Result;

use sparse_dp_emb::config::RunConfig;
use sparse_dp_emb::coordinator::{Algorithm, Trainer};
use sparse_dp_emb::data::{SynthText, TextConfig};
use sparse_dp_emb::runtime::Runtime;

fn run_one(rt: &Runtime, cfg: &RunConfig) -> Result<(f64, f64)> {
    let model = rt.manifest.model(&cfg.model)?;
    let gen = SynthText::new(TextConfig::new(
        model.attr_usize("vocab")?,
        model.attr_usize("seq_len")?,
        model.attr_usize("num_classes")?,
        cfg.seed ^ 0xDA7A,
    ));
    let mut trainer = Trainer::new(cfg.clone(), rt)?;
    let out = trainer.run_text(&gen)?;
    Ok((out.utility, out.reduction_factor))
}

fn main() -> Result<()> {
    let rt = Runtime::new("artifacts")?;

    let mut base = RunConfig::default();
    base.model = "nlu-roberta".into();
    base.steps = 120;
    base.eval_batches = 10;
    base.epsilon = 1.0;
    base.c2 = 0.5;

    println!("synthetic SST-2-like task, vocab 50,265, eps = 1.0\n");

    // 1. DP-SGD, trainable embeddings
    let mut c1 = base.clone();
    c1.algorithm = Algorithm::DpSgd;
    let (acc1, _) = run_one(&rt, &c1)?;
    println!("dp-sgd (embeddings trained):   acc {acc1:.4}  reduction 1.0x");

    // 2. DP-SGD, frozen embeddings (Table 6)
    let mut c2 = base.clone();
    c2.algorithm = Algorithm::DpSgd;
    c2.freeze_embedding = true;
    let (acc2, _) = run_one(&rt, &c2)?;
    println!("dp-sgd (embeddings frozen):    acc {acc2:.4}  (Table 6: expect <= trained)");

    // 3. DP-AdaFEST on embeddings
    let mut c3 = base.clone();
    c3.algorithm = Algorithm::DpAdaFest;
    c3.sigma_ratio = 10.0;
    c3.tau = 2.0;
    let (acc3, red3) = run_one(&rt, &c3)?;
    println!("dp-adafest:                    acc {acc3:.4}  reduction {red3:.1}x");

    // 4. LoRA-on-embedding baseline (Table 1), analytic gradient size
    let model = rt.manifest.model("nlu-roberta")?;
    let v = model.attr_usize("vocab")? as f64;
    let d = model.attr_usize("d_model")? as f64;
    let r = 16f64;
    let lora_red = v * d / (v * r + r * d);
    let mut c4 = base.clone();
    c4.model = "nlu-roberta-loraemb16".into();
    c4.algorithm = Algorithm::DpSgd;
    let (acc4, _) = run_one(&rt, &c4)?;
    println!("lora-emb r=16 (dense dp-sgd):  acc {acc4:.4}  reduction {lora_red:.1}x (analytic)");

    println!(
        "\nTable-1 shape: DP-AdaFEST's measured reduction should exceed LoRA's\n\
         analytic {lora_red:.1}x at comparable accuracy; Table-6 shape: trained \n\
         embeddings beat frozen."
    );
    Ok(())
}
