//! Quickstart — the end-to-end driver (DESIGN.md "End-to-end validation").
//!
//! Trains the Criteo-small pCTR model for a few hundred steps on synthetic
//! ad-click data with each of: non-private SGD, vanilla DP-SGD, and
//! DP-AdaFEST — logging the loss curve — then prints the utility /
//! gradient-size comparison that is the paper's whole point.
//!
//! Run with: `cargo run --release --example quickstart` (after
//! `make artifacts`).

use anyhow::Result;

use sparse_dp_emb::config::RunConfig;
use sparse_dp_emb::coordinator::{Algorithm, Trainer};
use sparse_dp_emb::data::{CriteoConfig, SynthCriteo};
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::util::rng::Xoshiro256;

fn main() -> Result<()> {
    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}\n", rt.platform());

    let mut base = RunConfig::default();
    base.model = "criteo-small".into();
    base.steps = 300;
    base.eval_batches = 16;
    base.epsilon = 1.0;
    base.c2 = 0.5;

    let model = rt.manifest.model(&base.model)?;
    let vocabs = model.attr_usize_list("vocabs")?;
    let gen = SynthCriteo::new(CriteoConfig::new(vocabs, base.seed ^ 0xDA7A));

    let mut results = Vec::new();
    for algo in [Algorithm::NonPrivate, Algorithm::DpSgd, Algorithm::DpAdaFest] {
        let mut cfg = base.clone();
        cfg.algorithm = algo;
        if algo == Algorithm::DpAdaFest {
            cfg.sigma_ratio = 10.0;
            cfg.tau = 2.0;
        }
        println!("=== {} (eps={}) ===", algo.name(), cfg.epsilon);
        let mut trainer = Trainer::new(cfg.clone(), &rt)?;
        println!(
            "noise: sigma1={:.3} sigma2={:.3}",
            trainer.sigma1(), trainer.sigma2()
        );

        // explicit step loop so the loss curve is visible
        let mut rng = Xoshiro256::seed_from(cfg.seed ^ 0xBA7C4);
        for step in 0..cfg.steps {
            let batch = gen.batch(0, trainer.batch_size(), &mut rng);
            let stats = trainer.step_pctr(&batch)?;
            if step % 50 == 0 || step + 1 == cfg.steps {
                println!(
                    "  step {:>4}  loss {:.4}  emb-coords-noised {:>8}  survivors {:>6}",
                    step, stats.loss, stats.emb_coords_noised, stats.survivors
                );
            }
        }
        let eval: Vec<_> = (0..cfg.eval_batches)
            .map(|_| gen.batch(0, trainer.batch_size(), &mut rng))
            .collect();
        let (auc, eval_loss) = trainer.eval_pctr(&eval)?;
        println!(
            "  -> AUC {auc:.4}  eval-loss {eval_loss:.4}  grad-size reduction {:.1}x\n",
            trainer.meter().reduction_factor()
        );
        results.push((algo, auc, trainer.meter().reduction_factor()));
    }

    println!("=== summary ===");
    println!("{:<16} {:>8} {:>14}", "algorithm", "AUC", "reduction");
    for (algo, auc, red) in &results {
        println!("{:<16} {:>8.4} {:>13.1}x", algo.name(), auc, red);
    }
    println!(
        "\nThe paper's claim in miniature: DP-AdaFEST retains DP-SGD-level AUC\n\
         while noising a small fraction of the embedding coordinates."
    );
    Ok(())
}
