//! Streaming ads — the paper's §4.3 time-series scenario as a runnable
//! example: 24 days of drifting click data, trained day-by-day with a
//! streaming period, evaluated on the six held-out future days.
//!
//! Compares DP-FEST with first-day vs streaming frequency sources against
//! DP-AdaFEST — the example-level version of Figure 5.
//!
//! Run with: `cargo run --release --example streaming_ads`

use anyhow::Result;

use sparse_dp_emb::config::RunConfig;
use sparse_dp_emb::coordinator::{Algorithm, StreamingTrainer, Trainer};
use sparse_dp_emb::data::{CriteoConfig, SynthCriteo};
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::selection::FrequencySource;

fn main() -> Result<()> {
    let rt = Runtime::new("artifacts")?;

    let mut base = RunConfig::default();
    base.model = "criteo-small".into();
    base.steps = 180; // 10 per simulated day
    base.eval_batches = 12;
    base.epsilon = 1.0;
    base.c2 = 0.5;
    base.streaming_period = 1;
    base.fest_top_k = 4096;

    let model = rt.manifest.model(&base.model)?;
    let vocabs = model.attr_usize_list("vocabs")?;
    let gen = SynthCriteo::new(CriteoConfig::new(vocabs, base.seed ^ 0xDA7A).with_drift());

    let scenarios: Vec<(&str, Algorithm, FrequencySource)> = vec![
        ("dp-fest / first-day freq", Algorithm::DpFest, FrequencySource::FirstDay),
        ("dp-fest / streaming freq", Algorithm::DpFest, FrequencySource::Streaming),
        ("dp-adafest (per-batch)", Algorithm::DpAdaFest, FrequencySource::Streaming),
    ];

    println!("24-day drifting stream; train days 0-17, eval days 18-23\n");
    let mut summary = Vec::new();
    for (label, algo, source) in scenarios {
        let mut cfg = base.clone();
        cfg.algorithm = algo;
        cfg.freq_source = source;
        if algo == Algorithm::DpAdaFest {
            cfg.sigma_ratio = 10.0;
            cfg.tau = 2.0;
        }
        println!("=== {label} ===");
        let trainer = Trainer::new(cfg.clone(), &rt)?;
        let mut st = StreamingTrainer::new(trainer, 6);
        let out = st.run(&gen)?;
        print!("  per-day AUC (days 18..23):");
        for a in &out.per_day_auc {
            print!(" {a:.4}");
        }
        println!();
        println!(
            "  overall AUC {:.4}  reduction {:.1}x  reselections {}\n",
            out.outcome.utility, out.outcome.reduction_factor, out.reselections
        );
        summary.push((label, out.outcome.utility, out.outcome.reduction_factor));
    }

    println!("=== summary (paper Figure-5 shape) ===");
    for (label, auc, red) in summary {
        println!("{label:<28} AUC {auc:.4}  reduction {red:.1}x");
    }
    println!(
        "\nExpected ordering: streaming-frequency DP-FEST beats first-day;\n\
         DP-AdaFEST adapts per batch and achieves the best reduction at utility parity."
    );
    Ok(())
}
