"""AOT build: lower every step computation to HLO *text* + a JSON manifest.

HLO text — NOT ``lowered.compiler_ir("hlo")`` protos or ``.serialize()`` — is
the interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the pinned xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  ``python -m compile.aot --out-dir ../artifacts [--only pctr]``

The manifest records, for each artifact, the ordered input/output specs and
the model configuration (vocab sizes, row offsets, parameter inventory) that
the Rust coordinator needs to drive it.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is load-bearing: the default printer elides big
    # constant literals as `constant({...})`, which the HLO text parser then
    # silently reads back as garbage (we hit this with the row-offset vector
    # and the positional-encoding table).
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _spec_entry(name: str, shape: Tuple[int, ...], dtype: str) -> dict:
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _abstractify(entries: List[dict]) -> List[jax.ShapeDtypeStruct]:
    m = {"f32": jnp.float32, "i32": jnp.int32}
    return [_spec(e["shape"], m[e["dtype"]]) for e in entries]


def _out_entries(fn, in_specs: List[dict], names: List[str]) -> List[dict]:
    outs = jax.eval_shape(fn, *_abstractify(in_specs))
    assert len(outs) == len(names), f"{len(outs)} outputs vs {len(names)} names"
    dm = {jnp.dtype("float32"): "f32", jnp.dtype("int32"): "i32"}
    return [
        {"name": n, "shape": list(o.shape), "dtype": dm[jnp.dtype(o.dtype)]}
        for n, o in zip(names, outs)
    ]


# ---------------------------------------------------------------------------
# Artifact builders
# ---------------------------------------------------------------------------


def pctr_artifacts(cfg: configs.PctrConfig):
    b, nf = cfg.batch_size, len(cfg.vocabs)
    pspecs = model.pctr_param_specs(cfg)
    params_in = [_spec_entry(n, s, "f32") for n, s in pspecs]
    batch_in = [
        _spec_entry("cat_idx", (b, nf), "i32"),
        _spec_entry("x_num", (b, configs.NUM_NUMERIC_FEATURES), "f32"),
        _spec_entry("y", (b,), "f32"),
    ]
    clip_in = [_spec_entry("c1", (1,), "f32"), _spec_entry("c2", (1,), "f32")]

    mlp_names = [n for n, _ in pspecs if n.startswith("mlp_")]
    fwd = model.make_pctr_fwd(cfg)
    grads = model.make_pctr_grads(cfg)

    yield ("pctr_fwd", fwd, params_in + batch_in, ["loss", "logits"])
    yield (
        "pctr_grads",
        grads,
        params_in + batch_in + clip_in,
        ["loss"] + [f"grad_{n}" for n in mlp_names]
        + ["zgrads_scaled", "counts", "scales"],
    )


def nlu_artifacts(cfg: configs.NluConfig, prefix: str):
    b, t = cfg.batch_size, cfg.seq_len
    pspecs = model.nlu_param_specs(cfg)
    params_in = [_spec_entry(n, s, "f32") for n, s, _ in pspecs]
    batch_in = [
        _spec_entry("token_ids", (b, t), "i32"),
        _spec_entry("labels", (b,), "i32"),
    ]
    clip_in = [_spec_entry("c1", (1,), "f32"), _spec_entry("c2", (1,), "f32")]

    fwd = model.make_nlu_fwd(cfg)
    yield (f"{prefix}_fwd", fwd, params_in + batch_in, ["loss", "logits"])

    if cfg.emb_lora_rank == 0:
        step, names = model.make_nlu_grads(cfg)
        tail = ["zgrads_scaled", "counts", "scales"]
    else:
        step, names = model.make_nlu_lora_emb_grads(cfg)
        tail = ["aout_grads_scaled", "counts", "scales"]
    yield (
        f"{prefix}_grads",
        step,
        params_in + batch_in + clip_in,
        ["loss"] + [f"grad_{n}" for n in names] + tail,
    )


def model_manifest(cfg) -> dict:
    if isinstance(cfg, configs.PctrConfig):
        pspecs = model.pctr_param_specs(cfg)
        return {
            "kind": "pctr",
            "vocabs": cfg.vocabs,
            "dims": cfg.dims,
            "row_offsets": cfg.row_offsets,
            "total_vocab": cfg.total_vocab,
            "batch_size": cfg.batch_size,
            "hidden_dim": cfg.hidden_dim,
            "num_hidden_layers": cfg.num_hidden_layers,
            "num_numeric": configs.NUM_NUMERIC_FEATURES,
            "params": [
                {"name": n, "shape": list(s), "trainable": True} for n, s in pspecs
            ],
        }
    pspecs = model.nlu_param_specs(cfg)
    return {
        "kind": "nlu",
        "vocab": cfg.vocab,
        "seq_len": cfg.seq_len,
        "batch_size": cfg.batch_size,
        "d_model": cfg.d_model,
        "num_layers": cfg.num_layers,
        "num_heads": cfg.num_heads,
        "ff_dim": cfg.ff_dim,
        "lora_rank": cfg.lora_rank,
        "emb_lora_rank": cfg.emb_lora_rank,
        "num_classes": cfg.num_classes,
        "params": [
            {"name": n, "shape": list(s), "trainable": tr} for n, s, tr in pspecs
        ],
    }


def build(out_dir: str, only: str | None = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"artifacts": {}, "models": {}}

    plans = []
    pctr_cfg = configs.pctr_small()
    plans.append((pctr_cfg, "criteo-small", list(pctr_artifacts(pctr_cfg))))
    nlu_cfg = configs.nlu_roberta()
    plans.append((nlu_cfg, "nlu-roberta", list(nlu_artifacts(nlu_cfg, "nlu"))))
    xlmr_cfg = configs.nlu_xlmr()
    plans.append((xlmr_cfg, "nlu-xlmr", list(nlu_artifacts(xlmr_cfg, "nlu_xlmr"))))
    # LoRA-on-embedding baselines at several ranks (Table 1's r sweep)
    for r in (4, 16, 64):
        loraemb_cfg = configs.nlu_roberta(emb_lora_rank=r)
        plans.append(
            (loraemb_cfg, f"nlu-roberta-loraemb{r}",
             list(nlu_artifacts(loraemb_cfg, f"nlu_loraemb{r}")))
        )

    for cfg, model_name, artifacts in plans:
        manifest["models"][model_name] = model_manifest(cfg)
        for name, fn, in_specs, out_names in artifacts:
            if only and only not in name:
                continue
            out_specs = _out_entries(fn, in_specs, out_names)
            print(f"[aot] lowering {name} "
                  f"({len(in_specs)} inputs, {len(out_specs)} outputs)")
            lowered = jax.jit(fn).lower(*_abstractify(in_specs))
            text = to_hlo_text(lowered)
            fname = f"{name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            print(f"[aot]   wrote {fname}: {len(text)/1e6:.2f} MB")
            manifest["artifacts"][name] = {
                "file": fname,
                "model": model_name,
                "inputs": in_specs,
                "outputs": out_specs,
            }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    write_flat_manifest(manifest, os.path.join(out_dir, "manifest.txt"))
    print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts")


def write_flat_manifest(manifest: dict, path: str) -> None:
    """Line-oriented manifest for the Rust side (the vendored crate set has
    no JSON parser; this format is trivially whitespace-splittable).

    Grammar (one record per line, space-separated):
      model <name> <kind>
      attr  <model> <key> <value[,value...]>
      param <model> <param_name> <0|1 trainable> <d0,d1,...|scalar>
      artifact <name> <file> <model>
      in    <artifact> <name> <f32|i32> <dims|scalar>
      out   <artifact> <name> <f32|i32> <dims|scalar>
    """
    def dims(shape):
        return ",".join(str(s) for s in shape) if shape else "scalar"

    lines = []
    for mname, m in manifest["models"].items():
        lines.append(f"model {mname} {m['kind']}")
        for key, val in m.items():
            if key in ("kind", "params"):
                continue
            if isinstance(val, list):
                lines.append(f"attr {mname} {key} {','.join(str(v) for v in val)}")
            else:
                lines.append(f"attr {mname} {key} {val}")
        for p in m["params"]:
            tr = 1 if p["trainable"] else 0
            lines.append(f"param {mname} {p['name']} {tr} {dims(p['shape'])}")
    for aname, a in manifest["artifacts"].items():
        lines.append(f"artifact {aname} {a['file']} {a['model']}")
        for e in a["inputs"]:
            lines.append(f"in {aname} {e['name']} {e['dtype']} {dims(e['shape'])}")
        for e in a["outputs"]:
            lines.append(f"out {aname} {e['name']} {e['dtype']} {dims(e['shape'])}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--only", default=None, help="substring filter on artifact name")
    args = p.parse_args()
    build(args.out_dir, args.only)


if __name__ == "__main__":
    main()
