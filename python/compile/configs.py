"""Model configurations shared by the JAX build path and (via manifest.json)
the Rust runtime.

Two model families, mirroring the paper's evaluation (Section 4.1.1):

* ``pctr``  — the Criteo click-through-rate model: one embedding table per
  categorical feature (vocabulary sizes from Table 3 of the paper), embedding
  dimension ``int(2 * V ** 0.25)``, log-transformed numeric features, and a
  stack of fully-connected ReLU layers.
* ``nlu``   — a RoBERTa-stand-in transformer encoder with a real-size token
  vocabulary (50,265 for the RoBERTa tokenizer, 250,002 for XLM-R), LoRA
  adapters on the attention projections, and a trainable word-embedding table
  (the paper trains embeddings during DP fine-tuning; Table 6).

``criteo-small`` scales every vocabulary by 1/16 so that per-example-gradient
training runs comfortably on CPU; gradient-*size* accounting always happens at
the full Table-3 scale on the Rust side (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import List

# Vocabulary sizes of the 26 Criteo categorical features (paper Table 3,
# categorical-feature-14 .. categorical-feature-39, in order).
CRITEO_VOCABS: List[int] = [
    1472, 577, 82741, 18940, 305, 23, 1172, 633, 3, 9090, 5918, 64300, 3207,
    27, 1550, 44262, 10, 5485, 2161, 3, 56473, 17, 15, 27360, 104, 12934,
]

NUM_NUMERIC_FEATURES = 13  # 13 integer features, log-transformed upstream.

ROBERTA_VOCAB = 50_265
XLMR_VOCAB = 250_002


def embedding_dim(vocab: int) -> int:
    """The paper's heuristic rule: ``int(2 * V ** 0.25)`` (Appendix D.1.1)."""
    return max(2, int(2.0 * vocab ** 0.25))


@dataclasses.dataclass(frozen=True)
class PctrConfig:
    name: str
    vocabs: List[int]
    batch_size: int
    hidden_dim: int
    num_hidden_layers: int

    @property
    def dims(self) -> List[int]:
        return [embedding_dim(v) for v in self.vocabs]

    @property
    def total_embedding_dim(self) -> int:
        return sum(self.dims)

    @property
    def total_vocab(self) -> int:
        return sum(self.vocabs)

    @property
    def mlp_input_dim(self) -> int:
        return self.total_embedding_dim + NUM_NUMERIC_FEATURES

    @property
    def row_offsets(self) -> List[int]:
        """Start offset of each feature's rows in the concatenated id space."""
        offs, acc = [], 0
        for v in self.vocabs:
            offs.append(acc)
            acc += v
        return offs


@dataclasses.dataclass(frozen=True)
class NluConfig:
    name: str
    vocab: int
    seq_len: int
    batch_size: int
    d_model: int
    num_layers: int
    num_heads: int
    ff_dim: int
    lora_rank: int          # rank of the attention LoRA adapters
    num_classes: int
    emb_lora_rank: int = 0  # >0: freeze the table, train a LoRA (A, B) on it


def pctr_small() -> PctrConfig:
    """CPU-scale utility config: Table-3 vocabularies divided by 16."""
    return PctrConfig(
        name="criteo-small",
        vocabs=[max(4, v // 16) for v in CRITEO_VOCABS],
        batch_size=128,
        hidden_dim=128,
        num_hidden_layers=4,
    )


def pctr_full() -> PctrConfig:
    """Paper-scale config (Table 3 + 4x598 MLP). Used for gradient-size
    accounting and the Table-4 wall-clock bench; not trained on CPU."""
    return PctrConfig(
        name="criteo-full",
        vocabs=list(CRITEO_VOCABS),
        batch_size=2048,
        hidden_dim=598,
        num_hidden_layers=4,
    )


def nlu_roberta(emb_lora_rank: int = 0) -> NluConfig:
    return NluConfig(
        name="nlu-roberta" + (f"-loraemb{emb_lora_rank}" if emb_lora_rank else ""),
        vocab=ROBERTA_VOCAB,
        seq_len=32,
        batch_size=64,
        d_model=64,
        num_layers=2,
        num_heads=4,
        ff_dim=128,
        lora_rank=16,
        num_classes=2,
        emb_lora_rank=emb_lora_rank,
    )


def nlu_xlmr() -> NluConfig:
    return NluConfig(
        name="nlu-xlmr",
        vocab=XLMR_VOCAB,
        seq_len=32,
        batch_size=64,
        d_model=64,
        num_layers=2,
        num_heads=4,
        ff_dim=128,
        lora_rank=16,
        num_classes=3,  # XNLI is 3-way
        emb_lora_rank=0,
    )
