"""L1 — Pallas kernels for the sparsity-preserving DP training hot-spots.

Every kernel has a pure-``jnp`` oracle in :mod:`ref` and is validated against
it in ``python/tests/test_kernels.py`` (hypothesis sweeps over shapes and
dtypes).  All kernels run with ``interpret=True``: real-TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute, so on this image
the interpret path is the correctness target and TPU performance is estimated
analytically (DESIGN.md §Hardware-Adaptation).
"""

from .clip_scale import clip_scale
from .contribution_map import contribution_map
from .embedding_lookup import embedding_lookup, embedding_lookup_tiled
from .row_scatter import row_scatter, scale_grads

__all__ = [
    "clip_scale",
    "contribution_map",
    "embedding_lookup",
    "embedding_lookup_tiled",
    "row_scatter",
    "scale_grads",
]
