"""Pallas per-example clip-factor kernel (DP-SGD / Algorithm 1, line 5 & 9).

Computes ``s_i = min(1, C / ||g_i||_2)`` from a matrix of per-part squared
norms.  This is pure VPU element-wise work; on TPU it tiles to (8, 128)
vector lanes with a single row-reduction, negligible next to the backward
pass that produced the norms.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _clip_scale_kernel(sq_ref, c_ref, o_ref):
    sq = sq_ref[...]
    norms = jnp.sqrt(jnp.maximum(sq.sum(axis=-1), 1e-24))
    o_ref[...] = jnp.minimum(1.0, c_ref[0] / norms)


@jax.jit
def clip_scale(sq_norm_parts: jnp.ndarray, clip_norm: jnp.ndarray) -> jnp.ndarray:
    """``sq_norm_parts`` (B, K) f32, ``clip_norm`` scalar f32 → (B,) f32."""
    b, _ = sq_norm_parts.shape
    c = jnp.asarray(clip_norm, jnp.float32).reshape((1,))
    return pl.pallas_call(
        _clip_scale_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(sq_norm_parts.astype(jnp.float32), c)
