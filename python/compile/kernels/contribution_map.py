"""Pallas gradient-contribution-map kernel (Algorithm 1, lines 5–6).

Accumulates the batch-wise contribution map ``sum_i [v_i]_{C1}`` — the
l2-clipped indicator of which embedding rows each example activates — as a
*scatter-add* over the concatenated row space.  The Gaussian noise of line 6
is injected on the Rust side (all randomness lives in L3), so this kernel is
the deterministic, per-batch part.

TPU mapping: this is exactly the shape of a SparseCore scatter — the output
count vector is partitioned across memory channels and the (id, weight)
stream is routed by id.  Under ``interpret=True`` the scatter executes as an
XLA scatter-add.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _contribution_kernel(idx_ref, w_ref, o_ref):
    flat_idx = idx_ref[...].reshape(-1)
    flat_w = w_ref[...].reshape(-1)
    z = jnp.zeros(o_ref.shape, o_ref.dtype)
    o_ref[...] = z.at[flat_idx].add(flat_w)


@functools.partial(jax.jit, static_argnames=("num_rows",))
def contribution_map(idx: jnp.ndarray, weights: jnp.ndarray, num_rows: int) -> jnp.ndarray:
    """``idx`` (B, F) int32, ``weights`` (B, F) f32 → (num_rows,) f32 counts."""
    return pl.pallas_call(
        _contribution_kernel,
        out_shape=jax.ShapeDtypeStruct((num_rows,), jnp.float32),
        interpret=True,
    )(idx, weights.astype(jnp.float32))
