"""Pallas embedding-lookup kernel (paper §2.1, Figure 1a).

Forward pass of an embedding layer as a *gather* — never a one-hot matmul.

TPU mapping (DESIGN.md §Hardware-Adaptation): the table lives in HBM; the
grid iterates over batch tiles, and the ``BlockSpec`` schedule streams only
the ≤ B activated rows into VMEM per step (B·d ≪ c·d, so the working set
fits the ~16 MiB VMEM scratchpad where the dense table cannot).  On this CPU
image the kernel runs under ``interpret=True`` (real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lookup_kernel(table_ref, idx_ref, o_ref):
    # Whole-block load + vectorized gather.  On TPU the table block would be
    # staged by the BlockSpec; the gather itself maps to the SparseCore-style
    # dynamic-gather unit rather than the MXU.
    o_ref[...] = table_ref[...][idx_ref[...]]


@functools.partial(jax.jit, static_argnames=("block_b",))
def embedding_lookup(table: jnp.ndarray, idx: jnp.ndarray, *, block_b: int | None = None):
    """``z[i, :] = table[idx[i], :]`` for a flat index vector ``idx``.

    ``table`` (c, d) f32/bf16, ``idx`` (B,) int32 → (B, d).
    """
    b = idx.shape[0]
    c, d = table.shape
    return pl.pallas_call(
        _lookup_kernel,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=True,
    )(table, idx)


def _lookup_grid_kernel(idx_ref, table_ref, o_ref):
    # Grid variant: one program per batch tile; dynamic row fetch per slot.
    # Demonstrates the HBM→VMEM row-streaming schedule explicitly.
    rows = table_ref[...][idx_ref[...]]
    o_ref[...] = rows


def embedding_lookup_tiled(table: jnp.ndarray, idx: jnp.ndarray, block_b: int = 8):
    """Tiled variant: grid over batch tiles of ``block_b`` (the TPU-shaped
    schedule).  Identical numerics to :func:`embedding_lookup`."""
    b = idx.shape[0]
    c, d = table.shape
    assert b % block_b == 0, "batch must be divisible by block_b"
    return pl.pallas_call(
        _lookup_grid_kernel,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        interpret=True,
    )(idx, table)
