"""Pure-``jnp`` reference oracles for every Pallas kernel.

These are the ground truth the kernels are tested against (pytest +
hypothesis in ``python/tests/test_kernels.py``).  They are intentionally the
most direct possible transcription of the math in the paper — no tiling, no
memory tricks.
"""

from __future__ import annotations

import jax.numpy as jnp


def embedding_lookup_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Forward embedding lookup: ``z = W[idx, :]`` (paper §2.1, Figure 1a)."""
    return table[idx]


def clip_scale_ref(sq_norm_parts: jnp.ndarray, clip_norm: jnp.ndarray) -> jnp.ndarray:
    """Per-example clip factors ``s_i = min(1, C / ||g_i||_2)``.

    ``sq_norm_parts`` is a ``(B, K)`` matrix whose row ``i`` holds the squared
    l2 norms of the ``K`` parts of example ``i``'s gradient (MLP part,
    embedding part, ...).  Returns the ``(B,)`` scale vector.
    """
    norms = jnp.sqrt(jnp.maximum(sq_norm_parts.sum(axis=-1), 1e-24))
    return jnp.minimum(1.0, clip_norm / norms)


def contribution_map_ref(
    idx: jnp.ndarray, weights: jnp.ndarray, num_rows: int
) -> jnp.ndarray:
    """Batch-wise gradient contribution map ``sum_i [v_i]_{C1}``
    (Algorithm 1, line 6 — pre-noise part).

    ``idx``     (B, F) int32 — activated row ids (already offset into the
                concatenated row space across features / token positions).
    ``weights`` (B, F) f32  — per-entry clipped contribution weight.  For a
                single-valued categorical batch this is
                ``min(1, C1/sqrt(F))`` broadcast; for text it is
                ``min(1, C1/sqrt(n_unique_i)) / multiplicity`` so repeated
                tokens contribute once per example.
    Returns ``(num_rows,)`` f32 counts.
    """
    flat_idx = idx.reshape(-1)
    flat_w = weights.reshape(-1).astype(jnp.float32)
    return jnp.zeros((num_rows,), jnp.float32).at[flat_idx].add(flat_w)


def row_scatter_ref(
    idx: jnp.ndarray, grads: jnp.ndarray, scales: jnp.ndarray, num_rows: int
) -> jnp.ndarray:
    """Clipped embedding-gradient accumulation ``sum_i s_i * (x_i ⊗ dL/dz_i)``
    restricted to the activated rows (Algorithm 1, line 9 — pre-noise part).

    ``idx``    (B, F) int32 — row ids per example and slot.
    ``grads``  (B, F, d) f32 — per-slot gradient w.r.t. the embedding output.
    ``scales`` (B,) f32 — per-example clip factor.
    Returns the dense ``(num_rows, d)`` accumulated gradient (dense only in
    the oracle; the real pipeline keeps it row-sparse in Rust).
    """
    b, f, d = grads.shape
    scaled = grads * scales[:, None, None]
    flat_idx = idx.reshape(-1)
    flat_g = scaled.reshape(-1, d)
    return jnp.zeros((num_rows, d), jnp.float32).at[flat_idx].add(flat_g)


def scattered_sq_norm_ref(idx: jnp.ndarray, grads: jnp.ndarray) -> jnp.ndarray:
    """Per-example squared l2 norm of the *scattered* embedding gradient.

    When one example activates the same row several times (repeated tokens),
    the per-slot gradients add in the table row, so the scattered norm is
    ``|| sum over slots with equal id ||^2`` — not the sum of per-slot norms.

    ``idx``   (B, T) int32, ``grads`` (B, T, d) f32 → (B,) f32.
    """
    gram = jnp.einsum("btd,bsd->bts", grads, grads)
    same = (idx[:, :, None] == idx[:, None, :]).astype(grads.dtype)
    return (gram * same).sum(axis=(1, 2))


def unique_weights_ref(idx: jnp.ndarray, clip_norm: jnp.ndarray) -> jnp.ndarray:
    """Per-slot contribution weights for multi-slot (text) inputs.

    Each example's contribution-map vector ``v_i`` is the 0/1 indicator of
    its *unique* ids, l2-clipped to ``C1``.  Splitting the clipped weight of
    each unique id equally across its slots yields per-slot weights
    ``w[b, t] = min(1, C1/sqrt(u_b)) / m_{b,t}`` where ``u_b`` is the number
    of unique ids in example ``b`` and ``m_{b,t}`` the multiplicity of slot
    ``t``'s id within the example.  Scattering these per-slot weights gives
    exactly the clipped per-unique-id contribution.
    """
    same = (idx[:, :, None] == idx[:, None, :]).astype(jnp.float32)
    mult = same.sum(axis=-1)                      # (B, T) multiplicities
    n_unique = (1.0 / mult).sum(axis=-1)          # (B,)
    clipped = jnp.minimum(1.0, clip_norm / jnp.sqrt(jnp.maximum(n_unique, 1e-12)))
    return clipped[:, None] / mult
