"""Pallas clipped row-scatter kernel (Algorithm 1, line 9 — pre-noise part).

Scales each example's embedding-output gradients by its clip factor and
scatter-adds them into table rows: ``G[r, :] += s_i * dL/dz_{i,t}`` for every
slot ``(i, t)`` with ``idx[i, t] == r``.

In the production pipeline the scatter destination stays *row-sparse* and is
assembled in Rust (only activated rows ever exist); this kernel is the dense
oracle-shaped variant used (a) for kernel-level validation and (b) in the
fused single-artifact path for small tables.  A second entry point,
``scale_grads``, is the part that ships inside the AOT step artifact: it
applies the clip scales and leaves the (idx, value) pairs for the Rust
scatter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row_scatter_kernel(idx_ref, g_ref, s_ref, o_ref):
    b, f, d = g_ref.shape
    scaled = g_ref[...] * s_ref[...][:, None, None]
    z = jnp.zeros(o_ref.shape, o_ref.dtype)
    o_ref[...] = z.at[idx_ref[...].reshape(-1)].add(scaled.reshape(-1, d))


@functools.partial(jax.jit, static_argnames=("num_rows",))
def row_scatter(idx, grads, scales, num_rows: int):
    """``idx`` (B,F) i32, ``grads`` (B,F,d) f32, ``scales`` (B,) f32
    → dense (num_rows, d) accumulated clipped gradient."""
    b, f, d = grads.shape
    return pl.pallas_call(
        _row_scatter_kernel,
        out_shape=jax.ShapeDtypeStruct((num_rows, d), jnp.float32),
        interpret=True,
    )(idx, grads.astype(jnp.float32), scales.astype(jnp.float32))


def _scale_grads_kernel(g_ref, s_ref, o_ref):
    o_ref[...] = g_ref[...] * s_ref[...][:, None, None]


@jax.jit
def scale_grads(grads, scales):
    """Per-example clip scaling only: (B,F,d) * (B,) → (B,F,d).

    The Rust coordinator owns the sparse scatter (its destination is the
    row-sparse update structure, not a dense table)."""
    b, f, d = grads.shape
    return pl.pallas_call(
        _scale_grads_kernel,
        out_shape=jax.ShapeDtypeStruct((b, f, d), jnp.float32),
        interpret=True,
    )(grads.astype(jnp.float32), scales.astype(jnp.float32))
