"""L2 — JAX step computations for the paper's two model families.

Everything here is *build-time only*: ``aot.py`` lowers these functions once
to HLO text, and the Rust coordinator executes the artifacts on the PJRT CPU
client.  Three invariants shape the design:

1. **All randomness lives in Rust.**  The step functions are deterministic:
   they return clipped gradient *sums*, per-example embedding-output
   gradients, and the pre-noise contribution map.  Gaussian noise (σ₁ on the
   contribution map, σ₂ on gradients — Algorithm 1 lines 6 and 9) is injected
   by the L3 coordinator, which also owns privacy accounting.

2. **Embedding gradients never materialise densely.**  Per-example gradients
   are taken w.r.t. the embedding *outputs* ``z`` (``B×d`` per feature /
   ``B×T×d`` for text) — the sparse table gradient is ``x ⊗ ∂L/∂z`` (paper
   §2.1) and is assembled row-sparsely in Rust by scatter-add.

3. **Per-example clipping is exact.**  The clip norm covers the full gradient
   (dense params + scattered embedding rows); for text, repeated tokens in an
   example add within a row, so the scattered norm uses the pairwise-Gram
   identity (see ``kernels.ref.scattered_sq_norm_ref``).

Parameter lists are flat and ordered; ``aot.py`` records the order in
``artifacts/manifest.json`` for the Rust side.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import NluConfig, PctrConfig
from .kernels import clip_scale, contribution_map, embedding_lookup, scale_grads

# ---------------------------------------------------------------------------
# pCTR model (Criteo): per-feature embedding tables + ReLU MLP tower.
# ---------------------------------------------------------------------------


def pctr_param_specs(cfg: PctrConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) of every trainable parameter."""
    specs = [(f"table_{f:02d}", (v, d)) for f, (v, d) in enumerate(zip(cfg.vocabs, cfg.dims))]
    in_dim = cfg.mlp_input_dim
    for i in range(cfg.num_hidden_layers):
        specs.append((f"mlp_w{i}", (in_dim, cfg.hidden_dim)))
        specs.append((f"mlp_b{i}", (cfg.hidden_dim,)))
        in_dim = cfg.hidden_dim
    specs.append(("mlp_wout", (in_dim, 1)))
    specs.append(("mlp_bout", (1,)))
    return specs


def pctr_init(cfg: PctrConfig, seed: int = 0) -> List[np.ndarray]:
    """He-ish init matching the Rust ParamStore's (they must agree in shape,
    not value — Rust owns the canonical init)."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in pctr_param_specs(cfg):
        if name.startswith("table_"):
            out.append(rng.normal(0.0, 0.05, size=shape).astype(np.float32))
        elif name.endswith(tuple("0123")) or name == "mlp_wout":
            fan_in = shape[0] if len(shape) == 2 else 1
            out.append(rng.normal(0.0, (2.0 / fan_in) ** 0.5, size=shape).astype(np.float32))
        else:
            out.append(np.zeros(shape, np.float32))
    return out


def _split_pctr_params(cfg: PctrConfig, params):
    nf = len(cfg.vocabs)
    tables = list(params[:nf])
    mlp = list(params[nf:])
    return tables, mlp


def _mlp_forward(mlp, h):
    """ReLU tower; ``mlp`` alternates (w, b), last pair is the linear head."""
    n = len(mlp) // 2 - 1
    for i in range(n):
        h = jax.nn.relu(h @ mlp[2 * i] + mlp[2 * i + 1])
    return (h @ mlp[-2] + mlp[-1])[..., 0]


def _bce_with_logits(logit, y):
    # softplus(logit) - y*logit is the numerically stable BCE.
    return jax.nn.softplus(logit) - y * logit


def pctr_forward(cfg: PctrConfig, params, cat_idx, x_num, use_kernels=True):
    """Batch forward: returns logits (B,).

    ``use_kernels=False`` swaps the Pallas gather for a plain ``table[idx]``
    — needed when callers differentiate *through* the lookup (tests comparing
    against autodiff); the artifacts always use the kernel path.
    """
    tables, mlp = _split_pctr_params(cfg, params)
    lookup = embedding_lookup if use_kernels else (lambda t, i: t[i])
    zs = [lookup(t, cat_idx[:, f]) for f, t in enumerate(tables)]
    h = jnp.concatenate(zs + [x_num], axis=-1)
    return _mlp_forward(mlp, h)


def make_pctr_fwd(cfg: PctrConfig, use_kernels: bool = True):
    """Artifact ``pctr_fwd``: (params..., cat_idx, x_num, y) → (loss, logits)."""

    def fwd(*args):
        np_ = len(pctr_param_specs(cfg))
        params, (cat_idx, x_num, y) = list(args[:np_]), args[np_:]
        logits = pctr_forward(cfg, params, cat_idx, x_num, use_kernels)
        loss = _bce_with_logits(logits, y).mean()
        return (loss, logits)

    return fwd


def make_pctr_grads(cfg: PctrConfig):
    """Artifact ``pctr_grads``.

    Inputs : params..., cat_idx (B,26) i32, x_num (B,13) f32, y (B,) f32,
             c1 (1,) f32, c2 (1,) f32.
    Outputs: loss (),
             clipped-sum MLP grads (one per MLP param, same shapes),
             zgrads_scaled (B, D_emb) f32  — sᵢ·∂L/∂z, concatenated features,
             counts (c_total,) f32         — Σᵢ [vᵢ]_{C1}, pre-noise,
             scales (B,) f32               — the clip factors sᵢ.
    """
    nf = len(cfg.vocabs)
    np_ = len(pctr_param_specs(cfg))
    dims = cfg.dims
    offsets = jnp.asarray(cfg.row_offsets, jnp.int32)
    c_total = cfg.total_vocab

    def step(*args):
        params = list(args[:np_])
        cat_idx, x_num, y, c1, c2 = args[np_:]
        tables, mlp = _split_pctr_params(cfg, params)

        # Embedding outputs via the Pallas gather kernel (no grad through it:
        # we differentiate w.r.t. z directly).
        zs = [embedding_lookup(t, cat_idx[:, f]) for f, t in enumerate(tables)]
        zcat = jnp.concatenate(zs, axis=-1)  # (B, D_emb)

        def loss_one(mlp_params, z_row, xnum_row, y_row):
            h = jnp.concatenate([z_row, xnum_row], axis=-1)
            logit = _mlp_forward(mlp_params, h[None, :])[0]
            return _bce_with_logits(logit, y_row)

        per_ex = jax.vmap(
            jax.value_and_grad(loss_one, argnums=(0, 1)),
            in_axes=(None, 0, 0, 0),
        )
        losses, (mlp_g, z_g) = per_ex(mlp, zcat, x_num, y)

        # Per-example squared norms: dense part + embedding part.  Each
        # example touches one distinct row per feature (disjoint tables), so
        # the scattered embedding norm is just ||z_g||².
        sq_mlp = sum(jnp.square(g).reshape(g.shape[0], -1).sum(-1) for g in mlp_g)
        sq_emb = jnp.square(z_g).sum(-1)
        scales = clip_scale(jnp.stack([sq_mlp, sq_emb], axis=-1), c2[0])

        clipped_mlp = [jnp.einsum("b,b...->...", scales, g) for g in mlp_g]
        zgrads_scaled = scale_grads(z_g[:, None, :], scales)[:, 0, :]

        # Contribution map: every example activates exactly one bucket per
        # feature ⇒ ||v_i||₂ = √F, clipped weight min(1, C1/√F).
        w = jnp.minimum(1.0, c1[0] / jnp.sqrt(float(nf)))
        weights = jnp.full(cat_idx.shape, 1.0, jnp.float32) * w
        offset_idx = cat_idx + offsets[None, :]
        counts = contribution_map(offset_idx, weights, c_total)

        return (losses.mean(), *clipped_mlp, zgrads_scaled, counts, scales)

    return step


# ---------------------------------------------------------------------------
# NLU model: transformer encoder + LoRA adapters, trainable word embeddings.
# ---------------------------------------------------------------------------


def nlu_param_specs(cfg: NluConfig):
    """Ordered (name, shape, trainable) for the NLU model."""
    d, r, ff = cfg.d_model, cfg.lora_rank, cfg.ff_dim
    specs: List[Tuple[str, Tuple[int, ...], bool]] = []
    specs.append(("emb_table", (cfg.vocab, d), cfg.emb_lora_rank == 0))
    if cfg.emb_lora_rank > 0:
        specs.append(("emb_lora_a", (cfg.vocab, cfg.emb_lora_rank), True))
        specs.append(("emb_lora_b", (cfg.emb_lora_rank, d), True))
    for l in range(cfg.num_layers):
        for nm in ("wq", "wk", "wv", "wo"):
            specs.append((f"l{l}_{nm}", (d, d), False))
            specs.append((f"l{l}_{nm}_b", (d,), False))
        specs.append((f"l{l}_ln1_g", (d,), False))
        specs.append((f"l{l}_ln1_b", (d,), False))
        specs.append((f"l{l}_ff1", (d, ff), False))
        specs.append((f"l{l}_ff1_b", (ff,), False))
        specs.append((f"l{l}_ff2", (ff, d), False))
        specs.append((f"l{l}_ff2_b", (d,), False))
        specs.append((f"l{l}_ln2_g", (d,), False))
        specs.append((f"l{l}_ln2_b", (d,), False))
        # LoRA on Q and V projections (the [HSW+22] default).
        specs.append((f"l{l}_lora_aq", (d, r), True))
        specs.append((f"l{l}_lora_bq", (r, d), True))
        specs.append((f"l{l}_lora_av", (d, r), True))
        specs.append((f"l{l}_lora_bv", (r, d), True))
    specs.append(("head_w", (d, cfg.num_classes), True))
    specs.append(("head_b", (cfg.num_classes,), True))
    return specs


def nlu_init(cfg: NluConfig, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    out = []
    for name, shape, _ in nlu_param_specs(cfg):
        if name.endswith(("_b", "ln1_b", "ln2_b")) or name in ("head_b",):
            out.append(np.zeros(shape, np.float32))
        elif "ln" in name and name.endswith("_g"):
            out.append(np.ones(shape, np.float32))
        elif "lora_b" in name or name == "emb_lora_b":
            out.append(np.zeros(shape, np.float32))  # LoRA B starts at zero
        else:
            fan_in = shape[0] if len(shape) == 2 else 1
            out.append(rng.normal(0.0, fan_in ** -0.5, size=shape).astype(np.float32))
    return out


def _posenc(seq_len: int, d: int) -> jnp.ndarray:
    pos = np.arange(seq_len)[:, None]
    i = np.arange(d)[None, :]
    angle = pos / np.power(10000.0, (2 * (i // 2)) / d)
    pe = np.where(i % 2 == 0, np.sin(angle), np.cos(angle))
    return jnp.asarray(pe, jnp.float32)


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _encoder_from_z(cfg: NluConfig, frozen, lora, head, z):
    """Single-example transformer forward from embedding output ``z`` (T, d).

    ``frozen``: dict name→array of the non-trainable backbone.
    ``lora``:   dict name→array of the trainable adapters.
    Returns logits (num_classes,).
    """
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    t = cfg.seq_len
    x = z + _posenc(t, d)
    for l in range(cfg.num_layers):
        wq = frozen[f"l{l}_wq"] + lora[f"l{l}_lora_aq"] @ lora[f"l{l}_lora_bq"]
        wv = frozen[f"l{l}_wv"] + lora[f"l{l}_lora_av"] @ lora[f"l{l}_lora_bv"]
        q = (x @ wq + frozen[f"l{l}_wq_b"]).reshape(t, h, dh)
        k = (x @ frozen[f"l{l}_wk"] + frozen[f"l{l}_wk_b"]).reshape(t, h, dh)
        v = (x @ wv + frozen[f"l{l}_wv_b"]).reshape(t, h, dh)
        att = jnp.einsum("thd,shd->hts", q, k) / jnp.sqrt(float(dh))
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("hts,shd->thd", att, v).reshape(t, d)
        o = o @ frozen[f"l{l}_wo"] + frozen[f"l{l}_wo_b"]
        x = _layer_norm(x + o, frozen[f"l{l}_ln1_g"], frozen[f"l{l}_ln1_b"])
        f = jax.nn.gelu(x @ frozen[f"l{l}_ff1"] + frozen[f"l{l}_ff1_b"])
        f = f @ frozen[f"l{l}_ff2"] + frozen[f"l{l}_ff2_b"]
        x = _layer_norm(x + f, frozen[f"l{l}_ln2_g"], frozen[f"l{l}_ln2_b"])
    pooled = x.mean(axis=0)
    return pooled @ head["head_w"] + head["head_b"]


def _split_nlu(cfg: NluConfig, params):
    specs = nlu_param_specs(cfg)
    frozen, lora, head = {}, {}, {}
    emb = {}
    for (name, _, _), arr in zip(specs, params):
        if name.startswith("emb"):
            emb[name] = arr
        elif name.startswith("head"):
            head[name] = arr
        elif "lora" in name:
            lora[name] = arr
        else:
            frozen[name] = arr
    return emb, frozen, lora, head


def _ce_loss(logits, label):
    return -jax.nn.log_softmax(logits)[label]


def _pairwise_scattered_sqnorm(ids, grads):
    """(B,T) ids, (B,T,r) grads → (B,) scattered squared norms (Gram trick)."""
    gram = jnp.einsum("btd,bsd->bts", grads, grads)
    same = (ids[:, :, None] == ids[:, None, :]).astype(grads.dtype)
    return (gram * same).sum(axis=(1, 2))


def _unique_token_weights(ids, c1):
    """Per-slot contribution weights (see kernels.ref.unique_weights_ref)."""
    same = (ids[:, :, None] == ids[:, None, :]).astype(jnp.float32)
    mult = same.sum(axis=-1)
    n_unique = (1.0 / mult).sum(axis=-1)
    clipped = jnp.minimum(1.0, c1 / jnp.sqrt(jnp.maximum(n_unique, 1e-12)))
    return clipped[:, None] / mult


def make_nlu_fwd(cfg: NluConfig, use_kernels: bool = True):
    """Artifact ``nlu_fwd``: (params..., token_ids, labels) → (loss, logits)."""
    np_ = len(nlu_param_specs(cfg))
    lookup = embedding_lookup if use_kernels else (lambda t, i: t[i])

    def fwd(*args):
        params = list(args[:np_])
        token_ids, labels = args[np_:]
        emb, frozen, lora, head = _split_nlu(cfg, params)
        b = token_ids.shape[0]
        flat = token_ids.reshape(-1)
        z = lookup(emb["emb_table"], flat).reshape(b, cfg.seq_len, cfg.d_model)
        if cfg.emb_lora_rank > 0:
            a_out = lookup(emb["emb_lora_a"], flat).reshape(
                b, cfg.seq_len, cfg.emb_lora_rank)
            z = z + a_out @ emb["emb_lora_b"]
        logits = jax.vmap(lambda zz: _encoder_from_z(cfg, frozen, lora, head, zz))(z)
        losses = jax.vmap(_ce_loss)(logits, labels)
        return (losses.mean(), logits)

    return fwd


def make_nlu_grads(cfg: NluConfig):
    """Artifact ``nlu_grads`` (trainable embedding table; Table 6 'trained').

    Inputs : params..., token_ids (B,T) i32, labels (B,) i32, c1 (1,), c2 (1,).
    Outputs: loss,
             clipped-sum grads for every trainable non-embedding param
             (LoRA a/b per layer + head_w/head_b, in spec order),
             zgrads_scaled (B,T,d) — sᵢ·∂L/∂z per token position,
             counts (V,)           — pre-noise contribution map,
             scales (B,).
    """
    assert cfg.emb_lora_rank == 0
    np_ = len(nlu_param_specs(cfg))

    def step(*args):
        params = list(args[:np_])
        token_ids, labels, c1, c2 = args[np_:]
        emb, frozen, lora, head = _split_nlu(cfg, params)
        b, t = token_ids.shape
        flat = token_ids.reshape(-1)
        z = embedding_lookup(emb["emb_table"], flat).reshape(b, t, cfg.d_model)

        lora_names = sorted(lora)
        head_names = sorted(head)

        def loss_one(train_vec, z_row, label):
            lora_d = {n: v for n, v in zip(lora_names, train_vec[:-2])}
            head_d = {n: v for n, v in zip(head_names, train_vec[-2:])}
            logits = _encoder_from_z(cfg, frozen, lora_d, head_d, z_row)
            return _ce_loss(logits, label)

        train_vec = [lora[n] for n in lora_names] + [head[n] for n in head_names]
        per_ex = jax.vmap(
            jax.value_and_grad(loss_one, argnums=(0, 1)),
            in_axes=(None, 0, 0),
        )
        losses, (tg, z_g) = per_ex(train_vec, z, labels)

        sq_dense = sum(jnp.square(g).reshape(b, -1).sum(-1) for g in tg)
        sq_emb = _pairwise_scattered_sqnorm(token_ids, z_g)
        scales = clip_scale(jnp.stack([sq_dense, sq_emb], axis=-1), c2[0])

        clipped = [jnp.einsum("b,b...->...", scales, g) for g in tg]
        zgrads_scaled = scale_grads(z_g, scales)

        weights = _unique_token_weights(token_ids, c1[0])
        counts = contribution_map(token_ids, weights, cfg.vocab)

        return (losses.mean(), *clipped, zgrads_scaled, counts, scales)

    return step, [*sorted([f"l{l}_lora_{nm}" for l in range(cfg.num_layers)
                           for nm in ("aq", "bq", "av", "bv")]),
                  "head_b", "head_w"]


def make_nlu_lora_emb_grads(cfg: NluConfig):
    """Artifact ``nlu_loraemb_grads`` (Table 1 baseline: frozen table, LoRA
    (A, B) on the embedding — dense-noise path on A and B in Rust).

    Outputs: loss,
             clipped-sum grads for LoRA-attn + head + emb_lora_b,
             aout_grads_scaled (B,T,r_e) — sᵢ·∂L/∂(A[idₜ]) rows,
             counts (V,), scales (B,).
    """
    assert cfg.emb_lora_rank > 0
    np_ = len(nlu_param_specs(cfg))
    r_e = cfg.emb_lora_rank

    def step(*args):
        params = list(args[:np_])
        token_ids, labels, c1, c2 = args[np_:]
        emb, frozen, lora, head = _split_nlu(cfg, params)
        b, t = token_ids.shape
        flat = token_ids.reshape(-1)
        z0 = embedding_lookup(emb["emb_table"], flat).reshape(b, t, cfg.d_model)
        a_out = embedding_lookup(emb["emb_lora_a"], flat).reshape(b, t, r_e)

        lora_names = sorted(lora)
        head_names = sorted(head)

        def loss_one(train_vec, z0_row, aout_row, label):
            lora_d = {n: v for n, v in zip(lora_names, train_vec[:-3])}
            head_d = {n: v for n, v in zip(head_names, train_vec[-3:-1])}
            emb_b = train_vec[-1]
            z_row = z0_row + aout_row @ emb_b
            logits = _encoder_from_z(cfg, frozen, lora_d, head_d, z_row)
            return _ce_loss(logits, label)

        train_vec = [lora[n] for n in lora_names] + [head[n] for n in head_names] \
            + [emb["emb_lora_b"]]
        per_ex = jax.vmap(
            jax.value_and_grad(loss_one, argnums=(0, 2)),
            in_axes=(None, 0, 0, 0),
        )
        losses, (tg, aout_g) = per_ex(train_vec, z0, a_out, labels)

        sq_dense = sum(jnp.square(g).reshape(b, -1).sum(-1) for g in tg)
        sq_a = _pairwise_scattered_sqnorm(token_ids, aout_g)
        scales = clip_scale(jnp.stack([sq_dense, sq_a], axis=-1), c2[0])

        clipped = [jnp.einsum("b,b...->...", scales, g) for g in tg]
        aout_scaled = scale_grads(aout_g, scales)

        weights = _unique_token_weights(token_ids, c1[0])
        counts = contribution_map(token_ids, weights, cfg.vocab)

        return (losses.mean(), *clipped, aout_scaled, counts, scales)

    names = [*sorted([f"l{l}_lora_{nm}" for l in range(cfg.num_layers)
                      for nm in ("aq", "bq", "av", "bv")]),
             "head_b", "head_w", "emb_lora_b"]
    return step, names
