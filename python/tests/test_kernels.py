"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; numpy oracles recomputed per case.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    clip_scale,
    contribution_map,
    embedding_lookup,
    embedding_lookup_tiled,
    row_scatter,
    scale_grads,
)
from compile.kernels import ref

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# embedding_lookup
# ---------------------------------------------------------------------------


@given(
    c=st.integers(2, 300),
    d=st.integers(1, 64),
    b=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_lookup_matches_ref(c, d, b, seed):
    r = rng(seed)
    table = r.normal(size=(c, d)).astype(np.float32)
    idx = r.integers(0, c, size=b).astype(np.int32)
    got = embedding_lookup(jnp.asarray(table), jnp.asarray(idx))
    want = ref.embedding_lookup_ref(jnp.asarray(table), jnp.asarray(idx))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@given(
    c=st.integers(8, 128),
    d=st.integers(1, 32),
    tiles=st.integers(1, 6),
    block=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lookup_tiled_matches_ref(c, d, tiles, block, seed):
    r = rng(seed)
    b = tiles * block
    table = r.normal(size=(c, d)).astype(np.float32)
    idx = r.integers(0, c, size=b).astype(np.int32)
    got = embedding_lookup_tiled(jnp.asarray(table), jnp.asarray(idx), block_b=block)
    want = table[idx]
    np.testing.assert_allclose(got, want)


def test_lookup_bf16():
    r = rng(0)
    table = jnp.asarray(r.normal(size=(50, 8)), jnp.bfloat16)
    idx = jnp.asarray(r.integers(0, 50, size=16), jnp.int32)
    got = embedding_lookup(table, idx)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(got, np.float32), np.asarray(table, np.float32)[np.asarray(idx)]
    )


def test_lookup_repeated_and_edge_indices():
    table = jnp.arange(12.0).reshape(6, 2)
    idx = jnp.asarray([0, 5, 5, 0, 3], jnp.int32)
    got = embedding_lookup(table, idx)
    np.testing.assert_allclose(got, np.asarray(table)[np.asarray(idx)])


# ---------------------------------------------------------------------------
# clip_scale
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 128),
    k=st.integers(1, 5),
    c=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_clip_scale_matches_ref(b, k, c, seed):
    r = rng(seed)
    sq = (r.normal(size=(b, k)) ** 2).astype(np.float32)
    got = clip_scale(jnp.asarray(sq), jnp.float32(c))
    want = ref.clip_scale_ref(jnp.asarray(sq), jnp.float32(c))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@given(b=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_clip_never_amplifies(b, seed):
    r = rng(seed)
    sq = (r.normal(size=(b, 3)) ** 2).astype(np.float32)
    s = np.asarray(clip_scale(jnp.asarray(sq), jnp.float32(1.0)))
    assert (s <= 1.0 + 1e-6).all() and (s > 0).all()
    # post-clip norms never exceed C
    norms = np.sqrt(sq.sum(-1))
    assert (s * norms <= 1.0 + 1e-5).all()


def test_clip_scale_zero_grad():
    s = clip_scale(jnp.zeros((4, 2)), jnp.float32(1.0))
    assert np.isfinite(np.asarray(s)).all()


# ---------------------------------------------------------------------------
# contribution_map
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 64),
    f=st.integers(1, 30),
    c=st.integers(4, 500),
    seed=st.integers(0, 2**31 - 1),
)
def test_contribution_map_matches_ref(b, f, c, seed):
    r = rng(seed)
    idx = r.integers(0, c, size=(b, f)).astype(np.int32)
    w = r.uniform(0, 1, size=(b, f)).astype(np.float32)
    got = contribution_map(jnp.asarray(idx), jnp.asarray(w), c)
    want = ref.contribution_map_ref(jnp.asarray(idx), jnp.asarray(w), c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_contribution_total_mass_bounded():
    # sum of counts == sum of weights; with unit weights it is B*F
    b, f, c = 16, 4, 100
    r = rng(1)
    idx = r.integers(0, c, size=(b, f)).astype(np.int32)
    w = np.full((b, f), 0.5, np.float32)
    counts = np.asarray(contribution_map(jnp.asarray(idx), jnp.asarray(w), c))
    assert abs(counts.sum() - 0.5 * b * f) < 1e-3
    assert (counts >= 0).all()


def test_contribution_all_same_bucket():
    idx = np.zeros((8, 3), np.int32)
    w = np.ones((8, 3), np.float32)
    counts = np.asarray(contribution_map(jnp.asarray(idx), jnp.asarray(w), 10))
    assert counts[0] == pytest.approx(24.0)
    assert counts[1:].sum() == 0


# ---------------------------------------------------------------------------
# row_scatter / scale_grads
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 32),
    f=st.integers(1, 8),
    d=st.integers(1, 16),
    c=st.integers(4, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_row_scatter_matches_ref(b, f, d, c, seed):
    r = rng(seed)
    idx = r.integers(0, c, size=(b, f)).astype(np.int32)
    g = r.normal(size=(b, f, d)).astype(np.float32)
    s = r.uniform(0, 1, size=b).astype(np.float32)
    got = row_scatter(jnp.asarray(idx), jnp.asarray(g), jnp.asarray(s), c)
    want = ref.row_scatter_ref(jnp.asarray(idx), jnp.asarray(g), jnp.asarray(s), c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@given(
    b=st.integers(1, 32),
    f=st.integers(1, 8),
    d=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_scale_grads(b, f, d, seed):
    r = rng(seed)
    g = r.normal(size=(b, f, d)).astype(np.float32)
    s = r.uniform(0, 1, size=b).astype(np.float32)
    got = scale_grads(jnp.asarray(g), jnp.asarray(s))
    np.testing.assert_allclose(got, g * s[:, None, None], rtol=1e-6)


def test_row_scatter_sparsity():
    """Rows not activated by the batch stay exactly zero — the property the
    whole paper is about (Figure 1b)."""
    b, f, d, c = 8, 2, 4, 1000
    r = rng(3)
    idx = r.integers(0, 10, size=(b, f)).astype(np.int32)  # only rows < 10
    g = r.normal(size=(b, f, d)).astype(np.float32)
    s = np.ones(b, np.float32)
    out = np.asarray(row_scatter(jnp.asarray(idx), jnp.asarray(g), jnp.asarray(s), c))
    assert (out[10:] == 0).all()
    assert np.abs(out[:10]).sum() > 0


# ---------------------------------------------------------------------------
# oracle-level identities used by the models
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 16),
    t=st.integers(1, 12),
    d=st.integers(1, 8),
    c=st.integers(2, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_scattered_sqnorm_identity(b, t, d, c, seed):
    """Pairwise-Gram scattered norm == norm of the actually scattered rows."""
    r = rng(seed)
    idx = r.integers(0, c, size=(b, t)).astype(np.int32)
    g = r.normal(size=(b, t, d)).astype(np.float32)
    got = np.asarray(ref.scattered_sq_norm_ref(jnp.asarray(idx), jnp.asarray(g)))
    for i in range(b):
        dense = np.zeros((c, d), np.float64)
        for tt in range(t):
            dense[idx[i, tt]] += g[i, tt]
        np.testing.assert_allclose(got[i], (dense ** 2).sum(), rtol=1e-3, atol=1e-4)


@given(
    b=st.integers(1, 16),
    t=st.integers(1, 12),
    c=st.integers(2, 30),
    c1=st.floats(0.1, 100.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_unique_weights_identity(b, t, c, c1, seed):
    """Scattering per-slot weights == the l2-clipped unique-id indicator."""
    r = rng(seed)
    idx = r.integers(0, c, size=(b, t)).astype(np.int32)
    w = np.asarray(ref.unique_weights_ref(jnp.asarray(idx), jnp.float32(c1)))
    for i in range(b):
        per_id = np.zeros(c)
        for tt in range(t):
            per_id[idx[i, tt]] += w[i, tt]
        uniq = np.unique(idx[i])
        expect = min(1.0, c1 / np.sqrt(len(uniq)))
        np.testing.assert_allclose(per_id[uniq], expect, rtol=1e-4)
        assert per_id[np.setdiff1d(np.arange(c), uniq)].sum() == 0
        # the clipped indicator's l2 norm never exceeds C1
        assert np.linalg.norm(per_id) <= c1 * (1 + 1e-4)
