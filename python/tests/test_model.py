"""L2 step-function correctness: clipping invariants, contribution-map mass,
fwd/grads agreement, and gradient-vs-autodiff ground truth on tiny configs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model


def tiny_pctr():
    return configs.PctrConfig(
        name="tiny", vocabs=[8, 5, 12, 3], batch_size=6, hidden_dim=8,
        num_hidden_layers=2,
    )


def tiny_nlu(emb_lora_rank=0):
    return configs.NluConfig(
        name="tiny-nlu", vocab=40, seq_len=6, batch_size=5, d_model=8,
        num_layers=1, num_heads=2, ff_dim=16, lora_rank=2, num_classes=2,
        emb_lora_rank=emb_lora_rank,
    )


def pctr_batch(cfg, seed=0):
    r = np.random.default_rng(seed)
    cat = (r.integers(0, cfg.vocabs, size=(cfg.batch_size, len(cfg.vocabs)))
           .astype(np.int32))
    xn = r.normal(size=(cfg.batch_size, configs.NUM_NUMERIC_FEATURES)).astype(np.float32)
    y = r.integers(0, 2, size=cfg.batch_size).astype(np.float32)
    return cat, xn, y


# ---------------------------------------------------------------------------
# pCTR
# ---------------------------------------------------------------------------


def test_pctr_fwd_grads_loss_agree():
    cfg = tiny_pctr()
    params = model.pctr_init(cfg)
    cat, xn, y = pctr_batch(cfg)
    fwd = model.make_pctr_fwd(cfg)
    step = model.make_pctr_grads(cfg)
    l1 = fwd(*params, cat, xn, y)[0]
    l2 = step(*params, cat, xn, y, jnp.full(1, 1e9), jnp.full(1, 1e9))[0]
    np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_pctr_unclipped_grads_match_autodiff():
    """With C2 → ∞ the summed 'clipped' grads equal the plain sum of
    per-example grads == B * grad of the mean loss."""
    cfg = tiny_pctr()
    params = [jnp.asarray(p) for p in model.pctr_init(cfg)]
    cat, xn, y = pctr_batch(cfg)
    step = model.make_pctr_grads(cfg)
    outs = step(*params, cat, xn, y, jnp.full(1, 1e9), jnp.full(1, 1e9))
    nf = len(cfg.vocabs)
    mlp_grads = outs[1:1 + 2 * cfg.num_hidden_layers + 2]
    zg = outs[-3]

    def mean_loss(params_list):
        fwd = model.make_pctr_fwd(cfg, use_kernels=False)
        return fwd(*params_list, cat, xn, y)[0]

    auto = jax.grad(mean_loss)(params)
    b = cfg.batch_size
    for got, want in zip(mlp_grads, auto[nf:]):
        np.testing.assert_allclose(got, b * want, rtol=2e-3, atol=1e-5)
    # embedding: scatter zg and compare to autodiff table grads
    off = 0
    for f, (v, d) in enumerate(zip(cfg.vocabs, cfg.dims)):
        dense = np.zeros((v, d), np.float32)
        for i in range(b):
            dense[int(cat[i, f])] += np.asarray(zg)[i, off:off + d]
        np.testing.assert_allclose(dense, b * np.asarray(auto[f]),
                                   rtol=2e-3, atol=1e-5)
        off += d


def test_pctr_clipping_bounds_per_example_norm():
    cfg = tiny_pctr()
    params = model.pctr_init(cfg)
    cat, xn, y = pctr_batch(cfg, seed=1)
    c2 = 0.05  # aggressive clip so it binds
    step = model.make_pctr_grads(cfg)
    outs = step(*params, cat, xn, y, jnp.full(1, 1.0), jnp.full(1, c2))
    scales = np.asarray(outs[-1])
    assert (scales <= 1.0 + 1e-6).all()
    # rerun per single example and verify the scaled norm <= c2
    for i in range(cfg.batch_size):
        sub = configs.PctrConfig(name="t", vocabs=cfg.vocabs, batch_size=1,
                                 hidden_dim=cfg.hidden_dim,
                                 num_hidden_layers=cfg.num_hidden_layers)
        s1 = model.make_pctr_grads(sub)
        o1 = s1(*params, cat[i:i + 1], xn[i:i + 1], y[i:i + 1],
                jnp.full(1, 1.0), jnp.full(1, c2))
        g_parts = [np.asarray(g).ravel() for g in o1[1:-2]]
        total = np.sqrt(sum((g ** 2).sum() for g in g_parts))
        assert total <= c2 * (1 + 1e-4)


def test_pctr_counts_mass():
    cfg = tiny_pctr()
    params = model.pctr_init(cfg)
    cat, xn, y = pctr_batch(cfg)
    c1 = 1.0
    step = model.make_pctr_grads(cfg)
    counts = np.asarray(step(*params, cat, xn, y, jnp.full(1, c1),
                             jnp.full(1, 1.0))[-2])
    nf = len(cfg.vocabs)
    w = min(1.0, c1 / np.sqrt(nf))
    np.testing.assert_allclose(counts.sum(), w * cfg.batch_size * nf, rtol=1e-5)
    # per-example contribution-map l2 norm is clipped to C1
    assert counts.max() <= cfg.batch_size * w + 1e-5


def test_pctr_zgrad_rows_only_for_activated():
    cfg = tiny_pctr()
    params = model.pctr_init(cfg)
    cat, xn, y = pctr_batch(cfg)
    step = model.make_pctr_grads(cfg)
    counts = np.asarray(step(*params, cat, xn, y, jnp.full(1, 1e9),
                             jnp.full(1, 1e9))[-2])
    offs = cfg.row_offsets
    activated = set()
    for i in range(cfg.batch_size):
        for f in range(len(cfg.vocabs)):
            activated.add(offs[f] + int(cat[i, f]))
    nz = set(np.nonzero(counts)[0].tolist())
    assert nz == activated


# ---------------------------------------------------------------------------
# NLU
# ---------------------------------------------------------------------------


def nlu_batch(cfg, seed=0):
    r = np.random.default_rng(seed)
    ids = r.integers(0, cfg.vocab, size=(cfg.batch_size, cfg.seq_len)).astype(np.int32)
    labels = r.integers(0, cfg.num_classes, size=cfg.batch_size).astype(np.int32)
    return ids, labels


def test_nlu_fwd_grads_loss_agree():
    cfg = tiny_nlu()
    params = model.nlu_init(cfg)
    ids, labels = nlu_batch(cfg)
    fwd = model.make_nlu_fwd(cfg)
    step, _ = model.make_nlu_grads(cfg)
    l1 = fwd(*params, ids, labels)[0]
    l2 = step(*params, ids, labels, jnp.full(1, 1e9), jnp.full(1, 1e9))[0]
    np.testing.assert_allclose(l1, l2, rtol=1e-5)


def test_nlu_unclipped_embedding_grads_match_autodiff():
    cfg = tiny_nlu()
    params = [jnp.asarray(p) for p in model.nlu_init(cfg)]
    ids, labels = nlu_batch(cfg)
    step, names = model.make_nlu_grads(cfg)
    outs = step(*params, ids, labels, jnp.full(1, 1e9), jnp.full(1, 1e9))
    zg = np.asarray(outs[-3])  # (B,T,d)

    fwd = model.make_nlu_fwd(cfg, use_kernels=False)

    def mean_loss(emb_table):
        return fwd(emb_table, *params[1:], ids, labels)[0]

    auto = np.asarray(jax.grad(mean_loss)(params[0]))
    dense = np.zeros_like(auto)
    for i in range(cfg.batch_size):
        for t in range(cfg.seq_len):
            dense[ids[i, t]] += zg[i, t]
    np.testing.assert_allclose(dense, cfg.batch_size * auto, rtol=2e-3, atol=1e-5)


def test_nlu_repeated_tokens_clip_correctly():
    """An example made of one repeated token: the scattered row grad is the
    sum over positions — the clip must see that, not the per-slot norms."""
    cfg = tiny_nlu()
    params = model.nlu_init(cfg)
    ids, labels = nlu_batch(cfg)
    ids[0, :] = 7  # all positions the same token
    c2 = 0.01
    step, _ = model.make_nlu_grads(cfg)
    outs = step(*params, ids, labels, jnp.full(1, 1e9), jnp.full(1, c2))
    zg = np.asarray(outs[-3])
    # scattered row norm for example 0
    row = zg[0].sum(axis=0)
    dense_names = [n for n in np.arange(len(outs) - 4)]  # trainable grads exist
    assert np.linalg.norm(row) <= c2 * (1 + 1e-3)


def test_nlu_counts_unique_tokens():
    cfg = tiny_nlu()
    params = model.nlu_init(cfg)
    ids, labels = nlu_batch(cfg)
    ids[0, :] = 3  # repeated: contributes once, with weight min(1, c1/1)
    c1 = 100.0  # effectively no clip
    step, _ = model.make_nlu_grads(cfg)
    counts = np.asarray(step(*params, ids, labels, jnp.full(1, c1),
                             jnp.full(1, 1.0))[-2])
    # token 3's count includes exactly 1.0 from example 0
    manual = np.zeros(cfg.vocab)
    for i in range(cfg.batch_size):
        uniq, c = np.unique(ids[i], return_counts=True)
        w = min(1.0, c1 / np.sqrt(len(uniq)))
        manual[uniq] += w
    np.testing.assert_allclose(counts, manual, rtol=1e-4, atol=1e-5)


def test_nlu_loraemb_variant_runs_and_clips():
    cfg = tiny_nlu(emb_lora_rank=3)
    params = model.nlu_init(cfg)
    ids, labels = nlu_batch(cfg)
    step, names = model.make_nlu_lora_emb_grads(cfg)
    outs = step(*params, ids, labels, jnp.full(1, 10.0), jnp.full(1, 0.05))
    assert outs[-3].shape == (cfg.batch_size, cfg.seq_len, 3)
    scales = np.asarray(outs[-1])
    assert (scales <= 1.0 + 1e-6).all()
    assert np.isfinite(outs[0])


def test_nlu_param_spec_trainability():
    cfg = tiny_nlu()
    specs = model.nlu_param_specs(cfg)
    trainable = {n for n, _, tr in specs if tr}
    assert "emb_table" in trainable
    assert any("lora_aq" in n for n in trainable)
    assert not any(n.startswith("l0_wq") and n in trainable for n, _, _ in specs)
    cfg2 = tiny_nlu(emb_lora_rank=2)
    specs2 = model.nlu_param_specs(cfg2)
    tr2 = {n for n, _, tr in specs2 if tr}
    assert "emb_table" not in tr2 and "emb_lora_a" in tr2 and "emb_lora_b" in tr2
