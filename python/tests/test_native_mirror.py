"""f64 mirror of the native Rust transformer executor's backward formulas.

`rust/src/runtime/reference/transformer.rs` hand-derives the backward pass
for both embedding parametrizations (`EmbParam::Full` and
`EmbParam::LoRA`).  This file re-implements the forward and the *same*
analytic backward in NumPy f64 and central-differences the summed loss —
the acceptance bar for the formulas is a relative error <= 1e-4 per
coordinate (observed: ~1e-7; the in-tree f32 Rust tests necessarily use a
machine-precision-aware bound, see `fd_check` there).

Pure NumPy — runs without jax, unlike the kernel/pytest suites next door.
"""

import numpy as np
import pytest

GELU_C = 0.7978845608028654  # sqrt(2/pi), transformer.rs::GELU_C
GELU_A = 0.044715
LN_EPS = 1e-5


def posenc(T, d):
    pe = np.zeros((T, d))
    for pos in range(T):
        for i in range(d):
            ang = pos / (10000.0 ** ((2 * (i // 2)) / d))
            pe[pos, i] = np.sin(ang) if i % 2 == 0 else np.cos(ang)
    return pe


def gelu(x):
    u = GELU_C * (x + GELU_A * x ** 3)
    return 0.5 * x * (1.0 + np.tanh(u))


def gelu_prime(x):
    u = GELU_C * (x + GELU_A * x ** 3)
    th = np.tanh(u)
    return 0.5 * (1.0 + th) + 0.5 * x * (1.0 - th * th) * GELU_C * (
        1.0 + 3.0 * GELU_A * x * x
    )


def ln_fwd(u, g, b):
    mu = u.mean(-1, keepdims=True)
    var = ((u - mu) ** 2).mean(-1, keepdims=True)
    inv = 1.0 / np.sqrt(var + LN_EPS)
    xhat = (u - mu) * inv
    return xhat * g + b, (xhat, inv)


def ln_bwd(dy, g, cache):
    xhat, inv = cache
    dxh = dy * g
    m1 = dxh.mean(-1, keepdims=True)
    m2 = (dxh * xhat).mean(-1, keepdims=True)
    return (dxh - m1 - xhat * m2) * inv


class Mirror:
    """One-example forward/backward, mirroring transformer.rs layouts."""

    def __init__(self, V, d, h, ff, L, T, C, rank=0, seed=0):
        rng = np.random.default_rng(seed)
        self.V, self.d, self.h, self.ff = V, d, h, ff
        self.L, self.T, self.C, self.rank = L, T, C, rank
        self.pe = posenc(T, d)
        self.E = rng.normal(0, 0.3, (V, d))
        if rank:
            self.A = rng.normal(0, 0.3, (V, rank))
            self.B = rng.normal(0, 0.4, (rank, d))  # nonzero: A-path carries signal
        ws = d ** -0.5
        self.layers = []
        for _ in range(L):
            self.layers.append(dict(
                wq=rng.normal(0, ws, (d, d)), bq=rng.normal(0, 0.05, d),
                wk=rng.normal(0, ws, (d, d)), bk=rng.normal(0, 0.05, d),
                wv=rng.normal(0, ws, (d, d)), bv=rng.normal(0, 0.05, d),
                wo=rng.normal(0, ws, (d, d)), bo=rng.normal(0, 0.05, d),
                g1=1 + rng.normal(0, 0.1, d), b1=rng.normal(0, 0.05, d),
                ff1=rng.normal(0, ws, (d, ff)), bf1=rng.normal(0, 0.05, ff),
                ff2=rng.normal(0, ff ** -0.5, (ff, d)), bf2=rng.normal(0, 0.05, d),
                g2=1 + rng.normal(0, 0.1, d), b2=rng.normal(0, 0.05, d),
            ))
        self.hw = rng.normal(0, 0.3, (d, C))
        self.hb = rng.normal(0, 0.1, C)

    def encode(self, ids):
        dh = self.d // self.h
        z = self.E[ids].copy()
        if self.rank:
            z = z + self.A[ids] @ self.B
        x = z + self.pe
        caches = []
        for lay in self.layers:
            q = x @ lay["wq"] + lay["bq"]
            k = x @ lay["wk"] + lay["bk"]
            v = x @ lay["wv"] + lay["bv"]
            ctx = np.zeros_like(x)
            atts = []
            for hh in range(self.h):
                sl = slice(hh * dh, (hh + 1) * dh)
                sc = q[:, sl] @ k[:, sl].T / np.sqrt(dh)
                att = np.exp(sc - sc.max(-1, keepdims=True))
                att /= att.sum(-1, keepdims=True)
                ctx[:, sl] = att @ v[:, sl]
                atts.append(att)
            u1 = ctx @ lay["wo"] + lay["bo"] + x
            x1, ln1 = ln_fwd(u1, lay["g1"], lay["b1"])
            a = x1 @ lay["ff1"] + lay["bf1"]
            u2 = gelu(a) @ lay["ff2"] + lay["bf2"] + x1
            x2, ln2 = ln_fwd(u2, lay["g2"], lay["b2"])
            caches.append(dict(q=q, k=k, v=v, atts=atts, ln1=ln1, ln2=ln2, a=a))
            x = x2
        pooled = x.mean(0)
        return caches, pooled, pooled @ self.hw + self.hb

    def loss_one(self, ids, label):
        _, _, logits = self.encode(ids)
        m = logits.max()
        return m + np.log(np.exp(logits - m).sum()) - logits[label]

    def backward_one(self, ids, label):
        dh = self.d // self.h
        caches, pooled, logits = self.encode(ids)
        p = np.exp(logits - logits.max())
        p /= p.sum()
        dlog = p.copy()
        dlog[label] -= 1.0
        dhw = np.outer(pooled, dlog)
        dhb = dlog.copy()
        dx = np.tile((self.hw @ dlog) / self.T, (self.T, 1))
        for lay, c in zip(reversed(self.layers), reversed(caches)):
            du2 = ln_bwd(dx, lay["g2"], c["ln2"])
            dx1 = du2.copy()
            da = (du2 @ lay["ff2"].T) * gelu_prime(c["a"])
            dx1 += da @ lay["ff1"].T
            du1 = ln_bwd(dx1, lay["g1"], c["ln1"])
            dxin = du1.copy()
            dctx = du1 @ lay["wo"].T
            dq = np.zeros_like(dx)
            dk = np.zeros_like(dx)
            dv = np.zeros_like(dx)
            for hh in range(self.h):
                sl = slice(hh * dh, (hh + 1) * dh)
                att = c["atts"][hh]
                datt = dctx[:, sl] @ c["v"][:, sl].T
                dv[:, sl] += att.T @ dctx[:, sl]
                dot = (att * datt).sum(-1, keepdims=True)
                ds = att * (datt - dot) / np.sqrt(dh)
                dq[:, sl] += ds @ c["k"][:, sl]
                dk[:, sl] += ds.T @ c["q"][:, sl]
            dxin += dq @ lay["wq"].T + dk @ lay["wk"].T + dv @ lay["wv"].T
            dx = dxin
        dz = dx
        if self.rank:
            return dz, dz @ self.B.T, self.A[ids].T @ dz, dhw, dhb
        return dz, None, None, dhw, dhb


# The Rust FD batch: repeats within example 0 (token 5) and example 2
# (token 9), and token 5 shared across examples 0 and 3.
IDS = np.array([5, 5, 7, 2, 0, 1, 2, 3, 9, 11, 9, 4, 20, 6, 3, 5]).reshape(4, 4)
LABELS = [0, 2, 1, 0]
TOL = 1e-4  # the acceptance tolerance; observed errors are ~1e-7


def central_diff(f, arr, idx, eps=1e-6):
    orig = arr[idx]
    arr[idx] = orig + eps
    lp = f()
    arr[idx] = orig - eps
    lm = f()
    arr[idx] = orig
    return (lp - lm) / (2 * eps)


def batch_grads(m, ids=None, labels=None):
    ids = IDS if ids is None else ids
    labels = LABELS if labels is None else labels
    agg = {"hw": 0.0, "hb": 0.0, "B": 0.0}
    scat = np.zeros((m.V, m.rank or m.d))
    for i in range(len(labels)):
        dz, da_rows, dB, dhw, dhb = m.backward_one(ids[i], labels[i])
        agg["hw"] = agg["hw"] + dhw
        agg["hb"] = agg["hb"] + dhb
        if m.rank:
            agg["B"] = agg["B"] + dB
            np.add.at(scat, ids[i], da_rows)
        else:
            np.add.at(scat, ids[i], dz)
    return agg, scat


def relerr(a, f):
    scale = max(abs(a), abs(f), 1e-12)
    return abs(a - f) / scale


@pytest.mark.parametrize("rank", [0, 3])
def test_backward_matches_central_differences(rank):
    m = Mirror(V=24, d=8, h=2, ff=12, L=2, T=4, C=3, rank=rank, seed=1)
    total = lambda: sum(m.loss_one(IDS[i], LABELS[i]) for i in range(4))
    agg, scat = batch_grads(m)
    for c in range(3):
        assert relerr(agg["hb"][c], central_diff(total, m.hb, c)) < TOL
    for idx in [(0, 0), (3, 1), (7, 2)]:
        assert relerr(agg["hw"][idx], central_diff(total, m.hw, idx)) < TOL
    if rank:
        for idx in [(0, 0), (1, 3), (2, 7)]:
            assert relerr(agg["B"][idx], central_diff(total, m.B, idx)) < TOL
        for idx in [(5, 0), (5, 2), (7, 1), (2, 0), (9, 2), (20, 1)]:
            assert relerr(scat[idx], central_diff(total, m.A, idx)) < TOL
        # an A row no example touches carries exactly zero gradient
        assert scat[23, 0] == 0.0
        assert abs(central_diff(total, m.A, (23, 0))) < 1e-12
    else:
        for idx in [(5, 0), (5, 3), (7, 2), (2, 1), (9, 5), (20, 7)]:
            assert relerr(scat[idx], central_diff(total, m.E, idx)) < TOL


# The Rust kernel suite's off-tile geometry (seq_len 5, d_model 12, ff 9 —
# none multiples of the blocked kernels' 4x8 register tile) and batch, from
# transformer.rs::finite_difference_gradients_match_off_tile_shapes.
IDS_OFFTILE = np.array([3, 3, 7, 1, 9, 2, 8, 3, 1, 1]).reshape(2, 5)
LABELS_OFFTILE = [1, 0]


@pytest.mark.parametrize("rank", [0, 3])
def test_backward_matches_central_differences_offtile(rank):
    # the kernel-shaped case: every matmul the Rust executor runs at this
    # geometry exercises edge tiles, so the mirrored formulas double-check
    # the same seq_len/d_model/ff pair the Rust FD suite uses
    m = Mirror(V=24, d=12, h=2, ff=9, L=2, T=5, C=3, rank=rank, seed=2)
    total = lambda: sum(
        m.loss_one(IDS_OFFTILE[i], LABELS_OFFTILE[i]) for i in range(2)
    )
    agg, scat = batch_grads(m, IDS_OFFTILE, LABELS_OFFTILE)
    for c in range(3):
        assert relerr(agg["hb"][c], central_diff(total, m.hb, c)) < TOL
    for idx in [(0, 0), (7, 2), (11, 1)]:
        assert relerr(agg["hw"][idx], central_diff(total, m.hw, idx)) < TOL
    if rank:
        for idx in [(0, 0), (1, 8), (2, 11)]:
            assert relerr(agg["B"][idx], central_diff(total, m.B, idx)) < TOL
        for idx in [(3, 0), (3, 2), (7, 1), (1, 0), (9, 2), (8, 1)]:
            assert relerr(scat[idx], central_diff(total, m.A, idx)) < TOL
    else:
        for idx in [(3, 0), (3, 11), (7, 8), (1, 5), (9, 2), (8, 10)]:
            assert relerr(scat[idx], central_diff(total, m.E, idx)) < TOL


@pytest.mark.parametrize("rank", [0, 3])
def test_gram_identity_equals_dense_scatter(rank):
    # The clip factor's scattered squared norm (pairwise Gram identity over
    # same-token slots) must equal the norm of the dense scatter-add — in
    # the original token order and under permutations of each example.
    m = Mirror(V=24, d=8, h=2, ff=12, L=2, T=4, C=3, rank=rank, seed=1)
    for i in range(4):
        for perm_seed in range(3):
            perm = np.random.default_rng(perm_seed).permutation(4)
            ids = IDS[i][perm]
            dz, da_rows, _, _, _ = m.backward_one(ids, LABELS[i])
            rows = da_rows if rank else dz
            gram = sum(
                rows[p] @ rows[s]
                for p in range(4)
                for s in range(4)
                if ids[p] == ids[s]
            )
            scat = np.zeros((m.V, rows.shape[1]))
            np.add.at(scat, ids, rows)
            dense_sq = (scat ** 2).sum()
            assert abs(gram - dense_sq) <= 1e-9 * max(1.0, dense_sq)
