"""NumPy f32 validation of the SIMD backend's documented tolerance model.

The Rust SIMD backend (`rust/src/kernels/simd.rs`) reassociates k-term
reduction chains into 8 lane partials plus a fixed pairwise horizontal-sum
tree.  Its verification suite (`rust/tests/kernels.rs`) accepts an element
when it is within 4 ULPs of the scalar chain OR within the standard
reassociated-summation bound ``2*(k+1)*eps_f32*sum(|terms|)``.

This file replays both summation orders **in exact f32 arithmetic** with
NumPy and checks, over random and adversarially cancellation-heavy cases,
that the observed scalar-vs-lane difference always sits inside the hybrid
bound — i.e. the tolerance the Rust suite enforces is actually satisfiable
by the reassociation the backend performs, with no dependence on a Rust
toolchain.  Pure NumPy; no jax needed.
"""

import numpy as np

LANES = 8
EPS32 = np.float32(np.finfo(np.float32).eps)


def scalar_chain(terms, start=np.float32(0.0)):
    """The scalar kernels' order: one chain, ascending k."""
    acc = np.float32(start)
    for t in terms:
        acc = np.float32(acc + np.float32(t))
    return acc


def hsum8(v):
    """The documented pairwise tree: ((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))."""
    a = np.float32(np.float32(v[0] + v[1]) + np.float32(v[2] + v[3]))
    b = np.float32(np.float32(v[4] + v[5]) + np.float32(v[6] + v[7]))
    return np.float32(a + b)


def lane_chain(terms, start=np.float32(0.0)):
    """The SIMD backend's order: lane l accumulates terms 8c+l serially,
    lanes reduce through the pairwise tree, the tail (k % 8) is added
    serially, and the chain start lands first: start + (hsum8 + tail)."""
    terms = np.asarray(terms, dtype=np.float32)
    k = terms.shape[0]
    body = k - (k % LANES)
    lanes = np.zeros(LANES, dtype=np.float32)
    for c in range(body // LANES):
        for l in range(LANES):
            lanes[l] = np.float32(lanes[l] + terms[c * LANES + l])
    tail = np.float32(0.0)
    for t in terms[body:]:
        tail = np.float32(tail + t)
    return np.float32(np.float32(start) + np.float32(hsum8(lanes) + tail))


def ulp_distance(a, b):
    """Monotone-bit-map ULP distance; both zeros coincide."""

    def monotone(x):
        bits = np.float32(x).view(np.uint32)
        if bits & np.uint32(0x8000_0000):
            return -int(bits & np.uint32(0x7FFF_FFFF))
        return int(bits)

    return abs(monotone(a) - monotone(b))


def within_tolerance(got, want, k, mag):
    """The Rust suite's acceptance predicate."""
    if ulp_distance(got, want) <= 4:
        return True
    bound = 2.0 * (k + 1) * float(EPS32) * mag
    return abs(float(got) - float(want)) <= bound


def check_case(terms, start=np.float32(0.0)):
    terms = np.asarray(terms, dtype=np.float32)
    want = scalar_chain(terms, start)
    got = lane_chain(terms, start)
    mag = float(np.abs(terms.astype(np.float64)).sum()) + abs(float(start))
    assert within_tolerance(got, got, len(terms), mag)  # reflexivity
    assert within_tolerance(got, want, len(terms), mag), (
        f"k={len(terms)}: scalar {want!r} vs lanes {got!r}, "
        f"ulp={ulp_distance(got, want)}, mag={mag!r}"
    )


def test_gaussian_chains_stay_inside_the_bound():
    rng = np.random.default_rng(0xD07)
    for _ in range(300):
        k = int(rng.integers(0, 200))
        terms = (rng.standard_normal(k) * 1.5).astype(np.float32)
        start = np.float32(rng.standard_normal() * rng.choice([0.0, 1.0, 10.0]))
        check_case(terms, start)


def test_cancellation_heavy_chains_stay_inside_the_bound():
    # pairs that nearly cancel: the result is ~0 while sum(|terms|) is large.
    # This is exactly where a pure-ULP bar fails and the relative arm of the
    # hybrid bound (stated against the magnitude, not the result) must carry.
    rng = np.random.default_rng(0xCAFE)
    for _ in range(300):
        half = int(rng.integers(1, 60))
        a = (rng.standard_normal(half) * 100.0).astype(np.float32)
        jitter = (rng.standard_normal(half) * 1e-4).astype(np.float32)
        terms = np.empty(2 * half, dtype=np.float32)
        terms[0::2] = a
        terms[1::2] = -(a + jitter)
        check_case(terms)


def test_mixed_scale_chains_stay_inside_the_bound():
    # magnitudes spanning ~12 orders: small terms absorbed by large partials
    rng = np.random.default_rng(0xBEEF)
    for _ in range(200):
        k = int(rng.integers(1, 120))
        exp = rng.integers(-6, 6, size=k).astype(np.float64)
        terms = (rng.standard_normal(k) * 10.0**exp).astype(np.float32)
        check_case(terms)


def test_zero_one_chains_are_bitwise_exact():
    # the Rust suite's exhaustive {0,1} grid in miniature: small-integer
    # sums are exact under any association, so lanes owe bit equality
    rng = np.random.default_rng(0x51D)
    for _ in range(200):
        k = int(rng.integers(0, 64))
        terms = rng.integers(0, 2, size=k).astype(np.float32)
        start = np.float32(rng.integers(0, 2))
        want = scalar_chain(terms, start)
        got = lane_chain(terms, start)
        assert np.float32(got).view(np.uint32) == np.float32(want).view(np.uint32)


def test_dot_products_stay_inside_the_bound():
    # the matmul_bt / softmax-bwd shape of the chain: terms are products,
    # the magnitude oracle is sum(|a_i * b_i|) in f64
    rng = np.random.default_rng(0xD07B)
    for _ in range(200):
        k = int(rng.integers(0, 150))
        a = (rng.standard_normal(k) * 1.5).astype(np.float32)
        b = (rng.standard_normal(k) * 1.5).astype(np.float32)
        terms = (a * b).astype(np.float32)
        check_case(terms)


def test_ulp_arm_covers_tiny_magnitudes():
    # near-zero magnitudes: the relative arm's bound underflows to ~0, so
    # the ULP arm must accept the reassociated result on its own
    terms = np.array([1e-38, -1e-38, 3e-39, 2e-39] * 4, dtype=np.float32)
    check_case(terms)
