//! `cargo bench --bench accounting` — PLD accountant performance:
//! discretisation, FFT self-composition, and full σ calibration.

use sparse_dp_emb::accounting::{calibrate_sigma_uncached, Adjacency, Pld, SubsampledGaussian};
use sparse_dp_emb::util::bench::Bencher;

fn main() {
    let b = Bencher { samples: 5, ..Default::default() };

    let mech = SubsampledGaussian { sigma: 1.0, q: 0.01 };
    b.bench("pld-build/subsampled-gaussian", || {
        Pld::of(&mech, Adjacency::Remove).pmf.len()
    });

    let pld = Pld::of(&mech, Adjacency::Remove);
    for t in [100u64, 10_000] {
        b.bench(&format!("pld-compose-pow/T={t}"), || {
            pld.compose_pow(t).pmf.len()
        });
    }

    let composed = pld.compose_pow(1000);
    b.bench("pld-epsilon(delta=1e-6)", || composed.epsilon(1e-6));

    // the uncached bisection — calibrate_sigma itself memoizes process-wide
    // and would only measure a HashMap hit after the first sample
    let cal = Bencher { samples: 3, ..Default::default() };
    cal.bench("calibrate-sigma/eps=1,T=1000", || {
        calibrate_sigma_uncached(1.0, 1e-6, 0.01, 1000).unwrap()
    });
}
