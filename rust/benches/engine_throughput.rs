//! `cargo bench --bench engine_throughput` — sync trainer vs the async
//! sharded engine, steps/sec on the synthetic pCTR workload (criteo-small,
//! DP-AdaFEST), at 1/2/4 gradient workers, then a `--engine-staleness`
//! sweep at k ∈ {0, 1, 2, 4} quantifying what the bounded window buys,
//! then one `--engine-kernel-backend simd` row for the lane-parallel
//! kernel backend.
//!
//! The worker rows are bit-for-bit equivalent to the sync path (asserted
//! inside `engine::compare_throughput`), so that part is a pure throughput
//! comparison: the speedup comes from pipelined batch generation plus
//! per-example gradient chunks computed in parallel between aggregation
//! barriers.  Expected: ≥1.5x at 4 workers on a 4-core machine (the
//! per-step barrier work — selection, noise, sparse update — stays serial
//! by design).  The staleness rows relax bit-exactness (documented in
//! `docs/CONCURRENCY.md`), so they are timed directly rather than through
//! `compare_throughput`'s loss-equality gate.

use sparse_dp_emb::config::RunConfig;
use sparse_dp_emb::coordinator::Algorithm;
use sparse_dp_emb::data::CriteoConfig;
use sparse_dp_emb::engine;
use sparse_dp_emb::kernels::{simd_acceleration, KernelBackend};
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::telemetry::{BenchRow, BenchSnapshot, BENCH_SCHEMA_VERSION};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let rt = Runtime::builtin();
    let mut cfg = RunConfig::default();
    cfg.model = "criteo-small".into();
    cfg.algorithm = Algorithm::DpAdaFest;
    cfg.steps = if full { 200 } else { 60 };
    cfg.eval_batches = 1;

    let model = rt.manifest.model(&cfg.model).unwrap().clone();
    let vocabs = model.attr_usize_list("vocabs").unwrap();
    let gen_cfg = CriteoConfig::new(vocabs, cfg.seed ^ 0xDA7A);

    println!(
        "engine throughput: model={} algo={:?} steps={} (pass --full for 200 steps)\n",
        cfg.model, cfg.algorithm, cfg.steps
    );
    let rows = engine::compare_throughput(&cfg, &rt, &gen_cfg, &[1, 2, 4]).unwrap();
    let sync_sps = rows[0].steps_per_sec;
    for r in &rows {
        println!(
            "  {:<5} w={}  {:>7.2}s  {:>6.1} steps/s  ({:.2}x sync)",
            r.path, r.grad_workers, r.secs, r.steps_per_sec, r.speedup
        );
    }
    println!("\n(outcomes asserted bit-identical across all rows)");

    let mut bench_rows: Vec<BenchRow> = rows
        .iter()
        .map(|r| BenchRow {
            path: r.path.to_string(),
            grad_workers: r.grad_workers as u64,
            staleness: 0,
            store: "ram".into(),
            kernel_backend: "scalar".into(),
            secs: r.secs,
            steps_per_sec: r.steps_per_sec,
            speedup: r.speedup,
        })
        .collect();

    // staleness sweep at 4 workers: k > 0 trades bit-exactness for
    // pipelining, so these runs are timed directly (compare_throughput's
    // equality gate would reject them by design)
    println!("\nstaleness sweep (4 workers, k = window of in-flight steps):");
    for k in [0usize, 1, 2, 4] {
        let mut c = cfg.clone();
        c.engine.grad_workers = 4;
        c.engine.staleness = k;
        let out = engine::run_pctr(&c, &rt, gen_cfg.clone()).unwrap();
        let secs = out.telemetry.wall_secs;
        let sps = cfg.steps as f64 / secs;
        println!(
            "  async k={k}  {:>7.2}s  {:>6.1} steps/s  ({:.2}x sync)  max observed staleness {}",
            secs,
            sps,
            sps / sync_sps,
            out.telemetry.max_staleness
        );
        bench_rows.push(BenchRow {
            path: "async".into(),
            grad_workers: 4,
            staleness: k as u64,
            store: "ram".into(),
            kernel_backend: "scalar".into(),
            secs,
            steps_per_sec: sps,
            speedup: sps / sync_sps,
        });
    }

    // SIMD backend row at 4 workers: lane-parallel kernels reassociate the
    // reduction chains, so the loss trajectory is only ULP-close to scalar
    // (tolerances in tests/simd.rs) and the run is timed directly rather
    // than through compare_throughput's bit-equality gate.
    println!("\nkernel backend (4 workers, acceleration: {}):", simd_acceleration());
    {
        let mut c = cfg.clone();
        c.engine.grad_workers = 4;
        c.engine.kernel_backend = KernelBackend::Simd;
        let out = engine::run_pctr(&c, &rt, gen_cfg.clone()).unwrap();
        let secs = out.telemetry.wall_secs;
        let sps = cfg.steps as f64 / secs;
        println!(
            "  async simd  {:>7.2}s  {:>6.1} steps/s  ({:.2}x sync scalar)",
            secs,
            sps,
            sps / sync_sps
        );
        bench_rows.push(BenchRow {
            path: "async".into(),
            grad_workers: 4,
            staleness: 0,
            store: "ram".into(),
            kernel_backend: "simd".into(),
            secs,
            steps_per_sec: sps,
            speedup: sps / sync_sps,
        });
    }

    // tracked snapshot: CI's bench smoke regenerates BENCH_engine.json from
    // this same path (see docs/OBSERVABILITY.md for the schema)
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_engine.json".into());
    let snap = BenchSnapshot {
        schema_version: BENCH_SCHEMA_VERSION,
        bench: "engine_throughput".into(),
        model: cfg.model.clone(),
        algorithm: "dp-adafest".into(),
        steps: cfg.steps,
        provenance: format!(
            "cargo bench --bench engine_throughput{} (timings are machine-dependent; \
             compare rows within one snapshot, not across machines)",
            if full { " -- --full" } else { "" }
        ),
        rows: bench_rows,
    };
    std::fs::write(&out, snap.to_json_pretty()).unwrap();
    println!("wrote {out}");
}
