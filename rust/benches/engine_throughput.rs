//! `cargo bench --bench engine_throughput` — sync trainer vs the async
//! sharded engine, steps/sec on the synthetic pCTR workload (criteo-small,
//! DP-AdaFEST), at 1/2/4 gradient workers.
//!
//! The engine is bit-for-bit equivalent to the sync path (asserted inside
//! `engine::compare_throughput`), so this is a pure throughput comparison:
//! the speedup comes from pipelined batch generation plus per-example
//! gradient chunks computed in parallel between aggregation barriers.
//! Expected: ≥1.5x at 4 workers on a 4-core machine (the per-step barrier
//! work — selection, noise, sparse update — stays serial by design).

use sparse_dp_emb::config::RunConfig;
use sparse_dp_emb::coordinator::Algorithm;
use sparse_dp_emb::data::CriteoConfig;
use sparse_dp_emb::engine;
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::telemetry::{BenchRow, BenchSnapshot, BENCH_SCHEMA_VERSION};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let rt = Runtime::builtin();
    let mut cfg = RunConfig::default();
    cfg.model = "criteo-small".into();
    cfg.algorithm = Algorithm::DpAdaFest;
    cfg.steps = if full { 200 } else { 60 };
    cfg.eval_batches = 1;

    let model = rt.manifest.model(&cfg.model).unwrap().clone();
    let vocabs = model.attr_usize_list("vocabs").unwrap();
    let gen_cfg = CriteoConfig::new(vocabs, cfg.seed ^ 0xDA7A);

    println!(
        "engine throughput: model={} algo={:?} steps={} (pass --full for 200 steps)\n",
        cfg.model, cfg.algorithm, cfg.steps
    );
    let rows = engine::compare_throughput(&cfg, &rt, &gen_cfg, &[1, 2, 4]).unwrap();
    for r in &rows {
        println!(
            "  {:<5} w={}  {:>7.2}s  {:>6.1} steps/s  ({:.2}x sync)",
            r.path, r.grad_workers, r.secs, r.steps_per_sec, r.speedup
        );
    }
    println!("\n(outcomes asserted bit-identical across all rows)");

    // tracked snapshot: CI's bench smoke regenerates BENCH_engine.json from
    // this same path (see docs/OBSERVABILITY.md for the schema)
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_engine.json".into());
    let snap = BenchSnapshot {
        schema_version: BENCH_SCHEMA_VERSION,
        bench: "engine_throughput".into(),
        model: cfg.model.clone(),
        algorithm: "dp-adafest".into(),
        steps: cfg.steps,
        provenance: format!(
            "cargo bench --bench engine_throughput{} (timings are machine-dependent; \
             compare rows within one snapshot, not across machines)",
            if full { " -- --full" } else { "" }
        ),
        rows: rows
            .iter()
            .map(|r| BenchRow {
                path: r.path.to_string(),
                grad_workers: r.grad_workers as u64,
                secs: r.secs,
                steps_per_sec: r.steps_per_sec,
                speedup: r.speedup,
            })
            .collect(),
    };
    std::fs::write(&out, snap.to_json_pretty()).unwrap();
    println!("wrote {out}");
}
