//! `cargo bench --bench hot_path` — end-to-end trainer step timing plus the
//! L3 micro-kernels it is built from (noise generation, scatter-add,
//! contribution-map build).  The §Perf iteration log in EXPERIMENTS.md
//! tracks these numbers.

use sparse_dp_emb::config::RunConfig;
use sparse_dp_emb::coordinator::{Algorithm, Trainer};
use sparse_dp_emb::data::{CriteoConfig, SynthCriteo};
use sparse_dp_emb::filtering::ContributionMap;
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::sparse::RowSparseGrad;
use sparse_dp_emb::util::bench::Bencher;
use sparse_dp_emb::util::rng::Xoshiro256;

fn main() {
    let b = Bencher { samples: 7, ..Default::default() };

    // --- micro: dense noise generation throughput ---
    let mut rng = Xoshiro256::seed_from(1);
    let mut buf = vec![0f32; 1 << 20];
    let r = b.bench("gauss-fill/1M-f32", || {
        rng.fill_gauss_f32(&mut buf, 1.0);
    });
    println!(
        "  -> {:.1} M samples/s\n",
        1.0 / r.per_iter_secs() * (1 << 20) as f64 / 1e6
    );

    // --- micro: row-sparse accumulation (B=2048 rows, d=32) ---
    let rows: Vec<u32> = (0..2048).map(|_| rng.below(100_000) as u32).collect();
    let grad = vec![0.1f32; 32];
    b.bench("rowsparse-accumulate/B=2048,d=32", || {
        let mut g = RowSparseGrad::with_capacity(100_000, 32, 2048);
        for &r in &rows {
            g.add_row(r, &grad);
        }
        g.nnz_rows()
    });

    // --- micro: contribution map build + survivor sampling (full scale) ---
    let examples: Vec<Vec<u32>> = (0..2048)
        .map(|_| (0..26).map(|_| rng.below(340_000) as u32).collect())
        .collect();
    b.bench("contribution-map/B=2048,F=26", || {
        ContributionMap::from_batch(&examples, 340_000, 1.0).nnz()
    });
    let map = ContributionMap::from_batch(&examples, 340_000, 1.0);
    b.bench("survivors-sparse/B=2048", || {
        map.survivors(2.0, 1.0, 4.0, true, &mut rng).0.len()
    });
    b.bench("survivors-dense-oracle/B=2048", || {
        map.survivors(2.0, 1.0, 4.0, false, &mut rng).0.len()
    });

    // --- end-to-end: one trainer step per algorithm (needs artifacts) ---
    match Runtime::new("artifacts") {
        Ok(rt) => {
            for algo in [Algorithm::NonPrivate, Algorithm::DpSgd, Algorithm::DpAdaFest] {
                let mut cfg = RunConfig::default();
                cfg.model = "criteo-small".into();
                cfg.algorithm = algo;
                cfg.steps = 8; // calibration target only
                let model = rt.manifest.model(&cfg.model).unwrap();
                let vocabs = model.attr_usize_list("vocabs").unwrap();
                let gen = SynthCriteo::new(CriteoConfig::new(vocabs, 7));
                let mut trainer = Trainer::new(cfg, &rt).unwrap();
                let mut brng = Xoshiro256::seed_from(11);
                let batch = gen.batch(0, trainer.batch_size(), &mut brng);
                // warm the executable cache
                trainer.step_pctr(&batch).unwrap();
                let eb = Bencher { samples: 5, ..Default::default() };
                eb.bench(&format!("trainer-step/{}", algo.name()), || {
                    trainer.step_pctr(&batch).unwrap().loss
                });
            }
            let s = rt.stats();
            println!(
                "\nruntime split: {} execs, marshal-in {:?}, execute {:?}, marshal-out {:?}",
                s.executions, s.marshal_in, s.execute, s.marshal_out
            );
        }
        Err(e) => println!("(skipping end-to-end trainer bench: {e})"),
    }
}
