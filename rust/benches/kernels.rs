//! `cargo bench --bench kernels` — the blocked kernel subsystem vs the
//! retired scalar loops, at `nlu-small`-shaped matmuls, plus an `nlu-small`
//! gradient-step microbench on the kernel-backed executor.
//!
//! The scalar baselines below are the loops `runtime/reference/
//! transformer.rs` retired (bias-initialised affine with the zero skip;
//! fresh-dot backprop) — the same chains the kernels replicate bit-for-bit
//! (`tests/kernels.rs`), so this is a pure layout/blocking comparison.
//! Pass `--full` for longer runs; the default sizing is the CI smoke.

use std::time::Instant;

use sparse_dp_emb::kernels::{self, KernelBackend, MatInit, MatShape};
use sparse_dp_emb::runtime::reference::{builtin_manifest, BatchRef, RefModel, TensorView};
use sparse_dp_emb::runtime::HostTensor;
use sparse_dp_emb::util::rng::Xoshiro256;

/// The retired `affine`: `out = x·W + bias`, bias-first chain, zero skip.
fn scalar_affine(x: &[f32], w: &[f32], b: &[f32], d_in: usize, d_out: usize, out: &mut [f32]) {
    let t = x.len() / d_in;
    for r in 0..t {
        let xr = &x[r * d_in..(r + 1) * d_in];
        let or = &mut out[r * d_out..(r + 1) * d_out];
        or.copy_from_slice(b);
        for (i, &xv) in xr.iter().enumerate() {
            if xv != 0.0 {
                let wrow = &w[i * d_out..(i + 1) * d_out];
                for (ov, &wv) in or.iter_mut().zip(wrow) {
                    *ov += xv * wv;
                }
            }
        }
    }
}

/// The retired `backprop_input`: `dx += dout·Wᵀ`, fresh dot per element.
fn scalar_backprop(dout: &[f32], w: &[f32], d_in: usize, d_out: usize, dx: &mut [f32]) {
    let t = dout.len() / d_out;
    for r in 0..t {
        let dor = &dout[r * d_out..(r + 1) * d_out];
        let dxr = &mut dx[r * d_in..(r + 1) * d_in];
        for (i, dp) in dxr.iter_mut().enumerate() {
            let wrow = &w[i * d_out..(i + 1) * d_out];
            let mut acc = 0f32;
            for (&dv, &wv) in dor.iter().zip(wrow) {
                acc += dv * wv;
            }
            *dp += acc;
        }
    }
}

fn gauss(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gauss() as f32).collect()
}

/// Time `f` over `reps` calls, returning seconds per call.
fn time(reps: usize, mut f: impl FnMut()) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn bench_matmul_pair(name: &str, t: usize, k: usize, n: usize, reps: usize) {
    let mut rng = Xoshiro256::seed_from(7);
    let x = gauss(&mut rng, t * k);
    let w = gauss(&mut rng, k * n);
    let b = gauss(&mut rng, n);
    let mut out = vec![0f32; t * n];

    let scalar = time(reps, || {
        scalar_affine(&x, &w, &b, k, n, &mut out);
        std::hint::black_box(&out);
    });
    let blocked = time(reps, || {
        kernels::matmul(&x, &w, &mut out, MatShape::packed(t, k, n), MatInit::Bias(&b));
        std::hint::black_box(&out);
    });

    let mut dx = vec![0f32; t * k];
    let scalar_b = time(reps, || {
        scalar_backprop(&out, &w, k, n, &mut dx);
        std::hint::black_box(&dx);
    });
    let blocked_b = time(reps, || {
        kernels::matmul_bt(&out, &w, &mut dx, MatShape::packed_bt(t, n, k), MatInit::Accumulate);
        std::hint::black_box(&dx);
    });

    println!(
        "  {name:<26} fwd {:>9.1}ns -> {:>9.1}ns  ({:>4.2}x)   bwd {:>9.1}ns -> {:>9.1}ns  ({:>4.2}x)",
        scalar * 1e9,
        blocked * 1e9,
        scalar / blocked,
        scalar_b * 1e9,
        blocked_b * 1e9,
        scalar_b / blocked_b,
    );
}

/// Scalar backend vs the lane-parallel SIMD backend on the *same* blocked
/// kernels (fwd matmul + bwd matmul_bt) — isolates what lane parallelism
/// buys on top of blocking.
fn bench_backend_pair(name: &str, t: usize, k: usize, n: usize, reps: usize) {
    let mut rng = Xoshiro256::seed_from(7);
    let x = gauss(&mut rng, t * k);
    let w = gauss(&mut rng, k * n);
    let b = gauss(&mut rng, n);
    let mut out = vec![0f32; t * n];
    let mut dx = vec![0f32; t * k];

    let mut run = |backend: KernelBackend| {
        kernels::set_backend(backend);
        let fwd = time(reps, || {
            kernels::matmul(&x, &w, &mut out, MatShape::packed(t, k, n), MatInit::Bias(&b));
            std::hint::black_box(&out);
        });
        let bwd = time(reps, || {
            let sh = MatShape::packed_bt(t, n, k);
            kernels::matmul_bt(&out, &w, &mut dx, sh, MatInit::Accumulate);
            std::hint::black_box(&dx);
        });
        (fwd, bwd)
    };
    let (sf, sb) = run(KernelBackend::Scalar);
    let (vf, vb) = run(KernelBackend::Simd);
    kernels::set_backend(KernelBackend::Scalar);

    println!(
        "  {name:<26} fwd {:>9.1}ns -> {:>9.1}ns  ({:>4.2}x)   bwd {:>9.1}ns -> {:>9.1}ns  ({:>4.2}x)",
        sf * 1e9,
        vf * 1e9,
        sf / vf,
        sb * 1e9,
        vb * 1e9,
        sb / vb,
    );
}

/// One `nlu-small` gradient step (full batch, all reduction chunks) on the
/// kernel-backed executor.
fn bench_nlu_small_step(reps: usize) {
    let man = builtin_manifest();
    let model = man.model("nlu-small").expect("builtin");
    let rm = RefModel::from_manifest(model).expect("native");
    let store = sparse_dp_emb::models::ParamStore::init(model, 11).expect("init");
    let RefModel::Nlu(nm) = &rm else { panic!("nlu-small is nlu") };
    let (b, t, vocab) = (nm.batch_size, nm.seq_len, nm.vocab);
    let mut rng = Xoshiro256::seed_from(5);
    let ids: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32).collect();
    let labels: Vec<i32> = (0..b).map(|_| rng.below(2) as i32).collect();
    let params: Vec<HostTensor> = store.tensors();
    let view = TensorView::new(&params[..rm.num_params()], &rm).expect("view");
    let batch = BatchRef::Text { seq_len: t, ids: &ids, labels: &labels };

    let secs = time(reps, || {
        let mut lo = 0;
        while lo < b {
            let hi = (lo + sparse_dp_emb::runtime::reference::REDUCE_CHUNK).min(b);
            std::hint::black_box(rm.grads_chunk(&view, &batch, lo, hi, 1.0, 1.0));
            lo = hi;
        }
    });
    println!(
        "  nlu-small grads step       {:>8.2}ms  ({:.0} examples/s)",
        secs * 1e3,
        b as f64 / secs
    );
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let reps = if full { 20_000 } else { 2_000 };

    println!("blocked kernels vs retired scalar loops (per-call, {reps} reps)\n");
    println!("nlu-small shapes:");
    bench_matmul_pair("qkv/proj  32x64 . 64x64", 32, 64, 64, reps);
    bench_matmul_pair("mlp-in    32x64 . 64x128", 32, 64, 128, reps);
    bench_matmul_pair("mlp-out   32x128 . 128x64", 32, 128, 64, reps);
    println!("\nlarger shapes (blocking + L1 panel reuse dominate):");
    bench_matmul_pair("192x192 . 192x192", 192, 192, 192, reps / 20 + 1);
    bench_matmul_pair("512x256 . 256x256", 512, 256, 256, reps / 100 + 1);

    println!("\nexecutor microbench (kernel-backed, serial):");
    bench_nlu_small_step(if full { 200 } else { 20 });

    // the threaded fan-out on a shape above the par-min-work floor
    kernels::set_threads(4);
    println!("\nthreaded (kernel_threads = 4, large shape only):");
    bench_matmul_pair("512x256 . 256x256  t=4", 512, 256, 256, reps / 100 + 1);
    kernels::set_threads(1);

    // scalar backend vs the lane-parallel SIMD backend, same blocked kernels
    println!(
        "\nscalar backend vs simd backend (acceleration: {}):",
        kernels::simd_acceleration()
    );
    bench_backend_pair("qkv/proj  32x64 . 64x64", 32, 64, 64, reps);
    bench_backend_pair("mlp-in    32x64 . 64x128", 32, 64, 128, reps);
    bench_backend_pair("512x256 . 256x256", 512, 256, 256, reps / 100 + 1);
}
