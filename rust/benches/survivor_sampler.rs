//! `cargo bench --bench survivor_sampler` — Appendix B.2.
//!
//! The memory-efficient survivor sampler must be O(nnz + false-positives),
//! not O(c): compare it against the naive dense thresholding at growing
//! vocabulary sizes with fixed batch nnz.

use sparse_dp_emb::sparse::{survivors_dense, survivors_sparse};
use sparse_dp_emb::util::bench::Bencher;
use sparse_dp_emb::util::rng::Xoshiro256;

fn main() {
    let b = Bencher { samples: 7, ..Default::default() };
    let nnz = 2048; // batch-activated rows
    let (sigma1, c1, tau) = (2.0, 1.0, 6.0);

    println!("survivor sampler: nnz={nnz}, tau={tau}, sigma1={sigma1}\n");
    for &c in &[100_000usize, 1_000_000, 10_000_000] {
        let mut rng = Xoshiro256::seed_from(7);
        // nnz random distinct rows with count ~ 1..10
        let mut ids: Vec<u32> = (0..nnz * 2).map(|_| rng.below(c as u64) as u32).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.truncate(nnz);
        let nonzero: Vec<(u32, f32)> = ids
            .iter()
            .map(|&i| (i, 1.0 + rng.below(10) as f32))
            .collect();
        let mut dense = vec![0f32; c];
        for &(i, v) in &nonzero {
            dense[i as usize] = v;
        }

        let d = b.bench(&format!("dense-threshold/c={c}"), || {
            survivors_dense(&dense, sigma1, c1, tau, &mut rng).0.len()
        });
        let s = b.bench(&format!("sparse-sampler/c={c}"), || {
            survivors_sparse(&nonzero, c, sigma1, c1, tau, &mut rng).0.len()
        });
        println!(
            "  -> c={c}: speedup {:.1}x\n",
            d.per_iter_secs() / s.per_iter_secs()
        );
    }
    println!("expected: dense scales with c; sparse is ~flat (O(nnz + FP))");
}
