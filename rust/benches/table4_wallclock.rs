//! `cargo bench --bench table4_wallclock` — paper Table 4.
//!
//! Dense DP-SGD embedding update (dense Gaussian noise + dense write) vs the
//! sparsity-preserving update (scatter-add + row noise), per step, across
//! vocabulary sizes.  The reduction factor should grow roughly linearly
//! with the vocabulary (paper: 3x at 1e5 up to 177x at 1e7).

use sparse_dp_emb::sparse::{add_dense_noise, add_row_noise, DenseState, Optimizer, RowSparseGrad};
use sparse_dp_emb::util::bench::Bencher;
use sparse_dp_emb::util::rng::Xoshiro256;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let vocabs: &[usize] = if full {
        &[100_000, 200_000, 1_000_000, 2_000_000, 5_000_000, 10_000_000]
    } else {
        &[100_000, 200_000, 1_000_000, 2_000_000]
    };
    let (dim, batch) = (64, 1024);
    let b = Bencher { samples: 7, ..Default::default() };

    println!("Table 4 bench: d={dim}, B={batch} (pass --full for the 1e7 row)\n");
    let mut results = Vec::new();
    for &v in vocabs {
        let mut rng = Xoshiro256::seed_from(1);
        let opt = Optimizer::sgd(0.01);
        let mut table = vec![0.01f32; v * dim];
        let mut state = DenseState::default();
        let row_grad: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.01).sin()).collect();
        let rows: Vec<u32> = (0..batch).map(|_| rng.below(v as u64) as u32).collect();

        let mut dense_grad = vec![0f32; v * dim];
        let dense = b.bench(&format!("dense-update/V={v}"), || {
            for g in dense_grad.iter_mut() {
                *g = 0.0;
            }
            for &r in &rows {
                let base = r as usize * dim;
                for (g, x) in dense_grad[base..base + dim].iter_mut().zip(&row_grad) {
                    *g += x;
                }
            }
            add_dense_noise(&mut dense_grad, 1.0, &mut rng);
            opt.dense_step(&mut table, &dense_grad, &mut state);
        });

        let sparse = b.bench(&format!("sparse-update/V={v}"), || {
            let mut g = RowSparseGrad::with_capacity(v, dim, batch);
            for &r in &rows {
                g.add_row(r, &row_grad);
            }
            add_row_noise(&mut g, 1.0, &mut rng);
            opt.sparse_step(&mut table, &g, &mut state);
        });

        let factor = dense.per_iter_secs() / sparse.per_iter_secs();
        println!("  -> V={v}: reduction factor {factor:.1}x\n");
        results.push((v, factor));
    }

    println!("vocab,reduction_factor");
    for (v, f) in results {
        println!("{v},{f:.2}");
    }
}
