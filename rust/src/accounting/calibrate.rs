//! Inverse accounting: find the smallest noise multiplier σ meeting a target
//! (ε, δ) for a given sampling rate and step count, and split it into the
//! (σ₁, σ₂) pair DP-AdaFEST needs for a chosen noise ratio σ₁/σ₂.
//!
//! PLD calibration costs seconds and sweeps reuse budgets, so
//! [`calibrate_sigma`] memoizes through a **process-wide cache** — every
//! caller (the step core, `sparse-dp-emb account`, the harness sweeps,
//! [`calibrate_sigma_pair`]) shares it.  Keys are exact f64 bit patterns:
//! quantizing with `(x * 1e6) as u64` collided for nearby budgets and
//! truncated instead of rounding.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Result};

use super::Accountant;

static SIGMA_CACHE: Mutex<Option<HashMap<(u64, u64, u64, u64), f64>>> = Mutex::new(None);

/// Smallest σ such that the Poisson-subsampled Gaussian mechanism run for
/// `steps` steps at rate `q` satisfies (ε, δ)-DP, via the process-wide
/// cache.
pub fn calibrate_sigma(epsilon: f64, delta: f64, q: f64, steps: u64) -> Result<f64> {
    let key = (epsilon.to_bits(), delta.to_bits(), q.to_bits(), steps);
    {
        let cache = SIGMA_CACHE.lock().unwrap();
        if let Some(map) = cache.as_ref() {
            if let Some(&sigma) = map.get(&key) {
                return Ok(sigma);
            }
        }
    }
    let sigma = calibrate_sigma_uncached(epsilon, delta, q, steps)?;
    let mut cache = SIGMA_CACHE.lock().unwrap();
    cache.get_or_insert_with(HashMap::new).insert(key, sigma);
    Ok(sigma)
}

#[cfg(test)]
fn sigma_cache_has(epsilon: f64, delta: f64, q: f64, steps: u64) -> bool {
    let key = (epsilon.to_bits(), delta.to_bits(), q.to_bits(), steps);
    SIGMA_CACHE
        .lock()
        .unwrap()
        .as_ref()
        .is_some_and(|map| map.contains_key(&key))
}

/// The bisection behind [`calibrate_sigma`], cache-free — for callers that
/// measure calibration cost itself (`benches/accounting.rs`).
pub fn calibrate_sigma_uncached(epsilon: f64, delta: f64, q: f64, steps: u64) -> Result<f64> {
    if epsilon <= 0.0 || delta <= 0.0 || delta >= 1.0 {
        bail!("invalid privacy target eps={epsilon} delta={delta}");
    }
    let eps_of = |sigma: f64| Accountant::new(sigma, q, steps).epsilon(delta);

    let mut lo = 0.1f64;
    let mut hi = 2.0f64;
    // grow hi until it satisfies the budget
    while eps_of(hi) > epsilon {
        hi *= 2.0;
        if hi > 1e4 {
            bail!("calibration diverged: eps={epsilon} unreachable below sigma=1e4");
        }
    }
    // shrink lo until it violates (so the root is bracketed)
    while eps_of(lo) <= epsilon {
        lo *= 0.5;
        if lo < 1e-3 {
            return Ok(lo); // essentially no noise needed
        }
    }
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        if eps_of(mid) > epsilon {
            lo = mid;
        } else {
            hi = mid;
        }
        if (hi - lo) / hi < 1e-3 {
            break;
        }
    }
    Ok(hi)
}

/// The (σ₁, σ₂) noise pair for DP-AdaFEST (Algorithm 1) achieving the same
/// per-step privacy cost as a single Gaussian with `sigma_eff`, at the
/// requested ratio `ratio = σ₁/σ₂` (§4.5's tuning knob).
///
/// From `σ_eff = (σ₁⁻² + σ₂⁻²)^(−1/2)` and `σ₁ = r·σ₂`:
/// `σ₂ = σ_eff·√(1 + 1/r²)`, `σ₁ = r·σ₂`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SigmaPair {
    pub sigma1: f64,
    pub sigma2: f64,
}

pub fn calibrate_sigma_pair(
    epsilon: f64,
    delta: f64,
    q: f64,
    steps: u64,
    ratio: f64,
) -> Result<SigmaPair> {
    if ratio <= 0.0 {
        bail!("sigma ratio must be positive");
    }
    let sigma_eff = calibrate_sigma(epsilon, delta, q, steps)?;
    let sigma2 = sigma_eff * (1.0 + 1.0 / (ratio * ratio)).sqrt();
    let sigma1 = ratio * sigma2;
    Ok(SigmaPair { sigma1, sigma2 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accounting::gaussian::compose_sigmas;

    #[test]
    fn calibrated_sigma_meets_budget() {
        let (eps, delta, q, t) = (2.0, 1e-5, 0.02, 200);
        let sigma = calibrate_sigma(eps, delta, q, t).unwrap();
        let achieved = Accountant::new(sigma, q, t).epsilon(delta);
        assert!(achieved <= eps * 1.005, "achieved {achieved} > target {eps}");
        // ... and is not wastefully large: 5% smaller sigma must violate
        let achieved_tight = Accountant::new(sigma * 0.95, q, t).epsilon(delta);
        assert!(achieved_tight > eps * 0.98, "sigma not tight: {achieved_tight}");
    }

    #[test]
    fn sigma_grows_with_steps_and_budget_tightness() {
        let s_few = calibrate_sigma(1.0, 1e-5, 0.02, 50).unwrap();
        let s_many = calibrate_sigma(1.0, 1e-5, 0.02, 800).unwrap();
        assert!(s_many > s_few);
        let s_loose = calibrate_sigma(8.0, 1e-5, 0.02, 50).unwrap();
        assert!(s_loose < s_few);
    }

    #[test]
    fn sigma_cache_memoizes_and_distinguishes_nearby_budgets() {
        // regression: (x * 1e6) as u64 mapped 1.0 and 1.0000005 to the same
        // key.  With to_bits keys the cache must treat them as distinct.
        assert_ne!((1.0f64).to_bits(), (1.000_000_5f64).to_bits());
        // a call populates the cache under its exact key, and repeated /
        // pair calibrations are served from it
        let (eps, delta, q, t) = (1.375, 2e-5, 0.0175, 60);
        let first = calibrate_sigma(eps, delta, q, t).unwrap();
        assert!(sigma_cache_has(eps, delta, q, t));
        let second = calibrate_sigma(eps, delta, q, t).unwrap();
        assert_eq!(first, second);
        let pair = calibrate_sigma_pair(eps, delta, q, t, 5.0).unwrap();
        let eff = compose_sigmas(pair.sigma1, pair.sigma2);
        assert!((eff - first).abs() / first < 1e-9);
    }

    #[test]
    fn pair_composes_back_to_effective_sigma() {
        let pair = calibrate_sigma_pair(2.0, 1e-5, 0.02, 100, 5.0).unwrap();
        let eff = compose_sigmas(pair.sigma1, pair.sigma2);
        let direct = calibrate_sigma(2.0, 1e-5, 0.02, 100).unwrap();
        assert!((eff - direct).abs() / direct < 1e-9);
        assert!((pair.sigma1 / pair.sigma2 - 5.0).abs() < 1e-9);
        // a large ratio puts almost all the budget on the gradients:
        // sigma2 -> sigma_eff from above
        let pair_big = calibrate_sigma_pair(2.0, 1e-5, 0.02, 100, 100.0).unwrap();
        assert!(pair_big.sigma2 < pair.sigma2);
        assert!((pair_big.sigma2 - direct).abs() / direct < 1e-3);
    }
}
