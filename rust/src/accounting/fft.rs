//! Minimal iterative radix-2 complex FFT for PLD self-composition
//! (no external FFT crate in the offline set).

/// In-place iterative Cooley–Tukey FFT on interleaved (re, im) pairs.
/// `invert = true` computes the inverse transform including the 1/n scale.
pub fn fft(re: &mut [f64], im: &mut [f64], invert: bool) {
    let n = re.len();
    assert!(n.is_power_of_two(), "fft size must be a power of two");
    assert_eq!(im.len(), n);

    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }

    let sign = if invert { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (vr0, vi0) = (re[i + k + len / 2], im[i + k + len / 2]);
                let vr = vr0 * cr - vi0 * ci;
                let vi = vr0 * ci + vi0 * cr;
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }

    if invert {
        let inv = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= inv;
        }
        for v in im.iter_mut() {
            *v *= inv;
        }
    }
}

/// Linear convolution of two non-negative real sequences via FFT.
/// Output length is `a.len() + b.len() - 1`; small negative round-off
/// values are clamped to zero (inputs are probability masses).
pub fn convolve(a: &[f64], b: &[f64]) -> Vec<f64> {
    let out_len = a.len() + b.len() - 1;
    let n = out_len.next_power_of_two();
    let mut ar = vec![0f64; n];
    let mut ai = vec![0f64; n];
    let mut br = vec![0f64; n];
    let mut bi = vec![0f64; n];
    ar[..a.len()].copy_from_slice(a);
    br[..b.len()].copy_from_slice(b);
    fft(&mut ar, &mut ai, false);
    fft(&mut br, &mut bi, false);
    for i in 0..n {
        let r = ar[i] * br[i] - ai[i] * bi[i];
        let im = ar[i] * bi[i] + ai[i] * br[i];
        ar[i] = r;
        ai[i] = im;
    }
    fft(&mut ar, &mut ai, true);
    ar.truncate(out_len);
    for v in ar.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    ar
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn convolve_matches_naive() {
        let a = [0.1, 0.4, 0.5];
        let b = [0.25, 0.25, 0.25, 0.25];
        let got = convolve(&a, &b);
        let mut want = vec![0f64; a.len() + b.len() - 1];
        for (i, &x) in a.iter().enumerate() {
            for (j, &y) in b.iter().enumerate() {
                want[i + j] += x * y;
            }
        }
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12, "{g} vs {w}");
        }
    }

    #[test]
    fn convolution_preserves_mass() {
        let a = vec![0.125f64; 8];
        let b = vec![0.0625f64; 16];
        let c = convolve(&a, &b);
        let mass: f64 = c.iter().sum();
        assert!((mass - 1.0).abs() < 1e-10);
    }

    #[test]
    fn fft_roundtrip() {
        let orig: Vec<f64> = (0..64).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut re = orig.clone();
        let mut im = vec![0f64; 64];
        fft(&mut re, &mut im, false);
        fft(&mut re, &mut im, true);
        for (a, b) in re.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
