//! Closed-form Gaussian-mechanism results used both directly (AdaFEST's
//! two-noise composition) and as ground truth for the PLD accountant.

use crate::util::stats::gauss_cdf;

/// Analytic δ(ε) for the sensitivity-1 Gaussian mechanism with noise
/// multiplier σ (Balle & Wang 2018, Theorem 8):
/// `δ = Φ(1/(2σ) − εσ) − e^ε · Φ(−1/(2σ) − εσ)`.
pub fn gaussian_delta(epsilon: f64, sigma: f64) -> f64 {
    let a = 1.0 / (2.0 * sigma);
    (gauss_cdf(a - epsilon * sigma) - epsilon.exp() * gauss_cdf(-a - epsilon * sigma)).max(0.0)
}

/// Analytic ε(δ) for the Gaussian mechanism, by bisection on
/// [`gaussian_delta`] (monotone decreasing in ε).
pub fn gaussian_epsilon(delta: f64, sigma: f64) -> f64 {
    let mut lo = 0.0f64;
    let mut hi = 1.0f64;
    while gaussian_delta(hi, sigma) > delta {
        hi *= 2.0;
        if hi > 1e6 {
            return f64::INFINITY;
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if gaussian_delta(mid, sigma) > delta {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    hi
}

/// DRS19 Corollary 3.3 (paper §3.3): composing Gaussian mechanisms with
/// multipliers σ₁ and σ₂ equals a single Gaussian mechanism with
/// `σ = (σ₁⁻² + σ₂⁻²)^(−1/2)`.
pub fn compose_sigmas(sigma1: f64, sigma2: f64) -> f64 {
    (sigma1.powi(-2) + sigma2.powi(-2)).powf(-0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_decreasing_in_epsilon_and_sigma() {
        assert!(gaussian_delta(0.5, 1.0) > gaussian_delta(1.0, 1.0));
        assert!(gaussian_delta(1.0, 0.5) > gaussian_delta(1.0, 2.0));
    }

    #[test]
    fn epsilon_delta_roundtrip() {
        for sigma in [0.7, 1.0, 3.0] {
            let eps = gaussian_epsilon(1e-5, sigma);
            let back = gaussian_delta(eps, sigma);
            assert!((back - 1e-5).abs() < 1e-8, "sigma={sigma}: {back}");
        }
    }

    #[test]
    fn known_value() {
        // σ = 1: δ(ε=1) = Φ(0.5 − 1) − e·Φ(−0.5 − 1)
        //       = Φ(−0.5) − e·Φ(−1.5) ≈ 0.30854 − 2.71828·0.066807 ≈ 0.12693
        let d = gaussian_delta(1.0, 1.0);
        assert!((d - 0.12693).abs() < 1e-4, "{d}");
    }

    #[test]
    fn compose_sigmas_matches_paper() {
        // equal noise: σ_eff = σ/√2
        let s = compose_sigmas(2.0, 2.0);
        assert!((s - 2.0 / 2f64.sqrt()).abs() < 1e-12);
        // one mechanism infinitely noisy: composition is the other one
        let s = compose_sigmas(1e9, 1.5);
        assert!((s - 1.5).abs() < 1e-6);
        // composition is always *noisier budget-wise* (smaller σ_eff)
        assert!(compose_sigmas(1.0, 5.0) < 1.0);
    }
}
