//! Numerical privacy accounting (paper §3.3, Appendix C).
//!
//! Reimplements the accountant the paper takes from Google's DP library:
//! privacy-loss distributions (PLDs) of the Poisson-subsampled Gaussian
//! mechanism, discretised pessimistically, self-composed over `T` steps with
//! FFT convolution, and inverted (`σ` from `(ε, δ)`) by bisection.
//!
//! Key algebraic fact used by DP-AdaFEST (§3.3 / DRS19 Cor. 3.3): one step =
//! composition of two Gaussian mechanisms with multipliers σ₁ (contribution
//! map) and σ₂ (gradients), which is *exactly* a single Gaussian mechanism
//! with `σ_eff = (σ₁⁻² + σ₂⁻²)^(−1/2)` — so the whole run is accounted as
//! DP-SGD with σ_eff.  (Appendix C.4 of the paper prints the exponent as
//! −2; −1/2 is the correct value, as in §3.3.)

mod calibrate;
mod fft;
mod gaussian;
mod pld;

pub use calibrate::{calibrate_sigma, calibrate_sigma_pair, calibrate_sigma_uncached, SigmaPair};
pub use gaussian::{compose_sigmas, gaussian_delta, gaussian_epsilon};
pub use pld::{Adjacency, Pld, SubsampledGaussian};

/// A target (ε, δ) privacy budget.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrivacyBudget {
    pub epsilon: f64,
    pub delta: f64,
}

impl PrivacyBudget {
    pub fn new(epsilon: f64, delta: f64) -> Self {
        assert!(epsilon > 0.0 && delta > 0.0 && delta < 1.0);
        PrivacyBudget { epsilon, delta }
    }
}

/// End-to-end accountant for a training run: Poisson-subsampled Gaussian
/// mechanism, sampling rate `q = B/N`, `steps` iterations.
#[derive(Clone, Debug)]
pub struct Accountant {
    pub sigma: f64,
    pub q: f64,
    pub steps: u64,
}

impl Accountant {
    pub fn new(sigma: f64, q: f64, steps: u64) -> Self {
        assert!(sigma > 0.0 && q > 0.0 && q <= 1.0 && steps > 0);
        Accountant { sigma, q, steps }
    }

    /// δ(ε) after all steps (max over add/remove adjacency directions).
    pub fn delta(&self, epsilon: f64) -> f64 {
        let mech = SubsampledGaussian { sigma: self.sigma, q: self.q };
        let d1 = Pld::of(&mech, Adjacency::Remove)
            .compose_pow(self.steps)
            .delta(epsilon);
        let d2 = Pld::of(&mech, Adjacency::Add)
            .compose_pow(self.steps)
            .delta(epsilon);
        d1.max(d2)
    }

    /// ε(δ) after all steps.
    pub fn epsilon(&self, delta: f64) -> f64 {
        let mech = SubsampledGaussian { sigma: self.sigma, q: self.q };
        let p1 = Pld::of(&mech, Adjacency::Remove).compose_pow(self.steps);
        let p2 = Pld::of(&mech, Adjacency::Add).compose_pow(self.steps);
        let e1 = p1.epsilon(delta);
        let e2 = p2.epsilon(delta);
        e1.max(e2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epsilon_monotone_in_steps_and_sigma() {
        let e_100 = Accountant::new(1.0, 0.01, 100).epsilon(1e-5);
        let e_400 = Accountant::new(1.0, 0.01, 400).epsilon(1e-5);
        assert!(e_400 > e_100, "{e_400} !> {e_100}");
        let e_tight = Accountant::new(2.0, 0.01, 100).epsilon(1e-5);
        assert!(e_tight < e_100, "{e_tight} !< {e_100}");
    }

    #[test]
    fn epsilon_monotone_in_q() {
        let lo = Accountant::new(1.0, 0.005, 200).epsilon(1e-5);
        let hi = Accountant::new(1.0, 0.05, 200).epsilon(1e-5);
        assert!(hi > lo, "{hi} !> {lo}");
    }

    #[test]
    fn no_subsampling_single_step_matches_closed_form() {
        // q = 1, T = 1: PLD must match the analytic Gaussian mechanism.
        let acct = Accountant::new(2.0, 1.0, 1);
        for eps in [0.1, 0.5, 1.0, 2.0] {
            let pld = acct.delta(eps);
            let exact = gaussian_delta(eps, 2.0);
            assert!(
                (pld - exact).abs() < 2e-4 + 0.02 * exact,
                "eps={eps}: pld {pld} vs exact {exact}"
            );
            // discretisation is pessimistic: never *under*-reports delta
            assert!(pld >= exact - 1e-9, "eps={eps}: {pld} < {exact}");
        }
    }

    #[test]
    fn composition_bracketed_by_basic_composition() {
        // eps_T(δ) <= T * eps_1(δ/T) (basic composition upper bound)
        let t = 64u64;
        let single = Accountant::new(1.0, 0.02, 1);
        let multi = Accountant::new(1.0, 0.02, t);
        let delta = 1e-5;
        let e_multi = multi.epsilon(delta);
        let e_basic = t as f64 * single.epsilon(delta / t as f64);
        assert!(
            e_multi <= e_basic * 1.02,
            "PLD {e_multi} should beat basic composition {e_basic}"
        );
        // ... and at least as large as one step at the same delta
        let e_single = single.epsilon(delta);
        assert!(e_multi >= e_single * 0.98, "{e_multi} vs single {e_single}");
    }

    #[test]
    fn delta_epsilon_inverse_roundtrip() {
        let acct = Accountant::new(1.2, 0.01, 500);
        let eps = acct.epsilon(1e-5);
        let delta_back = acct.delta(eps);
        assert!(
            (delta_back.log10() - (-5.0)).abs() < 0.15,
            "delta(eps(1e-5)) = {delta_back:e}"
        );
    }
}
