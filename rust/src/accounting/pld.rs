//! Discretised privacy-loss distributions (PLDs) of the Poisson-subsampled
//! Gaussian mechanism, with pessimistic rounding and FFT self-composition —
//! the numerical core of §3.3 / Appendix C.5, in the style of
//! [KJH20, GLW21, DGK+22].
//!
//! Dominating pair (Lemma C.4): `P = (1−q)·N(0,σ²) + q·N(1,σ²)` vs
//! `Q = N(0,σ²)`.  We account both adjacency directions:
//!
//! * `Remove` — x ~ P, loss `ℓ(x) = ln(dP/dQ) = ln((1−q) + q·e^{(2x−1)/(2σ²)})`
//!   (monotone increasing in x);
//! * `Add`    — x ~ Q, loss `ℓ'(x) = −ln((1−q) + q·e^{(2x−1)/(2σ²)})`
//!   (monotone decreasing in x).
//!
//! Discretisation is *pessimistic*: each x-cell's mass is assigned the
//! maximal loss in the cell rounded **up** to the grid, and truncated tail
//! mass goes to the `+∞`-loss bucket, so reported δ is an upper bound.

use crate::util::stats::gauss_cdf;

use super::fft::convolve;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Adjacency {
    /// D = D' + one example (x ~ P mixture).
    Remove,
    /// D' = D + one example (x ~ Q).
    Add,
}

/// The Poisson-subsampled Gaussian mechanism: noise multiplier `sigma`,
/// sampling probability `q`.
#[derive(Clone, Copy, Debug)]
pub struct SubsampledGaussian {
    pub sigma: f64,
    pub q: f64,
}

impl SubsampledGaussian {
    /// `ln((1−q) + q·e^a)` computed overflow-safely.
    fn log_mix(&self, a: f64) -> f64 {
        let q = self.q;
        if q >= 1.0 {
            return a;
        }
        if a <= 0.0 {
            ((1.0 - q) + q * a.exp()).ln()
        } else {
            // ln((1-q) + q e^a) = a + ln(q + (1-q)e^{-a})
            a + (q + (1.0 - q) * (-a).exp()).ln()
        }
    }

    /// Privacy loss at sample x for the given direction.
    fn loss(&self, x: f64, dir: Adjacency) -> f64 {
        let a = (2.0 * x - 1.0) / (2.0 * self.sigma * self.sigma);
        match dir {
            Adjacency::Remove => self.log_mix(a),
            Adjacency::Add => -self.log_mix(a),
        }
    }

    /// CDF of the sampling distribution for the direction.
    fn cdf(&self, x: f64, dir: Adjacency) -> f64 {
        match dir {
            Adjacency::Remove => {
                (1.0 - self.q) * gauss_cdf(x / self.sigma)
                    + self.q * gauss_cdf((x - 1.0) / self.sigma)
            }
            Adjacency::Add => gauss_cdf(x / self.sigma),
        }
    }
}

/// Discrete PLD: `pmf[i]` is the probability of privacy loss
/// `(min_index + i) * dl`, plus `inf_mass` at `+∞`.
#[derive(Clone, Debug)]
pub struct Pld {
    pub dl: f64,
    pub min_index: i64,
    pub pmf: Vec<f64>,
    pub inf_mass: f64,
    /// truncation cap (losses are clamped into ±cap before/after composing)
    pub cap: f64,
}

/// Discretisation parameters.  `dl` trades accuracy for speed; the default
/// gives ≲0.01 ε error after thousands of compositions.
#[derive(Clone, Copy, Debug)]
pub struct PldParams {
    pub dl: f64,
    pub cap: f64,
    pub x_cells: usize,
    pub x_span_sigmas: f64,
}

impl Default for PldParams {
    fn default() -> Self {
        PldParams { dl: 5e-4, cap: 32.0, x_cells: 100_000, x_span_sigmas: 14.0 }
    }
}

impl Pld {
    pub fn of(mech: &SubsampledGaussian, dir: Adjacency) -> Pld {
        Pld::of_with(mech, dir, PldParams::default())
    }

    pub fn of_with(mech: &SubsampledGaussian, dir: Adjacency, p: PldParams) -> Pld {
        assert!(mech.sigma > 0.0 && mech.q > 0.0 && mech.q <= 1.0);
        let span = p.x_span_sigmas * mech.sigma;
        let (x_lo, x_hi) = (-span, 1.0 + span);
        let n = p.x_cells;
        let dx = (x_hi - x_lo) / n as f64;

        let cap_idx = (p.cap / p.dl).round() as i64;
        let mut pmf_map = vec![0f64; (2 * cap_idx + 1) as usize];
        let mut inf_mass = 0.0;

        // Tail mass (≈1e-40 at 14σ) is assigned to +∞ — pessimistic, valid.
        inf_mass += mech.cdf(x_lo, dir);
        inf_mass += 1.0 - mech.cdf(x_hi, dir);

        let mut cdf_prev = mech.cdf(x_lo, dir);
        let mut loss_prev = mech.loss(x_lo, dir);
        for i in 0..n {
            let x_next = x_lo + (i + 1) as f64 * dx;
            let cdf_next = mech.cdf(x_next, dir);
            let loss_next = mech.loss(x_next, dir);
            let mass = (cdf_next - cdf_prev).max(0.0);
            if mass > 0.0 {
                // pessimistic: max loss in the cell, rounded up to the grid
                let l = loss_prev.max(loss_next);
                let idx = (l / p.dl).ceil() as i64;
                if idx > cap_idx {
                    inf_mass += mass;
                } else {
                    let slot = (idx.max(-cap_idx) + cap_idx) as usize;
                    pmf_map[slot] += mass;
                }
            }
            cdf_prev = cdf_next;
            loss_prev = loss_next;
        }

        let mut pld = Pld {
            dl: p.dl,
            min_index: -cap_idx,
            pmf: pmf_map,
            inf_mass,
            cap: p.cap,
        };
        pld.trim();
        pld
    }

    /// Drop leading/trailing zero mass (keeps convolutions small).
    fn trim(&mut self) {
        let eps = 0.0;
        let first = self.pmf.iter().position(|&v| v > eps).unwrap_or(0);
        let last = self.pmf.iter().rposition(|&v| v > eps).unwrap_or(0);
        if first > 0 || last + 1 < self.pmf.len() {
            self.pmf = self.pmf[first..=last].to_vec();
            self.min_index += first as i64;
        }
    }

    /// Clamp losses into ±cap: mass above cap → ∞-bucket; mass below −cap
    /// accumulates at −cap (rounding up ⇒ pessimistic).
    fn truncate(&mut self) {
        let cap_idx = (self.cap / self.dl).round() as i64;
        let lo = self.min_index;
        let hi = self.min_index + self.pmf.len() as i64 - 1;
        if lo >= -cap_idx && hi <= cap_idx {
            return;
        }
        let new_lo = lo.max(-cap_idx);
        let new_hi = hi.min(cap_idx);
        let mut new_pmf = vec![0f64; (new_hi - new_lo + 1) as usize];
        for (i, &m) in self.pmf.iter().enumerate() {
            if m == 0.0 {
                continue;
            }
            let idx = lo + i as i64;
            if idx > cap_idx {
                self.inf_mass += m;
            } else {
                let clamped = idx.max(-cap_idx);
                new_pmf[(clamped - new_lo) as usize] += m;
            }
        }
        self.pmf = new_pmf;
        self.min_index = new_lo;
        self.trim();
    }

    /// Compose two PLDs (independent mechanisms): convolution of losses.
    pub fn compose(&self, other: &Pld) -> Pld {
        assert!((self.dl - other.dl).abs() < 1e-15, "grid mismatch");
        let pmf = convolve(&self.pmf, &other.pmf);
        let inf = 1.0 - (1.0 - self.inf_mass) * (1.0 - other.inf_mass);
        let mut out = Pld {
            dl: self.dl,
            min_index: self.min_index + other.min_index,
            pmf,
            inf_mass: inf,
            cap: self.cap,
        };
        out.truncate();
        out
    }

    /// T-fold self-composition by exponentiation-by-squaring.
    pub fn compose_pow(&self, t: u64) -> Pld {
        assert!(t >= 1);
        let mut result: Option<Pld> = None;
        let mut base = self.clone();
        let mut k = t;
        loop {
            if k & 1 == 1 {
                result = Some(match result {
                    None => base.clone(),
                    Some(r) => r.compose(&base),
                });
            }
            k >>= 1;
            if k == 0 {
                break;
            }
            base = base.compose(&base);
        }
        result.unwrap()
    }

    /// Hockey-stick divergence: `δ(ε) = Σ_{ℓ>ε} p(ℓ)·(1 − e^{ε−ℓ}) + inf_mass`.
    pub fn delta(&self, epsilon: f64) -> f64 {
        let mut d = self.inf_mass;
        for (i, &m) in self.pmf.iter().enumerate() {
            if m == 0.0 {
                continue;
            }
            let l = (self.min_index + i as i64) as f64 * self.dl;
            if l > epsilon {
                d += m * (1.0 - (epsilon - l).exp());
            }
        }
        d.min(1.0)
    }

    /// Smallest ε with `δ(ε) ≤ delta` (bisection; δ is monotone in ε).
    pub fn epsilon(&self, delta: f64) -> f64 {
        if self.inf_mass > delta {
            return f64::INFINITY;
        }
        if self.delta(0.0) <= delta {
            return 0.0;
        }
        let mut lo = 0.0;
        let mut hi = self.cap * 2.0; // composed losses clamp at ±cap... per-step; after compose ±cap again
        if self.delta(hi) > delta {
            return f64::INFINITY;
        }
        for _ in 0..100 {
            let mid = 0.5 * (lo + hi);
            if self.delta(mid) > delta {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        hi
    }

    pub fn total_mass(&self) -> f64 {
        self.pmf.iter().sum::<f64>() + self.inf_mass
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accounting::gaussian::gaussian_delta;

    #[test]
    fn mass_is_conserved() {
        let mech = SubsampledGaussian { sigma: 1.0, q: 0.05 };
        for dir in [Adjacency::Remove, Adjacency::Add] {
            let pld = Pld::of(&mech, dir);
            let m = pld.total_mass();
            assert!((m - 1.0).abs() < 1e-9, "{dir:?}: mass {m}");
            let c = pld.compose_pow(32);
            let mc = c.total_mass();
            assert!((mc - 1.0).abs() < 1e-7, "{dir:?} composed: mass {mc}");
        }
    }

    #[test]
    fn q1_single_step_matches_analytic_gaussian() {
        let mech = SubsampledGaussian { sigma: 1.5, q: 1.0 };
        let pld = Pld::of(&mech, Adjacency::Remove);
        for eps in [0.25, 0.5, 1.0] {
            let got = pld.delta(eps);
            let want = gaussian_delta(eps, 1.5);
            assert!(got >= want - 1e-12, "pessimism violated: {got} < {want}");
            assert!(got - want < 3e-4, "eps={eps}: {got} vs {want}");
        }
    }

    #[test]
    fn q1_composition_matches_sqrt_t_scaling() {
        // T compositions of Gaussian(σ) == single Gaussian(σ/√T)
        let t = 16u64;
        let mech = SubsampledGaussian { sigma: 4.0, q: 1.0 };
        let composed = Pld::of(&mech, Adjacency::Remove).compose_pow(t);
        let eff_sigma = 4.0 / (t as f64).sqrt();
        for eps in [0.5, 1.0, 2.0] {
            let got = composed.delta(eps);
            let want = gaussian_delta(eps, eff_sigma);
            assert!(
                (got - want).abs() < 5e-3 * (1.0 + want),
                "eps={eps}: {got} vs {want}"
            );
            assert!(got >= want - 1e-9, "pessimism violated");
        }
    }

    #[test]
    fn subsampling_helps() {
        // At the same sigma and T, smaller q must give smaller epsilon.
        let t = 128;
        let e_full = Pld::of(&SubsampledGaussian { sigma: 1.0, q: 1.0 }, Adjacency::Remove)
            .compose_pow(t)
            .epsilon(1e-5);
        let e_sub = Pld::of(&SubsampledGaussian { sigma: 1.0, q: 0.01 }, Adjacency::Remove)
            .compose_pow(t)
            .epsilon(1e-5);
        assert!(e_sub < e_full / 5.0, "{e_sub} vs {e_full}");
    }

    #[test]
    fn delta_monotone_decreasing_in_epsilon() {
        let pld = Pld::of(&SubsampledGaussian { sigma: 1.0, q: 0.02 }, Adjacency::Remove)
            .compose_pow(100);
        let mut prev = 1.0;
        for i in 0..20 {
            let d = pld.delta(i as f64 * 0.2);
            assert!(d <= prev + 1e-15);
            prev = d;
        }
    }
}
