//! Run configuration: typed config struct + `--key value` CLI parsing +
//! `key = value` config-file loading (no serde in the offline crate set —
//! the format is a deliberately tiny TOML subset).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::Algorithm;
use crate::kernels::KernelBackend;
use crate::selection::FrequencySource;
use crate::sparse::OptimizerKind;

/// Configuration of the asynchronous sharded engine (`train-async`).
///
/// Every knob except `staleness` is throughput-only: the engine is
/// bit-for-bit equivalent to the sync trainer at any worker/shard/depth
/// setting (see `engine/` module docs and `docs/CONCURRENCY.md`).
/// `staleness` is the one deliberate exception — at `> 0` it trades
/// bit-exactness for pipelining, with the privacy accounting unchanged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineConfig {
    /// gradient workers computing per-example clipped grads (`--engine-workers`)
    pub grad_workers: usize,
    /// pipelined batch-generation workers (`--engine-data-workers`)
    pub data_workers: usize,
    /// bound of the (step, batch) channel — pipeline depth (`--engine-channel-depth`)
    pub channel_depth: usize,
    /// row-range shards per embedding table (`--engine-shards`)
    pub shards: usize,
    /// 16-example reduction chunks dispatched per task (`--engine-microbatch`)
    pub microbatch_chunks: usize,
    /// threads the blocked executor kernels may fan output tiles across
    /// (`--engine-kernel-threads`; 1 = serial, the default).  Applied by
    /// both trainers at run start (`crate::kernels::set_threads`); like the
    /// other knobs it cannot change results — kernel threading partitions
    /// output rows and never splits an accumulation chain.  Large calls
    /// only (see `crate::kernels::par_min_work`); prefer `--engine-workers`
    /// for engine runs, which already parallelise across examples.
    pub kernel_threads: usize,
    /// kernel backend (`--engine-kernel-backend`): `scalar` (the default)
    /// keeps the bit-exact blocked chains; `simd` switches both trainers to
    /// the lane-parallel kernels (`crate::kernels::simd`), which
    /// reassociate the k-accumulation and are therefore ULP-close to — not
    /// bit-identical with — the scalar results (`docs/RUNTIME.md`).  Like
    /// `kernel_threads` it is applied for the run's scope only
    /// (`crate::kernels::ScopedConfig`) and composes with it; shipped to
    /// gradient actor processes in their `GradInit` frame.
    pub kernel_backend: KernelBackend,
    /// bounded staleness window (`--engine-staleness`): max steps the
    /// barrier may leave in flight, so gradient workers compute against
    /// parameter snapshots up to this many applies old.  The **only**
    /// engine knob that changes the trained model when non-zero — the
    /// default 0 is today's bit-exact behavior; `docs/CONCURRENCY.md` has
    /// the accounting argument and the decision table for turning it up.
    pub staleness: usize,
    /// multi-process mode (`--engine-processes`): at ≥ 2, replace the
    /// worker threads with this many gradient actor *processes* (plus
    /// `data_workers` data actor processes) talking to the barrier over
    /// unix-domain sockets; `grad_workers` and `microbatch` are then
    /// inert.  Throughput/isolation-only — bit-identical to the
    /// in-process engine and the sync trainer (`docs/ENGINE.md`).
    pub processes: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            grad_workers: 4,
            data_workers: 2,
            channel_depth: 8,
            shards: 16,
            microbatch_chunks: 1,
            kernel_threads: 1,
            kernel_backend: KernelBackend::Scalar,
            staleness: 0,
            processes: 1,
        }
    }
}

/// Full configuration of one training run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// model name in the manifest (`criteo-small`, `nlu-roberta`, ...)
    pub model: String,
    pub algorithm: Algorithm,
    pub steps: u64,
    pub eval_batches: usize,
    pub seed: u64,
    pub lr: f32,
    pub optimizer: OptimizerKind,

    // privacy
    pub epsilon: f64,
    pub delta: f64,
    /// dataset size N used for q = B/N and delta = 1/N defaults
    pub dataset_size: u64,
    /// contribution-map vs gradient noise ratio σ₁/σ₂ (§4.5)
    pub sigma_ratio: f64,
    pub tau: f64,
    pub c1: f64,
    pub c2: f64,

    // DP-FEST
    pub fest_top_k: usize,
    pub fest_epsilon: f64,
    pub freq_source: FrequencySource,

    // exponential-selection baseline
    pub exp_select_m: usize,

    // streaming (time-series) mode
    pub streaming_period: usize,

    // memory-efficient filtering (Appendix B.2) on/off
    pub memory_efficient_filtering: bool,

    /// Table 6: freeze word embeddings during DP fine-tuning (no update, no
    /// noise; gradient size counts 0 embedding coords)
    pub freeze_embedding: bool,

    pub artifacts_dir: String,

    /// telemetry JSONL sink path (`--metrics-out`); empty = disabled.
    /// Purely observational: enabling it cannot change trained results.
    pub metrics_out: String,

    /// paged-store budget in MiB (`--store-budget-mb`): at > 0, embedding
    /// tables live in page files on disk behind an LRU page cache of at
    /// most this many bytes (split across tables; per process in
    /// multi-process mode).  0 — the default — keeps every table in RAM.
    /// Throughput/memory-only: bit-exact at any setting (`docs/ENGINE.md`,
    /// `tests/store.rs`).
    pub store_budget_mb: usize,

    /// directory for the paged store's page files (`--store-dir`); empty =
    /// the system temp dir.  Files are removed on clean shutdown.
    pub store_dir: String,

    /// async engine knobs (throughput-only, except the opt-in
    /// [`EngineConfig::staleness`] window)
    pub engine: EngineConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "criteo-small".into(),
            algorithm: Algorithm::DpAdaFest,
            steps: 200,
            eval_batches: 20,
            seed: 17,
            lr: 0.05,
            optimizer: OptimizerKind::Adagrad,
            epsilon: 1.0,
            delta: 0.0, // 0 ⇒ use 1/dataset_size
            dataset_size: 1_000_000,
            sigma_ratio: 5.0,
            tau: 5.0,
            c1: 1.0,
            c2: 1.0,
            fest_top_k: 4096,
            fest_epsilon: 0.01,
            freq_source: FrequencySource::Streaming,
            exp_select_m: 1024,
            streaming_period: 1,
            memory_efficient_filtering: true,
            freeze_embedding: false,
            artifacts_dir: "artifacts".into(),
            metrics_out: String::new(),
            store_budget_mb: 0,
            store_dir: String::new(),
            engine: EngineConfig::default(),
        }
    }
}

impl RunConfig {
    pub fn effective_delta(&self) -> f64 {
        if self.delta > 0.0 {
            self.delta
        } else {
            1.0 / self.dataset_size as f64
        }
    }

    /// Reject `--store-budget-mb` / `--store-dir` on commands that do not
    /// read them.  Only `train-async` (the engine's sharded store) and
    /// `sweep fullscale` (the paged-store harness) honor the paged-store
    /// flags; everywhere else they used to be silently ignored, so a run
    /// the user believed was budget-capped kept every table in RAM.  Like
    /// the `--stream` check in `main.rs`, an explicit error beats a silent
    /// no-op.  `experiment` is the sweep id for `command == "sweep"`.
    pub fn reject_unused_store_flags(
        &self,
        command: &str,
        experiment: Option<&str>,
    ) -> Result<()> {
        let honored =
            command == "train-async" || (command == "sweep" && experiment == Some("fullscale"));
        if honored {
            return Ok(());
        }
        let flag = if self.store_budget_mb > 0 {
            "--store-budget-mb"
        } else if !self.store_dir.is_empty() {
            "--store-dir"
        } else {
            return Ok(());
        };
        bail!(
            "{flag} only applies to train-async and `sweep fullscale` — `{command}` would \
             silently ignore it and keep every table in RAM"
        );
    }

    /// Apply one `key = value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = value.trim();
        match key {
            "model" => self.model = v.into(),
            "algorithm" => self.algorithm = v.parse()?,
            "steps" => self.steps = v.parse().context("steps")?,
            "eval_batches" => self.eval_batches = v.parse().context("eval_batches")?,
            "seed" => self.seed = v.parse().context("seed")?,
            "lr" => self.lr = v.parse().context("lr")?,
            "optimizer" => self.optimizer = v.parse()?,
            "epsilon" => self.epsilon = v.parse().context("epsilon")?,
            "delta" => self.delta = v.parse().context("delta")?,
            "dataset_size" => self.dataset_size = v.parse().context("dataset_size")?,
            "sigma_ratio" => self.sigma_ratio = v.parse().context("sigma_ratio")?,
            "tau" => self.tau = v.parse().context("tau")?,
            "c1" => self.c1 = v.parse().context("c1")?,
            "c2" => self.c2 = v.parse().context("c2")?,
            "fest_top_k" => self.fest_top_k = v.parse().context("fest_top_k")?,
            "fest_epsilon" => self.fest_epsilon = v.parse().context("fest_epsilon")?,
            "freq_source" => self.freq_source = v.parse()?,
            "exp_select_m" => self.exp_select_m = v.parse().context("exp_select_m")?,
            "streaming_period" => {
                self.streaming_period = v.parse().context("streaming_period")?
            }
            "memory_efficient_filtering" => {
                self.memory_efficient_filtering = parse_bool(v)?
            }
            "freeze_embedding" => self.freeze_embedding = parse_bool(v)?,
            "artifacts_dir" => self.artifacts_dir = v.into(),
            "metrics_out" => self.metrics_out = v.into(),
            "store_budget_mb" => {
                self.store_budget_mb = v.parse().context("store_budget_mb")?
            }
            "store_dir" => self.store_dir = v.into(),
            "engine_workers" => {
                self.engine.grad_workers = v.parse().context("engine_workers")?
            }
            "engine_data_workers" => {
                self.engine.data_workers = v.parse().context("engine_data_workers")?
            }
            "engine_channel_depth" => {
                self.engine.channel_depth = v.parse().context("engine_channel_depth")?
            }
            "engine_shards" => self.engine.shards = v.parse().context("engine_shards")?,
            "engine_microbatch" => {
                self.engine.microbatch_chunks = v.parse().context("engine_microbatch")?
            }
            "engine_kernel_threads" => {
                self.engine.kernel_threads = v.parse().context("engine_kernel_threads")?
            }
            "engine_kernel_backend" => self.engine.kernel_backend = v.parse()?,
            "engine_staleness" => {
                self.engine.staleness = v.parse().context("engine_staleness")?
            }
            "engine_processes" => {
                self.engine.processes = v.parse().context("engine_processes")?
            }
            other => bail!("unknown config key `{other}`"),
        }
        Ok(())
    }

    /// Parse `--key value` pairs (flags may also be `--key=value`).
    /// Returns leftover positional args.
    pub fn apply_args(&mut self, args: &[String]) -> Result<Vec<String>> {
        let mut rest = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    self.set(&k.replace('-', "_"), v)?;
                } else {
                    let v = args
                        .get(i + 1)
                        .with_context(|| format!("flag --{stripped} needs a value"))?;
                    self.set(&stripped.replace('-', "_"), v)?;
                    i += 1;
                }
            } else {
                rest.push(a.clone());
            }
            i += 1;
        }
        Ok(rest)
    }

    /// Load `key = value` lines (# comments, blank lines ok).
    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        for (n, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{path:?}:{}: want key = value", n + 1))?;
            self.set(k.trim(), v.trim())
                .with_context(|| format!("{path:?}:{}", n + 1))?;
        }
        Ok(())
    }

    pub fn summary(&self) -> String {
        format!(
            "model={} algo={:?} steps={} eps={} delta={:.2e} ratio={} tau={} c1={} c2={} lr={} opt={:?}",
            self.model,
            self.algorithm,
            self.steps,
            self.epsilon,
            self.effective_delta(),
            self.sigma_ratio,
            self.tau,
            self.c1,
            self.c2,
            self.lr,
            self.optimizer,
        )
    }
}

fn parse_bool(v: &str) -> Result<bool> {
    match v {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        other => bail!("expected bool, got {other}"),
    }
}

/// Simple named-value overrides map used by the sweep harness.
pub fn overrides_from_pairs(pairs: &[(&str, String)]) -> HashMap<String, String> {
    pairs
        .iter()
        .map(|(k, v)| (k.to_string(), v.clone()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_and_args_roundtrip() {
        let mut c = RunConfig::default();
        let rest = c
            .apply_args(&[
                "train".to_string(),
                "--epsilon".to_string(),
                "3.0".to_string(),
                "--tau=10".to_string(),
                "--algorithm".to_string(),
                "dp-fest".to_string(),
            ])
            .unwrap();
        assert_eq!(rest, vec!["train"]);
        assert_eq!(c.epsilon, 3.0);
        assert_eq!(c.tau, 10.0);
        assert_eq!(c.algorithm, Algorithm::DpFest);
    }

    #[test]
    fn engine_keys_parse() {
        let mut c = RunConfig::default();
        let rest = c
            .apply_args(&[
                "train-async".to_string(),
                "--engine-workers".to_string(),
                "7".to_string(),
                "--engine-shards=3".to_string(),
                "--engine-microbatch".to_string(),
                "2".to_string(),
                "--engine-kernel-threads=4".to_string(),
                "--engine-staleness".to_string(),
                "2".to_string(),
                "--engine-processes=3".to_string(),
                "--engine-kernel-backend=simd".to_string(),
            ])
            .unwrap();
        assert_eq!(rest, vec!["train-async"]);
        assert_eq!(c.engine.grad_workers, 7);
        assert_eq!(c.engine.shards, 3);
        assert_eq!(c.engine.microbatch_chunks, 2);
        assert_eq!(c.engine.kernel_threads, 4);
        assert_eq!(c.engine.staleness, 2);
        assert_eq!(c.engine.processes, 3);
        assert_eq!(c.engine.kernel_backend, KernelBackend::Simd);
        assert_eq!(c.engine.data_workers, EngineConfig::default().data_workers);
        assert_eq!(EngineConfig::default().staleness, 0);
        assert_eq!(EngineConfig::default().processes, 1);
        assert_eq!(EngineConfig::default().kernel_backend, KernelBackend::Scalar);
    }

    #[test]
    fn metrics_out_flag_parses() {
        let mut c = RunConfig::default();
        assert!(c.metrics_out.is_empty());
        let rest = c
            .apply_args(&[
                "train-async".to_string(),
                "--metrics-out".to_string(),
                "/tmp/run.jsonl".to_string(),
            ])
            .unwrap();
        assert_eq!(rest, vec!["train-async"]);
        assert_eq!(c.metrics_out, "/tmp/run.jsonl");
    }

    #[test]
    fn store_flags_parse() {
        let mut c = RunConfig::default();
        assert_eq!(c.store_budget_mb, 0);
        assert!(c.store_dir.is_empty());
        let rest = c
            .apply_args(&[
                "train-async".to_string(),
                "--store-budget-mb".to_string(),
                "64".to_string(),
                "--store-dir=/tmp/pages".to_string(),
            ])
            .unwrap();
        assert_eq!(rest, vec!["train-async"]);
        assert_eq!(c.store_budget_mb, 64);
        assert_eq!(c.store_dir, "/tmp/pages");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = RunConfig::default();
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("steps", "notanum").is_err());
        let err = c.set("engine_kernel_backend", "avx512").unwrap_err();
        assert!(err.to_string().contains("unknown kernel backend"), "{err}");
    }

    #[test]
    fn store_flags_rejected_on_commands_that_ignore_them() {
        let mut c = RunConfig::default();
        // no store flags set: every command passes
        c.reject_unused_store_flags("train", None).unwrap();
        c.reject_unused_store_flags("sweep", Some("fig3")).unwrap();

        c.store_budget_mb = 64;
        // the two commands that honor the flags still pass
        c.reject_unused_store_flags("train-async", None).unwrap();
        c.reject_unused_store_flags("sweep", Some("fullscale")).unwrap();
        // everything else gets a clear error naming the flag
        for (cmd, exp) in
            [("train", None), ("stream", None), ("account", None), ("sweep", Some("fig3"))]
        {
            let err = c.reject_unused_store_flags(cmd, exp).unwrap_err().to_string();
            assert!(err.contains("--store-budget-mb"), "{cmd}: {err}");
            assert!(err.contains("silently ignore"), "{cmd}: {err}");
        }

        c.store_budget_mb = 0;
        c.store_dir = "/tmp/pages".into();
        let err = c.reject_unused_store_flags("train", None).unwrap_err().to_string();
        assert!(err.contains("--store-dir"), "{err}");
    }

    #[test]
    fn delta_defaults_to_inverse_n() {
        let mut c = RunConfig::default();
        c.dataset_size = 45_000_000;
        assert!((c.effective_delta() - 1.0 / 45e6).abs() < 1e-15);
        c.delta = 1e-6;
        assert_eq!(c.effective_delta(), 1e-6);
    }

    #[test]
    fn file_loading() {
        let dir = std::env::temp_dir().join("sde_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.cfg");
        std::fs::write(&p, "# comment\nepsilon = 8.0\nsteps=5\n").unwrap();
        let mut c = RunConfig::default();
        c.load_file(&p).unwrap();
        assert_eq!(c.epsilon, 8.0);
        assert_eq!(c.steps, 5);
    }
}
