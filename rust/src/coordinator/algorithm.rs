//! The algorithm menu of the paper's evaluation (§4.1.2).

/// Which update policy governs the embedding tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// no clipping, no noise (the ε = ∞ reference)
    NonPrivate,
    /// vanilla DP-SGD: dense Gaussian noise on every coordinate (Eq. 1)
    DpSgd,
    /// DP-SGD with exponential selection \[ZMH21\] (baseline)
    ExpSelection,
    /// DP-FEST (§3.1): frequency-filtered pre-selected buckets
    DpFest,
    /// DP-AdaFEST (§3.2, Algorithm 1): adaptive per-batch filtering
    DpAdaFest,
    /// DP-AdaFEST+ (§4.2): DP-FEST pre-selection ∘ DP-AdaFEST
    DpAdaFestPlus,
}

impl Algorithm {
    /// Does this algorithm clip and noise at all?
    pub fn is_private(self) -> bool {
        self != Algorithm::NonPrivate
    }

    /// Does this algorithm spend budget on the contribution map (σ₁)?
    pub fn uses_contribution_map(self) -> bool {
        matches!(self, Algorithm::DpAdaFest | Algorithm::DpAdaFestPlus)
    }

    /// Does this algorithm use DP-FEST pre-selection?
    pub fn uses_fest_selection(self) -> bool {
        matches!(self, Algorithm::DpFest | Algorithm::DpAdaFestPlus)
    }

    /// Every algorithm, in the paper's presentation order.
    pub fn all() -> [Algorithm; 6] {
        [
            Algorithm::NonPrivate,
            Algorithm::DpSgd,
            Algorithm::ExpSelection,
            Algorithm::DpFest,
            Algorithm::DpAdaFest,
            Algorithm::DpAdaFestPlus,
        ]
    }

    /// The CLI/CSV name (round-trips through [`str::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::NonPrivate => "non-private",
            Algorithm::DpSgd => "dp-sgd",
            Algorithm::ExpSelection => "exp-selection",
            Algorithm::DpFest => "dp-fest",
            Algorithm::DpAdaFest => "dp-adafest",
            Algorithm::DpAdaFestPlus => "dp-adafest-plus",
        }
    }
}

impl std::str::FromStr for Algorithm {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "non-private" | "nonprivate" => Ok(Algorithm::NonPrivate),
            "dp-sgd" | "dpsgd" => Ok(Algorithm::DpSgd),
            "exp-selection" | "exponential" => Ok(Algorithm::ExpSelection),
            "dp-fest" | "fest" => Ok(Algorithm::DpFest),
            "dp-adafest" | "adafest" => Ok(Algorithm::DpAdaFest),
            "dp-adafest-plus" | "adafest+" | "dp-adafest+" => Ok(Algorithm::DpAdaFestPlus),
            other => anyhow::bail!(
                "unknown algorithm {other} (want non-private|dp-sgd|exp-selection|dp-fest|dp-adafest|dp-adafest-plus)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for a in Algorithm::all() {
            let parsed: Algorithm = a.name().parse().unwrap();
            assert_eq!(parsed, a);
        }
    }

    #[test]
    fn predicates() {
        assert!(!Algorithm::NonPrivate.is_private());
        assert!(Algorithm::DpAdaFest.uses_contribution_map());
        assert!(!Algorithm::DpFest.uses_contribution_map());
        assert!(Algorithm::DpAdaFestPlus.uses_fest_selection());
        assert!(Algorithm::DpAdaFestPlus.uses_contribution_map());
    }
}
