//! L3 coordinator — the paper's system contribution.
//!
//! [`Trainer`] drives one model through DP training entirely from Rust:
//! per step it (1) feeds params + batch to the AOT grads artifact, (2) runs
//! the selected sparsity-preserving policy on the returned contribution map,
//! (3) injects all Gaussian noise (σ₁ map noise, σ₂ gradient noise), and
//! (4) applies row-sparse embedding updates + dense updates.  Privacy is
//! wired through [`crate::accounting`]: given (ε, δ, q, T) the noise pair is
//! calibrated once per run.
//!
//! The step mechanics live in [`step`] — shared verbatim with the
//! asynchronous sharded engine ([`crate::engine`]), so the two paths are
//! bit-for-bit equivalent (same noise stream, same batch streams, same
//! reductions).  The §4.3 time-series protocol lives in [`streaming`]: one
//! [`StreamSchedule`] drives both the synchronous [`StreamingTrainer`] and
//! the engine's streaming mode.
//!
//! [`Algorithm`] enumerates the paper's methods and baselines:
//! `NonPrivate`, `DpSgd` (dense noise), `ExpSelection` \[ZMH21\], `DpFest`
//! (§3.1), `DpAdaFest` (§3.2 / Algorithm 1), `DpAdaFestPlus` (§4.2).

#![warn(missing_docs)]

mod algorithm;
pub mod step;
pub mod streaming;
mod trainer;

pub use algorithm::Algorithm;
pub use step::{EmbTable, ModelMeta, StepState, StepStats, TrainOutcome};
pub use streaming::{StreamSchedule, StreamingOutcome, StreamingTrainer};
pub use trainer::{pctr_frequency_counts, text_frequency_counts, Trainer};
