//! The step core of Algorithm 1, extracted so the synchronous [`Trainer`]
//! and the asynchronous [`crate::engine`] run the *same* code for
//! everything that touches privacy or parameters:
//!
//! * model geometry + artifact plan derivation from the manifest,
//! * σ₁/σ₂ calibration (with a process-wide cache),
//! * gradient-bundle assembly from artifact outputs,
//! * survivor selection, noise injection, and optimizer updates
//!   ([`StepState::apply_update`]),
//! * evaluation and outcome reporting.
//!
//! ## Noise-draw-order invariant
//!
//! All DP randomness — FEST top-k Gumbel draws, exponential-selection draws,
//! contribution-map noise (σ₁), row noise and dense noise (σ₂) — is drawn
//! from the **single** [`StepState::rng`] stream in a fixed order per step:
//! selection first, then per-table row noise in table order, then dense-grad
//! noise in artifact output order.  Both the sync trainer and the async
//! engine funnel through [`StepState::apply_update`], so the noise stream is
//! bit-for-bit identical regardless of worker count.  `tests/engine.rs`
//! asserts this (`noise_draw_order_is_worker-count-invariant`).
//!
//! ## Batch-stream invariant
//!
//! Training batch `t` is generated from the self-contained RNG
//! [`train_batch_rng`]`(seed, t)` (and eval batch `i` from
//! [`eval_batch_rng`]`(seed, i)`), never from a sequential stream — this is
//! what lets the engine's data workers generate batches out of order and in
//! parallel while remaining bit-identical to the sync loop.
//!
//! [`Trainer`]: super::Trainer

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::accounting::{calibrate_sigma, compose_sigmas, gaussian_epsilon};
use crate::config::RunConfig;
use crate::data::{PctrBatch, TextBatch};
use crate::filtering::{ContributionMap, SurvivorSet};
use crate::metrics;
use crate::models::ParamStore;
use crate::runtime::{ArtifactManifest, HostTensor, Manifest, ModelManifest, Runtime};
use crate::selection::{dp_top_k_per_feature, exponential_select};
use crate::sparse::{
    add_dense_noise, add_row_noise, GradSizeMeter, Optimizer, RowSparseGrad,
};
use crate::telemetry::{RunSummary, Stage, StepRecord, Telemetry};
use crate::util::rng::Xoshiro256;

use super::algorithm::Algorithm;

/// One embedding table's geometry in the concatenated row space.  "Table"
/// means whatever parameter the model trains row-sparsely: a per-feature
/// Criteo table, the NLU token table, or the LoRA `emb_lora_a` factor
/// (token rows of the adapter rank).
#[derive(Clone, Debug)]
pub struct EmbTable {
    /// index of the table's parameter in the param store
    pub param_index: usize,
    /// parameter name in the manifest (e.g. `table_03`, `emb_table`,
    /// `emb_lora_a`)
    pub name: String,
    /// number of rows (buckets / tokens)
    pub vocab: usize,
    /// row width (embedding dimension, or the LoRA rank)
    pub dim: usize,
    /// offset of this table's first row in the concatenated row space
    pub row_offset: usize,
    /// offset of this table's slice in the artifact's per-example grads
    pub grad_offset: usize,
}

/// Model-kind-specific metadata derived from the manifest.
#[derive(Clone, Debug)]
pub enum ModelMeta {
    /// the Criteo-style pCTR tower
    Pctr {
        /// examples per training batch
        batch_size: usize,
        /// numeric (dense) input features
        num_numeric: usize,
        /// categorical features (= embedding tables)
        num_features: usize,
    },
    /// the NLU transformer classifier
    Nlu {
        /// examples per training batch
        batch_size: usize,
        /// tokens per example
        seq_len: usize,
        /// classification classes
        num_classes: usize,
    },
}

impl ModelMeta {
    /// The model's fixed training batch size.
    pub fn batch_size(&self) -> usize {
        match self {
            ModelMeta::Pctr { batch_size, .. } | ModelMeta::Nlu { batch_size, .. } => {
                *batch_size
            }
        }
    }
}

/// How each grads-artifact output is consumed.
#[derive(Clone, Debug)]
pub enum OutputKind {
    /// the scalar training loss
    Loss,
    /// clipped-sum gradient of the dense parameter at this index
    DenseGrad(usize),
    /// the per-example scaled embedding gradients (`zgrads_scaled`)
    EmbGrads,
    /// the pre-noise contribution map over the concatenated row space
    Counts,
    /// per-example clip scales (diagnostic; unused by the update path)
    Scales,
}

/// Per-step bookkeeping returned by [`StepState::apply_update`].
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    /// training loss of the step's batch
    pub loss: f64,
    /// embedding coordinates that received σ₂ noise
    pub emb_coords_noised: usize,
    /// dense coordinates that received σ₂ noise
    pub dense_coords_noised: usize,
    /// surviving embedding rows after selection
    pub survivors: usize,
    /// embedding rows with a nonzero gradient before selection
    pub present_rows: usize,
}

/// What one full training run reports.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    /// per-step training loss
    pub loss_history: Vec<f64>,
    /// eval utility: AUC (pctr) or accuracy (nlu)
    pub utility: f64,
    /// mean eval loss
    pub eval_loss: f64,
    /// mean noised embedding-gradient coordinates per step
    pub emb_grad_coords_per_step: f64,
    /// dense-DP-SGD size over this run's gradient size (the paper's
    /// headline reduction factor)
    pub reduction_factor: f64,
    /// calibrated contribution-map noise multiplier
    pub sigma1: f64,
    /// calibrated gradient noise multiplier
    pub sigma2: f64,
    /// end-of-run telemetry totals (stage timings, queue high-water marks,
    /// cumulative privacy spend) — see `docs/OBSERVABILITY.md`
    pub telemetry: RunSummary,
}

/// Everything the grads artifact returns for one logical batch, in a form
/// the update path consumes.  Produced by [`assemble_pctr`]/[`assemble_text`]
/// from artifact outputs — identically in the sync and async paths.
#[derive(Clone, Debug)]
pub struct GradBundle {
    /// the batch's training loss
    pub loss: f64,
    /// per-table row-sparse clipped-sum gradients
    pub table_grads: Vec<RowSparseGrad>,
    /// dense pre-noise contribution map over the concatenated row space —
    /// materialised only for algorithms that consume it (the copy is
    /// `total_vocab` floats, ~40 MB/step at paper scale)
    pub counts: Option<Vec<f32>>,
    /// (param index, clipped-sum grad) per dense parameter
    pub dense_grads: Vec<(usize, Vec<f32>)>,
}

/// Destination of optimizer updates.  [`ParamStore`] applies in place; the
/// engine's sharded store applies through per-shard locks.
pub trait ParamSink {
    /// Apply a row-sparse optimizer step to parameter `param_index`.
    fn apply_sparse(
        &mut self,
        param_index: usize,
        grad: &RowSparseGrad,
        opt: &Optimizer,
    ) -> Result<()>;
    /// Apply a dense optimizer step to parameter `param_index`.
    fn apply_dense(
        &mut self,
        param_index: usize,
        grad: &[f32],
        opt: &Optimizer,
    ) -> Result<()>;
}

impl ParamSink for ParamStore {
    fn apply_sparse(
        &mut self,
        param_index: usize,
        grad: &RowSparseGrad,
        opt: &Optimizer,
    ) -> Result<()> {
        let p = &mut self.params[param_index];
        opt.sparse_step(p.tensor.as_f32_mut()?, grad, &mut p.opt_state);
        Ok(())
    }

    fn apply_dense(
        &mut self,
        param_index: usize,
        grad: &[f32],
        opt: &Optimizer,
    ) -> Result<()> {
        let p = &mut self.params[param_index];
        opt.dense_step(p.tensor.as_f32_mut()?, grad, &mut p.opt_state);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Deterministic batch streams
// ---------------------------------------------------------------------------

/// RNG for training batch `step` — self-contained per step (see the
/// batch-stream invariant in the module docs).
pub fn train_batch_rng(seed: u64, step: u64) -> Xoshiro256 {
    Xoshiro256::seed_from(
        seed ^ 0xBA7C4 ^ (step + 1).wrapping_mul(0x9E3779B97F4A7C15),
    )
}

/// RNG for eval batch `index` (stream disjoint from training by tag).
pub fn eval_batch_rng(seed: u64, index: u64) -> Xoshiro256 {
    Xoshiro256::seed_from(
        seed ^ 0xE7A1BA7C ^ (index + 1).wrapping_mul(0xD1B54A32D192ED03),
    )
}

// ---------------------------------------------------------------------------
// Manifest-derived plans
// ---------------------------------------------------------------------------

/// Model geometry shared by both training paths.
#[derive(Clone, Debug)]
pub struct ModelGeometry {
    /// kind-specific batch/feature metadata
    pub meta: ModelMeta,
    /// the embedding tables, in feature order
    pub emb_tables: Vec<EmbTable>,
    /// total rows across all tables (the concatenated row space)
    pub total_vocab: usize,
}

/// Derive the model geometry (batch shape, embedding tables, concatenated
/// row space) from a manifest entry and its initialised param store.
pub fn model_geometry(model: &ModelManifest, store: &ParamStore) -> Result<ModelGeometry> {
    let (meta, emb_tables, total_vocab) = match model.kind.as_str() {
        "pctr" => {
            let vocabs = model.attr_usize_list("vocabs")?;
            let dims = model.attr_usize_list("dims")?;
            let offsets = model.attr_usize_list("row_offsets")?;
            let mut tables = Vec::with_capacity(vocabs.len());
            let mut grad_off = 0;
            for (f, ((&v, &d), &off)) in
                vocabs.iter().zip(&dims).zip(&offsets).enumerate()
            {
                tables.push(EmbTable {
                    param_index: store.index_of(&format!("table_{f:02}"))?,
                    name: format!("table_{f:02}"),
                    vocab: v,
                    dim: d,
                    row_offset: off,
                    grad_offset: grad_off,
                });
                grad_off += d;
            }
            (
                ModelMeta::Pctr {
                    batch_size: model.attr_usize("batch_size")?,
                    num_numeric: model.attr_usize("num_numeric")?,
                    num_features: vocabs.len(),
                },
                tables,
                model.attr_usize("total_vocab")?,
            )
        }
        "nlu" => {
            let vocab = model.attr_usize("vocab")?;
            // LoRA-on-embedding models train the (V, r) A factor
            // row-sparsely in place of the (V, d) table; the B factor and
            // the head ride the dense path (output_plan sees their
            // `grad_*` outputs).
            let emb_lora = model.attr_usize("emb_lora_rank").unwrap_or(0);
            let (pname, dim) = if emb_lora > 0 {
                ("emb_lora_a".to_string(), emb_lora)
            } else {
                ("emb_table".to_string(), model.attr_usize("d_model")?)
            };
            let tables = vec![EmbTable {
                param_index: store.index_of(&pname)?,
                name: pname,
                vocab,
                dim,
                row_offset: 0,
                grad_offset: 0,
            }];
            (
                ModelMeta::Nlu {
                    batch_size: model.attr_usize("batch_size")?,
                    seq_len: model.attr_usize("seq_len")?,
                    num_classes: model.attr_usize("num_classes")?,
                },
                tables,
                vocab,
            )
        }
        other => bail!("unknown model kind {other}"),
    };
    Ok(ModelGeometry { meta, emb_tables, total_vocab })
}

/// Locate the `(grads, fwd)` artifact pair for a model.
pub fn locate_artifacts(manifest: &Manifest, model: &str) -> Result<(String, String)> {
    let mut grads_artifact = None;
    let mut fwd_artifact = None;
    for (name, art) in &manifest.artifacts {
        if art.model == model {
            if name.ends_with("_grads") {
                grads_artifact = Some(name.clone());
            } else if name.ends_with("_fwd") {
                fwd_artifact = Some(name.clone());
            }
        }
    }
    Ok((
        grads_artifact.with_context(|| format!("no grads artifact for {model}"))?,
        fwd_artifact.with_context(|| format!("no fwd artifact for {model}"))?,
    ))
}

/// Classify every output of the grads artifact.
pub fn output_plan(art: &ArtifactManifest, store: &ParamStore) -> Result<Vec<OutputKind>> {
    let mut plan = Vec::with_capacity(art.outputs.len());
    for out in &art.outputs {
        let kind = match out.name.as_str() {
            "loss" => OutputKind::Loss,
            "zgrads_scaled" | "aout_grads_scaled" => OutputKind::EmbGrads,
            "counts" => OutputKind::Counts,
            "scales" => OutputKind::Scales,
            g if g.starts_with("grad_") => OutputKind::DenseGrad(store.index_of(&g[5..])?),
            other => bail!("unexpected grads output {other}"),
        };
        plan.push(kind);
    }
    Ok(plan)
}

/// Effective clip norms fed to the artifact (non-private runs disable
/// clipping with a huge C).
pub fn clip_values(cfg: &RunConfig) -> (f32, f32) {
    if cfg.algorithm.is_private() {
        (cfg.c1 as f32, cfg.c2 as f32)
    } else {
        (1e9, 1e9)
    }
}

/// The clip norms as the scalar input tensors the artifacts expect.
pub fn clip_inputs(cfg: &RunConfig) -> (HostTensor, HostTensor) {
    let (c1, c2) = clip_values(cfg);
    (
        HostTensor::f32(vec![1], vec![c1]),
        HostTensor::f32(vec![1], vec![c2]),
    )
}

// ---------------------------------------------------------------------------
// σ calibration
// ---------------------------------------------------------------------------

/// Calibrate the (σ₁, σ₂) pair for a run.  Semantics identical to the seed
/// trainer: FEST budget split first, then either a composed pair (σ₁/σ₂ at
/// `cfg.sigma_ratio`, for contribution-map algorithms) or a single σ₂.
/// Both branches share the process-wide σ_eff cache that now lives inside
/// [`calibrate_sigma`] itself, so `calibrate_sigma_pair` callers (the CLI
/// `account` command, harness sweeps) hit the same memo.
pub fn calibrate_noise(cfg: &RunConfig, batch_size: usize) -> Result<(f64, f64)> {
    let q = batch_size as f64 / cfg.dataset_size as f64;
    let delta = cfg.effective_delta();
    let mut eps_train = cfg.epsilon;
    if cfg.algorithm.uses_fest_selection() {
        eps_train -= cfg.fest_epsilon; // Appendix B.1 budget split
        if eps_train <= 0.0 {
            bail!("fest_epsilon exhausts the privacy budget");
        }
    }
    match cfg.algorithm {
        Algorithm::NonPrivate => Ok((0.0, 0.0)),
        a if a.uses_contribution_map() => {
            // Same split as accounting::calibrate_sigma_pair (the pair is a
            // closed-form function of the cached σ_eff).
            let ratio = cfg.sigma_ratio;
            if ratio <= 0.0 {
                bail!("sigma ratio must be positive");
            }
            let sigma_eff = calibrate_sigma(eps_train, delta, q, cfg.steps)?;
            let sigma2 = sigma_eff * (1.0 + 1.0 / (ratio * ratio)).sqrt();
            Ok((ratio * sigma2, sigma2))
        }
        _ => Ok((0.0, calibrate_sigma(eps_train, delta, q, cfg.steps)?)),
    }
}

// ---------------------------------------------------------------------------
// Gradient-bundle assembly from artifact outputs
// ---------------------------------------------------------------------------

fn assemble_common(
    plan: &[OutputKind],
    outs: &[HostTensor],
    need_counts: bool,
    mut emb: impl FnMut(&HostTensor) -> Result<Vec<RowSparseGrad>>,
) -> Result<GradBundle> {
    let mut loss = 0.0;
    let mut table_grads: Vec<RowSparseGrad> = Vec::new();
    let mut counts: Option<Vec<f32>> = None;
    let mut dense_grads: Vec<(usize, Vec<f32>)> = Vec::new();
    for (kind, out) in plan.iter().zip(outs) {
        match kind {
            OutputKind::Loss => loss = out.scalar()?,
            OutputKind::DenseGrad(pi) => dense_grads.push((*pi, out.as_f32()?.to_vec())),
            OutputKind::EmbGrads => table_grads = emb(out)?,
            OutputKind::Counts if need_counts => counts = Some(out.as_f32()?.to_vec()),
            OutputKind::Counts | OutputKind::Scales => {}
        }
    }
    if need_counts && counts.is_none() {
        bail!("grads artifact returned no counts");
    }
    Ok(GradBundle { loss, table_grads, counts, dense_grads })
}

/// Assemble per-table row-sparse grads from a pCTR grads-artifact output
/// tuple (`zgrads_scaled` is `(B, Σdims)` row-major).  `need_counts` should
/// be `algorithm.uses_contribution_map()` — copying the dense map is wasted
/// work otherwise.
pub fn assemble_pctr(
    plan: &[OutputKind],
    outs: &[HostTensor],
    emb_tables: &[EmbTable],
    batch: &PctrBatch,
    need_counts: bool,
) -> Result<GradBundle> {
    let b = batch.batch_size;
    assemble_common(plan, outs, need_counts, |out| {
        let zg = out.as_f32()?;
        let d_total: usize = emb_tables.iter().map(|t| t.dim).sum();
        let mut grads: Vec<RowSparseGrad> = emb_tables
            .iter()
            .map(|t| RowSparseGrad::with_capacity(t.vocab, t.dim, b))
            .collect();
        for i in 0..b {
            for (f, t) in emb_tables.iter().enumerate() {
                let row = batch.cat_of(i, f) as u32;
                let s = i * d_total + t.grad_offset;
                grads[f].add_row(row, &zg[s..s + t.dim]);
            }
        }
        Ok(grads)
    })
}

/// Assemble the single-table row-sparse grad from an NLU grads-artifact
/// output tuple (`zgrads_scaled` is `(B, T, d)` row-major).
pub fn assemble_text(
    plan: &[OutputKind],
    outs: &[HostTensor],
    emb_tables: &[EmbTable],
    batch: &TextBatch,
    seq_len: usize,
    need_counts: bool,
) -> Result<GradBundle> {
    let b = batch.batch_size;
    assemble_common(plan, outs, need_counts, |out| {
        let zg = out.as_f32()?;
        let t = &emb_tables[0];
        let mut g = RowSparseGrad::with_capacity(t.vocab, t.dim, b * seq_len);
        for i in 0..b {
            for p in 0..seq_len {
                let row = batch.token(i, p) as u32;
                let s = (i * seq_len + p) * t.dim;
                g.add_row(row, &zg[s..s + t.dim]);
            }
        }
        Ok(vec![g])
    })
}

// ---------------------------------------------------------------------------
// The mutable step state (selection + noise + update + bookkeeping)
// ---------------------------------------------------------------------------

/// Everything Algorithm 1 mutates across steps, independent of how the
/// gradients were computed or where the parameters live.
pub struct StepState {
    /// the run configuration
    pub cfg: RunConfig,
    /// kind-specific model metadata
    pub meta: ModelMeta,
    /// the embedding tables, in feature order
    pub emb_tables: Vec<EmbTable>,
    /// total rows across all tables (the concatenated row space)
    pub total_vocab: usize,
    /// the optimizer applied to every parameter
    pub opt: Optimizer,
    /// the **single** DP RNG stream — every selection and noise draw
    /// (module docs: noise-draw-order invariant)
    pub rng: Xoshiro256,
    /// gradient-size bookkeeping (the paper's reduction factor)
    pub meter: GradSizeMeter,
    /// calibrated contribution-map noise multiplier
    pub sigma1: f64,
    /// calibrated gradient noise multiplier
    pub sigma2: f64,
    /// DP-FEST pre-selected rows (concatenated space), if applicable
    pub fest_selected: Option<SurvivorSet>,
    /// per-step training loss so far
    pub loss_history: Vec<f64>,
    /// passive telemetry hub, shared (via `Arc`) with the engine's workers.
    /// Probing it never draws randomness or reorders reductions, so it
    /// cannot perturb the bit-exactness invariants above.
    pub tele: Arc<Telemetry>,
    /// privacy ε consumed by selection mechanisms so far (FEST top-k
    /// budgets, per-step exponential-selection budgets)
    pub eps_selection_spent: f64,
}

impl StepState {
    /// Initialise the step state for a run: derive the geometry, calibrate
    /// (σ₁, σ₂), and seed the DP RNG stream.
    pub fn new(cfg: RunConfig, model: &ModelManifest, store: &ParamStore) -> Result<StepState> {
        let geom = model_geometry(model, store)?;
        let (sigma1, sigma2) = calibrate_noise(&cfg, geom.meta.batch_size())?;
        let mut meter = GradSizeMeter::default();
        meter.set_baselines(store.embedding_coords(), store.dense_coords());
        let opt = Optimizer::new(cfg.optimizer, cfg.lr);
        let rng = Xoshiro256::seed_from(cfg.seed ^ 0xDEADBEEF);
        let tele = Arc::new(Telemetry::with_sink(
            (!cfg.metrics_out.is_empty()).then_some(cfg.metrics_out.as_str()),
        )?);
        Ok(StepState {
            cfg,
            meta: geom.meta,
            emb_tables: geom.emb_tables,
            total_vocab: geom.total_vocab,
            opt,
            rng,
            meter,
            sigma1,
            sigma2,
            fest_selected: None,
            loss_history: Vec::new(),
            tele,
            eps_selection_spent: 0.0,
        })
    }

    /// The model's fixed training batch size.
    pub fn batch_size(&self) -> usize {
        self.meta.batch_size()
    }

    /// DP-FEST pre-selection from per-feature frequency counts (Algorithm 2
    /// with the Appendix-B.1 ε/k split), at the configured selection budget.
    pub fn fest_select(&mut self, feature_counts: &[Vec<f64>]) -> Result<()> {
        let eps = self.cfg.fest_epsilon;
        self.fest_select_with_eps(feature_counts, eps)
    }

    /// DP-FEST pre-selection at an explicit selection budget.  The streaming
    /// trainer uses this to spread `fest_epsilon` over periodic reselections
    /// without mutating the run config.
    pub fn fest_select_with_eps(
        &mut self,
        feature_counts: &[Vec<f64>],
        epsilon: f64,
    ) -> Result<()> {
        if feature_counts.len() != self.emb_tables.len() {
            bail!(
                "got counts for {} features, model has {}",
                feature_counts.len(),
                self.emb_tables.len()
            );
        }
        let per_feature = dp_top_k_per_feature(
            feature_counts,
            self.cfg.fest_top_k,
            epsilon,
            &mut self.rng,
        );
        let mut ids: Vec<u32> = Vec::new();
        for (t, sel) in self.emb_tables.iter().zip(&per_feature) {
            for &b in sel {
                ids.push((t.row_offset + b as usize) as u32);
            }
        }
        ids.sort_unstable();
        ids.dedup();
        self.fest_selected = Some(SurvivorSet::from_sorted(ids));
        self.eps_selection_spent += epsilon;
        Ok(())
    }

    /// Cumulative privacy ε spent after `steps_done` training steps, at the
    /// run's effective δ: selection spend plus the closed-form Gaussian
    /// bound for the composed noise ([`compose_sigmas`] of σ₁/σ₂ when a
    /// contribution map is in play, else σ₂ alone, tightened by √t).
    ///
    /// This is a *pessimistic upper bound* — it ignores subsampling
    /// amplification (the exact PLD accountant is far too expensive to run
    /// per step), so it is always ≥ the ε the run was calibrated for.
    /// Non-private runs spend 0.
    pub fn eps_spent(&self, steps_done: u64) -> f64 {
        if !self.cfg.algorithm.is_private() || steps_done == 0 {
            return 0.0;
        }
        let sigma_eff = if self.sigma1 > 0.0 {
            compose_sigmas(self.sigma1, self.sigma2)
        } else {
            self.sigma2
        };
        if sigma_eff <= 0.0 {
            return f64::INFINITY;
        }
        let delta = self.cfg.effective_delta();
        self.eps_selection_spent
            + gaussian_epsilon(delta, sigma_eff / (steps_done as f64).sqrt())
    }

    /// Shared post-gradient logic: survivor selection, noise, updates.
    /// This is Algorithm 1 lines 5–11; the DP aggregation barrier of the
    /// async engine calls it with a sharded sink, the sync trainer with the
    /// plain param store — noise draw order is identical (module docs).
    pub fn apply_update(
        &mut self,
        bundle: GradBundle,
        sink: &mut impl ParamSink,
    ) -> Result<StepStats> {
        let GradBundle { loss, mut table_grads, counts, dense_grads } = bundle;
        let b = self.batch_size() as f32;
        let algo = self.cfg.algorithm;
        let noise2 = self.sigma2 * self.cfg.c2; // gradient noise stddev
        let present_rows: usize = table_grads.iter().map(|g| g.nnz_rows()).sum();
        // span guards borrow the hub through a local Arc so they can overlap
        // the `&mut self` borrows below; timing is passive (clock reads only)
        let tele = Arc::clone(&self.tele);

        // ---- survivor selection (embedding row set to noise & update) ----
        let select_span = tele.span(Stage::Select);
        let mut survivors_len = 0usize;
        let survivor_set: Option<SurvivorSet> = match algo {
            Algorithm::NonPrivate | Algorithm::DpSgd => None,
            Algorithm::ExpSelection => {
                // [ZMH21]: exponential mechanism over row gradient norms.
                let mut utilities: Vec<(u32, f64)> = Vec::with_capacity(present_rows);
                for (t, g) in self.emb_tables.iter().zip(&table_grads) {
                    for (row, vals) in g.iter_rows() {
                        let norm =
                            vals.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
                        utilities.push(((t.row_offset + row as usize) as u32, norm));
                    }
                }
                let ids = exponential_select(
                    &utilities,
                    self.cfg.exp_select_m,
                    self.cfg.epsilon / self.cfg.steps as f64, // per-step selection budget
                    self.cfg.c2,
                    &mut self.rng,
                );
                self.eps_selection_spent += self.cfg.epsilon / self.cfg.steps as f64;
                Some(SurvivorSet::from_sorted(ids))
            }
            Algorithm::DpFest => Some(
                self.fest_selected
                    .clone()
                    .context("DP-FEST requires fest_select() before training")?,
            ),
            Algorithm::DpAdaFest | Algorithm::DpAdaFestPlus => {
                let counts = counts
                    .as_deref()
                    .context("contribution map missing from the grad bundle")?;
                let map = ContributionMap::from_dense(counts);
                let (surv, _stats) = map.survivors(
                    self.sigma1,
                    self.cfg.c1,
                    self.cfg.tau,
                    self.cfg.memory_efficient_filtering,
                    &mut self.rng,
                );
                if algo == Algorithm::DpAdaFestPlus {
                    let fest = self
                        .fest_selected
                        .as_ref()
                        .context("DP-AdaFEST+ requires fest_select() before training")?;
                    Some(surv.intersect(fest))
                } else {
                    Some(surv)
                }
            }
        };
        drop(select_span);

        // ---- embedding updates ----
        let mut emb_coords = 0usize;
        if self.cfg.freeze_embedding {
            // Table 6 baseline: embeddings untouched — drop the grads.
            table_grads.clear();
        }
        match algo {
            _ if self.cfg.freeze_embedding => {}
            Algorithm::DpSgd => {
                // dense path: densify + dense noise + dense update
                for (t, g) in self.emb_tables.iter().zip(&table_grads) {
                    let mut dense = g.to_dense();
                    {
                        let _span = tele.span(Stage::Noise);
                        emb_coords += add_dense_noise(&mut dense, noise2, &mut self.rng);
                    }
                    for v in &mut dense {
                        *v /= b;
                    }
                    let _span = tele.span(Stage::Scatter);
                    sink.apply_dense(t.param_index, &dense, &self.opt)?;
                }
            }
            Algorithm::NonPrivate => {
                for (t, g) in self.emb_tables.iter().zip(&mut table_grads) {
                    g.scale(1.0 / b);
                    emb_coords += g.nnz_coords();
                    let _span = tele.span(Stage::Scatter);
                    sink.apply_sparse(t.param_index, g, &self.opt)?;
                }
            }
            _ => {
                // sparsity-preserving DP paths: restrict to survivors, make
                // sure *every* survivor row exists (noise lands on zero-grad
                // survivors too), then row noise + sparse update.
                let surv = survivor_set.as_ref().unwrap();
                survivors_len = surv.len();
                for (t, g) in self.emb_tables.iter().zip(&mut table_grads) {
                    let off = t.row_offset as u32;
                    let hi = (t.row_offset + t.vocab) as u32;
                    g.retain_rows(|row| surv.contains(off + row));
                    // add survivor rows missing from the gradient
                    let zero = vec![0f32; t.dim];
                    for &cid in surv.ids() {
                        if cid >= off && cid < hi {
                            let local = cid - off;
                            g.add_row_scaled(local, 0.0, &zero); // ensure presence
                        }
                    }
                    {
                        let _span = tele.span(Stage::Noise);
                        emb_coords += add_row_noise(g, noise2, &mut self.rng);
                    }
                    g.scale(1.0 / b);
                    let _span = tele.span(Stage::Scatter);
                    sink.apply_sparse(t.param_index, g, &self.opt)?;
                }
            }
        }

        // ---- dense (non-embedding) updates: standard DP-SGD ----
        let mut dense_coords = 0usize;
        for (pi, mut gbuf) in dense_grads {
            if algo.is_private() {
                let _span = tele.span(Stage::Noise);
                dense_coords += add_dense_noise(&mut gbuf, noise2, &mut self.rng);
            }
            for v in &mut gbuf {
                *v /= b;
            }
            let _span = tele.span(Stage::Scatter);
            sink.apply_dense(pi, &gbuf, &self.opt)?;
        }

        self.meter.record_step(emb_coords, dense_coords);
        self.loss_history.push(loss);
        let step = self.loss_history.len() as u64;
        self.tele.record_step(&StepRecord {
            step,
            loss,
            present_rows: present_rows as u64,
            survivors: survivor_set.map(|_| survivors_len as u64),
            emb_coords_noised: emb_coords as u64,
            dense_coords_noised: dense_coords as u64,
            reduction_factor: if emb_coords == 0 {
                f64::INFINITY
            } else {
                self.meter.emb_dense_baseline as f64 / emb_coords as f64
            },
            eps_spent: self.eps_spent(step),
            delta: self.cfg.effective_delta(),
            // the engine's collect_apply sets the gauge just before this
            // call; it stays 0 on the sync path and at --engine-staleness 0
            staleness: self.tele.staleness(),
        })?;
        Ok(StepStats {
            loss,
            emb_coords_noised: emb_coords,
            dense_coords_noised: dense_coords,
            survivors: survivors_len,
            present_rows,
        })
    }

    /// Package the run's accumulated state into a [`TrainOutcome`], capture
    /// the telemetry [`RunSummary`], and write the sink's final summary line
    /// (a failed summary write warns on stderr rather than failing the run —
    /// the trained result is already in hand).
    pub fn outcome(&self, utility: f64, eval_loss: f64) -> TrainOutcome {
        let telemetry = self.tele.summary(
            self.eps_spent(self.loss_history.len() as u64),
            self.cfg.effective_delta(),
        );
        if let Err(e) = self.tele.write_summary(&telemetry) {
            eprintln!("warning: metrics summary not written: {e:#}");
        }
        TrainOutcome {
            loss_history: self.loss_history.clone(),
            utility,
            eval_loss,
            emb_grad_coords_per_step: self.meter.emb_per_step(),
            reduction_factor: self.meter.reduction_factor(),
            sigma1: self.sigma1,
            sigma2: self.sigma2,
            telemetry,
        }
    }
}

// ---------------------------------------------------------------------------
// Evaluation (shared by Trainer and the engine)
// ---------------------------------------------------------------------------

/// Evaluate on pCTR batches: returns (AUC, mean loss).
pub fn eval_pctr(
    rt: &Runtime,
    fwd_artifact: &str,
    store: &ParamStore,
    batches: &[PctrBatch],
) -> Result<(f64, f64)> {
    let mut acc = metrics::EvalAccumulator::default();
    for batch in batches {
        let mut inputs = store.tensors();
        inputs.extend(batch.to_tensors());
        let outs = rt.execute(fwd_artifact, &inputs)?;
        let loss = outs[0].scalar()?;
        let logits = outs[1].as_f32()?;
        acc.push(logits, &batch.y, loss);
    }
    Ok((acc.auc(), acc.mean_loss()))
}

/// Evaluate on text batches: returns (accuracy, mean loss).  Both metrics
/// are weighted by example count, so a ragged final batch cannot skew them.
pub fn eval_text(
    rt: &Runtime,
    fwd_artifact: &str,
    store: &ParamStore,
    batches: &[TextBatch],
    num_classes: usize,
) -> Result<(f64, f64)> {
    let mut correct_w = 0.0;
    let mut loss_sum = 0.0;
    let mut n = 0;
    for batch in batches {
        let mut inputs = store.tensors();
        inputs.extend(batch.to_tensors());
        let outs = rt.execute(fwd_artifact, &inputs)?;
        loss_sum += outs[0].scalar()? * batch.batch_size as f64;
        let logits = outs[1].as_f32()?;
        correct_w += metrics::accuracy_from_logits(logits, &batch.labels, num_classes)
            * batch.batch_size as f64;
        n += batch.batch_size;
    }
    Ok((correct_w / n as f64, loss_sum / n as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_rng_streams_are_self_contained_and_distinct() {
        let mut a = train_batch_rng(7, 3);
        let mut b = train_batch_rng(7, 3);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = train_batch_rng(7, 4);
        let mut a2 = train_batch_rng(7, 3);
        assert_ne!(a2.next_u64(), c.next_u64());
        let mut e = eval_batch_rng(7, 3);
        let mut a3 = train_batch_rng(7, 3);
        assert_ne!(a3.next_u64(), e.next_u64());
    }

    #[test]
    fn clip_values_disable_clipping_when_nonprivate() {
        let mut cfg = RunConfig::default();
        cfg.c1 = 0.5;
        cfg.c2 = 0.25;
        cfg.algorithm = Algorithm::NonPrivate;
        assert_eq!(clip_values(&cfg), (1e9, 1e9));
        cfg.algorithm = Algorithm::DpAdaFest;
        assert_eq!(clip_values(&cfg), (0.5, 0.25));
    }
}
