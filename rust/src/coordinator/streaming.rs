//! Streaming (time-series) training — paper §4.3.
//!
//! Simulates the Criteo-1TB online setup: train on days 0..18 in day order,
//! evaluate on days 18..24.  A *streaming period* of `p` days groups the
//! stream into intervals; at each period boundary the frequency tracker
//! publishes its running counts and (for DP-FEST / DP-AdaFEST+) the bucket
//! pre-selection is recomputed from the configured [`FrequencySource`]:
//!
//! * `FirstDay`  — selection frozen after a day-0 warmup;
//! * `AllDays`   — oracle counts over the whole training range (upper bound);
//! * `Streaming` — running sums re-published every period (the deployable
//!   variant the paper finds nearly matches AllDays, Figure 5).

use anyhow::Result;

use crate::data::{PctrBatch, SynthCriteo, EVAL_DAYS, TRAIN_DAYS};
use crate::selection::{FrequencySource, FrequencyTracker};
use crate::util::rng::Xoshiro256;

use super::step::TrainOutcome;
use super::trainer::Trainer;

pub struct StreamingTrainer<'rt> {
    pub trainer: Trainer<'rt>,
    pub steps_per_day: u64,
    pub eval_batches_per_day: usize,
}

#[derive(Clone, Debug)]
pub struct StreamingOutcome {
    pub outcome: TrainOutcome,
    /// AUC per eval day (days 18..24) — distribution-shift profile
    pub per_day_auc: Vec<f64>,
    pub reselections: usize,
}

impl<'rt> StreamingTrainer<'rt> {
    pub fn new(trainer: Trainer<'rt>, eval_batches_per_day: usize) -> Self {
        let steps_per_day = (trainer.cfg().steps / TRAIN_DAYS as u64).max(1);
        StreamingTrainer { trainer, steps_per_day, eval_batches_per_day }
    }

    /// Run the full 24-day protocol. `gen` must be a drift-enabled
    /// SynthCriteo.
    pub fn run(&mut self, gen: &SynthCriteo) -> Result<StreamingOutcome> {
        let cfg = self.trainer.cfg().clone();
        let period = cfg.streaming_period.max(1);
        let uses_fest = cfg.algorithm.uses_fest_selection();
        let source = cfg.freq_source;
        let nf = self.trainer.emb_tables().len();
        let vocabs: Vec<usize> =
            self.trainer.emb_tables().iter().map(|t| t.vocab).collect();
        let mut tracker = FrequencyTracker::new(nf, source);
        let mut rng = Xoshiro256::seed_from(cfg.seed ^ 0x57AE);
        let bsz = self.trainer.batch_size();

        // Split the FEST selection budget across the expected number of
        // reselections (basic composition over disjoint... conservatively:
        // equal split).  The split budget is passed to each selection call
        // directly — a previous revision divided `cfg.fest_epsilon` in
        // place, so a second `run()` would halve the already-halved budget.
        let n_selections = match source {
            FrequencySource::FirstDay | FrequencySource::AllDays => 1,
            FrequencySource::Streaming => (TRAIN_DAYS + period - 1) / period,
        };
        let fest_eps_per_selection = cfg.fest_epsilon / n_selections as f64;
        let mut reselections = 0usize;

        let mut observe = |tracker: &mut FrequencyTracker, batch: &PctrBatch| {
            for f in 0..nf {
                let col: Vec<i32> =
                    (0..batch.batch_size).map(|i| batch.cat_of(i, f)).collect();
                tracker.observe(f, &col);
            }
        };

        // warmup / oracle pre-passes for the frequency source
        match source {
            FrequencySource::FirstDay => {
                for _ in 0..20 {
                    let b = gen.batch(0, bsz, &mut rng);
                    observe(&mut tracker, &b);
                }
                tracker.publish();
            }
            FrequencySource::AllDays => {
                for day in 0..TRAIN_DAYS {
                    for _ in 0..8 {
                        let b = gen.batch(day, bsz, &mut rng);
                        observe(&mut tracker, &b);
                    }
                }
                tracker.publish();
            }
            FrequencySource::Streaming => {}
        }

        let mut select = |trainer: &mut Trainer, tracker: &FrequencyTracker| -> Result<()> {
            let counts: Vec<Vec<f64>> = (0..nf)
                .map(|f| tracker.dense_counts(f, vocabs[f]))
                .collect();
            trainer.fest_select_with_eps(&counts, fest_eps_per_selection)?;
            Ok(())
        };

        if uses_fest && source != FrequencySource::Streaming {
            select(&mut self.trainer, &tracker)?;
            reselections += 1;
        }

        for day in 0..TRAIN_DAYS {
            // period boundary: publish + (streaming) reselect
            if day % period == 0 && source == FrequencySource::Streaming {
                tracker.publish();
                if uses_fest && (day > 0 || tracker.total_observed(0) > 0) {
                    select(&mut self.trainer, &tracker)?;
                    reselections += 1;
                } else if uses_fest {
                    // cold start: select from a tiny day-0 sniff
                    for _ in 0..4 {
                        let b = gen.batch(0, bsz, &mut rng);
                        observe(&mut tracker, &b);
                    }
                    tracker.publish();
                    select(&mut self.trainer, &tracker)?;
                    reselections += 1;
                }
            }
            for _ in 0..self.steps_per_day {
                let batch = gen.batch(day, bsz, &mut rng);
                observe(&mut tracker, &batch);
                self.trainer.step_pctr(&batch)?;
            }
        }

        // evaluation on held-out future days
        let mut per_day_auc = Vec::new();
        let mut all_scores: Vec<PctrBatch> = Vec::new();
        for day in EVAL_DAYS {
            let batches: Vec<PctrBatch> = (0..self.eval_batches_per_day)
                .map(|_| gen.batch(day, bsz, &mut rng))
                .collect();
            let (auc, _) = self.trainer.eval_pctr(&batches)?;
            per_day_auc.push(auc);
            all_scores.extend(batches);
        }
        let (auc_all, eval_loss) = self.trainer.eval_pctr(&all_scores)?;
        let outcome = self.trainer.outcome(auc_all, eval_loss);
        Ok(StreamingOutcome { outcome, per_day_auc, reselections })
    }
}
