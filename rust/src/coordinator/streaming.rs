//! Streaming (time-series) training — paper §4.3.
//!
//! Simulates the Criteo-1TB online setup: train on days 0..18 in day order,
//! evaluate on days 18..24.  A *streaming period* of `p` days groups the
//! stream into intervals; at each period boundary the frequency tracker
//! publishes its running counts and (for DP-FEST / DP-AdaFEST+) the bucket
//! pre-selection is recomputed from the configured [`FrequencySource`]:
//!
//! * `FirstDay`  — selection frozen after a day-0 warmup;
//! * `AllDays`   — oracle counts over the whole training range (upper bound);
//! * `Streaming` — running sums re-published every period (the deployable
//!   variant the paper finds nearly matches AllDays, Figure 5).
//!
//! ## One schedule, two executors
//!
//! The entire 24-day protocol — warmup passes, period boundaries, the
//! cold-start sniff, the per-day step loop, and the eval-day batch streams —
//! lives in [`StreamSchedule`], parameterised over a [`StreamDriver`] that
//! supplies only the two operations that differ between training paths:
//! running one step and recomputing the DP-FEST selection.  The synchronous
//! [`StreamingTrainer`] and the async engine's streaming barrier
//! (`engine::run_streaming`) both drive this one schedule, so the period
//! boundaries, selection budget splits, and every RNG draw line up
//! bit-for-bit by construction.
//!
//! ## Self-contained batch streams
//!
//! Every batch the protocol consumes comes from its own tagged RNG:
//! training step `t` from [`step::train_batch_rng`]`(seed, t)` (day
//! `t / steps_per_day`), warmup/sniff batch `i` from
//! [`prior_batch_rng`]`(seed, i)`, and eval batch `j` of day `d` from
//! [`step::eval_batch_rng`]`(seed, d·epd + j)`.  This is the streaming
//! extension of the engine's batch-stream invariant: the async data workers
//! can generate the day-ordered stream out of order and in parallel while
//! remaining bit-identical to this synchronous loop.

use anyhow::Result;

use crate::config::RunConfig;
use crate::data::{CriteoConfig, PctrBatch, SynthCriteo, EVAL_DAYS, TRAIN_DAYS};
use crate::runtime::ModelManifest;
use crate::selection::{FrequencySource, FrequencyTracker};
use crate::util::rng::Xoshiro256;

use super::step::{self, StepState, TrainOutcome};
use super::trainer::Trainer;

/// Warmup batches sampled from day 0 for the `FirstDay` source.
const FIRST_DAY_WARMUP_BATCHES: u64 = 20;
/// Warmup batches sampled per day for the `AllDays` oracle source.
const ALL_DAYS_WARMUP_BATCHES_PER_DAY: u64 = 8;
/// Day-0 batches sniffed when `Streaming` + DP-FEST starts cold.
const COLD_START_SNIFF_BATCHES: u64 = 4;

/// RNG for warmup / cold-start prior batch `index` — self-contained per
/// batch and disjoint (by tag) from the train and eval streams.
pub fn prior_batch_rng(seed: u64, index: u64) -> Xoshiro256 {
    Xoshiro256::seed_from(seed ^ 0x57AE ^ (index + 1).wrapping_mul(0xA24BAED4963EE407))
}

/// The warmup / cold-start prior pass of a streaming run, as a data-plan
/// item: how many batches are drawn from the tagged [`prior_batch_rng`]
/// stream before training, and which simulated day each samples from.
///
/// This is the single description both executors derive the prior batch
/// list from: [`StreamSchedule::run_days`] consumes the batches in index
/// order through [`StreamDriver::observe_prior`], and the async engine's
/// data workers *produce* exactly this list ahead of the training stream —
/// so the FirstDay/AllDays pre-passes and the cold-start sniff overlap
/// pipeline fill instead of generating barrier-side.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PriorPass {
    /// no prior batches (plain runs; `Streaming` without FEST selection)
    None,
    /// `first-day` warmup: 20 batches of day 0
    FirstDay,
    /// `all-days` oracle warmup: 8 batches from each of the 18 training days
    AllDays,
    /// `streaming` + DP-FEST cold start: a 4-batch day-0 sniff
    Sniff,
}

impl PriorPass {
    /// Total prior batches the pass generates (indices `0..num_batches()`).
    pub fn num_batches(self) -> u64 {
        match self {
            PriorPass::None => 0,
            PriorPass::FirstDay => FIRST_DAY_WARMUP_BATCHES,
            PriorPass::AllDays => TRAIN_DAYS as u64 * ALL_DAYS_WARMUP_BATCHES_PER_DAY,
            PriorPass::Sniff => COLD_START_SNIFF_BATCHES,
        }
    }

    /// Which simulated day prior batch `index` samples from.
    pub fn day_of(self, index: u64) -> usize {
        match self {
            PriorPass::AllDays => (index / ALL_DAYS_WARMUP_BATCHES_PER_DAY) as usize,
            _ => 0,
        }
    }
}

/// Which simulated day training step `step` belongs to, at `steps_per_day`
/// steps per day.  The **single** definition of the step→day mapping —
/// [`StreamSchedule::day_of_step`] and the engine's data workers both call
/// this, so the day a worker generates a batch for can never drift from
/// the day [`StreamSchedule::run_days`] records it under.
pub fn day_of_step(steps_per_day: u64, step: u64) -> usize {
    ((step / steps_per_day.max(1)) as usize).min(TRAIN_DAYS - 1)
}

/// How many eval batches each held-out day (18..24) gets for a run config:
/// half the plain-mode eval budget, at least one.  Shared by the `stream`
/// and `train-async --stream` CLI paths and the streaming harnesses — the
/// two backends are only bit-comparable while they split identically.
pub fn eval_batches_per_day(cfg: &RunConfig) -> usize {
    cfg.eval_batches.max(2) / 2
}

/// The drift-enabled synthetic-Criteo config of a streaming run: the
/// model's vocabularies, the run seed's data tag, drift on.  The single
/// derivation every streaming surface uses — the `stream` and
/// `train-async --stream` CLI commands and the tab5/fig5 harnesses — which
/// is what entitles them to compare outcomes bitwise.
pub fn drift_gen_cfg(cfg: &RunConfig, model: &ModelManifest) -> Result<CriteoConfig> {
    Ok(CriteoConfig::new(model.attr_usize_list("vocabs")?, cfg.seed ^ 0xDA7A).with_drift())
}

/// Aggregate one batch into per-feature `(bucket, count)` pairs, sorted by
/// bucket id.  The async engine's data workers ship these alongside each
/// batch; the sync path builds the identical pairs inline — either way the
/// tracker receives the same integer sums.
pub fn pctr_batch_counts(batch: &PctrBatch) -> Vec<Vec<(u32, u32)>> {
    (0..batch.num_features)
        .map(|f| {
            let mut col: Vec<u32> =
                (0..batch.batch_size).map(|i| batch.cat_of(i, f) as u32).collect();
            col.sort_unstable();
            let mut pairs: Vec<(u32, u32)> = Vec::new();
            for b in col {
                match pairs.last_mut() {
                    Some((pb, c)) if *pb == b => *c += 1,
                    _ => pairs.push((b, 1)),
                }
            }
            pairs
        })
        .collect()
}

/// Record one batch's bucket observations into the tracker (all features).
/// Goes straight through [`FrequencyTracker::observe`] — the sort-based
/// pre-aggregation of [`pctr_batch_counts`] only pays off when the pairs
/// travel over the engine's worker→barrier channel; the running sums are
/// bit-identical either way (integer addition commutes).
pub fn observe_batch(tracker: &mut FrequencyTracker, batch: &PctrBatch) {
    let mut col: Vec<i32> = Vec::with_capacity(batch.batch_size);
    for f in 0..batch.num_features {
        col.clear();
        col.extend((0..batch.batch_size).map(|i| batch.cat_of(i, f)));
        tracker.observe(f, &col);
    }
}

/// The two operations a training path must supply to run under a
/// [`StreamSchedule`]; everything else (warmup, period boundaries, budget
/// splits, batch streams) is shared, which is what keeps the sync trainer
/// and the async engine bit-identical in streaming mode.
pub trait StreamDriver {
    /// Run training step `step` of `day`: obtain the step's batch (from
    /// [`step::train_batch_rng`]`(seed, step)` at `day` — or from the data
    /// workers, who generated exactly that), record its bucket observations
    /// into `tracker`, and apply the DP update.
    fn train_step(
        &mut self,
        step: u64,
        day: usize,
        tracker: &mut FrequencyTracker,
    ) -> Result<()>;

    /// Record warmup / cold-start prior batch `index` (drawn from
    /// [`prior_batch_rng`]`(seed, index)` at `day` — see [`PriorPass`]) into
    /// `tracker`.  The sync path generates the batch inline; the engine
    /// merges the pre-aggregated counts its data workers shipped for that
    /// batch — integer sums commute, so the tracker ends up bit-identical.
    fn observe_prior(
        &mut self,
        index: u64,
        day: usize,
        tracker: &mut FrequencyTracker,
    ) -> Result<()>;

    /// Recompute the DP-FEST bucket pre-selection from published per-feature
    /// dense counts, at the split selection budget `epsilon`.
    fn select(&mut self, feature_counts: &[Vec<f64>], epsilon: f64) -> Result<()>;
}

/// The deterministic 24-day protocol: what happens on which day, which
/// batches feed warmup/training/eval, and when DP-FEST reselects.
///
/// Derived once from a [`RunConfig`]; both executors hold the same values,
/// so a `(cfg, seed)` pair fully determines the streaming run.
#[derive(Clone, Debug)]
pub struct StreamSchedule {
    /// training steps per simulated day (`cfg.steps / 18`, at least 1)
    pub steps_per_day: u64,
    /// eval batches drawn per held-out day (days 18..24)
    pub eval_batches_per_day: usize,
    /// streaming period in days (`cfg.streaming_period`, at least 1)
    pub period: usize,
    /// which frequency counts feed DP-FEST reselection
    pub source: FrequencySource,
    /// whether the algorithm reselects at all (DP-FEST / DP-AdaFEST+)
    pub uses_fest: bool,
    /// `cfg.fest_epsilon` split equally over the expected reselections
    /// (conservative basic composition; see [`StreamSchedule::new`])
    pub fest_eps_per_selection: f64,
    /// run seed — tags every batch stream
    pub seed: u64,
    /// examples per batch
    pub batch_size: usize,
}

impl StreamSchedule {
    /// Build the schedule for a run config.
    ///
    /// The FEST selection budget is split across the expected number of
    /// reselections (equal split — conservative basic composition).  The
    /// split budget is passed to each selection call directly: a previous
    /// revision divided `cfg.fest_epsilon` in place, so a second run would
    /// halve the already-halved budget.
    pub fn new(
        cfg: &RunConfig,
        batch_size: usize,
        eval_batches_per_day: usize,
    ) -> StreamSchedule {
        let period = cfg.streaming_period.max(1);
        let source = cfg.freq_source;
        let n_selections = match source {
            FrequencySource::FirstDay | FrequencySource::AllDays => 1,
            FrequencySource::Streaming => TRAIN_DAYS.div_ceil(period),
        };
        StreamSchedule {
            steps_per_day: (cfg.steps / TRAIN_DAYS as u64).max(1),
            eval_batches_per_day,
            period,
            source,
            uses_fest: cfg.algorithm.uses_fest_selection(),
            fest_eps_per_selection: cfg.fest_epsilon / n_selections as f64,
            seed: cfg.seed,
            batch_size,
        }
    }

    /// Total training steps of the protocol (18 days × steps per day).
    pub fn total_steps(&self) -> u64 {
        TRAIN_DAYS as u64 * self.steps_per_day
    }

    /// Which simulated day training step `step` belongs to.
    pub fn day_of_step(&self, step: u64) -> usize {
        day_of_step(self.steps_per_day, step)
    }

    /// Whether the protocol consumes per-batch training counts: only the
    /// `Streaming` source re-publishes running sums after warmup, and only
    /// FEST-selecting algorithms ever read the published snapshot.  Both
    /// executors gate their per-step counting on this — skipping it for
    /// every other run changes nothing the protocol consumes.
    pub fn needs_stream_counts(&self) -> bool {
        self.uses_fest && self.source == FrequencySource::Streaming
    }

    /// Which prior pass this run performs before its first training step.
    /// Deterministic from the schedule alone — in particular the `Streaming`
    /// cold-start sniff *always* fires for a FEST-selecting run, because the
    /// tracker is necessarily empty at the day-0 period boundary (nothing
    /// observes before it) — so the engine's data workers can generate the
    /// prior batches ahead of time without waiting on barrier state.
    pub fn prior_pass(&self) -> PriorPass {
        match self.source {
            FrequencySource::FirstDay => PriorPass::FirstDay,
            FrequencySource::AllDays => PriorPass::AllDays,
            FrequencySource::Streaming if self.uses_fest => PriorPass::Sniff,
            FrequencySource::Streaming => PriorPass::None,
        }
    }

    /// Align `state`'s privacy calibration with the streamed step count.
    /// The protocol runs [`total_steps`](StreamSchedule::total_steps) noisy
    /// steps (18 days × steps/day), not `cfg.steps`, so when `cfg.steps` is
    /// not a multiple of 18 the σ pair calibrated at construction covers
    /// the wrong number of compositions — more DP draws than the advertised
    /// ε on the low side, silently fewer steps on the high side.  Both
    /// executors call this (idempotently) before the first noise draw.
    pub fn recalibrate(&self, state: &mut StepState) -> Result<()> {
        let total = self.total_steps();
        if state.cfg.steps != total {
            state.cfg.steps = total;
            let (sigma1, sigma2) = step::calibrate_noise(&state.cfg, state.batch_size())?;
            state.sigma1 = sigma1;
            state.sigma2 = sigma2;
        }
        Ok(())
    }

    fn reselect(
        &self,
        tracker: &FrequencyTracker,
        vocabs: &[usize],
        driver: &mut impl StreamDriver,
    ) -> Result<()> {
        let counts: Vec<Vec<f64>> = (0..vocabs.len())
            .map(|f| tracker.dense_counts(f, vocabs[f]))
            .collect();
        driver.select(&counts, self.fest_eps_per_selection)
    }

    /// Run the 18 training days: frequency-source warmup, period-boundary
    /// publishes and reselections, and the per-day step loop.  Warmup and
    /// cold-start sniff batches (the run's [`PriorPass`]) are consumed in
    /// index order through [`StreamDriver::observe_prior`] — generated
    /// inline on the sync path, pre-counted by the data workers on the
    /// engine — and training batches through [`StreamDriver::train_step`].
    /// Returns the number of DP-FEST reselections performed.
    pub fn run_days(
        &self,
        tracker: &mut FrequencyTracker,
        vocabs: &[usize],
        driver: &mut impl StreamDriver,
    ) -> Result<usize> {
        let mut reselections = 0usize;

        // warmup / oracle pre-passes for the frequency source
        match self.prior_pass() {
            PriorPass::FirstDay => {
                for i in 0..FIRST_DAY_WARMUP_BATCHES {
                    driver.observe_prior(i, 0, tracker)?;
                }
                tracker.publish();
            }
            PriorPass::AllDays => {
                for day in 0..TRAIN_DAYS {
                    for i in 0..ALL_DAYS_WARMUP_BATCHES_PER_DAY {
                        let idx = day as u64 * ALL_DAYS_WARMUP_BATCHES_PER_DAY + i;
                        driver.observe_prior(idx, day, tracker)?;
                    }
                }
                tracker.publish();
            }
            PriorPass::Sniff | PriorPass::None => {}
        }
        if self.uses_fest && self.source != FrequencySource::Streaming {
            self.reselect(tracker, vocabs, driver)?;
            reselections += 1;
        }

        for day in 0..TRAIN_DAYS {
            // period boundary: publish + (streaming) reselect
            if day % self.period == 0 && self.source == FrequencySource::Streaming {
                tracker.publish();
                if self.uses_fest && (day > 0 || tracker.total_observed(0) > 0) {
                    self.reselect(tracker, vocabs, driver)?;
                    reselections += 1;
                } else if self.uses_fest {
                    // cold start: select from a tiny day-0 sniff
                    for i in 0..COLD_START_SNIFF_BATCHES {
                        driver.observe_prior(i, 0, tracker)?;
                    }
                    tracker.publish();
                    self.reselect(tracker, vocabs, driver)?;
                    reselections += 1;
                }
            }
            for s in 0..self.steps_per_day {
                let t = day as u64 * self.steps_per_day + s;
                driver.train_step(t, day, tracker)?;
            }
        }
        Ok(reselections)
    }

    /// The eval batches of held-out day `day` (each from its own tagged
    /// eval stream — identical across executors).
    pub fn eval_day_batches(&self, gen: &SynthCriteo, day: usize) -> Vec<PctrBatch> {
        (0..self.eval_batches_per_day)
            .map(|j| {
                let idx = (day * self.eval_batches_per_day + j) as u64;
                let mut rng = step::eval_batch_rng(self.seed, idx);
                gen.batch(day, self.batch_size, &mut rng)
            })
            .collect()
    }

    /// Evaluate on each held-out day (18..24) and on their union, through a
    /// caller-supplied `(AUC, mean loss)` evaluator.  Returns
    /// `(per-day AUC, combined AUC, combined eval loss)`.
    pub fn eval_days(
        &self,
        gen: &SynthCriteo,
        mut eval: impl FnMut(&[PctrBatch]) -> Result<(f64, f64)>,
    ) -> Result<(Vec<f64>, f64, f64)> {
        let mut per_day_auc = Vec::new();
        let mut all: Vec<PctrBatch> = Vec::new();
        for day in EVAL_DAYS {
            let batches = self.eval_day_batches(gen, day);
            let (auc, _) = eval(&batches)?;
            per_day_auc.push(auc);
            all.extend(batches);
        }
        let (auc_all, eval_loss) = eval(&all)?;
        Ok((per_day_auc, auc_all, eval_loss))
    }
}

/// The synchronous streaming trainer: a [`Trainer`] driven through the
/// shared [`StreamSchedule`].
pub struct StreamingTrainer<'rt> {
    /// the wrapped synchronous trainer (owns store, state, artifacts)
    pub trainer: Trainer<'rt>,
    /// the deterministic 24-day protocol this run follows
    pub schedule: StreamSchedule,
}

/// What a streaming run reports beyond the plain [`TrainOutcome`].
#[derive(Clone, Debug)]
pub struct StreamingOutcome {
    /// the plain training outcome (utility = AUC over all eval days)
    pub outcome: TrainOutcome,
    /// AUC per eval day (days 18..24) — distribution-shift profile
    pub per_day_auc: Vec<f64>,
    /// how many DP-FEST reselections the run performed
    pub reselections: usize,
}

impl<'rt> StreamingTrainer<'rt> {
    /// Wrap a trainer; the schedule derives from its run config.
    pub fn new(trainer: Trainer<'rt>, eval_batches_per_day: usize) -> Self {
        let schedule =
            StreamSchedule::new(trainer.cfg(), trainer.batch_size(), eval_batches_per_day);
        StreamingTrainer { trainer, schedule }
    }

    /// Run the full 24-day protocol. `gen` must be a drift-enabled
    /// SynthCriteo.
    pub fn run(&mut self, gen: &SynthCriteo) -> Result<StreamingOutcome> {
        self.schedule.recalibrate(&mut self.trainer.state)?;
        let vocabs: Vec<usize> =
            self.trainer.emb_tables().iter().map(|t| t.vocab).collect();
        let mut tracker = FrequencyTracker::new(vocabs.len(), self.schedule.source);
        let reselections = {
            let mut driver = TrainerDriver {
                trainer: &mut self.trainer,
                gen,
                count_batches: self.schedule.needs_stream_counts(),
            };
            self.schedule.run_days(&mut tracker, &vocabs, &mut driver)?
        };

        // evaluation on held-out future days
        let trainer = &self.trainer;
        let (per_day_auc, auc_all, eval_loss) =
            self.schedule.eval_days(gen, |batches| trainer.eval_pctr(batches))?;
        let outcome = self.trainer.outcome(auc_all, eval_loss);
        Ok(StreamingOutcome { outcome, per_day_auc, reselections })
    }
}

/// [`StreamDriver`] over the synchronous trainer: generates each step's
/// batch inline from its self-contained stream.
struct TrainerDriver<'a, 'rt> {
    trainer: &'a mut Trainer<'rt>,
    gen: &'a SynthCriteo,
    /// [`StreamSchedule::needs_stream_counts`] — skip per-batch counting
    /// when nothing ever reads the published snapshot
    count_batches: bool,
}

impl StreamDriver for TrainerDriver<'_, '_> {
    fn train_step(
        &mut self,
        step: u64,
        day: usize,
        tracker: &mut FrequencyTracker,
    ) -> Result<()> {
        let mut rng = step::train_batch_rng(self.trainer.cfg().seed, step);
        let batch = self.gen.batch(day, self.trainer.batch_size(), &mut rng);
        if self.count_batches {
            observe_batch(tracker, &batch);
        }
        self.trainer.step_pctr(&batch)?;
        Ok(())
    }

    fn observe_prior(
        &mut self,
        index: u64,
        day: usize,
        tracker: &mut FrequencyTracker,
    ) -> Result<()> {
        let mut rng = prior_batch_rng(self.trainer.cfg().seed, index);
        let batch = self.gen.batch(day, self.trainer.batch_size(), &mut rng);
        observe_batch(tracker, &batch);
        Ok(())
    }

    fn select(&mut self, feature_counts: &[Vec<f64>], epsilon: f64) -> Result<()> {
        self.trainer.fest_select_with_eps(feature_counts, epsilon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Algorithm;

    #[test]
    fn schedule_totals_and_day_mapping() {
        let mut cfg = RunConfig::default();
        cfg.steps = 54; // 3/day
        cfg.streaming_period = 4;
        cfg.algorithm = Algorithm::DpFest;
        cfg.freq_source = FrequencySource::Streaming;
        let s = StreamSchedule::new(&cfg, 32, 2);
        assert_eq!(s.steps_per_day, 3);
        assert_eq!(s.total_steps(), 54);
        assert_eq!(s.day_of_step(0), 0);
        assert_eq!(s.day_of_step(3), 1);
        assert_eq!(s.day_of_step(53), 17);
        // ceil(18/4) = 5 reselections split the budget
        assert!((s.fest_eps_per_selection - cfg.fest_epsilon / 5.0).abs() < 1e-15);
    }

    #[test]
    fn non_multiple_steps_round_to_whole_days() {
        let mut cfg = RunConfig::default();
        cfg.steps = 100; // 5/day over 18 days -> 90 streamed steps
        let s = StreamSchedule::new(&cfg, 16, 1);
        assert_eq!(s.steps_per_day, 5);
        assert_eq!(s.total_steps(), 90);
    }

    #[test]
    fn batch_counts_are_sorted_and_complete() {
        let b = PctrBatch {
            batch_size: 5,
            num_features: 2,
            num_numeric: 0,
            cat: vec![3, 0, 1, 1, 3, 0, 1, 2, 3, 1],
            num: vec![],
            y: vec![0.0; 5],
        };
        let counts = pctr_batch_counts(&b);
        assert_eq!(counts[0], vec![(1, 2), (3, 3)]);
        assert_eq!(counts[1], vec![(0, 2), (1, 2), (2, 1)]);
        let total: u32 = counts.iter().flatten().map(|&(_, c)| c).sum();
        assert_eq!(total as usize, 2 * 5);
    }

    #[test]
    fn prior_stream_is_self_contained_and_distinct_from_train() {
        let mut a = prior_batch_rng(7, 3);
        let mut b = prior_batch_rng(7, 3);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = prior_batch_rng(7, 4);
        let mut a2 = prior_batch_rng(7, 3);
        assert_ne!(a2.next_u64(), c.next_u64());
        let mut t = step::train_batch_rng(7, 3);
        let mut a3 = prior_batch_rng(7, 3);
        assert_ne!(a3.next_u64(), t.next_u64());
    }
}
