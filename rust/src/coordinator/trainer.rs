//! The synchronous DP training loop (Algorithm 1 and all baselines) over
//! AOT artifacts.  All step mechanics live in [`super::step`] and are shared
//! with the asynchronous [`crate::engine`]; this type owns the runtime
//! handle, the parameter store, and the per-model artifact plan.

use anyhow::{bail, Result};

use crate::config::RunConfig;
use crate::data::{PctrBatch, SynthCriteo, TextBatch};
use crate::runtime::Runtime;
use crate::sparse::GradSizeMeter;
use crate::telemetry::Stage;
use crate::util::rng::Xoshiro256;

use super::step::{self, ModelMeta, OutputKind, StepState, StepStats, TrainOutcome};
pub use super::step::EmbTable;

/// The synchronous trainer: one model, one runtime handle, one in-place
/// parameter store, driven a batch at a time through the shared step core.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    /// the model parameters, updated in place each step
    pub store: crate::models::ParamStore,
    /// Mutable Algorithm-1 state (selection, noise RNG, meter, history),
    /// shared structurally with the async engine.
    pub state: StepState,
    grads_artifact: String,
    fwd_artifact: String,
    output_plan: Vec<OutputKind>,
    /// Scopes the process-wide kernel knobs (threads + backend) to this
    /// trainer's lifetime; dropping the trainer restores the prior values,
    /// so back-to-back runs in one process cannot inherit them.
    _kernel_scope: crate::kernels::ScopedConfig,
}

impl<'rt> Trainer<'rt> {
    /// Initialise a trainer: locate the model's artifact pair, initialise
    /// parameters, and calibrate the noise pair.
    pub fn new(cfg: RunConfig, rt: &'rt Runtime) -> Result<Trainer<'rt>> {
        // Apply the executor-kernel knobs for this trainer's scope.
        // Threading is bit-exact at any setting; the backend is the one
        // knob that changes bits (`config::EngineConfig::kernel_backend`).
        let kernel_scope = crate::kernels::ScopedConfig::apply(
            cfg.engine.kernel_threads,
            cfg.engine.kernel_backend,
        );
        let model = rt.manifest.model(&cfg.model)?;
        let store = crate::models::ParamStore::init(model, cfg.seed)?;
        let (grads_artifact, fwd_artifact) =
            step::locate_artifacts(&rt.manifest, &cfg.model)?;
        let output_plan =
            step::output_plan(rt.manifest.artifact(&grads_artifact)?, &store)?;
        let state = StepState::new(cfg, model, &store)?;
        Ok(Trainer {
            rt,
            store,
            state,
            grads_artifact,
            fwd_artifact,
            output_plan,
            _kernel_scope: kernel_scope,
        })
    }

    /// The model's fixed training batch size.
    pub fn batch_size(&self) -> usize {
        self.state.batch_size()
    }

    /// The run configuration this trainer was built with.
    pub fn cfg(&self) -> &RunConfig {
        &self.state.cfg
    }

    /// Calibrated contribution-map noise multiplier.
    pub fn sigma1(&self) -> f64 {
        self.state.sigma1
    }

    /// Calibrated gradient noise multiplier.
    pub fn sigma2(&self) -> f64 {
        self.state.sigma2
    }

    /// Gradient-size bookkeeping (the paper's reduction factor).
    pub fn meter(&self) -> &GradSizeMeter {
        &self.state.meter
    }

    /// The embedding tables, in feature order.
    pub fn emb_tables(&self) -> &[EmbTable] {
        &self.state.emb_tables
    }

    /// DP-FEST pre-selection from per-feature frequency counts (Algorithm 2
    /// with the Appendix-B.1 ε/k split).  `feature_counts[f][bucket]`.
    pub fn fest_select(&mut self, feature_counts: &[Vec<f64>]) -> Result<()> {
        self.state.fest_select(feature_counts)
    }

    /// DP-FEST pre-selection at an explicit selection budget (used by the
    /// streaming trainer to split `fest_epsilon` over reselections).
    pub fn fest_select_with_eps(
        &mut self,
        feature_counts: &[Vec<f64>],
        epsilon: f64,
    ) -> Result<()> {
        self.state.fest_select_with_eps(feature_counts, epsilon)
    }

    /// One training step on a pCTR batch.
    pub fn step_pctr(&mut self, batch: &PctrBatch) -> Result<StepStats> {
        let b = self.batch_size();
        if batch.batch_size != b {
            bail!("batch size {} != model batch {b}", batch.batch_size);
        }
        let mut inputs = self.store.tensors();
        inputs.extend(batch.to_tensors());
        let (c1, c2) = step::clip_inputs(&self.state.cfg);
        inputs.push(c1);
        inputs.push(c2);
        let tele = self.state.tele.clone();
        let outs = tele.time(Stage::ChunkCompute, || {
            self.rt.execute(&self.grads_artifact, &inputs)
        })?;
        let need_counts = self.state.cfg.algorithm.uses_contribution_map();
        let bundle = tele.time(Stage::Assemble, || {
            step::assemble_pctr(
                &self.output_plan,
                &outs,
                &self.state.emb_tables,
                batch,
                need_counts,
            )
        })?;
        self.state.apply_update(bundle, &mut self.store)
    }

    /// One training step on a text batch.
    pub fn step_text(&mut self, batch: &TextBatch) -> Result<StepStats> {
        let b = self.batch_size();
        if batch.batch_size != b {
            bail!("batch size {} != model batch {b}", batch.batch_size);
        }
        let seq_len = match self.state.meta {
            ModelMeta::Nlu { seq_len, .. } => seq_len,
            _ => bail!("step_text on a non-NLU model"),
        };
        let mut inputs = self.store.tensors();
        inputs.extend(batch.to_tensors());
        let (c1, c2) = step::clip_inputs(&self.state.cfg);
        inputs.push(c1);
        inputs.push(c2);
        let tele = self.state.tele.clone();
        let outs = tele.time(Stage::ChunkCompute, || {
            self.rt.execute(&self.grads_artifact, &inputs)
        })?;
        let need_counts = self.state.cfg.algorithm.uses_contribution_map();
        let bundle = tele.time(Stage::Assemble, || {
            step::assemble_text(
                &self.output_plan,
                &outs,
                &self.state.emb_tables,
                batch,
                seq_len,
                need_counts,
            )
        })?;
        self.state.apply_update(bundle, &mut self.store)
    }

    /// Evaluate on pCTR batches: returns (AUC, mean loss).
    pub fn eval_pctr(&self, batches: &[PctrBatch]) -> Result<(f64, f64)> {
        step::eval_pctr(self.rt, &self.fwd_artifact, &self.store, batches)
    }

    /// Evaluate on text batches: returns (accuracy, mean loss).
    pub fn eval_text(&self, batches: &[TextBatch]) -> Result<(f64, f64)> {
        let num_classes = match self.state.meta {
            ModelMeta::Nlu { num_classes, .. } => num_classes,
            _ => bail!("eval_text on a non-NLU model"),
        };
        step::eval_text(self.rt, &self.fwd_artifact, &self.store, batches, num_classes)
    }

    /// Full non-streaming pCTR run: optional FEST selection from `prior`
    /// batches, `cfg.steps` training steps, then eval.
    ///
    /// Batch `t` comes from the self-contained stream
    /// [`step::train_batch_rng`]`(seed, t)` — the invariant that makes the
    /// async engine's pipelined data loading bit-identical to this loop.
    pub fn run_pctr(&mut self, gen: &SynthCriteo) -> Result<TrainOutcome> {
        if self.state.cfg.algorithm.uses_fest_selection()
            && self.state.fest_selected.is_none()
        {
            let counts =
                pctr_frequency_counts(gen, &self.state.emb_tables, 50, self.state.cfg.seed);
            self.fest_select(&counts)?;
        }
        let seed = self.state.cfg.seed;
        let bsz = self.batch_size();
        for t in 0..self.state.cfg.steps {
            let mut rng = step::train_batch_rng(seed, t);
            let batch = self
                .state
                .tele
                .time(Stage::DataGenerate, || gen.batch(0, bsz, &mut rng));
            self.step_pctr(&batch)?;
        }
        let eval: Vec<PctrBatch> = (0..self.state.cfg.eval_batches)
            .map(|i| {
                let mut rng = step::eval_batch_rng(seed, i as u64);
                gen.batch(0, bsz, &mut rng)
            })
            .collect();
        let (auc, eval_loss) = self.eval_pctr(&eval)?;
        Ok(self.outcome(auc, eval_loss))
    }

    /// Full non-streaming text run.
    pub fn run_text(&mut self, gen: &crate::data::SynthText) -> Result<TrainOutcome> {
        if self.state.cfg.algorithm.uses_fest_selection()
            && self.state.fest_selected.is_none()
        {
            let counts =
                text_frequency_counts(gen, self.state.total_vocab, 50, self.state.cfg.seed);
            self.fest_select(&[counts])?;
        }
        let seed = self.state.cfg.seed;
        let bsz = self.batch_size();
        for t in 0..self.state.cfg.steps {
            let mut rng = step::train_batch_rng(seed, t);
            let batch = self
                .state
                .tele
                .time(Stage::DataGenerate, || gen.batch(bsz, &mut rng));
            self.step_text(&batch)?;
        }
        let eval: Vec<TextBatch> = (0..self.state.cfg.eval_batches)
            .map(|i| {
                let mut rng = step::eval_batch_rng(seed, i as u64);
                gen.batch(bsz, &mut rng)
            })
            .collect();
        let (acc, eval_loss) = self.eval_text(&eval)?;
        Ok(self.outcome(acc, eval_loss))
    }

    /// Package the run's accumulated state into a [`TrainOutcome`].
    pub fn outcome(&self, utility: f64, eval_loss: f64) -> TrainOutcome {
        self.state.outcome(utility, eval_loss)
    }
}

/// Sample `n_batches` from the generator to build per-feature frequency
/// counts (the paper's "public prior" / DP-top-k input).
pub fn pctr_frequency_counts(
    gen: &SynthCriteo,
    tables: &[EmbTable],
    n_batches: usize,
    seed: u64,
) -> Vec<Vec<f64>> {
    let mut rng = Xoshiro256::seed_from(seed ^ 0xF2E9);
    let mut counts: Vec<Vec<f64>> = tables.iter().map(|t| vec![0f64; t.vocab]).collect();
    for _ in 0..n_batches {
        let b = gen.batch(0, 256, &mut rng);
        for i in 0..b.batch_size {
            for (f, c) in counts.iter_mut().enumerate() {
                c[b.cat_of(i, f) as usize] += 1.0;
            }
        }
    }
    counts
}

/// Token frequency counts for NLU FEST selection.
pub fn text_frequency_counts(
    gen: &crate::data::SynthText,
    vocab: usize,
    n_batches: usize,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Xoshiro256::seed_from(seed ^ 0xF2E9);
    let mut counts = vec![0f64; vocab];
    for _ in 0..n_batches {
        let b = gen.batch(64, &mut rng);
        for &t in &b.ids {
            counts[t as usize] += 1.0;
        }
    }
    counts
}
