//! The DP training loop (Algorithm 1 and all baselines) over AOT artifacts.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::accounting::{calibrate_sigma, calibrate_sigma_pair};
use crate::config::RunConfig;
use crate::data::{PctrBatch, SynthCriteo, TextBatch};
use crate::filtering::{ContributionMap, SurvivorSet};
use crate::metrics;
use crate::models::ParamStore;
use crate::runtime::{HostTensor, Runtime};
use crate::selection::{dp_top_k_per_feature, exponential_select};
use crate::sparse::{
    add_dense_noise, add_row_noise, GradSizeMeter, Optimizer, RowSparseGrad,
};
use crate::util::rng::Xoshiro256;

use super::algorithm::Algorithm;

/// One embedding table's geometry in the concatenated row space.
#[derive(Clone, Debug)]
pub struct EmbTable {
    pub param_index: usize,
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
    pub row_offset: usize,
    /// offset of this table's slice in the artifact's per-example grads
    pub grad_offset: usize,
}

/// Model-kind-specific metadata derived from the manifest.
#[derive(Clone, Debug)]
pub enum ModelMeta {
    Pctr {
        batch_size: usize,
        num_numeric: usize,
        num_features: usize,
    },
    Nlu {
        batch_size: usize,
        seq_len: usize,
        num_classes: usize,
    },
}

impl ModelMeta {
    pub fn batch_size(&self) -> usize {
        match self {
            ModelMeta::Pctr { batch_size, .. } | ModelMeta::Nlu { batch_size, .. } => {
                *batch_size
            }
        }
    }
}

/// How each grads-artifact output is consumed.
#[derive(Clone, Debug)]
enum OutputKind {
    Loss,
    DenseGrad(usize), // param index
    EmbGrads,
    Counts,
    Scales,
}

#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub loss: f64,
    pub emb_coords_noised: usize,
    pub dense_coords_noised: usize,
    pub survivors: usize,
    pub present_rows: usize,
}

#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub loss_history: Vec<f64>,
    pub utility: f64, // AUC (pctr) or accuracy (nlu)
    pub eval_loss: f64,
    pub emb_grad_coords_per_step: f64,
    pub reduction_factor: f64,
    pub sigma1: f64,
    pub sigma2: f64,
}

// Calibration cache: PLD calibration costs seconds; sweeps reuse budgets.
static SIGMA_CACHE: Mutex<Option<HashMap<(u64, u64, u64, u64), f64>>> = Mutex::new(None);

fn cached_calibrate(epsilon: f64, delta: f64, q: f64, steps: u64) -> Result<f64> {
    let key = (
        (epsilon * 1e6) as u64,
        (delta * 1e12) as u64,
        (q * 1e9) as u64,
        steps,
    );
    {
        let cache = SIGMA_CACHE.lock().unwrap();
        if let Some(map) = cache.as_ref() {
            if let Some(&s) = map.get(&key) {
                return Ok(s);
            }
        }
    }
    let sigma = calibrate_sigma(epsilon, delta, q, steps)?;
    let mut cache = SIGMA_CACHE.lock().unwrap();
    cache.get_or_insert_with(HashMap::new).insert(key, sigma);
    Ok(sigma)
}

pub struct Trainer<'rt> {
    pub cfg: RunConfig,
    rt: &'rt Runtime,
    pub store: ParamStore,
    pub meta: ModelMeta,
    pub emb_tables: Vec<EmbTable>,
    pub total_vocab: usize,
    opt: Optimizer,
    rng: Xoshiro256,
    pub meter: GradSizeMeter,
    pub sigma1: f64,
    pub sigma2: f64,
    grads_artifact: String,
    fwd_artifact: String,
    output_plan: Vec<OutputKind>,
    /// DP-FEST pre-selected rows (concatenated space), if applicable
    pub fest_selected: Option<SurvivorSet>,
    pub loss_history: Vec<f64>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(cfg: RunConfig, rt: &'rt Runtime) -> Result<Trainer<'rt>> {
        let model = rt.manifest.model(&cfg.model)?;
        let store = ParamStore::init(model, cfg.seed)?;

        // locate artifacts for this model
        let mut grads_artifact = None;
        let mut fwd_artifact = None;
        for (name, art) in &rt.manifest.artifacts {
            if art.model == cfg.model {
                if name.ends_with("_grads") {
                    grads_artifact = Some(name.clone());
                } else if name.ends_with("_fwd") {
                    fwd_artifact = Some(name.clone());
                }
            }
        }
        let grads_artifact =
            grads_artifact.with_context(|| format!("no grads artifact for {}", cfg.model))?;
        let fwd_artifact =
            fwd_artifact.with_context(|| format!("no fwd artifact for {}", cfg.model))?;

        // model geometry
        let (meta, emb_tables, total_vocab) = match model.kind.as_str() {
            "pctr" => {
                let vocabs = model.attr_usize_list("vocabs")?;
                let dims = model.attr_usize_list("dims")?;
                let offsets = model.attr_usize_list("row_offsets")?;
                let mut tables = Vec::with_capacity(vocabs.len());
                let mut grad_off = 0;
                for (f, ((&v, &d), &off)) in
                    vocabs.iter().zip(&dims).zip(&offsets).enumerate()
                {
                    tables.push(EmbTable {
                        param_index: store.index_of(&format!("table_{f:02}"))?,
                        name: format!("table_{f:02}"),
                        vocab: v,
                        dim: d,
                        row_offset: off,
                        grad_offset: grad_off,
                    });
                    grad_off += d;
                }
                (
                    ModelMeta::Pctr {
                        batch_size: model.attr_usize("batch_size")?,
                        num_numeric: model.attr_usize("num_numeric")?,
                        num_features: vocabs.len(),
                    },
                    tables,
                    model.attr_usize("total_vocab")?,
                )
            }
            "nlu" => {
                let vocab = model.attr_usize("vocab")?;
                let emb_lora = model.attr_usize("emb_lora_rank").unwrap_or(0);
                let (pname, dim) = if emb_lora > 0 {
                    ("emb_lora_a".to_string(), emb_lora)
                } else {
                    ("emb_table".to_string(), model.attr_usize("d_model")?)
                };
                let tables = vec![EmbTable {
                    param_index: store.index_of(&pname)?,
                    name: pname,
                    vocab,
                    dim,
                    row_offset: 0,
                    grad_offset: 0,
                }];
                (
                    ModelMeta::Nlu {
                        batch_size: model.attr_usize("batch_size")?,
                        seq_len: model.attr_usize("seq_len")?,
                        num_classes: model.attr_usize("num_classes")?,
                    },
                    tables,
                    vocab,
                )
            }
            other => bail!("unknown model kind {other}"),
        };

        // output plan for the grads artifact
        let art = rt.manifest.artifact(&grads_artifact)?;
        let mut output_plan = Vec::with_capacity(art.outputs.len());
        for out in &art.outputs {
            let kind = match out.name.as_str() {
                "loss" => OutputKind::Loss,
                "zgrads_scaled" | "aout_grads_scaled" => OutputKind::EmbGrads,
                "counts" => OutputKind::Counts,
                "scales" => OutputKind::Scales,
                g if g.starts_with("grad_") => {
                    OutputKind::DenseGrad(store.index_of(&g[5..])?)
                }
                other => bail!("unexpected grads output {other}"),
            };
            output_plan.push(kind);
        }

        // privacy calibration
        let b = meta.batch_size();
        let q = b as f64 / cfg.dataset_size as f64;
        let delta = cfg.effective_delta();
        let mut eps_train = cfg.epsilon;
        if cfg.algorithm.uses_fest_selection() {
            eps_train -= cfg.fest_epsilon; // Appendix B.1 budget split
            if eps_train <= 0.0 {
                bail!("fest_epsilon exhausts the privacy budget");
            }
        }
        let (sigma1, sigma2) = match cfg.algorithm {
            Algorithm::NonPrivate => (0.0, 0.0),
            a if a.uses_contribution_map() => {
                let pair =
                    calibrate_sigma_pair(eps_train, delta, q, cfg.steps, cfg.sigma_ratio)?;
                (pair.sigma1, pair.sigma2)
            }
            _ => (0.0, cached_calibrate(eps_train, delta, q, cfg.steps)?),
        };

        let mut meter = GradSizeMeter::default();
        meter.set_baselines(store.embedding_coords(), store.dense_coords());

        let opt = Optimizer::new(cfg.optimizer, cfg.lr);
        let rng = Xoshiro256::seed_from(cfg.seed ^ 0xDEADBEEF);

        Ok(Trainer {
            cfg,
            rt,
            store,
            meta,
            emb_tables,
            total_vocab,
            opt,
            rng,
            meter,
            sigma1,
            sigma2,
            grads_artifact,
            fwd_artifact,
            output_plan,
            fest_selected: None,
            loss_history: Vec::new(),
        })
    }

    pub fn batch_size(&self) -> usize {
        self.meta.batch_size()
    }

    /// DP-FEST pre-selection from per-feature frequency counts (Algorithm 2
    /// with the Appendix-B.1 ε/k split).  `feature_counts[f][bucket]`.
    pub fn fest_select(&mut self, feature_counts: &[Vec<f64>]) -> Result<()> {
        if feature_counts.len() != self.emb_tables.len() {
            bail!(
                "got counts for {} features, model has {}",
                feature_counts.len(),
                self.emb_tables.len()
            );
        }
        let per_feature = dp_top_k_per_feature(
            feature_counts,
            self.cfg.fest_top_k,
            self.cfg.fest_epsilon,
            &mut self.rng,
        );
        let mut ids: Vec<u32> = Vec::new();
        for (t, sel) in self.emb_tables.iter().zip(&per_feature) {
            for &b in sel {
                ids.push((t.row_offset + b as usize) as u32);
            }
        }
        ids.sort_unstable();
        ids.dedup();
        self.fest_selected = Some(SurvivorSet::from_sorted(ids));
        Ok(())
    }

    /// Effective clip norms fed to the artifact (non-private runs disable
    /// clipping with a huge C).
    fn clip_inputs(&self) -> (HostTensor, HostTensor) {
        let (c1, c2) = if self.cfg.algorithm.is_private() {
            (self.cfg.c1 as f32, self.cfg.c2 as f32)
        } else {
            (1e9, 1e9)
        };
        (
            HostTensor::f32(vec![1], vec![c1]),
            HostTensor::f32(vec![1], vec![c2]),
        )
    }

    /// One training step on a pCTR batch.
    pub fn step_pctr(&mut self, batch: &PctrBatch) -> Result<StepStats> {
        let b = self.batch_size();
        if batch.batch_size != b {
            bail!("batch size {} != model batch {b}", batch.batch_size);
        }
        let mut inputs = self.store.tensors();
        inputs.extend(batch.to_tensors());
        let (c1, c2) = self.clip_inputs();
        inputs.push(c1);
        inputs.push(c2);
        let outs = self.rt.execute(&self.grads_artifact, &inputs)?;
        let nf = self.emb_tables.len();
        // assemble per-table row-sparse grads from zgrads
        let plan = self.output_plan.clone();
        let mut loss = 0.0;
        let mut table_grads: Vec<RowSparseGrad> = Vec::new();
        let mut counts: Option<&HostTensor> = None;
        let mut dense_grads: Vec<(usize, &HostTensor)> = Vec::new();
        for (kind, out) in plan.iter().zip(&outs) {
            match kind {
                OutputKind::Loss => loss = out.scalar()?,
                OutputKind::DenseGrad(pi) => dense_grads.push((*pi, out)),
                OutputKind::EmbGrads => {
                    let zg = out.as_f32()?;
                    let d_total: usize = self.emb_tables.iter().map(|t| t.dim).sum();
                    table_grads = self
                        .emb_tables
                        .iter()
                        .map(|t| RowSparseGrad::with_capacity(t.vocab, t.dim, b))
                        .collect();
                    for i in 0..b {
                        for (f, t) in self.emb_tables.iter().enumerate() {
                            let row = batch.cat_of(i, f) as u32;
                            let s = i * d_total + t.grad_offset;
                            table_grads[f].add_row(row, &zg[s..s + t.dim]);
                        }
                    }
                    let _ = nf;
                }
                OutputKind::Counts => counts = Some(out),
                OutputKind::Scales => {}
            }
        }
        let counts = counts.context("grads artifact returned no counts")?;
        let stats = self.apply_update(loss, table_grads, counts, dense_grads)?;
        Ok(stats)
    }

    /// One training step on a text batch.
    pub fn step_text(&mut self, batch: &TextBatch) -> Result<StepStats> {
        let b = self.batch_size();
        if batch.batch_size != b {
            bail!("batch size {} != model batch {b}", batch.batch_size);
        }
        let seq_len = match self.meta {
            ModelMeta::Nlu { seq_len, .. } => seq_len,
            _ => bail!("step_text on a non-NLU model"),
        };
        let mut inputs = self.store.tensors();
        inputs.extend(batch.to_tensors());
        let (c1, c2) = self.clip_inputs();
        inputs.push(c1);
        inputs.push(c2);
        let outs = self.rt.execute(&self.grads_artifact, &inputs)?;
        let plan = self.output_plan.clone();
        let mut loss = 0.0;
        let mut table_grads: Vec<RowSparseGrad> = Vec::new();
        let mut counts: Option<&HostTensor> = None;
        let mut dense_grads: Vec<(usize, &HostTensor)> = Vec::new();
        for (kind, out) in plan.iter().zip(&outs) {
            match kind {
                OutputKind::Loss => loss = out.scalar()?,
                OutputKind::DenseGrad(pi) => dense_grads.push((*pi, out)),
                OutputKind::EmbGrads => {
                    let zg = out.as_f32()?;
                    let t = &self.emb_tables[0];
                    let mut g = RowSparseGrad::with_capacity(t.vocab, t.dim, b * seq_len);
                    for i in 0..b {
                        for p in 0..seq_len {
                            let row = batch.token(i, p) as u32;
                            let s = (i * seq_len + p) * t.dim;
                            g.add_row(row, &zg[s..s + t.dim]);
                        }
                    }
                    table_grads = vec![g];
                }
                OutputKind::Counts => counts = Some(out),
                OutputKind::Scales => {}
            }
        }
        let counts = counts.context("grads artifact returned no counts")?;
        self.apply_update(loss, table_grads, counts, dense_grads)
    }

    /// Shared post-gradient logic: survivor selection, noise, updates.
    fn apply_update(
        &mut self,
        loss: f64,
        mut table_grads: Vec<RowSparseGrad>,
        counts: &HostTensor,
        dense_grads: Vec<(usize, &HostTensor)>,
    ) -> Result<StepStats> {
        let b = self.batch_size() as f32;
        let algo = self.cfg.algorithm;
        let noise2 = self.sigma2 * self.cfg.c2; // gradient noise stddev
        let present_rows: usize = table_grads.iter().map(|g| g.nnz_rows()).sum();

        // ---- survivor selection (embedding row set to noise & update) ----
        let mut survivors_len = 0usize;
        let survivor_set: Option<SurvivorSet> = match algo {
            Algorithm::NonPrivate | Algorithm::DpSgd => None,
            Algorithm::ExpSelection => {
                // [ZMH21]: exponential mechanism over row gradient norms.
                let mut utilities: Vec<(u32, f64)> = Vec::with_capacity(present_rows);
                for (t, g) in self.emb_tables.iter().zip(&table_grads) {
                    for (row, vals) in g.iter_rows() {
                        let norm = vals.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
                        utilities.push(((t.row_offset + row as usize) as u32, norm));
                    }
                }
                let ids = exponential_select(
                    &utilities,
                    self.cfg.exp_select_m,
                    self.cfg.epsilon / self.cfg.steps as f64, // per-step selection budget
                    self.cfg.c2,
                    &mut self.rng,
                );
                Some(SurvivorSet::from_sorted(ids))
            }
            Algorithm::DpFest => Some(
                self.fest_selected
                    .clone()
                    .context("DP-FEST requires fest_select() before training")?,
            ),
            Algorithm::DpAdaFest | Algorithm::DpAdaFestPlus => {
                let map = ContributionMap::from_dense(counts.as_f32()?);
                let (surv, _stats) = map.survivors(
                    self.sigma1,
                    self.cfg.c1,
                    self.cfg.tau,
                    self.cfg.memory_efficient_filtering,
                    &mut self.rng,
                );
                if algo == Algorithm::DpAdaFestPlus {
                    let fest = self
                        .fest_selected
                        .as_ref()
                        .context("DP-AdaFEST+ requires fest_select() before training")?;
                    Some(surv.intersect(fest))
                } else {
                    Some(surv)
                }
            }
        };

        // ---- embedding updates ----
        let mut emb_coords = 0usize;
        if self.cfg.freeze_embedding {
            // Table 6 baseline: embeddings untouched — drop the grads.
            table_grads.clear();
        }
        match algo {
            _ if self.cfg.freeze_embedding => {}
            Algorithm::DpSgd => {
                // dense path: densify + dense noise + dense update
                for (t, g) in self.emb_tables.iter().zip(&table_grads) {
                    let mut dense = g.to_dense();
                    emb_coords += add_dense_noise(&mut dense, noise2, &mut self.rng);
                    for v in &mut dense {
                        *v /= b;
                    }
                    let p = &mut self.store.params[t.param_index];
                    self.opt
                        .dense_step(p.tensor.as_f32_mut()?, &dense, &mut p.opt_state);
                }
            }
            Algorithm::NonPrivate => {
                for (t, g) in self.emb_tables.iter().zip(&mut table_grads) {
                    g.scale(1.0 / b);
                    emb_coords += g.nnz_coords();
                    let p = &mut self.store.params[t.param_index];
                    self.opt
                        .sparse_step(p.tensor.as_f32_mut()?, g, &mut p.opt_state);
                }
            }
            _ => {
                // sparsity-preserving DP paths: restrict to survivors, make
                // sure *every* survivor row exists (noise lands on zero-grad
                // survivors too), then row noise + sparse update.
                let surv = survivor_set.as_ref().unwrap();
                survivors_len = surv.len();
                for (t, g) in self.emb_tables.iter().zip(&mut table_grads) {
                    let off = t.row_offset as u32;
                    let hi = (t.row_offset + t.vocab) as u32;
                    g.retain_rows(|row| surv.contains(off + row));
                    // add survivor rows missing from the gradient
                    let zero = vec![0f32; t.dim];
                    for &cid in surv.ids() {
                        if cid >= off && cid < hi {
                            let local = cid - off;
                            g.add_row_scaled(local, 0.0, &zero); // ensure presence
                        }
                    }
                    emb_coords += add_row_noise(g, noise2, &mut self.rng);
                    g.scale(1.0 / b);
                    let p = &mut self.store.params[t.param_index];
                    self.opt
                        .sparse_step(p.tensor.as_f32_mut()?, g, &mut p.opt_state);
                }
            }
        }

        // ---- dense (non-embedding) updates: standard DP-SGD ----
        let mut dense_coords = 0usize;
        for (pi, gt) in dense_grads {
            let mut gbuf = gt.as_f32()?.to_vec();
            if algo.is_private() {
                dense_coords += add_dense_noise(&mut gbuf, noise2, &mut self.rng);
            }
            for v in &mut gbuf {
                *v /= b;
            }
            let p = &mut self.store.params[pi];
            self.opt
                .dense_step(p.tensor.as_f32_mut()?, &gbuf, &mut p.opt_state);
        }

        self.meter.record_step(emb_coords, dense_coords);
        self.loss_history.push(loss);
        Ok(StepStats {
            loss,
            emb_coords_noised: emb_coords,
            dense_coords_noised: dense_coords,
            survivors: survivors_len,
            present_rows,
        })
    }

    /// Evaluate on pCTR batches: returns (AUC, mean loss).
    pub fn eval_pctr(&self, batches: &[PctrBatch]) -> Result<(f64, f64)> {
        let mut acc = metrics::EvalAccumulator::default();
        for batch in batches {
            let mut inputs = self.store.tensors();
            inputs.extend(batch.to_tensors());
            let outs = self.rt.execute(&self.fwd_artifact, &inputs)?;
            let loss = outs[0].scalar()?;
            let logits = outs[1].as_f32()?;
            acc.push(logits, &batch.y, loss);
        }
        Ok((acc.auc(), acc.mean_loss()))
    }

    /// Evaluate on text batches: returns (accuracy, mean loss).
    pub fn eval_text(&self, batches: &[TextBatch]) -> Result<(f64, f64)> {
        let num_classes = match self.meta {
            ModelMeta::Nlu { num_classes, .. } => num_classes,
            _ => bail!("eval_text on a non-NLU model"),
        };
        let mut correct_w = 0.0;
        let mut loss_sum = 0.0;
        let mut n = 0;
        for batch in batches {
            let mut inputs = self.store.tensors();
            inputs.extend(batch.to_tensors());
            let outs = self.rt.execute(&self.fwd_artifact, &inputs)?;
            loss_sum += outs[0].scalar()?;
            let logits = outs[1].as_f32()?;
            correct_w += metrics::accuracy_from_logits(logits, &batch.labels, num_classes)
                * batch.batch_size as f64;
            n += batch.batch_size;
        }
        Ok((correct_w / n as f64, loss_sum / batches.len() as f64))
    }

    /// Full non-streaming pCTR run: optional FEST selection from `prior`
    /// batches, `cfg.steps` training steps, then eval.
    pub fn run_pctr(&mut self, gen: &SynthCriteo) -> Result<TrainOutcome> {
        if self.cfg.algorithm.uses_fest_selection() && self.fest_selected.is_none() {
            let counts = pctr_frequency_counts(gen, &self.emb_tables, 50, self.cfg.seed);
            self.fest_select(&counts)?;
        }
        let mut rng = Xoshiro256::seed_from(self.cfg.seed ^ 0xBA7C4);
        for _ in 0..self.cfg.steps {
            let batch = gen.batch(0, self.batch_size(), &mut rng);
            self.step_pctr(&batch)?;
        }
        let eval: Vec<PctrBatch> = (0..self.cfg.eval_batches)
            .map(|_| gen.batch(0, self.batch_size(), &mut rng))
            .collect();
        let (auc, eval_loss) = self.eval_pctr(&eval)?;
        Ok(self.outcome(auc, eval_loss))
    }

    /// Full non-streaming text run.
    pub fn run_text(&mut self, gen: &crate::data::SynthText) -> Result<TrainOutcome> {
        if self.cfg.algorithm.uses_fest_selection() && self.fest_selected.is_none() {
            let counts = text_frequency_counts(gen, self.total_vocab, 50, self.cfg.seed);
            self.fest_select(&[counts])?;
        }
        let mut rng = Xoshiro256::seed_from(self.cfg.seed ^ 0xBA7C4);
        for _ in 0..self.cfg.steps {
            let batch = gen.batch(self.batch_size(), &mut rng);
            self.step_text(&batch)?;
        }
        let eval: Vec<TextBatch> = (0..self.cfg.eval_batches)
            .map(|_| gen.batch(self.batch_size(), &mut rng))
            .collect();
        let (acc, eval_loss) = self.eval_text(&eval)?;
        Ok(self.outcome(acc, eval_loss))
    }

    pub fn outcome(&self, utility: f64, eval_loss: f64) -> TrainOutcome {
        TrainOutcome {
            loss_history: self.loss_history.clone(),
            utility,
            eval_loss,
            emb_grad_coords_per_step: self.meter.emb_per_step(),
            reduction_factor: self.meter.reduction_factor(),
            sigma1: self.sigma1,
            sigma2: self.sigma2,
        }
    }
}

/// Sample `n_batches` from the generator to build per-feature frequency
/// counts (the paper's "public prior" / DP-top-k input).
pub fn pctr_frequency_counts(
    gen: &SynthCriteo,
    tables: &[EmbTable],
    n_batches: usize,
    seed: u64,
) -> Vec<Vec<f64>> {
    let mut rng = Xoshiro256::seed_from(seed ^ 0xF2E9);
    let mut counts: Vec<Vec<f64>> = tables.iter().map(|t| vec![0f64; t.vocab]).collect();
    for _ in 0..n_batches {
        let b = gen.batch(0, 256, &mut rng);
        for i in 0..b.batch_size {
            for (f, c) in counts.iter_mut().enumerate() {
                c[b.cat_of(i, f) as usize] += 1.0;
            }
        }
    }
    counts
}

/// Token frequency counts for NLU FEST selection.
pub fn text_frequency_counts(
    gen: &crate::data::SynthText,
    vocab: usize,
    n_batches: usize,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Xoshiro256::seed_from(seed ^ 0xF2E9);
    let mut counts = vec![0f64; vocab];
    for _ in 0..n_batches {
        let b = gen.batch(64, &mut rng);
        for &t in &b.ids {
            counts[t as usize] += 1.0;
        }
    }
    counts
}
