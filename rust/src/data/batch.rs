//! Fixed-shape mini-batches and their conversion to artifact input tensors.

use crate::runtime::HostTensor;

/// A Criteo-style batch: `cat[b*F + f]` categorical bucket ids (per-feature
/// local), `num[b*13 + j]` log-transformed numeric features, `y[b]` labels.
#[derive(Clone, Debug)]
pub struct PctrBatch {
    pub batch_size: usize,
    pub num_features: usize,
    pub num_numeric: usize,
    pub cat: Vec<i32>,
    pub num: Vec<f32>,
    pub y: Vec<f32>,
}

impl PctrBatch {
    pub fn cat_of(&self, example: usize, feature: usize) -> i32 {
        self.cat[example * self.num_features + feature]
    }

    /// The artifact's batch inputs, in manifest order (cat_idx, x_num, y).
    pub fn to_tensors(&self) -> Vec<HostTensor> {
        vec![
            HostTensor::i32(vec![self.batch_size, self.num_features], self.cat.clone()),
            HostTensor::f32(vec![self.batch_size, self.num_numeric], self.num.clone()),
            HostTensor::f32(vec![self.batch_size], self.y.clone()),
        ]
    }

    /// Per-example activated rows in the concatenated row space.
    pub fn activated_rows(&self, row_offsets: &[usize]) -> Vec<Vec<u32>> {
        (0..self.batch_size)
            .map(|i| {
                (0..self.num_features)
                    .map(|f| (row_offsets[f] + self.cat_of(i, f) as usize) as u32)
                    .collect()
            })
            .collect()
    }
}

/// A text-classification batch: `ids[b*T + t]` token ids, `labels[b]`.
#[derive(Clone, Debug)]
pub struct TextBatch {
    pub batch_size: usize,
    pub seq_len: usize,
    pub ids: Vec<i32>,
    pub labels: Vec<i32>,
}

impl TextBatch {
    pub fn token(&self, example: usize, pos: usize) -> i32 {
        self.ids[example * self.seq_len + pos]
    }

    pub fn to_tensors(&self) -> Vec<HostTensor> {
        vec![
            HostTensor::i32(vec![self.batch_size, self.seq_len], self.ids.clone()),
            HostTensor::i32(vec![self.batch_size], self.labels.clone()),
        ]
    }

    /// Per-example activated vocabulary rows (token ids; duplicates kept —
    /// the contribution map dedups per example).
    pub fn activated_rows(&self) -> Vec<Vec<u32>> {
        (0..self.batch_size)
            .map(|i| {
                (0..self.seq_len)
                    .map(|t| self.token(i, t) as u32)
                    .collect()
            })
            .collect()
    }
}

/// Either workload's owned batch — the kind-generic currency of the async
/// engine's channels (data workers → aggregation loop → gradient workers).
#[derive(Clone, Debug)]
pub enum Batch {
    Pctr(PctrBatch),
    Text(TextBatch),
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        match self {
            Batch::Pctr(b) => b.batch_size,
            Batch::Text(b) => b.batch_size,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pctr_tensor_shapes() {
        let b = PctrBatch {
            batch_size: 2,
            num_features: 3,
            num_numeric: 13,
            cat: vec![0, 1, 2, 3, 4, 5],
            num: vec![0.0; 26],
            y: vec![1.0, 0.0],
        };
        let ts = b.to_tensors();
        assert_eq!(ts[0].dims(), &[2, 3]);
        assert_eq!(ts[1].dims(), &[2, 13]);
        assert_eq!(ts[2].dims(), &[2]);
        assert_eq!(b.cat_of(1, 0), 3);
    }

    #[test]
    fn activated_rows_offsets() {
        let b = PctrBatch {
            batch_size: 1,
            num_features: 2,
            num_numeric: 13,
            cat: vec![1, 0],
            num: vec![0.0; 13],
            y: vec![0.0],
        };
        let rows = b.activated_rows(&[0, 10]);
        assert_eq!(rows, vec![vec![1u32, 10u32]]);
    }
}
