//! `SynthCriteo` — synthetic Criteo-pCTR stand-in (DESIGN.md §2).
//!
//! Matches the properties the paper's algorithms act on:
//!
//! * **Vocabulary sizes** — exactly Table 3 (`criteo-full`) or a scaled
//!   config (`criteo-small`); per-feature embedding dims follow the paper's
//!   `int(2·V^0.25)` rule upstream.
//! * **Frequency skew** — bucket activations are Zipf(α_f) with per-feature
//!   exponents in [0.9, 1.5]; a per-feature permutation decouples bucket id
//!   from rank (frequent buckets are arbitrary ids, as in hashed real data).
//! * **Labels** — sparse logistic teacher over bucket/numeric weights, so
//!   models can genuinely learn (AUC well above 0.5) and per-bucket
//!   information content correlates with frequency the way §3's intuition
//!   assumes.
//! * **Time-series drift** (§4.3) — day `d` re-ranks a drifting fraction of
//!   buckets and perturbs the teacher, reproducing the non-stationarity that
//!   separates streaming/first-day/all-days frequency sources (Fig. 5) and
//!   makes DP training degrade with longer staleness (Table 5).

use std::cell::RefCell;

use crate::util::rng::Xoshiro256;

use super::batch::PctrBatch;
use super::zipf::ZipfSampler;

/// The 24-day Criteo-1TB split the paper uses: first 18 days train,
/// days 19–24 evaluate.
pub const TRAIN_DAYS: usize = 18;
pub const EVAL_DAYS: std::ops::Range<usize> = 18..24;

#[derive(Clone, Debug)]
pub struct CriteoConfig {
    pub vocabs: Vec<usize>,
    pub num_numeric: usize,
    pub seed: u64,
    /// enable per-day drift (time-series mode)
    pub drift: bool,
    /// fraction of bucket ranks re-permuted per day
    pub drift_swap_frac: f64,
    /// teacher weight perturbation per day
    pub drift_teacher: f64,
}

impl CriteoConfig {
    pub fn new(vocabs: Vec<usize>, seed: u64) -> Self {
        CriteoConfig {
            vocabs,
            num_numeric: 13,
            seed,
            drift: false,
            drift_swap_frac: 0.02,
            drift_teacher: 0.03,
        }
    }

    pub fn with_drift(mut self) -> Self {
        self.drift = true;
        self
    }
}

struct DayState {
    day: usize,
    /// rank → bucket-id permutation per feature
    perms: Vec<Vec<u32>>,
    /// teacher bucket weights per feature (indexed by bucket id)
    weights: Vec<Vec<f32>>,
}

pub struct SynthCriteo {
    pub cfg: CriteoConfig,
    samplers: Vec<ZipfSampler>,
    alphas: Vec<f64>,
    num_weights: Vec<f32>,
    bias: f32,
    /// Cached state for the most recent day.  A `RefCell` (not a lock):
    /// every consumer owns its generator — the sync trainers use one per
    /// run, and each async data worker builds its own from the shared
    /// [`CriteoConfig`].  Workers claim step indices in increasing order,
    /// so per-worker day access is monotone and the cache almost always
    /// hits even though the engine generates the day stream out of order
    /// across workers.
    day_state: RefCell<Option<DayState>>,
}

impl SynthCriteo {
    pub fn new(cfg: CriteoConfig) -> Self {
        let mut rng = Xoshiro256::seed_from(cfg.seed);
        let alphas: Vec<f64> = (0..cfg.vocabs.len())
            .map(|f| 0.9 + 0.6 * ((f * 7 + 3) % 10) as f64 / 10.0)
            .collect();
        let samplers = cfg
            .vocabs
            .iter()
            .zip(&alphas)
            .map(|(&v, &a)| ZipfSampler::new(v, a))
            .collect();
        let num_weights = (0..cfg.num_numeric)
            .map(|_| rng.gauss() as f32 * 0.3)
            .collect();
        SynthCriteo {
            cfg,
            samplers,
            alphas,
            num_weights,
            bias: -0.6, // skew towards negatives like real CTR data
            day_state: RefCell::new(None),
        }
    }

    pub fn num_features(&self) -> usize {
        self.cfg.vocabs.len()
    }

    pub fn zipf_alpha(&self, feature: usize) -> f64 {
        self.alphas[feature]
    }

    fn build_day_state(&self, day: usize) -> DayState {
        let mut perms = Vec::with_capacity(self.num_features());
        let mut weights = Vec::with_capacity(self.num_features());
        for (f, &v) in self.cfg.vocabs.iter().enumerate() {
            // base permutation, deterministic per feature
            let mut rng = Xoshiro256::seed_from(
                self.cfg.seed ^ (f as u64).wrapping_mul(0x9E3779B97F4A7C15),
            );
            let mut perm: Vec<u32> = (0..v as u32).collect();
            rng.shuffle(&mut perm);
            // teacher weights per bucket: informative mass concentrated on
            // frequent ranks (information correlates with frequency — the
            // paper's core intuition in §3)
            let mut w = vec![0f32; v];
            for (rank, &bucket) in perm.iter().enumerate() {
                let scale = 1.0 / (1.0 + rank as f32).sqrt();
                w[bucket as usize] = rng.gauss() as f32 * 0.55 * scale;
            }
            if self.cfg.drift {
                // cumulative per-day drift: swap a fraction of ranks and
                // perturb weights, once per elapsed day
                for d in 1..=day {
                    let mut drng = Xoshiro256::seed_from(
                        self.cfg.seed ^ 0xD1F7 ^ ((f * 131 + d) as u64),
                    );
                    let swaps = ((v as f64) * self.cfg.drift_swap_frac).ceil() as usize;
                    for _ in 0..swaps {
                        let a = drng.below(v as u64) as usize;
                        let b = drng.below(v as u64) as usize;
                        perm.swap(a, b);
                    }
                    for wv in w.iter_mut() {
                        *wv += drng.gauss() as f32 * self.cfg.drift_teacher as f32 * 0.1;
                    }
                }
            }
            perms.push(perm);
            weights.push(w);
        }
        DayState { day, perms, weights }
    }

    fn with_day_state<R>(&self, day: usize, f: impl FnOnce(&DayState) -> R) -> R {
        let day = if self.cfg.drift { day } else { 0 };
        {
            let cached = self.day_state.borrow();
            if let Some(st) = cached.as_ref() {
                if st.day == day {
                    return f(st);
                }
            }
        }
        let st = self.build_day_state(day);
        let out = f(&st);
        *self.day_state.borrow_mut() = Some(st);
        out
    }

    /// Generate one batch for `day` (ignored unless drift is enabled).
    pub fn batch(&self, day: usize, batch_size: usize, rng: &mut Xoshiro256) -> PctrBatch {
        let nf = self.num_features();
        let nn = self.cfg.num_numeric;
        self.with_day_state(day, |st| {
            let mut cat = Vec::with_capacity(batch_size * nf);
            let mut num = Vec::with_capacity(batch_size * nn);
            let mut y = Vec::with_capacity(batch_size);
            for _ in 0..batch_size {
                let mut logit = self.bias;
                for f in 0..nf {
                    let rank = self.samplers[f].sample(rng);
                    let bucket = st.perms[f][rank];
                    cat.push(bucket as i32);
                    logit += st.weights[f][bucket as usize];
                }
                for j in 0..nn {
                    // log-transformed integer features ≈ N(0,1)
                    let x = rng.gauss() as f32;
                    num.push(x);
                    logit += self.num_weights[j] * x * 0.3;
                }
                let p = 1.0 / (1.0 + (-logit as f64).exp());
                y.push(if rng.uniform() < p { 1.0 } else { 0.0 });
            }
            PctrBatch {
                batch_size,
                num_features: nf,
                num_numeric: nn,
                cat,
                num,
                y,
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SynthCriteo {
        SynthCriteo::new(CriteoConfig::new(vec![50, 20, 8], 7))
    }

    #[test]
    fn batch_shapes_and_ranges() {
        let g = tiny();
        let mut rng = Xoshiro256::seed_from(1);
        let b = g.batch(0, 64, &mut rng);
        assert_eq!(b.cat.len(), 64 * 3);
        assert_eq!(b.num.len(), 64 * 13);
        assert_eq!(b.y.len(), 64);
        for i in 0..64 {
            for (f, &v) in [50i32, 20, 8].iter().enumerate() {
                let c = b.cat_of(i, f);
                assert!(c >= 0 && c < v, "feature {f} bucket {c}");
            }
        }
        assert!(b.y.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn labels_are_learnable_not_constant() {
        let g = tiny();
        let mut rng = Xoshiro256::seed_from(2);
        let b = g.batch(0, 2000, &mut rng);
        let pos: f64 = b.y.iter().map(|&v| v as f64).sum::<f64>() / 2000.0;
        assert!(pos > 0.1 && pos < 0.9, "degenerate positive rate {pos}");
    }

    #[test]
    fn frequency_skew_present() {
        // the most frequent bucket of feature 0 should dominate uniform rate
        let g = tiny();
        let mut rng = Xoshiro256::seed_from(3);
        let b = g.batch(0, 5000, &mut rng);
        let mut counts = vec![0u32; 50];
        for i in 0..5000 {
            counts[b.cat_of(i, 0) as usize] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64 / 5000.0;
        assert!(max > 0.1, "no skew: top bucket rate {max}"); // uniform would be 0.02
    }

    #[test]
    fn no_drift_means_stationary() {
        let g = tiny();
        let mut r1 = Xoshiro256::seed_from(4);
        let mut r2 = Xoshiro256::seed_from(4);
        let b0 = g.batch(0, 32, &mut r1);
        let b9 = g.batch(9, 32, &mut r2);
        assert_eq!(b0.cat, b9.cat); // same rng, same distribution
    }

    #[test]
    fn drift_changes_distribution_gradually() {
        let g = SynthCriteo::new(CriteoConfig::new(vec![500], 5).with_drift());
        // estimate top-bucket sets across days; day 1 should overlap day 0
        // strongly, day 20 much less
        let top = |day: usize| -> Vec<u32> {
            let mut rng = Xoshiro256::seed_from(100);
            let b = g.batch(day, 4000, &mut rng);
            let mut counts = vec![0u32; 500];
            for i in 0..4000 {
                counts[b.cat_of(i, 0) as usize] += 1;
            }
            let mut ids: Vec<u32> = (0..500).collect();
            ids.sort_by_key(|&i| std::cmp::Reverse(counts[i as usize]));
            ids.truncate(20);
            ids.sort();
            ids
        };
        let t0 = top(0);
        let t1 = top(1);
        let t20 = top(20);
        let overlap = |a: &[u32], b: &[u32]| {
            a.iter().filter(|x| b.contains(x)).count()
        };
        let o1 = overlap(&t0, &t1);
        let o20 = overlap(&t0, &t20);
        assert!(o1 >= 15, "day-1 overlap too small: {o1}/20");
        assert!(o20 < o1, "drift not cumulative: day20 {o20} vs day1 {o1}");
    }
}
