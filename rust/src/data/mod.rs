//! Synthetic data substrates standing in for the paper's datasets
//! (DESIGN.md §2 documents each substitution):
//!
//! * [`criteo`] — `SynthCriteo`: 26 categorical features with the exact
//!   Table-3 vocabulary sizes (or any scaled config), Zipf-distributed
//!   bucket activations behind per-feature permutations, 13 numeric
//!   features, labels from a sparse logistic teacher; a time-series mode
//!   adds per-day distribution drift (Criteo-1TB stand-in, §4.3).
//! * [`text`] — `SynthText`: Zipf token streams over a real-size vocabulary
//!   (50,265 RoBERTa / 250,002 XLM-R) with a bag-of-tokens teacher
//!   (SST-2/QNLI/QQP/XNLI stand-ins).
//! * [`zipf`] — the shared Zipf(α) sampler.
//!
//! [`GenConfig`] / [`Generator`] wrap both substrates behind one interface
//! so kind-generic callers (the async engine's data workers) can be handed
//! either workload.

mod batch;
mod criteo;
mod text;
mod zipf;

pub use batch::{Batch, PctrBatch, TextBatch};
pub use criteo::{CriteoConfig, SynthCriteo, EVAL_DAYS, TRAIN_DAYS};
pub use text::{SynthText, TextConfig};
pub use zipf::ZipfSampler;

use crate::util::rng::Xoshiro256;

/// Data-source configuration for either workload — cloneable across the
/// engine's data-worker threads (each worker builds its own generator).
#[derive(Clone, Debug)]
pub enum GenConfig {
    Pctr(CriteoConfig),
    Text(TextConfig),
}

/// A constructed generator for either workload.
pub enum Generator {
    Pctr(SynthCriteo),
    Text(SynthText),
}

impl Generator {
    pub fn new(cfg: GenConfig) -> Generator {
        match cfg {
            GenConfig::Pctr(c) => Generator::Pctr(SynthCriteo::new(c)),
            GenConfig::Text(c) => Generator::Text(SynthText::new(c)),
        }
    }

    /// One batch from the wrapped generator.  `day` selects the simulated
    /// day of the pCTR substrate (meaningful when the config enables drift —
    /// the engine's streaming mode); the text substrate is stationary and
    /// ignores it.
    pub fn batch(&self, day: usize, batch_size: usize, rng: &mut Xoshiro256) -> Batch {
        match self {
            Generator::Pctr(g) => Batch::Pctr(g.batch(day, batch_size, rng)),
            Generator::Text(g) => Batch::Text(g.batch(batch_size, rng)),
        }
    }
}
