//! Synthetic data substrates standing in for the paper's datasets
//! (DESIGN.md §2 documents each substitution):
//!
//! * [`criteo`] — `SynthCriteo`: 26 categorical features with the exact
//!   Table-3 vocabulary sizes (or any scaled config), Zipf-distributed
//!   bucket activations behind per-feature permutations, 13 numeric
//!   features, labels from a sparse logistic teacher; a time-series mode
//!   adds per-day distribution drift (Criteo-1TB stand-in, §4.3).
//! * [`text`] — `SynthText`: Zipf token streams over a real-size vocabulary
//!   (50,265 RoBERTa / 250,002 XLM-R) with a bag-of-tokens teacher
//!   (SST-2/QNLI/QQP/XNLI stand-ins).
//! * [`zipf`] — the shared Zipf(α) sampler.

mod batch;
mod criteo;
mod text;
mod zipf;

pub use batch::{PctrBatch, TextBatch};
pub use criteo::{CriteoConfig, SynthCriteo, EVAL_DAYS, TRAIN_DAYS};
pub use text::{SynthText, TextConfig};
pub use zipf::ZipfSampler;
