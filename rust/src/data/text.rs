//! `SynthText` — synthetic GLUE-task stand-in (SST-2 / QNLI / QQP / XNLI).
//!
//! Token streams are Zipf(1.07) over a *real-size* vocabulary (50,265 for
//! the RoBERTa tokenizer, 250,002 for XLM-R — the quantity Table 2 varies),
//! and labels come from a bag-of-tokens teacher: a sparse set of
//! class-informative tokens shifts the class logits.  What matters for the
//! paper's claims is (a) vocabulary size, (b) Zipf token frequencies, and
//! (c) that labels are learnable from token identity — all matched here.

use crate::util::rng::Xoshiro256;

use super::batch::TextBatch;
use super::zipf::ZipfSampler;

#[derive(Clone, Debug)]
pub struct TextConfig {
    pub vocab: usize,
    pub seq_len: usize,
    pub num_classes: usize,
    pub seed: u64,
    /// number of class-informative tokens (drawn from the frequent range)
    pub informative: usize,
}

impl TextConfig {
    pub fn new(vocab: usize, seq_len: usize, num_classes: usize, seed: u64) -> Self {
        TextConfig { vocab, seq_len, num_classes, seed, informative: 512 }
    }

    /// Build from an NLU model's manifest attrs — the one place the
    /// (vocab, seq_len, num_classes) triple is read, shared by the CLI,
    /// the harnesses, and the async engine.
    pub fn from_model(
        model: &crate::runtime::ModelManifest,
        seed: u64,
    ) -> anyhow::Result<TextConfig> {
        Ok(TextConfig::new(
            model.attr_usize("vocab")?,
            model.attr_usize("seq_len")?,
            model.attr_usize("num_classes")?,
            seed,
        ))
    }
}

pub struct SynthText {
    pub cfg: TextConfig,
    sampler: ZipfSampler,
    /// rank → token-id permutation (frequent tokens are arbitrary ids)
    perm: Vec<u32>,
    /// (token, class, weight) sparse teacher
    token_class_w: Vec<(u32, usize, f32)>,
}

impl SynthText {
    pub fn new(cfg: TextConfig) -> Self {
        let mut rng = Xoshiro256::seed_from(cfg.seed);
        let sampler = ZipfSampler::new(cfg.vocab, 1.07);
        let mut perm: Vec<u32> = (0..cfg.vocab as u32).collect();
        rng.shuffle(&mut perm);
        // informative tokens live among the top ~4·informative ranks so they
        // actually occur.  Classes are assigned round-robin over the
        // *rank-sorted* informative set so every class has the same token
        // frequency profile — otherwise whichever class lands the most
        // frequent tokens dominates the labels.
        let mut ranks: Vec<usize> = (0..cfg.informative)
            .map(|_| rng.below((cfg.informative * 4).min(cfg.vocab) as u64) as usize)
            .collect();
        ranks.sort_unstable();
        ranks.dedup();
        let mut token_class_w = Vec::with_capacity(ranks.len());
        for (i, &rank) in ranks.iter().enumerate() {
            let token = perm[rank];
            let class = i % cfg.num_classes;
            let w = 1.5 + 1.5 * rng.uniform() as f32;
            token_class_w.push((token, class, w));
        }
        SynthText { cfg, sampler, perm, token_class_w }
    }

    pub fn batch(&self, batch_size: usize, rng: &mut Xoshiro256) -> TextBatch {
        let t = self.cfg.seq_len;
        let mut ids = Vec::with_capacity(batch_size * t);
        let mut labels = Vec::with_capacity(batch_size);
        for _ in 0..batch_size {
            let start = ids.len();
            for _ in 0..t {
                let rank = self.sampler.sample(rng);
                ids.push(self.perm[rank] as i32);
            }
            let mut logits = vec![0f32; self.cfg.num_classes];
            for &(token, class, w) in &self.token_class_w {
                let occ = ids[start..]
                    .iter()
                    .filter(|&&x| x as u32 == token)
                    .count();
                logits[class] += w * occ as f32;
            }
            // Gumbel-softmax label draw: teacher signal + irreducible noise
            let label = logits
                .iter()
                .enumerate()
                .map(|(c, &l)| (l as f64 + rng.gumbel(0.5), c))
                .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
                .unwrap()
                .1;
            labels.push(label as i32);
        }
        TextBatch { batch_size, seq_len: t, ids, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_ranges() {
        let g = SynthText::new(TextConfig::new(1000, 16, 2, 1));
        let mut rng = Xoshiro256::seed_from(1);
        let b = g.batch(32, &mut rng);
        assert_eq!(b.ids.len(), 32 * 16);
        assert_eq!(b.labels.len(), 32);
        assert!(b.ids.iter().all(|&t| t >= 0 && (t as usize) < 1000));
        assert!(b.labels.iter().all(|&l| l == 0 || l == 1));
    }

    #[test]
    fn both_classes_present() {
        let g = SynthText::new(TextConfig::new(5000, 32, 2, 2));
        let mut rng = Xoshiro256::seed_from(2);
        let b = g.batch(500, &mut rng);
        let ones = b.labels.iter().filter(|&&l| l == 1).count();
        assert!(ones > 50 && ones < 450, "degenerate class balance: {ones}/500");
    }

    #[test]
    fn tokens_are_zipf_skewed() {
        let g = SynthText::new(TextConfig::new(10_000, 32, 2, 3));
        let mut rng = Xoshiro256::seed_from(3);
        let b = g.batch(500, &mut rng);
        let mut counts = std::collections::HashMap::new();
        for &t in &b.ids {
            *counts.entry(t).or_insert(0u32) += 1;
        }
        let distinct = counts.len();
        // 16000 zipf draws over 10k vocab must reuse tokens heavily
        assert!(distinct < 6_000, "no skew: {distinct} distinct tokens");
        let max = *counts.values().max().unwrap();
        assert!(max > 50, "top token too rare: {max}");
    }

    #[test]
    fn labels_depend_on_tokens() {
        // shuffling tokens while keeping labels must break the association:
        // check the teacher actually uses the tokens by verifying that
        // examples containing a strong class-0 token skew to label 0.
        let g = SynthText::new(TextConfig::new(2000, 32, 2, 4));
        let (tok, cls, _) = *g
            .token_class_w
            .iter()
            .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap();
        let mut rng = Xoshiro256::seed_from(5);
        let (mut with, mut with_match) = (0, 0);
        for _ in 0..200 {
            let b = g.batch(64, &mut rng);
            for i in 0..64 {
                let has = (0..32).any(|t| b.token(i, t) as u32 == tok);
                if has {
                    with += 1;
                    if b.labels[i] as usize == cls {
                        with_match += 1;
                    }
                }
            }
        }
        if with > 30 {
            let rate = with_match as f64 / with as f64;
            assert!(rate > 0.55, "informative token ignored: {rate}");
        }
    }
}
