//! Zipf(α) sampler over `{0, .., n-1}`: `P(rank r) ∝ (r+1)^(−α)`.
//!
//! Bucket frequencies in ads/categorical data and token frequencies in text
//! are canonically Zipf-like — this skew is exactly what frequency filtering
//! (DP-FEST) and contribution thresholding (DP-AdaFEST) exploit, so the
//! synthetic generators must reproduce it.  Sampling is inverse-CDF with
//! binary search on a precomputed cumulative table (O(log n) per draw).

use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 0..n {
            acc += ((r + 1) as f64).powf(-alpha);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        ZipfSampler { cdf }
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// Sample a rank (0 = most frequent).
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.uniform();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// P(rank r).
    pub fn pmf(&self, r: usize) -> f64 {
        if r == 0 {
            self.cdf[0]
        } else {
            self.cdf[r] - self.cdf[r - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmf_sums_to_one_and_decreases() {
        let z = ZipfSampler::new(100, 1.2);
        let total: f64 = (0..100).map(|r| z.pmf(r)).sum();
        assert!((total - 1.0).abs() < 1e-12);
        for r in 1..100 {
            assert!(z.pmf(r) <= z.pmf(r - 1) + 1e-15);
        }
    }

    #[test]
    fn empirical_matches_pmf() {
        let z = ZipfSampler::new(50, 1.0);
        let mut rng = Xoshiro256::seed_from(1);
        let n = 200_000;
        let mut counts = vec![0u64; 50];
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        for r in [0usize, 1, 5, 20] {
            let emp = counts[r] as f64 / n as f64;
            let want = z.pmf(r);
            let sd = (want * (1.0 - want) / n as f64).sqrt();
            assert!(
                (emp - want).abs() < 6.0 * sd + 1e-4,
                "rank {r}: emp {emp} want {want}"
            );
        }
    }

    #[test]
    fn alpha_zero_is_uniform() {
        let z = ZipfSampler::new(10, 0.0);
        for r in 0..10 {
            assert!((z.pmf(r) - 0.1).abs() < 1e-12);
        }
    }

    #[test]
    fn single_bucket() {
        let z = ZipfSampler::new(1, 2.0);
        let mut rng = Xoshiro256::seed_from(2);
        assert_eq!(z.sample(&mut rng), 0);
    }
}
