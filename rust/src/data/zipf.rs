//! Zipf(α) sampler over `{0, .., n-1}`: `P(rank r) ∝ (r+1)^(−α)`.
//!
//! Bucket frequencies in ads/categorical data and token frequencies in text
//! are canonically Zipf-like — this skew is exactly what frequency filtering
//! (DP-FEST) and contribution thresholding (DP-AdaFEST) exploit, so the
//! synthetic generators must reproduce it.
//!
//! For `n` up to [`HEAD_RANKS`] sampling is inverse-CDF with binary search
//! on a precomputed cumulative table (O(log n) per draw) — the historical
//! behaviour, bit-identical draw for draw.  Beyond that (the `fullscale`
//! harness runs hundred-million-row vocabularies, where a dense f64 CDF
//! alone would be ~800 MB) the table covers only the top [`HEAD_RANKS`]
//! ranks, which hold nearly all the mass at the α ≈ 1 skews we model, and
//! the tail is drawn from the continuous density `x^(−α)` by inverting its
//! closed-form integral `x^(1−α)/(1−α)` (`ln x` at α = 1).  The tail rank
//! probabilities are then `∫_{k}^{k+1} x^(−α) dx` rather than exactly
//! `k^(−α)` — an approximation confined to ranks past the head, fine for
//! throughput workloads and reflected consistently by [`ZipfSampler::pmf`].

use crate::util::rng::Xoshiro256;

/// Ranks covered by the exact cumulative table; `n` at or below this bound
/// reproduces the historical all-exact sampler draw for draw.
pub const HEAD_RANKS: usize = 1 << 20;

#[derive(Clone, Debug)]
pub struct ZipfSampler {
    n: usize,
    alpha: f64,
    /// Cumulative mass of the head ranks, normalised by head + tail mass;
    /// covers all of `{0, .., n-1}` when `n <= HEAD_RANKS`.
    cdf: Vec<f64>,
    /// Total unnormalised mass (head sum + tail integral).
    total: f64,
}

impl ZipfSampler {
    pub fn new(n: usize, alpha: f64) -> Self {
        Self::with_head(n, alpha, HEAD_RANKS)
    }

    /// As [`ZipfSampler::new`] with an explicit head size — lets tests
    /// exercise the integral tail without building a million-entry table.
    fn with_head(n: usize, alpha: f64, head: usize) -> Self {
        assert!(n > 0);
        let head_len = n.min(head.max(1));
        let mut cdf = Vec::with_capacity(head_len);
        let mut acc = 0.0;
        for r in 0..head_len {
            acc += ((r + 1) as f64).powf(-alpha);
            cdf.push(acc);
        }
        // tail ranks r ∈ [head_len, n), i.e. 1-based k ∈ [head_len+1, n],
        // approximated by the continuous density on x ∈ [head_len+1, n+1)
        let tail = if n > head_len {
            primitive(alpha, (n + 1) as f64) - primitive(alpha, (head_len + 1) as f64)
        } else {
            0.0
        };
        let total = acc + tail;
        for v in &mut cdf {
            *v /= total;
        }
        ZipfSampler { n, alpha, cdf, total }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Sample a rank (0 = most frequent).
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.uniform();
        let head_mass = *self.cdf.last().unwrap();
        if u <= head_mass || self.cdf.len() == self.n {
            return match self
                .cdf
                .binary_search_by(|p| p.partial_cmp(&u).unwrap())
            {
                Ok(i) => i,
                Err(i) => i.min(self.cdf.len() - 1),
            };
        }
        // invert the tail integral: x with F(x) = F(a) + v·(F(b) − F(a))
        let v = (u - head_mass) / (1.0 - head_mass);
        let a = (self.cdf.len() + 1) as f64;
        let b = (self.n + 1) as f64;
        let x = if (self.alpha - 1.0).abs() < 1e-9 {
            a * (b / a).powf(v)
        } else {
            let e = 1.0 - self.alpha;
            (a.powf(e) + v * (b.powf(e) - a.powf(e))).powf(1.0 / e)
        };
        // x ∈ [a, b) maps to 1-based rank k = floor(x); clamp guards the
        // open upper end against floating-point overshoot
        (x.floor() as usize).clamp(self.cdf.len() + 1, self.n) - 1
    }

    /// P(rank r).  Exact within the head table; integral-approximated for
    /// ranks past it (consistent with how [`ZipfSampler::sample`] draws
    /// them, so empirical frequencies match this function everywhere).
    pub fn pmf(&self, r: usize) -> f64 {
        assert!(r < self.n);
        if r == 0 {
            self.cdf[0]
        } else if r < self.cdf.len() {
            self.cdf[r] - self.cdf[r - 1]
        } else {
            let k = (r + 1) as f64;
            (primitive(self.alpha, k + 1.0) - primitive(self.alpha, k)) / self.total
        }
    }
}

/// Antiderivative of `x^(−α)` (increasing for any α since the density is
/// positive): `x^(1−α)/(1−α)`, or `ln x` at α = 1.
fn primitive(alpha: f64, x: f64) -> f64 {
    if (alpha - 1.0).abs() < 1e-9 {
        x.ln()
    } else {
        let e = 1.0 - alpha;
        x.powf(e) / e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmf_sums_to_one_and_decreases() {
        let z = ZipfSampler::new(100, 1.2);
        let total: f64 = (0..100).map(|r| z.pmf(r)).sum();
        assert!((total - 1.0).abs() < 1e-12);
        for r in 1..100 {
            assert!(z.pmf(r) <= z.pmf(r - 1) + 1e-15);
        }
    }

    #[test]
    fn empirical_matches_pmf() {
        let z = ZipfSampler::new(50, 1.0);
        let mut rng = Xoshiro256::seed_from(1);
        let n = 200_000;
        let mut counts = vec![0u64; 50];
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        for r in [0usize, 1, 5, 20] {
            let emp = counts[r] as f64 / n as f64;
            let want = z.pmf(r);
            let sd = (want * (1.0 - want) / n as f64).sqrt();
            assert!(
                (emp - want).abs() < 6.0 * sd + 1e-4,
                "rank {r}: emp {emp} want {want}"
            );
        }
    }

    #[test]
    fn alpha_zero_is_uniform() {
        let z = ZipfSampler::new(10, 0.0);
        for r in 0..10 {
            assert!((z.pmf(r) - 0.1).abs() < 1e-12);
        }
    }

    #[test]
    fn single_bucket() {
        let z = ZipfSampler::new(1, 2.0);
        let mut rng = Xoshiro256::seed_from(2);
        assert_eq!(z.sample(&mut rng), 0);
    }

    #[test]
    fn tail_pmf_sums_to_one_and_decreases() {
        // small head forces the integral-tail path for most ranks
        for alpha in [0.0, 0.8, 1.0, 1.1, 2.0] {
            let z = ZipfSampler::with_head(1000, alpha, 50);
            let total: f64 = (0..1000).map(|r| z.pmf(r)).sum();
            assert!((total - 1.0).abs() < 1e-9, "alpha {alpha}: total {total}");
            for r in 1..1000 {
                assert!(
                    z.pmf(r) <= z.pmf(r - 1) + 1e-12,
                    "alpha {alpha}: pmf increased at rank {r}"
                );
            }
        }
    }

    #[test]
    fn tail_samples_stay_in_range_and_match_pmf() {
        let z = ZipfSampler::with_head(1000, 1.1, 50);
        let mut rng = Xoshiro256::seed_from(3);
        let n = 400_000;
        let mut counts = vec![0u64; 1000];
        for _ in 0..n {
            let r = z.sample(&mut rng);
            assert!(r < 1000);
            counts[r] += 1;
        }
        // head rank, boundary tail rank, and a deep-tail band all track pmf
        for r in [0usize, 10, 49, 50, 60, 200] {
            let emp = counts[r] as f64 / n as f64;
            let want = z.pmf(r);
            let sd = (want * (1.0 - want) / n as f64).sqrt();
            assert!(
                (emp - want).abs() < 6.0 * sd + 1e-4,
                "rank {r}: emp {emp} want {want}"
            );
        }
    }

    #[test]
    fn small_n_head_matches_historical_exact_sampler() {
        // n below HEAD_RANKS must keep the all-exact table: same pmf and
        // same draw sequence as a sampler whose head trivially covers n
        let z = ZipfSampler::new(64, 1.3);
        let all_head = ZipfSampler::with_head(64, 1.3, 64);
        let (mut r1, mut r2) = (Xoshiro256::seed_from(7), Xoshiro256::seed_from(7));
        for _ in 0..1000 {
            assert_eq!(z.sample(&mut r1), all_head.sample(&mut r2));
        }
        for r in 0..64 {
            assert_eq!(z.pmf(r), all_head.pmf(r));
        }
    }
}
