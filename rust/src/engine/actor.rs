//! Multi-process mode for the async engine (`--engine-processes <n>`):
//! data and gradient **actor processes** talking to the barrier process
//! over unix-domain sockets, with the wire format in [`super::wire`].
//!
//! Topology (an actor-manager split — see `docs/ENGINE.md` for the full
//! diagram and protocol table):
//!
//! * **Data actors** (`engine_data_workers` processes) own a strided slice
//!   of the batch sequence (`offset, offset + stride, …`) and stream
//!   `Batch` frames to the barrier; invariant 1 (self-contained batch
//!   streams) makes the slice assignment irrelevant to the bytes produced.
//! * **Gradient actors** (`n` processes) each own a **contiguous row
//!   range** of every embedding table, held as a local [`TableStore`] —
//!   in-RAM row shards by default, or a file-backed paged table for the
//!   actor's own range when the run sets `--store-budget-mb` (each actor
//!   pages only the rows it owns, so the budget splits across the fleet).
//!   They rebuild their slice from `ParamStore::init(manifest, seed)` —
//!   a pure function of the init frame — so no parameter values ride the
//!   wire at startup.  Per step they receive the batch + row-cache
//!   snapshot, compute their assigned 16-example chunks, and stream the
//!   partials back; scatter updates route to them by row range.
//! * **The barrier** (this process) keeps the full `ParamStore` for the
//!   dense parameters and the *unchanged* serial assemble → select →
//!   noise → scatter tail, so (ε, δ) accounting, σ calibration, and the
//!   FEST reselection protocol are byte-identical to the in-process paths.
//!
//! Per grad actor the barrier runs one **reader thread** that demuxes the
//! actor → barrier direction (chunk results to the aggregation channel,
//! row fetches and finalize results to per-actor channels) — because the
//! reader always drains, an actor's writes can never block indefinitely,
//! which is the no-deadlock argument for the socket protocol.  A reader
//! that sees EOF without a clean final frame bumps a `down` counter that
//! the barrier's timeout loops poll, so a killed actor becomes a
//! bounded-time error instead of a hang (`rust/tests/engine_fault.rs`).

use std::io::BufReader;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::step::ParamSink;
use crate::data::{Batch, GenConfig, Generator};
use crate::models::ParamStore;
use crate::runtime::reference::{BatchRef, ChunkGrads, RefModel, REDUCE_CHUNK};
use crate::runtime::{HostTensor, Runtime};
use crate::sparse::{DenseState, Optimizer, OptimizerKind, RowSparseGrad};
use crate::telemetry::{Queue, Stage, Telemetry};

use super::pipeline::{self, BatchMsg, DataPlan, RowCache, WorkerView};
use super::wire::{self, Frame, GradInit, StepData, WireFeat};
use crate::store::{
    default_page_rows, unique_path, PagedTable, ShardedTable, StoreOptions, TableStore,
};

/// Marks a process as an actor child: `data:<i>` or `grad:<i>`.
const ENV_ROLE: &str = "SPARSE_DP_EMB_ACTOR";
/// Filesystem path of the barrier's unix-domain listener.
const ENV_SOCKET: &str = "SPARSE_DP_EMB_ACTOR_SOCKET";
/// Fault-injection spec forwarded to children (tests only): `role:i:n`
/// makes actor `role:i` abort the process after its `n`-th outbound
/// payload frame.
const ENV_FAULT: &str = "SPARSE_DP_EMB_ACTOR_FAULT";

/// Exit code of a fault-injected abort (distinguishable from real errors
/// in test output; nothing depends on the value).
const FAULT_EXIT: i32 = 42;

static ACTOR_EXE: OnceLock<PathBuf> = OnceLock::new();
static FAULT: Mutex<Option<String>> = Mutex::new(None);

/// Route actor children through `exe` instead of `current_exe()`.
///
/// Integration tests need this: their own executable's `main` is the
/// libtest harness, which never reaches [`maybe_actor_main`] — so they
/// point the spawner at the CLI binary (`env!("CARGO_BIN_EXE_...")`),
/// whose `main` does.  First call wins; later calls are ignored.
pub fn set_actor_exe(exe: PathBuf) {
    let _ = ACTOR_EXE.set(exe);
}

/// Fault injection for tests: `"<role>:<index>:<n>"` makes that actor
/// process abort (hard `process::exit`, no shutdown protocol) right after
/// sending its `n`-th payload frame.  Applies to every subsequent
/// [`ProcEngine`] launch in this process; pass via the child's
/// environment only — the parent's is never mutated.
pub fn set_fault(spec: &str) {
    *FAULT.lock().unwrap() = Some(spec.to_string());
}

/// Parse this process's fault spec for `role:index`: the number of payload
/// frames to send before aborting.
fn fault_after(role: &str, index: u32) -> Option<u64> {
    let spec = std::env::var(ENV_FAULT).ok()?;
    let (target, n) = spec.rsplit_once(':')?;
    if target == format!("{role}:{index}") {
        n.parse().ok()
    } else {
        None
    }
}

/// The contiguous row range owner `a` of `owners` holds in a table of
/// `rows` rows: `[a·per, (a+1)·per)` clamped, with `per = ceil(rows /
/// owners)`.  Ranges are ascending and disjoint, so concatenating the
/// owners' slices in index order reassembles the table.
fn owner_range(rows: usize, owners: usize, a: usize) -> (usize, usize) {
    let per = rows.div_ceil(owners.max(1)).max(1);
    ((a * per).min(rows), ((a + 1) * per).min(rows))
}

/// Which owner's range contains `row`.
fn owner_of(rows: usize, owners: usize, row: usize) -> usize {
    let per = rows.div_ceil(owners.max(1)).max(1);
    (row / per).min(owners - 1)
}

/// Non-zero `(stage, nanos, count)` totals of an actor-local telemetry hub,
/// ready to ride a `DataDone` / `FinalizeResult` frame.
fn stage_totals(tele: &Telemetry) -> Vec<(Stage, u64, u64)> {
    Stage::ALL
        .iter()
        .map(|&s| {
            let (nanos, count) = tele.stage_total(s);
            (s, nanos, count)
        })
        .filter(|&(_, nanos, count)| nanos > 0 || count > 0)
        .collect()
}

// ---------------------------------------------------------------------------
// actor-process side
// ---------------------------------------------------------------------------

/// Actor-process entry hook — the CLI binary calls this first thing in
/// `main`.  When the process was spawned as an actor child (the
/// `SPARSE_DP_EMB_ACTOR` environment variable is set by the barrier's
/// spawner) this runs the actor loop and **exits the process**; otherwise
/// it returns immediately and the CLI proceeds as usual.
pub fn maybe_actor_main() {
    let Ok(role) = std::env::var(ENV_ROLE) else { return };
    let code = match actor_main(&role) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("[actor {role}] error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn actor_main(role: &str) -> Result<()> {
    let path = std::env::var(ENV_SOCKET).context("actor spawned without a socket path")?;
    let (kind, index) = role
        .split_once(':')
        .and_then(|(k, i)| Some((k, i.parse::<u32>().ok()?)))
        .with_context(|| format!("malformed actor role {role:?}"))?;
    let sock = UnixStream::connect(&path)
        .with_context(|| format!("connecting to the barrier socket {path}"))?;
    let reader = BufReader::new(sock.try_clone().context("cloning the actor socket")?);
    let mut w = sock;
    let role_tag = match kind {
        "data" => 0,
        "grad" => 1,
        _ => bail!("unknown actor kind {kind:?}"),
    };
    wire::write_frame(&mut w, &Frame::Hello { role: role_tag, index })?;
    match kind {
        "data" => data_actor(reader, w, index),
        _ => grad_actor(reader, w, index),
    }
}

/// Data actor body: generate the strided slice `offset, offset + stride, …`
/// of the plan's sequence through the same [`pipeline::gen_item`] as the
/// in-process workers, stream each batch, then report stage totals and
/// exit.
fn data_actor(mut r: BufReader<UnixStream>, mut w: UnixStream, index: u32) -> Result<()> {
    let Frame::DataInit { gen, plan, stride, offset } = wire::read_frame(&mut r)? else {
        bail!("expected DataInit");
    };
    let gen = Generator::new(gen);
    let tele = Telemetry::new();
    let fault = fault_after("data", index);
    let total = plan.prior.num_batches() + plan.steps;
    let mut sent = 0u64;
    let mut seq = offset as u64;
    while seq < total {
        let msg = pipeline::gen_item(&gen, &plan, seq, &tele);
        let _span = tele.span(Stage::DataSend);
        wire::write_frame(&mut w, &Frame::Batch(msg))?;
        drop(_span);
        sent += 1;
        if fault == Some(sent) {
            std::process::exit(FAULT_EXIT);
        }
        seq += stride.max(1) as u64;
    }
    wire::write_frame(&mut w, &Frame::DataDone { stages: stage_totals(&tele) })
}

/// One embedding-table slice a gradient actor owns: global rows
/// `[lo, hi)` of parameter `param`, held in whichever backend the init
/// frame selected (in-RAM shards, or pages over the owned range only).
struct OwnedTable {
    param: usize,
    lo: usize,
    hi: usize,
    table: TableStore,
}

impl OwnedTable {
    /// Map a global (table-level) row id into the owned range.
    fn local(&self, global: u32) -> Result<usize> {
        (global as usize)
            .checked_sub(self.lo)
            .filter(|&l| l < self.hi - self.lo)
            .with_context(|| {
                format!("row {global} outside owned range {}..{} of param {}", self.lo, self.hi,
                    self.param)
            })
    }
}

/// Gradient actor body: rebuild the owned row ranges from the
/// deterministic `ParamStore::init`, then serve the barrier's frame loop —
/// row fetches, step dispatches (chunk gradients), scatter updates, and
/// the final table hand-back.
fn grad_actor(mut r: BufReader<UnixStream>, mut w: UnixStream, index: u32) -> Result<()> {
    let init = match wire::read_frame(&mut r)? {
        Frame::GradInit(g) => g,
        _ => bail!("expected GradInit"),
    };
    // The parent resolved its runtime from the same directory: when the
    // manifest file is absent both sides fall back to the identical
    // built-in reference manifest (checked here to keep children from
    // re-printing the fallback notice).
    let dir = std::path::Path::new(&init.artifacts_dir);
    let rt = if dir.join("manifest.txt").exists() {
        Runtime::new(&init.artifacts_dir)?
    } else {
        Runtime::builtin()
    };
    let model = rt.manifest.model(&init.model)?;
    let rm = RefModel::from_manifest(model)?;
    // Scope the kernel knobs like the in-process trainers do; the actor
    // computes with the run's backend so multi-process == in-process
    // stays bit-identical at either backend.
    let _kernel_scope =
        crate::kernels::ScopedConfig::apply(init.kernel_threads as usize, init.kernel_backend);
    let opt = Optimizer::new(init.opt_kind, init.lr);
    // Rebuild the full init store locally (deterministic in (manifest,
    // seed)), slice out this actor's owned row ranges, and keep the dense
    // parameters as the step snapshot baseline — zero parameter bytes on
    // the wire.
    let store = ParamStore::init(model, init.seed)?;
    let owners = init.n_owners as usize;
    // `--store-budget-mb` splits evenly across this actor's owned tables —
    // each actor pages only its own contiguous range, so the fleet-wide
    // resident footprint is bounded per process, not just per run.
    let per_table_budget =
        (init.store_budget_mb as usize * 1024 * 1024) / init.emb_params.len().max(1);
    let mut owned = Vec::with_capacity(init.emb_params.len());
    for &p in &init.emb_params {
        let p = p as usize;
        let t = &store.params[p].tensor;
        let dims = t.dims();
        if dims.len() != 2 {
            bail!("embedding parameter {} is not 2-D", store.params[p].name);
        }
        let (rows, dim) = (dims[0], dims[1]);
        let (lo, hi) = owner_range(rows, owners, index as usize);
        let values = t.as_f32()?[lo * dim..hi * dim].to_vec();
        let table = if init.store_budget_mb > 0 {
            let dir = StoreOptions::resolve_dir(&init.store_dir);
            TableStore::Paged(PagedTable::from_dense(
                unique_path(&dir, &format!("a{index}_p{p}")),
                hi - lo,
                dim,
                values,
                default_page_rows(dim),
                per_table_budget.max(1),
            )?)
        } else {
            TableStore::Ram(ShardedTable::from_dense(
                hi - lo,
                dim,
                values,
                init.shards as usize,
            ))
        };
        owned.push(OwnedTable { param: p, lo, hi, table });
    }
    let nt = rm.num_tables();
    let mut dense: Vec<Arc<Vec<f32>>> = (nt..rm.num_params())
        .map(|i| Ok(Arc::new(store.params[i].tensor.as_f32()?.to_vec())))
        .collect::<Result<_>>()?;
    let tele = Telemetry::new();
    let fault = fault_after("grad", index);
    let mut sent = 0u64;
    loop {
        let frame = match wire::read_frame(&mut r) {
            Ok(f) => f,
            // EOF: the barrier dropped the socket (error-path shutdown or
            // kill) — exit quietly, nothing left to serve.
            Err(_) => return Ok(()),
        };
        match frame {
            Frame::FetchRows { rows } => {
                if rows.len() != owned.len() {
                    bail!("row fetch feature count mismatch");
                }
                let mut values = Vec::with_capacity(rows.len());
                for (o, ids) in owned.iter().zip(&rows) {
                    let dim = o.table.dim();
                    let mut out = vec![0f32; ids.len() * dim];
                    for (k, &gid) in ids.iter().enumerate() {
                        o.table.read_row(o.local(gid)?, &mut out[k * dim..(k + 1) * dim]);
                    }
                    values.push(out);
                }
                wire::write_frame(&mut w, &Frame::RowValues { values })?;
            }
            Frame::StepData(sd) => {
                let StepData { step, chunk_lo, chunk_hi, c1, c2, batch, feats, dense: dv } = sd;
                let cache = RowCache::from_parts(feats);
                for (idx, values) in dv {
                    dense[idx as usize - nt] = Arc::new(values);
                }
                let view = WorkerView { rows: &cache, dense: dense.as_slice() };
                let bref = BatchRef::from_batch(&batch);
                let b = batch.batch_size();
                for chunk in chunk_lo..chunk_hi {
                    let lo = chunk as usize * REDUCE_CHUNK;
                    let hi = (lo + REDUCE_CHUNK).min(b);
                    let grads = tele.time(Stage::ChunkCompute, || {
                        rm.grads_chunk(&view, &bref, lo, hi, c1, c2)
                    });
                    wire::write_frame(&mut w, &Frame::ChunkResult { step, chunk, grads })?;
                    sent += 1;
                    if fault == Some(sent) {
                        std::process::exit(FAULT_EXIT);
                    }
                }
            }
            Frame::Scatter { param, rows, values } => {
                let o = find_owned(&owned, param)?;
                let dim = o.table.dim();
                if rows.len() * dim != values.len() {
                    bail!("scatter geometry mismatch for param {param}");
                }
                let mut g = RowSparseGrad::with_capacity(o.hi - o.lo, dim, rows.len());
                for (k, &gid) in rows.iter().enumerate() {
                    g.add_row(o.local(gid)? as u32, &values[k * dim..(k + 1) * dim]);
                }
                o.table.apply_sparse(&g, &opt)?;
            }
            Frame::DenseScatter { param, values } => {
                let o = find_owned(&owned, param)?;
                if values.len() != (o.hi - o.lo) * o.table.dim() {
                    bail!("dense scatter length mismatch for param {param}");
                }
                o.table.apply_dense(&values, &opt)?;
            }
            Frame::Finalize => {
                let mut tables = Vec::with_capacity(owned.len());
                for o in std::mem::take(&mut owned) {
                    let (values, accum) = o.table.into_dense()?;
                    tables.push((o.param as u32, values, accum));
                }
                let stages = stage_totals(&tele);
                return wire::write_frame(&mut w, &Frame::FinalizeResult { tables, stages });
            }
            _ => bail!("unexpected frame in the gradient actor loop"),
        }
    }
}

fn find_owned(owned: &[OwnedTable], param: u32) -> Result<&OwnedTable> {
    owned
        .iter()
        .find(|o| o.param == param as usize)
        .with_context(|| format!("update aimed at parameter {param}, which this actor owns no \
             slice of"))
}

// ---------------------------------------------------------------------------
// barrier side
// ---------------------------------------------------------------------------

/// Everything [`ProcEngine::launch`] needs to describe the run to its
/// actors.
pub(crate) struct ProcSpec<'a> {
    /// Manifest model name.
    pub model: &'a str,
    /// `RunConfig::artifacts_dir` (children resolve the same manifest).
    pub artifacts_dir: &'a str,
    /// The run seed.
    pub seed: u64,
    /// Optimizer kind (fixed for the run).
    pub opt_kind: OptimizerKind,
    /// Learning rate.
    pub lr: f32,
    /// Data-generator config for the data actors.
    pub gen: &'a GenConfig,
    /// The data plan (sequence length, streaming calendar, priors).
    pub plan: DataPlan,
    /// Number of data actor processes.
    pub n_data: usize,
    /// Number of gradient actor processes (= row-range owners).
    pub n_grad: usize,
    /// Shard count inside each actor's local tables.
    pub shards: usize,
    /// Kernel threads inside each gradient actor.
    pub kernel_threads: usize,
    /// Kernel backend inside each gradient actor (must match the barrier's
    /// so every chain is computed the same way fleet-wide).
    pub kernel_backend: crate::kernels::KernelBackend,
    /// Parameter indices of the embedding tables, in feature order.
    pub emb_params: &'a [usize],
    /// Number of embedding tables (dense params start at this index).
    pub nt: usize,
    /// Reduction chunks per step (`ceil(batch / 16)`).
    pub n_chunks: usize,
    /// `--store-budget-mb`: per-process paged-store budget (0 = in RAM).
    pub store_budget_mb: usize,
    /// `--store-dir`: directory for the actors' page files ("" = temp dir).
    pub store_dir: &'a str,
}

/// The spawned children plus their reader threads; dropping kills every
/// child (orphan-free on success and error paths alike) and joins the
/// readers (they exit on the resulting EOFs).
struct ActorSet {
    children: Vec<Child>,
    readers: Vec<JoinHandle<()>>,
}

impl Drop for ActorSet {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Barrier-side handle to one gradient actor: the write half of its
/// socket plus the per-actor reply channels its reader thread feeds.
struct GradPeer {
    sock: UnixStream,
    rows_rx: Receiver<Vec<Vec<f32>>>,
    fin_rx: Receiver<Vec<(u32, Vec<f32>, Vec<f32>)>>,
}

/// Row-range geometry of one embedding table.
struct EmbMeta {
    param: usize,
    rows: usize,
    dim: usize,
}

/// Barrier-side handle to a running multi-process actor fleet — the
/// multi-process counterpart of the in-process `ShardedStore` + worker
/// scope.  Owns the children (killed on drop), the full `ParamStore`
/// (dense half authoritative; embedding values are reassembled from the
/// actors at [`ProcEngine::into_store`]), and the per-step epoch counter
/// that the staleness telemetry reads.
pub(crate) struct ProcEngine {
    actors: ActorSet,
    grads: Vec<GradPeer>,
    emb: Vec<EmbMeta>,
    store: Mutex<ParamStore>,
    nt: usize,
    n_grad: usize,
    n_chunks: usize,
    epoch: AtomicU64,
    data_down: Arc<AtomicUsize>,
    tele: Arc<Telemetry>,
}

impl ProcEngine {
    /// Spawn and connect the actor fleet: bind a private unix socket,
    /// fork `n_data + n_grad` children of the current executable (or the
    /// [`set_actor_exe`] override), collect their hellos with a startup
    /// deadline (a child that dies before connecting is surfaced, not
    /// waited for), send the init frames, and start one reader thread per
    /// actor.
    pub(crate) fn launch(
        spec: ProcSpec,
        store: ParamStore,
        batch_tx: SyncSender<BatchMsg>,
        res_tx: Sender<(u64, usize, ChunkGrads)>,
        workers_down: Arc<AtomicUsize>,
        tele: Arc<Telemetry>,
    ) -> Result<ProcEngine> {
        static NEXT_SOCKET: AtomicU64 = AtomicU64::new(0);
        let tag = NEXT_SOCKET.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("sparse-dp-emb-{}-{tag}.sock", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("binding actor socket {}", path.display()))?;
        listener.set_nonblocking(true).context("unblocking the actor listener")?;

        let exe = match ACTOR_EXE.get() {
            Some(p) => p.clone(),
            None => std::env::current_exe().context("resolving the actor executable")?,
        };
        let fault = FAULT.lock().unwrap().clone();
        let mut children = Vec::with_capacity(spec.n_data + spec.n_grad);
        let mut spawn = |role: &str, idx: usize| -> Result<()> {
            let mut cmd = Command::new(&exe);
            cmd.env(ENV_ROLE, format!("{role}:{idx}"))
                .env(ENV_SOCKET, &path)
                .stdin(Stdio::null());
            if let Some(f) = &fault {
                cmd.env(ENV_FAULT, f);
            }
            children.push(cmd.spawn().with_context(|| format!("spawning {role} actor {idx}"))?);
            Ok(())
        };
        for i in 0..spec.n_data {
            spawn("data", i)?;
        }
        for a in 0..spec.n_grad {
            spawn("grad", a)?;
        }
        let mut actors = ActorSet { children, readers: Vec::new() };

        // Collect hellos.  The listener is non-blocking so a child that
        // dies before connecting turns into an error within the deadline
        // instead of an accept() hang.
        let mut data_socks: Vec<Option<UnixStream>> = (0..spec.n_data).map(|_| None).collect();
        let mut grad_socks: Vec<Option<UnixStream>> = (0..spec.n_grad).map(|_| None).collect();
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut connected = 0;
        while connected < spec.n_data + spec.n_grad {
            match listener.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false).context("blocking an actor socket")?;
                    s.set_read_timeout(Some(Duration::from_secs(10)))?;
                    let Frame::Hello { role, index } = wire::read_frame(&mut &s)? else {
                        bail!("expected Hello from a connecting actor");
                    };
                    // the timeout guards the hello only; steady-state sockets
                    // may legitimately idle (a grad actor between slow steps)
                    s.set_read_timeout(None)?;
                    let slot = match role {
                        0 => data_socks.get_mut(index as usize),
                        1 => grad_socks.get_mut(index as usize),
                        r => bail!("unknown actor role {r}"),
                    };
                    match slot {
                        Some(slot @ None) => *slot = Some(s),
                        Some(_) => bail!("duplicate hello from actor {role}:{index}"),
                        None => bail!("actor index {index} out of range for role {role}"),
                    }
                    connected += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        bail!("timed out waiting for actor processes to connect");
                    }
                    for c in &mut actors.children {
                        if let Some(status) = c.try_wait()? {
                            bail!("an actor process exited during startup ({status})");
                        }
                    }
                    thread::sleep(Duration::from_millis(20));
                }
                Err(e) => return Err(e).context("accepting an actor connection"),
            }
        }
        drop(listener);
        let _ = std::fs::remove_file(&path);

        let mut emb = Vec::with_capacity(spec.emb_params.len());
        for &p in spec.emb_params {
            let dims = store.params[p].tensor.dims();
            if dims.len() != 2 {
                bail!("embedding parameter {} is not 2-D", store.params[p].name);
            }
            emb.push(EmbMeta { param: p, rows: dims[0], dim: dims[1] });
        }

        for (i, s) in data_socks.iter().enumerate() {
            let s = s.as_ref().unwrap();
            let init = Frame::DataInit {
                gen: spec.gen.clone(),
                plan: spec.plan,
                stride: spec.n_data as u32,
                offset: i as u32,
            };
            wire::write_frame(&mut &*s, &init).context("initializing a data actor")?;
        }
        let emb_u32: Vec<u32> = spec.emb_params.iter().map(|&p| p as u32).collect();
        for (a, s) in grad_socks.iter().enumerate() {
            let s = s.as_ref().unwrap();
            let init = Frame::GradInit(GradInit {
                model: spec.model.to_string(),
                artifacts_dir: spec.artifacts_dir.to_string(),
                seed: spec.seed,
                opt_kind: spec.opt_kind,
                lr: spec.lr,
                emb_params: emb_u32.clone(),
                n_owners: spec.n_grad as u32,
                owner_index: a as u32,
                shards: spec.shards as u32,
                kernel_threads: spec.kernel_threads as u32,
                kernel_backend: spec.kernel_backend,
                store_budget_mb: spec.store_budget_mb as u64,
                store_dir: spec.store_dir.to_string(),
            });
            wire::write_frame(&mut &*s, &init).context("initializing a gradient actor")?;
        }

        // One reader thread per actor — *not* scoped: they must outlive
        // the worker scope because `into_store` still talks the finalize
        // protocol afterwards.  They hold only owned Arcs and exit on
        // socket EOF or channel disconnect, and `ActorSet::drop` joins
        // them after killing the children.
        let data_down = Arc::new(AtomicUsize::new(0));
        for s in data_socks.into_iter().map(Option::unwrap) {
            let tx = batch_tx.clone();
            let tl = Arc::clone(&tele);
            let down = Arc::clone(&data_down);
            actors.readers.push(thread::spawn(move || data_reader(s, tx, tl, down)));
        }
        let mut grads = Vec::with_capacity(spec.n_grad);
        for s in grad_socks.into_iter().map(Option::unwrap) {
            let rs = s.try_clone().context("cloning a gradient actor socket")?;
            let (rows_tx, rows_rx) = mpsc::channel();
            let (fin_tx, fin_rx) = mpsc::channel();
            let tx = res_tx.clone();
            let tl = Arc::clone(&tele);
            let down = Arc::clone(&workers_down);
            actors
                .readers
                .push(thread::spawn(move || grad_reader(rs, tx, rows_tx, fin_tx, tl, down)));
            grads.push(GradPeer { sock: s, rows_rx, fin_rx });
        }

        Ok(ProcEngine {
            actors,
            grads,
            emb,
            store: Mutex::new(store),
            nt: spec.nt,
            n_grad: spec.n_grad,
            n_chunks: spec.n_chunks,
            epoch: AtomicU64::new(0),
            data_down,
            tele,
        })
    }

    /// Count of data actor processes that died mid-sequence — feeds the
    /// `BatchStream` watchdog.
    pub(crate) fn data_down(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.data_down)
    }

    /// Applied-update count (the snapshot-age reference for the staleness
    /// gauge — same semantics as `ShardedStore::epoch`).
    pub(crate) fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Note one applied update.
    pub(crate) fn bump_epoch(&self) {
        self.epoch.fetch_add(1, Ordering::Release);
    }

    /// Whether parameter `index` is trainable.
    pub(crate) fn is_trainable(&self, index: usize) -> bool {
        self.store.lock().unwrap().params[index].trainable
    }

    /// Snapshot of dense parameter `index` (barrier-owned, so a plain
    /// locked read).
    pub(crate) fn dense_values(&self, index: usize) -> Vec<f32> {
        let store = self.store.lock().unwrap();
        store.params[index].tensor.as_f32().expect("dense parameter is f32").to_vec()
    }

    /// Build the step's [`RowCache`] by fetching each owner's slice of the
    /// batch's unique rows.  The per-feature row lists are sorted and the
    /// owner ranges are contiguous and ascending, so concatenating the
    /// replies in owner order *is* the sorted global row list — the cache
    /// is byte-identical to an in-process `RowCache::build`.
    pub(crate) fn fetch_row_cache(&self, batch: &Batch) -> Result<RowCache> {
        let uniq = RowCache::unique_rows(batch);
        for (a, peer) in self.grads.iter().enumerate() {
            let rows: Vec<Vec<u32>> = uniq
                .iter()
                .zip(&self.emb)
                .map(|(rows, m)| {
                    let (lo, hi) = owner_range(m.rows, self.n_grad, a);
                    let s = rows.partition_point(|&r| (r as usize) < lo);
                    let e = rows.partition_point(|&r| (r as usize) < hi);
                    rows[s..e].to_vec()
                })
                .collect();
            wire::write_frame(&mut &peer.sock, &Frame::FetchRows { rows })
                .context("requesting rows from a gradient actor")?;
        }
        let mut feats: Vec<WireFeat> = uniq
            .into_iter()
            .zip(&self.emb)
            .map(|(rows, m)| {
                let values = Vec::with_capacity(rows.len() * m.dim);
                (rows, values, m.dim)
            })
            .collect();
        for peer in &self.grads {
            let values = peer
                .rows_rx
                .recv()
                .map_err(|_| anyhow!("a gradient actor process terminated during a row fetch"))?;
            if values.len() != feats.len() {
                bail!("row fetch reply feature count mismatch");
            }
            for (f, v) in values.into_iter().enumerate() {
                feats[f].1.extend_from_slice(&v);
            }
        }
        for (rows, values, dim) in &feats {
            if values.len() != rows.len() * dim {
                bail!("row fetch reply length mismatch");
            }
        }
        Ok(RowCache::from_parts(feats))
    }

    /// Dispatch step `step` to the gradient actors: each owner gets the
    /// batch, the full row-cache snapshot, the trainable dense values, and
    /// its contiguous block of reduction chunks.
    pub(crate) fn send_step(
        &self,
        step: u64,
        batch: &Batch,
        rows: &RowCache,
        dense: &[Arc<Vec<f32>>],
        clips: (f32, f32),
    ) -> Result<()> {
        let feats: Vec<WireFeat> =
            rows.parts().map(|(r, v, d)| (r.to_vec(), v.to_vec(), d)).collect();
        let trainable: Vec<(u32, Vec<f32>)> = {
            let store = self.store.lock().unwrap();
            dense
                .iter()
                .enumerate()
                .filter(|(j, _)| store.params[self.nt + j].trainable)
                .map(|(j, v)| ((self.nt + j) as u32, v.as_ref().clone()))
                .collect()
        };
        for (a, peer) in self.grads.iter().enumerate() {
            let (lo, hi) = owner_range(self.n_chunks, self.n_grad, a);
            if lo >= hi {
                continue;
            }
            for _ in lo..hi {
                self.tele.queue_inc(Queue::Task);
            }
            let frame = Frame::StepData(StepData {
                step,
                chunk_lo: lo as u32,
                chunk_hi: hi as u32,
                c1: clips.0,
                c2: clips.1,
                batch: batch.clone(),
                feats: feats.clone(),
                dense: trainable.clone(),
            });
            wire::write_frame(&mut &peer.sock, &frame)
                .context("dispatching a step to a gradient actor")?;
        }
        Ok(())
    }

    /// Run the finalize protocol and reassemble the full [`ParamStore`]:
    /// each gradient actor ships back its owned `(values, accum)` slices,
    /// which concatenate in owner order into the embedding tables; the
    /// dense half was barrier-owned all along.
    pub(crate) fn into_store(self) -> Result<ParamStore> {
        let ProcEngine { actors, grads, emb, store, n_grad, .. } = self;
        for peer in &grads {
            wire::write_frame(&mut &peer.sock, &Frame::Finalize)
                .context("sending finalize to a gradient actor")?;
        }
        let mut store = store.into_inner().unwrap();
        let mut parts: Vec<Vec<(Vec<f32>, Vec<f32>)>> = emb.iter().map(|_| Vec::new()).collect();
        for (a, peer) in grads.iter().enumerate() {
            let tables = peer.fin_rx.recv().map_err(|_| {
                anyhow!("a gradient actor process terminated before finalizing")
            })?;
            if tables.len() != emb.len() {
                bail!("finalize reply table count mismatch");
            }
            for (f, (param, values, accum)) in tables.into_iter().enumerate() {
                let m = &emb[f];
                if param as usize != m.param {
                    bail!("finalize reply param order mismatch");
                }
                let (lo, hi) = owner_range(m.rows, n_grad, a);
                if values.len() != (hi - lo) * m.dim {
                    bail!("finalize reply slice length mismatch");
                }
                if !accum.is_empty() && accum.len() != (hi - lo) * m.dim {
                    bail!("finalize reply accum length mismatch");
                }
                parts[f].push((values, accum));
            }
        }
        for (m, slices) in emb.iter().zip(parts) {
            // Optimizer state merges like `ShardedTable::into_dense`: empty
            // iff no owner accumulated any; otherwise untouched owners'
            // slices zero-fill (adagrad state starts at zero).
            let any_state = slices.iter().any(|(_, a)| !a.is_empty());
            let mut values = Vec::with_capacity(m.rows * m.dim);
            let mut accum = Vec::new();
            for (a, (v, acc)) in slices.into_iter().enumerate() {
                let (lo, hi) = owner_range(m.rows, n_grad, a);
                values.extend_from_slice(&v);
                if any_state {
                    if acc.is_empty() {
                        accum.resize(accum.len() + (hi - lo) * m.dim, 0.0);
                    } else {
                        accum.extend_from_slice(&acc);
                    }
                }
            }
            let p = &mut store.params[m.param];
            p.tensor = HostTensor::f32(vec![m.rows, m.dim], values);
            p.opt_state =
                if any_state { DenseState::from_accum(accum) } else { DenseState::default() };
        }
        drop(actors);
        Ok(store)
    }
}

/// [`ParamSink`] that routes the barrier's optimizer updates to their
/// owners: embedding updates travel to the owning gradient actors as
/// `Scatter` / `DenseScatter` frames (the actors hold the run's fixed
/// optimizer from their init frame, so no optimizer payload rides per
/// update), while non-embedding dense updates apply locally to the
/// barrier's store.  Socket FIFO ordering is the correctness argument:
/// the next step's row fetch is written after these frames on the same
/// socket, so it observes exactly the updates applied before it.
pub(crate) struct RoutedSink<'a>(pub(crate) &'a ProcEngine);

impl ParamSink for RoutedSink<'_> {
    fn apply_sparse(
        &mut self,
        param_index: usize,
        grad: &RowSparseGrad,
        _opt: &Optimizer,
    ) -> Result<()> {
        let eng = self.0;
        let Some(m) = eng.emb.iter().find(|m| m.param == param_index) else {
            bail!("row-sparse update aimed at non-embedding parameter {param_index}");
        };
        let mut rows: Vec<Vec<u32>> = eng.grads.iter().map(|_| Vec::new()).collect();
        let mut values: Vec<Vec<f32>> = eng.grads.iter().map(|_| Vec::new()).collect();
        for (row, vals) in grad.iter_rows() {
            let a = owner_of(m.rows, eng.n_grad, row as usize);
            rows[a].push(row);
            values[a].extend_from_slice(vals);
        }
        for (a, (rows, values)) in rows.into_iter().zip(values).enumerate() {
            if rows.is_empty() {
                continue;
            }
            let frame = Frame::Scatter { param: param_index as u32, rows, values };
            wire::write_frame(&mut &eng.grads[a].sock, &frame)
                .context("sending a scatter update to a gradient actor")?;
        }
        Ok(())
    }

    fn apply_dense(&mut self, param_index: usize, grad: &[f32], opt: &Optimizer) -> Result<()> {
        let eng = self.0;
        if let Some(m) = eng.emb.iter().find(|m| m.param == param_index) {
            // densified embedding update (DP-SGD baseline): slice by owner
            for (a, peer) in eng.grads.iter().enumerate() {
                let (lo, hi) = owner_range(m.rows, eng.n_grad, a);
                if lo >= hi {
                    continue;
                }
                let frame = Frame::DenseScatter {
                    param: param_index as u32,
                    values: grad[lo * m.dim..hi * m.dim].to_vec(),
                };
                wire::write_frame(&mut &peer.sock, &frame)
                    .context("sending a dense scatter to a gradient actor")?;
            }
            Ok(())
        } else {
            ParamSink::apply_dense(&mut *eng.store.lock().unwrap(), param_index, grad, opt)
        }
    }
}

/// Reader thread for one data actor: forwards batches into the barrier's
/// bounded channel (backpressure propagates to the actor through the
/// socket buffer), merges the actor's stage totals on a clean `DataDone`,
/// and flags `down` on EOF-without-done so the `BatchStream` watchdog can
/// turn a dead producer into an error.
fn data_reader(
    sock: UnixStream,
    tx: SyncSender<BatchMsg>,
    tele: Arc<Telemetry>,
    down: Arc<AtomicUsize>,
) {
    let mut r = BufReader::new(sock);
    loop {
        match wire::read_frame(&mut r) {
            Ok(Frame::Batch(msg)) => {
                tele.queue_inc(Queue::Batch);
                if tx.send(msg).is_err() {
                    return; // barrier loop is gone — normal shutdown
                }
            }
            Ok(Frame::DataDone { stages }) => {
                tele.merge_stage_totals(&stages);
                return;
            }
            Ok(_) | Err(_) => {
                down.fetch_add(1, Ordering::SeqCst);
                return;
            }
        }
    }
}

/// Reader thread for one gradient actor: demuxes chunk results into the
/// aggregation channel (with the `Queue::Task` gauge decrement), row-fetch
/// and finalize replies into their per-actor channels, and flags `down`
/// on EOF-without-finalize so `collect_step`'s timeout loop surfaces the
/// death.
fn grad_reader(
    sock: UnixStream,
    res_tx: Sender<(u64, usize, ChunkGrads)>,
    rows_tx: Sender<Vec<Vec<f32>>>,
    fin_tx: Sender<Vec<(u32, Vec<f32>, Vec<f32>)>>,
    tele: Arc<Telemetry>,
    down: Arc<AtomicUsize>,
) {
    let mut r = BufReader::new(sock);
    loop {
        match wire::read_frame(&mut r) {
            Ok(Frame::ChunkResult { step, chunk, grads }) => {
                tele.queue_dec(Queue::Task);
                if res_tx.send((step, chunk as usize, grads)).is_err() {
                    return;
                }
            }
            Ok(Frame::RowValues { values }) => {
                if rows_tx.send(values).is_err() {
                    return;
                }
            }
            Ok(Frame::FinalizeResult { tables, stages }) => {
                tele.merge_stage_totals(&stages);
                let _ = fin_tx.send(tables);
                return;
            }
            Ok(_) | Err(_) => {
                down.fetch_add(1, Ordering::SeqCst);
                return;
            }
        }
    }
}
