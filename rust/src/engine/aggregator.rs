//! The DP aggregation barrier.
//!
//! Collects the gradient workers' step-tagged per-chunk partials and folds
//! each step's chunks **in chunk order** into the full-batch artifact
//! output tuple — the identical accumulation the sync reference backend
//! performs — then hands the result to the shared
//! [`StepState::apply_update`] which performs selection, draws *all* σ₁/σ₂
//! noise from the single RNG stream **once per logical batch**, and
//! scatters optimizer updates into the sharded store.  Because everything
//! stochastic happens here, serially, in step order, on bit-identical
//! inputs, the privacy accounting and (at the default `--engine-staleness
//! 0`) the trained model are bit-for-bit equal to the sync path regardless
//! of worker count — see `docs/CONCURRENCY.md` for what `k > 0` relaxes.
//!
//! In streaming mode (§4.3) the barrier additionally hosts the
//! streaming-period boundaries: between steps it merges the data workers'
//! per-batch frequency counts into the `FrequencyTracker`, publishes the
//! running sums at each period start, and recomputes the FEST/AdaFEST+
//! pre-selection — all on this one thread, so the selection Gumbel draws
//! interleave with the noise stream exactly as in the sync streaming
//! trainer (see `coordinator::streaming::StreamSchedule`).
//!
//! [`StepState::apply_update`]: crate::coordinator::step::StepState::apply_update

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::runtime::reference::{ChunkGrads, GradsAcc, RefModel};
use crate::runtime::HostTensor;

/// Receive step `step`'s `n_chunks` chunk results (arriving in any order)
/// and merge them in ascending chunk order into the artifact output tuple.
///
/// With bounded staleness (`--engine-staleness > 0`) several steps' tasks
/// are in flight at once, so results are step-tagged and a result belonging
/// to a *later* step than the one being collected is parked in `early` — a
/// buffer the barrier keeps alive across calls — and drained when that
/// step's collection comes around.  At the default `k = 0` only one step is
/// ever in flight, `early` stays empty between calls, and the merge is the
/// exact serial collection it has always been.
///
/// `workers_down` counts gradient workers that have exited (each worker
/// bumps it from a drop guard, so panics count too).  During a step no
/// worker exits legitimately — the task channel is still open — so a
/// non-zero count while chunks are outstanding means a worker died and its
/// chunk will never arrive; we bail instead of blocking forever.
pub fn collect_step(
    model: &RefModel,
    step: u64,
    n_chunks: usize,
    results: &Receiver<(u64, usize, ChunkGrads)>,
    early: &mut BTreeMap<(u64, usize), ChunkGrads>,
    workers_down: &AtomicUsize,
) -> Result<Vec<HostTensor>> {
    let mut acc = GradsAcc::new(model);
    let mut next = 0usize;
    // chunks of this step that arrived while an older step was collecting
    while let Some(g) = early.remove(&(step, next)) {
        acc.merge(model, g);
        next += 1;
    }
    while next < n_chunks {
        let (s, chunk, grads) = loop {
            match results.recv_timeout(Duration::from_millis(200)) {
                Ok(r) => break r,
                Err(RecvTimeoutError::Timeout) => {
                    if workers_down.load(Ordering::SeqCst) > 0 {
                        bail!(
                            "a gradient worker terminated mid-step \
                             ({next}/{n_chunks} chunks merged) — likely a panic; \
                             see stderr above"
                        );
                    }
                }
                Err(RecvTimeoutError::Disconnected) => bail!(
                    "gradient workers terminated early ({next}/{n_chunks} chunks merged)"
                ),
            }
        };
        if chunk >= n_chunks {
            bail!("chunk index {chunk} out of range (step has {n_chunks})");
        }
        if s < step {
            // steps are collected strictly in order, so an older tag means a
            // duplicate or a collection that already bailed — never silently
            // merge it into the wrong step
            bail!("chunk result for already-collected step {s} while collecting step {step}");
        }
        early.insert((s, chunk), grads);
        while let Some(g) = early.remove(&(step, next)) {
            acc.merge(model, g);
            next += 1;
        }
    }
    Ok(acc.into_outputs(model))
}
