//! The DP aggregation barrier.
//!
//! Collects the gradient workers' per-chunk partials and folds them **in
//! chunk order** into the full-batch artifact output tuple — the identical
//! accumulation the sync reference backend performs — then hands the result
//! to the shared [`StepState::apply_update`] which performs selection,
//! draws *all* σ₁/σ₂ noise from the single RNG stream **once per logical
//! batch**, and scatters optimizer updates into the sharded store.  Because
//! everything stochastic happens here, serially, on bit-identical inputs,
//! the privacy accounting and the trained model are bit-for-bit equal to
//! the sync path regardless of worker count.
//!
//! In streaming mode (§4.3) the barrier additionally hosts the
//! streaming-period boundaries: between steps it merges the data workers'
//! per-batch frequency counts into the `FrequencyTracker`, publishes the
//! running sums at each period start, and recomputes the FEST/AdaFEST+
//! pre-selection — all on this one thread, so the selection Gumbel draws
//! interleave with the noise stream exactly as in the sync streaming
//! trainer (see `coordinator::streaming::StreamSchedule`).
//!
//! [`StepState::apply_update`]: crate::coordinator::step::StepState::apply_update

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::runtime::reference::{ChunkGrads, GradsAcc, RefModel};
use crate::runtime::HostTensor;

/// Receive `n_chunks` chunk results (arriving in any order) and merge them
/// in ascending chunk order into the artifact output tuple.
///
/// `workers_down` counts gradient workers that have exited (each worker
/// bumps it from a drop guard, so panics count too).  During a step no
/// worker exits legitimately — the task channel is still open — so a
/// non-zero count while chunks are outstanding means a worker died and its
/// chunk will never arrive; we bail instead of blocking forever.
pub fn collect_step(
    model: &RefModel,
    n_chunks: usize,
    results: &Receiver<(usize, ChunkGrads)>,
    workers_down: &AtomicUsize,
) -> Result<Vec<HostTensor>> {
    let mut acc = GradsAcc::new(model);
    let mut buffered: BTreeMap<usize, ChunkGrads> = BTreeMap::new();
    let mut next = 0usize;
    while next < n_chunks {
        let (chunk, grads) = loop {
            match results.recv_timeout(Duration::from_millis(200)) {
                Ok(r) => break r,
                Err(RecvTimeoutError::Timeout) => {
                    if workers_down.load(Ordering::SeqCst) > 0 {
                        bail!(
                            "a gradient worker terminated mid-step \
                             ({next}/{n_chunks} chunks merged) — likely a panic; \
                             see stderr above"
                        );
                    }
                }
                Err(RecvTimeoutError::Disconnected) => bail!(
                    "gradient workers terminated early ({next}/{n_chunks} chunks merged)"
                ),
            }
        };
        if chunk >= n_chunks {
            bail!("chunk index {chunk} out of range (step has {n_chunks})");
        }
        buffered.insert(chunk, grads);
        while let Some(g) = buffered.remove(&next) {
            acc.merge(model, g);
            next += 1;
        }
    }
    Ok(acc.into_outputs(model))
}
