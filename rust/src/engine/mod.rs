//! Asynchronous sharded DP training engine.
//!
//! Runs the same Algorithm-1 semantics as the synchronous
//! [`Trainer`](crate::coordinator::Trainer), pipelined across threads:
//!
//! ```text
//!  data workers (N)          gradient workers (M)        aggregation barrier
//!  ───────────────           ────────────────────        ───────────────────
//!  step counter ──┐           ┌── ChunkTask ◀─────────────── dispatch per step
//!  gen batch(t) ──┴─▶ bounded │   (16-example reduction       │ (row cache +
//!  [+ freq counts]   channel  │    chunks, per-step row       │  dense snapshot)
//!  BatchMsg ──▶ BatchStream   │    cache + dense param        ▼
//!                  (reorder)  │    snapshots, lock-free)   merge chunks in order
//!                             └──▶ (step, chunk, grads) ────▶ select ∘ noise(σ₁σ₂)
//!                                                             ∘ sharded update
//! ```
//!
//! The pipeline is **kind-generic**: [`run`] derives the data source from
//! the model manifest and drives either workload — the Criteo tower
//! ([`run_pctr`]) or the NLU transformer ([`run_text`]), with the full
//! embedding table or its LoRA reparametrization — through the same worker
//! bodies, with the chunk math dispatched by
//! [`RefModel`](crate::runtime::reference::RefModel).  The sparse table the
//! engine shards and row-caches is whatever parameter the manifest
//! designates row-sparse (`table_*`, `emb_table`, or the LoRA `emb_lora_a`
//! factor), so the LoRA models ride the same snapshots.
//!
//! **Bit-for-bit equivalence with the sync path** (at the default
//! `--engine-staleness 0`) rests on three documented invariants (each with
//! a test in `tests/engine.rs`, for both workloads; `docs/CONCURRENCY.md`
//! is the single source of truth):
//!
//! 1. *Batch streams* — batch `t` comes from the self-contained RNG
//!    `train_batch_rng(seed, t)`, so data workers can produce batches in
//!    any order ([`crate::coordinator::step`]).
//! 2. *Fixed-chunk reductions* — all batch reductions merge 16-example
//!    chunk partials in chunk order, independent of worker count
//!    ([`crate::runtime::reference`]).
//! 3. *Noise draw order* — every DP random draw happens once per logical
//!    batch, serially, at the aggregation barrier, from the single
//!    [`StepState`](crate::coordinator::step::StepState) RNG.
//!
//! **Bounded staleness** (`--engine-staleness k`, opt-in) lets the barrier
//! keep up to `k` dispatched steps in flight, so gradient workers compute
//! against parameter snapshots at most `k` applies old while the barrier
//! pipelines ahead.  Dispatch order, chunk merge order, and the serial
//! noise stream are all unchanged — only the *parameters read* are stale,
//! so per-example clipping still bounds sensitivity and the σ calibration
//! and (ε, δ) accounting carry over verbatim; `docs/CONCURRENCY.md` has the
//! accounting argument and the stale-FEST-selection caveat.
//!
//! **Streaming mode** ([`run_streaming`]) threads the paper's §4.3 time
//! axis (days and streaming periods) through the same pipeline: the data
//! workers map each step to its simulated day and aggregate per-batch
//! frequency counts that travel with the batch messages, the aggregation
//! barrier doubles as the streaming-period boundary — publish the running
//! counts, recompute the FEST/AdaFEST+ bucket pre-selection under the
//! split selection budget — and the held-out days 18..24 are evaluated
//! per-day once the workers have shut down.  The whole day/period calendar
//! lives in the shared [`StreamSchedule`], so the streaming run is
//! bit-identical to the sync
//! [`StreamingTrainer`](crate::coordinator::StreamingTrainer) for every
//! [`FrequencySource`](crate::selection::FrequencySource) variant.
//!
//! **Multi-process mode** (`--engine-processes <n>`, n ≥ 2) replaces the
//! worker threads with actor *processes* talking to this barrier over
//! unix-domain sockets ([`actor`], wire format in [`wire`]): data actors
//! stream batches, gradient actors own contiguous row ranges of the
//! embedding tables and compute chunk partials, and the barrier keeps the
//! exact same serial assemble → select → noise → scatter tail.  The three
//! invariants above are process-location-independent, so the multi-process
//! run is bit-identical to both in-process paths (`tests/engine.rs`).
//!
//! The engine requires the reference runtime backend (PJRT artifacts have a
//! fixed batch shape and cannot compute per-chunk partials); with `xla`
//! artifacts use the sync trainer.

#![warn(missing_docs)]

pub mod actor;
mod aggregator;
mod pipeline;
pub mod wire;

pub use aggregator::collect_step;
pub use pipeline::{BatchMsg, BatchStream, ChunkTask, DataPlan, RowCache, WorkerView};
pub use crate::store::{ShardedStore, ShardedTable};

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::coordinator::step::{self, ModelMeta, OutputKind, StepState, TrainOutcome};
use crate::coordinator::streaming::{PriorPass, StreamDriver, StreamSchedule};
use crate::coordinator::{pctr_frequency_counts, text_frequency_counts, StreamingOutcome};
use crate::data::{
    Batch, CriteoConfig, GenConfig, PctrBatch, SynthCriteo, SynthText, TextBatch,
    TextConfig,
};
use crate::models::ParamStore;
use crate::runtime::reference::{ChunkGrads, RefModel, REDUCE_CHUNK};
use crate::runtime::Runtime;
use crate::selection::FrequencyTracker;
use crate::store::StoreOptions;
use crate::telemetry::{Queue, Stage};

/// Run a full async training (train → eval) for whatever kind of model
/// `cfg.model` names, deriving the synthetic data source from the manifest
/// exactly as the sync CLI path does.  Returns the same [`TrainOutcome`] as
/// the sync trainer — bitwise, given the same config and seed, at the
/// default `engine.staleness = 0` (see `docs/CONCURRENCY.md` for what a
/// non-zero staleness window trades away).
///
/// # Example
///
/// Train the built-in `criteo-tiny` model for two steps, no artifacts or
/// network needed:
///
/// ```
/// use sparse_dp_emb::config::RunConfig;
/// use sparse_dp_emb::runtime::Runtime;
///
/// let rt = Runtime::builtin();
/// let mut cfg = RunConfig::default();
/// cfg.model = "criteo-tiny".into();
/// cfg.steps = 2;
/// cfg.eval_batches = 1;
/// let outcome = sparse_dp_emb::engine::run(&cfg, &rt).unwrap();
/// assert_eq!(outcome.loss_history.len(), 2);
/// assert!(outcome.loss_history.iter().all(|l| l.is_finite()));
/// ```
pub fn run(cfg: &RunConfig, rt: &Runtime) -> Result<TrainOutcome> {
    run_with_params(cfg, rt).map(|(outcome, _)| outcome)
}

/// Like [`run`], but also return the final [`ParamStore`] — for
/// checkpointing, and for the bit-exactness tests that compare the engine's
/// final parameters against the sync trainer's store coordinate for
/// coordinate (`tests/engine.rs` does this on the LoRA models).
pub fn run_with_params(cfg: &RunConfig, rt: &Runtime) -> Result<(TrainOutcome, ParamStore)> {
    let model = rt.manifest.model(&cfg.model)?;
    let src = match model.kind.as_str() {
        "pctr" => GenConfig::Pctr(CriteoConfig::new(
            model.attr_usize_list("vocabs")?,
            cfg.seed ^ 0xDA7A,
        )),
        "nlu" => GenConfig::Text(TextConfig::from_model(model, cfg.seed ^ 0xDA7A)?),
        other => bail!("unknown model kind {other}"),
    };
    match run_with(cfg, rt, src, None)? {
        Trained::Plain(outcome, store) => Ok((outcome, store)),
        Trained::Streaming(_) => unreachable!("plain run_with returns Plain"),
    }
}

/// Async pCTR training over an explicit generator config (harness/bench
/// entry point; [`run`] derives the config from the manifest instead).
pub fn run_pctr(cfg: &RunConfig, rt: &Runtime, gen_cfg: CriteoConfig) -> Result<TrainOutcome> {
    run_plain(cfg, rt, GenConfig::Pctr(gen_cfg))
}

/// Async NLU training over an explicit generator config.
pub fn run_text(cfg: &RunConfig, rt: &Runtime, gen_cfg: TextConfig) -> Result<TrainOutcome> {
    run_plain(cfg, rt, GenConfig::Text(gen_cfg))
}

/// Run the streaming (§4.3) 24-day protocol on the async engine: train on
/// days 0..18 in day order with period-boundary frequency publishes and
/// DP-FEST reselections at the aggregation barrier, then evaluate each
/// held-out day 18..24.  `gen_cfg` should be drift-enabled
/// ([`CriteoConfig::with_drift`]) to reproduce the paper's non-stationary
/// setting.  `cfg.steps` rounds to whole days — `18 × max(1, steps/18)`
/// streamed steps, so fewer than 18 requested steps still run one step
/// per day — and σ is re-calibrated for the streamed step count
/// ([`StreamSchedule::recalibrate`]).  Returns the same
/// [`StreamingOutcome`] as the synchronous
/// [`StreamingTrainer`](crate::coordinator::StreamingTrainer) — bitwise,
/// for every `FrequencySource` and any worker/shard/depth setting, at the
/// default `engine.staleness = 0`.
pub fn run_streaming(
    cfg: &RunConfig,
    rt: &Runtime,
    gen_cfg: CriteoConfig,
    eval_batches_per_day: usize,
) -> Result<StreamingOutcome> {
    match run_with(cfg, rt, GenConfig::Pctr(gen_cfg), Some(eval_batches_per_day))? {
        Trained::Streaming(out) => Ok(out),
        Trained::Plain(_) => unreachable!("streaming run_with returns Streaming"),
    }
}

fn run_plain(cfg: &RunConfig, rt: &Runtime, src: GenConfig) -> Result<TrainOutcome> {
    match run_with(cfg, rt, src, None)? {
        Trained::Plain(out, _) => Ok(out),
        Trained::Streaming(_) => unreachable!("plain run_with returns Plain"),
    }
}

/// What [`run_with`] produced, depending on the requested mode.  Plain runs
/// carry the final parameter store out (see [`run_with_params`]).
enum Trained {
    Plain(TrainOutcome, ParamStore),
    Streaming(StreamingOutcome),
}

/// The parameter-holding compute fabric behind the aggregation barrier:
/// either the in-process sharded store served by worker threads, or the
/// multi-process actor fleet ([`actor::ProcEngine`]).  The barrier's step
/// loop is fabric-agnostic — it reads snapshots, dispatches chunks, and
/// applies updates through this façade, which is what makes the
/// bit-exactness argument carry across process boundaries unchanged.
enum Fabric {
    /// In-process: gradient worker threads over a [`ShardedStore`].
    Threads(ShardedStore),
    /// Multi-process: actor children over unix-domain sockets.
    Procs(actor::ProcEngine),
}

impl Fabric {
    /// Applied-update count (snapshot-age reference for the staleness gauge).
    fn epoch(&self) -> u64 {
        match self {
            Fabric::Threads(s) => s.epoch(),
            Fabric::Procs(p) => p.epoch(),
        }
    }

    fn bump_epoch(&self) {
        match self {
            Fabric::Threads(s) => s.bump_epoch(),
            Fabric::Procs(p) => p.bump_epoch(),
        }
    }

    fn is_trainable(&self, index: usize) -> bool {
        match self {
            Fabric::Threads(s) => s.is_trainable(index),
            Fabric::Procs(p) => p.is_trainable(index),
        }
    }

    fn dense_values(&self, index: usize) -> Vec<f32> {
        match self {
            Fabric::Threads(s) => s.dense_values(index),
            Fabric::Procs(p) => p.dense_values(index),
        }
    }

    /// Reassemble the final full [`ParamStore`] (shards or actor slices).
    fn into_store(self) -> Result<ParamStore> {
        match self {
            Fabric::Threads(s) => s.into_store(),
            Fabric::Procs(p) => p.into_store(),
        }
    }
}

/// Everything the aggregation barrier needs to push one logical batch
/// through the workers and apply its DP update: per-step snapshots (row
/// cache + dense params), chunk dispatch, in-order merge, assembly, and
/// the shared [`StepState::apply_update`] — plus the bounded-staleness
/// window: up to `staleness` dispatched steps ride in `inflight` before
/// the barrier collects, so workers may compute against snapshots at most
/// that many applies old (`docs/CONCURRENCY.md`).  Shared by the plain
/// step loop and the streaming driver so the two modes cannot drift.
struct StepExec<'a> {
    rm: &'a RefModel,
    fab: &'a Fabric,
    emb_params: &'a [usize],
    static_dense: &'a [Option<Arc<Vec<f32>>>],
    plan: &'a [OutputKind],
    task_tx: &'a mpsc::Sender<ChunkTask>,
    res_rx: &'a mpsc::Receiver<(u64, usize, ChunkGrads)>,
    workers_down: &'a AtomicUsize,
    n_chunks: usize,
    chunks_per_task: usize,
    nt: usize,
    b: usize,
    c1: f32,
    c2: f32,
    seq_len: usize,
    /// `--engine-staleness`: max dispatched-but-uncollected steps left in
    /// flight between [`StepExec::run_step`] calls (0 = fully serial)
    staleness: usize,
    /// dispatched steps awaiting collection, oldest first
    inflight: VecDeque<InflightStep>,
    /// chunk results that arrived ahead of their step's collection
    /// (see [`collect_step`]); always empty at `staleness = 0`
    early: BTreeMap<(u64, usize), ChunkGrads>,
}

/// One dispatched-but-not-yet-applied step.
struct InflightStep {
    step: u64,
    batch: Arc<Batch>,
    /// store epoch ([`ShardedStore::epoch`]) the snapshot was taken at;
    /// `step − epoch` is the snapshot age the telemetry gauge reports
    epoch: u64,
}

impl StepExec<'_> {
    /// Snapshot the store and fan step `step`'s chunk tasks out to the
    /// gradient workers, leaving the step in flight (uncollected).
    fn dispatch(&mut self, state: &StepState, step: u64, batch: Batch) -> Result<()> {
        if batch.batch_size() != self.b {
            bail!("batch size {} != model batch {}", batch.batch_size(), self.b);
        }
        let batch = Arc::new(batch);
        let tele = Arc::clone(&state.tele);
        let epoch = self.fab.epoch();
        // Per-step read-only snapshots, taken after the newest *collected*
        // step's updates: every embedding row the batch touches (gathered
        // once, read lock-free by all workers — this is what keeps
        // per-chunk per-shard lock traffic off the hot path; in
        // multi-process mode fetched from the owning actors) and the dense
        // params (frozen entries are shared across steps).
        let snap_span = tele.span(Stage::Snapshot);
        let rows = Arc::new(match self.fab {
            Fabric::Threads(estore) => RowCache::build(&batch, estore, self.emb_params),
            Fabric::Procs(pe) => pe.fetch_row_cache(&batch)?,
        });
        let dense: Arc<Vec<Arc<Vec<f32>>>> = Arc::new(
            self.static_dense
                .iter()
                .enumerate()
                .map(|(j, frozen)| match frozen {
                    Some(a) => Arc::clone(a),
                    None => Arc::new(self.fab.dense_values(self.nt + j)),
                })
                .collect(),
        );
        drop(snap_span);
        match self.fab {
            Fabric::Threads(_) => {
                let mut c0 = 0usize;
                while c0 < self.n_chunks {
                    let hi = (c0 + self.chunks_per_task).min(self.n_chunks);
                    // gauge up before the send, so in-flight +
                    // claimed-but-unfinished work is what the depth reads
                    // (the task channel is unbounded — the send itself
                    // never blocks)
                    tele.queue_inc(Queue::Task);
                    self.task_tx
                        .send(ChunkTask {
                            step,
                            chunks: c0..hi,
                            batch: Arc::clone(&batch),
                            rows: Arc::clone(&rows),
                            dense: Arc::clone(&dense),
                            c1: self.c1,
                            c2: self.c2,
                        })
                        .ok()
                        .context("gradient workers terminated early")?;
                    c0 = hi;
                }
            }
            Fabric::Procs(pe) => {
                // each gradient actor gets its contiguous block of chunks
                // (`microbatch_chunks` does not apply across processes)
                pe.send_step(step, &batch, &rows, dense.as_slice(), (self.c1, self.c2))?;
            }
        }
        self.inflight.push_back(InflightStep { step, batch, epoch });
        Ok(())
    }

    /// Collect the oldest in-flight step's chunks, assemble the gradient
    /// bundle, and apply its DP update — serially, on this thread, so the
    /// chunk merge order and the noise stream are identical at any
    /// staleness window.
    fn collect_apply(&mut self, state: &mut StepState) -> Result<()> {
        let inflight = self
            .inflight
            .pop_front()
            .expect("collect_apply called with nothing in flight");
        let tele = Arc::clone(&state.tele);
        let (rm, res_rx, early, workers_down) =
            (self.rm, self.res_rx, &mut self.early, self.workers_down);
        let (step, n_chunks) = (inflight.step, self.n_chunks);
        let outs = tele.time(Stage::Collect, move || {
            collect_step(rm, step, n_chunks, res_rx, early, workers_down)
        })?;
        let need_counts = state.cfg.algorithm.uses_contribution_map();
        let assemble_span = tele.span(Stage::Assemble);
        let bundle = match inflight.batch.as_ref() {
            Batch::Pctr(pb) => {
                step::assemble_pctr(self.plan, &outs, &state.emb_tables, pb, need_counts)?
            }
            Batch::Text(tb) => step::assemble_text(
                self.plan,
                &outs,
                &state.emb_tables,
                tb,
                self.seq_len,
                need_counts,
            )?,
        };
        drop(assemble_span);
        // snapshot age of the update being applied; always 0 at k = 0
        tele.set_staleness(inflight.step - inflight.epoch);
        match self.fab {
            Fabric::Threads(estore) => {
                let mut sink = estore;
                state.apply_update(bundle, &mut sink)?;
            }
            Fabric::Procs(pe) => {
                let mut sink = actor::RoutedSink(pe);
                state.apply_update(bundle, &mut sink)?;
            }
        }
        self.fab.bump_epoch();
        Ok(())
    }

    /// Push one logical batch through: dispatch step `step`, then collect
    /// until at most `staleness` steps remain in flight.  At the default
    /// `staleness = 0` this is dispatch-then-collect — the fully serial,
    /// bit-exact barrier.
    fn run_step(&mut self, state: &mut StepState, step: u64, batch: Batch) -> Result<()> {
        self.dispatch(state, step, batch)?;
        while self.inflight.len() > self.staleness {
            self.collect_apply(state)?;
        }
        Ok(())
    }

    /// Collect and apply every step still in flight — at the end of
    /// training, and before any streaming reselection boundary (no step's
    /// update may cross one).
    fn drain(&mut self, state: &mut StepState) -> Result<()> {
        while !self.inflight.is_empty() {
            self.collect_apply(state)?;
        }
        Ok(())
    }
}

/// [`StreamDriver`] over the engine internals: warmup/sniff prior batches
/// and step `t`'s training batch (with its pre-aggregated frequency
/// counts) all come from the reordered data-worker stream, the update goes
/// through the shared [`StepExec`], and DP-FEST reselection mutates the
/// barrier's [`StepState`] exactly where the sync path would — after
/// draining the staleness window, so no step's update crosses a
/// reselection boundary.
struct EngineDriver<'a, 'b> {
    stream: BatchStream,
    exec: &'a mut StepExec<'b>,
    state: &'a mut StepState,
    /// prior-pass batches prepended to the data-worker sequence
    /// ([`PriorPass::num_batches`]); training step `t` rides sequence key
    /// `prior_batches + t`
    prior_batches: u64,
    /// [`StreamSchedule::needs_stream_counts`] — matches the data workers'
    /// [`DataPlan::with_counts`], so counts are shipped iff they are read
    count_batches: bool,
}

impl StreamDriver for EngineDriver<'_, '_> {
    fn observe_prior(
        &mut self,
        index: u64,
        _day: usize,
        tracker: &mut FrequencyTracker,
    ) -> Result<()> {
        // The data workers generated this warmup/sniff batch (sequence key
        // `index`, day resolved worker-side via `PriorPass::day_of`) and
        // always ship counts with it; integer count sums commute, so
        // merging here is bit-identical to the sync trainer observing the
        // batch itself.
        let msg = self.stream.next(index)?;
        let counts = msg
            .counts
            .context("data workers shipped no counts with a prior batch")?;
        for (f, pairs) in counts.iter().enumerate() {
            tracker.merge_counts(f, pairs);
        }
        Ok(())
    }

    fn train_step(
        &mut self,
        step: u64,
        _day: usize,
        tracker: &mut FrequencyTracker,
    ) -> Result<()> {
        let msg = self.stream.next(self.prior_batches + step)?;
        if self.count_batches {
            // merged at dispatch time, in step order — identical tracker
            // contents at every publish boundary because `select` drains
            // the staleness window before reading them
            let counts = msg
                .counts
                .context("data workers shipped no frequency counts in streaming mode")?;
            for (f, pairs) in counts.iter().enumerate() {
                tracker.merge_counts(f, pairs);
            }
        }
        self.exec.run_step(self.state, step, msg.batch)
    }

    fn select(&mut self, feature_counts: &[Vec<f64>], epsilon: f64) -> Result<()> {
        // Drain the staleness window first: reselection mutates the
        // selection state, so no in-flight step's update may cross the
        // boundary — this also keeps the Gumbel draws in their sync stream
        // position relative to the noise draws.
        self.exec.drain(self.state)?;
        self.state.fest_select_with_eps(feature_counts, epsilon)
    }
}

fn run_with(
    cfg: &RunConfig,
    rt: &Runtime,
    src: GenConfig,
    stream_eval_epd: Option<usize>,
) -> Result<Trained> {
    if !rt.is_reference() {
        bail!(
            "the async engine requires the reference runtime backend \
             (PJRT artifacts cannot be chunk-sliced); run without AOT artifacts"
        );
    }
    let model = rt.manifest.model(&cfg.model)?;
    let rm = RefModel::from_manifest(model)?;
    // The grad workers consume batches without going through the shape
    // checks of Runtime::execute, so the generator geometry must be
    // validated against the model up front — a seq_len/vocab mismatch
    // would otherwise scatter gradients onto the wrong rows silently.
    match (&rm, &src) {
        (RefModel::Pctr(m), GenConfig::Pctr(g)) => {
            if g.vocabs != m.vocabs {
                bail!(
                    "generator vocabularies do not match model {} ({} vs {} features)",
                    model.name,
                    g.vocabs.len(),
                    m.vocabs.len()
                );
            }
        }
        (RefModel::Nlu(m), GenConfig::Text(g)) => {
            if g.vocab != m.vocab || g.seq_len != m.seq_len || g.num_classes != m.num_classes
            {
                bail!(
                    "generator geometry (vocab {}, seq_len {}, classes {}) does not \
                     match model {} (vocab {}, seq_len {}, classes {})",
                    g.vocab,
                    g.seq_len,
                    g.num_classes,
                    model.name,
                    m.vocab,
                    m.seq_len,
                    m.num_classes
                );
            }
        }
        _ => bail!("data source kind does not match model {} ({})", model.name, model.kind),
    }
    let store = ParamStore::init(model, cfg.seed)?;
    let (grads_artifact, fwd_artifact) = step::locate_artifacts(&rt.manifest, &cfg.model)?;
    let plan = step::output_plan(rt.manifest.artifact(&grads_artifact)?, &store)?;
    let mut state = StepState::new(cfg.clone(), model, &store)?;
    let (seq_len, num_classes) = match state.meta {
        ModelMeta::Nlu { seq_len, num_classes, .. } => (seq_len, num_classes),
        ModelMeta::Pctr { .. } => (0, 0),
    };
    let b = state.batch_size();

    // Streaming mode follows the shared day/period calendar; it also
    // overrides the step count (18 days × steps/day, with σ re-calibrated
    // to match) and drives its own FEST selections at the period
    // boundaries.  The pCTR generator config is destructured once here —
    // every later streaming branch relies on it.
    let streaming: Option<(StreamSchedule, CriteoConfig)> = match stream_eval_epd {
        Some(epd) => {
            let GenConfig::Pctr(g) = &src else {
                bail!("streaming mode is for pctr models (the 24-day Criteo protocol)");
            };
            let sched = StreamSchedule::new(&state.cfg, b, epd);
            sched.recalibrate(&mut state)?;
            Some((sched, g.clone()))
        }
        None => None,
    };
    let steps = streaming.as_ref().map_or(state.cfg.steps, |(s, _)| s.total_steps());

    // FEST pre-selection — same prior pass and RNG stream as the sync path.
    if streaming.is_none()
        && state.cfg.algorithm.uses_fest_selection()
        && state.fest_selected.is_none()
    {
        match &src {
            GenConfig::Pctr(g) => {
                let gen = SynthCriteo::new(g.clone());
                let counts =
                    pctr_frequency_counts(&gen, &state.emb_tables, 50, state.cfg.seed);
                state.fest_select(&counts)?;
            }
            GenConfig::Text(g) => {
                let gen = SynthText::new(g.clone());
                let counts =
                    text_frequency_counts(&gen, state.total_vocab, 50, state.cfg.seed);
                state.fest_select(&[counts])?;
            }
        }
    }

    let emb_params: Vec<usize> = state.emb_tables.iter().map(|t| t.param_index).collect();
    let ecfg = state.cfg.engine;
    // Scope the process-wide kernel knobs to this run.  Threading is
    // throughput-only (partitions output tiles, never splits a chain);
    // the backend is the one kernel knob that changes bits — `simd`
    // reassociates the k-chains, ULP-bounded vs scalar (tests/kernels.rs,
    // tests/engine.rs, docs/RUNTIME.md).  The guard restores the prior
    // values when the run ends, so back-to-back runs cannot inherit them.
    let _kernel_scope =
        crate::kernels::ScopedConfig::apply(ecfg.kernel_threads, ecfg.kernel_backend);

    let seed = state.cfg.seed;
    let (c1, c2) = step::clip_values(&state.cfg);
    let n_chunks = b.div_ceil(REDUCE_CHUNK);
    let chunks_per_task = ecfg.microbatch_chunks.clamp(1, n_chunks);
    let dplan = DataPlan {
        seed,
        batch_size: b,
        steps,
        steps_per_day: streaming.as_ref().map(|(s, _)| s.steps_per_day),
        with_counts: streaming.as_ref().is_some_and(|(s, _)| s.needs_stream_counts()),
        prior: streaming.as_ref().map_or(PriorPass::None, |(s, _)| s.prior_pass()),
    };
    let nt = rm.num_tables();
    let np = rm.num_params();

    let next_step = AtomicU64::new(0);
    let workers_down = Arc::new(AtomicUsize::new(0));
    let (batch_tx, batch_rx) = mpsc::sync_channel::<BatchMsg>(ecfg.channel_depth.max(1));
    let (task_tx, task_rx) = mpsc::channel::<ChunkTask>();
    let task_rx = Arc::new(Mutex::new(task_rx));
    let (res_tx, res_rx) = mpsc::channel();

    // The telemetry hub travels to every worker by Arc — probing it is
    // atomics and clock reads only, so instrumented workers stay bit-exact.
    let tele = Arc::clone(&state.tele);

    // `--engine-processes ≥ 2` swaps the worker threads for actor
    // processes; the barrier loop below is identical either way.
    let fab = if ecfg.processes >= 2 {
        let spec = actor::ProcSpec {
            model: &state.cfg.model,
            artifacts_dir: &state.cfg.artifacts_dir,
            seed,
            opt_kind: state.cfg.optimizer,
            lr: state.cfg.lr,
            gen: &src,
            plan: dplan,
            n_data: ecfg.data_workers.max(1),
            n_grad: ecfg.processes,
            shards: ecfg.shards.max(1),
            kernel_threads: ecfg.kernel_threads,
            kernel_backend: ecfg.kernel_backend,
            emb_params: &emb_params,
            nt,
            n_chunks,
            store_budget_mb: state.cfg.store_budget_mb,
            store_dir: &state.cfg.store_dir,
        };
        Fabric::Procs(actor::ProcEngine::launch(
            spec,
            store,
            batch_tx.clone(),
            res_tx.clone(),
            Arc::clone(&workers_down),
            Arc::clone(&tele),
        )?)
    } else {
        // `--store-budget-mb > 0` swaps the in-RAM row shards for the
        // file-backed paged tables — throughput/memory-only, bit-exact at
        // any setting (tests/store.rs, tests/engine.rs).
        let opts = StoreOptions {
            budget_mb: state.cfg.store_budget_mb,
            dir: state.cfg.store_dir.clone(),
            tele: Some(Arc::clone(&tele)),
        };
        Fabric::Threads(ShardedStore::from_store_with(
            store,
            &emb_params,
            ecfg.shards.max(1),
            &opts,
        )?)
    };

    // Frozen dense params (the NLU transformer backbone) never receive
    // updates, so snapshot them once; only trainable dense params (the MLP
    // stack / classifier head) are re-cloned per step.
    let static_dense: Vec<Option<Arc<Vec<f32>>>> = (nt..np)
        .map(|i| {
            if fab.is_trainable(i) {
                None
            } else {
                Some(Arc::new(fab.dense_values(i)))
            }
        })
        .collect();

    let reselections = std::thread::scope(|scope| -> Result<Option<usize>> {
        if matches!(fab, Fabric::Threads(_)) {
            for _ in 0..ecfg.data_workers.max(1) {
                let tx = batch_tx.clone();
                let gcfg = src.clone();
                let next = &next_step;
                let tl = Arc::clone(&tele);
                scope.spawn(move || pipeline::data_worker(gcfg, dplan, next, tx, &tl));
            }
            for _ in 0..ecfg.grad_workers.max(1) {
                let rx = Arc::clone(&task_rx);
                let tx = res_tx.clone();
                let rm = &rm;
                let down = &*workers_down;
                let tl = Arc::clone(&tele);
                scope.spawn(move || {
                    // Bump the exit counter even on panic, so the aggregator
                    // can tell a dead worker from a slow one (aggregator.rs).
                    struct ExitGuard<'a>(&'a AtomicUsize);
                    impl Drop for ExitGuard<'_> {
                        fn drop(&mut self) {
                            self.0.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    let _guard = ExitGuard(down);
                    pipeline::grad_worker(rm, &rx, &tx, &tl)
                });
            }
        }
        // In-process: the aggregator detects data-worker exit via channel
        // close.  Multi-process: the actor reader threads hold their own
        // clones, so the channels close when the last reader exits.
        drop(batch_tx);
        drop(res_tx);

        // ---- the aggregation loop (this thread) ----
        let run_loop = |state: &mut StepState| -> Result<Option<usize>> {
            let mut exec = StepExec {
                rm: &rm,
                fab: &fab,
                emb_params: &emb_params,
                static_dense: &static_dense,
                plan: &plan,
                task_tx: &task_tx,
                res_rx: &res_rx,
                workers_down: &*workers_down,
                n_chunks,
                chunks_per_task,
                nt,
                b,
                c1,
                c2,
                seq_len,
                staleness: ecfg.staleness,
                inflight: VecDeque::new(),
                early: BTreeMap::new(),
            };
            // Against actor processes a plain channel recv could hang
            // forever if a data actor dies (its reader thread keeps the
            // channel sender alive until EOF, but mpsc cannot say *which*
            // producer went quiet) — the watchdog variant polls the
            // reader-maintained down counter instead.
            let mut stream = match &fab {
                Fabric::Procs(pe) => {
                    BatchStream::with_watchdog(batch_rx, Arc::clone(&tele), pe.data_down())
                }
                Fabric::Threads(_) => {
                    BatchStream::with_telemetry(batch_rx, Arc::clone(&tele))
                }
            };
            match &streaming {
                None => {
                    for t in 0..steps {
                        let msg = stream.next(t)?;
                        exec.run_step(state, t, msg.batch)?;
                    }
                    exec.drain(state)?;
                    Ok(None)
                }
                Some((sched, _)) => {
                    // Warmup/sniff prior batches come from the data workers
                    // too (sequence keys 0..prior_batches, ahead of the
                    // training steps), so the pre-passes overlap pipeline
                    // fill instead of stalling the barrier.
                    let vocabs: Vec<usize> =
                        state.emb_tables.iter().map(|t| t.vocab).collect();
                    let mut tracker = FrequencyTracker::new(vocabs.len(), sched.source);
                    let n = {
                        let mut driver = EngineDriver {
                            stream,
                            exec: &mut exec,
                            state: &mut *state,
                            prior_batches: sched.prior_pass().num_batches(),
                            count_batches: sched.needs_stream_counts(),
                        };
                        sched.run_days(&mut tracker, &vocabs, &mut driver)?
                    };
                    exec.drain(state)?;
                    Ok(Some(n))
                }
            }
        };
        let result = run_loop(&mut state);
        // Orderly shutdown on both the success and error paths: closing the
        // task channel ends the gradient workers; the batch receiver died
        // with `stream` (end of `run_loop`), which unblocks any data worker
        // parked on a full channel.
        drop(task_tx);
        result
    })?;

    // ---- evaluation on the reassembled store (same streams as sync) ----
    let store = fab.into_store()?;
    match streaming {
        Some((sched, gcfg)) => {
            let gen = SynthCriteo::new(gcfg);
            let (per_day_auc, auc_all, eval_loss) = sched
                .eval_days(&gen, |batches| step::eval_pctr(rt, &fwd_artifact, &store, batches))?;
            let outcome = state.outcome(auc_all, eval_loss);
            Ok(Trained::Streaming(StreamingOutcome {
                outcome,
                per_day_auc,
                reselections: reselections.unwrap_or(0),
            }))
        }
        None => {
            let (utility, eval_loss) = match &src {
                GenConfig::Pctr(g) => {
                    let gen = SynthCriteo::new(g.clone());
                    let eval: Vec<PctrBatch> = (0..state.cfg.eval_batches)
                        .map(|i| {
                            let mut rng = step::eval_batch_rng(seed, i as u64);
                            gen.batch(0, b, &mut rng)
                        })
                        .collect();
                    step::eval_pctr(rt, &fwd_artifact, &store, &eval)?
                }
                GenConfig::Text(g) => {
                    let gen = SynthText::new(g.clone());
                    let eval: Vec<TextBatch> = (0..state.cfg.eval_batches)
                        .map(|i| {
                            let mut rng = step::eval_batch_rng(seed, i as u64);
                            gen.batch(b, &mut rng)
                        })
                        .collect();
                    step::eval_text(rt, &fwd_artifact, &store, &eval, num_classes)?
                }
            };
            Ok(Trained::Plain(state.outcome(utility, eval_loss), store))
        }
    }
}

/// One row of a sync-vs-async throughput comparison.
#[derive(Clone, Debug)]
pub struct ThroughputRow {
    /// which path produced the row (`"sync"` or `"async"`)
    pub path: &'static str,
    /// gradient workers the engine ran with (1 for the sync row)
    pub grad_workers: usize,
    /// wall-clock seconds for the run (train + eval), taken from the run's
    /// telemetry clock — the same clock the JSONL traces are measured on
    pub secs: f64,
    /// training steps per second
    pub steps_per_sec: f64,
    /// relative to the sync row (sync row reports 1.0)
    pub speedup: f64,
}

/// Timed sync-vs-async comparison on one config: warms the σ-calibration
/// cache, runs the sync trainer once, then the engine at each worker count,
/// asserting the loss histories bit-identical throughout.  Shared by the
/// tab4 harness and `benches/engine_throughput.rs` so the protocol cannot
/// drift between them.  Wall clock is single-sourced from each run's
/// telemetry ([`crate::telemetry::RunSummary::wall_secs`]) rather than an
/// ad-hoc `Instant` around the call.
pub fn compare_throughput(
    cfg: &RunConfig,
    rt: &Runtime,
    gen_cfg: &CriteoConfig,
    worker_counts: &[usize],
) -> Result<Vec<ThroughputRow>> {
    use crate::coordinator::Trainer;
    // warm calibration so every timed run measures the training loop
    let _ = Trainer::new(cfg.clone(), rt)?;

    let mut rows = Vec::with_capacity(1 + worker_counts.len());
    let mut trainer = Trainer::new(cfg.clone(), rt)?;
    let gen = SynthCriteo::new(gen_cfg.clone());
    let sync_out = trainer.run_pctr(&gen)?;
    let sync_secs = sync_out.telemetry.wall_secs;
    let sync_sps = cfg.steps as f64 / sync_secs;
    rows.push(ThroughputRow {
        path: "sync",
        grad_workers: 1,
        secs: sync_secs,
        steps_per_sec: sync_sps,
        speedup: 1.0,
    });

    for &workers in worker_counts {
        let mut c = cfg.clone();
        c.engine.grad_workers = workers;
        // the loss-equality gate below requires the bit-exact window
        c.engine.staleness = 0;
        let out = run_pctr(&c, rt, gen_cfg.clone())?;
        let secs = out.telemetry.wall_secs;
        if out.loss_history != sync_out.loss_history {
            bail!("async engine ({workers} workers) diverged from the sync trainer");
        }
        let sps = cfg.steps as f64 / secs;
        rows.push(ThroughputRow {
            path: "async",
            grad_workers: workers,
            secs,
            steps_per_sec: sps,
            speedup: sps / sync_sps,
        });
    }
    Ok(rows)
}
