//! Asynchronous sharded DP training engine.
//!
//! Runs the same Algorithm-1 semantics as the synchronous
//! [`Trainer`](crate::coordinator::Trainer), pipelined across threads:
//!
//! ```text
//!  data workers (N)          gradient workers (M)        aggregation barrier
//!  ───────────────           ────────────────────        ───────────────────
//!  step counter ──┐           ┌── ChunkTask ◀─────────────── dispatch per step
//!  gen batch(t) ──┴─▶ bounded │   (16-example reduction       │
//!                    channel  │    chunks, shared param       ▼
//!  (t, batch) ──▶ BatchStream │    snapshot + sharded      merge chunks in order
//!                  (reorder)  │    embedding reads)           │
//!                             └──▶ (chunk, grads) ──────────▶ select ∘ noise(σ₁σ₂)
//!                                                             ∘ sharded update
//! ```
//!
//! The pipeline is **kind-generic**: [`run`] derives the data source from
//! the model manifest and drives either workload — the Criteo tower
//! ([`run_pctr`]) or the NLU transformer ([`run_text`]) — through the same
//! worker bodies, with the chunk math dispatched by
//! [`RefModel`](crate::runtime::reference::RefModel).
//!
//! **Bit-for-bit equivalence with the sync path** rests on three documented
//! invariants (each with a test in `tests/engine.rs`, for both workloads):
//!
//! 1. *Batch streams* — batch `t` comes from the self-contained RNG
//!    `train_batch_rng(seed, t)`, so data workers can produce batches in
//!    any order ([`crate::coordinator::step`]).
//! 2. *Fixed-chunk reductions* — all batch reductions merge 16-example
//!    chunk partials in chunk order, independent of worker count
//!    ([`crate::runtime::reference`]).
//! 3. *Noise draw order* — every DP random draw happens once per logical
//!    batch, serially, at the aggregation barrier, from the single
//!    [`StepState`](crate::coordinator::step::StepState) RNG.
//!
//! The engine requires the reference runtime backend (PJRT artifacts have a
//! fixed batch shape and cannot compute per-chunk partials); with `xla`
//! artifacts use the sync trainer.

mod aggregator;
mod pipeline;
mod sharded_store;

pub use aggregator::collect_step;
pub use pipeline::{BatchStream, ChunkTask, WorkerView};
pub use sharded_store::{ShardedStore, ShardedTable};

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::coordinator::step::{self, ModelMeta, StepState, TrainOutcome};
use crate::coordinator::{pctr_frequency_counts, text_frequency_counts};
use crate::data::{
    Batch, CriteoConfig, GenConfig, PctrBatch, SynthCriteo, SynthText, TextBatch,
    TextConfig,
};
use crate::models::ParamStore;
use crate::runtime::reference::{RefModel, REDUCE_CHUNK};
use crate::runtime::Runtime;

/// Run a full async training (train → eval) for whatever kind of model
/// `cfg.model` names, deriving the synthetic data source from the manifest
/// exactly as the sync CLI path does.  Returns the same [`TrainOutcome`] as
/// the sync trainer — bitwise, given the same config and seed.
pub fn run(cfg: &RunConfig, rt: &Runtime) -> Result<TrainOutcome> {
    let model = rt.manifest.model(&cfg.model)?;
    let src = match model.kind.as_str() {
        "pctr" => GenConfig::Pctr(CriteoConfig::new(
            model.attr_usize_list("vocabs")?,
            cfg.seed ^ 0xDA7A,
        )),
        "nlu" => GenConfig::Text(TextConfig::from_model(model, cfg.seed ^ 0xDA7A)?),
        other => bail!("unknown model kind {other}"),
    };
    run_with(cfg, rt, src)
}

/// Async pCTR training over an explicit generator config (harness/bench
/// entry point; [`run`] derives the config from the manifest instead).
pub fn run_pctr(cfg: &RunConfig, rt: &Runtime, gen_cfg: CriteoConfig) -> Result<TrainOutcome> {
    run_with(cfg, rt, GenConfig::Pctr(gen_cfg))
}

/// Async NLU training over an explicit generator config.
pub fn run_text(cfg: &RunConfig, rt: &Runtime, gen_cfg: TextConfig) -> Result<TrainOutcome> {
    run_with(cfg, rt, GenConfig::Text(gen_cfg))
}

fn run_with(cfg: &RunConfig, rt: &Runtime, src: GenConfig) -> Result<TrainOutcome> {
    if !rt.is_reference() {
        bail!(
            "the async engine requires the reference runtime backend \
             (PJRT artifacts cannot be chunk-sliced); run without AOT artifacts"
        );
    }
    let model = rt.manifest.model(&cfg.model)?;
    let rm = RefModel::from_manifest(model)?;
    // The grad workers consume batches without going through the shape
    // checks of Runtime::execute, so the generator geometry must be
    // validated against the model up front — a seq_len/vocab mismatch
    // would otherwise scatter gradients onto the wrong rows silently.
    match (&rm, &src) {
        (RefModel::Pctr(m), GenConfig::Pctr(g)) => {
            if g.vocabs != m.vocabs {
                bail!(
                    "generator vocabularies do not match model {} ({} vs {} features)",
                    model.name,
                    g.vocabs.len(),
                    m.vocabs.len()
                );
            }
        }
        (RefModel::Nlu(m), GenConfig::Text(g)) => {
            if g.vocab != m.vocab || g.seq_len != m.seq_len || g.num_classes != m.num_classes
            {
                bail!(
                    "generator geometry (vocab {}, seq_len {}, classes {}) does not \
                     match model {} (vocab {}, seq_len {}, classes {})",
                    g.vocab,
                    g.seq_len,
                    g.num_classes,
                    model.name,
                    m.vocab,
                    m.seq_len,
                    m.num_classes
                );
            }
        }
        _ => bail!("data source kind does not match model {} ({})", model.name, model.kind),
    }
    let store = ParamStore::init(model, cfg.seed)?;
    let (grads_artifact, fwd_artifact) = step::locate_artifacts(&rt.manifest, &cfg.model)?;
    let plan = step::output_plan(rt.manifest.artifact(&grads_artifact)?, &store)?;
    let mut state = StepState::new(cfg.clone(), model, &store)?;
    let (seq_len, num_classes) = match state.meta {
        ModelMeta::Nlu { seq_len, num_classes, .. } => (seq_len, num_classes),
        ModelMeta::Pctr { .. } => (0, 0),
    };

    // FEST pre-selection — same prior pass and RNG stream as the sync path.
    if state.cfg.algorithm.uses_fest_selection() && state.fest_selected.is_none() {
        match &src {
            GenConfig::Pctr(g) => {
                let gen = SynthCriteo::new(g.clone());
                let counts =
                    pctr_frequency_counts(&gen, &state.emb_tables, 50, state.cfg.seed);
                state.fest_select(&counts)?;
            }
            GenConfig::Text(g) => {
                let gen = SynthText::new(g.clone());
                let counts =
                    text_frequency_counts(&gen, state.total_vocab, 50, state.cfg.seed);
                state.fest_select(&[counts])?;
            }
        }
    }

    let emb_params: Vec<usize> = state.emb_tables.iter().map(|t| t.param_index).collect();
    let ecfg = state.cfg.engine;
    let estore = ShardedStore::from_store(store, &emb_params, ecfg.shards.max(1))?;

    let b = state.batch_size();
    let steps = state.cfg.steps;
    let seed = state.cfg.seed;
    let (c1, c2) = step::clip_values(&state.cfg);
    let n_chunks = (b + REDUCE_CHUNK - 1) / REDUCE_CHUNK;
    let chunks_per_task = ecfg.microbatch_chunks.clamp(1, n_chunks);

    // Frozen dense params (the NLU transformer backbone) never receive
    // updates, so snapshot them once; only trainable dense params (the MLP
    // stack / classifier head) are re-cloned per step.
    let nt = rm.num_tables();
    let np = rm.num_params();
    let static_dense: Vec<Option<Arc<Vec<f32>>>> = (nt..np)
        .map(|i| {
            if estore.is_trainable(i) {
                None
            } else {
                Some(Arc::new(estore.dense_values(i)))
            }
        })
        .collect();

    let next_step = AtomicU64::new(0);
    let workers_down = AtomicUsize::new(0);
    let (batch_tx, batch_rx) = mpsc::sync_channel::<(u64, Batch)>(ecfg.channel_depth.max(1));
    let (task_tx, task_rx) = mpsc::channel::<ChunkTask>();
    let task_rx = Arc::new(Mutex::new(task_rx));
    let (res_tx, res_rx) = mpsc::channel();

    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..ecfg.data_workers.max(1) {
            let tx = batch_tx.clone();
            let gcfg = src.clone();
            let next = &next_step;
            scope.spawn(move || pipeline::data_worker(gcfg, seed, b, steps, next, tx));
        }
        drop(batch_tx); // aggregator detects data-worker exit via channel close

        for _ in 0..ecfg.grad_workers.max(1) {
            let rx = Arc::clone(&task_rx);
            let tx = res_tx.clone();
            let (rm, estore, emb) = (&rm, &estore, &emb_params[..]);
            let down = &workers_down;
            scope.spawn(move || {
                // Bump the exit counter even on panic, so the aggregator
                // can tell a dead worker from a slow one (aggregator.rs).
                struct ExitGuard<'a>(&'a AtomicUsize);
                impl Drop for ExitGuard<'_> {
                    fn drop(&mut self) {
                        self.0.fetch_add(1, Ordering::SeqCst);
                    }
                }
                let _guard = ExitGuard(down);
                pipeline::grad_worker(rm, estore, emb, &rx, &tx)
            });
        }
        drop(res_tx);

        // ---- the aggregation loop (this thread) ----
        let run_loop = |state: &mut StepState| -> Result<()> {
            let mut stream = BatchStream::new(batch_rx);
            for t in 0..steps {
                let batch = Arc::new(stream.next(t)?);
                if batch.batch_size() != b {
                    bail!("batch size {} != model batch {b}", batch.batch_size());
                }
                let dense: Arc<Vec<Arc<Vec<f32>>>> = Arc::new(
                    static_dense
                        .iter()
                        .enumerate()
                        .map(|(j, frozen)| match frozen {
                            Some(a) => Arc::clone(a),
                            None => Arc::new(estore.dense_values(nt + j)),
                        })
                        .collect(),
                );
                let mut c0 = 0usize;
                while c0 < n_chunks {
                    let c1_idx = (c0 + chunks_per_task).min(n_chunks);
                    task_tx
                        .send(ChunkTask {
                            chunks: c0..c1_idx,
                            batch: Arc::clone(&batch),
                            dense: Arc::clone(&dense),
                            c1,
                            c2,
                        })
                        .ok()
                        .context("gradient workers terminated early")?;
                    c0 = c1_idx;
                }
                let outs = collect_step(&rm, n_chunks, &res_rx, &workers_down)?;
                let need_counts = state.cfg.algorithm.uses_contribution_map();
                let bundle = match batch.as_ref() {
                    Batch::Pctr(pb) => step::assemble_pctr(
                        &plan,
                        &outs,
                        &state.emb_tables,
                        pb,
                        need_counts,
                    )?,
                    Batch::Text(tb) => step::assemble_text(
                        &plan,
                        &outs,
                        &state.emb_tables,
                        tb,
                        seq_len,
                        need_counts,
                    )?,
                };
                let mut sink = &estore;
                state.apply_update(bundle, &mut sink)?;
            }
            Ok(())
        };
        let result = run_loop(&mut state);
        // Orderly shutdown on both the success and error paths: closing the
        // task channel ends the gradient workers; the batch receiver died
        // with `stream` (end of `run_loop`), which unblocks any data worker
        // parked on a full channel.
        drop(task_tx);
        result
    })?;

    // ---- evaluation on the reassembled store (same stream as sync) ----
    let store = estore.into_store()?;
    let (utility, eval_loss) = match &src {
        GenConfig::Pctr(g) => {
            let gen = SynthCriteo::new(g.clone());
            let eval: Vec<PctrBatch> = (0..state.cfg.eval_batches)
                .map(|i| {
                    let mut rng = step::eval_batch_rng(seed, i as u64);
                    gen.batch(0, b, &mut rng)
                })
                .collect();
            step::eval_pctr(rt, &fwd_artifact, &store, &eval)?
        }
        GenConfig::Text(g) => {
            let gen = SynthText::new(g.clone());
            let eval: Vec<TextBatch> = (0..state.cfg.eval_batches)
                .map(|i| {
                    let mut rng = step::eval_batch_rng(seed, i as u64);
                    gen.batch(b, &mut rng)
                })
                .collect();
            step::eval_text(rt, &fwd_artifact, &store, &eval, num_classes)?
        }
    };
    Ok(state.outcome(utility, eval_loss))
}

/// One row of a sync-vs-async throughput comparison.
#[derive(Clone, Debug)]
pub struct ThroughputRow {
    pub path: &'static str,
    pub grad_workers: usize,
    pub secs: f64,
    pub steps_per_sec: f64,
    /// relative to the sync row (sync row reports 1.0)
    pub speedup: f64,
}

/// Timed sync-vs-async comparison on one config: warms the σ-calibration
/// cache, runs the sync trainer once, then the engine at each worker count,
/// asserting the loss histories bit-identical throughout.  Shared by the
/// tab4 harness and `benches/engine_throughput.rs` so the protocol cannot
/// drift between them.
pub fn compare_throughput(
    cfg: &RunConfig,
    rt: &Runtime,
    gen_cfg: &CriteoConfig,
    worker_counts: &[usize],
) -> Result<Vec<ThroughputRow>> {
    use crate::coordinator::Trainer;
    // warm calibration so every timed run measures the training loop
    let _ = Trainer::new(cfg.clone(), rt)?;

    let mut rows = Vec::with_capacity(1 + worker_counts.len());
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(cfg.clone(), rt)?;
    let gen = SynthCriteo::new(gen_cfg.clone());
    let sync_out = trainer.run_pctr(&gen)?;
    let sync_secs = t0.elapsed().as_secs_f64();
    let sync_sps = cfg.steps as f64 / sync_secs;
    rows.push(ThroughputRow {
        path: "sync",
        grad_workers: 1,
        secs: sync_secs,
        steps_per_sec: sync_sps,
        speedup: 1.0,
    });

    for &workers in worker_counts {
        let mut c = cfg.clone();
        c.engine.grad_workers = workers;
        let t0 = std::time::Instant::now();
        let out = run_pctr(&c, rt, gen_cfg.clone())?;
        let secs = t0.elapsed().as_secs_f64();
        if out.loss_history != sync_out.loss_history {
            bail!("async engine ({workers} workers) diverged from the sync trainer");
        }
        let sps = cfg.steps as f64 / secs;
        rows.push(ThroughputRow {
            path: "async",
            grad_workers: workers,
            secs,
            steps_per_sec: sps,
            speedup: sps / sync_sps,
        });
    }
    Ok(rows)
}
