//! Worker side of the async engine: pipelined data loaders and per-example
//! gradient workers, generic over both workloads (pCTR and NLU).
//!
//! * **Data workers** claim sequence indices off a shared atomic counter
//!   and generate that item's batch from its self-contained RNG, sending a
//!   [`BatchMsg`] over a bounded channel — order across workers is
//!   irrelevant, the [`BatchStream`] reorders.  Backpressure comes from the
//!   channel bound.  The sequence starts with the streaming run's prior
//!   pass (warmup/sniff batches from `prior_batch_rng`, always shipped with
//!   their frequency counts), followed by the training steps
//!   ([`step::train_batch_rng`]).  In streaming mode the [`DataPlan`] maps
//!   each step to its simulated day and the workers also aggregate the
//!   batch's per-feature bucket counts, so the barrier can feed its
//!   `FrequencyTracker` without re-scanning batches.
//! * **Gradient workers** pull [`ChunkTask`]s (a range of fixed 16-example
//!   reduction chunks of one step's batch), compute per-example clipped
//!   gradients against that step's read-only snapshots — the [`RowCache`]
//!   of every embedding row the batch touches plus the dense parameters —
//!   and send `(step, chunk_index, ChunkGrads)` to the aggregation barrier.
//!   The step tag is what lets the barrier pipeline up to
//!   `--engine-staleness` steps concurrently and still merge each step's
//!   chunks in order.  The chunk math dispatches through [`RefModel`], so
//!   the same worker body drives the Criteo tower and the transformer.
//!
//! Shutdown is purely channel-driven: dropping the task sender ends the
//! gradient workers, dropping the batch receiver ends the data workers
//! (their `send` fails), and workers never block on result sends (the
//! result channel is unbounded).  `tests/engine.rs` exercises the
//! no-deadlock property.

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::step;
use crate::coordinator::streaming::{self, PriorPass};
use crate::data::{Batch, GenConfig, Generator};
use crate::runtime::reference::{BatchRef, ChunkGrads, ParamsView, RefModel, REDUCE_CHUNK};
use crate::telemetry::{Queue, Stage, Telemetry};

use crate::store::ShardedStore;

/// What the data workers produce: which steps, how steps map to simulated
/// days, and whether per-batch frequency counts ride along.
#[derive(Clone, Copy, Debug)]
pub struct DataPlan {
    /// run seed — batch `t` derives from [`step::train_batch_rng`]`(seed, t)`
    pub seed: u64,
    /// examples per batch
    pub batch_size: usize,
    /// total number of training steps to produce
    pub steps: u64,
    /// streaming mode: steps per simulated day (`day = t / steps_per_day`);
    /// `None` generates everything from day 0 (stationary)
    pub steps_per_day: Option<u64>,
    /// aggregate per-feature bucket counts for every batch (streaming mode —
    /// they feed the barrier's `FrequencyTracker` at period boundaries)
    pub with_counts: bool,
    /// warmup / cold-start prior batches produced *before* the training
    /// stream (streaming mode; [`PriorPass::None`] elsewhere).  Prior
    /// batches always ship their frequency counts — counting them is their
    /// entire purpose
    pub prior: PriorPass,
}

/// One data-worker message: one batch of the run's reordered sequence, plus
/// its per-feature `(bucket, count)` pairs when the [`DataPlan`] asks for
/// them.  Sequence keys: prior batch `i` is key `i`, training step `t` is
/// key `prior.num_batches() + t`.
#[derive(Clone, Debug)]
pub struct BatchMsg {
    /// sequence key of this batch in the reordered stream
    pub step: u64,
    /// the generated batch
    pub batch: Batch,
    /// per-feature sorted `(bucket, count)` pairs (pCTR streaming mode only)
    pub counts: Option<Vec<Vec<(u32, u32)>>>,
}

/// One unit of gradient work: reduction chunks `chunks` of step `step`'s
/// batch.
pub struct ChunkTask {
    /// which training step the chunks belong to — echoed back with every
    /// result so the barrier can keep several steps in flight
    /// (`--engine-staleness`) and still collect each one in chunk order
    pub step: u64,
    /// which fixed 16-example reduction chunks of the batch to compute
    pub chunks: Range<usize>,
    /// the step's batch (shared across the step's tasks)
    pub batch: Arc<Batch>,
    /// per-step snapshot of every embedding row the batch touches,
    /// read lock-free by the workers
    pub rows: Arc<RowCache>,
    /// per-step snapshot of the dense (non-table) parameters, read-only;
    /// frozen entries are shared across steps (the engine clones only the
    /// trainable dense params each step)
    pub dense: Arc<Vec<Arc<Vec<f32>>>>,
    /// contribution-map clip norm C₁
    pub c1: f32,
    /// gradient clip norm C₂
    pub c2: f32,
}

/// Per-step read-only snapshot of every embedding row the batch touches —
/// rows of the full table, or of the LoRA `emb_lora_a` factor when that is
/// the model's sparse table (the row width comes from the sharded store).
///
/// Built once per step at the aggregation barrier — after the previous
/// step's updates and before this step's dispatch, so it is bit-identical
/// to what live per-shard reads would return — and shared with the
/// gradient workers through the [`ChunkTask`]s.  Workers resolve
/// [`ParamsView::emb_row`] by binary search into the snapshot instead of
/// taking a shard lock per lookup: each unique row is gathered exactly
/// once per step instead of once per chunk per worker (the ROADMAP
/// lock-traffic item).
pub struct RowCache {
    feats: Vec<FeatRows>,
}

struct FeatRows {
    /// sorted unique table-local rows of this feature present in the batch
    rows: Vec<u32>,
    /// row values packed in `rows` order
    values: Vec<f32>,
    dim: usize,
}

impl RowCache {
    /// The batch's sorted, deduplicated table-local rows, per embedding
    /// feature — the "which rows" half of a snapshot, with no values read
    /// yet.  The multi-process barrier uses this directly to build its
    /// per-owner `FetchRows` requests (`engine::actor`).
    pub(crate) fn unique_rows(batch: &Batch) -> Vec<Vec<u32>> {
        let mut per_feature: Vec<Vec<u32>> = match batch {
            Batch::Pctr(b) => (0..b.num_features)
                .map(|f| (0..b.batch_size).map(|i| b.cat_of(i, f) as u32).collect())
                .collect(),
            Batch::Text(b) => vec![b.ids.iter().map(|&t| t as u32).collect()],
        };
        for rows in &mut per_feature {
            rows.sort_unstable();
            rows.dedup();
        }
        per_feature
    }

    /// Gather the batch's unique rows, feature by feature, from the sharded
    /// store (one locked read per unique row).
    pub fn build(batch: &Batch, store: &ShardedStore, emb_params: &[usize]) -> RowCache {
        let feats = Self::unique_rows(batch)
            .into_iter()
            .zip(emb_params)
            .map(|(rows, &param)| {
                let dim = store.emb_row_dim(param);
                let mut values = vec![0f32; rows.len() * dim];
                for (k, &row) in rows.iter().enumerate() {
                    store.read_emb_row(param, row as usize, &mut values[k * dim..(k + 1) * dim]);
                }
                FeatRows { rows, values, dim }
            })
            .collect();
        RowCache { feats }
    }

    /// Assemble a cache from per-feature `(sorted rows, packed values, dim)`
    /// parts — the multi-process barrier concatenates per-owner fetches into
    /// these, and the gradient actors rebuild the cache from the wire.
    pub(crate) fn from_parts(feats: Vec<(Vec<u32>, Vec<f32>, usize)>) -> RowCache {
        let feats = feats
            .into_iter()
            .map(|(rows, values, dim)| FeatRows { rows, values, dim })
            .collect();
        RowCache { feats }
    }

    /// Per-feature `(rows, values, dim)` views of the cache, in feature
    /// order — the inverse of [`RowCache::from_parts`], used to put a
    /// snapshot on the wire.
    pub(crate) fn parts(&self) -> impl Iterator<Item = (&[u32], &[f32], usize)> {
        self.feats.iter().map(|f| (f.rows.as_slice(), f.values.as_slice(), f.dim))
    }

    /// The cached row, by feature and table-local row id.
    ///
    /// # Panics
    /// If the row is not in the step's batch — the executors only ever read
    /// batch rows, so a miss is a programming error, not a data condition.
    #[inline]
    pub fn row(&self, feature: usize, row: usize) -> &[f32] {
        let fr = &self.feats[feature];
        let k = fr
            .rows
            .binary_search(&(row as u32))
            .expect("row outside the per-step cache");
        &fr.values[k * fr.dim..(k + 1) * fr.dim]
    }
}

/// [`ParamsView`] over the step's read-only snapshots: the [`RowCache`]
/// (embedding rows, lock-free) plus the dense-parameter snapshot.
pub struct WorkerView<'a> {
    /// per-step snapshot of the batch's embedding rows
    pub rows: &'a RowCache,
    /// per-step snapshot of the dense (non-table) parameters
    pub dense: &'a [Arc<Vec<f32>>],
}

impl ParamsView for WorkerView<'_> {
    fn emb_row(&self, feature: usize, row: usize, out: &mut [f32]) {
        out.copy_from_slice(self.rows.row(feature, row));
    }

    fn mlp(&self, index: usize) -> &[f32] {
        self.dense[index].as_slice()
    }
}

/// Generate sequence item `seq` of a [`DataPlan`] — the self-contained
/// per-item body shared by the in-process data workers and the data actor
/// processes (`engine::actor`).  The first `prior.num_batches()` sequence
/// items are the streaming run's prior pass (warmup / cold-start sniff)
/// from its own tagged RNG stream; training step `t` rides at sequence key
/// `n_prior + t`.
pub(crate) fn gen_item(gen: &Generator, plan: &DataPlan, seq: u64, tele: &Telemetry) -> BatchMsg {
    let n_prior = plan.prior.num_batches();
    let (day, mut rng, is_prior) = if seq < n_prior {
        (plan.prior.day_of(seq), streaming::prior_batch_rng(plan.seed, seq), true)
    } else {
        let step_idx = seq - n_prior;
        let day = match plan.steps_per_day {
            Some(spd) => streaming::day_of_step(spd, step_idx),
            None => 0,
        };
        (day, step::train_batch_rng(plan.seed, step_idx), false)
    };
    let _span = tele.span(Stage::DataGenerate);
    let batch = gen.batch(day, plan.batch_size, &mut rng);
    let counts = match (&batch, is_prior || plan.with_counts) {
        (Batch::Pctr(pb), true) => Some(streaming::pctr_batch_counts(pb)),
        _ => None,
    };
    BatchMsg { step: seq, batch, counts }
}

/// Body of one data-worker thread.
pub fn data_worker(
    gen_cfg: GenConfig,
    plan: DataPlan,
    next_step: &AtomicU64,
    tx: SyncSender<BatchMsg>,
    tele: &Telemetry,
) {
    let gen = Generator::new(gen_cfg);
    let n_prior = plan.prior.num_batches();
    loop {
        let seq = next_step.fetch_add(1, Ordering::Relaxed);
        if seq >= n_prior + plan.steps {
            return;
        }
        let msg = gen_item(&gen, &plan, seq, tele);
        // gauge up *before* the (possibly blocking) send so the depth also
        // counts producers stalled on a full channel — backpressure shows as
        // depth pinned at `channel_depth + data_workers`
        tele.queue_inc(Queue::Batch);
        let _span = tele.span(Stage::DataSend);
        if tx.send(msg).is_err() {
            return; // aggregator gone — shut down
        }
    }
}

/// Body of one gradient-worker thread.
pub fn grad_worker(
    model: &RefModel,
    tasks: &Mutex<Receiver<ChunkTask>>,
    results: &Sender<(u64, usize, ChunkGrads)>,
    tele: &Telemetry,
) {
    loop {
        // hold the lock only for the recv, not for the compute
        let task = {
            let _span = tele.span(Stage::TaskWait);
            tasks.lock().unwrap().recv()
        };
        let Ok(task) = task else { return };
        tele.queue_dec(Queue::Task);
        let view = WorkerView { rows: task.rows.as_ref(), dense: task.dense.as_slice() };
        let batch = BatchRef::from_batch(&task.batch);
        let b = task.batch.batch_size();
        for chunk in task.chunks.clone() {
            let lo = chunk * REDUCE_CHUNK;
            let hi = (lo + REDUCE_CHUNK).min(b);
            let out = tele.time(Stage::ChunkCompute, || {
                model.grads_chunk(&view, &batch, lo, hi, task.c1, task.c2)
            });
            if results.send((task.step, chunk, out)).is_err() {
                return;
            }
        }
    }
}

/// Reorders the data workers' out-of-order [`BatchMsg`] stream.
pub struct BatchStream {
    rx: Receiver<BatchMsg>,
    pending: BTreeMap<u64, BatchMsg>,
    tele: Option<Arc<Telemetry>>,
    /// Multi-process mode: count of data actor processes that died without
    /// completing their sequence slice.  In-process data workers share the
    /// channel's sender set, so a dead worker closes the channel; a dead
    /// data actor *process* does not (the surviving actors keep their
    /// senders open), so the stream polls this counter on a timeout to turn
    /// the hang into an error.
    down: Option<Arc<AtomicUsize>>,
}

impl BatchStream {
    /// Wrap the receiving end of the data workers' channel.
    pub fn new(rx: Receiver<BatchMsg>) -> BatchStream {
        BatchStream { rx, pending: BTreeMap::new(), tele: None, down: None }
    }

    /// Like [`BatchStream::new`], but receive waits and queue-depth changes
    /// are reported to `tele`.
    pub fn with_telemetry(rx: Receiver<BatchMsg>, tele: Arc<Telemetry>) -> BatchStream {
        BatchStream { rx, pending: BTreeMap::new(), tele: Some(tele), down: None }
    }

    /// Like [`BatchStream::with_telemetry`], plus a watchdog on `down`: when
    /// a producer *process* dies mid-sequence (counter goes nonzero) the
    /// blocked receive becomes a bounded-time error instead of a deadlock.
    pub fn with_watchdog(
        rx: Receiver<BatchMsg>,
        tele: Arc<Telemetry>,
        down: Arc<AtomicUsize>,
    ) -> BatchStream {
        BatchStream { rx, pending: BTreeMap::new(), tele: Some(tele), down: Some(down) }
    }

    fn recv(&self, step: u64) -> Result<BatchMsg> {
        let Some(down) = &self.down else {
            return self
                .rx
                .recv()
                .map_err(|_| anyhow::anyhow!("data workers exited before producing step {step}"));
        };
        loop {
            match self.rx.recv_timeout(Duration::from_millis(200)) {
                Ok(m) => return Ok(m),
                Err(RecvTimeoutError::Timeout) => {
                    if down.load(Ordering::SeqCst) > 0 {
                        bail!("a data actor process terminated before producing step {step}");
                    }
                }
                // The channel can also close *after* the death: the dead
                // actor's reader is gone and the surviving actors finished
                // their slices — attribute that to the death too, so the
                // error is deterministic whichever side of the race wins.
                Err(RecvTimeoutError::Disconnected) => {
                    if down.load(Ordering::SeqCst) > 0 {
                        bail!("a data actor process terminated before producing step {step}");
                    }
                    bail!("data workers exited before producing step {step}")
                }
            }
        }
    }

    /// Block until the message for `step` is available.
    pub fn next(&mut self, step: u64) -> Result<BatchMsg> {
        loop {
            if let Some(m) = self.pending.remove(&step) {
                return Ok(m);
            }
            let received = match &self.tele {
                Some(tele) => {
                    let _span = tele.span(Stage::BatchWait);
                    self.recv(step)
                }
                None => self.recv(step),
            };
            let m = received?;
            if let Some(tele) = &self.tele {
                tele.queue_dec(Queue::Batch);
            }
            self.pending.insert(m.step, m);
        }
    }
}
