//! Worker side of the async engine: pipelined data loaders and per-example
//! gradient workers, generic over both workloads (pCTR and NLU).
//!
//! * **Data workers** claim step indices off a shared atomic counter and
//!   generate that step's batch from its self-contained RNG
//!   ([`step::train_batch_rng`]), sending `(step, batch)` over a bounded
//!   channel — order across workers is irrelevant, the [`BatchStream`]
//!   reorders.  Backpressure comes from the channel bound.
//! * **Gradient workers** pull [`ChunkTask`]s (a range of fixed 16-example
//!   reduction chunks of the current step's batch), compute per-example
//!   clipped gradients against a read-only view of the sharded store + a
//!   dense-parameter snapshot, and send `(chunk_index, ChunkGrads)` to the
//!   aggregation barrier.  The chunk math dispatches through [`RefModel`],
//!   so the same worker body drives the Criteo tower and the transformer.
//!
//! Shutdown is purely channel-driven: dropping the task sender ends the
//! gradient workers, dropping the batch receiver ends the data workers
//! (their `send` fails), and workers never block on result sends (the
//! result channel is unbounded).  `tests/engine.rs` exercises the
//! no-deadlock property.

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::coordinator::step;
use crate::data::{Batch, GenConfig, Generator};
use crate::runtime::reference::{BatchRef, ChunkGrads, ParamsView, RefModel, REDUCE_CHUNK};

use super::sharded_store::ShardedStore;

/// One unit of gradient work: reduction chunks `chunks` of the step's batch.
pub struct ChunkTask {
    pub chunks: Range<usize>,
    pub batch: Arc<Batch>,
    /// per-step snapshot of the dense (non-table) parameters, read-only;
    /// frozen entries are shared across steps (the engine clones only the
    /// trainable dense params each step)
    pub dense: Arc<Vec<Arc<Vec<f32>>>>,
    pub c1: f32,
    pub c2: f32,
}

/// [`ParamsView`] over the sharded store (embedding rows through per-shard
/// locks) plus the step's dense snapshot (lock-free).
pub struct WorkerView<'a> {
    pub store: &'a ShardedStore,
    /// param index of each embedding table, in feature order
    pub emb_params: &'a [usize],
    pub dense: &'a [Arc<Vec<f32>>],
}

impl ParamsView for WorkerView<'_> {
    fn emb_row(&self, feature: usize, row: usize, out: &mut [f32]) {
        self.store.read_emb_row(self.emb_params[feature], row, out);
    }

    fn mlp(&self, index: usize) -> &[f32] {
        self.dense[index].as_slice()
    }
}

/// Body of one data-worker thread.
pub fn data_worker(
    gen_cfg: GenConfig,
    seed: u64,
    batch_size: usize,
    steps: u64,
    next_step: &AtomicU64,
    tx: SyncSender<(u64, Batch)>,
) {
    let gen = Generator::new(gen_cfg);
    loop {
        let step_idx = next_step.fetch_add(1, Ordering::Relaxed);
        if step_idx >= steps {
            return;
        }
        let mut rng = step::train_batch_rng(seed, step_idx);
        let batch = gen.batch(batch_size, &mut rng);
        if tx.send((step_idx, batch)).is_err() {
            return; // aggregator gone — shut down
        }
    }
}

/// Body of one gradient-worker thread.
pub fn grad_worker(
    model: &RefModel,
    store: &ShardedStore,
    emb_params: &[usize],
    tasks: &Mutex<Receiver<ChunkTask>>,
    results: &Sender<(usize, ChunkGrads)>,
) {
    loop {
        // hold the lock only for the recv, not for the compute
        let task = { tasks.lock().unwrap().recv() };
        let Ok(task) = task else { return };
        let view = WorkerView { store, emb_params, dense: task.dense.as_slice() };
        let batch = BatchRef::from_batch(&task.batch);
        let b = task.batch.batch_size();
        for chunk in task.chunks.clone() {
            let lo = chunk * REDUCE_CHUNK;
            let hi = (lo + REDUCE_CHUNK).min(b);
            let out = model.grads_chunk(&view, &batch, lo, hi, task.c1, task.c2);
            if results.send((chunk, out)).is_err() {
                return;
            }
        }
    }
}

/// Reorders the data workers' out-of-order `(step, batch)` stream.
pub struct BatchStream {
    rx: Receiver<(u64, Batch)>,
    pending: BTreeMap<u64, Batch>,
}

impl BatchStream {
    pub fn new(rx: Receiver<(u64, Batch)>) -> BatchStream {
        BatchStream { rx, pending: BTreeMap::new() }
    }

    /// Block until the batch for `step` is available.
    pub fn next(&mut self, step: u64) -> Result<Batch> {
        loop {
            if let Some(b) = self.pending.remove(&step) {
                return Ok(b);
            }
            match self.rx.recv() {
                Ok((s, b)) => {
                    self.pending.insert(s, b);
                }
                Err(_) => bail!("data workers exited before producing step {step}"),
            }
        }
    }
}
