//! Length-prefixed binary wire format for the multi-process engine.
//!
//! Hand-rolled and zero-dependency, in the same spirit as
//! `telemetry/json.rs`: every frame is `[u32 le body_len][u8 tag][payload]`,
//! integers are little-endian fixed width, lengths ride as `u64`, and
//! **floats travel as raw bits** (`to_bits`/`from_bits`) so a value decodes
//! to the exact bit pattern that was encoded — NaNs, `-0.0`, and subnormals
//! included.  That is what lets the multi-process engine stay bit-identical
//! to the in-process paths (`docs/CONCURRENCY.md`): serialization is a
//! bijection on the payloads, never a rounding step.
//!
//! Decoding is **strict and total**: a [`Frame::decode`] on truncated or
//! garbage bytes returns an error (never panics, never over-allocates —
//! every vector length is validated against the bytes actually present),
//! and trailing bytes after a well-formed payload are an error too.  The
//! combination makes the encoding canonical: if `decode(b)` succeeds, then
//! re-encoding the result reproduces `b` exactly
//! (`rust/tests/wire.rs` proves these properties over random payloads).

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::coordinator::streaming::PriorPass;
use crate::data::{Batch, CriteoConfig, GenConfig, PctrBatch, TextBatch, TextConfig};
use crate::runtime::reference::ChunkGrads;
use crate::sparse::OptimizerKind;
use crate::telemetry::Stage;

use super::pipeline::{BatchMsg, DataPlan};

/// Upper bound on a single frame body (1 GiB) — rejects garbage length
/// prefixes before any allocation happens.
pub const MAX_FRAME: usize = 1 << 30;

// ---------------------------------------------------------------------------
// primitive encoder / decoder
// ---------------------------------------------------------------------------

/// Append-only little-endian byte encoder.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    /// Fresh empty encoder.
    pub fn new() -> Enc {
        Enc::default()
    }

    /// The encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append one byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a bool as `0`/`1`.
    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    /// Append a `u32`, little-endian.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `i32`, little-endian.
    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`, little-endian.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `usize` as a `u64` (the format is 64-bit regardless of host).
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Append an `f32` as its raw bit pattern.
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    /// Append an `f64` as its raw bit pattern.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a length-prefixed `f32` slice (bit patterns).
    pub fn f32s(&mut self, v: &[f32]) {
        self.usize(v.len());
        for &x in v {
            self.f32(x);
        }
    }

    /// Append a length-prefixed `u32` slice.
    pub fn u32s(&mut self, v: &[u32]) {
        self.usize(v.len());
        for &x in v {
            self.u32(x);
        }
    }

    /// Append a length-prefixed `i32` slice.
    pub fn i32s(&mut self, v: &[i32]) {
        self.usize(v.len());
        for &x in v {
            self.i32(x);
        }
    }

    /// Append a length-prefixed `usize` slice (as `u64`s).
    pub fn usizes(&mut self, v: &[usize]) {
        self.usize(v.len());
        for &x in v {
            self.usize(x);
        }
    }
}

/// Bounds-checked little-endian byte decoder over a borrowed buffer.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    /// Decode from `buf`, starting at offset 0.
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let rest = self.buf.len() - self.pos;
        if rest < n {
            bail!("frame truncated: need {n} bytes at offset {}, have {rest}", self.pos);
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// One byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// A bool — only `0`/`1` are accepted (keeps the encoding canonical).
    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("invalid bool byte {b:#x}"),
        }
    }

    /// A little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// A little-endian `i32`.
    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// A little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A `usize` carried as `u64` (errors if it overflows the host).
    pub fn usize(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).context("usize overflows host width")
    }

    /// An `f32` from its raw bit pattern.
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// An `f64` from its raw bit pattern.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A length prefix for a vector of `elem`-byte items, validated against
    /// the bytes actually remaining so garbage can never trigger a huge
    /// allocation.
    fn seq_len(&mut self, elem: usize) -> Result<usize> {
        let n = self.usize()?;
        let rest = self.buf.len() - self.pos;
        if n.saturating_mul(elem.max(1)) > rest {
            bail!("sequence length {n} ({elem}-byte items) exceeds remaining {rest} bytes");
        }
        Ok(n)
    }

    /// A length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String> {
        let n = self.seq_len(1)?;
        String::from_utf8(self.take(n)?.to_vec()).context("invalid UTF-8 in wire string")
    }

    /// A length-prefixed `f32` vector.
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.seq_len(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    /// A length-prefixed `u32` vector.
    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.seq_len(4)?;
        (0..n).map(|_| self.u32()).collect()
    }

    /// A length-prefixed `i32` vector.
    pub fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.seq_len(4)?;
        (0..n).map(|_| self.i32()).collect()
    }

    /// A length-prefixed `usize` vector.
    pub fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.seq_len(8)?;
        (0..n).map(|_| self.usize()).collect()
    }

    /// Assert every byte was consumed (strict decode).
    pub fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes after frame payload", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// domain-type codecs
// ---------------------------------------------------------------------------

fn enc_prior(e: &mut Enc, p: PriorPass) {
    e.u8(match p {
        PriorPass::None => 0,
        PriorPass::FirstDay => 1,
        PriorPass::AllDays => 2,
        PriorPass::Sniff => 3,
    });
}

fn dec_prior(d: &mut Dec) -> Result<PriorPass> {
    Ok(match d.u8()? {
        0 => PriorPass::None,
        1 => PriorPass::FirstDay,
        2 => PriorPass::AllDays,
        3 => PriorPass::Sniff,
        t => bail!("unknown PriorPass tag {t}"),
    })
}

fn enc_opt_kind(e: &mut Enc, k: OptimizerKind) {
    e.u8(match k {
        OptimizerKind::Sgd => 0,
        OptimizerKind::Adagrad => 1,
    });
}

fn dec_opt_kind(d: &mut Dec) -> Result<OptimizerKind> {
    Ok(match d.u8()? {
        0 => OptimizerKind::Sgd,
        1 => OptimizerKind::Adagrad,
        t => bail!("unknown OptimizerKind tag {t}"),
    })
}

fn enc_kernel_backend(e: &mut Enc, b: crate::kernels::KernelBackend) {
    e.u8(match b {
        crate::kernels::KernelBackend::Scalar => 0,
        crate::kernels::KernelBackend::Simd => 1,
    });
}

fn dec_kernel_backend(d: &mut Dec) -> Result<crate::kernels::KernelBackend> {
    Ok(match d.u8()? {
        0 => crate::kernels::KernelBackend::Scalar,
        1 => crate::kernels::KernelBackend::Simd,
        t => bail!("unknown KernelBackend tag {t}"),
    })
}

fn enc_gen(e: &mut Enc, g: &GenConfig) {
    match g {
        GenConfig::Pctr(c) => {
            e.u8(0);
            e.usizes(&c.vocabs);
            e.usize(c.num_numeric);
            e.u64(c.seed);
            e.bool(c.drift);
            e.f64(c.drift_swap_frac);
            e.f64(c.drift_teacher);
        }
        GenConfig::Text(c) => {
            e.u8(1);
            e.usize(c.vocab);
            e.usize(c.seq_len);
            e.usize(c.num_classes);
            e.u64(c.seed);
            e.usize(c.informative);
        }
    }
}

fn dec_gen(d: &mut Dec) -> Result<GenConfig> {
    Ok(match d.u8()? {
        0 => GenConfig::Pctr(CriteoConfig {
            vocabs: d.usizes()?,
            num_numeric: d.usize()?,
            seed: d.u64()?,
            drift: d.bool()?,
            drift_swap_frac: d.f64()?,
            drift_teacher: d.f64()?,
        }),
        1 => GenConfig::Text(TextConfig {
            vocab: d.usize()?,
            seq_len: d.usize()?,
            num_classes: d.usize()?,
            seed: d.u64()?,
            informative: d.usize()?,
        }),
        t => bail!("unknown GenConfig tag {t}"),
    })
}

fn enc_plan(e: &mut Enc, p: &DataPlan) {
    e.u64(p.seed);
    e.usize(p.batch_size);
    e.u64(p.steps);
    match p.steps_per_day {
        None => e.bool(false),
        Some(s) => {
            e.bool(true);
            e.u64(s);
        }
    }
    e.bool(p.with_counts);
    enc_prior(e, p.prior);
}

fn dec_plan(d: &mut Dec) -> Result<DataPlan> {
    Ok(DataPlan {
        seed: d.u64()?,
        batch_size: d.usize()?,
        steps: d.u64()?,
        steps_per_day: if d.bool()? { Some(d.u64()?) } else { None },
        with_counts: d.bool()?,
        prior: dec_prior(d)?,
    })
}

fn enc_batch(e: &mut Enc, b: &Batch) {
    match b {
        Batch::Pctr(p) => {
            e.u8(0);
            e.usize(p.batch_size);
            e.usize(p.num_features);
            e.usize(p.num_numeric);
            e.i32s(&p.cat);
            e.f32s(&p.num);
            e.f32s(&p.y);
        }
        Batch::Text(t) => {
            e.u8(1);
            e.usize(t.batch_size);
            e.usize(t.seq_len);
            e.i32s(&t.ids);
            e.i32s(&t.labels);
        }
    }
}

fn dec_batch(d: &mut Dec) -> Result<Batch> {
    Ok(match d.u8()? {
        0 => Batch::Pctr(PctrBatch {
            batch_size: d.usize()?,
            num_features: d.usize()?,
            num_numeric: d.usize()?,
            cat: d.i32s()?,
            num: d.f32s()?,
            y: d.f32s()?,
        }),
        1 => Batch::Text(TextBatch {
            batch_size: d.usize()?,
            seq_len: d.usize()?,
            ids: d.i32s()?,
            labels: d.i32s()?,
        }),
        t => bail!("unknown Batch tag {t}"),
    })
}

fn enc_counts(e: &mut Enc, counts: &Option<Vec<Vec<(u32, u32)>>>) {
    match counts {
        None => e.bool(false),
        Some(feats) => {
            e.bool(true);
            e.usize(feats.len());
            for f in feats {
                e.usize(f.len());
                for &(bucket, count) in f {
                    e.u32(bucket);
                    e.u32(count);
                }
            }
        }
    }
}

fn dec_counts(d: &mut Dec) -> Result<Option<Vec<Vec<(u32, u32)>>>> {
    if !d.bool()? {
        return Ok(None);
    }
    let nf = d.seq_len(8)?;
    let mut feats = Vec::with_capacity(nf);
    for _ in 0..nf {
        let n = d.seq_len(8)?;
        let mut f = Vec::with_capacity(n);
        for _ in 0..n {
            f.push((d.u32()?, d.u32()?));
        }
        feats.push(f);
    }
    Ok(Some(feats))
}

fn enc_grads(e: &mut Enc, g: &ChunkGrads) {
    e.usize(g.lo);
    e.usize(g.hi);
    e.f32(g.loss_sum);
    e.usize(g.dense_grads.len());
    for dg in &g.dense_grads {
        e.f32s(dg);
    }
    e.f32s(&g.zgrads);
    e.usize(g.counts.len());
    for &(row, c) in &g.counts {
        e.u32(row);
        e.f32(c);
    }
    e.f32s(&g.scales);
}

fn dec_grads(d: &mut Dec) -> Result<ChunkGrads> {
    let lo = d.usize()?;
    let hi = d.usize()?;
    let loss_sum = d.f32()?;
    let nd = d.seq_len(8)?;
    let dense_grads = (0..nd).map(|_| d.f32s()).collect::<Result<Vec<_>>>()?;
    let zgrads = d.f32s()?;
    let nc = d.seq_len(8)?;
    let counts = (0..nc)
        .map(|_| Ok((d.u32()?, d.f32()?)))
        .collect::<Result<Vec<_>>>()?;
    let scales = d.f32s()?;
    Ok(ChunkGrads { lo, hi, loss_sum, dense_grads, zgrads, counts, scales })
}

/// Encode per-stage telemetry totals as `(stage index, nanos, count)`.
fn enc_stages(e: &mut Enc, stages: &[(Stage, u64, u64)]) {
    e.usize(stages.len());
    for &(stage, nanos, count) in stages {
        e.u8(stage as u8);
        e.u64(nanos);
        e.u64(count);
    }
}

fn dec_stages(d: &mut Dec) -> Result<Vec<(Stage, u64, u64)>> {
    let n = d.seq_len(17)?;
    (0..n)
        .map(|_| {
            let idx = d.u8()? as usize;
            if idx >= Stage::COUNT {
                bail!("unknown telemetry stage index {idx}");
            }
            Ok((Stage::ALL[idx], d.u64()?, d.u64()?))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------------

/// The gradient actor's startup payload: everything it needs to rebuild its
/// owned slice of the world deterministically.  No parameter values ride the
/// wire — `ParamStore::init(manifest, seed)` is a pure function, so the
/// child reconstructs its contiguous row range locally and bit-identically.
#[derive(Clone, Debug)]
pub struct GradInit {
    /// Manifest model name (resolved against `artifacts_dir` or the
    /// built-in reference manifest).
    pub model: String,
    /// The run's artifacts directory (`RunConfig::artifacts_dir`).
    pub artifacts_dir: String,
    /// The run seed (drives `ParamStore::init`).
    pub seed: u64,
    /// Optimizer kind — fixed for the whole run, so it rides once here and
    /// never again on scatter frames.
    pub opt_kind: OptimizerKind,
    /// Learning rate.
    pub lr: f32,
    /// Parameter indices of the embedding tables, in feature order.
    pub emb_params: Vec<u32>,
    /// Total number of gradient actors (= row-range owners).
    pub n_owners: u32,
    /// This actor's owner index in `0..n_owners`.
    pub owner_index: u32,
    /// Shard count for the actor's local `ShardedTable`s.
    pub shards: u32,
    /// Kernel fan-out threads inside the actor.
    pub kernel_threads: u32,
    /// Kernel backend inside the actor — must match the barrier's so every
    /// accumulation chain is computed the same way fleet-wide.
    pub kernel_backend: crate::kernels::KernelBackend,
    /// `--store-budget-mb`: per-process paged-store budget in MiB (0 keeps
    /// the actor's tables in RAM).
    pub store_budget_mb: u64,
    /// `--store-dir`: directory for the actor's page files ("" = temp dir).
    pub store_dir: String,
}

/// One per-feature slice of a step's row cache on the wire:
/// `(sorted unique global row ids, packed row values, row dim)`.
pub type WireFeat = (Vec<u32>, Vec<f32>, usize);

/// A step dispatch to one gradient actor: the batch, the full row-cache
/// snapshot, the trainable dense parameters, and the contiguous chunk range
/// `[chunk_lo, chunk_hi)` this actor computes.
#[derive(Clone, Debug)]
pub struct StepData {
    /// The logical step index.
    pub step: u64,
    /// First 16-example chunk (inclusive) assigned to this actor.
    pub chunk_lo: u32,
    /// Last chunk (exclusive).
    pub chunk_hi: u32,
    /// Row-grad clip norm (σ₂ side).
    pub c1: f32,
    /// Contribution-map clip norm (σ₁ side).
    pub c2: f32,
    /// The step's batch.
    pub batch: Batch,
    /// The step's full row-cache snapshot, per embedding feature.
    pub feats: Vec<WireFeat>,
    /// Trainable dense parameter snapshots as `(param index, values)`.
    pub dense: Vec<(u32, Vec<f32>)>,
}

/// Every message exchanged between the barrier process and its actors.
///
/// See the protocol table in `docs/ENGINE.md` for direction and cadence.
#[derive(Clone, Debug)]
pub enum Frame {
    /// Actor → barrier, once on connect: `role` (0 = data, 1 = grad) and
    /// the actor's index.
    Hello {
        /// 0 for a data actor, 1 for a gradient actor.
        role: u8,
        /// Actor index within its role.
        index: u32,
    },
    /// Barrier → data actor, once: generator config + data plan + the
    /// actor's stride/offset slice of the step sequence.
    DataInit {
        /// Generator configuration (the data substrate).
        gen: GenConfig,
        /// The run's data plan (seed, steps, streaming calendar, priors).
        plan: DataPlan,
        /// Number of data actors (sequence stride).
        stride: u32,
        /// This actor's starting sequence offset.
        offset: u32,
    },
    /// Barrier → gradient actor, once: see [`GradInit`].
    GradInit(GradInit),
    /// Data actor → barrier: one generated batch (with optional per-batch
    /// frequency counts in streaming mode).
    Batch(BatchMsg),
    /// Data actor → barrier, last frame: the actor finished its slice of
    /// the sequence; carries its stage-timer totals.
    DataDone {
        /// `(stage, nanos, count)` totals from the actor's telemetry.
        stages: Vec<(Stage, u64, u64)>,
    },
    /// Barrier → gradient actor: fetch current values for these global row
    /// ids (per feature, all within the actor's owned range).
    FetchRows {
        /// Sorted global row ids per embedding feature.
        rows: Vec<Vec<u32>>,
    },
    /// Gradient actor → barrier: the packed values answering a
    /// [`Frame::FetchRows`], per feature.
    RowValues {
        /// Packed row values per feature, in request order.
        values: Vec<Vec<f32>>,
    },
    /// Barrier → gradient actor: one step dispatch, see [`StepData`].
    StepData(StepData),
    /// Gradient actor → barrier: one computed chunk partial.
    ChunkResult {
        /// The step the chunk belongs to.
        step: u64,
        /// Chunk index within the step.
        chunk: u32,
        /// The fixed-16-example chunk partial.
        grads: ChunkGrads,
    },
    /// Barrier → gradient actor: apply a row-sparse optimizer step to the
    /// actor's slice of `param` (global row ids; values packed row-major).
    Scatter {
        /// Parameter index of the embedding table.
        param: u32,
        /// Global row ids (within the actor's owned range).
        rows: Vec<u32>,
        /// Row values, `rows.len() × dim`.
        values: Vec<f32>,
    },
    /// Barrier → gradient actor: apply a dense optimizer step to the
    /// actor's contiguous slice of embedding table `param`.
    DenseScatter {
        /// Parameter index of the embedding table.
        param: u32,
        /// The dense gradient slice covering the actor's row range.
        values: Vec<f32>,
    },
    /// Barrier → gradient actor, last frame: ship the final tables back.
    Finalize,
    /// Gradient actor → barrier: final `(param, values, adagrad accum)` for
    /// every owned slice (accum empty when no state accumulated), plus the
    /// actor's stage-timer totals.
    FinalizeResult {
        /// `(param index, row values, optimizer accum)` per owned slice.
        tables: Vec<(u32, Vec<f32>, Vec<f32>)>,
        /// `(stage, nanos, count)` totals from the actor's telemetry.
        stages: Vec<(Stage, u64, u64)>,
    },
}

impl Frame {
    /// Encode to a frame body (tag byte + payload, no length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            Frame::Hello { role, index } => {
                e.u8(1);
                e.u8(*role);
                e.u32(*index);
            }
            Frame::DataInit { gen, plan, stride, offset } => {
                e.u8(2);
                enc_gen(&mut e, gen);
                enc_plan(&mut e, plan);
                e.u32(*stride);
                e.u32(*offset);
            }
            Frame::GradInit(g) => {
                e.u8(3);
                e.str(&g.model);
                e.str(&g.artifacts_dir);
                e.u64(g.seed);
                enc_opt_kind(&mut e, g.opt_kind);
                e.f32(g.lr);
                e.u32s(&g.emb_params);
                e.u32(g.n_owners);
                e.u32(g.owner_index);
                e.u32(g.shards);
                e.u32(g.kernel_threads);
                enc_kernel_backend(&mut e, g.kernel_backend);
                e.u64(g.store_budget_mb);
                e.str(&g.store_dir);
            }
            Frame::Batch(m) => {
                e.u8(4);
                e.u64(m.step);
                enc_batch(&mut e, &m.batch);
                enc_counts(&mut e, &m.counts);
            }
            Frame::DataDone { stages } => {
                e.u8(5);
                enc_stages(&mut e, stages);
            }
            Frame::FetchRows { rows } => {
                e.u8(6);
                e.usize(rows.len());
                for r in rows {
                    e.u32s(r);
                }
            }
            Frame::RowValues { values } => {
                e.u8(7);
                e.usize(values.len());
                for v in values {
                    e.f32s(v);
                }
            }
            Frame::StepData(s) => {
                e.u8(8);
                e.u64(s.step);
                e.u32(s.chunk_lo);
                e.u32(s.chunk_hi);
                e.f32(s.c1);
                e.f32(s.c2);
                enc_batch(&mut e, &s.batch);
                e.usize(s.feats.len());
                for (rows, values, dim) in &s.feats {
                    e.u32s(rows);
                    e.f32s(values);
                    e.usize(*dim);
                }
                e.usize(s.dense.len());
                for (idx, values) in &s.dense {
                    e.u32(*idx);
                    e.f32s(values);
                }
            }
            Frame::ChunkResult { step, chunk, grads } => {
                e.u8(9);
                e.u64(*step);
                e.u32(*chunk);
                enc_grads(&mut e, grads);
            }
            Frame::Scatter { param, rows, values } => {
                e.u8(10);
                e.u32(*param);
                e.u32s(rows);
                e.f32s(values);
            }
            Frame::DenseScatter { param, values } => {
                e.u8(11);
                e.u32(*param);
                e.f32s(values);
            }
            Frame::Finalize => {
                e.u8(12);
            }
            Frame::FinalizeResult { tables, stages } => {
                e.u8(13);
                e.usize(tables.len());
                for (param, values, accum) in tables {
                    e.u32(*param);
                    e.f32s(values);
                    e.f32s(accum);
                }
                enc_stages(&mut e, stages);
            }
        }
        e.into_bytes()
    }

    /// Strict decode of a frame body: every byte must be consumed, every
    /// length validated, and malformed input returns an error — never a
    /// panic.
    pub fn decode(body: &[u8]) -> Result<Frame> {
        let mut d = Dec::new(body);
        let frame = match d.u8().context("empty frame body")? {
            1 => Frame::Hello { role: d.u8()?, index: d.u32()? },
            2 => Frame::DataInit {
                gen: dec_gen(&mut d)?,
                plan: dec_plan(&mut d)?,
                stride: d.u32()?,
                offset: d.u32()?,
            },
            3 => Frame::GradInit(GradInit {
                model: d.str()?,
                artifacts_dir: d.str()?,
                seed: d.u64()?,
                opt_kind: dec_opt_kind(&mut d)?,
                lr: d.f32()?,
                emb_params: d.u32s()?,
                n_owners: d.u32()?,
                owner_index: d.u32()?,
                shards: d.u32()?,
                kernel_threads: d.u32()?,
                kernel_backend: dec_kernel_backend(&mut d)?,
                store_budget_mb: d.u64()?,
                store_dir: d.str()?,
            }),
            4 => Frame::Batch(BatchMsg {
                step: d.u64()?,
                batch: dec_batch(&mut d)?,
                counts: dec_counts(&mut d)?,
            }),
            5 => Frame::DataDone { stages: dec_stages(&mut d)? },
            6 => {
                let n = d.seq_len(8)?;
                let rows = (0..n).map(|_| d.u32s()).collect::<Result<Vec<_>>>()?;
                Frame::FetchRows { rows }
            }
            7 => {
                let n = d.seq_len(8)?;
                let values = (0..n).map(|_| d.f32s()).collect::<Result<Vec<_>>>()?;
                Frame::RowValues { values }
            }
            8 => {
                let step = d.u64()?;
                let chunk_lo = d.u32()?;
                let chunk_hi = d.u32()?;
                let c1 = d.f32()?;
                let c2 = d.f32()?;
                let batch = dec_batch(&mut d)?;
                let nf = d.seq_len(8)?;
                let feats = (0..nf)
                    .map(|_| Ok((d.u32s()?, d.f32s()?, d.usize()?)))
                    .collect::<Result<Vec<_>>>()?;
                let nd = d.seq_len(8)?;
                let dense = (0..nd)
                    .map(|_| Ok((d.u32()?, d.f32s()?)))
                    .collect::<Result<Vec<_>>>()?;
                Frame::StepData(StepData { step, chunk_lo, chunk_hi, c1, c2, batch, feats, dense })
            }
            9 => Frame::ChunkResult {
                step: d.u64()?,
                chunk: d.u32()?,
                grads: dec_grads(&mut d)?,
            },
            10 => Frame::Scatter { param: d.u32()?, rows: d.u32s()?, values: d.f32s()? },
            11 => Frame::DenseScatter { param: d.u32()?, values: d.f32s()? },
            12 => Frame::Finalize,
            13 => {
                let nt = d.seq_len(8)?;
                let tables = (0..nt)
                    .map(|_| Ok((d.u32()?, d.f32s()?, d.f32s()?)))
                    .collect::<Result<Vec<_>>>()?;
                Frame::FinalizeResult { tables, stages: dec_stages(&mut d)? }
            }
            t => bail!("unknown frame tag {t}"),
        };
        d.finish()?;
        Ok(frame)
    }
}

/// Write one length-prefixed frame and flush.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    let body = frame.encode();
    if body.len() > MAX_FRAME {
        bail!("frame body of {} bytes exceeds MAX_FRAME", body.len());
    }
    w.write_all(&(body.len() as u32).to_le_bytes())
        .context("writing frame length")?;
    w.write_all(&body).context("writing frame body")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one length-prefixed frame.  A garbage length prefix is rejected
/// before allocation; a short read is an error, not a panic.
pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len).context("reading frame length")?;
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds MAX_FRAME");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("reading frame body")?;
    Frame::decode(&body)
}
