//! Contribution maps and noisy thresholding — Algorithm 1 lines 5–8.
//!
//! The contribution map `V_t = Σᵢ [vᵢ]_{C₁}` arrives either from the AOT
//! artifact (the Pallas `contribution_map` kernel's dense count vector,
//! small models) or is built natively from batch indices (full-Table-3-scale
//! gradient-size simulations, where `c` is too big to round-trip densely).
//! Both feed the same survivor selection: explicit Gaussian noise on the
//! non-zero counts and Appendix-B.2 geometric sampling for zero-count false
//! positives.

use std::collections::HashMap;

use crate::sparse::{survivors_dense, survivors_sparse, SurvivorStats};
use crate::util::rng::Xoshiro256;

/// Sparse batch-wise contribution map over `num_rows` concatenated rows.
#[derive(Clone, Debug)]
pub struct ContributionMap {
    pub num_rows: usize,
    /// sorted by row id, no duplicates
    pub nonzero: Vec<(u32, f32)>,
}

impl ContributionMap {
    /// Extract the non-zeros of a dense count vector (artifact output).
    pub fn from_dense(counts: &[f32]) -> Self {
        let nonzero = counts
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .map(|(i, &v)| (i as u32, v))
            .collect();
        ContributionMap { num_rows: counts.len(), nonzero }
    }

    /// Build natively from per-example activated rows (already offset into
    /// the concatenated row space).  Each example's indicator vector is
    /// l2-clipped to `c1`: an example activating `u` distinct rows
    /// contributes `min(1, c1/√u)` to each of them (paper Alg. 1, line 5).
    pub fn from_batch(examples: &[Vec<u32>], num_rows: usize, c1: f64) -> Self {
        let mut acc: HashMap<u32, f32> = HashMap::new();
        let mut scratch: Vec<u32> = Vec::new();
        for ex in examples {
            scratch.clear();
            scratch.extend_from_slice(ex);
            scratch.sort_unstable();
            scratch.dedup();
            let u = scratch.len();
            if u == 0 {
                continue;
            }
            let w = (c1 / (u as f64).sqrt()).min(1.0) as f32;
            for &r in &scratch {
                *acc.entry(r).or_insert(0.0) += w;
            }
        }
        let mut nonzero: Vec<(u32, f32)> = acc.into_iter().collect();
        nonzero.sort_unstable_by_key(|&(r, _)| r);
        ContributionMap { num_rows, nonzero }
    }

    pub fn nnz(&self) -> usize {
        self.nonzero.len()
    }

    /// Total clipped mass (diagnostics; bounded by `B·C₁·√F` trivially and
    /// by `B·min(1, C₁/√u)·u` per example).
    pub fn total_mass(&self) -> f64 {
        self.nonzero.iter().map(|&(_, v)| v as f64).sum()
    }

    /// Algorithm 1 lines 6–8: add `N(0, (σ₁C₁)²)` and threshold at τ.
    /// `memory_efficient = true` uses the Appendix-B.2 sampler (O(nnz+FP));
    /// `false` materialises the dense noisy vector (O(c) oracle).
    pub fn survivors(
        &self,
        sigma1: f64,
        c1: f64,
        tau: f64,
        memory_efficient: bool,
        rng: &mut Xoshiro256,
    ) -> (SurvivorSet, SurvivorStats) {
        let (ids, stats) = if memory_efficient {
            survivors_sparse(&self.nonzero, self.num_rows, sigma1, c1, tau, rng)
        } else {
            let mut dense = vec![0f32; self.num_rows];
            for &(r, v) in &self.nonzero {
                dense[r as usize] = v;
            }
            let (mut ids, stats) = survivors_dense(&dense, sigma1, c1, tau, rng);
            ids.sort_unstable();
            (ids, stats)
        };
        (SurvivorSet { ids }, stats)
    }
}

/// Sorted survivor row set with O(log n) membership.
#[derive(Clone, Debug, Default)]
pub struct SurvivorSet {
    ids: Vec<u32>,
}

impl SurvivorSet {
    pub fn from_sorted(ids: Vec<u32>) -> Self {
        debug_assert!(ids.windows(2).all(|w| w[0] < w[1]));
        SurvivorSet { ids }
    }

    pub fn all(num_rows: usize) -> Self {
        SurvivorSet { ids: (0..num_rows as u32).collect() }
    }

    pub fn contains(&self, id: u32) -> bool {
        self.ids.binary_search(&id).is_ok()
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    /// Intersect with another sorted set (DP-AdaFEST+ composes the FEST
    /// pre-selection with the per-batch survivors).
    pub fn intersect(&self, other: &SurvivorSet) -> SurvivorSet {
        let mut out = Vec::with_capacity(self.len().min(other.len()));
        let (mut i, mut j) = (0, 0);
        while i < self.ids.len() && j < other.ids.len() {
            match self.ids[i].cmp(&other.ids[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    out.push(self.ids[i]);
                    i += 1;
                    j += 1;
                }
            }
        }
        SurvivorSet { ids: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_batch_clips_per_example() {
        // one example with 4 distinct rows, c1 = 1 ⇒ weight 0.5 each
        let m = ContributionMap::from_batch(&[vec![1, 5, 9, 3]], 16, 1.0);
        assert_eq!(m.nnz(), 4);
        for &(_, v) in &m.nonzero {
            assert!((v - 0.5).abs() < 1e-6);
        }
        // duplicate rows inside an example count once
        let m2 = ContributionMap::from_batch(&[vec![2, 2, 2]], 16, 10.0);
        assert_eq!(m2.nnz(), 1);
        assert!((m2.nonzero[0].1 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn from_batch_accumulates_across_examples() {
        let m = ContributionMap::from_batch(&[vec![7], vec![7], vec![7]], 8, 5.0);
        assert_eq!(m.nnz(), 1);
        assert!((m.nonzero[0].1 - 3.0).abs() < 1e-6);
    }

    #[test]
    fn from_dense_matches_from_batch() {
        let mut dense = vec![0f32; 10];
        dense[2] = 2.0;
        dense[9] = 1.0;
        let a = ContributionMap::from_dense(&dense);
        let b = ContributionMap::from_batch(&[vec![2], vec![2], vec![9]], 10, 100.0);
        assert_eq!(a.nonzero, b.nonzero);
    }

    #[test]
    fn survivors_dense_and_sparse_same_interface() {
        let m = ContributionMap::from_batch(&[vec![0], vec![0], vec![1]], 1000, 100.0);
        let mut rng = Xoshiro256::seed_from(1);
        // no noise: threshold separates counts exactly
        let (s, _) = m.survivors(0.0, 1.0, 1.5, true, &mut rng);
        assert_eq!(s.ids(), &[0]);
        let (s2, _) = m.survivors(0.0, 1.0, 1.5, false, &mut rng);
        assert_eq!(s2.ids(), &[0]);
        assert!(s.contains(0) && !s.contains(1));
    }

    #[test]
    fn intersect_is_sorted_intersection() {
        let a = SurvivorSet::from_sorted(vec![1, 3, 5, 7, 9]);
        let b = SurvivorSet::from_sorted(vec![3, 4, 5, 6, 7]);
        assert_eq!(a.intersect(&b).ids(), &[3, 5, 7]);
        assert_eq!(a.intersect(&SurvivorSet::default()).len(), 0);
    }

    #[test]
    fn threshold_monotone_in_tau() {
        // higher tau ⇒ (stochastically) fewer survivors; with shared seed
        // and no noise it is deterministic
        let examples: Vec<Vec<u32>> = (0..50).map(|i| vec![i % 10]).collect();
        let m = ContributionMap::from_batch(&examples, 100, 100.0);
        let mut r1 = Xoshiro256::seed_from(2);
        let mut r2 = Xoshiro256::seed_from(2);
        let (lo, _) = m.survivors(0.0, 1.0, 2.0, true, &mut r1);
        let (hi, _) = m.survivors(0.0, 1.0, 6.0, true, &mut r2);
        assert!(hi.len() <= lo.len());
    }
}
