//! Shared harness plumbing: configured single runs, sweep records, CSV
//! output, and aligned-table printing.

use std::fs;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::config::RunConfig;
use crate::coordinator::{StreamingOutcome, StreamingTrainer, Trainer, TrainOutcome};
use crate::data::{CriteoConfig, SynthCriteo, SynthText, TextConfig};
use crate::runtime::Runtime;

/// One sweep result: a flat (label → value) record.
#[derive(Clone, Debug, Default)]
pub struct SweepRow {
    pub fields: Vec<(String, String)>,
}

impl SweepRow {
    pub fn push(&mut self, key: &str, value: impl std::fmt::Display) {
        self.fields.push((key.to_string(), value.to_string()));
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.fields
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key)?.parse().ok()
    }
}

/// Write rows as CSV under `results/` and return the path.
pub fn write_csv(name: &str, rows: &[SweepRow]) -> Result<PathBuf> {
    fs::create_dir_all("results").context("creating results/")?;
    let path = PathBuf::from(format!("results/{name}.csv"));
    let mut out = String::new();
    if let Some(first) = rows.first() {
        let header: Vec<&str> = first.fields.iter().map(|(k, _)| k.as_str()).collect();
        out.push_str(&header.join(","));
        out.push('\n');
        for r in rows {
            let vals: Vec<&str> = r.fields.iter().map(|(_, v)| v.as_str()).collect();
            out.push_str(&vals.join(","));
            out.push('\n');
        }
    }
    fs::write(&path, out).with_context(|| format!("writing {path:?}"))?;
    println!("[csv] wrote {} rows to {}", rows.len(), path.display());
    Ok(path)
}

/// Print rows as an aligned text table.
pub fn print_table(title: &str, rows: &[SweepRow]) {
    println!("\n== {title} ==");
    let Some(first) = rows.first() else {
        println!("(no rows)");
        return;
    };
    let keys: Vec<&str> = first.fields.iter().map(|(k, _)| k.as_str()).collect();
    let mut widths: Vec<usize> = keys.iter().map(|k| k.len()).collect();
    for r in rows {
        for (i, (_, v)) in r.fields.iter().enumerate() {
            widths[i] = widths[i].max(v.len());
        }
    }
    let header: Vec<String> = keys
        .iter()
        .zip(&widths)
        .map(|(k, w)| format!("{k:>w$}"))
        .collect();
    println!("{}", header.join("  "));
    for r in rows {
        let vals: Vec<String> = r
            .fields
            .iter()
            .zip(&widths)
            .map(|((_, v), w)| format!("{v:>w$}"))
            .collect();
        println!("{}", vals.join("  "));
    }
}

/// Build the data generator matching a manifest model and run one training.
pub fn train_once(cfg: &RunConfig, rt: &Runtime) -> Result<TrainOutcome> {
    let model = rt.manifest.model(&cfg.model)?;
    let mut trainer = Trainer::new(cfg.clone(), rt)?;
    match model.kind.as_str() {
        "pctr" => {
            let vocabs = model.attr_usize_list("vocabs")?;
            let gen = SynthCriteo::new(CriteoConfig::new(vocabs, cfg.seed ^ 0xDA7A));
            trainer.run_pctr(&gen)
        }
        "nlu" => {
            let gen = SynthText::new(TextConfig::from_model(model, cfg.seed ^ 0xDA7A)?);
            trainer.run_text(&gen)
        }
        other => anyhow::bail!("unknown model kind {other}"),
    }
}

/// One streaming (§4.3) run on the chosen backend: the synchronous
/// [`StreamingTrainer`] or the async engine's streaming barrier
/// (`engine::run_streaming`) — bit-identical outcomes, so the tab5/fig5
/// harnesses can sweep on whichever path and compare freely.  Both
/// backends derive their generators from `gen_cfg` alone (every batch
/// stream is a self-contained tagged RNG), so the two cannot drift.
pub fn streaming_once(
    cfg: &RunConfig,
    rt: &Runtime,
    gen_cfg: &CriteoConfig,
    engine: bool,
) -> Result<StreamingOutcome> {
    let eval_batches_per_day = crate::coordinator::streaming::eval_batches_per_day(cfg);
    if engine {
        crate::engine::run_streaming(cfg, rt, gen_cfg.clone(), eval_batches_per_day)
    } else {
        let gen = SynthCriteo::new(gen_cfg.clone());
        let trainer = Trainer::new(cfg.clone(), rt)?;
        let mut st = StreamingTrainer::new(trainer, eval_batches_per_day);
        st.run(&gen)
    }
}

/// Whether the active backend can actually run `name`: the model must be in
/// the loaded manifest, and on the reference backend its inventory must be
/// natively executable (an on-disk artifact manifest can be driven by the
/// reference backend when the `xla` feature is off, but e.g. its
/// attention-LoRA NLU inventories are not).
pub fn model_executable(rt: &Runtime, name: &str) -> bool {
    match rt.manifest.model(name) {
        Ok(model) => {
            !rt.is_reference()
                || crate::runtime::reference::RefModel::from_manifest(model).is_ok()
        }
        Err(_) => false,
    }
}

/// Prefer `name` when the loaded manifest has it *and* the active backend
/// can execute it ([`model_executable`]); fall back to the named built-in
/// reference model otherwise, so the NLU harnesses run with zero artifacts.
pub fn model_or_builtin(rt: &Runtime, name: &str, fallback: &str) -> String {
    if model_executable(rt, name) {
        name.to_string()
    } else if model_executable(rt, fallback) {
        println!("[harness] model {name} unavailable on this runtime — using built-in {fallback}");
        fallback.to_string()
    } else {
        // No runnable variant: keep the requested name so the caller's
        // error names the real problem (e.g. rebuild with --features xla).
        name.to_string()
    }
}

/// A (description, outcome) pair from a hyper-parameter sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub label: String,
    pub outcome: TrainOutcome,
}

/// Best gradient-size reduction among points whose utility is within
/// `max_loss` of `baseline_utility` (the paper's Figure-3 y-axis).
pub fn best_reduction_within(
    points: &[SweepPoint],
    baseline_utility: f64,
    max_loss: f64,
) -> Option<(f64, &SweepPoint)> {
    points
        .iter()
        .filter(|p| baseline_utility - p.outcome.utility <= max_loss)
        .map(|p| (p.outcome.reduction_factor, p))
        .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(label: &str, utility: f64, reduction: f64) -> SweepPoint {
        SweepPoint {
            label: label.into(),
            outcome: TrainOutcome {
                loss_history: vec![],
                utility,
                eval_loss: 0.0,
                emb_grad_coords_per_step: 0.0,
                reduction_factor: reduction,
                sigma1: 0.0,
                sigma2: 0.0,
                telemetry: Default::default(),
            },
        }
    }

    #[test]
    fn best_reduction_respects_threshold() {
        let pts = vec![
            pt("a", 0.75, 10.0),
            pt("b", 0.748, 100.0),
            pt("c", 0.70, 100000.0),
        ];
        let (r, p) = best_reduction_within(&pts, 0.75, 0.005).unwrap();
        assert_eq!(r, 100.0);
        assert_eq!(p.label, "b");
        let (r2, _) = best_reduction_within(&pts, 0.75, 0.1).unwrap();
        assert_eq!(r2, 100000.0);
        assert!(best_reduction_within(&pts, 0.9, 0.001).is_none());
    }

    #[test]
    fn sweep_row_roundtrip() {
        let mut r = SweepRow::default();
        r.push("x", 1.5);
        r.push("name", "foo");
        assert_eq!(r.get_f64("x"), Some(1.5));
        assert_eq!(r.get("name"), Some("foo"));
        assert_eq!(r.get("missing"), None);
    }
}
