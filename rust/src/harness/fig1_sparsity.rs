//! Figure 1b — embedding gradient sparsity of the Criteo pCTR model.
//!
//! At full Table-3 scale: B = 2048, 50 update steps; report mean gradient
//! sparsity (fraction of *zero* gradient rows) for the five
//! highest-vocabulary categorical features and over all features.  This is
//! a pure data-path computation (sparsity is a property of activations).

use anyhow::Result;

use crate::config::RunConfig;
use crate::data::{CriteoConfig, SynthCriteo};
use crate::util::rng::Xoshiro256;

use super::common::{print_table, write_csv, SweepRow};

/// Table-3 vocabulary sizes (criteo-full).
pub const CRITEO_VOCABS: [usize; 26] = [
    1472, 577, 82741, 18940, 305, 23, 1172, 633, 3, 9090, 5918, 64300, 3207, 27,
    1550, 44262, 10, 5485, 2161, 3, 56473, 17, 15, 27360, 104, 12934,
];

pub fn run(cfg: &RunConfig, fast: bool) -> Result<()> {
    let steps = if fast { 10 } else { 50 };
    let batch = if fast { 512 } else { 2048 };
    let vocabs = CRITEO_VOCABS.to_vec();
    let gen = SynthCriteo::new(CriteoConfig::new(vocabs.clone(), cfg.seed));
    let mut rng = Xoshiro256::seed_from(cfg.seed ^ 0xF161);

    // per-feature: mean over steps of (distinct activated rows / vocab)
    let nf = vocabs.len();
    let mut sparsity_sum = vec![0f64; nf];
    let mut all_rows_sum = 0f64;
    let total_vocab: usize = vocabs.iter().sum();
    for _ in 0..steps {
        let b = gen.batch(0, batch, &mut rng);
        let mut step_rows = 0usize;
        for f in 0..nf {
            let mut seen = std::collections::HashSet::new();
            for i in 0..batch {
                seen.insert(b.cat_of(i, f));
            }
            sparsity_sum[f] += 1.0 - seen.len() as f64 / vocabs[f] as f64;
            step_rows += seen.len();
        }
        all_rows_sum += 1.0 - step_rows as f64 / total_vocab as f64;
    }

    // the paper plots the top-5 vocab features + "all"
    let mut order: Vec<usize> = (0..nf).collect();
    order.sort_by_key(|&f| std::cmp::Reverse(vocabs[f]));
    let mut rows = Vec::new();
    for &f in order.iter().take(5) {
        let mut r = SweepRow::default();
        r.push("feature", format!("categorical-feature-{}", 14 + f));
        r.push("vocab", vocabs[f]);
        r.push("grad_sparsity", format!("{:.6}", sparsity_sum[f] / steps as f64));
        rows.push(r);
    }
    let mut r = SweepRow::default();
    r.push("feature", "all-26-features");
    r.push("vocab", total_vocab);
    r.push("grad_sparsity", format!("{:.6}", all_rows_sum / steps as f64));
    rows.push(r);

    print_table(
        &format!("Figure 1b: embedding gradient sparsity (B={batch}, {steps} steps)"),
        &rows,
    );
    write_csv("fig1b_sparsity", &rows)?;
    println!(
        "\npaper shape check: sparsity > 0.95 for large-vocab features, near 1.0 overall"
    );
    Ok(())
}
