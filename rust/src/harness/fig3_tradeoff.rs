//! Figure 3 — best gradient-size reduction vs utility-loss threshold, per
//! algorithm — and Figure 8 — the underlying utility/efficiency scatter.
//!
//! Protocol (paper §4.2): train DP-SGD as the utility reference; sweep each
//! sparsity-preserving algorithm's knobs (k for DP-FEST; σ₁/σ₂, τ, C₁ for
//! DP-AdaFEST; m for exponential selection); for every utility-loss
//! threshold report the best reduction achieved within it.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::Algorithm;
use crate::runtime::Runtime;

use super::common::{
    best_reduction_within, print_table, train_once, write_csv, SweepPoint, SweepRow,
};

pub const LOSS_THRESHOLDS: [f64; 5] = [0.001, 0.002, 0.005, 0.01, 0.02];

/// Hyper-parameter grids per algorithm (paper Appendix D.1).
pub fn sweep_algorithm(
    base: &RunConfig,
    rt: &Runtime,
    algo: Algorithm,
    fast: bool,
) -> Result<Vec<SweepPoint>> {
    let mut points = Vec::new();
    let mut run = |label: String, cfg: RunConfig| -> Result<()> {
        let outcome = train_once(&cfg, rt)?;
        println!(
            "  [{}] {label}: utility={:.4} reduction={:.1}x (sig1={:.2} sig2={:.2})",
            algo.name(),
            outcome.utility,
            outcome.reduction_factor,
            outcome.sigma1,
            outcome.sigma2
        );
        points.push(SweepPoint { label, outcome });
        Ok(())
    };

    match algo {
        Algorithm::DpFest | Algorithm::DpAdaFestPlus => {
            let ks: &[usize] = if fast {
                &[512, 4096]
            } else {
                &[128, 512, 2048, 8192, 32768]
            };
            let (ratios, taus): (&[f64], &[f64]) = if algo == Algorithm::DpAdaFestPlus {
                if fast {
                    (&[5.0], &[5.0])
                } else {
                    (&[2.0, 5.0], &[1.0, 5.0, 20.0])
                }
            } else {
                (&[5.0], &[0.0])
            };
            for &k in ks {
                for &ratio in ratios {
                    for &tau in taus {
                        let mut cfg = base.clone();
                        cfg.algorithm = algo;
                        cfg.fest_top_k = k;
                        cfg.sigma_ratio = ratio;
                        cfg.tau = tau;
                        run(format!("k={k},ratio={ratio},tau={tau}"), cfg)?;
                    }
                }
            }
        }
        Algorithm::DpAdaFest => {
            let ratios: &[f64] = if fast { &[5.0] } else { &[1.0, 2.0, 5.0, 10.0] };
            let taus: &[f64] = if fast {
                &[1.0, 10.0]
            } else {
                &[0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0]
            };
            let c1s: &[f64] = if fast { &[1.0] } else { &[1.0] };
            for &ratio in ratios {
                for &tau in taus {
                    for &c1 in c1s {
                        let mut cfg = base.clone();
                        cfg.algorithm = algo;
                        cfg.sigma_ratio = ratio;
                        cfg.tau = tau;
                        cfg.c1 = c1;
                        run(format!("ratio={ratio},tau={tau},c1={c1}"), cfg)?;
                    }
                }
            }
        }
        Algorithm::ExpSelection => {
            let ms: &[usize] = if fast {
                &[1024]
            } else {
                &[256, 1024, 4096, 16384]
            };
            for &m in ms {
                let mut cfg = base.clone();
                cfg.algorithm = algo;
                cfg.exp_select_m = m;
                run(format!("m={m}"), cfg)?;
            }
        }
        other => {
            let mut cfg = base.clone();
            cfg.algorithm = other;
            run(other.name().to_string(), cfg)?;
        }
    }
    Ok(points)
}

pub fn run(cfg: &RunConfig, rt: &Runtime, fast: bool) -> Result<()> {
    let mut base = cfg.clone();
    if fast {
        base.steps = base.steps.min(60);
        base.eval_batches = base.eval_batches.min(10);
    }
    println!("Figure 3 sweep on {} ({})", base.model, base.summary());

    let mut dpsgd_cfg = base.clone();
    dpsgd_cfg.algorithm = Algorithm::DpSgd;
    let baseline = train_once(&dpsgd_cfg, rt)?;
    println!(
        "DP-SGD baseline: utility={:.4} (reduction 1x by definition)",
        baseline.utility
    );

    let algos = [
        Algorithm::DpAdaFest,
        Algorithm::DpFest,
        Algorithm::ExpSelection,
    ];
    let mut rows = Vec::new();
    let mut all_points = Vec::new();
    for algo in algos {
        let points = sweep_algorithm(&base, rt, algo, fast)?;
        for &thr in &LOSS_THRESHOLDS {
            let mut r = SweepRow::default();
            r.push("algorithm", algo.name());
            r.push("utility_loss_threshold", thr);
            match best_reduction_within(&points, baseline.utility, thr) {
                Some((red, p)) => {
                    r.push("best_reduction", format!("{red:.2}"));
                    r.push("at", &p.label);
                    r.push("utility", format!("{:.4}", p.outcome.utility));
                }
                None => {
                    r.push("best_reduction", "none");
                    r.push("at", "-");
                    r.push("utility", "-");
                }
            }
            rows.push(r);
        }
        all_points.push((algo, points));
    }
    print_table("Figure 3: best reduction vs utility-loss threshold", &rows);
    write_csv(&format!("fig3_{}", base.model), &rows)?;
    println!("\npaper shape check: DP-AdaFEST ≥ DP-FEST ≫ exp-selection at every threshold");
    Ok(())
}

/// Figure 8 — the raw scatter of every sweep point.
pub fn run_scatter(cfg: &RunConfig, rt: &Runtime, fast: bool) -> Result<()> {
    let mut base = cfg.clone();
    if fast {
        base.steps = base.steps.min(60);
        base.eval_batches = base.eval_batches.min(10);
    }
    let mut dpsgd_cfg = base.clone();
    dpsgd_cfg.algorithm = Algorithm::DpSgd;
    let baseline = train_once(&dpsgd_cfg, rt)?;

    let mut rows = Vec::new();
    let mut r0 = SweepRow::default();
    r0.push("algorithm", "dp-sgd");
    r0.push("label", "baseline");
    r0.push("utility", format!("{:.4}", baseline.utility));
    r0.push("reduction", "1.0");
    rows.push(r0);
    for algo in [
        Algorithm::DpAdaFest,
        Algorithm::DpFest,
        Algorithm::ExpSelection,
    ] {
        for p in sweep_algorithm(&base, rt, algo, fast)? {
            let mut r = SweepRow::default();
            r.push("algorithm", algo.name());
            r.push("label", &p.label);
            r.push("utility", format!("{:.4}", p.outcome.utility));
            r.push("reduction", format!("{:.2}", p.outcome.reduction_factor));
            rows.push(r);
        }
    }
    print_table("Figure 8: utility/efficiency scatter", &rows);
    write_csv(&format!("fig8_{}", base.model), &rows)?;
    Ok(())
}
