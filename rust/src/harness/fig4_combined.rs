//! Figure 4 — DP-AdaFEST+ (combined) vs DP-AdaFEST vs DP-FEST at several ε
//! on Criteo-Kaggle (criteo-small here): best reduction within a fixed
//! utility-loss budget per ε.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::Algorithm;
use crate::runtime::Runtime;

use super::common::{best_reduction_within, print_table, train_once, write_csv, SweepRow};
use super::fig3_tradeoff::sweep_algorithm;

pub fn run(cfg: &RunConfig, rt: &Runtime, fast: bool) -> Result<()> {
    let mut base = cfg.clone();
    if fast {
        base.steps = base.steps.min(60);
        base.eval_batches = base.eval_batches.min(10);
    }
    let epsilons: &[f64] = if fast { &[1.0, 8.0] } else { &[1.0, 3.0, 8.0] };
    let threshold = 0.005;

    let mut rows = Vec::new();
    for &eps in epsilons {
        let mut b = base.clone();
        b.epsilon = eps;
        let mut dpsgd = b.clone();
        dpsgd.algorithm = Algorithm::DpSgd;
        let baseline = train_once(&dpsgd, rt)?;
        println!("eps={eps}: DP-SGD utility {:.4}", baseline.utility);
        for algo in [
            Algorithm::DpFest,
            Algorithm::DpAdaFest,
            Algorithm::DpAdaFestPlus,
        ] {
            let points = sweep_algorithm(&b, rt, algo, fast)?;
            let mut r = SweepRow::default();
            r.push("epsilon", eps);
            r.push("algorithm", algo.name());
            r.push("dpsgd_utility", format!("{:.4}", baseline.utility));
            match best_reduction_within(&points, baseline.utility, threshold) {
                Some((red, p)) => {
                    r.push("best_reduction", format!("{red:.2}"));
                    r.push("utility", format!("{:.4}", p.outcome.utility));
                    r.push("at", &p.label);
                }
                None => {
                    r.push("best_reduction", "none");
                    r.push("utility", "-");
                    r.push("at", "-");
                }
            }
            rows.push(r);
        }
    }
    print_table(
        &format!("Figure 4: combined algorithm vs parts (loss budget {threshold})"),
        &rows,
    );
    write_csv(&format!("fig4_{}", base.model), &rows)?;
    println!("\npaper shape check: dp-adafest-plus ≥ max(dp-adafest, dp-fest) per ε");
    Ok(())
}
