//! Figures 5 & 6 — time-series (streaming) evaluation on drifting data.
//!
//! Figure 5: DP-AdaFEST vs DP-FEST across streaming periods T ∈ {1, 2, 4}
//! and frequency sources (first-day / all-days / streaming), ε = 1.0.
//! Figure 6: the combined DP-AdaFEST+ vs its parts at period 1 with
//! streaming frequencies.
//!
//! Runs on either training path: the sync `StreamingTrainer` (`sweep
//! fig5`/`fig6`) or the async engine's streaming mode (`sweep
//! fig5-async`/`fig6-async`) — bit-identical by the engine's equivalence
//! contract, so the async ids exist to exercise the scale path.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::Algorithm;
use crate::data::CriteoConfig;
use crate::runtime::Runtime;
use crate::selection::FrequencySource;

use super::common::{print_table, streaming_once, write_csv, SweepRow};

fn streaming_run(
    cfg: &RunConfig,
    rt: &Runtime,
    gen_cfg: &CriteoConfig,
    engine: bool,
) -> Result<(f64, f64, f64)> {
    let out = streaming_once(cfg, rt, gen_cfg, engine)?;
    Ok((
        out.outcome.utility,
        out.outcome.reduction_factor,
        out.outcome.emb_grad_coords_per_step,
    ))
}

fn drift_cfg(cfg: &RunConfig, rt: &Runtime) -> Result<CriteoConfig> {
    let model = rt.manifest.model(&cfg.model)?;
    crate::coordinator::streaming::drift_gen_cfg(cfg, model)
}

pub fn run(cfg: &RunConfig, rt: &Runtime, fast: bool, combined: bool, engine: bool) -> Result<()> {
    let mut base = cfg.clone();
    base.epsilon = 1.0;
    if fast {
        base.steps = base.steps.min(72); // 4/day over 18 days
        base.eval_batches = base.eval_batches.min(8);
    }
    let gen_cfg = drift_cfg(&base, rt)?;
    let backend = if engine { "async engine" } else { "sync" };

    let mut rows = Vec::new();
    if combined {
        // Figure 6: period 1, streaming source; compare the three methods
        base.streaming_period = 1;
        base.freq_source = FrequencySource::Streaming;
        for algo in [
            Algorithm::DpFest,
            Algorithm::DpAdaFest,
            Algorithm::DpAdaFestPlus,
        ] {
            let mut c = base.clone();
            c.algorithm = algo;
            let (auc, red, coords) = streaming_run(&c, rt, &gen_cfg, engine)?;
            let mut r = SweepRow::default();
            r.push("algorithm", algo.name());
            r.push("auc", format!("{auc:.4}"));
            r.push("reduction", format!("{red:.2}"));
            r.push("emb_coords_per_step", format!("{coords:.0}"));
            println!("  [fig6] {}: auc={auc:.4} red={red:.1}x", algo.name());
            rows.push(r);
        }
        print_table(
            &format!("Figure 6: combined on Criteo-time-series ({backend})"),
            &rows,
        );
        write_csv(
            if engine { "fig6_timeseries_combined_async" } else { "fig6_timeseries_combined" },
            &rows,
        )?;
        println!("\npaper shape check: dp-adafest-plus ≥ max(parts) in reduction at ~equal AUC");
        return Ok(());
    }

    // Figure 5
    let periods: &[usize] = if fast { &[1, 4] } else { &[1, 2, 4] };
    for &period in periods {
        // DP-FEST at each frequency source
        for source in [
            FrequencySource::FirstDay,
            FrequencySource::AllDays,
            FrequencySource::Streaming,
        ] {
            let mut c = base.clone();
            c.algorithm = Algorithm::DpFest;
            c.streaming_period = period;
            c.freq_source = source;
            let (auc, red, _) = streaming_run(&c, rt, &gen_cfg, engine)?;
            let mut r = SweepRow::default();
            r.push("period", period);
            r.push("algorithm", "dp-fest");
            r.push("freq_source", format!("{source:?}"));
            r.push("auc", format!("{auc:.4}"));
            r.push("reduction", format!("{red:.2}"));
            println!("  [fig5] T={period} fest/{source:?}: auc={auc:.4} red={red:.1}x");
            rows.push(r);
        }
        // DP-AdaFEST (frequency source irrelevant)
        let mut c = base.clone();
        c.algorithm = Algorithm::DpAdaFest;
        c.streaming_period = period;
        let (auc, red, _) = streaming_run(&c, rt, &gen_cfg, engine)?;
        let mut r = SweepRow::default();
        r.push("period", period);
        r.push("algorithm", "dp-adafest");
        r.push("freq_source", "-");
        r.push("auc", format!("{auc:.4}"));
        r.push("reduction", format!("{red:.2}"));
        println!("  [fig5] T={period} adafest: auc={auc:.4} red={red:.1}x");
        rows.push(r);
    }
    print_table(
        &format!("Figure 5: time-series utility/efficiency ({backend})"),
        &rows,
    );
    write_csv(
        if engine { "fig5_timeseries_async" } else { "fig5_timeseries" },
        &rows,
    )?;
    println!(
        "\npaper shape check: streaming ≈ all-days ≫ first-day for DP-FEST; \
         dp-adafest beats dp-fest at equal utility"
    );
    Ok(())
}
