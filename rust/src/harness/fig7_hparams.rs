//! Figure 7 — effect of σ₁/σ₂ and τ on utility and embedding gradient size
//! — and Figure 9 — their joint heatmap.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::Algorithm;
use crate::runtime::Runtime;

use super::common::{print_table, train_once, write_csv, SweepRow};

pub fn run(cfg: &RunConfig, rt: &Runtime, fast: bool, heatmap: bool) -> Result<()> {
    let mut base = cfg.clone();
    base.algorithm = Algorithm::DpAdaFest;
    if fast {
        base.steps = base.steps.min(60);
        base.eval_batches = base.eval_batches.min(10);
    }

    let ratios: &[f64] = if fast {
        &[0.5, 5.0]
    } else {
        &[0.1, 0.5, 1.0, 2.0, 5.0, 10.0]
    };
    let taus: &[f64] = if fast {
        &[1.0, 20.0]
    } else {
        &[0.5, 1.0, 5.0, 10.0, 20.0, 50.0, 100.0]
    };

    let mut rows = Vec::new();
    if heatmap {
        // Figure 9: full ratio × tau grid
        for &ratio in ratios {
            for &tau in taus {
                let mut c = base.clone();
                c.sigma_ratio = ratio;
                c.tau = tau;
                let out = train_once(&c, rt)?;
                let mut r = SweepRow::default();
                r.push("sigma_ratio", ratio);
                r.push("tau", tau);
                r.push("utility", format!("{:.4}", out.utility));
                r.push("emb_coords_per_step", format!("{:.0}", out.emb_grad_coords_per_step));
                r.push("reduction", format!("{:.2}", out.reduction_factor));
                println!(
                    "  [fig9] ratio={ratio} tau={tau}: utility={:.4} size={:.0}",
                    out.utility, out.emb_grad_coords_per_step
                );
                rows.push(r);
            }
        }
        print_table("Figure 9: joint ratio × tau heatmap", &rows);
        write_csv(&format!("fig9_{}", base.model), &rows)?;
        return Ok(());
    }

    // Figure 7 left: vary ratio at fixed tau
    for &ratio in ratios {
        let mut c = base.clone();
        c.sigma_ratio = ratio;
        let out = train_once(&c, rt)?;
        let mut r = SweepRow::default();
        r.push("knob", "sigma_ratio");
        r.push("value", ratio);
        r.push("utility", format!("{:.4}", out.utility));
        r.push("emb_coords_per_step", format!("{:.0}", out.emb_grad_coords_per_step));
        println!(
            "  [fig7] ratio={ratio}: utility={:.4} size={:.0}",
            out.utility, out.emb_grad_coords_per_step
        );
        rows.push(r);
    }
    // Figure 7 right: vary tau at fixed ratio
    for &tau in taus {
        let mut c = base.clone();
        c.tau = tau;
        let out = train_once(&c, rt)?;
        let mut r = SweepRow::default();
        r.push("knob", "tau");
        r.push("value", tau);
        r.push("utility", format!("{:.4}", out.utility));
        r.push("emb_coords_per_step", format!("{:.0}", out.emb_grad_coords_per_step));
        println!(
            "  [fig7] tau={tau}: utility={:.4} size={:.0}",
            out.utility, out.emb_grad_coords_per_step
        );
        rows.push(r);
    }
    print_table("Figure 7: hyper-parameter effects", &rows);
    write_csv(&format!("fig7_{}", base.model), &rows)?;
    println!(
        "\npaper shape check: larger ratio → higher utility & larger grad size; \
         larger tau → smaller grad size, sharp utility drop only at extreme tau"
    );
    Ok(())
}
