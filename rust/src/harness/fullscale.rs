//! Full-scale harness, two halves:
//!
//! 1. The Table-3-scale gradient-size simulation — the paper's headline
//!    `>10⁵–10⁶×` reduction numbers live at the real Criteo vocabulary
//!    (≈339k rows, embedding dims from `int(2·V^0.25)`, B = 2048).
//!    Gradient *size* depends only on the selection/thresholding pipeline,
//!    not on model quality (DESIGN.md §2), so this half runs the actual
//!    DP-AdaFEST / DP-FEST survivor machinery on full-scale synthetic
//!    activations and counts noised coordinates — utility for the same
//!    knobs is measured at `criteo-small` scale by fig3.
//!
//! 2. A hundred-million-row paged-store workload: a `10⁸ × 8` table is
//!    opened zero-initialised through [`PagedTable`] (the file is one big
//!    sparse hole), rows are drawn Zipf(1.1) — the skew the paper's sparse
//!    gradients actually have — and sparse select (row reads) and scatter
//!    (Adagrad applies) throughput is measured, with the telemetry
//!    resident-bytes high-water asserted against `--store-budget-mb`.
//!    Rows land in `BENCH_engine.json` (schema v3, `"store": "paged"`) per
//!    docs/OBSERVABILITY.md; `--fast` shrinks to `10⁶` rows with a budget
//!    small enough that eviction still happens.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::RunConfig;
use crate::data::{CriteoConfig, SynthCriteo, ZipfSampler};
use crate::filtering::ContributionMap;
use crate::selection::dp_top_k_per_feature;
use crate::sparse::{Optimizer, RowSparseGrad};
use crate::store::{default_page_rows, unique_path, PagedTable, StoreOptions};
use crate::telemetry::{BenchRow, BenchSnapshot, Telemetry, BENCH_SCHEMA_VERSION};
use crate::util::rng::Xoshiro256;

use super::common::{print_table, write_csv, SweepRow};
use super::fig1_sparsity::CRITEO_VOCABS;

fn emb_dim(v: usize) -> usize {
    (2.0 * (v as f64).powf(0.25)) as usize
}

pub fn run(cfg: &RunConfig, fast: bool) -> Result<()> {
    let seed = cfg.seed;
    let vocabs = CRITEO_VOCABS.to_vec();
    let dims: Vec<usize> = vocabs.iter().map(|&v| emb_dim(v)).collect();
    let total_coords: usize = vocabs.iter().zip(&dims).map(|(&v, &d)| v * d).sum();
    let total_vocab: usize = vocabs.iter().sum();
    let offsets: Vec<usize> = {
        let mut acc = 0;
        vocabs
            .iter()
            .map(|&v| {
                let o = acc;
                acc += v;
                o
            })
            .collect()
    };
    let batch = if fast { 512 } else { 2048 };
    let steps = if fast { 5 } else { 20 };
    let sigma1 = 2.34; // the eps=1 calibration from the small-scale runs
    let c1 = 1.0;

    let gen = SynthCriteo::new(CriteoConfig::new(vocabs.clone(), seed));
    let mut rng = Xoshiro256::seed_from(seed ^ 0xF011);

    let mut rows = Vec::new();

    // dense DP-SGD baseline
    let mut r0 = SweepRow::default();
    r0.push("method", "dp-sgd (dense)");
    r0.push("knob", "-");
    r0.push("emb_coords_per_step", total_coords);
    r0.push("reduction", "1.00");
    rows.push(r0);

    // DP-AdaFEST across tau
    for &tau in &[0.5f64, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0] {
        let mut total = 0f64;
        for s in 0..steps {
            let b = gen.batch(0, batch, &mut rng);
            let examples = b.activated_rows(&offsets);
            let map = ContributionMap::from_batch(&examples, total_vocab, c1);
            let (surv, _) = map.survivors(sigma1, c1, tau, true, &mut rng);
            // count coordinates: survivors weighted by their table dims
            let mut coords = 0usize;
            let mut f = 0usize;
            for &id in surv.ids() {
                while f + 1 < offsets.len() && (id as usize) >= offsets[f + 1] {
                    f += 1;
                }
                // ids are sorted, so f only moves forward; reset per step
                coords += dims[f];
            }
            total += coords as f64;
            if s == 0 && tau == 0.5 {
                println!(
                    "  [fullscale] B={batch}: {} present rows of {total_vocab}",
                    map.nnz()
                );
            }
        }
        let per_step = total / steps as f64;
        let mut r = SweepRow::default();
        r.push("method", "dp-adafest");
        r.push("knob", format!("tau={tau}"));
        r.push("emb_coords_per_step", format!("{per_step:.0}"));
        r.push("reduction", format!("{:.1}", total_coords as f64 / per_step.max(1.0)));
        rows.push(r);
    }

    // DP-FEST across k
    let counts: Vec<Vec<f64>> = {
        let mut c: Vec<Vec<f64>> = vocabs.iter().map(|&v| vec![0f64; v]).collect();
        for _ in 0..10 {
            let b = gen.batch(0, batch, &mut rng);
            for i in 0..batch {
                for f in 0..vocabs.len() {
                    c[f][b.cat_of(i, f) as usize] += 1.0;
                }
            }
        }
        c
    };
    for &k in &[260usize, 2600, 26000, 130000] {
        let sel = dp_top_k_per_feature(&counts, k, 0.01, &mut rng);
        let coords: usize = sel
            .iter()
            .zip(&dims)
            .map(|(ids, &d)| ids.len() * d)
            .sum();
        let mut r = SweepRow::default();
        r.push("method", "dp-fest");
        r.push("knob", format!("k={k}"));
        r.push("emb_coords_per_step", coords);
        r.push(
            "reduction",
            format!("{:.1}", total_coords as f64 / coords.max(1) as f64),
        );
        rows.push(r);
    }

    print_table(
        &format!(
            "Full-scale gradient size (Table-3 vocabs: {total_vocab} rows, {total_coords} coords)"
        ),
        &rows,
    );
    write_csv("fullscale_gradsize", &rows)?;
    println!(
        "\npaper shape check: dp-adafest at high tau reaches >=1e4x; combined with\n\
         the Kaggle-scale vocab (1.7M rows in the paper) this is the >1e5-1e6x regime"
    );

    paged_throughput(cfg, fast)
}

/// The paged-store half: Zipf select/scatter throughput on a table far
/// larger than the page-cache budget, peak resident bytes asserted.
fn paged_throughput(cfg: &RunConfig, fast: bool) -> Result<()> {
    let rows = if fast { 1_000_000 } else { 100_000_000 };
    let dim = 8usize;
    let steps = if fast { 50 } else { 200 };
    let rows_per_step = if fast { 2048 } else { 4096 };
    // default budgets keep the cache well under the table so eviction is
    // actually on the measured path (fast: 10⁶ rows ≈ 61 MiB paged cost)
    let budget_mb = if cfg.store_budget_mb > 0 {
        cfg.store_budget_mb
    } else if fast {
        8
    } else {
        64
    };
    let budget_bytes = budget_mb * 1024 * 1024;
    let page_rows = default_page_rows(dim);
    let page_cost = (page_rows * dim * 8) as u64;

    let tele = Arc::new(Telemetry::new());
    let dir = StoreOptions::resolve_dir(&cfg.store_dir);
    let table = PagedTable::create_zeroed(
        unique_path(&dir, "fullscale"),
        rows,
        dim,
        page_rows,
        budget_bytes,
    )?
    .with_telemetry(Arc::clone(&tele));
    println!(
        "\n[fullscale] paged store: {rows} x {dim} table, {} rows/page, \
         budget {budget_mb} MiB ({} pages), file {}",
        table.page_rows(),
        table.budget_pages(),
        table.path().display()
    );

    let zipf = ZipfSampler::new(rows, 1.1);
    let mut rng = Xoshiro256::seed_from(cfg.seed ^ 0xFA57);
    let opt = Optimizer::adagrad(0.1);
    let mut vals = vec![0f32; dim];

    // scatter: one row-sparse Adagrad apply per step, Zipf-drawn rows
    let t0 = Instant::now();
    for step in 0..steps {
        let mut grad = RowSparseGrad::with_capacity(rows, dim, rows_per_step);
        for i in 0..rows_per_step {
            let r = zipf.sample(&mut rng);
            for (j, v) in vals.iter_mut().enumerate() {
                *v = ((step + i + j) % 13) as f32 * 1e-3;
            }
            grad.add_row(r as u32, &vals);
        }
        table.apply_sparse(&grad, &opt)?;
    }
    let scatter_secs = t0.elapsed().as_secs_f64();
    let touched = (steps * rows_per_step) as f64;

    // select: RowCache-style row reads over a fresh Zipf stream
    let mut out = vec![0f32; dim];
    let t1 = Instant::now();
    for _ in 0..steps {
        for _ in 0..rows_per_step {
            table.read_row(zipf.sample(&mut rng), &mut out)?;
        }
    }
    let select_secs = t1.elapsed().as_secs_f64();

    let peak = tele.store_resident_max();
    let resident_now = table.resident_bytes();
    drop(table);
    // the budget is a hard bound on resident cache bytes (floored at one
    // page when the budget is below a single page's worst-case cost)
    ensure!(
        peak <= budget_bytes.max(page_cost as usize) as u64,
        "paged store exceeded its budget: peak resident {peak} bytes > {budget_bytes}"
    );

    let mut table_rows = Vec::new();
    for (phase, secs) in [("scatter", scatter_secs), ("select", select_secs)] {
        let mut r = SweepRow::default();
        r.push("phase", phase);
        r.push("table_rows", rows);
        r.push("rows_touched", touched as u64);
        r.push("secs", format!("{secs:.3}"));
        r.push("rows_per_sec", format!("{:.0}", touched / secs.max(1e-9)));
        r.push(
            "peak_resident_mib",
            format!("{:.2}", peak as f64 / (1024.0 * 1024.0)),
        );
        table_rows.push(r);
    }
    print_table(
        &format!("Paged-store Zipf throughput ({rows} rows, budget {budget_mb} MiB)"),
        &table_rows,
    );
    write_csv("fullscale_paged", &table_rows)?;
    println!(
        "[fullscale] peak resident {:.2} MiB (budget {budget_mb} MiB), {:.2} MiB \
         resident at teardown",
        peak as f64 / (1024.0 * 1024.0),
        resident_now as f64 / (1024.0 * 1024.0)
    );

    append_bench_rows(steps, scatter_secs, select_secs)
}

/// Merge the paged throughput rows into the tracked bench snapshot
/// (`BENCH_engine.json`, or `$BENCH_OUT`), preserving any in-RAM rows the
/// engine throughput bench already wrote and replacing stale paged ones.
fn append_bench_rows(steps: usize, scatter_secs: f64, select_secs: f64) -> Result<()> {
    let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_engine.json".into());
    let mut snap = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| BenchSnapshot::parse(&t).ok())
        .unwrap_or_else(|| BenchSnapshot {
            schema_version: BENCH_SCHEMA_VERSION,
            bench: "engine_throughput".into(),
            model: "criteo-small".into(),
            algorithm: "dp-adafest".into(),
            steps: steps as u64,
            provenance: "sweep fullscale (paged rows only; ram rows come from \
                         cargo bench --bench engine_throughput)"
                .into(),
            rows: Vec::new(),
        });
    snap.rows.retain(|r| r.store != "paged");
    for (label, secs) in [("paged-scatter", scatter_secs), ("paged-select", select_secs)] {
        snap.rows.push(BenchRow {
            path: label.into(),
            grad_workers: 1,
            staleness: 0,
            store: "paged".into(),
            kernel_backend: "scalar".into(),
            secs,
            steps_per_sec: steps as f64 / secs.max(1e-9),
            speedup: 1.0,
        });
    }
    std::fs::write(&path, snap.to_json_pretty())?;
    println!("[fullscale] appended paged rows to {path}");
    Ok(())
}
