//! Full-Table-3-scale gradient-size simulation — the paper's headline
//! `>10⁵–10⁶×` reduction numbers live at the real Criteo vocabulary
//! (≈339k rows, embedding dims from `int(2·V^0.25)`, B = 2048).
//!
//! Gradient *size* depends only on the selection/thresholding pipeline, not
//! on model quality (DESIGN.md §2), so this harness runs the actual
//! DP-AdaFEST / DP-FEST survivor machinery on full-scale synthetic
//! activations and counts noised coordinates — utility for the same knobs is
//! measured at `criteo-small` scale by fig3.

use anyhow::Result;

use crate::data::{CriteoConfig, SynthCriteo};
use crate::filtering::ContributionMap;
use crate::selection::dp_top_k_per_feature;
use crate::util::rng::Xoshiro256;

use super::common::{print_table, write_csv, SweepRow};
use super::fig1_sparsity::CRITEO_VOCABS;

fn emb_dim(v: usize) -> usize {
    (2.0 * (v as f64).powf(0.25)) as usize
}

pub fn run(seed: u64, fast: bool) -> Result<()> {
    let vocabs = CRITEO_VOCABS.to_vec();
    let dims: Vec<usize> = vocabs.iter().map(|&v| emb_dim(v)).collect();
    let total_coords: usize = vocabs.iter().zip(&dims).map(|(&v, &d)| v * d).sum();
    let total_vocab: usize = vocabs.iter().sum();
    let offsets: Vec<usize> = {
        let mut acc = 0;
        vocabs
            .iter()
            .map(|&v| {
                let o = acc;
                acc += v;
                o
            })
            .collect()
    };
    let batch = if fast { 512 } else { 2048 };
    let steps = if fast { 5 } else { 20 };
    let sigma1 = 2.34; // the eps=1 calibration from the small-scale runs
    let c1 = 1.0;

    let gen = SynthCriteo::new(CriteoConfig::new(vocabs.clone(), seed));
    let mut rng = Xoshiro256::seed_from(seed ^ 0xF011);

    let mut rows = Vec::new();

    // dense DP-SGD baseline
    let mut r0 = SweepRow::default();
    r0.push("method", "dp-sgd (dense)");
    r0.push("knob", "-");
    r0.push("emb_coords_per_step", total_coords);
    r0.push("reduction", "1.00");
    rows.push(r0);

    // DP-AdaFEST across tau
    for &tau in &[0.5f64, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0] {
        let mut total = 0f64;
        for s in 0..steps {
            let b = gen.batch(0, batch, &mut rng);
            let examples = b.activated_rows(&offsets);
            let map = ContributionMap::from_batch(&examples, total_vocab, c1);
            let (surv, _) = map.survivors(sigma1, c1, tau, true, &mut rng);
            // count coordinates: survivors weighted by their table dims
            let mut coords = 0usize;
            let mut f = 0usize;
            for &id in surv.ids() {
                while f + 1 < offsets.len() && (id as usize) >= offsets[f + 1] {
                    f += 1;
                }
                // ids are sorted, so f only moves forward; reset per step
                coords += dims[f];
            }
            total += coords as f64;
            if s == 0 && tau == 0.5 {
                println!(
                    "  [fullscale] B={batch}: {} present rows of {total_vocab}",
                    map.nnz()
                );
            }
        }
        let per_step = total / steps as f64;
        let mut r = SweepRow::default();
        r.push("method", "dp-adafest");
        r.push("knob", format!("tau={tau}"));
        r.push("emb_coords_per_step", format!("{per_step:.0}"));
        r.push("reduction", format!("{:.1}", total_coords as f64 / per_step.max(1.0)));
        rows.push(r);
    }

    // DP-FEST across k
    let counts: Vec<Vec<f64>> = {
        let mut c: Vec<Vec<f64>> = vocabs.iter().map(|&v| vec![0f64; v]).collect();
        for _ in 0..10 {
            let b = gen.batch(0, batch, &mut rng);
            for i in 0..batch {
                for f in 0..vocabs.len() {
                    c[f][b.cat_of(i, f) as usize] += 1.0;
                }
            }
        }
        c
    };
    for &k in &[260usize, 2600, 26000, 130000] {
        let sel = dp_top_k_per_feature(&counts, k, 0.01, &mut rng);
        let coords: usize = sel
            .iter()
            .zip(&dims)
            .map(|(ids, &d)| ids.len() * d)
            .sum();
        let mut r = SweepRow::default();
        r.push("method", "dp-fest");
        r.push("knob", format!("k={k}"));
        r.push("emb_coords_per_step", coords);
        r.push(
            "reduction",
            format!("{:.1}", total_coords as f64 / coords.max(1) as f64),
        );
        rows.push(r);
    }

    print_table(
        &format!(
            "Full-scale gradient size (Table-3 vocabs: {total_vocab} rows, {total_coords} coords)"
        ),
        &rows,
    );
    write_csv("fullscale_gradsize", &rows)?;
    println!(
        "\npaper shape check: dp-adafest at high tau reaches >=1e4x; combined with\n\
         the Kaggle-scale vocab (1.7M rows in the paper) this is the >1e5-1e6x regime"
    );
    Ok(())
}
