//! §3.4 bias–variance trade-off — an empirical check of Lemma 3.1 / Eq. (2)
//! on a convex problem with a controllable gradient oracle.
//!
//! Objective: `L(θ) = ½‖θ − θ*‖²` over R^D (Lipschitz within the ball we
//! project to).  Two oracles:
//!
//! * DP-SGD-style   — unbiased, noise on all D coordinates: variance D·σ²;
//! * AdaFEST-style  — the γ-fraction smallest-|∇| coordinates are truncated
//!   (bias ≈ γ·L) and noise lands on the surviving h coordinates only
//!   (variance h·σ²).
//!
//! Per Eq. (2), for small γ and h ≪ D the truncated oracle wins; for large
//! γ the bias term dominates and DP-SGD wins — the harness sweeps γ and
//! prints both losses so the crossover is visible.

use anyhow::Result;

use crate::util::rng::Xoshiro256;

use super::common::{print_table, write_csv, SweepRow};

fn project(theta: &mut [f64], radius: f64) {
    let norm: f64 = theta.iter().map(|v| v * v).sum::<f64>().sqrt();
    if norm > radius {
        let s = radius / norm;
        for v in theta.iter_mut() {
            *v *= s;
        }
    }
}

/// Run projected SGD with the chosen oracle; returns the final average loss.
fn run_sgd(
    d: usize,
    keep_frac: f64, // fraction of coordinates kept (1.0 = DP-SGD)
    sigma: f64,
    steps: usize,
    seed: u64,
) -> f64 {
    let mut rng = Xoshiro256::seed_from(seed);
    let theta_star: Vec<f64> = (0..d).map(|_| rng.gauss()).collect();
    let radius = 4.0 * (d as f64).sqrt();
    let mut theta = vec![0f64; d];
    let h = ((d as f64) * keep_frac).ceil() as usize;
    let eta = radius / ((1.0 + (h as f64) * sigma * sigma) * steps as f64).sqrt();
    let mut avg = vec![0f64; d];
    for _ in 0..steps {
        // gradient = theta - theta*
        let mut idx: Vec<usize> = (0..d).collect();
        if keep_frac < 1.0 {
            // keep the h largest-magnitude gradient coordinates (the
            // "most-contributing" ones — AdaFEST's thresholding analogue)
            idx.sort_by(|&a, &b| {
                let ga = (theta[a] - theta_star[a]).abs();
                let gb = (theta[b] - theta_star[b]).abs();
                gb.partial_cmp(&ga).unwrap()
            });
            idx.truncate(h);
        }
        for &i in &idx {
            let g = (theta[i] - theta_star[i]) + rng.gauss() * sigma;
            theta[i] -= eta * g;
        }
        project(&mut theta, radius);
        for (a, t) in avg.iter_mut().zip(&theta) {
            *a += t;
        }
    }
    let inv = 1.0 / steps as f64;
    let loss: f64 = avg
        .iter()
        .zip(&theta_star)
        .map(|(a, s)| {
            let d = a * inv - s;
            0.5 * d * d
        })
        .sum();
    loss
}

pub fn run(fast: bool) -> Result<()> {
    let d = 2000;
    let steps = if fast { 300 } else { 2000 };
    let sigma = 0.8;
    let trials = if fast { 3 } else { 8 };

    let mut rows = Vec::new();
    let keeps = [1.0, 0.5, 0.2, 0.1, 0.05, 0.01, 0.002];
    for &keep in &keeps {
        let mut losses = Vec::new();
        for t in 0..trials {
            losses.push(run_sgd(d, keep, sigma, steps, 1000 + t as u64));
        }
        let mean = crate::util::stats::mean(&losses);
        let mut r = SweepRow::default();
        r.push("keep_frac", keep);
        r.push(
            "oracle",
            if keep == 1.0 { "dp-sgd (dense noise)" } else { "truncated (sparse noise)" },
        );
        r.push("mean_final_loss", format!("{mean:.4}"));
        println!("  [lemma31] keep={keep}: loss={mean:.4}");
        rows.push(r);
    }
    print_table("Lemma 3.1 / Eq.(2): bias-variance trade-off", &rows);
    write_csv("lemma31_bias_variance", &rows)?;
    println!(
        "\npaper shape check: moderate truncation beats dense noise \
         (h·σ² ≪ D·σ² outweighs small bias); extreme truncation loses (bias dominates)"
    );
    Ok(())
}
