//! Experiment harness: one module per paper table/figure (DESIGN.md §4).
//! Each produces the same rows/series the paper reports, printed as aligned
//! text and written as CSV under `results/`.

mod common;
mod fig1_sparsity;
mod fig3_tradeoff;
mod fig4_combined;
mod fig5_timeseries;
mod fig7_hparams;
mod fullscale;
mod lemma31;
mod tab1_lora;
mod tab2_vocab;
mod tab4_wallclock;
mod tab5_streaming;
mod tab6_frozen;

pub use common::{write_csv, SweepRow};

use anyhow::{bail, Result};

use crate::config::RunConfig;
use crate::runtime::Runtime;

/// Dispatch a named experiment.  `fast` scales the sweep down for CI.
pub fn run_experiment(name: &str, cfg: &RunConfig, rt: &Runtime, fast: bool) -> Result<()> {
    match name {
        "fig1b" => fig1_sparsity::run(cfg, fast),
        "fig3" => fig3_tradeoff::run(cfg, rt, fast),
        "fig4" => fig4_combined::run(cfg, rt, fast),
        "fig5" => fig5_timeseries::run(cfg, rt, fast, false, false),
        "fig5-async" => fig5_timeseries::run(cfg, rt, fast, false, true),
        "fig6" => fig5_timeseries::run(cfg, rt, fast, true, false),
        "fig6-async" => fig5_timeseries::run(cfg, rt, fast, true, true),
        "fig7" => fig7_hparams::run(cfg, rt, fast, false),
        "fig8" => fig3_tradeoff::run_scatter(cfg, rt, fast),
        "fig9" => fig7_hparams::run(cfg, rt, fast, true),
        "tab1" => tab1_lora::run(cfg, rt, fast),
        "tab2" => tab2_vocab::run(cfg, rt, fast),
        "tab4" => tab4_wallclock::run(fast),
        "tab5" => tab5_streaming::run(cfg, rt, fast, false),
        "tab5-async" => tab5_streaming::run(cfg, rt, fast, true),
        "tab6" => tab6_frozen::run(cfg, rt, fast),
        "lemma31" => lemma31::run(fast),
        "fullscale" => fullscale::run(cfg, fast),
        other => bail!(
            "unknown experiment {other} (want fig1b|fig3|fig4|fig5|fig5-async|fig6|fig6-async|\
             fig7|fig8|fig9|tab1|tab2|tab4|tab5|tab5-async|tab6|lemma31|fullscale)"
        ),
    }
}
