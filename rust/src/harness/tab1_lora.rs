//! Table 1 — gradient-size reduction of DP-AdaFEST vs LoRA-on-embeddings
//! for the RoBERTa-stand-in on SST-2-like data, ε = 1.0.
//!
//! LoRA's embedding "gradient size" is exact arithmetic: training (A, B)
//! instead of the (V×d) table densifies (V·r + r·d) coordinates per step, so
//! its reduction vs DP-SGD is `V·d / (V·r + r·d)`.  Utility per rank is
//! *measured* by training the `nlu-roberta-loraemb{r}` artifact models when
//! built (r ∈ {4, 16, 64}), falling back to the built-in
//! `nlu-small-lora{r}` reference models otherwise — the rank rows run
//! artifact-free on the native LoRA executor
//! (`runtime/reference/transformer.rs`), under dense DP-SGD, exactly the
//! baseline the paper describes.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::Algorithm;
use crate::runtime::Runtime;

use super::common::{
    best_reduction_within, model_executable, model_or_builtin, print_table, train_once,
    write_csv, SweepPoint, SweepRow,
};
use super::fig3_tradeoff::sweep_algorithm;

pub const THRESHOLDS: [f64; 3] = [0.001, 0.005, 0.01];

pub fn run(cfg: &RunConfig, rt: &Runtime, fast: bool) -> Result<()> {
    let mut base = cfg.clone();
    base.model = model_or_builtin(rt, "nlu-roberta", "nlu-small");
    base.epsilon = 1.0;
    if fast {
        base.steps = base.steps.min(50);
        base.eval_batches = base.eval_batches.min(8);
    }

    // DP-SGD reference on the full-embedding model
    let mut dpsgd = base.clone();
    dpsgd.algorithm = Algorithm::DpSgd;
    let baseline = train_once(&dpsgd, rt)?;
    println!("DP-SGD (full embedding) utility: {:.4}", baseline.utility);

    // DP-AdaFEST sweep (measured reductions)
    let ada_points = sweep_algorithm(&base, rt, Algorithm::DpAdaFest, fast)?;

    // LoRA points: measured utility per rank, analytic size from that
    // model's own (V, d) geometry
    let ranks: &[usize] = if fast { &[16] } else { &[4, 16, 64] };
    let mut lora_points: Vec<SweepPoint> = Vec::new();
    for &r in ranks {
        let mname = model_or_builtin(
            rt,
            &format!("nlu-roberta-loraemb{r}"),
            &format!("nlu-small-lora{r}"),
        );
        if !model_executable(rt, &mname) {
            println!("  (skipping LoRA r={r}: {mname} not runnable on this backend)");
            continue;
        }
        let lmodel = rt.manifest.model(&mname)?;
        let v = lmodel.attr_usize("vocab")? as f64;
        let d = lmodel.attr_usize("d_model")? as f64;
        let mut c = base.clone();
        c.model = mname;
        c.algorithm = Algorithm::DpSgd; // dense noise on A and B — the LoRA baseline
        let mut out = train_once(&c, rt)?;
        let reduction = v * d / (v * r as f64 + r as f64 * d);
        out.reduction_factor = reduction;
        println!(
            "  [lora] r={r}: utility={:.4} analytic reduction={reduction:.2}x",
            out.utility
        );
        lora_points.push(SweepPoint { label: format!("r={r}"), outcome: out });
    }

    let mut rows = Vec::new();
    for &thr in &THRESHOLDS {
        let mut row = SweepRow::default();
        row.push("utility_loss", thr);
        match best_reduction_within(&ada_points, baseline.utility, thr) {
            Some((red, _)) => row.push("dp_adafest_reduction", format!("{red:.2}")),
            None => row.push("dp_adafest_reduction", "none"),
        }
        match best_reduction_within(&lora_points, baseline.utility, thr) {
            Some((red, p)) => {
                row.push("lora_reduction", format!("{red:.2}"));
                row.push("lora_rank", &p.label);
            }
            None => {
                row.push("lora_reduction", "none");
                row.push("lora_rank", "-");
            }
        }
        rows.push(row);
    }
    print_table("Table 1: DP-AdaFEST vs LoRA (word embeddings)", &rows);
    write_csv("tab1_lora", &rows)?;
    println!("\npaper shape check: DP-AdaFEST reduction > LoRA reduction at every threshold");
    Ok(())
}
