//! Table 2 — DP-AdaFEST's reduction grows with vocabulary size:
//! RoBERTa-size (50,265) vs XLM-R-size (250,002) vocabularies, ε = 1.0.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::Algorithm;
use crate::runtime::Runtime;

use super::common::{
    best_reduction_within, model_or_builtin, print_table, train_once, write_csv, SweepRow,
};
use super::fig3_tradeoff::sweep_algorithm;
use super::tab1_lora::THRESHOLDS;

pub fn run(cfg: &RunConfig, rt: &Runtime, fast: bool) -> Result<()> {
    let mut rows = Vec::new();
    // artifact builds compare real tokenizer vocabularies; the built-in
    // fallback keeps the small-vs-large contrast (512 vs 4096)
    let models = [
        model_or_builtin(rt, "nlu-roberta", "nlu-tiny"),
        model_or_builtin(rt, "nlu-xlmr", "nlu-small"),
    ];

    let mut per_model = Vec::new();
    for model in &models {
        let mut base = cfg.clone();
        base.model = model.clone();
        base.epsilon = 1.0;
        if fast {
            base.steps = base.steps.min(50);
            base.eval_batches = base.eval_batches.min(8);
        }
        let mut dpsgd = base.clone();
        dpsgd.algorithm = Algorithm::DpSgd;
        let baseline = train_once(&dpsgd, rt)?;
        println!("[{model}] DP-SGD utility: {:.4}", baseline.utility);
        let points = sweep_algorithm(&base, rt, Algorithm::DpAdaFest, fast)?;
        per_model.push((model, baseline, points));
    }

    for &thr in &THRESHOLDS {
        let mut row = SweepRow::default();
        row.push("utility_loss", thr);
        for (model, baseline, points) in &per_model {
            match best_reduction_within(points, baseline.utility, thr) {
                Some((red, _)) => row.push(&format!("{model}_reduction"), format!("{red:.2}")),
                None => row.push(&format!("{model}_reduction"), "none"),
            }
        }
        rows.push(row);
    }
    print_table("Table 2: reduction vs vocabulary size (50k vs 250k)", &rows);
    write_csv("tab2_vocab", &rows)?;
    println!("\npaper shape check: the 250k-vocab column dominates the 50k column");
    Ok(())
}
