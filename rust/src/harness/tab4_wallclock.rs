//! Table 4 — wall-clock cost of the dense DP-SGD embedding update vs the
//! sparsity-preserving update, as the vocabulary grows (1e5 … 1e7).
//!
//! The paper measures 100 training steps of a (V × 64) embedding layer at
//! batch 1024.  The mechanism is hardware-independent: the dense path must
//! (a) generate V·d Gaussian samples and (b) write V·d coordinates, both
//! linear in V, while the sparse path touches only the ≤B activated rows.
//! We time exactly those two code paths in the Rust update engine.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::Algorithm;
use crate::data::CriteoConfig;
use crate::engine;
use crate::runtime::Runtime;
use crate::sparse::{add_dense_noise, add_row_noise, Optimizer, RowSparseGrad};
use crate::telemetry::Stopwatch;
use crate::util::bench::fmt_dur;
use crate::util::rng::Xoshiro256;

use super::common::{print_table, write_csv, SweepRow};

pub struct UpdateTiming {
    pub vocab: usize,
    pub dense_secs: f64,
    pub sparse_secs: f64,
}

/// Time `steps` dense vs sparse embedding updates at the given geometry.
pub fn time_updates(
    vocab: usize,
    dim: usize,
    batch: usize,
    steps: usize,
    seed: u64,
) -> UpdateTiming {
    let mut rng = Xoshiro256::seed_from(seed);
    let opt = Optimizer::sgd(0.01);
    let mut table = vec![0.01f32; vocab * dim];
    let mut state = crate::sparse::DenseState::default();
    let row_grad: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.01).sin()).collect();
    // pre-draw activated rows per step (zipf-free uniform is fine: cost is
    // row-count driven)
    let act: Vec<Vec<u32>> = (0..steps)
        .map(|_| (0..batch).map(|_| rng.below(vocab as u64) as u32).collect())
        .collect();

    // dense path: dense grad buffer + dense noise + dense update
    // (timed on the telemetry stopwatch — same clock as the run traces)
    let t0 = Stopwatch::start();
    let mut dense_grad = vec![0f32; vocab * dim];
    for rows in &act {
        for v in dense_grad.iter_mut() {
            *v = 0.0;
        }
        for &r in rows {
            let base = r as usize * dim;
            for (g, x) in dense_grad[base..base + dim].iter_mut().zip(&row_grad) {
                *g += x;
            }
        }
        add_dense_noise(&mut dense_grad, 1.0, &mut rng);
        opt.dense_step(&mut table, &dense_grad, &mut state);
    }
    let dense_secs = t0.elapsed_secs();

    // sparse path: row-sparse grad + row noise + scatter update
    let t1 = Stopwatch::start();
    for rows in &act {
        let mut g = RowSparseGrad::with_capacity(vocab, dim, batch);
        for &r in rows {
            g.add_row(r, &row_grad);
        }
        add_row_noise(&mut g, 1.0, &mut rng);
        opt.sparse_step(&mut table, &g, &mut state);
    }
    let sparse_secs = t1.elapsed_secs();

    UpdateTiming { vocab, dense_secs, sparse_secs }
}

pub fn run(fast: bool) -> Result<()> {
    // fast keeps the full vocab range (the shape is the point) with fewer
    // steps; full matches the paper's 100-step protocol.
    let vocabs: &[usize] =
        &[100_000, 200_000, 1_000_000, 2_000_000, 5_000_000, 10_000_000];
    let steps = if fast { 10 } else { 100 };
    let (dim, batch) = (64, 1024);

    let mut rows = Vec::new();
    for &v in vocabs {
        let t = time_updates(v, dim, batch, steps, 42);
        let factor = t.dense_secs / t.sparse_secs;
        let mut r = SweepRow::default();
        r.push("vocab", v);
        r.push("dp_sgd_secs", format!("{:.3}", t.dense_secs));
        r.push("ours_secs", format!("{:.3}", t.sparse_secs));
        r.push("reduction_factor", format!("{factor:.2}"));
        println!(
            "  [tab4] V={v}: dense {} sparse {} ({factor:.1}x)",
            fmt_dur(std::time::Duration::from_secs_f64(t.dense_secs)),
            fmt_dur(std::time::Duration::from_secs_f64(t.sparse_secs)),
        );
        rows.push(r);
    }
    print_table(
        &format!("Table 4: wall-clock, {steps} steps, d={dim}, B={batch}"),
        &rows,
    );
    write_csv("tab4_wallclock", &rows)?;
    println!(
        "\npaper shape check: dense time grows ~linearly with V; sparse is ~flat; \
         reduction factor grows with V (paper reports 3x…177x over 1e5…1e7)"
    );
    engine_comparison(fast)
}

/// End-to-end steps/sec: sync trainer vs the async engine at 1/2/4 gradient
/// workers, on the reference runtime's criteo-small (results asserted
/// bit-identical — the engine only changes wall-clock).
fn engine_comparison(fast: bool) -> Result<()> {
    let rt = Runtime::builtin();
    let mut cfg = RunConfig::default();
    cfg.model = "criteo-small".into();
    cfg.algorithm = Algorithm::DpAdaFest;
    cfg.steps = if fast { 24 } else { 80 };
    cfg.eval_batches = 1;
    let model = rt.manifest.model(&cfg.model)?.clone();
    let vocabs = model.attr_usize_list("vocabs")?;
    let gen_cfg = CriteoConfig::new(vocabs, cfg.seed ^ 0xDA7A);

    let comparison = engine::compare_throughput(&cfg, &rt, &gen_cfg, &[1, 2, 4])?;
    let mut rows = Vec::new();
    for t in &comparison {
        let mut r = SweepRow::default();
        r.push("path", t.path);
        r.push("workers", t.grad_workers);
        r.push("steps_per_sec", format!("{:.1}", t.steps_per_sec));
        r.push("speedup", format!("{:.2}", t.speedup));
        rows.push(r);
    }
    print_table(
        &format!("Table 4b: engine steps/sec, {} steps, criteo-small", cfg.steps),
        &rows,
    );
    write_csv("tab4_engine", &rows)?;
    println!("(loss histories asserted bit-identical across all rows)");
    Ok(())
}
