//! Table 5 — evaluation AUC of the time-series model under vanilla DP-SGD
//! and non-private training, across streaming periods and ε.
//!
//! The paper's observation: DP training degrades as the streaming period
//! shrinks (more staleness sensitivity / fewer examples per update window),
//! while non-private training is insensitive — evidence that DP training is
//! more vulnerable to distribution shift.
//!
//! Runs on either training path: the sync `StreamingTrainer` (`sweep tab5`)
//! or the async engine's streaming mode (`sweep tab5-async`) — the two are
//! bit-identical, so the async variant exists to exercise/benchmark the
//! scale path, not to change numbers.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::Algorithm;
use crate::runtime::Runtime;

use super::common::{print_table, streaming_once, write_csv, SweepRow};

pub fn run(cfg: &RunConfig, rt: &Runtime, fast: bool, engine: bool) -> Result<()> {
    let mut base = cfg.clone();
    if fast {
        base.steps = base.steps.min(72);
        base.eval_batches = base.eval_batches.min(8);
    }
    let model = rt.manifest.model(&base.model)?;
    let gen_cfg = crate::coordinator::streaming::drift_gen_cfg(&base, model)?;
    let backend = if engine { "async engine" } else { "sync" };

    let periods: &[usize] = if fast { &[1, 18] } else { &[1, 2, 4, 8, 16, 18] };
    let epsilons: &[f64] = if fast { &[1.0] } else { &[1.0, 3.0, 8.0] };

    let mut rows = Vec::new();
    for &period in periods {
        let mut row = SweepRow::default();
        row.push("streaming_period", period);
        for &eps in epsilons {
            let mut c = base.clone();
            c.algorithm = Algorithm::DpSgd;
            c.epsilon = eps;
            c.streaming_period = period;
            let out = streaming_once(&c, rt, &gen_cfg, engine)?;
            row.push(&format!("eps_{eps}"), format!("{:.4}", out.outcome.utility));
            println!("  [tab5] T={period} eps={eps}: auc={:.4}", out.outcome.utility);
        }
        // non-private column
        let mut c = base.clone();
        c.algorithm = Algorithm::NonPrivate;
        c.streaming_period = period;
        let out = streaming_once(&c, rt, &gen_cfg, engine)?;
        row.push("non_private", format!("{:.4}", out.outcome.utility));
        println!("  [tab5] T={period} non-private: auc={:.4}", out.outcome.utility);
        rows.push(row);
    }
    print_table(&format!("Table 5: AUC vs streaming period ({backend})"), &rows);
    write_csv(
        if engine { "tab5_streaming_async" } else { "tab5_streaming" },
        &rows,
    )?;
    println!(
        "\npaper shape check: DP columns improve slightly with larger periods; \
         non-private column is ~flat"
    );
    Ok(())
}
