//! Table 6 — training word embeddings under DP improves accuracy vs
//! freezing them (the paper's motivation for making embedding training
//! efficient in the first place).

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::Algorithm;
use crate::runtime::Runtime;

use super::common::{model_or_builtin, print_table, train_once, write_csv, SweepRow};

pub fn run(cfg: &RunConfig, rt: &Runtime, fast: bool) -> Result<()> {
    let mut base = cfg.clone();
    base.model = model_or_builtin(rt, "nlu-roberta", "nlu-small");
    if fast {
        base.steps = base.steps.min(50);
        base.eval_batches = base.eval_batches.min(8);
    }
    let epsilons: &[f64] = if fast { &[1.0] } else { &[1.0, 3.0, 8.0] };

    let mut rows = Vec::new();

    // non-private reference
    let mut np = base.clone();
    np.algorithm = Algorithm::NonPrivate;
    let np_out = train_once(&np, rt)?;
    let mut r = SweepRow::default();
    r.push("setting", "non-private");
    r.push("accuracy", format!("{:.4}", np_out.utility));
    rows.push(r);

    for &eps in epsilons {
        for frozen in [false, true] {
            let mut c = base.clone();
            c.algorithm = Algorithm::DpSgd;
            c.epsilon = eps;
            c.freeze_embedding = frozen;
            let out = train_once(&c, rt)?;
            let mut r = SweepRow::default();
            r.push(
                "setting",
                format!(
                    "dp-sgd eps={eps}{}",
                    if frozen { " (embedding frozen)" } else { "" }
                ),
            );
            r.push("accuracy", format!("{:.4}", out.utility));
            println!(
                "  [tab6] eps={eps} frozen={frozen}: acc={:.4}",
                out.utility
            );
            rows.push(r);
        }
    }
    print_table("Table 6: frozen vs trained embeddings under DP", &rows);
    write_csv("tab6_frozen", &rows)?;
    println!("\npaper shape check: trained-embedding rows ≥ frozen rows at each ε");
    Ok(())
}
