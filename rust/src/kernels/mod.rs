//! Cache-blocked, register-tiled dense kernels for the native executors.
//!
//! The reference runtime's hot path — the NLU transformer's attention and
//! MLP matmuls, and the pCTR tower's affine stack — used to run as scalar
//! triple loops.  This module replaces them with blocked kernels that are
//! **bit-identical** to those retired loops, which is what lets the rest of
//! the system (sync==async equivalence, Gram==scatter clipping, the FD
//! gradchecks) carry over untouched.
//!
//! ## The bit-exactness argument
//!
//! Each output element of every kernel is produced by exactly one
//! *accumulation chain*: an initial value (0, a bias entry, or a fresh dot
//! product later added onto the output once — see [`MatInit`]), followed by
//! the `k` multiply-add terms **in ascending k order**, exactly as the
//! scalar loop ordered them.  Blocking changes only the *interleaving
//! across* output elements (i/j tiles; f32 ops on different elements are
//! independent), never the order *within* a chain — there is deliberately
//! no k-blocking, because splitting a chain through memory would be the one
//! transformation able to change rounding.  Threading ([`set_threads`])
//! partitions output **rows** across threads and nothing else, so it cannot
//! reorder a chain either.  `tests/kernels.rs` pins all of this with
//! `to_bits` equality against naive in-test oracles over random shapes and
//! strides.
//!
//! Like the retired loops, [`matmul`], [`matmul_at`], and [`add_bias_gelu`]
//! skip multiply-adds whose A-operand is exactly `0.0` (the pCTR tower's
//! post-ReLU activations and the LoRA `A` rows are sparse); the oracle
//! defines this skip as part of the chain.  A few retired call sites (the
//! attention dq/dk/dv loops, the head outer product) had *no* skip; for
//! those the equivalence is scoped to finite operands — a `+0.0`-initialised
//! chain can never reach `-0.0` in round-to-nearest, so skipping a `±0.0`
//! term is bit-invisible there, but a signed-zero store or a `0·∞` term
//! could differ in non-finite/signed-zero corners no trained model reaches.
//!
//! ## Layout
//!
//! All operands are row-major `f32` with an explicit row pitch
//! ([`MatShape`]'s `ra`/`rb`/`rc` — pitch ≥ logical width), which is what
//! lets the attention kernels run directly on per-head column slices of the
//! `(T, d)` activation buffers (pitch `d`, width `d/heads`) without any
//! packing or copies.
//!
//! ## Tiling
//!
//! The register tile is [`MR`]×[`NR`] (4×8): [`NR`] accumulator chains per
//! A row are held across the whole k loop (instead of round-tripping the
//! output row through memory every k step, as the scalar loops did), and
//! [`MR`] A rows share each B panel load.  The k×[`NR`] B panel a j-tile
//! streams is at most a few KiB and stays in L1 across the i sweep.  Edge
//! tiles (dims not divisible by 4/8) run the same chains at reduced width.
//!
//! ## The SIMD backend
//!
//! Everything above describes the default [`KernelBackend::Scalar`] path.
//! An opt-in [`KernelBackend::Simd`] path ([`set_backend`], selected per
//! run via `--engine-kernel-backend simd`) trades the bit-exactness
//! guarantee for lane-parallel accumulation: its kernels (`simd`
//! submodule) reassociate the k-chains into fixed 8-lane partial sums plus
//! a fixed pairwise horizontal reduce, which is verified against the
//! scalar kernels at a documented ULP/relative-error tolerance instead of
//! `to_bits` (`tests/kernels.rs`, `docs/RUNTIME.md`).  The SIMD path is
//! itself deterministic — same inputs, same bits, on every machine — it is
//! only *different* bits from the scalar chains.

#![warn(missing_docs)]

mod pool;
mod simd;

pub use pool::{
    backend, fan_out_count, par_min_work, set_backend, set_par_min_work, set_threads, threads,
    ScopedConfig, DEFAULT_PAR_MIN_WORK,
};
pub use simd::simd_acceleration;

/// Which kernel implementation a run computes with (process-wide, like the
/// thread knob — see [`set_backend`] and [`ScopedConfig`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelBackend {
    /// The blocked scalar chains — bit-identical to the retired loops and
    /// the backend every bit-exactness proof is pinned to.  The default.
    #[default]
    Scalar,
    /// Lane-parallel variants (8-wide f32, AVX2 when the CPU has it,
    /// portable lanes otherwise) that reassociate the k-chains —
    /// ULP-bounded against [`KernelBackend::Scalar`], not bit-identical.
    Simd,
}

impl KernelBackend {
    /// Stable lower-case label (`"scalar"` / `"simd"`) used by the CLI,
    /// telemetry summaries, and `BENCH_engine.json` rows.
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Simd => "simd",
        }
    }
}

impl std::fmt::Display for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for KernelBackend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "scalar" => Ok(KernelBackend::Scalar),
            "simd" => Ok(KernelBackend::Simd),
            other => anyhow::bail!(
                "unknown kernel backend {other:?} (expected \"scalar\" or \"simd\")"
            ),
        }
    }
}

/// Register-tile height: A rows processed together per tile.
pub const MR: usize = 4;
/// Register-tile width: accumulator chains held per A row.
pub const NR: usize = 8;

/// Logical geometry of one kernel call: an `(m × n)` output contracted over
/// `k`, with the row pitches of the three operands.  What A's and B's rows
/// mean depends on the kernel — see each kernel's docs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MatShape {
    /// output rows
    pub m: usize,
    /// contraction length
    pub k: usize,
    /// output columns
    pub n: usize,
    /// row pitch of A (≥ its logical width)
    pub ra: usize,
    /// row pitch of B
    pub rb: usize,
    /// row pitch of C (the output)
    pub rc: usize,
}

impl MatShape {
    /// Packed (pitch = width) shape for [`matmul`]: `A (m×k) · B (k×n)`.
    pub fn packed(m: usize, k: usize, n: usize) -> MatShape {
        MatShape { m, k, n, ra: k, rb: n, rc: n }
    }

    /// Packed shape for [`matmul_bt`]: `A (m×k) · Bᵀ` with `B (n×k)`.
    pub fn packed_bt(m: usize, k: usize, n: usize) -> MatShape {
        MatShape { m, k, n, ra: k, rb: k, rc: n }
    }

    /// Packed shape for [`matmul_at`]: `Aᵀ · B` with `A (k×m)`, `B (k×n)`.
    pub fn packed_at(m: usize, k: usize, n: usize) -> MatShape {
        MatShape { m, k, n, ra: m, rb: n, rc: n }
    }
}

/// How each output element's accumulation chain starts and lands — the
/// three patterns the retired scalar loops used:
#[derive(Clone, Copy, Debug)]
pub enum MatInit<'a> {
    /// chain starts at `0.0`; the result is **stored** (a buffer the old
    /// loop zero-initialised and accumulated into in place)
    Zero,
    /// chain starts at `0.0`; the result is **added onto** the existing
    /// output once (the old `out[i] += dot` pattern)
    Accumulate,
    /// chain starts at `bias[j]` (the output column's bias) and is stored —
    /// the old affine's `copy_from_slice(bias)`-then-accumulate pattern
    Bias(&'a [f32]),
}

/// Minimal buffer length for `rows` rows at `pitch` whose last row only
/// needs `cols` elements.
fn min_len(rows: usize, pitch: usize, cols: usize) -> usize {
    if rows == 0 || cols == 0 {
        0
    } else {
        (rows - 1) * pitch + cols
    }
}

fn check_out(out: &[f32], sh: &MatShape, init: &MatInit<'_>, kernel: &str) {
    assert!(
        out.len() >= min_len(sh.m, sh.rc, sh.n),
        "{kernel}: output too short for {sh:?}"
    );
    if let MatInit::Bias(bias) = init {
        assert!(bias.len() >= sh.n, "{kernel}: bias shorter than n ({sh:?})");
    }
}

// ---------------------------------------------------------------------------
// matmul: C = A · B
// ---------------------------------------------------------------------------

/// `C (m×n) ←[init] A (m×k) · B (k×n)`.
///
/// Chain per element `(i, j)`: start per [`MatInit`], then
/// `+= A[i,kk] · B[kk,j]` for `kk = 0..k` ascending, skipping terms with
/// `A[i,kk] == 0.0` — the retired `affine` loop exactly.
pub fn matmul(a: &[f32], b: &[f32], out: &mut [f32], sh: MatShape, init: MatInit<'_>) {
    assert!(a.len() >= min_len(sh.m, sh.ra, sh.k), "matmul: A too short for {sh:?}");
    assert!(b.len() >= min_len(sh.k, sh.rb, sh.n), "matmul: B too short for {sh:?}");
    check_out(out, &sh, &init, "matmul");
    if sh.m == 0 || sh.n == 0 {
        return;
    }
    let lanes = pool::backend() == KernelBackend::Simd;
    pool::dispatch_rows(out, sh.rc, sh.m, sh.m * sh.k * sh.n, |r0, rows, block| {
        if lanes {
            simd::matmul_rows(a, b, block, sh, init, r0, rows);
        } else {
            matmul_rows(a, b, block, sh, init, r0, rows);
        }
    });
}

/// One row block of [`matmul`]: rows `[r0, r0 + rows)` of A/C, with `out`
/// starting at row `r0`'s first element.
fn matmul_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    let mut i0 = 0;
    while i0 < rows {
        let h = MR.min(rows - i0);
        let mut j0 = 0;
        while j0 < sh.n {
            let w = NR.min(sh.n - j0);
            let mut acc = [[0f32; NR]; MR];
            if let MatInit::Bias(bias) = init {
                for accr in acc.iter_mut().take(h) {
                    accr[..w].copy_from_slice(&bias[j0..j0 + w]);
                }
            }
            for kk in 0..sh.k {
                let bb = kk * sh.rb + j0;
                if w == NR {
                    // full-width hot path: fixed-size B panel row, so the
                    // 8 chains per A row unroll and vectorise
                    let brow: &[f32; NR] =
                        b[bb..bb + NR].try_into().expect("len checked");
                    for r in 0..h {
                        let av = a[(r0 + i0 + r) * sh.ra + kk];
                        if av != 0.0 {
                            let accr = &mut acc[r];
                            for l in 0..NR {
                                accr[l] += av * brow[l];
                            }
                        }
                    }
                } else {
                    let brow = &b[bb..bb + w];
                    for r in 0..h {
                        let av = a[(r0 + i0 + r) * sh.ra + kk];
                        if av != 0.0 {
                            for (accv, &bv) in acc[r][..w].iter_mut().zip(brow) {
                                *accv += av * bv;
                            }
                        }
                    }
                }
            }
            store_tile(out, sh.rc, &acc, init, (i0, j0, h, w));
            j0 += NR;
        }
        i0 += MR;
    }
}

/// Land a finished accumulator tile on the output per the [`MatInit`] mode;
/// `tile` is `(i0, j0, h, w)` — the tile's origin and extent.
fn store_tile(
    out: &mut [f32],
    rc: usize,
    acc: &[[f32; NR]; MR],
    init: MatInit<'_>,
    tile: (usize, usize, usize, usize),
) {
    let (i0, j0, h, w) = tile;
    for r in 0..h {
        let orow = &mut out[(i0 + r) * rc + j0..(i0 + r) * rc + j0 + w];
        if let MatInit::Accumulate = init {
            for (ov, &v) in orow.iter_mut().zip(&acc[r][..w]) {
                *ov += v;
            }
        } else {
            orow.copy_from_slice(&acc[r][..w]);
        }
    }
}

// ---------------------------------------------------------------------------
// matmul_bt: C = A · Bᵀ
// ---------------------------------------------------------------------------

/// `C (m×n) ←[init] A (m×k) · Bᵀ` with `B (n×k)` — both operands row-major
/// over `k`, the layout of every backward input-gradient (`dx = dy · Wᵀ`)
/// and of the attention score/`datt` dot products.
///
/// Chain per element `(i, j)`: start per [`MatInit`], then
/// `+= A[i,kk] · B[j,kk]` for `kk = 0..k` ascending, no zero-skip — the
/// retired `backprop_input` loop exactly.
pub fn matmul_bt(a: &[f32], b: &[f32], out: &mut [f32], sh: MatShape, init: MatInit<'_>) {
    assert!(a.len() >= min_len(sh.m, sh.ra, sh.k), "matmul_bt: A too short for {sh:?}");
    assert!(b.len() >= min_len(sh.n, sh.rb, sh.k), "matmul_bt: B too short for {sh:?}");
    check_out(out, &sh, &init, "matmul_bt");
    if sh.m == 0 || sh.n == 0 {
        return;
    }
    let lanes = pool::backend() == KernelBackend::Simd;
    pool::dispatch_rows(out, sh.rc, sh.m, sh.m * sh.k * sh.n, |r0, rows, block| {
        if lanes {
            simd::matmul_bt_rows(a, b, block, sh, init, r0, rows);
        } else {
            matmul_bt_rows(a, b, block, sh, init, r0, rows);
        }
    });
}

fn matmul_bt_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    let mut i0 = 0;
    while i0 < rows {
        let h = MR.min(rows - i0);
        let mut j0 = 0;
        while j0 < sh.n {
            let w = NR.min(sh.n - j0);
            let mut acc = [[0f32; NR]; MR];
            if let MatInit::Bias(bias) = init {
                for accr in acc.iter_mut().take(h) {
                    accr[..w].copy_from_slice(&bias[j0..j0 + w]);
                }
            }
            // B row starts for the j tile (each streams contiguously in kk)
            let mut bstart = [0usize; NR];
            for (l, bs) in bstart[..w].iter_mut().enumerate() {
                *bs = (j0 + l) * sh.rb;
            }
            for kk in 0..sh.k {
                for r in 0..h {
                    let av = a[(r0 + i0 + r) * sh.ra + kk];
                    for l in 0..w {
                        acc[r][l] += av * b[bstart[l] + kk];
                    }
                }
            }
            store_tile(out, sh.rc, &acc, init, (i0, j0, h, w));
            j0 += NR;
        }
        i0 += MR;
    }
}

// ---------------------------------------------------------------------------
// matmul_at: C = Aᵀ · B
// ---------------------------------------------------------------------------

/// `C (m×n) ←[init] Aᵀ · B` with `A (k×m)`, `B (k×n)` — the
/// sum-of-outer-products layout of every weight-style gradient
/// (`∂L/∂B = Σ_p A[p]ᵀ ∂L/∂z_p`, attention `dv`/`dk`, the head outer
/// product).
///
/// Chain per element `(i, j)`: start per [`MatInit`], then
/// `+= A[p,i] · B[p,j]` for `p = 0..k` ascending, skipping terms with
/// `A[p,i] == 0.0` — the retired LoRA `∂L/∂B` loop exactly.
pub fn matmul_at(a: &[f32], b: &[f32], out: &mut [f32], sh: MatShape, init: MatInit<'_>) {
    assert!(a.len() >= min_len(sh.k, sh.ra, sh.m), "matmul_at: A too short for {sh:?}");
    assert!(b.len() >= min_len(sh.k, sh.rb, sh.n), "matmul_at: B too short for {sh:?}");
    check_out(out, &sh, &init, "matmul_at");
    if sh.m == 0 || sh.n == 0 {
        return;
    }
    let lanes = pool::backend() == KernelBackend::Simd;
    pool::dispatch_rows(out, sh.rc, sh.m, sh.m * sh.k * sh.n, |r0, rows, block| {
        if lanes {
            simd::matmul_at_rows(a, b, block, sh, init, r0, rows);
        } else {
            matmul_at_rows(a, b, block, sh, init, r0, rows);
        }
    });
}

fn matmul_at_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    let mut i0 = 0;
    while i0 < rows {
        let h = MR.min(rows - i0);
        let mut j0 = 0;
        while j0 < sh.n {
            let w = NR.min(sh.n - j0);
            let mut acc = [[0f32; NR]; MR];
            if let MatInit::Bias(bias) = init {
                for accr in acc.iter_mut().take(h) {
                    accr[..w].copy_from_slice(&bias[j0..j0 + w]);
                }
            }
            for p in 0..sh.k {
                let brow = &b[p * sh.rb + j0..p * sh.rb + j0 + w];
                for r in 0..h {
                    let av = a[p * sh.ra + r0 + i0 + r];
                    if av != 0.0 {
                        for (accv, &bv) in acc[r][..w].iter_mut().zip(brow) {
                            *accv += av * bv;
                        }
                    }
                }
            }
            store_tile(out, sh.rc, &acc, init, (i0, j0, h, w));
            j0 += NR;
        }
        i0 += MR;
    }
}

// ---------------------------------------------------------------------------
// Fused bias + GELU affine
// ---------------------------------------------------------------------------

// GELU, tanh approximation (JAX's `jax.nn.gelu` default).
const GELU_C: f32 = 0.797_884_6; // √(2/π)
const GELU_A: f32 = 0.044_715;

/// GELU (tanh approximation — `jax.nn.gelu`'s default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    let u = GELU_C * (x + GELU_A * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

/// Derivative of [`gelu`].
#[inline]
pub fn gelu_prime(x: f32) -> f32 {
    let x2 = x * x;
    let u = GELU_C * (x + GELU_A * x * x2);
    let th = u.tanh();
    0.5 * (1.0 + th) + 0.5 * x * (1.0 - th * th) * GELU_C * (1.0 + 3.0 * GELU_A * x2)
}

/// The MLP's first affine with its GELU fused into the tile store:
/// `pre (m×n) = X (m×k) · W (k×n) + bias` and `post = gelu(pre)` in one
/// pass.  The backward needs the pre-activations, so both land.
///
/// Chain per element: starts at `bias[j]` and folds `k` ascending with the
/// `X == 0.0` skip — exactly [`matmul`] with [`MatInit::Bias`]; the GELU is
/// applied to each finished chain value at store time, so `pre`/`post` are
/// bit-identical to running the retired affine and a separate `gelu` pass.
pub fn add_bias_gelu(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    pre: &mut [f32],
    post: &mut [f32],
    sh: MatShape,
) {
    assert!(x.len() >= min_len(sh.m, sh.ra, sh.k), "add_bias_gelu: X too short for {sh:?}");
    assert!(w.len() >= min_len(sh.k, sh.rb, sh.n), "add_bias_gelu: W too short for {sh:?}");
    assert!(bias.len() >= sh.n, "add_bias_gelu: bias shorter than n ({sh:?})");
    assert!(
        pre.len() >= min_len(sh.m, sh.rc, sh.n) && post.len() >= min_len(sh.m, sh.rc, sh.n),
        "add_bias_gelu: output too short for {sh:?}"
    );
    if sh.m == 0 || sh.n == 0 {
        return;
    }
    let lanes = pool::backend() == KernelBackend::Simd;
    pool::dispatch_rows2(
        pre,
        post,
        sh.rc,
        sh.m,
        sh.m * sh.k * sh.n,
        |r0, rows, pb, gb| {
            if lanes {
                simd::add_bias_gelu_rows(x, w, bias, (pb, gb), sh, r0, rows);
            } else {
                add_bias_gelu_rows(x, w, bias, (pb, gb), sh, r0, rows);
            }
        },
    );
}

fn add_bias_gelu_rows(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    out: (&mut [f32], &mut [f32]),
    sh: MatShape,
    r0: usize,
    rows: usize,
) {
    let (pre, post) = out;
    let mut i0 = 0;
    while i0 < rows {
        let h = MR.min(rows - i0);
        let mut j0 = 0;
        while j0 < sh.n {
            let wd = NR.min(sh.n - j0);
            let mut acc = [[0f32; NR]; MR];
            for accr in acc.iter_mut().take(h) {
                accr[..wd].copy_from_slice(&bias[j0..j0 + wd]);
            }
            for kk in 0..sh.k {
                let wrow = &w[kk * sh.rb + j0..kk * sh.rb + j0 + wd];
                for r in 0..h {
                    let xv = x[(r0 + i0 + r) * sh.ra + kk];
                    if xv != 0.0 {
                        for (accv, &wv) in acc[r][..wd].iter_mut().zip(wrow) {
                            *accv += xv * wv;
                        }
                    }
                }
            }
            for r in 0..h {
                let base = (i0 + r) * sh.rc + j0;
                let prow = &mut pre[base..base + wd];
                prow.copy_from_slice(&acc[r][..wd]);
                for (gv, &av) in post[base..base + wd].iter_mut().zip(&acc[r][..wd]) {
                    *gv = gelu(av);
                }
            }
            j0 += NR;
        }
        i0 += MR;
    }
}

// ---------------------------------------------------------------------------
// Softmax row primitives
// ---------------------------------------------------------------------------

/// In-place scaled softmax over each of `rows` rows of `x` (logical width
/// `cols`, row pitch `pitch`): scale, subtract the row max, exponentiate,
/// normalise — the exact pass structure (and op order) of the retired
/// attention loop, which computed `score = dot · scale` while tracking the
/// max, then exponentiated accumulating the denominator, then multiplied by
/// its reciprocal.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize, pitch: usize, scale: f32) {
    assert!(x.len() >= min_len(rows, pitch, cols), "softmax_rows: buffer too short");
    if rows == 0 || cols == 0 {
        return;
    }
    let lanes = pool::backend() == KernelBackend::Simd;
    pool::dispatch_rows(x, pitch, rows, rows * cols * 16, |_, nrows, block| {
        if lanes {
            simd::softmax_rows_block(block, nrows, cols, pitch, scale);
            return;
        }
        for r in 0..nrows {
            let row = &mut block[r * pitch..r * pitch + cols];
            let mut mx = f32::NEG_INFINITY;
            for v in row.iter_mut() {
                *v *= scale;
                if *v > mx {
                    mx = *v;
                }
            }
            let mut denom = 0f32;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                denom += *v;
            }
            let inv = 1.0 / denom;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
    });
}

/// Softmax backward over rows, in place over `d`: with `att` the forward
/// probabilities (pitch `ra`) and `d` holding `∂L/∂att` (pitch `rd`),
/// rewrite each row as `d[j] ← att[j] · (d[j] − Σ_s att[s]·d[s]) · scale`
/// — the score gradient, with the dot accumulated in ascending `s` exactly
/// as the retired loop did.
pub fn softmax_rows_bwd(
    att: &[f32],
    d: &mut [f32],
    rows: usize,
    cols: usize,
    ra: usize,
    rd: usize,
    scale: f32,
) {
    assert!(att.len() >= min_len(rows, ra, cols), "softmax_rows_bwd: att too short");
    assert!(d.len() >= min_len(rows, rd, cols), "softmax_rows_bwd: d too short");
    if rows == 0 || cols == 0 {
        return;
    }
    let lanes = pool::backend() == KernelBackend::Simd;
    pool::dispatch_rows(d, rd, rows, rows * cols * 4, |r0, nrows, block| {
        if lanes {
            simd::softmax_rows_bwd_block(att, block, r0, nrows, cols, ra, rd, scale);
            return;
        }
        for r in 0..nrows {
            let arow = &att[(r0 + r) * ra..(r0 + r) * ra + cols];
            let drow = &mut block[r * rd..r * rd + cols];
            let mut dot = 0f32;
            for (&aw, &dw) in arow.iter().zip(drow.iter()) {
                dot += aw * dw;
            }
            for (dv, &aw) in drow.iter_mut().zip(arow) {
                *dv = aw * (*dv - dot) * scale;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_shapes_have_tight_pitches() {
        let want = MatShape { m: 2, k: 3, n: 5, ra: 3, rb: 5, rc: 5 };
        assert_eq!(MatShape::packed(2, 3, 5), want);
        assert_eq!(MatShape::packed_bt(2, 3, 5).rb, 3);
        assert_eq!(MatShape::packed_at(2, 3, 5).ra, 2);
    }

    #[test]
    fn matmul_identity_and_bias() {
        // (2×2) identity times B, plus a bias
        let a = [1.0, 0.0, 0.0, 1.0];
        let b = [1.0, 2.0, 3.0, 4.0];
        let bias = [10.0, 20.0];
        let mut out = [0f32; 4];
        matmul(&a, &b, &mut out, MatShape::packed(2, 2, 2), MatInit::Bias(&bias));
        assert_eq!(out, [11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn bt_and_at_transpose_correctly() {
        // A (2×3), B stored transposed / A stored transposed
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let bt = [1.0, 0.0, 0.0, 0.0, 1.0, 0.0]; // B (2×3) = rows of I
        let mut out = [0f32; 4];
        matmul_bt(&a, &bt, &mut out, MatShape::packed_bt(2, 3, 2), MatInit::Zero);
        assert_eq!(out, [1.0, 2.0, 4.0, 5.0]);

        let at = [1.0, 4.0, 2.0, 5.0, 3.0, 6.0]; // Aᵀ stored as (3×2)
        let b3 = [1.0, 0.0, 1.0]; // B (3×1)
        let mut out2 = [0f32; 2];
        matmul_at(&at, &b3, &mut out2, MatShape::packed_at(2, 3, 1), MatInit::Zero);
        assert_eq!(out2, [1.0 + 3.0, 4.0 + 6.0]);
    }

    #[test]
    fn degenerate_dims_are_noops_or_bias_copies() {
        let mut out = [7f32; 3];
        // k = 0, Bias: output is the bias
        matmul(&[], &[], &mut out, MatShape::packed(1, 0, 3), MatInit::Bias(&[1.0, 2.0, 3.0]));
        assert_eq!(out, [1.0, 2.0, 3.0]);
        // m = 0 / n = 0: untouched
        let mut keep = [5f32; 4];
        matmul(&[], &[1.0; 4], &mut keep, MatShape::packed(0, 1, 4), MatInit::Zero);
        matmul_bt(&[1.0], &[], &mut keep, MatShape::packed_bt(1, 1, 0), MatInit::Zero);
        assert_eq!(keep, [5.0; 4]);
    }

    #[test]
    fn softmax_rows_normalise() {
        let mut x = [0.0, 0.0, 1.0, 0.0, 0.0, 2.0];
        softmax_rows(&mut x, 2, 3, 3, 1.0);
        for row in x.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(x[5] > x[3] && x[2] > x[0]);
    }

    #[test]
    fn gelu_matches_known_values() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-5);
        // derivative by central difference
        let eps = 1e-3f32;
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 1.9] {
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((gelu_prime(x) - fd).abs() < 1e-3, "gelu'({x})");
        }
    }
}
