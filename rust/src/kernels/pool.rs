//! Opt-in thread fan-out over output row blocks, plus the process-wide
//! kernel knobs.
//!
//! Every blocked kernel computes each output element with one fixed
//! k-accumulation chain (see the parent module); parallelism therefore only
//! ever **partitions the output rows across threads** — no chain is ever
//! split, so the fan-out cannot reorder a single floating-point operation
//! and the threaded result is bit-identical to the serial one by
//! construction.
//!
//! The fan-out is rayon-free and `std`-only: [`dispatch_rows`] splits the
//! output into contiguous row blocks and runs each block on a scoped thread
//! (`std::thread::scope`), which keeps borrowed operands safe without any
//! `'static` gymnastics.  Scoped spawns cost tens of microseconds, so the
//! fan-out only engages when a call is worth it: `threads() > 1` **and** the
//! call's multiply-add count reaches [`par_min_work`].  At the built-in
//! model shapes a per-example kernel call never reaches the default floor —
//! the engine's gradient workers already parallelise across examples, and
//! nesting a second level of threads under them would oversubscribe — so
//! the knob is off (`threads = 1`) unless explicitly requested
//! (`--engine-kernel-threads`, [`set_threads`]).

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};

use super::KernelBackend;

/// Default [`par_min_work`] floor: a kernel call fans out only when
/// `m·k·n` (its multiply-add count) reaches ~1M, the point where the
/// scoped-spawn overhead is comfortably amortised.
pub const DEFAULT_PAR_MIN_WORK: usize = 1 << 20;

static THREADS: AtomicUsize = AtomicUsize::new(1);
static PAR_MIN_WORK: AtomicUsize = AtomicUsize::new(DEFAULT_PAR_MIN_WORK);
static FAN_OUTS: AtomicUsize = AtomicUsize::new(0);
// 0 = Scalar, 1 = Simd — mirrors `KernelBackend` (see `set_backend`).
static BACKEND: AtomicU8 = AtomicU8::new(0);

/// Kernel calls that actually fanned out across threads since process
/// start.  Diagnostics: the knobs are process-wide and every trainer
/// resets them at run start, so a test claiming threaded coverage asserts
/// this advanced during its run instead of trusting the globals stayed put.
pub fn fan_out_count() -> usize {
    FAN_OUTS.load(Ordering::Relaxed)
}

/// Set the kernel thread count (1 = serial, the default).  Process-wide:
/// the engine applies `EngineConfig::kernel_threads` here at run start, and
/// the sync trainer does the same from its config.  Changing it never
/// changes any kernel's output bits — only how many threads compute them.
pub fn set_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Current kernel thread count (see [`set_threads`]).
pub fn threads() -> usize {
    THREADS.load(Ordering::Relaxed).max(1)
}

/// Set the fan-out floor: calls with fewer than `work` multiply-adds stay
/// serial even when [`threads`] > 1.  Tests set 0 to force the threaded
/// tiling at tiny shapes; [`DEFAULT_PAR_MIN_WORK`] restores the default.
pub fn set_par_min_work(work: usize) {
    PAR_MIN_WORK.store(work, Ordering::Relaxed);
}

/// Current fan-out floor (see [`set_par_min_work`]).
pub fn par_min_work() -> usize {
    PAR_MIN_WORK.load(Ordering::Relaxed)
}

/// Select the kernel backend (see [`KernelBackend`]; `Scalar` is the
/// default).  Process-wide, like [`set_threads`] — but unlike the thread
/// knob it **does** change output bits: the SIMD backend reassociates the
/// k-chains (`kernels::simd`), so anything relying on bit-exactness must
/// run on `Scalar`.  Prefer [`ScopedConfig`] over calling this directly so
/// the selection cannot leak past a run.
pub fn set_backend(backend: KernelBackend) {
    BACKEND.store(backend as u8, Ordering::Relaxed);
}

/// Currently selected kernel backend (see [`set_backend`]).
pub fn backend() -> KernelBackend {
    match BACKEND.load(Ordering::Relaxed) {
        1 => KernelBackend::Simd,
        _ => KernelBackend::Scalar,
    }
}

/// RAII scope for the process-wide kernel knobs: captures the prior
/// `threads` / `par_min_work` / `backend` on construction, applies the
/// requested values, and restores all three on drop.
///
/// Both trainers hold one of these for the duration of a run so that
/// back-to-back runs in one process (`compare_throughput`, benches,
/// multi-run test binaries) cannot silently inherit the previous run's
/// thread count or backend — the bug this replaced was a bare
/// [`set_threads`] at run start with no restore.
#[derive(Debug)]
pub struct ScopedConfig {
    prev_threads: usize,
    prev_min_work: usize,
    prev_backend: KernelBackend,
}

impl ScopedConfig {
    /// Capture the current knobs, then apply `threads` and `backend` for
    /// the lifetime of the returned guard.  (`par_min_work` is captured and
    /// restored but not changed — only tests touch that knob.)
    pub fn apply(threads: usize, backend: KernelBackend) -> ScopedConfig {
        let guard = ScopedConfig {
            prev_threads: self::threads(),
            prev_min_work: self::par_min_work(),
            prev_backend: self::backend(),
        };
        set_threads(threads);
        set_backend(backend);
        guard
    }
}

impl Drop for ScopedConfig {
    fn drop(&mut self) {
        set_threads(self.prev_threads);
        set_par_min_work(self.prev_min_work);
        set_backend(self.prev_backend);
    }
}

/// How many threads a call over `rows` output rows and `work` multiply-adds
/// should fan out to (1 = stay serial).
fn planned_threads(rows: usize, work: usize) -> usize {
    let t = threads();
    if t <= 1 || rows < 2 || work < par_min_work() {
        return 1;
    }
    t.min(rows)
}

/// `(first_row, row_count)` per block: `rows` split into `t` contiguous
/// blocks, remainder spread over the leading blocks.
fn row_blocks(rows: usize, t: usize) -> Vec<(usize, usize)> {
    let base = rows / t;
    let extra = rows % t;
    let mut out = Vec::with_capacity(t);
    let mut r0 = 0;
    for b in 0..t {
        let n = base + usize::from(b < extra);
        out.push((r0, n));
        r0 += n;
    }
    out
}

/// Split `buf` (row pitch `pitch`) into one `&mut` slab per block; the last
/// slab takes the remainder so a final partial row (pitch > logical width)
/// stays in bounds.
fn split_rows_mut<'a>(
    mut buf: &'a mut [f32],
    pitch: usize,
    blocks: &[(usize, usize)],
) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(blocks.len());
    for &(_, n) in &blocks[..blocks.len() - 1] {
        let tmp = buf;
        let (head, tail) = tmp.split_at_mut(n * pitch);
        out.push(head);
        buf = tail;
    }
    out.push(buf);
    out
}

/// Run `run(first_row, row_count, block)` over `out` (row pitch `pitch`,
/// `rows` logical rows), fanning the row blocks out across threads when the
/// call is large enough (see module docs).  `block` starts at `first_row`'s
/// first element.
pub(crate) fn dispatch_rows<F>(out: &mut [f32], pitch: usize, rows: usize, work: usize, run: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let t = planned_threads(rows, work);
    if t <= 1 {
        run(0, rows, out);
        return;
    }
    FAN_OUTS.fetch_add(1, Ordering::Relaxed);
    let blocks = row_blocks(rows, t);
    let parts = split_rows_mut(out, pitch, &blocks);
    std::thread::scope(|s| {
        let run = &run;
        let mut pairs: Vec<_> = blocks.iter().copied().zip(parts).collect();
        let ((r0, n), part) = pairs.pop().expect("blocks are non-empty");
        for ((rb, nb), pb) in pairs {
            s.spawn(move || run(rb, nb, pb));
        }
        run(r0, n, part);
    });
}

/// Two-output variant of [`dispatch_rows`] for kernels that write a pair of
/// same-shaped buffers (the fused bias+GELU kernel's pre- and
/// post-activation outputs); both are split at the same row boundaries.
pub(crate) fn dispatch_rows2<F>(
    o1: &mut [f32],
    o2: &mut [f32],
    pitch: usize,
    rows: usize,
    work: usize,
    run: F,
) where
    F: Fn(usize, usize, &mut [f32], &mut [f32]) + Sync,
{
    let t = planned_threads(rows, work);
    if t <= 1 {
        run(0, rows, o1, o2);
        return;
    }
    FAN_OUTS.fetch_add(1, Ordering::Relaxed);
    let blocks = row_blocks(rows, t);
    let p1 = split_rows_mut(o1, pitch, &blocks);
    let p2 = split_rows_mut(o2, pitch, &blocks);
    std::thread::scope(|s| {
        let run = &run;
        let mut triples: Vec<_> = blocks
            .iter()
            .copied()
            .zip(p1.into_iter().zip(p2))
            .collect();
        let ((r0, n), (a, b)) = triples.pop().expect("blocks are non-empty");
        for ((rb, nb), (ab, bb)) in triples {
            s.spawn(move || run(rb, nb, ab, bb));
        }
        run(r0, n, a, b);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The knobs are process-global; tests that touch them must not
    /// interleave with each other under the parallel test runner.
    fn knob_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn row_blocks_cover_exactly() {
        for rows in 1..40 {
            for t in 1..=rows.min(9) {
                let blocks = row_blocks(rows, t);
                assert_eq!(blocks.len(), t);
                let mut next = 0;
                for (r0, n) in blocks {
                    assert_eq!(r0, next, "contiguous");
                    assert!(n >= 1, "no empty block at t <= rows");
                    next = r0 + n;
                }
                assert_eq!(next, rows);
            }
        }
    }

    #[test]
    fn split_rows_mut_partitions_buffer() {
        let mut buf = vec![0f32; 3 * 5 + 2]; // 4 rows at pitch 5, last partial
        let blocks = row_blocks(4, 2);
        let parts = split_rows_mut(&mut buf, 5, &blocks);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].len(), 2 * 5);
        assert_eq!(parts[1].len(), 5 + 2); // remainder, incl. the partial row
    }

    #[test]
    fn scoped_config_restores_prior_knobs() {
        // nested scopes restore exactly what they captured, including a
        // par_min_work a test fiddled with inside the scope
        let _serial = knob_lock();
        assert_eq!(threads(), 1);
        assert_eq!(backend(), KernelBackend::Scalar);
        {
            let _outer = ScopedConfig::apply(3, KernelBackend::Simd);
            assert_eq!(threads(), 3);
            assert_eq!(backend(), KernelBackend::Simd);
            set_par_min_work(0);
            {
                let _inner = ScopedConfig::apply(2, KernelBackend::Scalar);
                assert_eq!(threads(), 2);
                assert_eq!(backend(), KernelBackend::Scalar);
            }
            assert_eq!(threads(), 3);
            assert_eq!(backend(), KernelBackend::Simd);
            assert_eq!(par_min_work(), 0, "inner scope restored the fiddled floor");
        }
        assert_eq!(threads(), 1);
        assert_eq!(backend(), KernelBackend::Scalar);
        assert_eq!(par_min_work(), DEFAULT_PAR_MIN_WORK);
    }

    #[test]
    fn dispatch_runs_every_row_once() {
        // threaded dispatch touches each logical row exactly once
        let _serial = knob_lock();
        struct Restore;
        impl Drop for Restore {
            fn drop(&mut self) {
                set_threads(1);
                set_par_min_work(DEFAULT_PAR_MIN_WORK);
            }
        }
        let _restore = Restore;
        set_threads(3);
        set_par_min_work(0);
        let rows = 10;
        let pitch = 4;
        let mut buf = vec![0f32; rows * pitch];
        dispatch_rows(&mut buf, pitch, rows, usize::MAX, |r0, n, block| {
            for r in 0..n {
                for c in 0..pitch {
                    block[r * pitch + c] += (r0 + r) as f32;
                }
            }
        });
        for (r, row) in buf.chunks(pitch).enumerate() {
            assert!(row.iter().all(|&v| v == r as f32), "row {r}");
        }
    }
}
