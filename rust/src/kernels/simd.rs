//! Lane-parallel kernel bodies for [`KernelBackend::Simd`].
//!
//! ## Chain reassociation
//!
//! Where the scalar kernels fold each output element's `k` multiply-add
//! terms in one ascending chain, the SIMD bodies split the accumulation
//! across [`LANES`] (= 8) independent f32 lanes and reduce at the end:
//!
//! * **j-vectorised** (`matmul_rows`, `matmul_at_rows`,
//!   `add_bias_gelu_rows`): the 8 lanes are 8 *output columns*, each lane
//!   still folding its own chain in ascending k — the per-element chain
//!   order is unchanged; the only difference from the scalar path is that
//!   the `A == 0.0` skip is dropped so the inner loop is branchless.  For
//!   finite operands a skipped `±0.0` term is bit-invisible (the parent
//!   module's signed-zero argument), so these three match the scalar
//!   kernels bit-for-bit outside signed-zero/non-finite corners.
//! * **k-vectorised** (`matmul_bt_rows`, the softmax denominator and the
//!   softmax-backward dot): lane `l` accumulates terms `8c + l`, the 8
//!   lane partials are reduced by the fixed pairwise tree
//!   `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` ([`hsum8`]), remaining tail
//!   terms (`k mod 8`) are folded serially in ascending order, and the
//!   chain start (0 / bias / the accumulate target) is added once at the
//!   end: `value = start + (hsum8(lanes) + tail)`.  This *reassociates*
//!   the sum, so the result is ULP-close to the scalar chain, not
//!   bit-equal — `tests/kernels.rs` pins the documented tolerance model
//!   and `docs/RUNTIME.md` derives it.
//!
//! Sums of values that are exactly representable small integers (the 0/1
//! exhaustive grid in the test suite) are exact under *any* association,
//! so there the SIMD kernels are bitwise identical to the scalar ones.
//!
//! ## Runtime feature detection, and why both paths give the same bits
//!
//! On x86_64 each body has a clone compiled with
//! `#[target_feature(enable = "avx2")]`, selected once per process via
//! `is_x86_feature_detected!` ([`simd_acceleration`]); everywhere else
//! (and on x86_64 without AVX2) the portable array-of-lanes body runs as
//! plain Rust.  The clones contain **no intrinsics and no FMA** — they are
//! the same source lanes, just compiled so LLVM may use 256-bit registers
//! — so both paths execute the identical sequence of IEEE-754 single ops
//! and produce bit-identical results.  The backend choice changes bits
//! (vs `Scalar`); the machine running it never does.

use super::{MatInit, MatShape, MR, NR};

/// f32 lanes per accumulation group (AVX2's 256-bit register width).
pub(crate) const LANES: usize = 8;

/// Which lane implementation the SIMD backend runs on this machine:
/// `"avx2"` when runtime detection found AVX2, `"portable"` otherwise.
/// A label for benches/telemetry only — both produce identical bits (see
/// the module docs).
pub fn simd_acceleration() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            return "avx2";
        }
    }
    "portable"
}

/// Cached `is_x86_feature_detected!("avx2")`: 0 = unknown, 1 = no, 2 = yes.
#[cfg(target_arch = "x86_64")]
fn avx2() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static STATE: AtomicU8 = AtomicU8::new(0);
    match STATE.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx2");
            STATE.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
            yes
        }
    }
}

/// The fixed pairwise horizontal reduce of the 8 lane partials.
#[inline(always)]
fn hsum8(v: &[f32; LANES]) -> f32 {
    ((v[0] + v[1]) + (v[2] + v[3])) + ((v[4] + v[5]) + (v[6] + v[7]))
}

/// Lane dot product: `hsum8(lane partials) + serial tail` (module docs).
#[inline(always)]
fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut lanes = [0f32; LANES];
    let mut i = 0;
    while i + LANES <= n {
        let av: &[f32; LANES] = a[i..i + LANES].try_into().expect("len checked");
        let bv: &[f32; LANES] = b[i..i + LANES].try_into().expect("len checked");
        for l in 0..LANES {
            lanes[l] += av[l] * bv[l];
        }
        i += LANES;
    }
    let mut tail = 0f32;
    while i < n {
        tail += a[i] * b[i];
        i += 1;
    }
    hsum8(&lanes) + tail
}

/// Lane sum, same association as [`dot_lanes`].
#[inline(always)]
fn sum_lanes(x: &[f32]) -> f32 {
    let mut lanes = [0f32; LANES];
    let mut i = 0;
    while i + LANES <= x.len() {
        let xv: &[f32; LANES] = x[i..i + LANES].try_into().expect("len checked");
        for l in 0..LANES {
            lanes[l] += xv[l];
        }
        i += LANES;
    }
    let mut tail = 0f32;
    while i < x.len() {
        tail += x[i];
        i += 1;
    }
    hsum8(&lanes) + tail
}

// ---------------------------------------------------------------------------
// matmul (j-vectorised: the scalar tile loop, branchless)
// ---------------------------------------------------------------------------

#[inline(always)]
fn matmul_rows_body(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    let mut i0 = 0;
    while i0 < rows {
        let h = MR.min(rows - i0);
        let mut j0 = 0;
        while j0 < sh.n {
            let w = NR.min(sh.n - j0);
            let mut acc = [[0f32; NR]; MR];
            if let MatInit::Bias(bias) = init {
                for accr in acc.iter_mut().take(h) {
                    accr[..w].copy_from_slice(&bias[j0..j0 + w]);
                }
            }
            for kk in 0..sh.k {
                let bb = kk * sh.rb + j0;
                if w == NR {
                    let brow: &[f32; NR] = b[bb..bb + NR].try_into().expect("len checked");
                    for r in 0..h {
                        let av = a[(r0 + i0 + r) * sh.ra + kk];
                        let accr = &mut acc[r];
                        for l in 0..NR {
                            accr[l] += av * brow[l];
                        }
                    }
                } else {
                    let brow = &b[bb..bb + w];
                    for r in 0..h {
                        let av = a[(r0 + i0 + r) * sh.ra + kk];
                        for (accv, &bv) in acc[r][..w].iter_mut().zip(brow) {
                            *accv += av * bv;
                        }
                    }
                }
            }
            super::store_tile(out, sh.rc, &acc, init, (i0, j0, h, w));
            j0 += NR;
        }
        i0 += MR;
    }
}

pub(crate) fn matmul_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: runtime detection confirmed this CPU supports AVX2.
            unsafe { matmul_rows_avx2(a, b, out, sh, init, r0, rows) };
            return;
        }
    }
    matmul_rows_body(a, b, out, sh, init, r0, rows);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn matmul_rows_avx2(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    matmul_rows_body(a, b, out, sh, init, r0, rows);
}

// ---------------------------------------------------------------------------
// matmul_bt (k-vectorised: lane partial sums + horizontal reduce)
// ---------------------------------------------------------------------------

#[inline(always)]
fn matmul_bt_rows_body(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    // k == 0 leaves the operands possibly empty (the length contracts only
    // cover k elements per row) — land the chain starts without slicing
    let empty: &[f32] = &[];
    for r in 0..rows {
        let arow = if sh.k == 0 { empty } else { &a[(r0 + r) * sh.ra..(r0 + r) * sh.ra + sh.k] };
        for j in 0..sh.n {
            let brow = if sh.k == 0 { empty } else { &b[j * sh.rb..j * sh.rb + sh.k] };
            let dot = dot_lanes(arow, brow);
            let o = &mut out[r * sh.rc + j];
            match init {
                MatInit::Zero => *o = dot,
                MatInit::Accumulate => *o += dot,
                MatInit::Bias(bias) => *o = bias[j] + dot,
            }
        }
    }
}

pub(crate) fn matmul_bt_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: runtime detection confirmed this CPU supports AVX2.
            unsafe { matmul_bt_rows_avx2(a, b, out, sh, init, r0, rows) };
            return;
        }
    }
    matmul_bt_rows_body(a, b, out, sh, init, r0, rows);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn matmul_bt_rows_avx2(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    matmul_bt_rows_body(a, b, out, sh, init, r0, rows);
}

// ---------------------------------------------------------------------------
// matmul_at (j-vectorised: the scalar tile loop, branchless)
// ---------------------------------------------------------------------------

#[inline(always)]
fn matmul_at_rows_body(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    let mut i0 = 0;
    while i0 < rows {
        let h = MR.min(rows - i0);
        let mut j0 = 0;
        while j0 < sh.n {
            let w = NR.min(sh.n - j0);
            let mut acc = [[0f32; NR]; MR];
            if let MatInit::Bias(bias) = init {
                for accr in acc.iter_mut().take(h) {
                    accr[..w].copy_from_slice(&bias[j0..j0 + w]);
                }
            }
            for p in 0..sh.k {
                let brow = &b[p * sh.rb + j0..p * sh.rb + j0 + w];
                for r in 0..h {
                    let av = a[p * sh.ra + r0 + i0 + r];
                    for (accv, &bv) in acc[r][..w].iter_mut().zip(brow) {
                        *accv += av * bv;
                    }
                }
            }
            super::store_tile(out, sh.rc, &acc, init, (i0, j0, h, w));
            j0 += NR;
        }
        i0 += MR;
    }
}

pub(crate) fn matmul_at_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: runtime detection confirmed this CPU supports AVX2.
            unsafe { matmul_at_rows_avx2(a, b, out, sh, init, r0, rows) };
            return;
        }
    }
    matmul_at_rows_body(a, b, out, sh, init, r0, rows);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn matmul_at_rows_avx2(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    sh: MatShape,
    init: MatInit<'_>,
    r0: usize,
    rows: usize,
) {
    matmul_at_rows_body(a, b, out, sh, init, r0, rows);
}

// ---------------------------------------------------------------------------
// Fused bias + GELU affine (j-vectorised, branchless)
// ---------------------------------------------------------------------------

#[inline(always)]
fn add_bias_gelu_rows_body(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    out: (&mut [f32], &mut [f32]),
    sh: MatShape,
    r0: usize,
    rows: usize,
) {
    let (pre, post) = out;
    let mut i0 = 0;
    while i0 < rows {
        let h = MR.min(rows - i0);
        let mut j0 = 0;
        while j0 < sh.n {
            let wd = NR.min(sh.n - j0);
            let mut acc = [[0f32; NR]; MR];
            for accr in acc.iter_mut().take(h) {
                accr[..wd].copy_from_slice(&bias[j0..j0 + wd]);
            }
            for kk in 0..sh.k {
                let wrow = &w[kk * sh.rb + j0..kk * sh.rb + j0 + wd];
                for r in 0..h {
                    let xv = x[(r0 + i0 + r) * sh.ra + kk];
                    for (accv, &wv) in acc[r][..wd].iter_mut().zip(wrow) {
                        *accv += xv * wv;
                    }
                }
            }
            for r in 0..h {
                let base = (i0 + r) * sh.rc + j0;
                pre[base..base + wd].copy_from_slice(&acc[r][..wd]);
                for (gv, &av) in post[base..base + wd].iter_mut().zip(&acc[r][..wd]) {
                    *gv = super::gelu(av);
                }
            }
            j0 += NR;
        }
        i0 += MR;
    }
}

pub(crate) fn add_bias_gelu_rows(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    out: (&mut [f32], &mut [f32]),
    sh: MatShape,
    r0: usize,
    rows: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: runtime detection confirmed this CPU supports AVX2.
            unsafe { add_bias_gelu_rows_avx2(x, w, bias, out, sh, r0, rows) };
            return;
        }
    }
    add_bias_gelu_rows_body(x, w, bias, out, sh, r0, rows);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn add_bias_gelu_rows_avx2(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    out: (&mut [f32], &mut [f32]),
    sh: MatShape,
    r0: usize,
    rows: usize,
) {
    add_bias_gelu_rows_body(x, w, bias, out, sh, r0, rows);
}

// ---------------------------------------------------------------------------
// Softmax row primitives (k-vectorised denominator / dot)
// ---------------------------------------------------------------------------

#[inline(always)]
fn softmax_rows_block_body(block: &mut [f32], nrows: usize, cols: usize, pitch: usize, scale: f32) {
    for r in 0..nrows {
        let row = &mut block[r * pitch..r * pitch + cols];
        // scale + max and the exponentials are elementwise — identical ops
        // to the scalar pass; only the denominator sum is reassociated
        let mut mx = f32::NEG_INFINITY;
        for v in row.iter_mut() {
            *v *= scale;
            if *v > mx {
                mx = *v;
            }
        }
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
        }
        let inv = 1.0 / sum_lanes(row);
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

pub(crate) fn softmax_rows_block(
    block: &mut [f32],
    nrows: usize,
    cols: usize,
    pitch: usize,
    scale: f32,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: runtime detection confirmed this CPU supports AVX2.
            unsafe { softmax_rows_block_avx2(block, nrows, cols, pitch, scale) };
            return;
        }
    }
    softmax_rows_block_body(block, nrows, cols, pitch, scale);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn softmax_rows_block_avx2(
    block: &mut [f32],
    nrows: usize,
    cols: usize,
    pitch: usize,
    scale: f32,
) {
    softmax_rows_block_body(block, nrows, cols, pitch, scale);
}

#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn softmax_rows_bwd_block_body(
    att: &[f32],
    block: &mut [f32],
    r0: usize,
    nrows: usize,
    cols: usize,
    ra: usize,
    rd: usize,
    scale: f32,
) {
    for r in 0..nrows {
        let arow = &att[(r0 + r) * ra..(r0 + r) * ra + cols];
        let drow = &mut block[r * rd..r * rd + cols];
        let dot = dot_lanes(arow, drow);
        for (dv, &aw) in drow.iter_mut().zip(arow) {
            *dv = aw * (*dv - dot) * scale;
        }
    }
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn softmax_rows_bwd_block(
    att: &[f32],
    block: &mut [f32],
    r0: usize,
    nrows: usize,
    cols: usize,
    ra: usize,
    rd: usize,
    scale: f32,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: runtime detection confirmed this CPU supports AVX2.
            unsafe { softmax_rows_bwd_block_avx2(att, block, r0, nrows, cols, ra, rd, scale) };
            return;
        }
    }
    softmax_rows_bwd_block_body(att, block, r0, nrows, cols, ra, rd, scale);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn softmax_rows_bwd_block_avx2(
    att: &[f32],
    block: &mut [f32],
    r0: usize,
    nrows: usize,
    cols: usize,
    ra: usize,
    rd: usize,
    scale: f32,
) {
    softmax_rows_bwd_block_body(att, block, r0, nrows, cols, ra, rd, scale);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hsum8_uses_the_documented_tree() {
        // magnitudes chosen so association matters: the pairwise tree and
        // the serial left fold disagree, and we pin the tree
        let v = [1e8f32, 1.0, -1e8, 1.0, 1e8, 1.0, -1e8, 1.0];
        let tree = ((v[0] + v[1]) + (v[2] + v[3])) + ((v[4] + v[5]) + (v[6] + v[7]));
        assert_eq!(hsum8(&v).to_bits(), tree.to_bits());
        let serial: f32 = v.iter().sum();
        assert_ne!(tree.to_bits(), serial.to_bits(), "case must discriminate");
    }

    #[test]
    fn dot_and_sum_lanes_match_f64_closely() {
        let eps = f64::from(f32::EPSILON);
        let bound = |terms: usize, mag: f64| 2.0 * (terms as f64 + 1.0) * eps * mag + 1e-12;
        let mut rng = crate::util::rng::Xoshiro256::seed_from(42);
        for n in [0usize, 1, 7, 8, 9, 16, 23, 64, 100] {
            let a: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
            let prods: Vec<f64> =
                a.iter().zip(&b).map(|(&x, &y)| f64::from(x) * f64::from(y)).collect();
            let want: f64 = prods.iter().sum();
            let mag: f64 = prods.iter().map(|p| p.abs()).sum();
            let got = f64::from(dot_lanes(&a, &b));
            assert!((got - want).abs() <= bound(n, mag), "dot n={n}: got {got}, want {want}");
            let wsum: f64 = a.iter().map(|&x| f64::from(x)).sum();
            let gsum = f64::from(sum_lanes(&a));
            let msum: f64 = a.iter().map(|&x| f64::from(x).abs()).sum();
            assert!((gsum - wsum).abs() <= bound(n, msum), "sum n={n}: got {gsum}, want {wsum}");
        }
    }

    #[test]
    fn acceleration_label_is_stable() {
        let l = simd_acceleration();
        assert!(l == "avx2" || l == "portable");
        assert_eq!(l, simd_acceleration(), "cached detection must not flip");
    }
}
