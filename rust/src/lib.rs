//! # sparse_dp_emb
//!
//! Reproduction of **"Sparsity-Preserving Differentially Private Training of
//! Large Embedding Models"** (Ghazi et al., NeurIPS 2023) as a three-layer
//! Rust + JAX + Pallas training framework:
//!
//! * **L1** — Pallas kernels (embedding gather, per-example clipping,
//!   contribution-map scatter) authored in `python/compile/kernels/`,
//!   validated against pure-jnp oracles, lowered AOT.
//! * **L2** — JAX step computations (pCTR tower, transformer + LoRA) lowered
//!   once to HLO text by `python/compile/aot.py`.
//! * **L3** — this crate: the training coordinator.  It owns the parameter
//!   store, mini-batch scheduling, all DP randomness (contribution-map noise
//!   σ₁, gradient noise σ₂), sparse row updates, privacy accounting, and the
//!   experiment harness reproducing every table and figure of the paper.
//!
//! Two execution backends drive the models ([`runtime`]): the PJRT client
//! over AOT artifacts (`--features xla`), and a pure-Rust **reference
//! executor** for both model families — the pCTR tower and a native
//! transformer for the NLU workload, with the embedding trainable as the
//! full table or as a LoRA adapter pair (the default — no Python build
//! step, no external crates) — whose fixed-chunk reductions also power the
//! async engine.  The native executors' matmuls run on the blocked,
//! register-tiled kernel subsystem ([`kernels`]), bit-identical to the
//! scalar loops it retired.  `docs/RUNTIME.md` is the layer's architecture
//! reference.
//!
//! Two training paths share one step core ([`coordinator::step`]):
//!
//! * [`coordinator::Trainer`] — the synchronous loop;
//! * [`engine`] — the asynchronous sharded engine: pipelined data workers →
//!   per-example gradient workers → a DP aggregation barrier that draws all
//!   noise once per logical batch.  Bit-for-bit equivalent to the sync path
//!   at any worker count at the default `--engine-staleness 0`, with an
//!   opt-in bounded-staleness window for more pipelining at the same
//!   privacy accounting (`sparse-dp-emb train-async`); `docs/ENGINE.md` is
//!   the architecture reference and `docs/CONCURRENCY.md` the exactness
//!   and staleness story.
//!
//! Both paths are instrumented by a passive [`telemetry`] subsystem —
//! per-stage span timers, channel queue-depth gauges, and per-step
//! sparsity/privacy metrics streamed as JSONL via `--metrics-out`
//! (`docs/OBSERVABILITY.md`) — without perturbing bit-exactness.
//!
//! Both paths also run the paper's §4.3 streaming (time-series) protocol
//! through one shared calendar ([`coordinator::streaming::StreamSchedule`]):
//! the sync [`coordinator::StreamingTrainer`] (`stream`) and the engine's
//! streaming barrier ([`engine::run_streaming`], `train-async --stream`)
//! produce bit-identical [`coordinator::StreamingOutcome`]s.
//!
//! Python never runs on the training path: `make artifacts` is an optional
//! one-time build step and the resulting binary is self-contained.
//!
//! Entry points: [`coordinator::Trainer`] / [`engine::run`] for training
//! (either workload), [`harness`] for paper-experiment reproduction,
//! `sparse-dp-emb` (see `main.rs`) for the CLI.

pub mod accounting;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod filtering;
pub mod harness;
pub mod kernels;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod selection;
pub mod sparse;
pub mod store;
pub mod telemetry;
pub mod util;

pub mod proptest;
