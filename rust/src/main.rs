//! `sparse-dp-emb` — launcher CLI for the DP-FEST / DP-AdaFEST training
//! framework.
//!
//! ```text
//! sparse-dp-emb train       [--model criteo-small] [--algorithm dp-adafest] [--epsilon 1.0] ...
//! sparse-dp-emb train-async [--engine-workers 4] [--engine-shards 16] [--engine-staleness 0]
//!                           [--store-budget-mb 0] [--store-dir <dir>] ...   # pipelined engine
//! sparse-dp-emb train-async --stream [--freq-source streaming] [--streaming-period 1] ...
//! sparse-dp-emb stream      [--streaming-period 1] [--freq-source streaming] ...
//! sparse-dp-emb sweep       <fig1b|fig3|fig4|fig5[-async]|fig6[-async]|fig7|fig8|fig9|tab1|tab2|tab4|tab5[-async]|tab6|lemma31|fullscale> [--fast]
//! sparse-dp-emb account     [--epsilon 1.0] [--steps 200] ...   # privacy accounting only
//! sparse-dp-emb info                                            # manifest / artifact inventory
//! ```
//!
//! `train-async` runs the asynchronous sharded engine and produces the
//! exact same outcome as `train` for the same seed/config — only faster.
//! Both commands execute on the blocked-kernel native executors
//! (`rust/src/kernels/`); `--engine-kernel-threads N` additionally fans
//! large kernel calls' output tiles across `N` threads (bit-exact at any
//! setting, like every engine knob except `--engine-staleness`, which at
//! `k > 0` opts into bounded-staleness pipelining — same privacy
//! accounting, no longer bit-identical; see `docs/CONCURRENCY.md`).
//! `--engine-kernel-backend simd` opts both trainers into the
//! lane-parallel SIMD kernels (AVX2 when detected at runtime, portable
//! lanes otherwise) — ULP-close to the default `scalar` backend rather
//! than bit-identical; see `docs/RUNTIME.md`.
//! `--store-budget-mb N` swaps the in-RAM embedding-table shards for
//! file-backed paged tables under an `N` MiB page-cache budget
//! (`--store-dir` picks where the page files live) — bit-exact at any
//! budget; see `docs/ENGINE.md`.
//! Both commands drive either model family: the built-in reference manifest
//! covers `criteo-small`/`criteo-tiny` (pCTR) and `nlu-small`/`nlu-tiny`
//! (native transformer) plus their LoRA-on-embedding variants
//! `nlu-small-lora{4,16,64}`/`nlu-tiny-lora{4,16}` (Table 1's rank axis),
//! so no artifacts are needed for any of them.
//! `train-async --stream` runs the §4.3 streaming (time-series) protocol on
//! the engine, bit-identical to the sync `stream` command for the same
//! seed/config (`--freq-source first-day|all-days|streaming`,
//! `--streaming-period <days>`).
//!
//! Any `RunConfig` field can be overridden with `--key value`; `--config
//! path` loads a `key = value` file first.

use anyhow::{bail, Context, Result};

use sparse_dp_emb::accounting::{calibrate_sigma_pair, Accountant};
use sparse_dp_emb::config::RunConfig;
use sparse_dp_emb::coordinator::{StreamingTrainer, Trainer};
use sparse_dp_emb::data::{CriteoConfig, SynthCriteo, SynthText, TextConfig};
use sparse_dp_emb::harness;
use sparse_dp_emb::runtime::Runtime;

fn main() -> Result<()> {
    // Multi-process engine children re-exec this binary: when the actor
    // environment marker is set this runs the actor loop and exits, so it
    // must come before any CLI parsing.
    sparse_dp_emb::engine::actor::maybe_actor_main();

    let mut args: Vec<String> = std::env::args().skip(1).collect();

    // --config file is applied before other flags
    let mut cfg = RunConfig::default();
    if let Some(pos) = args.iter().position(|a| a == "--config") {
        let path = args
            .get(pos + 1)
            .context("--config needs a path")?
            .clone();
        args.drain(pos..=pos + 1);
        cfg.load_file(std::path::Path::new(&path))?;
    }
    let fast = if let Some(pos) = args.iter().position(|a| a == "--fast") {
        args.remove(pos);
        true
    } else {
        false
    };
    let stream = if let Some(pos) = args.iter().position(|a| a == "--stream") {
        args.remove(pos);
        true
    } else {
        false
    };
    let positional = cfg.apply_args(&args)?;
    let Some(command) = positional.first() else {
        print_usage();
        bail!("no command given");
    };
    if stream && command != "train-async" {
        // not silently ignorable: `train --stream` is a likely typo for the
        // `stream` subcommand and would otherwise train non-streaming
        bail!("--stream only applies to train-async (did you mean the `stream` command?)");
    }
    // same policy for the paged-store flags: only train-async and the
    // fullscale harness read them, so anywhere else they must error rather
    // than silently keep every table in RAM
    let experiment = if command == "sweep" { positional.get(1).map(String::as_str) } else { None };
    cfg.reject_unused_store_flags(command, experiment)?;

    match command.as_str() {
        "train" => cmd_train(&cfg),
        "train-async" => cmd_train_async(&cfg, stream),
        "stream" => cmd_stream(&cfg),
        "sweep" => {
            let exp = positional
                .get(1)
                .context("sweep needs an experiment id (e.g. fig3)")?;
            let rt = Runtime::new(&cfg.artifacts_dir)?;
            harness::run_experiment(exp, &cfg, &rt, fast)
        }
        "account" => cmd_account(&cfg),
        "info" => cmd_info(&cfg),
        other => {
            print_usage();
            bail!("unknown command {other}");
        }
    }
}

fn print_usage() {
    eprintln!(
        "usage: sparse-dp-emb <train|train-async|stream|sweep|account|info> [--key value ...] [--fast]\n\
         train-async also takes --stream (async §4.3 time-series protocol, \
         with --freq-source / --streaming-period)\n\
         see rust/src/main.rs docs for the command list"
    );
}

fn cmd_train(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::new(&cfg.artifacts_dir)?;
    println!("[train] platform={} {}", rt.platform(), cfg.summary());
    let model = rt.manifest.model(&cfg.model)?.clone();
    let mut trainer = Trainer::new(cfg.clone(), &rt)?;
    println!(
        "[train] sigma1={:.4} sigma2={:.4} (q={:.2e}, T={})",
        trainer.sigma1(),
        trainer.sigma2(),
        trainer.batch_size() as f64 / cfg.dataset_size as f64,
        cfg.steps
    );
    let outcome = match model.kind.as_str() {
        "pctr" => {
            let vocabs = model.attr_usize_list("vocabs")?;
            let gen = SynthCriteo::new(CriteoConfig::new(vocabs, cfg.seed ^ 0xDA7A));
            trainer.run_pctr(&gen)?
        }
        "nlu" => {
            let gen = SynthText::new(TextConfig::from_model(&model, cfg.seed ^ 0xDA7A)?);
            trainer.run_text(&gen)?
        }
        other => bail!("unknown model kind {other}"),
    };
    report(&outcome, &rt);
    Ok(())
}

fn cmd_train_async(cfg: &RunConfig, stream: bool) -> Result<()> {
    let rt = Runtime::new(&cfg.artifacts_dir)?;
    println!(
        "[train-async] platform={} {} workers={} data={} shards={} depth={} staleness={} \
         processes={}",
        rt.platform(),
        cfg.summary(),
        cfg.engine.grad_workers,
        cfg.engine.data_workers,
        cfg.engine.shards,
        cfg.engine.channel_depth,
        cfg.engine.staleness,
        cfg.engine.processes,
    );
    if stream {
        // the async twin of `stream`: same drift generator, same seed
        // derivation, bit-identical StreamingOutcome
        let model = rt.manifest.model(&cfg.model)?.clone();
        if model.kind != "pctr" {
            bail!("--stream is for pctr models");
        }
        let gcfg = sparse_dp_emb::coordinator::streaming::drift_gen_cfg(cfg, &model)?;
        println!(
            "[train-async] streaming period={} source={:?}",
            cfg.streaming_period, cfg.freq_source
        );
        let epd = sparse_dp_emb::coordinator::streaming::eval_batches_per_day(cfg);
        let out = sparse_dp_emb::engine::run_streaming(cfg, &rt, gcfg, epd)?;
        // wall clock comes from the run's own telemetry (single timing source)
        let secs = out.outcome.telemetry.wall_secs;
        println!(
            "[train-async] {} streamed steps in {:.2}s ({:.1} steps/s)",
            out.outcome.loss_history.len(),
            secs,
            out.outcome.loss_history.len() as f64 / secs
        );
        println!("[train-async] per-eval-day AUC: {:?}", out.per_day_auc);
        println!("[train-async] reselections: {}", out.reselections);
        report(&out.outcome, &rt);
        return Ok(());
    }
    let outcome = sparse_dp_emb::engine::run(cfg, &rt)?;
    let secs = outcome.telemetry.wall_secs;
    println!(
        "[train-async] {} steps in {:.2}s ({:.1} steps/s)",
        cfg.steps,
        secs,
        cfg.steps as f64 / secs
    );
    report(&outcome, &rt);
    Ok(())
}

fn cmd_stream(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::new(&cfg.artifacts_dir)?;
    let model = rt.manifest.model(&cfg.model)?.clone();
    if model.kind != "pctr" {
        bail!("stream mode is for pctr models");
    }
    let gen =
        SynthCriteo::new(sparse_dp_emb::coordinator::streaming::drift_gen_cfg(cfg, &model)?);
    let trainer = Trainer::new(cfg.clone(), &rt)?;
    println!(
        "[stream] {} period={} source={:?}",
        cfg.summary(),
        cfg.streaming_period,
        cfg.freq_source
    );
    let epd = sparse_dp_emb::coordinator::streaming::eval_batches_per_day(cfg);
    let mut st = StreamingTrainer::new(trainer, epd);
    let out = st.run(&gen)?;
    println!("[stream] per-eval-day AUC: {:?}", out.per_day_auc);
    println!("[stream] reselections: {}", out.reselections);
    report(&out.outcome, &rt);
    Ok(())
}

fn cmd_account(cfg: &RunConfig) -> Result<()> {
    let q = 128.0 / cfg.dataset_size as f64; // criteo-small batch default
    let delta = cfg.effective_delta();
    println!(
        "[account] target eps={} delta={delta:.2e} q={q:.2e} T={}",
        cfg.epsilon, cfg.steps
    );
    let pair = calibrate_sigma_pair(cfg.epsilon, delta, q, cfg.steps, cfg.sigma_ratio)?;
    let eff = sparse_dp_emb::accounting::compose_sigmas(pair.sigma1, pair.sigma2);
    println!(
        "[account] sigma_eff={eff:.4}  sigma1={:.4} sigma2={:.4} (ratio {})",
        pair.sigma1, pair.sigma2, cfg.sigma_ratio
    );
    let achieved = Accountant::new(eff, q, cfg.steps).epsilon(delta);
    println!("[account] achieved eps at that sigma: {achieved:.4}");
    Ok(())
}

fn cmd_info(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::new(&cfg.artifacts_dir)?;
    println!("platform: {}", rt.platform());
    println!("\nmodels:");
    let mut models: Vec<_> = rt.manifest.models.values().collect();
    models.sort_by_key(|m| m.name.clone());
    for m in models {
        let total: usize = m.params.iter().map(|p| p.dims.iter().product::<usize>()).sum();
        let trainable: usize = m
            .params
            .iter()
            .filter(|p| p.trainable)
            .map(|p| p.dims.iter().product::<usize>())
            .sum();
        println!(
            "  {:<28} kind={:<5} params={:>9} trainable={:>9}",
            m.name, m.kind, total, trainable
        );
    }
    println!("\nartifacts:");
    let mut arts: Vec<_> = rt.manifest.artifacts.values().collect();
    arts.sort_by_key(|a| a.name.clone());
    for a in arts {
        println!(
            "  {:<28} model={:<28} inputs={:>2} outputs={:>2}",
            a.name,
            a.model,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}

fn report(outcome: &sparse_dp_emb::coordinator::TrainOutcome, rt: &Runtime) {
    println!("\n=== outcome ===");
    println!("utility (AUC/acc):      {:.4}", outcome.utility);
    println!("eval loss:              {:.4}", outcome.eval_loss);
    println!(
        "first/last train loss:  {:.4} -> {:.4}",
        outcome.loss_history.first().copied().unwrap_or(f64::NAN),
        outcome.loss_history.last().copied().unwrap_or(f64::NAN)
    );
    println!(
        "emb grad coords/step:   {:.1}",
        outcome.emb_grad_coords_per_step
    );
    println!("grad size reduction:    {:.2}x", outcome.reduction_factor);
    println!(
        "noise: sigma1={:.4} sigma2={:.4}",
        outcome.sigma1, outcome.sigma2
    );
    let s = rt.stats();
    println!(
        "runtime: {} execs, marshal-in {:?}, execute {:?}, marshal-out {:?}",
        s.executions, s.marshal_in, s.execute, s.marshal_out
    );

    let t = &outcome.telemetry;
    println!("\n=== telemetry ===");
    println!(
        "steps: {}  wall: {:.2}s  eps_spent: {:.4}  delta: {:.2e}",
        t.steps, t.wall_secs, t.eps_spent, t.delta
    );
    if t.kernel_backend != "scalar" {
        println!(
            "kernel backend: {} ({})",
            t.kernel_backend,
            sparse_dp_emb::kernels::simd_acceleration()
        );
    }
    if t.batch_queue_max > 0 || t.task_queue_max > 0 {
        println!(
            "queue max depth: batch={} task={}",
            t.batch_queue_max, t.task_queue_max
        );
    }
    if t.max_staleness > 0 {
        println!("max snapshot staleness: {} steps", t.max_staleness);
    }
    if t.max_store_resident_bytes > 0 {
        println!(
            "paged store peak resident: {:.2} MiB",
            t.max_store_resident_bytes as f64 / (1024.0 * 1024.0)
        );
    }
    for s in &t.stages {
        println!(
            "  {:<14} {:>10.3}s  x{}",
            s.stage.name(),
            s.nanos as f64 / 1e9,
            s.count
        );
    }
    println!("(per-step traces: pass --metrics-out <path> for JSONL)");
}
