//! Evaluation metrics: exact AUC (the pCTR metric), log-loss, accuracy.

/// Exact ROC AUC by rank statistics with proper tie handling
/// (Mann–Whitney U).  `scores` are arbitrary reals, `labels` 0/1.
pub fn auc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());

    // average ranks over tie groups (1-based ranks)
    let mut rank = vec![0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let avg = (i + j + 2) as f64 / 2.0;
        for k in i..=j {
            rank[order[k]] = avg;
        }
        i = j + 1;
    }

    let pos: f64 = labels.iter().map(|&y| y as f64).sum();
    let neg = n as f64 - pos;
    if pos == 0.0 || neg == 0.0 {
        return f64::NAN;
    }
    let rank_sum_pos: f64 = (0..n).filter(|&i| labels[i] > 0.5).map(|i| rank[i]).sum();
    (rank_sum_pos - pos * (pos + 1.0) / 2.0) / (pos * neg)
}

/// Mean binary cross-entropy from logits.
pub fn logloss_from_logits(logits: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(logits.len(), labels.len());
    let mut total = 0.0;
    for (&z, &y) in logits.iter().zip(labels) {
        let z = z as f64;
        let y = y as f64;
        // softplus(z) - y*z, stable
        let sp = if z > 30.0 { z } else { (1.0 + z.exp()).ln() };
        total += sp - y * z;
    }
    total / logits.len() as f64
}

/// Multi-class accuracy from per-class logits (row-major `[n, c]`).
pub fn accuracy_from_logits(logits: &[f32], labels: &[i32], num_classes: usize) -> f64 {
    let n = labels.len();
    assert_eq!(logits.len(), n * num_classes);
    let mut correct = 0;
    for i in 0..n {
        let row = &logits[i * num_classes..(i + 1) * num_classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred as i32 == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Accumulates (score, label) pairs across eval batches.
#[derive(Clone, Debug, Default)]
pub struct EvalAccumulator {
    pub scores: Vec<f32>,
    pub labels: Vec<f32>,
    /// example-weighted loss total (each batch's mean loss × its size)
    pub loss_sum: f64,
    pub batches: usize,
    pub examples: usize,
}

impl EvalAccumulator {
    /// Record one eval batch: its per-example scores/labels and its *mean*
    /// loss (the loss is re-weighted by the batch size internally).
    pub fn push(&mut self, scores: &[f32], labels: &[f32], loss: f64) {
        debug_assert_eq!(scores.len(), labels.len());
        self.scores.extend_from_slice(scores);
        self.labels.extend_from_slice(labels);
        self.loss_sum += loss * scores.len() as f64;
        self.batches += 1;
        self.examples += scores.len();
    }

    pub fn auc(&self) -> f64 {
        auc(&self.scores, &self.labels)
    }

    /// Mean loss per *example*, so a ragged final batch carries exactly its
    /// share of the weight (a plain per-batch mean would skew it).
    pub fn mean_loss(&self) -> f64 {
        if self.examples == 0 {
            f64::NAN
        } else {
            self.loss_sum / self.examples as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auc_perfect_and_inverted() {
        let s = [0.1f32, 0.2, 0.8, 0.9];
        let y = [0f32, 0.0, 1.0, 1.0];
        assert_eq!(auc(&s, &y), 1.0);
        let y_inv = [1f32, 1.0, 0.0, 0.0];
        assert_eq!(auc(&s, &y_inv), 0.0);
    }

    #[test]
    fn auc_random_is_half() {
        // deterministic construction: interleaved scores
        let mut s = vec![];
        let mut y = vec![];
        for i in 0..1000 {
            s.push(i as f32);
            y.push((i % 2) as f32);
        }
        let a = auc(&s, &y);
        assert!((a - 0.5).abs() < 0.01, "{a}");
    }

    #[test]
    fn auc_ties_averaged() {
        // all scores equal → AUC must be exactly 0.5
        let s = [1f32; 10];
        let y = [0f32, 1., 0., 1., 0., 1., 0., 1., 0., 1.];
        assert!((auc(&s, &y) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_matches_brute_force() {
        let s = [0.3f32, 0.7, 0.7, 0.1, 0.5, 0.9, 0.2];
        let y = [0f32, 1.0, 0.0, 0.0, 1.0, 1.0, 0.0];
        // brute force pair counting
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..7 {
            for j in 0..7 {
                if y[i] > 0.5 && y[j] < 0.5 {
                    den += 1.0;
                    if s[i] > s[j] {
                        num += 1.0;
                    } else if s[i] == s[j] {
                        num += 0.5;
                    }
                }
            }
        }
        assert!((auc(&s, &y) - num / den).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_is_nan() {
        assert!(auc(&[1.0, 2.0], &[1.0, 1.0]).is_nan());
    }

    #[test]
    fn logloss_known_value() {
        // logit 0 → loss ln 2 regardless of label
        let l = logloss_from_logits(&[0.0, 0.0], &[0.0, 1.0]);
        assert!((l - std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn mean_loss_weights_by_example_count() {
        let mut acc = EvalAccumulator::default();
        // full batch of 4 at mean loss 1.0, ragged final batch of 1 at 6.0
        acc.push(&[0.1, 0.2, 0.3, 0.4], &[0.0, 1.0, 0.0, 1.0], 1.0);
        acc.push(&[0.5], &[1.0], 6.0);
        // example-weighted: (4*1 + 1*6) / 5 = 2.0 (a batch mean would say 3.5)
        assert_eq!(acc.mean_loss(), 2.0);
        assert_eq!(acc.batches, 2);
        assert_eq!(acc.examples, 5);
        assert!(EvalAccumulator::default().mean_loss().is_nan());
    }

    #[test]
    fn accuracy_multiclass() {
        let logits = [1.0f32, 0.0, 0.0, /* pred 0 */ 0.0, 2.0, 1.0 /* pred 1 */];
        let acc = accuracy_from_logits(&logits, &[0, 2], 3);
        assert_eq!(acc, 0.5);
    }
}
