//! Parameter store: the Rust-owned canonical model state.
//!
//! The coordinator owns every parameter as a host tensor; artifacts are pure
//! functions of (params, batch).  Initialisation follows the same
//! conventions as `python/compile/model.py` (tables N(0, 0.05), fan-in
//! scaling for the LoRA A factor, He for MLP weights, zeros for biases and
//! LoRA-B — adapters begin as identity, ones for LayerNorm gains) — the
//! Rust init is canonical, the Python one exists only for pytest.

#![warn(missing_docs)]

use anyhow::{bail, Context, Result};

use crate::runtime::{HostTensor, ModelManifest};
use crate::sparse::DenseState;
use crate::util::rng::Xoshiro256;

/// Whether a parameter name denotes a row-sparse embedding table (the
/// paper's sparse noise/update path): a per-feature Criteo table, the NLU
/// token table, or the LoRA `emb_lora_a` factor (whose rows are token
/// rows of rank `r`).
fn is_row_sparse(name: &str) -> bool {
    name.starts_with("table_") || name == "emb_table" || name == "emb_lora_a"
}

/// One named parameter plus its optimizer slot state.
#[derive(Clone, Debug)]
pub struct Param {
    /// manifest parameter name
    pub name: String,
    /// whether the parameter receives updates (frozen otherwise)
    pub trainable: bool,
    /// the parameter values, row-major
    pub tensor: HostTensor,
    /// per-coordinate optimizer state (Adagrad accumulator)
    pub opt_state: DenseState,
}

impl Param {
    /// The parameter's tensor dimensions.
    pub fn dims(&self) -> &[usize] {
        self.tensor.dims()
    }

    /// Total coordinate count.
    pub fn num_elements(&self) -> usize {
        self.tensor.len()
    }
}

/// Role of a parameter in the DP update (embedding rows get sparse noise,
/// dense params get standard DP-SGD noise, frozen params get nothing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamRole {
    /// embedding table updated row-sparsely (`table_*`, `emb_table`,
    /// `emb_lora_a`)
    EmbeddingTable,
    /// trainable dense parameter (MLP / LoRA / head)
    Dense,
    /// frozen backbone
    Frozen,
}

/// The full parameter inventory of one model, in manifest order.
#[derive(Clone, Debug)]
pub struct ParamStore {
    /// manifest model name
    pub model_name: String,
    /// model kind (`pctr` | `nlu`)
    pub kind: String,
    /// the parameters, in manifest order (the artifact input prefix)
    pub params: Vec<Param>,
}

impl ParamStore {
    /// Build + initialise from the manifest's parameter inventory.
    pub fn init(manifest: &ModelManifest, seed: u64) -> Result<ParamStore> {
        let mut rng = Xoshiro256::seed_from(seed);
        let mut params = Vec::with_capacity(manifest.params.len());
        for spec in &manifest.params {
            let n: usize = spec.dims.iter().product();
            let mut data = vec![0f32; n];
            let name = spec.name.as_str();
            if name.starts_with("table_") || name == "emb_table" {
                for v in &mut data {
                    *v = rng.gauss() as f32 * 0.05;
                }
            } else if name == "emb_lora_a" {
                let fan_in = spec.dims[0].max(1);
                let s = (fan_in as f32).powf(-0.5);
                for v in &mut data {
                    *v = rng.gauss() as f32 * s;
                }
            } else if name.ends_with("ln1_g") || name.ends_with("ln2_g") {
                data.fill(1.0);
            } else if name.contains("lora_b") || name == "emb_lora_b" {
                // LoRA B starts at zero (adapters begin as identity)
            } else if name.ends_with("_b") || name.ends_with("bout") {
                // biases zero
            } else if spec.dims.len() == 2 {
                let fan_in = spec.dims[0].max(1);
                let s = (2.0 / fan_in as f32).sqrt();
                for v in &mut data {
                    *v = rng.gauss() as f32 * s;
                }
            }
            params.push(Param {
                name: spec.name.clone(),
                trainable: spec.trainable,
                tensor: HostTensor::f32(spec.dims.clone(), data),
                opt_state: DenseState::default(),
            });
        }
        Ok(ParamStore {
            model_name: manifest.name.clone(),
            kind: manifest.kind.clone(),
            params,
        })
    }

    /// Role of parameter `name` in the DP update (unknown names count as
    /// frozen).
    pub fn role(&self, name: &str) -> ParamRole {
        let p = self.params.iter().find(|p| p.name == name);
        match p {
            Some(p) if !p.trainable => ParamRole::Frozen,
            Some(p) if is_row_sparse(&p.name) => ParamRole::EmbeddingTable,
            Some(_) => ParamRole::Dense,
            None => ParamRole::Frozen,
        }
    }

    /// Position of parameter `name` in the store (= manifest order).
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.params
            .iter()
            .position(|p| p.name == name)
            .with_context(|| format!("no param {name} in store"))
    }

    /// Look a parameter up by name.
    pub fn get(&self, name: &str) -> Result<&Param> {
        Ok(&self.params[self.index_of(name)?])
    }

    /// Look a parameter up by name, mutably.
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Param> {
        let i = self.index_of(name)?;
        Ok(&mut self.params[i])
    }

    /// Tensors in manifest order — the artifact's leading inputs.
    pub fn tensors(&self) -> Vec<HostTensor> {
        self.params.iter().map(|p| p.tensor.clone()).collect()
    }

    /// Embedding-table coordinate count (the DP-SGD dense-noise baseline for
    /// the gradient-size reduction factor).  On a LoRA model this is the A
    /// factor's `V·r` — the baseline the paper's Table 1 compares against.
    pub fn embedding_coords(&self) -> usize {
        self.params
            .iter()
            .filter(|p| p.trainable && is_row_sparse(&p.name))
            .map(|p| p.num_elements())
            .sum()
    }

    /// Trainable dense (non-embedding) coordinate count (`emb_lora_b`
    /// included — the B factor rides the dense DP-SGD path).
    pub fn dense_coords(&self) -> usize {
        self.params
            .iter()
            .filter(|p| p.trainable && !is_row_sparse(&p.name))
            .map(|p| p.num_elements())
            .sum()
    }

    /// Sanity check against an artifact's input specs (params must be a
    /// prefix of the inputs).
    pub fn check_against(&self, inputs: &[crate::runtime::TensorSpec]) -> Result<()> {
        if inputs.len() < self.params.len() {
            bail!("artifact has fewer inputs than params");
        }
        for (p, spec) in self.params.iter().zip(inputs) {
            if p.name != spec.name || p.dims() != spec.dims.as_slice() {
                bail!(
                    "param/input mismatch: store has {}{:?}, artifact wants {}{:?}",
                    p.name,
                    p.dims(),
                    spec.name,
                    spec.dims
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    const SAMPLE: &str = "\
model tiny pctr
attr tiny batch_size 4
param tiny table_00 1 8,2
param tiny mlp_w0 1 4,3
param tiny mlp_b0 1 3
param tiny frozen_x 0 2,2
";

    #[test]
    fn init_conventions() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let store = ParamStore::init(m.model("tiny").unwrap(), 1).unwrap();
        let table = store.get("table_00").unwrap();
        let vals = table.tensor.as_f32().unwrap();
        assert!(vals.iter().any(|&v| v != 0.0));
        assert!(vals.iter().all(|&v| v.abs() < 0.5));
        let bias = store.get("mlp_b0").unwrap();
        assert!(bias.tensor.as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert_eq!(store.role("table_00"), ParamRole::EmbeddingTable);
        assert_eq!(store.role("mlp_w0"), ParamRole::Dense);
        assert_eq!(store.role("frozen_x"), ParamRole::Frozen);
    }

    #[test]
    fn coordinate_counts() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let store = ParamStore::init(m.model("tiny").unwrap(), 1).unwrap();
        assert_eq!(store.embedding_coords(), 16);
        assert_eq!(store.dense_coords(), 15);
    }

    #[test]
    fn nlu_transformer_roles_and_init() {
        // the native NLU layout: trainable table + head, frozen backbone,
        // LayerNorm gains at one, biases at zero
        let m = crate::runtime::reference::builtin_manifest();
        let store = ParamStore::init(m.model("nlu-tiny").unwrap(), 3).unwrap();
        assert_eq!(store.role("emb_table"), ParamRole::EmbeddingTable);
        assert_eq!(store.role("head_w"), ParamRole::Dense);
        assert_eq!(store.role("head_b"), ParamRole::Dense);
        assert_eq!(store.role("l0_wq"), ParamRole::Frozen);
        assert_eq!(store.role("l1_ff2"), ParamRole::Frozen);
        let g = store.get("l0_ln1_g").unwrap();
        assert!(g.tensor.as_f32().unwrap().iter().all(|&v| v == 1.0));
        let b = store.get("l0_wq_b").unwrap();
        assert!(b.tensor.as_f32().unwrap().iter().all(|&v| v == 0.0));
        // backbone weights are randomly initialised (a random frozen encoder)
        let wq = store.get("l0_wq").unwrap();
        assert!(wq.tensor.as_f32().unwrap().iter().any(|&v| v != 0.0));
        // gradient-size baselines count only the trainable table
        let model = m.model("nlu-tiny").unwrap();
        let v = model.attr_usize("vocab").unwrap();
        let d = model.attr_usize("d_model").unwrap();
        let c = model.attr_usize("num_classes").unwrap();
        assert_eq!(store.embedding_coords(), v * d);
        assert_eq!(store.dense_coords(), d * c + c);
    }

    #[test]
    fn nlu_lora_roles_and_init() {
        // the LoRA-on-embedding layout: trainable (A, B, head), frozen
        // table + backbone; A fan-in-scaled random, B exactly zero
        let m = crate::runtime::reference::builtin_manifest();
        let model = m.model("nlu-tiny-lora4").unwrap();
        let store = ParamStore::init(model, 3).unwrap();
        assert_eq!(store.role("emb_lora_a"), ParamRole::EmbeddingTable);
        assert_eq!(store.role("emb_lora_b"), ParamRole::Dense);
        assert_eq!(store.role("emb_table"), ParamRole::Frozen);
        assert_eq!(store.role("head_w"), ParamRole::Dense);
        assert_eq!(store.role("l0_wq"), ParamRole::Frozen);
        let a = store.get("emb_lora_a").unwrap();
        assert!(a.trainable);
        assert!(a.tensor.as_f32().unwrap().iter().any(|&v| v != 0.0));
        // B starts at zero: the adapter begins as identity (z = E[id])
        let b = store.get("emb_lora_b").unwrap();
        assert!(b.trainable);
        assert!(b.tensor.as_f32().unwrap().iter().all(|&v| v == 0.0));
        // the frozen table is still randomly initialised
        let e = store.get("emb_table").unwrap();
        assert!(!e.trainable);
        assert!(e.tensor.as_f32().unwrap().iter().any(|&v| v != 0.0));
        // gradient-size baselines: A is the sparse baseline, B + head dense
        let v = model.attr_usize("vocab").unwrap();
        let d = model.attr_usize("d_model").unwrap();
        let c = model.attr_usize("num_classes").unwrap();
        let r = model.attr_usize("emb_lora_rank").unwrap();
        assert_eq!(store.embedding_coords(), v * r);
        assert_eq!(store.dense_coords(), r * d + d * c + c);
    }

    #[test]
    fn deterministic_init() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = ParamStore::init(m.model("tiny").unwrap(), 42).unwrap();
        let b = ParamStore::init(m.model("tiny").unwrap(), 42).unwrap();
        assert_eq!(
            a.get("mlp_w0").unwrap().tensor,
            b.get("mlp_w0").unwrap().tensor
        );
    }
}
