//! Minimal property-testing harness (the offline crate set has no
//! `proptest`/`quickcheck`).  Runs a property over many seeded random cases
//! and reports the failing seed for reproduction; generators are provided by
//! the seeded [`Xoshiro256`] itself.
//!
//! ```ignore
//! check("clip never amplifies", 200, |rng| {
//!     let n = rng.below(100) as usize + 1;
//!     /* ... */
//!     ensure(cond, format!("..."))
//! });
//! ```

use crate::util::rng::Xoshiro256;

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

pub fn ensure(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn approx_eq(a: f64, b: f64, tol: f64, what: &str) -> CaseResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} != {b} (tol {tol})"))
    }
}

/// Run `cases` random cases of `prop`; panic with the failing seed on the
/// first failure (re-run that seed to reproduce).
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Xoshiro256) -> CaseResult) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256::seed_from(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Uniform usize in [lo, hi].
pub fn usize_in(rng: &mut Xoshiro256, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// Uniform f64 in [lo, hi).
pub fn f64_in(rng: &mut Xoshiro256, lo: f64, hi: f64) -> f64 {
    lo + rng.uniform() * (hi - lo)
}

/// Random f32 vector with entries ~ N(0, scale²).
pub fn gauss_vec(rng: &mut Xoshiro256, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.gauss() * scale) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("u64 is non-negative-ish", 50, |rng| {
            ensure(rng.uniform() < 1.0, "uniform out of range")
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn check_reports_failures() {
        check("always fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn generators_in_range() {
        check("usize_in bounds", 100, |rng| {
            let v = usize_in(rng, 3, 9);
            ensure((3..=9).contains(&v), format!("{v} out of [3,9]"))
        });
    }
}
