//! Parser for `artifacts/manifest.txt` — the flat, line-oriented manifest
//! emitted by `python/compile/aot.py::write_flat_manifest` describing every
//! AOT artifact (ordered inputs/outputs) and model (parameter inventory,
//! vocabulary layout).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One ordered artifact input or output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// tensor name (e.g. `emb_table`, `token_ids`, `loss`)
    pub name: String,
    /// element type: `"f32"` | `"i32"`
    pub dtype: String,
    /// dimensions (empty = rank-0 scalar)
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Total element count of the spec'd shape.
    pub fn num_elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One model parameter: name, trainability, shape.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    /// parameter name (the positional contract with the executors)
    pub name: String,
    /// whether the parameter receives updates
    pub trainable: bool,
    /// parameter dimensions
    pub dims: Vec<usize>,
}

/// One model: kind, free-form attrs, ordered parameter inventory.
#[derive(Clone, Debug, Default)]
pub struct ModelManifest {
    /// model name (the `--model` value)
    pub name: String,
    /// model kind: `"pctr"` | `"nlu"`
    pub kind: String,
    /// free-form key → value attributes (geometry, ranks, batch size…)
    pub attrs: HashMap<String, String>,
    /// the parameters, in artifact-input order
    pub params: Vec<ParamSpec>,
}

impl ModelManifest {
    /// Read attr `key` as an integer.
    pub fn attr_usize(&self, key: &str) -> Result<usize> {
        self.attrs
            .get(key)
            .with_context(|| format!("model {}: missing attr {key}", self.name))?
            .parse()
            .with_context(|| format!("model {}: attr {key} not an integer", self.name))
    }

    /// Read attr `key` as a comma-separated integer list.
    pub fn attr_usize_list(&self, key: &str) -> Result<Vec<usize>> {
        let raw = self
            .attrs
            .get(key)
            .with_context(|| format!("model {}: missing attr {key}", self.name))?;
        raw.split(',')
            .map(|s| s.parse().with_context(|| format!("bad int in attr {key}: {s}")))
            .collect()
    }

    /// Look a parameter spec up by name.
    pub fn param(&self, name: &str) -> Result<&ParamSpec> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("model {}: no param {name}", self.name))
    }
}

/// One executable artifact: HLO file, owning model, ordered I/O specs.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    /// artifact name (e.g. `pctr_grads`, `nlu_tiny_lora4_fwd`)
    pub name: String,
    /// HLO-text file name relative to the artifacts directory
    pub file: String,
    /// name of the model this artifact computes over
    pub model: String,
    /// ordered input specs (params first, then batch, then clip norms)
    pub inputs: Vec<TensorSpec>,
    /// ordered output specs
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactManifest {
    /// Position of output `name` in the output tuple.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o.name == name)
            .with_context(|| format!("artifact {}: no output {name}", self.name))
    }
}

/// The full model + artifact inventory one runtime executes against.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// models by name
    pub models: HashMap<String, ModelManifest>,
    /// artifacts by name
    pub artifacts: HashMap<String, ArtifactManifest>,
}

fn parse_dims(tok: &str) -> Result<Vec<usize>> {
    if tok == "scalar" {
        return Ok(vec![]);
    }
    tok.split(',')
        .map(|s| s.parse::<usize>().with_context(|| format!("bad dim {s}")))
        .collect()
}

impl Manifest {
    /// Parse the flat line-oriented manifest grammar (see
    /// `aot.py::write_flat_manifest` for the emitter).
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("manifest line {}: {line}", lineno + 1);
            match toks[0] {
                "model" => {
                    if toks.len() != 3 {
                        bail!("{}: want `model <name> <kind>`", ctx());
                    }
                    m.models.insert(
                        toks[1].to_string(),
                        ModelManifest {
                            name: toks[1].to_string(),
                            kind: toks[2].to_string(),
                            ..Default::default()
                        },
                    );
                }
                "attr" => {
                    if toks.len() != 4 {
                        bail!("{}: want `attr <model> <key> <value>`", ctx());
                    }
                    m.models
                        .get_mut(toks[1])
                        .with_context(ctx)?
                        .attrs
                        .insert(toks[2].to_string(), toks[3].to_string());
                }
                "param" => {
                    if toks.len() != 5 {
                        bail!("{}: want `param <model> <name> <0|1> <dims>`", ctx());
                    }
                    let spec = ParamSpec {
                        name: toks[2].to_string(),
                        trainable: toks[3] == "1",
                        dims: parse_dims(toks[4]).with_context(ctx)?,
                    };
                    m.models.get_mut(toks[1]).with_context(ctx)?.params.push(spec);
                }
                "artifact" => {
                    if toks.len() != 4 {
                        bail!("{}: want `artifact <name> <file> <model>`", ctx());
                    }
                    m.artifacts.insert(
                        toks[1].to_string(),
                        ArtifactManifest {
                            name: toks[1].to_string(),
                            file: toks[2].to_string(),
                            model: toks[3].to_string(),
                            inputs: vec![],
                            outputs: vec![],
                        },
                    );
                }
                "in" | "out" => {
                    if toks.len() != 5 {
                        bail!("{}: want `in|out <artifact> <name> <dtype> <dims>`", ctx());
                    }
                    let spec = TensorSpec {
                        name: toks[2].to_string(),
                        dtype: toks[3].to_string(),
                        dims: parse_dims(toks[4]).with_context(ctx)?,
                    };
                    let art = m.artifacts.get_mut(toks[1]).with_context(ctx)?;
                    if toks[0] == "in" {
                        art.inputs.push(spec);
                    } else {
                        art.outputs.push(spec);
                    }
                }
                other => bail!("{}: unknown record kind {other}", ctx()),
            }
        }
        Ok(m)
    }

    /// Read and parse a manifest file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`)"))?;
        Manifest::parse(&text)
    }

    /// Look an artifact up by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactManifest> {
        self.artifacts
            .get(name)
            .with_context(|| format!("no artifact {name} in manifest"))
    }

    /// Look a model up by name.
    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .with_context(|| format!("no model {name} in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
model tiny pctr
attr tiny batch_size 4
attr tiny vocabs 8,5
param tiny table_00 1 8,2
param tiny mlp_b0 1 3
artifact tiny_fwd tiny_fwd.hlo.txt tiny
in tiny_fwd table_00 f32 8,2
in tiny_fwd c1 f32 1
out tiny_fwd loss f32 scalar
out tiny_fwd logits f32 4
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let model = m.model("tiny").unwrap();
        assert_eq!(model.kind, "pctr");
        assert_eq!(model.attr_usize("batch_size").unwrap(), 4);
        assert_eq!(model.attr_usize_list("vocabs").unwrap(), vec![8, 5]);
        assert_eq!(model.params.len(), 2);
        assert!(model.param("table_00").unwrap().trainable);
        let art = m.artifact("tiny_fwd").unwrap();
        assert_eq!(art.inputs.len(), 2);
        assert_eq!(art.outputs[0].dims, Vec::<usize>::new());
        assert_eq!(art.output_index("logits").unwrap(), 1);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("model onlyname").is_err());
        assert!(Manifest::parse("attr nomodel k v").is_err());
        assert!(Manifest::parse("in nosuch x f32 1").is_err());
        assert!(Manifest::parse("bogus rec").is_err());
    }
}
