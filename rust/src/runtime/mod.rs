//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the training hot path.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire model-execution surface of the Rust coordinator.  Pattern follows
//! `/opt/xla-example/load_hlo`: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled once per artifact and cached for the life of the
//! process (fixed shapes ⇒ a single compilation each).

mod manifest;
mod tensor;

pub use manifest::{ArtifactManifest, Manifest, ModelManifest, ParamSpec, TensorSpec};
pub use tensor::HostTensor;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// Cumulative runtime counters (marshalling vs execution time) — inputs to
/// the §Perf pass.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub marshal_in: Duration,
    pub execute: Duration,
    pub marshal_out: Duration,
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Load the manifest from `artifacts_dir` and initialise the PJRT CPU
    /// client.  Artifacts themselves are compiled lazily on first use.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for `artifact`.
    fn ensure_compiled(&self, artifact: &str) -> Result<()> {
        if self.exes.borrow().contains_key(artifact) {
            return Ok(());
        }
        let art = self.manifest.artifact(artifact)?;
        let path = self.dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {artifact}"))?;
        self.exes.borrow_mut().insert(artifact.to_string(), exe);
        Ok(())
    }

    /// Pre-compile an artifact (useful to front-load compile time).
    pub fn warmup(&self, artifact: &str) -> Result<()> {
        self.ensure_compiled(artifact)
    }

    /// Execute `artifact` with `inputs` (order and shapes are validated
    /// against the manifest) and return the decomposed output tuple.
    pub fn execute(&self, artifact: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let art = self.manifest.artifact(artifact)?;
        if inputs.len() != art.inputs.len() {
            bail!(
                "artifact {artifact}: got {} inputs, manifest wants {}",
                inputs.len(),
                art.inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&art.inputs).enumerate() {
            if t.dims() != spec.dims.as_slice() || t.dtype_str() != spec.dtype {
                bail!(
                    "artifact {artifact} input #{i} ({}): got {}{:?}, want {}{:?}",
                    spec.name,
                    t.dtype_str(),
                    t.dims(),
                    spec.dtype,
                    spec.dims
                );
            }
        }
        self.ensure_compiled(artifact)?;

        let t0 = Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let t1 = Instant::now();

        let exes = self.exes.borrow();
        let exe = exes.get(artifact).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact {artifact}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let t2 = Instant::now();

        // aot.py lowers with return_tuple=True: a single tuple literal.
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        if parts.len() != art.outputs.len() {
            bail!(
                "artifact {artifact}: got {} outputs, manifest wants {}",
                parts.len(),
                art.outputs.len()
            );
        }
        let outs: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        let t3 = Instant::now();

        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.marshal_in += t1 - t0;
        s.execute += t2 - t1;
        s.marshal_out += t3 - t2;
        Ok(outs)
    }

    /// Execute and return outputs as a name → tensor map (convenience for
    /// non-hot-path callers; the trainer uses positional access).
    pub fn execute_named(
        &self,
        artifact: &str,
        inputs: &[HostTensor],
    ) -> Result<HashMap<String, HostTensor>> {
        let outs = self.execute(artifact, inputs)?;
        let art = self.manifest.artifact(artifact)?;
        Ok(art
            .outputs
            .iter()
            .map(|o| o.name.clone())
            .zip(outs)
            .collect())
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }
}
