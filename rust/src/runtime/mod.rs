//! Model-execution runtime with two interchangeable backends:
//!
//! * **PJRT** (`--features xla`) — loads AOT-compiled HLO-text artifacts
//!   (built once by `python/compile/aot.py`) and executes them on the PJRT
//!   CPU client.  Pattern follows `/opt/xla-example/load_hlo`.
//! * **Reference** (default) — a pure-Rust executor implementing the same
//!   artifact contract for both model families (the pCTR tower and the NLU
//!   transformer), with a built-in manifest, so the CLI, tests, and benches
//!   run with no Python build step and no external crates.  See
//!   [`reference`] for the fixed-chunk reduction invariant that also powers
//!   the async engine.
//!
//! `Runtime::new(dir)` loads `dir/manifest.txt` when present (PJRT backend
//! if compiled in) and otherwise falls back to the built-in reference
//! manifest.  Executables are compiled/validated once per artifact and
//! cached for the life of the process.
//!
//! `docs/RUNTIME.md` is the architecture reference for this layer: the
//! manifest contract, backend resolution, the [`reference::RefModel`]
//! dispatch, the LoRA-on-embedding parametrization, and the
//! finite-difference verification method behind the native executors.

#![warn(missing_docs)]

mod manifest;
#[cfg(feature = "xla")]
mod pjrt;
pub mod reference;
mod tensor;

pub use manifest::{ArtifactManifest, Manifest, ModelManifest, ParamSpec, TensorSpec};
pub use tensor::HostTensor;

use std::cell::RefCell;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

/// Cumulative runtime counters (marshalling vs execution time) — inputs to
/// the §Perf pass.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// artifact executions so far
    pub executions: u64,
    /// host→device input marshalling time (PJRT only)
    pub marshal_in: Duration,
    /// time spent inside artifact execution
    pub execute: Duration,
    /// device→host output marshalling time (PJRT only)
    pub marshal_out: Duration,
}

enum Backend {
    Reference(reference::ReferenceBackend),
    #[cfg(feature = "xla")]
    Pjrt(pjrt::PjrtBackend),
}

/// A loaded manifest plus the backend that executes its artifacts.
pub struct Runtime {
    /// the model/artifact inventory this runtime executes against
    pub manifest: Manifest,
    backend: Backend,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Load the manifest from `artifacts_dir` and pick a backend.  With no
    /// manifest on disk the built-in reference manifest is used, so a fresh
    /// checkout trains out of the box.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        if manifest_path.exists() {
            let manifest = Manifest::load(&manifest_path)?;
            #[cfg(feature = "xla")]
            {
                return Ok(Runtime {
                    manifest,
                    backend: Backend::Pjrt(pjrt::PjrtBackend::new(dir)?),
                    stats: RefCell::new(RuntimeStats::default()),
                });
            }
            #[cfg(not(feature = "xla"))]
            {
                // Artifacts exist but the PJRT client is not compiled in:
                // execute natively off the on-disk manifest geometry.
                eprintln!(
                    "[runtime] {} found but the `xla` feature is not compiled in — \
                     using the native reference executor",
                    manifest_path.display()
                );
                return Ok(Runtime {
                    manifest,
                    backend: Backend::Reference(reference::ReferenceBackend::default()),
                    stats: RefCell::new(RuntimeStats::default()),
                });
            }
        }
        eprintln!(
            "[runtime] {} not found — using the built-in reference manifest \
             (criteo-small / criteo-tiny / nlu-small / nlu-tiny and the \
             nlu-*-lora{{4,16,64}} variants)",
            manifest_path.display()
        );
        Ok(Runtime::builtin())
    }

    /// The artifact-free runtime: built-in manifest + reference executor.
    /// Infallible — used by tests and benches.
    ///
    /// # Example
    ///
    /// Every built-in model trains end-to-end with zero artifacts — the
    /// LoRA-on-embedding Table-1 setting included.  Two steps of
    /// `nlu-tiny-lora4` on the sync trainer:
    ///
    /// ```
    /// use sparse_dp_emb::config::RunConfig;
    /// use sparse_dp_emb::coordinator::Trainer;
    /// use sparse_dp_emb::data::{SynthText, TextConfig};
    /// use sparse_dp_emb::runtime::Runtime;
    ///
    /// let rt = Runtime::builtin();
    /// let mut cfg = RunConfig::default();
    /// cfg.model = "nlu-tiny-lora4".into();
    /// cfg.steps = 2;
    /// cfg.eval_batches = 1;
    /// let model = rt.manifest.model(&cfg.model).unwrap();
    /// let gen = SynthText::new(TextConfig::from_model(model, cfg.seed ^ 0xDA7A).unwrap());
    /// let mut trainer = Trainer::new(cfg, &rt).unwrap();
    /// let outcome = trainer.run_text(&gen).unwrap();
    /// assert_eq!(outcome.loss_history.len(), 2);
    /// assert!(outcome.loss_history.iter().all(|l| l.is_finite()));
    /// ```
    pub fn builtin() -> Runtime {
        Runtime {
            manifest: reference::builtin_manifest(),
            backend: Backend::Reference(reference::ReferenceBackend::default()),
            stats: RefCell::new(RuntimeStats::default()),
        }
    }

    /// Name of the executing platform (`reference-cpu`, or PJRT's).
    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Reference(_) => "reference-cpu".to_string(),
            #[cfg(feature = "xla")]
            Backend::Pjrt(p) => p.platform(),
        }
    }

    /// True when the native reference executor is driving this runtime —
    /// the async engine requires it (its gradient workers compute reduction
    /// chunks with the same math, which PJRT artifacts cannot slice).
    pub fn is_reference(&self) -> bool {
        matches!(self.backend, Backend::Reference(_))
    }

    /// Pre-compile an artifact (useful to front-load compile time).
    pub fn warmup(&self, artifact: &str) -> Result<()> {
        match &self.backend {
            Backend::Reference(_) => {
                self.manifest.artifact(artifact)?;
                Ok(())
            }
            #[cfg(feature = "xla")]
            Backend::Pjrt(p) => p.ensure_compiled(&self.manifest, artifact),
        }
    }

    /// Execute `artifact` with `inputs` (order and shapes are validated
    /// against the manifest) and return the decomposed output tuple.
    pub fn execute(&self, artifact: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let art = self.manifest.artifact(artifact)?;
        if inputs.len() != art.inputs.len() {
            bail!(
                "artifact {artifact}: got {} inputs, manifest wants {}",
                inputs.len(),
                art.inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&art.inputs).enumerate() {
            if t.dims() != spec.dims.as_slice() || t.dtype_str() != spec.dtype {
                bail!(
                    "artifact {artifact} input #{i} ({}): got {}{:?}, want {}{:?}",
                    spec.name,
                    t.dtype_str(),
                    t.dims(),
                    spec.dtype,
                    spec.dims
                );
            }
        }
        let outs = match &self.backend {
            Backend::Reference(r) => {
                let t0 = Instant::now();
                let outs = r.execute(&self.manifest, art, inputs)?;
                let mut s = self.stats.borrow_mut();
                s.executions += 1;
                s.execute += t0.elapsed();
                outs
            }
            #[cfg(feature = "xla")]
            Backend::Pjrt(p) => {
                p.execute(&self.manifest, art, inputs, &mut self.stats.borrow_mut())?
            }
        };
        if outs.len() != art.outputs.len() {
            bail!(
                "artifact {artifact}: got {} outputs, manifest wants {}",
                outs.len(),
                art.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Execute and return outputs as a name → tensor map (convenience for
    /// non-hot-path callers; the trainer uses positional access).
    pub fn execute_named(
        &self,
        artifact: &str,
        inputs: &[HostTensor],
    ) -> Result<std::collections::HashMap<String, HostTensor>> {
        let outs = self.execute(artifact, inputs)?;
        let art = self.manifest.artifact(artifact)?;
        Ok(art
            .outputs
            .iter()
            .map(|o| o.name.clone())
            .zip(outs)
            .collect())
    }

    /// Snapshot of the cumulative execution counters.
    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }
}
