//! PJRT backend (`--features xla`): load AOT-compiled HLO-text artifacts
//! and execute them on the PJRT CPU client.  Compiled executables are cached
//! per artifact for the life of the process (fixed shapes ⇒ a single
//! compilation each).
//!
//! The `xla` crate is not in the offline registry; enabling this feature
//! requires adding it as a path dependency (see Cargo.toml).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use super::manifest::{ArtifactManifest, Manifest};
use super::tensor::HostTensor;
use super::RuntimeStats;

/// The PJRT CPU client plus its per-artifact executable cache.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    exes: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl PjrtBackend {
    /// Create the CPU client; artifacts compile lazily on first use.
    pub fn new(dir: PathBuf) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend { client, dir, exes: RefCell::new(HashMap::new()) })
    }

    /// The PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for `artifact`.
    pub fn ensure_compiled(&self, manifest: &Manifest, artifact: &str) -> Result<()> {
        if self.exes.borrow().contains_key(artifact) {
            return Ok(());
        }
        let art = manifest.artifact(artifact)?;
        let path = self.dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {artifact}"))?;
        self.exes.borrow_mut().insert(artifact.to_string(), exe);
        Ok(())
    }

    /// Execute one artifact on the PJRT client, recording marshalling and
    /// execution time into `stats`.
    pub fn execute(
        &self,
        manifest: &Manifest,
        art: &ArtifactManifest,
        inputs: &[HostTensor],
        stats: &mut RuntimeStats,
    ) -> Result<Vec<HostTensor>> {
        self.ensure_compiled(manifest, &art.name)?;

        let t0 = std::time::Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let t1 = std::time::Instant::now();

        let exes = self.exes.borrow();
        let exe = exes.get(&art.name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact {}", art.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let t2 = std::time::Instant::now();

        // aot.py lowers with return_tuple=True: a single tuple literal.
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        let outs: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        let t3 = std::time::Instant::now();

        stats.executions += 1;
        stats.marshal_in += t1 - t0;
        stats.execute += t2 - t1;
        stats.marshal_out += t3 - t2;
        Ok(outs)
    }
}
