//! Pure-Rust reference executor for both model families.
//!
//! When the `xla` feature (PJRT client for AOT HLO artifacts) is not
//! compiled in — the offline default — this module executes the models
//! natively: same inputs, same output tuple, same manifest contract as the
//! artifacts lowered by `python/compile/aot.py`.  Two executors sit behind
//! the [`RefModel`] dispatch:
//!
//! * [`PctrModel`] (this file) — the Criteo tower: per-feature embedding
//!   tables + ReLU MLP, per-example clipped grads, contribution map.
//! * [`NluModel`] ([`transformer`]) — the text workload: token + sinusoidal
//!   position embeddings into a frozen transformer encoder (attention + MLP
//!   blocks) with a trainable classifier head, hand-derived backward, and
//!   the same sparse per-token `zgrads_scaled` rows the pCTR path surfaces.
//!   The trainable embedding side is either the full table or a LoRA
//!   adapter pair ([`EmbParam`]) — the Table-1 rank rows run natively.
//!
//! A **built-in manifest** (`criteo-small` / `criteo-tiny`, `nlu-small` /
//! `nlu-tiny`, and the LoRA-on-embedding variants `nlu-small-lora{4,16,64}`
//! / `nlu-tiny-lora{4,16}`) lets the whole CLI and test suite run with zero
//! build-time artifacts on both workloads, every Table-1 row included.
//!
//! ## Fixed-chunk reduction invariant
//!
//! Every batch reduction (loss mean, clipped dense-grad sums, contribution
//! map) is computed as a **sequential merge of [`REDUCE_CHUNK`]-example
//! chunk partials**, never as one flat loop and never as a worker-count-
//! dependent tree.  [`RefModel::grads_chunk`] computes one chunk;
//! [`GradsAcc::merge`] folds chunks **in chunk order**.  The sync path
//! (full-batch `execute`) and the async engine (chunks computed by parallel
//! workers, merged in order at the aggregation barrier) therefore produce
//! bit-identical output tuples — this is the invariant that makes
//! `train-async` exactly reproduce `train`, on pCTR and NLU alike.

pub mod transformer;

pub use transformer::{EmbParam, NluModel};

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactManifest, Manifest, ModelManifest};
use super::tensor::HostTensor;
use crate::data::{Batch, PctrBatch, TextBatch};
use crate::kernels::{self, MatInit, MatShape};

/// Examples per reduction chunk (see module docs).  Changing this value
/// changes every f32 reduction result; it is part of the numerical contract
/// between the sync and async paths.
pub const REDUCE_CHUNK: usize = 16;

/// Vocabulary sizes of the 26 Criteo categorical features (paper Table 3),
/// mirrored from `python/compile/configs.py`.
pub const CRITEO_VOCABS: [usize; 26] = [
    1472, 577, 82741, 18940, 305, 23, 1172, 633, 3, 9090, 5918, 64300, 3207,
    27, 1550, 44262, 10, 5485, 2161, 3, 56473, 17, 15, 27360, 104, 12934,
];

/// Numeric (dense) input features of the Criteo rows.
pub const NUM_NUMERIC: usize = 13;

/// The paper's embedding-dimension rule `int(2 · V^0.25)` (Appendix D.1.1).
pub fn embedding_dim(vocab: usize) -> usize {
    ((2.0 * (vocab as f64).powf(0.25)) as usize).max(2)
}

// ---------------------------------------------------------------------------
// Model geometry
// ---------------------------------------------------------------------------

/// Geometry of a pCTR model, parsed once from the manifest.
#[derive(Clone, Debug)]
pub struct PctrModel {
    /// per-feature vocabulary sizes
    pub vocabs: Vec<usize>,
    /// per-feature embedding dimensions
    pub dims: Vec<usize>,
    /// per-feature row offsets in the concatenated row space
    pub offsets: Vec<usize>,
    /// total rows across all tables
    pub total_vocab: usize,
    /// examples per training batch
    pub batch_size: usize,
    /// hidden width of the ReLU MLP tower
    pub hidden_dim: usize,
    /// hidden layers in the tower
    pub num_hidden_layers: usize,
    /// numeric (dense) input features
    pub num_numeric: usize,
    /// concatenated embedding width `Σ dims`
    pub d_emb: usize,
    /// dims of every MLP param in order: w0, b0, …, wout, bout
    pub mlp_shapes: Vec<Vec<usize>>,
}

impl PctrModel {
    /// Parse a pCTR manifest entry into the tower's geometry.
    pub fn from_manifest(model: &ModelManifest) -> Result<PctrModel> {
        if model.kind != "pctr" {
            bail!(
                "PctrModel::from_manifest on kind `{}` for {} (use RefModel::from_manifest)",
                model.kind,
                model.name
            );
        }
        let vocabs = model.attr_usize_list("vocabs")?;
        let dims = model.attr_usize_list("dims")?;
        let offsets = model.attr_usize_list("row_offsets")?;
        let hidden = model.attr_usize("hidden_dim")?;
        let layers = model.attr_usize("num_hidden_layers")?;
        let num_numeric = model.attr_usize("num_numeric")?;
        let d_emb: usize = dims.iter().sum();
        let mut mlp_shapes = Vec::with_capacity(2 * layers + 2);
        let mut in_dim = d_emb + num_numeric;
        for _ in 0..layers {
            mlp_shapes.push(vec![in_dim, hidden]);
            mlp_shapes.push(vec![hidden]);
            in_dim = hidden;
        }
        mlp_shapes.push(vec![in_dim, 1]);
        mlp_shapes.push(vec![1]);
        Ok(PctrModel {
            total_vocab: model.attr_usize("total_vocab")?,
            batch_size: model.attr_usize("batch_size")?,
            hidden_dim: hidden,
            num_hidden_layers: layers,
            num_numeric,
            d_emb,
            vocabs,
            dims,
            offsets,
            mlp_shapes,
        })
    }

    /// Number of categorical features (= embedding tables).
    pub fn nf(&self) -> usize {
        self.vocabs.len()
    }

    /// Total parameter count (tables + MLP stack).
    pub fn num_params(&self) -> usize {
        self.nf() + self.mlp_shapes.len()
    }

    /// MLP input width: concatenated embeddings + numeric features.
    pub fn in_dim(&self) -> usize {
        self.d_emb + self.num_numeric
    }
}

/// Read access to the parameters the chunk math needs.  Implemented over
/// raw input tensors (sync path) and over the engine's sharded store.
pub trait ParamsView: Sync {
    /// Copy embedding row `row` of feature `feature` into `out`.
    fn emb_row(&self, feature: usize, row: usize, out: &mut [f32]);
    /// The `index`-th MLP parameter (order: w0, b0, …, wout, bout).
    fn mlp(&self, index: usize) -> &[f32];
}

/// [`ParamsView`] over the artifact's input tensors.
pub struct TensorView<'a> {
    tables: Vec<&'a [f32]>,
    dims: Vec<usize>,
    mlp: Vec<&'a [f32]>,
}

impl<'a> TensorView<'a> {
    /// Borrow a model's parameter tensors (tables first — the manifest
    /// prefix) as a [`ParamsView`].
    pub fn new(params: &'a [HostTensor], model: &RefModel) -> Result<TensorView<'a>> {
        let nt = model.num_tables();
        if params.len() != model.num_params() {
            bail!("expected {} param tensors, got {}", model.num_params(), params.len());
        }
        let mut tables = Vec::with_capacity(nt);
        for t in &params[..nt] {
            tables.push(t.as_f32()?);
        }
        let mut mlp = Vec::with_capacity(params.len() - nt);
        for t in &params[nt..] {
            mlp.push(t.as_f32()?);
        }
        Ok(TensorView { tables, dims: model.table_dims(), mlp })
    }
}

impl ParamsView for TensorView<'_> {
    fn emb_row(&self, feature: usize, row: usize, out: &mut [f32]) {
        let d = self.dims[feature];
        out.copy_from_slice(&self.tables[feature][row * d..row * d + d]);
    }

    fn mlp(&self, index: usize) -> &[f32] {
        self.mlp[index]
    }
}

/// Borrowed view of a batch (avoids coupling the executors to tensor or
/// owned-batch layouts).  Each variant carries exactly the fields the
/// matching chunk math reads; [`RefModel`] dispatch pairs model and batch
/// kinds, so a mismatch inside a chunk function is a programming error.
#[derive(Clone, Copy)]
pub enum BatchRef<'a> {
    /// a Criteo-style batch (categorical + numeric features, click labels)
    Pctr {
        /// categorical features per example
        nf: usize,
        /// numeric features per example
        nn: usize,
        /// `(B, nf)` categorical bucket ids, row-major
        cat: &'a [i32],
        /// `(B, nn)` numeric values, row-major
        num: &'a [f32],
        /// `(B,)` click labels
        y: &'a [f32],
    },
    /// a text-classification batch (token ids, class labels)
    Text {
        /// tokens per example
        seq_len: usize,
        /// `(B, T)` token ids, row-major
        ids: &'a [i32],
        /// `(B,)` class labels
        labels: &'a [i32],
    },
}

impl<'a> BatchRef<'a> {
    /// Borrow an owned pCTR batch.
    pub fn from_pctr(b: &'a PctrBatch) -> BatchRef<'a> {
        BatchRef::Pctr {
            nf: b.num_features,
            nn: b.num_numeric,
            cat: &b.cat,
            num: &b.num,
            y: &b.y,
        }
    }

    /// Borrow an owned text batch.
    pub fn from_text(b: &'a TextBatch) -> BatchRef<'a> {
        BatchRef::Text { seq_len: b.seq_len, ids: &b.ids, labels: &b.labels }
    }

    /// Borrow either kind of owned batch.
    pub fn from_batch(b: &'a Batch) -> BatchRef<'a> {
        match b {
            Batch::Pctr(p) => BatchRef::from_pctr(p),
            Batch::Text(t) => BatchRef::from_text(t),
        }
    }
}

// ---------------------------------------------------------------------------
// Chunked per-example gradients
// ---------------------------------------------------------------------------

/// Outputs of one reduction chunk (`[lo, hi)` examples), for either model
/// family.
#[derive(Clone, Debug)]
pub struct ChunkGrads {
    /// first example of the chunk (inclusive)
    pub lo: usize,
    /// last example of the chunk (exclusive)
    pub hi: usize,
    /// summed per-example losses of the chunk
    pub loss_sum: f32,
    /// clipped-sum grads per trainable dense param, in grads-artifact output
    /// order (pCTR: the MLP stack; NLU: `emb_lora_b` when present, then
    /// head_w, head_b)
    pub dense_grads: Vec<Vec<f32>>,
    /// `s_i · ∂L/∂z_i` rows, `(hi-lo) × emb_cols` row-major, where
    /// `emb_cols` is `Σ dims` (pCTR) or `T` times the sparse-table row
    /// width (NLU: `d_model`, or the LoRA rank)
    pub zgrads: Vec<f32>,
    /// sparse contribution-map partial (per-bucket value accumulated in
    /// example order within the chunk)
    pub counts: Vec<(u32, f32)>,
    /// per-example clip factors `s_i = min(1, C2/‖g_i‖)`
    pub scales: Vec<f32>,
}

#[inline]
fn softplus(x: f32) -> f32 {
    if x > 0.0 {
        x + (-x).exp().ln_1p()
    } else {
        x.exp().ln_1p()
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

impl PctrModel {
    /// Per-example clipped gradients for examples `[lo, hi)` — the unit of
    /// work of the async engine's gradient workers, and the reduction chunk
    /// of the sync path.  Pure function of (params view, batch, clip norms).
    pub fn grads_chunk<V: ParamsView + ?Sized>(
        &self,
        view: &V,
        batch: &BatchRef,
        lo: usize,
        hi: usize,
        c1: f32,
        c2: f32,
    ) -> ChunkGrads {
        let BatchRef::Pctr { cat, num, y, .. } = *batch else {
            panic!("pctr grads_chunk on a non-pctr batch (dispatch bug)")
        };
        let nf = self.nf();
        let cat_of = |i: usize, f: usize| cat[i * nf + f];
        let hidden = self.hidden_dim;
        let layers = self.num_hidden_layers;
        let d_emb = self.d_emb;
        let in_dim = self.in_dim();
        let w_cnt = (c1 / (nf as f32).sqrt()).min(1.0);

        let mut out = ChunkGrads {
            lo,
            hi,
            loss_sum: 0.0,
            dense_grads: self.mlp_shapes.iter().map(|s| vec![0f32; s.iter().product()]).collect(),
            zgrads: vec![0f32; (hi - lo) * d_emb],
            counts: Vec::new(),
            scales: Vec::with_capacity(hi - lo),
        };
        let mut cmap: HashMap<u32, f32> = HashMap::with_capacity((hi - lo) * nf);

        for i in lo..hi {
            // ---- gather h0 = [z_cat | x_num] ----
            let mut h0 = vec![0f32; in_dim];
            let mut off = 0;
            for f in 0..nf {
                let d = self.dims[f];
                view.emb_row(f, cat_of(i, f) as usize, &mut h0[off..off + d]);
                off += d;
            }
            h0[d_emb..].copy_from_slice(&num[i * self.num_numeric..(i + 1) * self.num_numeric]);

            // ---- forward, storing post-ReLU activations (each layer is a
            // 1×hidden blocked matmul with the bias-initialised chain and
            // the post-ReLU zero skip the scalar loop had) ----
            let mut hs: Vec<Vec<f32>> = Vec::with_capacity(layers + 1);
            hs.push(h0);
            for l in 0..layers {
                let prev = &hs[l];
                let mut h = vec![0f32; hidden];
                kernels::matmul(
                    prev,
                    view.mlp(2 * l),
                    &mut h,
                    MatShape::packed(1, prev.len(), hidden),
                    MatInit::Bias(view.mlp(2 * l + 1)),
                );
                for v in &mut h {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                hs.push(h);
            }
            let wout = view.mlp(2 * layers);
            let bout = view.mlp(2 * layers + 1)[0];
            let hl = &hs[layers];
            let mut logit = bout;
            for (hk, &wk) in hl.iter().zip(wout) {
                logit += hk * wk;
            }
            let y_i = y[i];
            let loss_i = softplus(logit) - y_i * logit;
            let dlogit = sigmoid(logit) - y_i;

            // ---- backward: da per layer + dh back to the embeddings ----
            // Per-param squared norms use the outer-product factorisation
            // ||h ⊗ da||² = ||h||²·||da||² (exact, deterministic).
            let mut sq_parts = vec![0f32; 2 * layers + 2];
            let sq_hl: f32 = hl.iter().map(|v| v * v).sum();
            sq_parts[2 * layers] = dlogit * dlogit * sq_hl;
            sq_parts[2 * layers + 1] = dlogit * dlogit;
            let mut dh: Vec<f32> = wout.iter().map(|&w| w * dlogit).collect();
            // da_rev[0] is layer L-1's da, da_rev[L-1] is layer 0's
            let mut da_rev: Vec<Vec<f32>> = Vec::with_capacity(layers);
            for l in (0..layers).rev() {
                let h = &hs[l + 1];
                let da: Vec<f32> = h
                    .iter()
                    .zip(&dh)
                    .map(|(&hv, &dv)| if hv > 0.0 { dv } else { 0.0 })
                    .collect();
                let prev = &hs[l];
                let sq_prev: f32 = prev.iter().map(|v| v * v).sum();
                let sq_da: f32 = da.iter().map(|v| v * v).sum();
                sq_parts[2 * l] = sq_prev * sq_da;
                sq_parts[2 * l + 1] = sq_da;
                let mut dprev = vec![0f32; prev.len()];
                kernels::matmul_bt(
                    &da,
                    view.mlp(2 * l),
                    &mut dprev,
                    MatShape::packed_bt(1, hidden, prev.len()),
                    MatInit::Zero,
                );
                da_rev.push(da);
                dh = dprev;
            }

            // ---- clip factor over the full per-example gradient ----
            let sq_mlp: f32 = sq_parts.iter().sum();
            let sq_emb: f32 = dh[..d_emb].iter().map(|v| v * v).sum();
            let norm = (sq_mlp + sq_emb).max(1e-24).sqrt();
            let s = (c2 / norm).min(1.0);

            // ---- accumulate clipped grads into the chunk partials ----
            out.loss_sum += loss_i;
            for l in 0..layers {
                let da = &da_rev[layers - 1 - l];
                let prev = &hs[l];
                let wbuf = &mut out.dense_grads[2 * l];
                for (k, &x) in prev.iter().enumerate() {
                    if x != 0.0 {
                        let sx = s * x;
                        let row = &mut wbuf[k * hidden..(k + 1) * hidden];
                        for (rj, &dj) in row.iter_mut().zip(da) {
                            *rj += sx * dj;
                        }
                    }
                }
                let bbuf = &mut out.dense_grads[2 * l + 1];
                for (bj, &dj) in bbuf.iter_mut().zip(da) {
                    *bj += s * dj;
                }
            }
            let sd = s * dlogit;
            let woutbuf = &mut out.dense_grads[2 * layers];
            for (wk, &hk) in woutbuf.iter_mut().zip(hl.iter()) {
                *wk += sd * hk;
            }
            out.dense_grads[2 * layers + 1][0] += sd;

            let zrow = &mut out.zgrads[(i - lo) * d_emb..(i - lo + 1) * d_emb];
            for (zo, &zv) in zrow.iter_mut().zip(&dh[..d_emb]) {
                *zo = s * zv;
            }
            out.scales.push(s);

            // Contribution map: one bucket per feature per example, weight
            // min(1, C1/√F) (Alg. 1 line 5).  Per-bucket accumulation is in
            // example order (HashMap entry add is in-place).
            for f in 0..nf {
                let idx = (self.offsets[f] + cat_of(i, f) as usize) as u32;
                *cmap.entry(idx).or_insert(0.0) += w_cnt;
            }
        }
        out.counts = cmap.into_iter().collect();
        out
    }

    /// Forward pass for examples `[lo, hi)`: per-example BCE loss sum and
    /// logits.
    pub fn forward_chunk<V: ParamsView + ?Sized>(
        &self,
        view: &V,
        batch: &BatchRef,
        lo: usize,
        hi: usize,
    ) -> (f32, Vec<f32>) {
        let BatchRef::Pctr { cat, num, y, .. } = *batch else {
            panic!("pctr forward_chunk on a non-pctr batch (dispatch bug)")
        };
        let nf = self.nf();
        let cat_of = |i: usize, f: usize| cat[i * nf + f];
        let hidden = self.hidden_dim;
        let layers = self.num_hidden_layers;
        let d_emb = self.d_emb;
        let in_dim = self.in_dim();
        let mut loss_sum = 0f32;
        let mut logits = Vec::with_capacity(hi - lo);
        let mut h0 = vec![0f32; in_dim];
        for i in lo..hi {
            let mut off = 0;
            for f in 0..nf {
                let d = self.dims[f];
                view.emb_row(f, cat_of(i, f) as usize, &mut h0[off..off + d]);
                off += d;
            }
            h0[d_emb..]
                .copy_from_slice(&num[i * self.num_numeric..(i + 1) * self.num_numeric]);
            let mut prev = h0.clone();
            for l in 0..layers {
                let mut h = vec![0f32; hidden];
                kernels::matmul(
                    &prev,
                    view.mlp(2 * l),
                    &mut h,
                    MatShape::packed(1, prev.len(), hidden),
                    MatInit::Bias(view.mlp(2 * l + 1)),
                );
                for v in &mut h {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                prev = h;
            }
            let wout = view.mlp(2 * layers);
            let mut logit = view.mlp(2 * layers + 1)[0];
            for (hk, &wk) in prev.iter().zip(wout) {
                logit += hk * wk;
            }
            loss_sum += softplus(logit) - y[i] * logit;
            logits.push(logit);
        }
        (loss_sum, logits)
    }
}

// ---------------------------------------------------------------------------
// Model dispatch
// ---------------------------------------------------------------------------

/// A parsed native model — the dispatch point of the reference executor.
/// Everything downstream of the manifest (chunk math, output assembly, the
/// async engine's gradient workers) is generic over this enum.
#[derive(Clone, Debug)]
pub enum RefModel {
    /// the Criteo pCTR tower
    Pctr(PctrModel),
    /// the NLU transformer (full-table or LoRA-on-embedding)
    Nlu(NluModel),
}

impl RefModel {
    /// Parse a manifest entry into whichever native executor covers it.
    pub fn from_manifest(model: &ModelManifest) -> Result<RefModel> {
        match model.kind.as_str() {
            "pctr" => Ok(RefModel::Pctr(PctrModel::from_manifest(model)?)),
            "nlu" => Ok(RefModel::Nlu(NluModel::from_manifest(model)?)),
            other => bail!(
                "reference runtime: unknown model kind `{other}` for {}",
                model.name
            ),
        }
    }

    /// The model's fixed training batch size.
    pub fn batch_size(&self) -> usize {
        match self {
            RefModel::Pctr(m) => m.batch_size,
            RefModel::Nlu(m) => m.batch_size,
        }
    }

    /// Total parameter count (the artifact-input prefix length).
    pub fn num_params(&self) -> usize {
        match self {
            RefModel::Pctr(m) => m.num_params(),
            RefModel::Nlu(m) => m.num_params(),
        }
    }

    /// Embedding-table parameters — always a prefix of the param list.
    pub fn num_tables(&self) -> usize {
        match self {
            RefModel::Pctr(m) => m.nf(),
            RefModel::Nlu(_) => 1,
        }
    }

    /// Row width of each embedding table, in table order.  For a LoRA NLU
    /// model the sparse table is the `emb_lora_a` factor, so its width is
    /// the adapter rank.
    pub fn table_dims(&self) -> Vec<usize> {
        match self {
            RefModel::Pctr(m) => m.dims.clone(),
            RefModel::Nlu(m) => vec![m.emb_dim()],
        }
    }

    /// Per-example width of the scattered embedding-grads output
    /// (`zgrads_scaled` / `aout_grads_scaled`).
    pub fn emb_cols(&self) -> usize {
        match self {
            RefModel::Pctr(m) => m.d_emb,
            RefModel::Nlu(m) => m.seq_len * m.emb_dim(),
        }
    }

    /// Total rows of the concatenated row space (the contribution-map
    /// width).
    pub fn total_vocab(&self) -> usize {
        match self {
            RefModel::Pctr(m) => m.total_vocab,
            RefModel::Nlu(m) => m.vocab,
        }
    }

    /// Shapes of the trainable dense-grad outputs, in artifact output order.
    pub fn dense_grad_shapes(&self) -> Vec<Vec<usize>> {
        match self {
            RefModel::Pctr(m) => m.mlp_shapes.clone(),
            RefModel::Nlu(m) => m.dense_grad_shapes(),
        }
    }

    fn zgrads_dims(&self) -> Vec<usize> {
        match self {
            RefModel::Pctr(m) => vec![m.batch_size, m.d_emb],
            RefModel::Nlu(m) => vec![m.batch_size, m.seq_len, m.emb_dim()],
        }
    }

    fn logits_dims(&self) -> Vec<usize> {
        match self {
            RefModel::Pctr(m) => vec![m.batch_size],
            RefModel::Nlu(m) => vec![m.batch_size, m.num_classes],
        }
    }

    /// Number of batch tensors following the params in the artifact inputs.
    pub fn num_batch_inputs(&self) -> usize {
        match self {
            RefModel::Pctr(_) => 3, // cat_idx, x_num, y
            RefModel::Nlu(_) => 2,  // token_ids, labels
        }
    }

    /// Borrow the batch tensors (the artifact inputs after the params) as a
    /// [`BatchRef`].
    pub fn batch_ref<'a>(&self, batch: &'a [HostTensor]) -> Result<BatchRef<'a>> {
        match self {
            RefModel::Pctr(m) => Ok(BatchRef::Pctr {
                nf: m.nf(),
                nn: m.num_numeric,
                cat: batch[0].as_i32()?,
                num: batch[1].as_f32()?,
                y: batch[2].as_f32()?,
            }),
            RefModel::Nlu(m) => Ok(BatchRef::Text {
                seq_len: m.seq_len,
                ids: batch[0].as_i32()?,
                labels: batch[1].as_i32()?,
            }),
        }
    }

    /// Per-example clipped gradients for examples `[lo, hi)` — the unit of
    /// work of the async engine and the reduction chunk of the sync path.
    pub fn grads_chunk<V: ParamsView + ?Sized>(
        &self,
        view: &V,
        batch: &BatchRef,
        lo: usize,
        hi: usize,
        c1: f32,
        c2: f32,
    ) -> ChunkGrads {
        match self {
            RefModel::Pctr(m) => m.grads_chunk(view, batch, lo, hi, c1, c2),
            RefModel::Nlu(m) => m.grads_chunk(view, batch, lo, hi, c1, c2),
        }
    }

    /// Forward pass for examples `[lo, hi)`: per-example loss sum and flat
    /// logits.
    pub fn forward_chunk<V: ParamsView + ?Sized>(
        &self,
        view: &V,
        batch: &BatchRef,
        lo: usize,
        hi: usize,
    ) -> (f32, Vec<f32>) {
        match self {
            RefModel::Pctr(m) => m.forward_chunk(view, batch, lo, hi),
            RefModel::Nlu(m) => m.forward_chunk(view, batch, lo, hi),
        }
    }
}

// ---------------------------------------------------------------------------
// Chunk accumulation (the artifact-output assembler)
// ---------------------------------------------------------------------------

/// Accumulates [`ChunkGrads`] **in chunk order** into the full-batch output
/// tuple.  Used identically by the sync `execute` loop and by the async
/// engine's DP aggregation barrier, for both model families.
pub struct GradsAcc {
    loss_sum: f32,
    dense_grads: Vec<Vec<f32>>,
    zgrads: Vec<f32>,
    counts: Vec<f32>,
    scales: Vec<f32>,
}

impl GradsAcc {
    /// An empty accumulator sized for one full batch of `model`.
    pub fn new(model: &RefModel) -> GradsAcc {
        GradsAcc {
            loss_sum: 0.0,
            dense_grads: model
                .dense_grad_shapes()
                .iter()
                .map(|s| vec![0f32; s.iter().product()])
                .collect(),
            zgrads: vec![0f32; model.batch_size() * model.emb_cols()],
            counts: vec![0f32; model.total_vocab()],
            scales: vec![0f32; model.batch_size()],
        }
    }

    /// Fold one chunk in.  Must be called in ascending chunk order — the
    /// merge order is part of the numerical contract (module docs).
    pub fn merge(&mut self, model: &RefModel, chunk: ChunkGrads) {
        self.loss_sum += chunk.loss_sum;
        for (acc, part) in self.dense_grads.iter_mut().zip(&chunk.dense_grads) {
            for (a, &p) in acc.iter_mut().zip(part) {
                *a += p;
            }
        }
        let d = model.emb_cols();
        self.zgrads[chunk.lo * d..chunk.hi * d].copy_from_slice(&chunk.zgrads);
        for &(idx, v) in &chunk.counts {
            self.counts[idx as usize] += v;
        }
        self.scales[chunk.lo..chunk.hi].copy_from_slice(&chunk.scales);
    }

    /// Final artifact output tuple, in manifest order:
    /// `loss, grad_*…, zgrads_scaled, counts, scales`.
    pub fn into_outputs(self, model: &RefModel) -> Vec<HostTensor> {
        let mut outs = Vec::with_capacity(4 + self.dense_grads.len());
        outs.push(HostTensor::f32(
            vec![],
            vec![self.loss_sum / model.batch_size() as f32],
        ));
        for (buf, shape) in self.dense_grads.into_iter().zip(model.dense_grad_shapes()) {
            outs.push(HostTensor::f32(shape, buf));
        }
        outs.push(HostTensor::f32(model.zgrads_dims(), self.zgrads));
        outs.push(HostTensor::f32(vec![model.total_vocab()], self.counts));
        outs.push(HostTensor::f32(vec![model.batch_size()], self.scales));
        outs
    }
}

// ---------------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------------

/// Native CPU executor implementing the artifact contract for both model
/// families.  Parsed model geometries are cached per model name (the hot
/// path runs `execute` every step — mirroring `PjrtBackend`'s executable
/// cache).
#[derive(Default)]
pub struct ReferenceBackend {
    models: std::cell::RefCell<HashMap<String, RefModel>>,
}

impl ReferenceBackend {
    fn model_for(&self, model: &ModelManifest) -> Result<RefModel> {
        if let Some(rm) = self.models.borrow().get(&model.name) {
            return Ok(rm.clone());
        }
        let rm = RefModel::from_manifest(model)?;
        self.models
            .borrow_mut()
            .insert(model.name.clone(), rm.clone());
        Ok(rm)
    }

    /// Execute a `*_fwd` or `*_grads` artifact natively: inputs and outputs
    /// follow the manifest's ordered specs exactly (the AOT contract).
    pub fn execute(
        &self,
        manifest: &Manifest,
        art: &ArtifactManifest,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let model = manifest.model(&art.model)?;
        let rm = self.model_for(model)?;
        let np = rm.num_params();
        let b = rm.batch_size();
        let nb = rm.num_batch_inputs();
        let view = TensorView::new(&inputs[..np], &rm)?;
        let batch = rm.batch_ref(&inputs[np..np + nb])?;
        if art.name.ends_with("_grads") {
            let c1 = inputs[np + nb].as_f32()?[0];
            let c2 = inputs[np + nb + 1].as_f32()?[0];
            let mut acc = GradsAcc::new(&rm);
            let mut lo = 0;
            while lo < b {
                let hi = (lo + REDUCE_CHUNK).min(b);
                acc.merge(&rm, rm.grads_chunk(&view, &batch, lo, hi, c1, c2));
                lo = hi;
            }
            Ok(acc.into_outputs(&rm))
        } else if art.name.ends_with("_fwd") {
            let mut loss_sum = 0f32;
            let mut logits = Vec::with_capacity(b);
            let mut lo = 0;
            while lo < b {
                let hi = (lo + REDUCE_CHUNK).min(b);
                let (ls, lg) = rm.forward_chunk(&view, &batch, lo, hi);
                loss_sum += ls;
                logits.extend(lg);
                lo = hi;
            }
            Ok(vec![
                HostTensor::f32(vec![], vec![loss_sum / b as f32]),
                HostTensor::f32(rm.logits_dims(), logits),
            ])
        } else {
            bail!("reference runtime: unknown artifact kind {}", art.name)
        }
    }
}

// ---------------------------------------------------------------------------
// Built-in manifest (no `make artifacts` needed)
// ---------------------------------------------------------------------------

struct BuiltinPctr {
    model: &'static str,
    artifact_prefix: &'static str,
    vocabs: Vec<usize>,
    batch_size: usize,
    hidden_dim: usize,
    num_hidden_layers: usize,
}

fn dims_str(dims: &[usize]) -> String {
    if dims.is_empty() {
        "scalar".to_string()
    } else {
        dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
    }
}

fn push_pctr(lines: &mut Vec<String>, cfg: &BuiltinPctr) {
    let m = cfg.model;
    let dims: Vec<usize> = cfg.vocabs.iter().map(|&v| embedding_dim(v)).collect();
    let mut offsets = Vec::with_capacity(cfg.vocabs.len());
    let mut acc = 0usize;
    for &v in &cfg.vocabs {
        offsets.push(acc);
        acc += v;
    }
    let total_vocab = acc;
    let d_emb: usize = dims.iter().sum();
    let join = |xs: &[usize]| {
        xs.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
    };
    lines.push(format!("model {m} pctr"));
    lines.push(format!("attr {m} vocabs {}", join(&cfg.vocabs)));
    lines.push(format!("attr {m} dims {}", join(&dims)));
    lines.push(format!("attr {m} row_offsets {}", join(&offsets)));
    lines.push(format!("attr {m} total_vocab {total_vocab}"));
    lines.push(format!("attr {m} batch_size {}", cfg.batch_size));
    lines.push(format!("attr {m} hidden_dim {}", cfg.hidden_dim));
    lines.push(format!("attr {m} num_hidden_layers {}", cfg.num_hidden_layers));
    lines.push(format!("attr {m} num_numeric {NUM_NUMERIC}"));

    // params: tables, then the MLP stack
    let mut params: Vec<(String, Vec<usize>)> = Vec::new();
    for (f, (&v, &d)) in cfg.vocabs.iter().zip(&dims).enumerate() {
        params.push((format!("table_{f:02}"), vec![v, d]));
    }
    let mut in_dim = d_emb + NUM_NUMERIC;
    for i in 0..cfg.num_hidden_layers {
        params.push((format!("mlp_w{i}"), vec![in_dim, cfg.hidden_dim]));
        params.push((format!("mlp_b{i}"), vec![cfg.hidden_dim]));
        in_dim = cfg.hidden_dim;
    }
    params.push(("mlp_wout".to_string(), vec![in_dim, 1]));
    params.push(("mlp_bout".to_string(), vec![1]));
    for (name, d) in &params {
        lines.push(format!("param {m} {name} 1 {}", dims_str(d)));
    }

    let b = cfg.batch_size;
    let nf = cfg.vocabs.len();
    for suffix in ["fwd", "grads"] {
        let a = format!("{}_{suffix}", cfg.artifact_prefix);
        lines.push(format!("artifact {a} {a}.hlo.txt {m}"));
        for (name, d) in &params {
            lines.push(format!("in {a} {name} f32 {}", dims_str(d)));
        }
        lines.push(format!("in {a} cat_idx i32 {b},{nf}"));
        lines.push(format!("in {a} x_num f32 {b},{NUM_NUMERIC}"));
        lines.push(format!("in {a} y f32 {b}"));
        if suffix == "grads" {
            lines.push(format!("in {a} c1 f32 1"));
            lines.push(format!("in {a} c2 f32 1"));
            lines.push(format!("out {a} loss f32 scalar"));
            for (name, d) in params.iter().filter(|(n, _)| n.starts_with("mlp_")) {
                lines.push(format!("out {a} grad_{name} f32 {}", dims_str(d)));
            }
            lines.push(format!("out {a} zgrads_scaled f32 {b},{d_emb}"));
            lines.push(format!("out {a} counts f32 {total_vocab}"));
            lines.push(format!("out {a} scales f32 {b}"));
        } else {
            lines.push(format!("out {a} loss f32 scalar"));
            lines.push(format!("out {a} logits f32 {b}"));
        }
    }
}

struct BuiltinNlu {
    model: &'static str,
    artifact_prefix: &'static str,
    vocab: usize,
    d_model: usize,
    num_heads: usize,
    ff_dim: usize,
    num_layers: usize,
    seq_len: usize,
    num_classes: usize,
    batch_size: usize,
    /// 0 = the full table trains; r > 0 = frozen table + rank-r LoRA
    /// adapters on the embedding (the Table-1 `loraemb{r}` setting)
    emb_lora_rank: usize,
}

fn push_nlu(lines: &mut Vec<String>, cfg: &BuiltinNlu) {
    let m = cfg.model;
    let (d, ff, c, r) = (cfg.d_model, cfg.ff_dim, cfg.num_classes, cfg.emb_lora_rank);
    lines.push(format!("model {m} nlu"));
    for (key, val) in [
        ("vocab", cfg.vocab),
        ("d_model", d),
        ("num_heads", cfg.num_heads),
        ("ff_dim", ff),
        ("num_layers", cfg.num_layers),
        ("seq_len", cfg.seq_len),
        ("num_classes", c),
        ("batch_size", cfg.batch_size),
    ] {
        lines.push(format!("attr {m} {key} {val}"));
    }
    if r > 0 {
        lines.push(format!("attr {m} emb_lora_rank {r}"));
    }

    // params: the sparse table slot (the full trainable table, or the
    // LoRA A factor followed by the frozen table and the B factor), the
    // frozen per-layer backbone in the native layout (transformer.rs),
    // the trainable head
    let mut params: Vec<(String, bool, Vec<usize>)> = if r > 0 {
        vec![
            ("emb_lora_a".to_string(), true, vec![cfg.vocab, r]),
            ("emb_table".to_string(), false, vec![cfg.vocab, d]),
            ("emb_lora_b".to_string(), true, vec![r, d]),
        ]
    } else {
        vec![("emb_table".to_string(), true, vec![cfg.vocab, d])]
    };
    for l in 0..cfg.num_layers {
        for nm in ["wq", "wk", "wv", "wo"] {
            params.push((format!("l{l}_{nm}"), false, vec![d, d]));
            params.push((format!("l{l}_{nm}_b"), false, vec![d]));
        }
        params.push((format!("l{l}_ln1_g"), false, vec![d]));
        params.push((format!("l{l}_ln1_b"), false, vec![d]));
        params.push((format!("l{l}_ff1"), false, vec![d, ff]));
        params.push((format!("l{l}_ff1_b"), false, vec![ff]));
        params.push((format!("l{l}_ff2"), false, vec![ff, d]));
        params.push((format!("l{l}_ff2_b"), false, vec![d]));
        params.push((format!("l{l}_ln2_g"), false, vec![d]));
        params.push((format!("l{l}_ln2_b"), false, vec![d]));
    }
    params.push(("head_w".to_string(), true, vec![d, c]));
    params.push(("head_b".to_string(), true, vec![c]));
    for (name, trainable, dims) in &params {
        lines.push(format!(
            "param {m} {name} {} {}",
            *trainable as u8,
            dims_str(dims)
        ));
    }

    let (b, t) = (cfg.batch_size, cfg.seq_len);
    for suffix in ["fwd", "grads"] {
        let a = format!("{}_{suffix}", cfg.artifact_prefix);
        lines.push(format!("artifact {a} {a}.hlo.txt {m}"));
        for (name, _, dims) in &params {
            lines.push(format!("in {a} {name} f32 {}", dims_str(dims)));
        }
        lines.push(format!("in {a} token_ids i32 {b},{t}"));
        lines.push(format!("in {a} labels i32 {b}"));
        if suffix == "grads" {
            lines.push(format!("in {a} c1 f32 1"));
            lines.push(format!("in {a} c2 f32 1"));
            lines.push(format!("out {a} loss f32 scalar"));
            if r > 0 {
                lines.push(format!("out {a} grad_emb_lora_b f32 {r},{d}"));
            }
            lines.push(format!("out {a} grad_head_w f32 {d},{c}"));
            lines.push(format!("out {a} grad_head_b f32 {c}"));
            if r > 0 {
                lines.push(format!("out {a} aout_grads_scaled f32 {b},{t},{r}"));
            } else {
                lines.push(format!("out {a} zgrads_scaled f32 {b},{t},{d}"));
            }
            lines.push(format!("out {a} counts f32 {}", cfg.vocab));
            lines.push(format!("out {a} scales f32 {b}"));
        } else {
            lines.push(format!("out {a} loss f32 scalar"));
            lines.push(format!("out {a} logits f32 {b},{c}"));
        }
    }
}

/// The `nlu-small` geometry, at the given embedding-LoRA rank (0 = full
/// table).
fn builtin_nlu_small(
    model: &'static str,
    artifact_prefix: &'static str,
    emb_lora_rank: usize,
) -> BuiltinNlu {
    BuiltinNlu {
        model,
        artifact_prefix,
        vocab: 4096,
        d_model: 64,
        num_heads: 4,
        ff_dim: 128,
        num_layers: 3,
        seq_len: 32,
        num_classes: 2,
        batch_size: 64,
        emb_lora_rank,
    }
}

/// The `nlu-tiny` geometry, at the given embedding-LoRA rank.
fn builtin_nlu_tiny(
    model: &'static str,
    artifact_prefix: &'static str,
    emb_lora_rank: usize,
) -> BuiltinNlu {
    BuiltinNlu {
        model,
        artifact_prefix,
        vocab: 512,
        d_model: 16,
        num_heads: 2,
        ff_dim: 32,
        num_layers: 2,
        seq_len: 12,
        num_classes: 2,
        batch_size: 32,
        emb_lora_rank,
    }
}

/// The built-in manifest: `criteo-small` (the paper's CPU-scale config,
/// Table-3 vocabularies / 16) and `criteo-tiny` (test-sized), the NLU
/// transformer pair `nlu-small` / `nlu-tiny`, and their LoRA-on-embedding
/// variants `nlu-small-lora{4,16,64}` (the Table-1 rank rows) and
/// `nlu-tiny-lora{4,16}` (test-sized).
pub fn builtin_manifest() -> Manifest {
    let mut lines: Vec<String> = Vec::new();
    push_pctr(
        &mut lines,
        &BuiltinPctr {
            model: "criteo-small",
            artifact_prefix: "pctr",
            vocabs: CRITEO_VOCABS.iter().map(|&v| (v / 16).max(4)).collect(),
            batch_size: 128,
            hidden_dim: 128,
            num_hidden_layers: 4,
        },
    );
    push_pctr(
        &mut lines,
        &BuiltinPctr {
            model: "criteo-tiny",
            artifact_prefix: "pctr_tiny",
            vocabs: vec![96, 48, 200, 12],
            batch_size: 32,
            hidden_dim: 16,
            num_hidden_layers: 2,
        },
    );
    push_nlu(&mut lines, &builtin_nlu_small("nlu-small", "nlu_small", 0));
    push_nlu(&mut lines, &builtin_nlu_tiny("nlu-tiny", "nlu_tiny", 0));
    push_nlu(&mut lines, &builtin_nlu_small("nlu-small-lora4", "nlu_small_lora4", 4));
    push_nlu(&mut lines, &builtin_nlu_small("nlu-small-lora16", "nlu_small_lora16", 16));
    push_nlu(&mut lines, &builtin_nlu_small("nlu-small-lora64", "nlu_small_lora64", 64));
    push_nlu(&mut lines, &builtin_nlu_tiny("nlu-tiny-lora4", "nlu_tiny_lora4", 4));
    push_nlu(&mut lines, &builtin_nlu_tiny("nlu-tiny-lora16", "nlu_tiny_lora16", 16));
    Manifest::parse(&lines.join("\n"))
        .context("built-in manifest must parse")
        .expect("built-in manifest is static")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ParamStore;

    #[test]
    fn builtin_manifest_parses_and_is_consistent() {
        let m = builtin_manifest();
        for name in ["criteo-small", "criteo-tiny"] {
            let model = m.model(name).unwrap();
            let pm = PctrModel::from_manifest(model).unwrap();
            assert_eq!(pm.vocabs.len(), pm.dims.len());
            assert_eq!(pm.total_vocab, pm.vocabs.iter().sum::<usize>());
            let store = ParamStore::init(model, 1).unwrap();
            assert_eq!(store.params.len(), pm.num_params());
        }
        for name in ["nlu-small", "nlu-tiny"] {
            let model = m.model(name).unwrap();
            let rm = RefModel::from_manifest(model).unwrap();
            let store = ParamStore::init(model, 1).unwrap();
            assert_eq!(store.params.len(), rm.num_params());
            // only the table and the head train; the backbone is frozen
            assert_eq!(
                store.params.iter().filter(|p| p.trainable).count(),
                3,
                "{name}"
            );
            assert_eq!(store.params[0].name, "emb_table");
        }
        for name in [
            "nlu-small-lora4",
            "nlu-small-lora16",
            "nlu-small-lora64",
            "nlu-tiny-lora4",
            "nlu-tiny-lora16",
        ] {
            let model = m.model(name).unwrap();
            let rm = RefModel::from_manifest(model).unwrap();
            let store = ParamStore::init(model, 1).unwrap();
            assert_eq!(store.params.len(), rm.num_params());
            // A/B factors + head train; the table and backbone are frozen
            assert_eq!(
                store.params.iter().filter(|p| p.trainable).count(),
                4,
                "{name}"
            );
            // the sparse A factor leads (the table-prefix contract)
            assert_eq!(store.params[0].name, "emb_lora_a");
            assert!(!store.get("emb_table").unwrap().trainable, "{name}");
            let rank = model.attr_usize("emb_lora_rank").unwrap();
            assert_eq!(rm.table_dims(), vec![rank], "{name}");
        }
        assert!(m.artifact("pctr_grads").is_ok());
        assert!(m.artifact("pctr_tiny_fwd").is_ok());
        // grads artifact I/O arity: params + 3 batch + 2 clip inputs;
        // loss + mlp grads + 3 tail outputs
        let art = m.artifact("pctr_tiny_grads").unwrap();
        let pm = PctrModel::from_manifest(m.model("criteo-tiny").unwrap()).unwrap();
        assert_eq!(art.inputs.len(), pm.num_params() + 5);
        assert_eq!(art.outputs.len(), 1 + pm.mlp_shapes.len() + 3);
        // same arity law for the nlu pair: params + 2 batch + 2 clip inputs;
        // loss + head grads + 3 tail outputs
        let art = m.artifact("nlu_tiny_grads").unwrap();
        let rm = RefModel::from_manifest(m.model("nlu-tiny").unwrap()).unwrap();
        assert_eq!(art.inputs.len(), rm.num_params() + 4);
        assert_eq!(art.outputs.len(), 1 + 2 + 3);
        // LoRA pair: one extra dense grad (emb_lora_b) in the outputs
        let art = m.artifact("nlu_tiny_lora4_grads").unwrap();
        let rm = RefModel::from_manifest(m.model("nlu-tiny-lora4").unwrap()).unwrap();
        assert_eq!(art.inputs.len(), rm.num_params() + 4);
        assert_eq!(art.outputs.len(), 1 + 3 + 3);
        assert_eq!(art.output_index("aout_grads_scaled").unwrap(), 4);
    }

    #[test]
    fn embedding_dim_rule_matches_python() {
        // int(2 * v**0.25) with a floor of 2
        assert_eq!(embedding_dim(3), 2);
        assert_eq!(embedding_dim(92), 6);
        assert_eq!(embedding_dim(5171), 16);
    }

    fn tiny_exec() -> (Manifest, Vec<HostTensor>, PctrModel) {
        let m = builtin_manifest();
        let model = m.model("criteo-tiny").unwrap();
        let pm = PctrModel::from_manifest(model).unwrap();
        let store = ParamStore::init(model, 7).unwrap();
        let mut rng = crate::util::rng::Xoshiro256::seed_from(3);
        let b = pm.batch_size;
        let nf = pm.nf();
        let cat: Vec<i32> = (0..b * nf)
            .map(|i| rng.below(pm.vocabs[i % nf] as u64) as i32)
            .collect();
        let num: Vec<f32> = (0..b * pm.num_numeric).map(|_| rng.gauss() as f32).collect();
        let y: Vec<f32> = (0..b).map(|_| rng.below(2) as f32).collect();
        let mut inputs = store.tensors();
        inputs.push(HostTensor::i32(vec![b, nf], cat));
        inputs.push(HostTensor::f32(vec![b, pm.num_numeric], num));
        inputs.push(HostTensor::f32(vec![b], y));
        (m, inputs, pm)
    }

    #[test]
    fn reference_grads_shapes_and_determinism() {
        let (m, mut inputs, pm) = tiny_exec();
        inputs.push(HostTensor::f32(vec![1], vec![1.0]));
        inputs.push(HostTensor::f32(vec![1], vec![0.7]));
        let backend = ReferenceBackend::default();
        let art = m.artifact("pctr_tiny_grads").unwrap();
        let o1 = backend.execute(&m, art, &inputs).unwrap();
        let o2 = backend.execute(&m, art, &inputs).unwrap();
        assert_eq!(o1.len(), art.outputs.len());
        assert_eq!(o1, o2, "reference execution must be deterministic");
        let loss = o1[0].scalar().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        // scales respect the clip norm
        let scales = o1.last().unwrap().as_f32().unwrap();
        assert!(scales.iter().all(|&s| s > 0.0 && s <= 1.0));
        // counts mass: every example contributes min(1, c1/sqrt(F)) per feature
        let counts = o1[o1.len() - 2].as_f32().unwrap();
        let mass: f64 = counts.iter().map(|&v| v as f64).sum();
        let w = (1.0 / (pm.nf() as f64).sqrt()).min(1.0);
        let want = w * (pm.batch_size * pm.nf()) as f64;
        assert!((mass - want).abs() < 1e-2, "mass {mass} want {want}");
    }

    #[test]
    fn clipping_caps_per_example_norm() {
        // With a tiny clip norm, the summed grad's norm is bounded by B*C2.
        let (m, mut inputs, pm) = tiny_exec();
        inputs.push(HostTensor::f32(vec![1], vec![1.0]));
        inputs.push(HostTensor::f32(vec![1], vec![0.05]));
        let art = m.artifact("pctr_tiny_grads").unwrap();
        let outs = ReferenceBackend::default().execute(&m, art, &inputs).unwrap();
        let mut sq = 0f64;
        for (spec, out) in art.outputs.iter().zip(&outs) {
            if spec.name.starts_with("grad_") || spec.name == "zgrads_scaled" {
                sq += out
                    .as_f32()
                    .unwrap()
                    .iter()
                    .map(|&v| (v as f64) * (v as f64))
                    .sum::<f64>();
            }
        }
        // mlp grads are summed over B (norm ≤ B·C2); zgrads stay per-example
        // (Σ‖·‖² ≤ B·C2²) — so the total is ≤ C2·√(B² + B).
        let b = pm.batch_size as f64;
        let bound = 0.05 * (b * b + b).sqrt();
        assert!(
            sq.sqrt() <= bound + 1e-3,
            "clipped norm {} exceeds C2*sqrt(B^2+B) = {bound}",
            sq.sqrt()
        );
    }

    #[test]
    fn forward_matches_grads_loss() {
        // fwd and grads artifacts must agree on the loss for c2 -> inf
        let (m, inputs, _pm) = tiny_exec();
        let fwd = ReferenceBackend::default()
            .execute(&m, m.artifact("pctr_tiny_fwd").unwrap(), &inputs)
            .unwrap();
        let mut ginputs = inputs;
        ginputs.push(HostTensor::f32(vec![1], vec![1e9]));
        ginputs.push(HostTensor::f32(vec![1], vec![1e9]));
        let grads = ReferenceBackend::default()
            .execute(&m, m.artifact("pctr_tiny_grads").unwrap(), &ginputs)
            .unwrap();
        assert_eq!(fwd[0].scalar().unwrap(), grads[0].scalar().unwrap());
    }

    #[test]
    fn chunk_merge_equals_full_batch() {
        // merging per-chunk partials in order == the sync execute loop
        let (m, mut inputs, pm) = tiny_exec();
        inputs.push(HostTensor::f32(vec![1], vec![1.0]));
        inputs.push(HostTensor::f32(vec![1], vec![1.0]));
        let art = m.artifact("pctr_tiny_grads").unwrap();
        let full = ReferenceBackend::default().execute(&m, art, &inputs).unwrap();
        let rm = RefModel::Pctr(pm.clone());
        let np = pm.num_params();
        let view = TensorView::new(&inputs[..np], &rm).unwrap();
        let batch = rm.batch_ref(&inputs[np..np + 3]).unwrap();
        // compute chunks out of order, merge in order — as the engine does
        let mut chunks: Vec<ChunkGrads> = Vec::new();
        let mut lo = 0;
        while lo < pm.batch_size {
            let hi = (lo + REDUCE_CHUNK).min(pm.batch_size);
            chunks.push(rm.grads_chunk(&view, &batch, lo, hi, 1.0, 1.0));
            lo = hi;
        }
        chunks.reverse();
        chunks.sort_by_key(|c| c.lo);
        let mut acc = GradsAcc::new(&rm);
        for c in chunks {
            acc.merge(&rm, c);
        }
        let merged = acc.into_outputs(&rm);
        assert_eq!(full, merged, "chunked merge must be bit-identical");
    }

    #[test]
    fn grads_point_downhill() {
        // one SGD step along -grad must reduce the fwd loss (sanity that
        // the hand-written backward pass is a real gradient)
        let (m, inputs, pm) = tiny_exec();
        let art_f = m.artifact("pctr_tiny_fwd").unwrap();
        let loss0 = ReferenceBackend::default().execute(&m, art_f, &inputs).unwrap()[0]
            .scalar()
            .unwrap();
        let mut ginputs = inputs.clone();
        ginputs.push(HostTensor::f32(vec![1], vec![1e9]));
        ginputs.push(HostTensor::f32(vec![1], vec![1e9]));
        let art_g = m.artifact("pctr_tiny_grads").unwrap();
        let grads = ReferenceBackend::default().execute(&m, art_g, &ginputs).unwrap();
        let np = pm.num_params();
        let nf = pm.nf();
        let lr = 0.05f32 / pm.batch_size as f32;
        let mut stepped = inputs;
        // dense params: grad_mlp_* outputs are 1..=mlp count
        for (j, out) in grads[1..1 + pm.mlp_shapes.len()].iter().enumerate() {
            let p = stepped[nf + j].as_f32_mut().unwrap();
            for (pv, &g) in p.iter_mut().zip(out.as_f32().unwrap()) {
                *pv -= lr * g;
            }
        }
        // embedding rows via zgrads scatter
        let zg = grads[1 + pm.mlp_shapes.len()].as_f32().unwrap().to_vec();
        let cat = stepped[np].as_i32().unwrap().to_vec();
        for i in 0..pm.batch_size {
            let mut off = 0;
            for f in 0..nf {
                let d = pm.dims[f];
                let row = cat[i * nf + f] as usize;
                let t = stepped[f].as_f32_mut().unwrap();
                for k in 0..d {
                    t[row * d + k] -= lr * zg[i * pm.d_emb + off + k];
                }
                off += d;
            }
        }
        let loss1 = ReferenceBackend::default().execute(&m, art_f, &stepped).unwrap()[0]
            .scalar()
            .unwrap();
        assert!(loss1 < loss0, "loss did not decrease: {loss0} -> {loss1}");
    }
}
