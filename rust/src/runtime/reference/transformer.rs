//! Native transformer forward/backward — the NLU half of the reference
//! executor.
//!
//! Mirrors the JAX model in `python/compile/model.py` at the geometry the
//! built-in manifests use: token embeddings plus a fixed sinusoidal position
//! encoding feed a stack of post-norm encoder blocks (multi-head attention
//! and a GELU MLP, each behind a residual + LayerNorm), mean-pooled into a
//! linear classifier head.  The backbone is **frozen** (the paper's DP
//! fine-tuning setting); what trains on the embedding side is selected by
//! [`EmbParam`]:
//!
//! * [`EmbParam::Full`] — the `(V, d)` token table itself, `z = E[id]`;
//! * [`EmbParam::LoRA`] — the table freezes and a rank-`r` adapter pair
//!   trains instead (`[HSW+22]`; the Table-1 `loraemb{r}` baseline):
//!   `z = E[id] + A[id]·B`.  Backward through the reparametrization gives
//!   per-token rows `∂L/∂A[id_p] = ∂L/∂z_p · Bᵀ` — scattered row-sparsely
//!   exactly like full-table rows — plus a *dense* factor gradient
//!   `∂L/∂B = Σ_p A[id_p]ᵀ · ∂L/∂z_p` (every example touches all of `B`).
//!
//! Either way the backward pass propagates ∂L/∂z through every block down
//! to the per-token embedding outputs and produces:
//!
//! * per-example clipped dense gradients — the head, plus `emb_lora_b` in
//!   LoRA mode (the dense DP-SGD path),
//! * `s_i · ∂L/∂z_i` rows (`zgrads_scaled`, `(B, T, d)`) — or
//!   `s_i · ∂L/∂A[id]` rows (`aout_grads_scaled`, `(B, T, r)`) in LoRA
//!   mode — that Rust scatter-adds into the row-sparse table gradient:
//!   exactly the pCTR contract, so the whole selection/noise/update
//!   pipeline is shared,
//! * the pre-noise contribution map over the vocabulary (Alg. 1 line 5),
//!   with the per-example weight `min(1, C1/√u)` per *distinct* token
//!   (`u` = distinct tokens in the example — the per-slot `1/mult` split of
//!   the Python reference sums back to this); the map is over token ids, so
//!   it is identical under both parametrizations.
//!
//! The per-example clip norm covers the dense gradients plus the scattered
//! embedding rows; repeated tokens within an example add inside a row, so
//! the scattered norm uses the pairwise Gram identity (`kernels/ref.py`,
//! mirroring the ghost-clipping treatment of `[LTLH22]`), accumulated in
//! a fixed loop order to keep the executor bit-deterministic.
//!
//! Everything here is a pure function of (params view, batch): chunked
//! through [`ChunkGrads`] it satisfies the fixed-chunk reduction invariant
//! of the parent module, which is what lets `train-async` run NLU
//! bit-identically to `train`.
//!
//! All matmuls — QKV/scores/context/projection, the GELU MLP, the LoRA
//! factors, the head — run on the blocked, register-tiled kernels of
//! [`crate::kernels`], which keep each output element's k-accumulation
//! chain in the retired scalar order (bit-identical by construction;
//! `tests/kernels.rs` pins it with `to_bits` equality).

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::{BatchRef, ChunkGrads, ParamsView};
use crate::kernels::{self, gelu_prime, MatInit, MatShape};
use crate::runtime::ModelManifest;

/// Dense-parameter slots per encoder layer (after the embedding table), in
/// manifest order.
pub const LAYER_PARAMS: usize = 16;

const P_WQ: usize = 0;
const P_WQ_B: usize = 1;
const P_WK: usize = 2;
const P_WK_B: usize = 3;
const P_WV: usize = 4;
const P_WV_B: usize = 5;
const P_WO: usize = 6;
const P_WO_B: usize = 7;
const P_LN1_G: usize = 8;
const P_LN1_B: usize = 9;
const P_FF1: usize = 10;
const P_FF1_B: usize = 11;
const P_FF2: usize = 12;
const P_FF2_B: usize = 13;
const P_LN2_G: usize = 14;
const P_LN2_B: usize = 15;

/// LoRA-mode index of the frozen `(V, d)` token table in the dense
/// ([`ParamsView::mlp`]) space — the trainable `emb_lora_a` factor occupies
/// the table slot instead (see [`EmbParam`]).
const M_EMB_TABLE: usize = 0;

/// LoRA-mode index of the `(r, d)` `emb_lora_b` factor in the dense space.
const M_LORA_B: usize = 1;

const LN_EPS: f32 = 1e-5;

/// How the trainable embedding path is parametrised.
///
/// This is the axis Table 1 sweeps: the full table trains row-sparsely,
/// while the LoRA reparametrization `z = E[id] + A[id]·B` freezes the table
/// and trains the rank-`r` factors — `A` row-sparsely (its rows are token
/// rows, so the whole FEST/AdaFEST selection machinery applies unchanged),
/// `B` on the dense DP-SGD path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EmbParam {
    /// the `(V, d)` token table itself trains; `z = E[id]`
    Full,
    /// frozen table plus trainable rank-`r` adapters; `z = E[id] + A[id]·B`
    LoRA {
        /// adapter rank `r` (the manifest's `emb_lora_rank`)
        rank: usize,
    },
}

/// Geometry of an NLU model, parsed once from the manifest.
#[derive(Clone, Debug)]
pub struct NluModel {
    /// token vocabulary size `V` (rows of the embedding table)
    pub vocab: usize,
    /// model width `d`
    pub d_model: usize,
    /// attention heads per block
    pub num_heads: usize,
    /// hidden width of the GELU MLP
    pub ff_dim: usize,
    /// encoder blocks in the stack
    pub num_layers: usize,
    /// tokens per example `T`
    pub seq_len: usize,
    /// classifier output classes
    pub num_classes: usize,
    /// examples per training batch
    pub batch_size: usize,
    /// sinusoidal position encoding, `(seq_len, d_model)` row-major
    pub posenc: Vec<f32>,
    /// trainable-embedding parametrization (full table vs LoRA adapters)
    pub emb: EmbParam,
}

/// The standard sinusoidal position encoding (`model.py::_posenc`).
pub fn sinusoidal_posenc(seq_len: usize, d: usize) -> Vec<f32> {
    let mut pe = vec![0f32; seq_len * d];
    for pos in 0..seq_len {
        for i in 0..d {
            let angle =
                pos as f64 / 10000f64.powf((2 * (i / 2)) as f64 / d as f64);
            let v = if i % 2 == 0 { angle.sin() } else { angle.cos() };
            pe[pos * d + i] = v as f32;
        }
    }
    pe
}

impl NluModel {
    /// Parse an NLU manifest entry into the native executor's geometry.
    ///
    /// Fails with the offending attr / parameter named when the model needs
    /// a capability the native executor does not have (attention-LoRA
    /// adapters, or a parameter inventory that differs from the native
    /// layout) — those manifests need the `xla` backend.
    pub fn from_manifest(model: &ModelManifest) -> Result<NluModel> {
        if model.kind != "nlu" {
            bail!(
                "NluModel::from_manifest on kind `{}` for {}",
                model.kind,
                model.name
            );
        }
        // Attention-LoRA adapters (attr `lora_rank`) exist only in artifact
        // builds; reject them by name so the fix is obvious.
        let attn_lora = model.attr_usize("lora_rank").unwrap_or(0);
        if attn_lora != 0 {
            bail!(
                "model {}: attr `lora_rank` = {attn_lora} is not supported by \
                 the native NLU executor (attention-LoRA adapters need the \
                 `xla` backend)",
                model.name
            );
        }
        let emb = match model.attr_usize("emb_lora_rank").unwrap_or(0) {
            0 => EmbParam::Full,
            r => EmbParam::LoRA { rank: r },
        };
        let d = model.attr_usize("d_model")?;
        let heads = model.attr_usize("num_heads")?;
        if heads == 0 || d % heads != 0 {
            bail!("{}: d_model {d} not divisible by num_heads {heads}", model.name);
        }
        let seq_len = model.attr_usize("seq_len")?;
        let m = NluModel {
            vocab: model.attr_usize("vocab")?,
            d_model: d,
            num_heads: heads,
            ff_dim: model.attr_usize("ff_dim")?,
            num_layers: model.attr_usize("num_layers")?,
            seq_len,
            num_classes: model.attr_usize("num_classes")?,
            batch_size: model.attr_usize("batch_size")?,
            posenc: sinusoidal_posenc(seq_len, d),
            emb,
        };
        // The executor addresses parameters positionally; reject manifests
        // whose inventory differs from the native layout instead of
        // silently misreading them — naming the first offender.
        let want = m.param_names();
        if model.params.len() != want.len() {
            bail!(
                "model {}: {} parameters in the manifest, the native \
                 transformer layout wants {}",
                model.name,
                model.params.len(),
                want.len()
            );
        }
        for (p, want_name) in model.params.iter().zip(&want) {
            if &p.name != want_name {
                bail!(
                    "model {}: param `{}` where the native layout expects \
                     `{want_name}` (adapter layouts beyond LoRA-on-embedding \
                     need the `xla` backend)",
                    model.name,
                    p.name
                );
            }
        }
        Ok(m)
    }

    /// Parameter names in manifest order (the positional contract).  The
    /// sparse table — `emb_table`, or the `emb_lora_a` factor in LoRA mode —
    /// always leads (the table-prefix contract of
    /// [`super::RefModel::num_tables`]).
    pub fn param_names(&self) -> Vec<String> {
        let mut names = Vec::with_capacity(self.num_params());
        match self.emb {
            EmbParam::Full => names.push("emb_table".to_string()),
            EmbParam::LoRA { .. } => {
                names.push("emb_lora_a".to_string());
                names.push("emb_table".to_string());
                names.push("emb_lora_b".to_string());
            }
        }
        for l in 0..self.num_layers {
            for nm in ["wq", "wk", "wv", "wo"] {
                names.push(format!("l{l}_{nm}"));
                names.push(format!("l{l}_{nm}_b"));
            }
            for nm in ["ln1_g", "ln1_b", "ff1", "ff1_b", "ff2", "ff2_b", "ln2_g", "ln2_b"] {
                names.push(format!("l{l}_{nm}"));
            }
        }
        names.push("head_w".to_string());
        names.push("head_b".to_string());
        names
    }

    /// Total parameter count (table + dense space).
    pub fn num_params(&self) -> usize {
        3 + self.dense_base() + LAYER_PARAMS * self.num_layers
    }

    /// Per-head width of the attention blocks.
    pub fn head_dim(&self) -> usize {
        self.d_model / self.num_heads
    }

    /// Row width of the sparse embedding-path table: `d_model` for the full
    /// table, the adapter rank for LoRA (the `emb_lora_a` rows).
    pub fn emb_dim(&self) -> usize {
        match self.emb {
            EmbParam::Full => self.d_model,
            EmbParam::LoRA { rank } => rank,
        }
    }

    /// Offset of the first encoder-layer parameter in the dense
    /// ([`ParamsView::mlp`]) space: LoRA mode places the frozen `emb_table`
    /// and the `emb_lora_b` factor before the backbone.
    fn dense_base(&self) -> usize {
        match self.emb {
            EmbParam::Full => 0,
            EmbParam::LoRA { .. } => 2,
        }
    }

    /// Dense-param index (the [`ParamsView::mlp`] space, table excluded) of
    /// the classifier weight.
    pub fn head_w_index(&self) -> usize {
        self.dense_base() + LAYER_PARAMS * self.num_layers
    }

    /// Dense-param index of the classifier bias.
    pub fn head_b_index(&self) -> usize {
        self.head_w_index() + 1
    }

    /// Shapes of the trainable dense-grad outputs, in grads-artifact output
    /// order: `emb_lora_b` first in LoRA mode, then `head_w`, `head_b`.
    pub fn dense_grad_shapes(&self) -> Vec<Vec<usize>> {
        let mut shapes = Vec::with_capacity(3);
        if let EmbParam::LoRA { rank } = self.emb {
            shapes.push(vec![rank, self.d_model]);
        }
        shapes.push(vec![self.d_model, self.num_classes]);
        shapes.push(vec![self.num_classes]);
        shapes
    }
}

// ---------------------------------------------------------------------------
// Row-wise primitives the kernel subsystem does not cover (LayerNorm and the
// Gram-identity clip norm).  All matmuls — attention QKV/scores/context/
// projection, the GELU MLP, the LoRA factors, the classifier head — run on
// the blocked kernels of `crate::kernels`, bit-identical to the scalar
// loops they retired (the k-accumulation order is preserved; see the
// kernels module docs and `tests/kernels.rs`).
// ---------------------------------------------------------------------------

/// Per-row normalization state saved by the forward pass for the backward.
struct LnCache {
    /// normalized rows `(u - μ)/σ`, same shape as the input
    xhat: Vec<f32>,
    /// `1/σ` per row
    inv_std: Vec<f32>,
}

impl LnCache {
    fn zeros(t: usize, d: usize) -> LnCache {
        LnCache { xhat: vec![0f32; t * d], inv_std: vec![0f32; t] }
    }
}

/// Row-wise LayerNorm: `out = xhat * g + b`, caching `(xhat, 1/σ)`.
fn layer_norm_fwd(u: &[f32], g: &[f32], b: &[f32], cache: &mut LnCache, out: &mut [f32]) {
    let d = g.len();
    let t = u.len() / d;
    let inv_d = 1.0 / d as f32;
    for r in 0..t {
        let urow = &u[r * d..(r + 1) * d];
        let mut mu = 0f32;
        for &uv in urow {
            mu += uv;
        }
        mu *= inv_d;
        let mut var = 0f32;
        for &uv in urow {
            let c = uv - mu;
            var += c * c;
        }
        var *= inv_d;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        cache.inv_std[r] = inv;
        let xh = &mut cache.xhat[r * d..(r + 1) * d];
        let orow = &mut out[r * d..(r + 1) * d];
        for i in 0..d {
            let xv = (urow[i] - mu) * inv;
            xh[i] = xv;
            orow[i] = xv * g[i] + b[i];
        }
    }
}

/// LayerNorm backward: `du += (dŷ − mean(dŷ) − x̂·mean(dŷ∘x̂)) / σ` with
/// `dŷ = dy ∘ g`.
fn layer_norm_bwd(dy: &[f32], g: &[f32], cache: &LnCache, du: &mut [f32]) {
    let d = g.len();
    let t = dy.len() / d;
    let inv_d = 1.0 / d as f32;
    let mut dxh = vec![0f32; d];
    for r in 0..t {
        let dyr = &dy[r * d..(r + 1) * d];
        let xh = &cache.xhat[r * d..(r + 1) * d];
        let mut m1 = 0f32;
        let mut m2 = 0f32;
        for i in 0..d {
            let v = dyr[i] * g[i];
            dxh[i] = v;
            m1 += v;
            m2 += v * xh[i];
        }
        m1 *= inv_d;
        m2 *= inv_d;
        let inv = cache.inv_std[r];
        let dur = &mut du[r * d..(r + 1) * d];
        for i in 0..d {
            dur[i] += (dxh[i] - m1 - xh[i] * m2) * inv;
        }
    }
}

/// Accumulate onto `sq` the squared norm of the scatter-add of per-slot
/// rows (width `w`) into their token rows: `Σ_{p,s: id_p = id_s}
/// ⟨row_p, row_s⟩` — the pairwise Gram identity (`kernels/ref.py`), in
/// fixed `(p, s)` order for bit-determinism.
fn add_scattered_sqnorm(sq: &mut f32, ids: &[i32], rows: &[f32], w: usize) {
    let t = ids.len();
    for p in 0..t {
        let rp = &rows[p * w..(p + 1) * w];
        for s in 0..t {
            if ids[p] == ids[s] {
                let rs = &rows[s * w..(s + 1) * w];
                let mut dot = 0f32;
                for (&av, &bv) in rp.iter().zip(rs) {
                    dot += av * bv;
                }
                *sq += dot;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Forward (with activation caches) and backward
// ---------------------------------------------------------------------------

/// Saved activations of one encoder block, per example.
struct LayerCache {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// softmax attention probabilities, `(heads, T, T)`
    att: Vec<f32>,
    ln1: LnCache,
    ln2: LnCache,
    /// pre-GELU MLP activations `(T, ff)`
    a: Vec<f32>,
}

/// One example's forward state.
struct Encoded {
    layers: Vec<LayerCache>,
    pooled: Vec<f32>,
    logits: Vec<f32>,
    /// LoRA mode: the gathered `A[id]` rows, `(T, r)` row-major (empty when
    /// the full table trains) — the backward needs them for `∂L/∂B`
    aout: Vec<f32>,
}

impl NluModel {
    /// Forward one example from its token ids, caching what the backward
    /// pass needs.
    fn encode<V: ParamsView + ?Sized>(&self, view: &V, ids: &[i32]) -> Encoded {
        let (t, d, ff) = (self.seq_len, self.d_model, self.ff_dim);
        let (h, dh) = (self.num_heads, self.head_dim());
        let scale = 1.0 / (dh as f32).sqrt();

        // z = E[id] (full) or E[id] + A[id]·B (LoRA; A rows are cached for
        // the backward's ∂L/∂B).
        let mut x = vec![0f32; t * d];
        let mut aout = Vec::new();
        match self.emb {
            EmbParam::Full => {
                for (p, &id) in ids.iter().enumerate() {
                    view.emb_row(0, id as usize, &mut x[p * d..(p + 1) * d]);
                }
            }
            EmbParam::LoRA { rank } => {
                let table = view.mlp(M_EMB_TABLE);
                let bmat = view.mlp(M_LORA_B);
                aout = vec![0f32; t * rank];
                for (p, &id) in ids.iter().enumerate() {
                    let row = id as usize;
                    let ar = &mut aout[p * rank..(p + 1) * rank];
                    view.emb_row(0, row, ar);
                    // z_p = E[id_p] + A[id_p]·B: a 1×d matmul whose chain
                    // starts at the frozen table row (Bias init)
                    kernels::matmul(
                        ar,
                        bmat,
                        &mut x[p * d..(p + 1) * d],
                        MatShape::packed(1, rank, d),
                        MatInit::Bias(&table[row * d..(row + 1) * d]),
                    );
                }
            }
        }
        for (xv, &pv) in x.iter_mut().zip(&self.posenc) {
            *xv += pv;
        }

        let mut layers = Vec::with_capacity(self.num_layers);
        for l in 0..self.num_layers {
            let base = self.dense_base() + l * LAYER_PARAMS;
            let mut q = vec![0f32; t * d];
            let mut k = vec![0f32; t * d];
            let mut v = vec![0f32; t * d];
            let aff = MatShape::packed(t, d, d);
            let bias = |p: usize| MatInit::Bias(view.mlp(base + p));
            kernels::matmul(&x, view.mlp(base + P_WQ), &mut q, aff, bias(P_WQ_B));
            kernels::matmul(&x, view.mlp(base + P_WK), &mut k, aff, bias(P_WK_B));
            kernels::matmul(&x, view.mlp(base + P_WV), &mut v, aff, bias(P_WV_B));

            // Per-head attention on column slices of the (t, d) activation
            // buffers: scores = (q_h · k_hᵀ)·scale through the softmax rows,
            // then ctx_h = att_h · v_h — pitch d, width dh, no packing.
            let mut att = vec![0f32; h * t * t];
            let mut ctx = vec![0f32; t * d];
            for head in 0..h {
                let off = head * dh;
                let att_h = &mut att[head * t * t..(head + 1) * t * t];
                kernels::matmul_bt(
                    &q[off..],
                    &k[off..],
                    att_h,
                    MatShape { m: t, k: dh, n: t, ra: d, rb: d, rc: t },
                    MatInit::Zero,
                );
                kernels::softmax_rows(att_h, t, t, t, scale);
                kernels::matmul(
                    att_h,
                    &v[off..],
                    &mut ctx[off..],
                    MatShape { m: t, k: t, n: dh, ra: t, rb: d, rc: d },
                    MatInit::Zero,
                );
            }

            // wo projection, residual, LN1 (u1 built in place over attn_out)
            let mut u1 = vec![0f32; t * d];
            kernels::matmul(&ctx, view.mlp(base + P_WO), &mut u1, aff, bias(P_WO_B));
            for (uv, &xv) in u1.iter_mut().zip(&x) {
                *uv += xv;
            }
            let mut ln1 = LnCache::zeros(t, d);
            let mut x1 = vec![0f32; t * d];
            layer_norm_fwd(
                &u1,
                view.mlp(base + P_LN1_G),
                view.mlp(base + P_LN1_B),
                &mut ln1,
                &mut x1,
            );

            // GELU MLP (bias + GELU fused into the first matmul's store —
            // `a` keeps the pre-activations for the backward), residual, LN2
            let mut a = vec![0f32; t * ff];
            let mut ga = vec![0f32; t * ff];
            kernels::add_bias_gelu(
                &x1,
                view.mlp(base + P_FF1),
                view.mlp(base + P_FF1_B),
                &mut a,
                &mut ga,
                MatShape::packed(t, d, ff),
            );
            let mut u2 = vec![0f32; t * d];
            kernels::matmul(
                &ga,
                view.mlp(base + P_FF2),
                &mut u2,
                MatShape::packed(t, ff, d),
                MatInit::Bias(view.mlp(base + P_FF2_B)),
            );
            for (uv, &xv) in u2.iter_mut().zip(&x1) {
                *uv += xv;
            }
            let mut ln2 = LnCache::zeros(t, d);
            let mut x2 = vec![0f32; t * d];
            layer_norm_fwd(
                &u2,
                view.mlp(base + P_LN2_G),
                view.mlp(base + P_LN2_B),
                &mut ln2,
                &mut x2,
            );

            layers.push(LayerCache { q, k, v, att, ln1, ln2, a });
            x = x2;
        }

        // mean pool + classifier head
        let mut pooled = vec![0f32; d];
        for row in x.chunks(d) {
            for (pv, &xv) in pooled.iter_mut().zip(row) {
                *pv += xv;
            }
        }
        let inv_t = 1.0 / t as f32;
        for pv in &mut pooled {
            *pv *= inv_t;
        }
        let c = self.num_classes;
        let mut logits = vec![0f32; c];
        kernels::matmul(
            &pooled,
            view.mlp(self.head_w_index()),
            &mut logits,
            MatShape::packed(1, d, c),
            MatInit::Bias(view.mlp(self.head_b_index())),
        );
        Encoded { layers, pooled, logits, aout }
    }

    /// Backward one example from `∂L/∂logits`: returns
    /// `(∂L/∂z (T,d), ∂L/∂head_w, ∂L/∂head_b)`, unclipped.
    fn backward<V: ParamsView + ?Sized>(
        &self,
        view: &V,
        enc: &Encoded,
        dlogits: &[f32],
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (t, d, ff) = (self.seq_len, self.d_model, self.ff_dim);
        let (h, dh) = (self.num_heads, self.head_dim());
        let scale = 1.0 / (dh as f32).sqrt();
        let c = self.num_classes;
        let hw = view.mlp(self.head_w_index());

        // head grads (∂L/∂head_w = pooled ⊗ dlogits) + pooled grad
        let mut dhw = vec![0f32; d * c];
        kernels::matmul_at(
            &enc.pooled,
            dlogits,
            &mut dhw,
            MatShape::packed_at(d, 1, c),
            MatInit::Zero,
        );
        let dhb = dlogits.to_vec();

        // mean pool broadcasts ∂L/∂pooled / T to every position
        let inv_t = 1.0 / t as f32;
        let mut dpooled = vec![0f32; d];
        kernels::matmul_bt(
            dlogits,
            hw,
            &mut dpooled,
            MatShape::packed_bt(1, c, d),
            MatInit::Zero,
        );
        for dp in &mut dpooled {
            *dp *= inv_t;
        }
        let mut dx = vec![0f32; t * d];
        for row in dx.chunks_mut(d) {
            row.copy_from_slice(&dpooled);
        }

        let mut datt = vec![0f32; t * t];
        for (l, cache) in enc.layers.iter().enumerate().rev() {
            let base = self.dense_base() + l * LAYER_PARAMS;
            let bp = MatShape::packed_bt(t, d, d); // dX += dY · Wᵀ, W (d×d)

            // LN2 → residual split (x1 branch + MLP branch)
            let mut du2 = vec![0f32; t * d];
            layer_norm_bwd(&dx, view.mlp(base + P_LN2_G), &cache.ln2, &mut du2);
            let mut dx1 = du2.clone();

            // MLP backward (frozen weights: input grads only)
            let mut da = vec![0f32; t * ff];
            kernels::matmul_bt(
                &du2,
                view.mlp(base + P_FF2),
                &mut da,
                MatShape::packed_bt(t, d, ff),
                MatInit::Accumulate,
            );
            for (dv, &av) in da.iter_mut().zip(&cache.a) {
                *dv *= gelu_prime(av);
            }
            kernels::matmul_bt(
                &da,
                view.mlp(base + P_FF1),
                &mut dx1,
                MatShape::packed_bt(t, ff, d),
                MatInit::Accumulate,
            );

            // LN1 → residual split (layer input + attention branch)
            let mut du1 = vec![0f32; t * d];
            layer_norm_bwd(&dx1, view.mlp(base + P_LN1_G), &cache.ln1, &mut du1);
            let mut dxin = du1.clone();

            // wo
            let mut dctx = vec![0f32; t * d];
            kernels::matmul_bt(&du1, view.mlp(base + P_WO), &mut dctx, bp, MatInit::Accumulate);

            // attention backward, head by head, on the same per-head column
            // slices as the forward:
            //   datt = dctx_h · v_hᵀ        dv_h = att_hᵀ · dctx_h
            //   ds   = softmax_bwd(att_h)   dq_h = ds · k_h,  dk_h = dsᵀ · q_h
            let mut dq = vec![0f32; t * d];
            let mut dk = vec![0f32; t * d];
            let mut dv = vec![0f32; t * d];
            for head in 0..h {
                let off = head * dh;
                let att_h = &cache.att[head * t * t..(head + 1) * t * t];
                let wide = MatShape { m: t, k: dh, n: t, ra: d, rb: d, rc: t };
                let thin = MatShape { m: t, k: t, n: dh, ra: t, rb: d, rc: d };
                kernels::matmul_bt(&dctx[off..], &cache.v[off..], &mut datt, wide, MatInit::Zero);
                kernels::matmul_at(att_h, &dctx[off..], &mut dv[off..], thin, MatInit::Zero);
                kernels::softmax_rows_bwd(att_h, &mut datt, t, t, t, t, scale);
                kernels::matmul(&datt, &cache.k[off..], &mut dq[off..], thin, MatInit::Zero);
                kernels::matmul_at(&datt, &cache.q[off..], &mut dk[off..], thin, MatInit::Zero);
            }
            kernels::matmul_bt(&dq, view.mlp(base + P_WQ), &mut dxin, bp, MatInit::Accumulate);
            kernels::matmul_bt(&dk, view.mlp(base + P_WK), &mut dxin, bp, MatInit::Accumulate);
            kernels::matmul_bt(&dv, view.mlp(base + P_WV), &mut dxin, bp, MatInit::Accumulate);
            dx = dxin;
        }
        // the position encoding is constant, so ∂L/∂z = ∂L/∂x₀
        (dx, dhw, dhb)
    }

    /// Per-example clipped gradients for examples `[lo, hi)` — the NLU arm
    /// of [`super::RefModel::grads_chunk`].
    pub fn grads_chunk<V: ParamsView + ?Sized>(
        &self,
        view: &V,
        batch: &BatchRef,
        lo: usize,
        hi: usize,
        c1: f32,
        c2: f32,
    ) -> ChunkGrads {
        let BatchRef::Text { ids, labels, .. } = *batch else {
            panic!("nlu grads_chunk on a non-text batch (dispatch bug)")
        };
        let (t, d) = (self.seq_len, self.d_model);
        let ew = self.emb_dim();
        let emb_cols = t * ew;
        let mut out = ChunkGrads {
            lo,
            hi,
            loss_sum: 0.0,
            dense_grads: self
                .dense_grad_shapes()
                .iter()
                .map(|s| vec![0f32; s.iter().product()])
                .collect(),
            zgrads: vec![0f32; (hi - lo) * emb_cols],
            counts: Vec::new(),
            scales: Vec::with_capacity(hi - lo),
        };
        let mut cmap: HashMap<u32, f32> = HashMap::with_capacity((hi - lo) * t);

        for i in lo..hi {
            let ids_i = &ids[i * t..(i + 1) * t];
            let label = labels[i] as usize;
            let enc = self.encode(view, ids_i);

            // cross-entropy + softmax backward
            let mut mx = f32::NEG_INFINITY;
            for &lv in &enc.logits {
                if lv > mx {
                    mx = lv;
                }
            }
            let mut denom = 0f32;
            for &lv in &enc.logits {
                denom += (lv - mx).exp();
            }
            let loss_i = mx + denom.ln() - enc.logits[label];
            let inv = 1.0 / denom;
            let mut dlogits: Vec<f32> =
                enc.logits.iter().map(|&lv| (lv - mx).exp() * inv).collect();
            dlogits[label] -= 1.0;

            let (dz, dhw, dhb) = self.backward(view, &enc, &dlogits);

            // ---- embedding-path gradients ----
            // `erows` are the per-slot rows scattered into the sparse table
            // (∂L/∂z for the full table; ∂L/∂A[id] = ∂L/∂z·Bᵀ for LoRA);
            // `db` is the LoRA-B factor gradient (empty in full mode).
            let (erows, db) = match self.emb {
                EmbParam::Full => (dz, Vec::new()),
                EmbParam::LoRA { rank } => {
                    // ∂L/∂A[id] = ∂L/∂z · Bᵀ (per-token rows), and the dense
                    // factor grad ∂L/∂B = Σ_p A[id_p]ᵀ · ∂L/∂z_p
                    let bmat = view.mlp(M_LORA_B);
                    let mut da = vec![0f32; t * rank];
                    kernels::matmul_bt(
                        &dz,
                        bmat,
                        &mut da,
                        MatShape::packed_bt(t, d, rank),
                        MatInit::Zero,
                    );
                    let mut db = vec![0f32; rank * d];
                    kernels::matmul_at(
                        &enc.aout,
                        &dz,
                        &mut db,
                        MatShape::packed_at(rank, t, d),
                        MatInit::Zero,
                    );
                    (da, db)
                }
            };

            // ---- clip factor over the full trainable set: dense grads
            // (head, plus LoRA-B) + scattered embedding rows.  Repeated
            // tokens add within a row, so the scattered squared norm uses
            // the pairwise Gram identity. ----
            let mut sq = 0f32;
            for &g in &dhw {
                sq += g * g;
            }
            for &g in &dhb {
                sq += g * g;
            }
            for &g in &db {
                sq += g * g;
            }
            add_scattered_sqnorm(&mut sq, ids_i, &erows, ew);
            let norm = sq.max(1e-24).sqrt();
            let s = (c2 / norm).min(1.0);

            // ---- accumulate clipped grads into the chunk partials ----
            // (dense order matches dense_grad_shapes: LoRA-B first when
            // present, then head_w, head_b)
            out.loss_sum += loss_i;
            if let EmbParam::LoRA { .. } = self.emb {
                for (acc, &g) in out.dense_grads[0].iter_mut().zip(&db) {
                    *acc += s * g;
                }
            }
            let hoff = out.dense_grads.len() - 2;
            for (acc, &g) in out.dense_grads[hoff].iter_mut().zip(&dhw) {
                *acc += s * g;
            }
            for (acc, &g) in out.dense_grads[hoff + 1].iter_mut().zip(&dhb) {
                *acc += s * g;
            }
            let zrow = &mut out.zgrads[(i - lo) * emb_cols..(i - lo + 1) * emb_cols];
            for (zo, &zv) in zrow.iter_mut().zip(&erows) {
                *zo = s * zv;
            }
            out.scales.push(s);

            // Contribution map: weight min(1, C1/√u) per distinct token,
            // u = distinct tokens in the example (Alg. 1 line 5; matches
            // model.py::_unique_token_weights summed per token).
            let mut uniq = 0usize;
            for p in 0..t {
                if ids_i[..p].iter().all(|&x| x != ids_i[p]) {
                    uniq += 1;
                }
            }
            let w = (c1 / (uniq.max(1) as f32).sqrt()).min(1.0);
            for p in 0..t {
                if ids_i[..p].iter().all(|&x| x != ids_i[p]) {
                    *cmap.entry(ids_i[p] as u32).or_insert(0.0) += w;
                }
            }
        }
        out.counts = cmap.into_iter().collect();
        out
    }

    /// Forward pass for examples `[lo, hi)`: per-example CE loss sum and
    /// flat `(hi-lo, num_classes)` logits.
    pub fn forward_chunk<V: ParamsView + ?Sized>(
        &self,
        view: &V,
        batch: &BatchRef,
        lo: usize,
        hi: usize,
    ) -> (f32, Vec<f32>) {
        let BatchRef::Text { ids, labels, .. } = *batch else {
            panic!("nlu forward_chunk on a non-text batch (dispatch bug)")
        };
        let t = self.seq_len;
        let mut loss_sum = 0f32;
        let mut logits_out = Vec::with_capacity((hi - lo) * self.num_classes);
        for i in lo..hi {
            let enc = self.encode(view, &ids[i * t..(i + 1) * t]);
            let mut mx = f32::NEG_INFINITY;
            for &lv in &enc.logits {
                if lv > mx {
                    mx = lv;
                }
            }
            let mut denom = 0f32;
            for &lv in &enc.logits {
                denom += (lv - mx).exp();
            }
            loss_sum += mx + denom.ln() - enc.logits[labels[i] as usize];
            logits_out.extend_from_slice(&enc.logits);
        }
        (loss_sum, logits_out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::{builtin_manifest, RefModel, ReferenceBackend};
    use crate::runtime::HostTensor;
    use crate::util::rng::Xoshiro256;

    /// Plain-vector [`ParamsView`] for gradient checks.
    struct VecView {
        table: Vec<f32>,
        d: usize,
        dense: Vec<Vec<f32>>,
    }

    impl ParamsView for VecView {
        fn emb_row(&self, _feature: usize, row: usize, out: &mut [f32]) {
            out.copy_from_slice(&self.table[row * self.d..(row + 1) * self.d]);
        }

        fn mlp(&self, index: usize) -> &[f32] {
            &self.dense[index]
        }
    }

    fn fd_model() -> NluModel {
        NluModel {
            vocab: 24,
            d_model: 8,
            num_heads: 2,
            ff_dim: 12,
            num_layers: 2,
            seq_len: 4,
            num_classes: 3,
            batch_size: 4,
            posenc: sinusoidal_posenc(4, 8),
            emb: EmbParam::Full,
        }
    }

    fn fd_lora_model(rank: usize) -> NluModel {
        NluModel { emb: EmbParam::LoRA { rank }, ..fd_model() }
    }

    fn rand_params(m: &NluModel, seed: u64) -> VecView {
        let mut rng = Xoshiro256::seed_from(seed);
        let d = m.d_model;
        let mut g = |n: usize, s: f32| -> Vec<f32> {
            (0..n).map(|_| rng.gauss() as f32 * s).collect()
        };
        // `table` is whatever occupies the sparse-table slot: the full
        // (V, d) table, or the (V, r) A factor in LoRA mode — with the
        // frozen table and a *nonzero* B leading the dense space (B = 0
        // would zero every A gradient and blind the gradcheck).
        let (table, mut dense): (Vec<f32>, Vec<Vec<f32>>) = match m.emb {
            EmbParam::Full => (g(m.vocab * d, 0.3), Vec::new()),
            EmbParam::LoRA { rank } => {
                let a = g(m.vocab * rank, 0.3);
                let e = g(m.vocab * d, 0.3);
                let b = g(rank * d, 0.4);
                (a, vec![e, b])
            }
        };
        let ws = (d as f32).powf(-0.5);
        for _l in 0..m.num_layers {
            for _nm in 0..4 {
                dense.push(g(d * d, ws));
                dense.push(g(d, 0.05));
            }
            dense.push(g(d, 0.1).iter().map(|v| 1.0 + v).collect()); // ln1_g
            dense.push(g(d, 0.05)); // ln1_b
            dense.push(g(d * m.ff_dim, ws)); // ff1
            dense.push(g(m.ff_dim, 0.05));
            dense.push(g(m.ff_dim * d, (m.ff_dim as f32).powf(-0.5))); // ff2
            dense.push(g(d, 0.05));
            dense.push(g(d, 0.1).iter().map(|v| 1.0 + v).collect()); // ln2_g
            dense.push(g(d, 0.05)); // ln2_b
        }
        dense.push(g(d * m.num_classes, 0.3)); // head_w
        dense.push(g(m.num_classes, 0.1)); // head_b
        VecView { table, d: m.emb_dim(), dense }
    }

    // Batch with deliberate within-example token repeats (token 5 twice in
    // example 0, token 9 twice in example 2, token 5 shared across 0 and 3).
    const FD_IDS: [i32; 16] = [5, 5, 7, 2, 0, 1, 2, 3, 9, 11, 9, 4, 20, 6, 3, 5];
    const FD_LABELS: [i32; 4] = [0, 2, 1, 0];

    // f32 central differences carry ~1e-4-scale roundoff through this deep
    // a network, so the in-tree bound is machine-precision-aware; the
    // strict <= 1e-4 relative gradcheck of the same formulas runs in f64 in
    // `python/tests/test_native_mirror.py` (observed errors ~1e-7).
    fn fd_check(got: f32, fd: f32, what: &str) {
        let tol = 0.05 * got.abs().max(fd.abs()) + 3e-3;
        assert!(
            (got - fd).abs() <= tol,
            "{what}: analytic {got} vs finite-difference {fd}"
        );
    }

    #[test]
    fn finite_difference_gradients_match() {
        let m = fd_model();
        let mut view = rand_params(&m, 1);
        let (b, t, d) = (4usize, m.seq_len, m.d_model);
        let batch = BatchRef::Text { seq_len: t, ids: &FD_IDS, labels: &FD_LABELS };
        let g = m.grads_chunk(&view, &batch, 0, b, 1e9, 1e9);
        assert!(g.scales.iter().all(|&s| s == 1.0), "huge C2 must not clip");
        let eps = 1e-2f32;

        // classifier head, bias and a spread of weight coordinates
        let hb = m.head_b_index();
        for c in 0..m.num_classes {
            let orig = view.dense[hb][c];
            view.dense[hb][c] = orig + eps;
            let lp = m.forward_chunk(&view, &batch, 0, b).0;
            view.dense[hb][c] = orig - eps;
            let lm = m.forward_chunk(&view, &batch, 0, b).0;
            view.dense[hb][c] = orig;
            fd_check(g.dense_grads[1][c], (lp - lm) / (2.0 * eps), &format!("head_b[{c}]"));
        }
        let hw = m.head_w_index();
        for &idx in &[0usize, 5, 10, 17, 23] {
            let orig = view.dense[hw][idx];
            view.dense[hw][idx] = orig + eps;
            let lp = m.forward_chunk(&view, &batch, 0, b).0;
            view.dense[hw][idx] = orig - eps;
            let lm = m.forward_chunk(&view, &batch, 0, b).0;
            view.dense[hw][idx] = orig;
            fd_check(g.dense_grads[0][idx], (lp - lm) / (2.0 * eps), &format!("head_w[{idx}]"));
        }

        // embedding rows: the table gradient is the scatter-add of the
        // per-position zgrads over token ids (repeats included)
        for &(row, coord) in &[(5usize, 0usize), (5, 3), (7, 2), (2, 1), (9, 5), (20, 7)] {
            let mut analytic = 0f32;
            for (slot, &id) in FD_IDS.iter().enumerate() {
                if id as usize == row {
                    analytic += g.zgrads[slot * d + coord];
                }
            }
            let orig = view.table[row * d + coord];
            view.table[row * d + coord] = orig + eps;
            let lp = m.forward_chunk(&view, &batch, 0, b).0;
            view.table[row * d + coord] = orig - eps;
            let lm = m.forward_chunk(&view, &batch, 0, b).0;
            view.table[row * d + coord] = orig;
            fd_check(analytic, (lp - lm) / (2.0 * eps), &format!("emb[{row},{coord}]"));
        }

        // a row no example touches does not affect the loss at all
        let base = m.forward_chunk(&view, &batch, 0, b).0;
        view.table[23 * d] += 0.5;
        assert_eq!(base, m.forward_chunk(&view, &batch, 0, b).0);
    }

    /// Geometry deliberately off the kernel register tile (MR=4, NR=8):
    /// seq_len 5, d_model 12, ff_dim 9 — every blocked matmul runs edge
    /// tiles, which must carry the same exact k-chains as the full ones.
    fn fd_offtile_model() -> NluModel {
        NluModel {
            vocab: 24,
            d_model: 12,
            num_heads: 2,
            ff_dim: 9,
            num_layers: 2,
            seq_len: 5,
            num_classes: 3,
            batch_size: 2,
            posenc: sinusoidal_posenc(5, 12),
            emb: EmbParam::Full,
        }
    }

    // Off-tile batch: token 3 repeated within example 0, token 1 within
    // example 1, tokens 3/1 shared across examples.
    const FD_IDS_OFFTILE: [i32; 10] = [3, 3, 7, 1, 9, 2, 8, 3, 1, 1];
    const FD_LABELS_OFFTILE: [i32; 2] = [1, 0];

    #[test]
    fn finite_difference_gradients_match_off_tile_shapes() {
        // the FD protocol of `finite_difference_gradients_match`, re-run at
        // a seq_len/d_model/ff pair that is NOT a multiple of the kernel
        // block size, for both embedding parametrizations
        for rank in [0usize, 3] {
            let m = match rank {
                0 => fd_offtile_model(),
                r => NluModel { emb: EmbParam::LoRA { rank: r }, ..fd_offtile_model() },
            };
            let mut view = rand_params(&m, 21 + rank as u64);
            let b = 2usize;
            let batch = BatchRef::Text {
                seq_len: m.seq_len,
                ids: &FD_IDS_OFFTILE,
                labels: &FD_LABELS_OFFTILE,
            };
            let g = m.grads_chunk(&view, &batch, 0, b, 1e9, 1e9);
            assert!(g.scales.iter().all(|&s| s == 1.0), "huge C2 must not clip");
            let eps = 1e-2f32;
            let hoff = g.dense_grads.len() - 2;

            // classifier head
            let hb = m.head_b_index();
            for c in 0..m.num_classes {
                let orig = view.dense[hb][c];
                view.dense[hb][c] = orig + eps;
                let lp = m.forward_chunk(&view, &batch, 0, b).0;
                view.dense[hb][c] = orig - eps;
                let lm = m.forward_chunk(&view, &batch, 0, b).0;
                view.dense[hb][c] = orig;
                fd_check(
                    g.dense_grads[hoff + 1][c],
                    (lp - lm) / (2.0 * eps),
                    &format!("offtile r{rank} head_b[{c}]"),
                );
            }
            let hw = m.head_w_index();
            for &idx in &[0usize, 7, 20, 35] {
                let orig = view.dense[hw][idx];
                view.dense[hw][idx] = orig + eps;
                let lp = m.forward_chunk(&view, &batch, 0, b).0;
                view.dense[hw][idx] = orig - eps;
                let lm = m.forward_chunk(&view, &batch, 0, b).0;
                view.dense[hw][idx] = orig;
                fd_check(
                    g.dense_grads[hoff][idx],
                    (lp - lm) / (2.0 * eps),
                    &format!("offtile r{rank} head_w[{idx}]"),
                );
            }

            // the dense LoRA-B factor, when present
            if rank > 0 {
                for &idx in &[0usize, 17, 35] {
                    let orig = view.dense[1][idx];
                    view.dense[1][idx] = orig + eps;
                    let lp = m.forward_chunk(&view, &batch, 0, b).0;
                    view.dense[1][idx] = orig - eps;
                    let lm = m.forward_chunk(&view, &batch, 0, b).0;
                    view.dense[1][idx] = orig;
                    fd_check(
                        g.dense_grads[0][idx],
                        (lp - lm) / (2.0 * eps),
                        &format!("offtile emb_lora_b[{idx}]"),
                    );
                }
            }

            // embedding / adapter rows via the zgrads scatter (repeats
            // included); coords chosen inside the edge tiles
            let w = m.emb_dim();
            let coords: &[(usize, usize)] = if rank == 0 {
                &[(3, 0), (3, 11), (7, 8), (1, 5), (9, 2), (8, 10)]
            } else {
                &[(3, 0), (3, 2), (7, 1), (1, 0), (9, 2), (8, 1)]
            };
            for &(row, coord) in coords {
                let mut analytic = 0f32;
                for (slot, &id) in FD_IDS_OFFTILE.iter().enumerate() {
                    if id as usize == row {
                        analytic += g.zgrads[slot * w + coord];
                    }
                }
                let orig = view.table[row * w + coord];
                view.table[row * w + coord] = orig + eps;
                let lp = m.forward_chunk(&view, &batch, 0, b).0;
                view.table[row * w + coord] = orig - eps;
                let lm = m.forward_chunk(&view, &batch, 0, b).0;
                view.table[row * w + coord] = orig;
                fd_check(
                    analytic,
                    (lp - lm) / (2.0 * eps),
                    &format!("offtile r{rank} emb[{row},{coord}]"),
                );
            }

            // an untouched row stays bit-inert
            let base = m.forward_chunk(&view, &batch, 0, b).0;
            view.table[23 * w] += 0.5;
            assert_eq!(base, m.forward_chunk(&view, &batch, 0, b).0);
            view.table[23 * w] -= 0.5;
        }
    }

    #[test]
    fn finite_difference_gradients_match_lora() {
        // Same FD protocol as the full-table check, but through the LoRA
        // reparametrization z = E[id] + A[id]·B: per-token A rows via the
        // grads scatter (repeats included), the dense B factor, the head.
        let rank = 3usize;
        let m = fd_lora_model(rank);
        let mut view = rand_params(&m, 6);
        let b = 4usize;
        let batch = BatchRef::Text { seq_len: m.seq_len, ids: &FD_IDS, labels: &FD_LABELS };
        let g = m.grads_chunk(&view, &batch, 0, b, 1e9, 1e9);
        assert!(g.scales.iter().all(|&s| s == 1.0), "huge C2 must not clip");
        assert_eq!(g.dense_grads.len(), 3, "lora-B + head_w + head_b");
        let eps = 1e-2f32;

        // classifier head (dense_grads[1] = head_w, [2] = head_b)
        let hb = m.head_b_index();
        for c in 0..m.num_classes {
            let orig = view.dense[hb][c];
            view.dense[hb][c] = orig + eps;
            let lp = m.forward_chunk(&view, &batch, 0, b).0;
            view.dense[hb][c] = orig - eps;
            let lm = m.forward_chunk(&view, &batch, 0, b).0;
            view.dense[hb][c] = orig;
            fd_check(g.dense_grads[2][c], (lp - lm) / (2.0 * eps), &format!("head_b[{c}]"));
        }
        let hw = m.head_w_index();
        for &idx in &[0usize, 7, 13, 23] {
            let orig = view.dense[hw][idx];
            view.dense[hw][idx] = orig + eps;
            let lp = m.forward_chunk(&view, &batch, 0, b).0;
            view.dense[hw][idx] = orig - eps;
            let lm = m.forward_chunk(&view, &batch, 0, b).0;
            view.dense[hw][idx] = orig;
            fd_check(g.dense_grads[1][idx], (lp - lm) / (2.0 * eps), &format!("head_w[{idx}]"));
        }

        // the dense B factor (dense_grads[0], (r, d) coords)
        for &idx in &[0usize, 5, 11, 17, 23] {
            let orig = view.dense[1][idx];
            view.dense[1][idx] = orig + eps;
            let lp = m.forward_chunk(&view, &batch, 0, b).0;
            view.dense[1][idx] = orig - eps;
            let lm = m.forward_chunk(&view, &batch, 0, b).0;
            view.dense[1][idx] = orig;
            fd_check(
                g.dense_grads[0][idx],
                (lp - lm) / (2.0 * eps),
                &format!("emb_lora_b[{idx}]"),
            );
        }

        // A rows: the factor gradient is the scatter-add of the per-slot
        // rows over token ids (repeats included)
        for &(row, coord) in &[(5usize, 0usize), (5, 2), (7, 1), (2, 0), (9, 2), (20, 1)] {
            let mut analytic = 0f32;
            for (slot, &id) in FD_IDS.iter().enumerate() {
                if id as usize == row {
                    analytic += g.zgrads[slot * rank + coord];
                }
            }
            let orig = view.table[row * rank + coord];
            view.table[row * rank + coord] = orig + eps;
            let lp = m.forward_chunk(&view, &batch, 0, b).0;
            view.table[row * rank + coord] = orig - eps;
            let lm = m.forward_chunk(&view, &batch, 0, b).0;
            view.table[row * rank + coord] = orig;
            fd_check(analytic, (lp - lm) / (2.0 * eps), &format!("emb_lora_a[{row},{coord}]"));
        }

        // an A row no example touches does not affect the loss at all
        let base = m.forward_chunk(&view, &batch, 0, b).0;
        view.table[23 * rank] += 0.5;
        assert_eq!(base, m.forward_chunk(&view, &batch, 0, b).0);
    }

    #[test]
    fn clip_identity_and_counts_invariant_under_token_permutation() {
        // Permuting an example's tokens moves them to different positions
        // (the gradients themselves change with the position encoding), but
        // two things must hold in every arrangement: the Gram-identity clip
        // factor matches an independent dense scatter-add of the per-slot
        // rows (clipped norm exactly C2), and the contribution map — a
        // function of the distinct-token set only — is unchanged.
        let arrangements: [[i32; 4]; 4] =
            [[5, 5, 7, 2], [5, 7, 5, 2], [2, 7, 5, 5], [7, 5, 2, 5]];
        for m in [fd_model(), fd_lora_model(3)] {
            let view = rand_params(&m, 8);
            let w = m.emb_dim();
            let c2 = 1e-3f32;
            let mut ref_counts: Option<Vec<(u32, f32)>> = None;
            for ids in &arrangements {
                let batch = BatchRef::Text { seq_len: 4, ids: &ids[..], labels: &[0] };
                let g = m.grads_chunk(&view, &batch, 0, 1, 1.0, c2);
                assert!(g.scales[0] < 1.0, "C2 = {c2} must clip ({:?})", m.emb);
                // dense scatter-add of the scaled rows by token id
                let mut rows: HashMap<i32, Vec<f32>> = HashMap::new();
                for (p, &id) in ids.iter().enumerate() {
                    let acc = rows.entry(id).or_insert_with(|| vec![0f32; w]);
                    for (av, &zv) in acc.iter_mut().zip(&g.zgrads[p * w..(p + 1) * w]) {
                        *av += zv;
                    }
                }
                let mut sq: f64 = rows
                    .values()
                    .flat_map(|r| r.iter())
                    .map(|&v| v as f64 * v as f64)
                    .sum();
                for buf in &g.dense_grads {
                    sq += buf.iter().map(|&v| v as f64 * v as f64).sum::<f64>();
                }
                assert!(
                    (sq.sqrt() - c2 as f64).abs() < 1e-6,
                    "clipped norm {} != C2 {c2} for ids {ids:?} ({:?})",
                    sq.sqrt(),
                    m.emb
                );
                // same distinct-token set ⇒ identical contribution map
                let mut counts = g.counts.clone();
                counts.sort_unstable_by_key(|&(k, _)| k);
                match &ref_counts {
                    None => ref_counts = Some(counts),
                    Some(want) => assert_eq!(&counts, want, "ids {ids:?} ({:?})", m.emb),
                }
            }
        }
    }

    #[test]
    fn lora_per_example_clip_caps_total_norm() {
        let m = fd_lora_model(3);
        let view = rand_params(&m, 2);
        let (t, w) = (m.seq_len, m.emb_dim());
        let batch = BatchRef::Text { seq_len: t, ids: &FD_IDS, labels: &FD_LABELS };
        let c2 = 0.05f32;
        let mut clipped = 0;
        for i in 0..4 {
            let g = m.grads_chunk(&view, &batch, i, i + 1, 1.0, c2);
            if g.scales[0] >= 1.0 {
                continue;
            }
            clipped += 1;
            // the clipped per-example norm (B + head + scattered A rows)
            // is exactly C2
            let mut sq = 0f64;
            for buf in &g.dense_grads {
                sq += buf.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
            }
            let ids_i = &FD_IDS[i * t..(i + 1) * t];
            for p in 0..t {
                for s in 0..t {
                    if ids_i[p] == ids_i[s] {
                        let rp = &g.zgrads[p * w..(p + 1) * w];
                        let rs = &g.zgrads[s * w..(s + 1) * w];
                        sq += rp
                            .iter()
                            .zip(rs)
                            .map(|(&av, &bv)| av as f64 * bv as f64)
                            .sum::<f64>();
                    }
                }
            }
            let norm = sq.sqrt();
            assert!(
                (norm - c2 as f64).abs() < 1e-4,
                "example {i}: clipped norm {norm} != C2 {c2}"
            );
        }
        assert!(clipped > 0, "no example clipped at C2 = {c2}");
    }

    #[test]
    fn per_example_clip_caps_total_norm() {
        let m = fd_model();
        let view = rand_params(&m, 2);
        let (t, d) = (m.seq_len, m.d_model);
        let batch = BatchRef::Text { seq_len: t, ids: &FD_IDS, labels: &FD_LABELS };
        let c2 = 0.05f32;
        let mut clipped = 0;
        for i in 0..4 {
            let g = m.grads_chunk(&view, &batch, i, i + 1, 1.0, c2);
            if g.scales[0] >= 1.0 {
                continue;
            }
            clipped += 1;
            // the clipped per-example norm (dense + scattered rows) is
            // exactly C2
            let mut sq = 0f64;
            for buf in &g.dense_grads {
                sq += buf.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
            }
            let ids_i = &FD_IDS[i * t..(i + 1) * t];
            for p in 0..t {
                for s in 0..t {
                    if ids_i[p] == ids_i[s] {
                        let rp = &g.zgrads[p * d..(p + 1) * d];
                        let rs = &g.zgrads[s * d..(s + 1) * d];
                        sq += rp
                            .iter()
                            .zip(rs)
                            .map(|(&av, &bv)| av as f64 * bv as f64)
                            .sum::<f64>();
                    }
                }
            }
            let norm = sq.sqrt();
            assert!(
                (norm - c2 as f64).abs() < 1e-4,
                "example {i}: clipped norm {norm} != C2 {c2}"
            );
        }
        assert!(clipped > 0, "no example clipped at C2 = {c2}");
    }

    #[test]
    fn fwd_and_grads_agree_on_loss() {
        let m = fd_model();
        let view = rand_params(&m, 3);
        let batch =
            BatchRef::Text { seq_len: m.seq_len, ids: &FD_IDS, labels: &FD_LABELS };
        let (fwd_loss, logits) = m.forward_chunk(&view, &batch, 0, 4);
        assert_eq!(logits.len(), 4 * m.num_classes);
        let g = m.grads_chunk(&view, &batch, 0, 4, 1e9, 1e9);
        assert_eq!(fwd_loss, g.loss_sum, "fwd and grads losses must be bit-equal");
    }

    #[test]
    fn contribution_map_uses_distinct_tokens() {
        let m = fd_model();
        let view = rand_params(&m, 4);
        // example 0 repeats token 5: u = 3 distinct tokens {5, 7, 2}
        let g = m.grads_chunk(
            &view,
            &BatchRef::Text { seq_len: m.seq_len, ids: &FD_IDS, labels: &FD_LABELS },
            0,
            1,
            1e9,
            1e9,
        );
        let counts: std::collections::HashMap<u32, f32> =
            g.counts.iter().copied().collect();
        let w = (1e9f32 / 3f32.sqrt()).min(1.0); // = 1.0
        assert_eq!(counts.len(), 3);
        assert_eq!(counts[&5], w, "repeated token counted once");
        assert_eq!(counts[&7], w);
        assert_eq!(counts[&2], w);
    }

    #[test]
    fn builtin_nlu_executes_deterministically_and_points_downhill() {
        use crate::models::ParamStore;
        let man = builtin_manifest();
        let model = man.model("nlu-tiny").unwrap();
        let rm = RefModel::from_manifest(model).unwrap();
        let (np, b) = (rm.num_params(), rm.batch_size());
        let RefModel::Nlu(nm) = &rm else { panic!("nlu-tiny is nlu") };
        let (t, d, vocab) = (nm.seq_len, nm.d_model, nm.vocab);
        let store = ParamStore::init(model, 11).unwrap();
        let mut rng = Xoshiro256::seed_from(5);
        let ids: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32).collect();
        let labels: Vec<i32> = (0..b).map(|_| rng.below(2) as i32).collect();
        let mut inputs = store.tensors();
        inputs.push(HostTensor::i32(vec![b, t], ids.clone()));
        inputs.push(HostTensor::i32(vec![b], labels));

        let backend = ReferenceBackend::default();
        let art_f = man.artifact("nlu_tiny_fwd").unwrap();
        let loss0 = backend.execute(&man, art_f, &inputs).unwrap()[0].scalar().unwrap();
        assert!(loss0.is_finite() && loss0 > 0.0);

        let mut ginputs = inputs.clone();
        ginputs.push(HostTensor::f32(vec![1], vec![1e9]));
        ginputs.push(HostTensor::f32(vec![1], vec![1e9]));
        let art_g = man.artifact("nlu_tiny_grads").unwrap();
        let g1 = backend.execute(&man, art_g, &ginputs).unwrap();
        let g2 = backend.execute(&man, art_g, &ginputs).unwrap();
        assert_eq!(g1, g2, "reference NLU execution must be deterministic");
        assert_eq!(g1[0].scalar().unwrap(), loss0, "grads loss == fwd loss");

        // one SGD step on the trainable params (head via dense grads,
        // table via the zgrads scatter) must reduce the loss
        let lr = 0.1f32 / b as f32;
        let mut stepped = inputs;
        for (out_i, param_i) in [(1, np - 2), (2, np - 1)] {
            let gbuf = g1[out_i].as_f32().unwrap().to_vec();
            let p = stepped[param_i].as_f32_mut().unwrap();
            for (pv, &gv) in p.iter_mut().zip(&gbuf) {
                *pv -= lr * gv;
            }
        }
        let zg = g1[3].as_f32().unwrap().to_vec();
        let table = stepped[0].as_f32_mut().unwrap();
        for (slot, &id) in ids.iter().enumerate() {
            let row = id as usize;
            for k in 0..d {
                table[row * d + k] -= lr * zg[slot * d + k];
            }
        }
        let loss1 = backend.execute(&man, art_f, &stepped).unwrap()[0].scalar().unwrap();
        assert!(loss1 < loss0, "loss did not decrease: {loss0} -> {loss1}");
    }

    #[test]
    fn posenc_matches_reference_form() {
        let pe = sinusoidal_posenc(4, 6);
        assert_eq!(pe.len(), 24);
        // position 0: sin(0)=0 on even dims, cos(0)=1 on odd dims
        for i in 0..6 {
            let want = if i % 2 == 0 { 0.0 } else { 1.0 };
            assert!((pe[i] - want).abs() < 1e-6);
        }
        // values bounded and non-degenerate
        assert!(pe.iter().all(|v| v.abs() <= 1.0 + 1e-6));
        assert!(pe[6..].iter().any(|&v| v != 0.0 && v != 1.0));
    }

    #[test]
    fn builtin_lora_executes_deterministically_and_points_downhill() {
        use crate::models::ParamStore;
        let man = builtin_manifest();
        let model = man.model("nlu-tiny-lora4").unwrap();
        let rm = RefModel::from_manifest(model).unwrap();
        let RefModel::Nlu(nm) = &rm else { panic!("nlu-tiny-lora4 is nlu") };
        assert_eq!(nm.emb, EmbParam::LoRA { rank: 4 });
        let (np, b) = (rm.num_params(), rm.batch_size());
        let (t, r, vocab) = (nm.seq_len, nm.emb_dim(), nm.vocab);
        let store = ParamStore::init(model, 11).unwrap();
        let mut rng = Xoshiro256::seed_from(5);
        let ids: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32).collect();
        let labels: Vec<i32> = (0..b).map(|_| rng.below(2) as i32).collect();
        let mut inputs = store.tensors();
        inputs.push(HostTensor::i32(vec![b, t], ids.clone()));
        inputs.push(HostTensor::i32(vec![b], labels));

        let backend = ReferenceBackend::default();
        let art_f = man.artifact("nlu_tiny_lora4_fwd").unwrap();
        let loss0 = backend.execute(&man, art_f, &inputs).unwrap()[0].scalar().unwrap();
        assert!(loss0.is_finite() && loss0 > 0.0);

        let mut ginputs = inputs.clone();
        ginputs.push(HostTensor::f32(vec![1], vec![1e9]));
        ginputs.push(HostTensor::f32(vec![1], vec![1e9]));
        let art_g = man.artifact("nlu_tiny_lora4_grads").unwrap();
        let g1 = backend.execute(&man, art_g, &ginputs).unwrap();
        let g2 = backend.execute(&man, art_g, &ginputs).unwrap();
        assert_eq!(g1, g2, "reference LoRA execution must be deterministic");
        assert_eq!(g1[0].scalar().unwrap(), loss0, "grads loss == fwd loss");

        // one SGD step on the trainable set: B (output 1 → param 2), head
        // (outputs 2, 3 → the last two params), and the A rows via the
        // aout_grads_scaled scatter.  B starts at zero (adapters begin as
        // identity), so the step must reduce the loss through B + head.
        let lr = 0.1f32 / b as f32;
        let mut stepped = inputs;
        for (out_i, param_i) in [(1, 2), (2, np - 2), (3, np - 1)] {
            let gbuf = g1[out_i].as_f32().unwrap().to_vec();
            let p = stepped[param_i].as_f32_mut().unwrap();
            for (pv, &gv) in p.iter_mut().zip(&gbuf) {
                *pv -= lr * gv;
            }
        }
        let zg = g1[4].as_f32().unwrap().to_vec();
        let table = stepped[0].as_f32_mut().unwrap();
        for (slot, &id) in ids.iter().enumerate() {
            let row = id as usize;
            for k in 0..r {
                table[row * r + k] -= lr * zg[slot * r + k];
            }
        }
        let loss1 = backend.execute(&man, art_f, &stepped).unwrap()[0].scalar().unwrap();
        assert!(loss1 < loss0, "loss did not decrease: {loss0} -> {loss1}");
    }

    #[test]
    fn from_manifest_rejects_mismatched_inventories() {
        let man = builtin_manifest();
        let mut model = man.model("nlu-tiny").unwrap().clone();
        model.params[1].name = "l0_lora_aq".to_string();
        assert!(NluModel::from_manifest(&model).is_err());
        // emb_lora_rank without the adapter params: the native layout for
        // that attr wants emb_lora_a/emb_table/emb_lora_b leading
        let mut model = man.model("nlu-tiny").unwrap().clone();
        model.attrs.insert("emb_lora_rank".into(), "8".into());
        assert!(NluModel::from_manifest(&model).is_err());
        // attention-LoRA adapters are rejected with the attr named
        let mut model = man.model("nlu-tiny").unwrap().clone();
        model.attrs.insert("lora_rank".into(), "16".into());
        let err = NluModel::from_manifest(&model).unwrap_err().to_string();
        assert!(err.contains("lora_rank"), "error must name the attr: {err}");
        // the built-in LoRA inventories parse
        assert!(NluModel::from_manifest(man.model("nlu-tiny-lora16").unwrap()).is_ok());
    }
}
