//! Host-side tensors and (with `--features xla`) conversions to/from
//! `xla::Literal`.
//!
//! The coordinator works in plain `Vec<f32>` / `Vec<i32>` row-major buffers;
//! literals are created only at the PJRT boundary.

use anyhow::{bail, Result};
#[cfg(feature = "xla")]
use anyhow::Context;

/// Dense row-major host tensor (f32 or i32 — the only dtypes the artifacts
/// use; scalars are rank-0).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    /// an f32 tensor
    F32 {
        /// dimensions (empty = rank-0 scalar)
        dims: Vec<usize>,
        /// row-major values
        data: Vec<f32>,
    },
    /// an i32 tensor
    I32 {
        /// dimensions (empty = rank-0 scalar)
        dims: Vec<usize>,
        /// row-major values
        data: Vec<i32>,
    },
}

impl HostTensor {
    /// Build an f32 tensor (panics on shape/data mismatch).
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { dims, data }
    }

    /// Build an i32 tensor (panics on shape/data mismatch).
    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { dims, data }
    }

    /// A rank-0 f32 scalar.
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { dims: vec![], data: vec![v] }
    }

    /// An all-zeros f32 tensor of the given shape.
    pub fn zeros_f32(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        HostTensor::F32 { dims, data: vec![0.0; n] }
    }

    /// The tensor's dimensions.
    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32 { dims, .. } | HostTensor::I32 { dims, .. } => dims,
        }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `"f32"` or `"i32"` — the manifest's dtype vocabulary.
    pub fn dtype_str(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "f32",
            HostTensor::I32 { .. } => "i32",
        }
    }

    /// Borrow the values as f32 (errors on an i32 tensor).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor, got i32"),
        }
    }

    /// Borrow the values mutably as f32 (errors on an i32 tensor).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor, got i32"),
        }
    }

    /// Borrow the values as i32 (errors on an f32 tensor).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor, got f32"),
        }
    }

    /// Take the f32 values out (errors on an i32 tensor).
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor, got i32"),
        }
    }

    /// Scalar extraction (rank-0 or single-element).
    pub fn scalar(&self) -> Result<f64> {
        match self {
            HostTensor::F32 { data, .. } if data.len() == 1 => Ok(data[0] as f64),
            HostTensor::I32 { data, .. } if data.len() == 1 => Ok(data[0] as f64),
            _ => bail!("tensor is not a scalar (len={})", self.len()),
        }
    }

    /// Convert to an `xla::Literal` at the PJRT boundary.
    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        if dims.len() == 1 && dims[0] == self.len() as i64 {
            return Ok(lit);
        }
        lit.reshape(&dims).context("literal reshape")
    }

    /// Read a literal back into a host tensor.
    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal array_shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 { dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(HostTensor::I32 { dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "xla")]
    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn roundtrip_i32_scalar_shape() {
        let t = HostTensor::i32(vec![4], vec![7, -1, 0, 3]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn scalar_rank0_accessors() {
        let t = HostTensor::scalar_f32(3.5);
        assert_eq!(t.scalar().unwrap(), 3.5);
        assert_eq!(t.dims(), &[] as &[usize]);
        assert_eq!(t.dtype_str(), "f32");
        let z = HostTensor::zeros_f32(vec![2, 2]);
        assert_eq!(z.len(), 4);
        assert!(z.as_f32().unwrap().iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0; 3]);
    }
}
