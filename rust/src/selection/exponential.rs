//! DP-SGD with exponential selection \[ZMH21\] — the prior-work baseline.
//!
//! Per step, a fixed number `m` of embedding rows is sampled (without
//! replacement) with probability proportional to
//! `exp(ε_sel · u(row) / (2Δu))` where the utility `u` is the row's clipped
//! gradient l2 norm; only the selected rows are noised and updated.  We
//! implement the sampling with the Gumbel-max trick on log-weights, which
//! draws the exponential mechanism exactly.
//!
//! The paper (§4.2) finds this baseline loses substantial utility at scale —
//! our Figure-3/8 harness reproduces that ordering.

use crate::util::rng::Xoshiro256;

/// Sample `m` distinct row ids from `utilities` (row id, utility) by the
/// exponential mechanism with exponent `eps_sel / (2 * sensitivity)`.
/// Returns ids sorted ascending.
pub fn exponential_select(
    utilities: &[(u32, f64)],
    m: usize,
    eps_sel: f64,
    sensitivity: f64,
    rng: &mut Xoshiro256,
) -> Vec<u32> {
    let m = m.min(utilities.len());
    if m == 0 {
        return vec![];
    }
    let coef = if sensitivity > 0.0 { eps_sel / (2.0 * sensitivity) } else { 0.0 };
    // Gumbel-max: top-m of (coef·u_i + Gumbel(1)) is an exact sample of the
    // exponential mechanism applied m times without replacement.
    let mut scored: Vec<(f64, u32)> = utilities
        .iter()
        .map(|&(id, u)| (coef * u + rng.gumbel(1.0), id))
        .collect();
    scored.select_nth_unstable_by(m - 1, |a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut ids: Vec<u32> = scored[..m].iter().map(|&(_, id)| id).collect();
    ids.sort_unstable();
    ids
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_m_distinct() {
        let utils: Vec<(u32, f64)> = (0..100).map(|i| (i, (i % 7) as f64)).collect();
        let mut rng = Xoshiro256::seed_from(1);
        let sel = exponential_select(&utils, 10, 1.0, 1.0, &mut rng);
        assert_eq!(sel.len(), 10);
        let mut u = sel.clone();
        u.dedup();
        assert_eq!(u.len(), 10);
    }

    #[test]
    fn high_eps_prefers_high_utility() {
        let utils: Vec<(u32, f64)> = (0..50).map(|i| (i, i as f64)).collect();
        let mut rng = Xoshiro256::seed_from(2);
        let mut hits = 0;
        for _ in 0..100 {
            let sel = exponential_select(&utils, 5, 200.0, 1.0, &mut rng);
            if sel == vec![45, 46, 47, 48, 49] {
                hits += 1;
            }
        }
        assert!(hits > 80, "top-5 hit only {hits}/100");
    }

    #[test]
    fn eps_zero_is_uniform() {
        // with eps 0 every subset is equally likely: each id selected with
        // prob m/n; check empirical rate for one id
        let utils: Vec<(u32, f64)> = (0..20).map(|i| (i, if i == 0 { 100.0 } else { 0.0 })).collect();
        let mut rng = Xoshiro256::seed_from(3);
        let trials = 2000;
        let hits = (0..trials)
            .filter(|_| exponential_select(&utils, 5, 0.0, 1.0, &mut rng).contains(&0))
            .count();
        let rate = hits as f64 / trials as f64;
        assert!((rate - 0.25).abs() < 0.05, "rate {rate}, want 0.25");
    }

    #[test]
    fn m_zero_or_empty_input() {
        let mut rng = Xoshiro256::seed_from(4);
        assert!(exponential_select(&[], 5, 1.0, 1.0, &mut rng).is_empty());
        assert!(exponential_select(&[(1, 1.0)], 0, 1.0, 1.0, &mut rng).is_empty());
    }
}
