//! Streaming bucket-frequency tracking for DP-FEST on time-series data
//! (paper §4.3, Figure 5).
//!
//! Three frequency sources are compared in the paper:
//! * `FirstDay`   — counts gathered on day 0 only, then frozen;
//! * `AllDays`    — oracle counts over the whole training range;
//! * `Streaming`  — a running sum updated once per streaming period.

use std::collections::HashMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrequencySource {
    FirstDay,
    AllDays,
    Streaming,
}

impl std::str::FromStr for FrequencySource {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "first-day" => Ok(FrequencySource::FirstDay),
            "all-days" => Ok(FrequencySource::AllDays),
            "streaming" => Ok(FrequencySource::Streaming),
            other => anyhow::bail!("unknown frequency source {other}"),
        }
    }
}

/// Per-feature running bucket counts with period snapshots.
#[derive(Clone, Debug)]
pub struct FrequencyTracker {
    /// counts[f][bucket]
    counts: Vec<HashMap<u32, u64>>,
    /// snapshot used for selection (what DP-FEST sees), refreshed on
    /// `publish`; for `FirstDay` it is frozen after the first publish.
    published: Vec<HashMap<u32, u64>>,
    publishes: usize,
    source: FrequencySource,
}

impl FrequencyTracker {
    pub fn new(num_features: usize, source: FrequencySource) -> Self {
        FrequencyTracker {
            counts: vec![HashMap::new(); num_features],
            published: vec![HashMap::new(); num_features],
            publishes: 0,
            source,
        }
    }

    pub fn source(&self) -> FrequencySource {
        self.source
    }

    /// Observe one batch of per-feature bucket ids (ids are *per-feature*
    /// local indices).
    pub fn observe(&mut self, feature: usize, buckets: &[i32]) {
        let m = &mut self.counts[feature];
        for &b in buckets {
            *m.entry(b as u32).or_insert(0) += 1;
        }
    }

    /// Merge pre-aggregated `(bucket, count)` pairs for one feature — the
    /// form in which the async engine's data workers ship each batch's
    /// observations to the aggregation barrier.  Addition commutes, so the
    /// running sums are bit-identical to per-example [`observe`] calls no
    /// matter how batches were counted or in what order they arrive.
    ///
    /// [`observe`]: FrequencyTracker::observe
    pub fn merge_counts(&mut self, feature: usize, pairs: &[(u32, u32)]) {
        let m = &mut self.counts[feature];
        for &(b, c) in pairs {
            *m.entry(b).or_insert(0) += c as u64;
        }
    }

    /// Publish the running counts to the selection snapshot (called at each
    /// streaming-period boundary).  `FirstDay` freezes after the first call.
    pub fn publish(&mut self) {
        if self.source == FrequencySource::FirstDay && self.publishes > 0 {
            return;
        }
        self.published = self.counts.clone();
        self.publishes += 1;
    }

    /// Dense count vector for a feature (for the top-k mechanism).
    pub fn dense_counts(&self, feature: usize, vocab: usize) -> Vec<f64> {
        let mut v = vec![0f64; vocab];
        for (&b, &c) in &self.published[feature] {
            if (b as usize) < vocab {
                v[b as usize] = c as f64;
            }
        }
        v
    }

    pub fn total_observed(&self, feature: usize) -> u64 {
        self.counts[feature].values().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_updates_snapshot_each_publish() {
        let mut t = FrequencyTracker::new(1, FrequencySource::Streaming);
        t.observe(0, &[1, 1, 2]);
        t.publish();
        assert_eq!(t.dense_counts(0, 4), vec![0.0, 2.0, 1.0, 0.0]);
        t.observe(0, &[3]);
        t.publish();
        assert_eq!(t.dense_counts(0, 4), vec![0.0, 2.0, 1.0, 1.0]);
    }

    #[test]
    fn first_day_freezes() {
        let mut t = FrequencyTracker::new(1, FrequencySource::FirstDay);
        t.observe(0, &[1]);
        t.publish();
        t.observe(0, &[2, 2, 2]);
        t.publish(); // must be ignored
        assert_eq!(t.dense_counts(0, 3), vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn merge_counts_equals_per_example_observe() {
        let mut a = FrequencyTracker::new(1, FrequencySource::Streaming);
        let mut b = FrequencyTracker::new(1, FrequencySource::Streaming);
        a.observe(0, &[3, 1, 3, 3, 7]);
        b.merge_counts(0, &[(1, 1), (3, 3), (7, 1)]);
        a.publish();
        b.publish();
        assert_eq!(a.dense_counts(0, 8), b.dense_counts(0, 8));
        assert_eq!(a.total_observed(0), b.total_observed(0));
    }

    #[test]
    fn unpublished_counts_invisible() {
        let mut t = FrequencyTracker::new(1, FrequencySource::Streaming);
        t.observe(0, &[0]);
        assert_eq!(t.dense_counts(0, 2), vec![0.0, 0.0]);
        assert_eq!(t.total_observed(0), 1);
    }
}
