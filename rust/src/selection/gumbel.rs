//! One-shot DP top-k selection (paper Algorithm 2, following \[DR21\]).
//!
//! Add i.i.d. `Gumbel(k/ε)`-style noise to bucket frequencies and return the
//! indices of the k largest noisy counts.  With per-user contribution
//! bounded by 1 per feature (paper Appendix B.1), the one-shot mechanism
//! with scale `k/ε` is (ε, 0)-DP; here we expose the scale directly and let
//! the caller implement the paper's budget split.

use crate::util::rng::Xoshiro256;

/// Select the top-k buckets of `counts` under Gumbel noise of scale `beta`
/// (`beta = k/ε` for the one-shot (ε,0)-DP guarantee; `beta = 0` recovers
/// exact top-k).  Returns indices sorted by noisy score, best first.
pub fn dp_top_k(counts: &[f64], k: usize, beta: f64, rng: &mut Xoshiro256) -> Vec<u32> {
    let k = k.min(counts.len());
    if k == 0 {
        return vec![];
    }
    let mut scored: Vec<(f64, u32)> = counts
        .iter()
        .enumerate()
        .map(|(i, &c)| {
            let noise = if beta > 0.0 { rng.gumbel(beta) } else { 0.0 };
            (c + noise, i as u32)
        })
        .collect();
    // partial selection: top-k by score
    scored.select_nth_unstable_by(k - 1, |a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut top: Vec<(f64, u32)> = scored[..k].to_vec();
    top.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    top.into_iter().map(|(_, i)| i).collect()
}

/// The paper's multi-feature budget split (Appendix B.1): total selection
/// budget `k` and privacy budget `epsilon` divided equally across `p`
/// features; per-feature one-shot top-`k/p` with budget `ε/p`.
///
/// `feature_counts[f]` are the (non-private) bucket frequencies of feature
/// `f`.  Returns per-feature selected bucket id lists.
pub fn dp_top_k_per_feature(
    feature_counts: &[Vec<f64>],
    k_total: usize,
    epsilon: f64,
    rng: &mut Xoshiro256,
) -> Vec<Vec<u32>> {
    let p = feature_counts.len().max(1);
    let k_per = (k_total / p).max(1);
    let eps_per = epsilon / p as f64;
    feature_counts
        .iter()
        .enumerate()
        .map(|(f, counts)| {
            let k_f = k_per.min(counts.len());
            let beta = if eps_per > 0.0 { k_f as f64 / eps_per } else { 0.0 };
            let mut sub = rng.fork(f as u64);
            dp_top_k(counts, k_f, beta, &mut sub)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_topk_when_no_noise() {
        let counts = vec![5.0, 1.0, 9.0, 7.0, 0.0];
        let mut rng = Xoshiro256::seed_from(1);
        let top = dp_top_k(&counts, 3, 0.0, &mut rng);
        assert_eq!(top, vec![2, 3, 0]);
    }

    #[test]
    fn high_budget_recovers_true_topk() {
        // well-separated counts + tiny noise scale => true top-k w.h.p.
        let counts: Vec<f64> = (0..100).map(|i| (i * 100) as f64).collect();
        let mut rng = Xoshiro256::seed_from(2);
        for _ in 0..20 {
            let top = dp_top_k(&counts, 5, 0.5, &mut rng);
            let mut sorted = top.clone();
            sorted.sort();
            assert_eq!(sorted, vec![95, 96, 97, 98, 99]);
        }
    }

    #[test]
    fn low_budget_is_noisy() {
        // huge noise scale: selection must NOT consistently equal top-k
        let counts: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let mut rng = Xoshiro256::seed_from(3);
        let mut agree = 0;
        let trials = 50;
        for _ in 0..trials {
            let top = dp_top_k(&counts, 5, 1e6, &mut rng);
            let mut s = top.clone();
            s.sort();
            if s == vec![45, 46, 47, 48, 49] {
                agree += 1;
            }
        }
        assert!(agree < trials / 4, "still exact {agree}/{trials} times");
    }

    #[test]
    fn frequency_bias_survives_statistically() {
        // with moderate noise, high-count buckets are selected more often
        let mut counts = vec![0.0f64; 20];
        counts[7] = 50.0;
        let mut rng = Xoshiro256::seed_from(4);
        let hits = (0..200)
            .filter(|_| dp_top_k(&counts, 1, 10.0, &mut rng)[0] == 7)
            .count();
        assert!(hits > 150, "bucket 7 selected only {hits}/200");
    }

    #[test]
    fn per_feature_split_counts_and_ranges() {
        let feats = vec![vec![1.0; 10], vec![2.0; 30], vec![3.0; 5]];
        let mut rng = Xoshiro256::seed_from(5);
        let sel = dp_top_k_per_feature(&feats, 9, 3.0, &mut rng);
        assert_eq!(sel.len(), 3);
        assert_eq!(sel[0].len(), 3);
        assert_eq!(sel[1].len(), 3);
        assert_eq!(sel[2].len(), 3);
        for (f, ids) in sel.iter().enumerate() {
            for &i in ids {
                assert!((i as usize) < feats[f].len());
            }
            let mut u = ids.clone();
            u.sort();
            u.dedup();
            assert_eq!(u.len(), ids.len(), "duplicates in feature {f}");
        }
    }

    #[test]
    fn k_larger_than_vocab_is_clamped() {
        let counts = vec![1.0, 2.0];
        let mut rng = Xoshiro256::seed_from(6);
        assert_eq!(dp_top_k(&counts, 10, 0.0, &mut rng).len(), 2);
    }
}
