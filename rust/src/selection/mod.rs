//! Bucket-selection mechanisms.
//!
//! * [`gumbel`] — one-shot DP top-k (Algorithm 2, [DR21]) used by DP-FEST's
//!   pre-training frequency filtering, with the per-feature ε/k budget split
//!   of Appendix B.1.
//! * [`exponential`] — the DP-SGD-with-exponential-selection baseline
//!   \[ZMH21\] that Figures 3/8 compare against.
//! * [`frequency`] — streaming frequency tracking for the time-series
//!   experiments (first-day / all-days / streaming-period sources, Fig. 5).

mod exponential;
mod frequency;
mod gumbel;

pub use exponential::exponential_select;
pub use frequency::{FrequencySource, FrequencyTracker};
pub use gumbel::{dp_top_k, dp_top_k_per_feature};
