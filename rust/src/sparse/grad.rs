//! `RowSparseGrad` — the row-sparse embedding-table gradient
//! `∇W = Σᵢ sᵢ·(xᵢ ⊗ ∂L/∂zᵢ)` (paper §2.1): at most B distinct rows are
//! non-zero out of a vocabulary of c rows.

use std::collections::HashMap;

/// A row-sparse gradient over a `(num_rows, dim)` table.
///
/// Internally `(indices, values)` with `values.len() == indices.len() * dim`,
/// kept unsorted during accumulation and canonicalised (sorted, unique) by
/// [`RowSparseGrad::finalize`].
#[derive(Clone, Debug, Default)]
pub struct RowSparseGrad {
    pub dim: usize,
    pub num_rows: usize,
    indices: Vec<u32>,
    values: Vec<f32>,
    /// map row-id → position in `indices` for O(1) accumulation
    slot: HashMap<u32, usize>,
}

impl RowSparseGrad {
    pub fn new(num_rows: usize, dim: usize) -> Self {
        RowSparseGrad {
            dim,
            num_rows,
            indices: Vec::new(),
            values: Vec::new(),
            slot: HashMap::new(),
        }
    }

    pub fn with_capacity(num_rows: usize, dim: usize, cap: usize) -> Self {
        RowSparseGrad {
            dim,
            num_rows,
            indices: Vec::with_capacity(cap),
            values: Vec::with_capacity(cap * dim),
            slot: HashMap::with_capacity(cap),
        }
    }

    /// Number of distinct non-zero rows.
    pub fn nnz_rows(&self) -> usize {
        self.indices.len()
    }

    /// Number of stored coordinates (`nnz_rows * dim`) — the paper's
    /// "gradient size" for this table.
    pub fn nnz_coords(&self) -> usize {
        self.values.len()
    }

    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    pub fn values(&self) -> &[f32] {
        &self.values
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.values[i * self.dim..(i + 1) * self.dim]
    }

    /// Accumulate `grad` into row `idx` (repeated ids within/between
    /// examples add, exactly like a dense scatter-add).
    pub fn add_row(&mut self, idx: u32, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.dim);
        debug_assert!((idx as usize) < self.num_rows, "row {idx} out of range");
        match self.slot.get(&idx) {
            Some(&pos) => {
                let base = pos * self.dim;
                for (v, g) in self.values[base..base + self.dim].iter_mut().zip(grad) {
                    *v += g;
                }
            }
            None => {
                self.slot.insert(idx, self.indices.len());
                self.indices.push(idx);
                self.values.extend_from_slice(grad);
            }
        }
    }

    /// Accumulate a scaled row: `row[idx] += s * grad`.
    pub fn add_row_scaled(&mut self, idx: u32, s: f32, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.dim);
        match self.slot.get(&idx) {
            Some(&pos) => {
                let base = pos * self.dim;
                for (v, g) in self.values[base..base + self.dim].iter_mut().zip(grad) {
                    *v += s * g;
                }
            }
            None => {
                self.slot.insert(idx, self.indices.len());
                self.indices.push(idx);
                let start = self.values.len();
                self.values.extend_from_slice(grad);
                for v in &mut self.values[start..] {
                    *v *= s;
                }
            }
        }
    }

    /// Drop every row not in `keep` (survivor filtering, Algorithm 1 line 8).
    /// `keep` must answer membership for raw row ids.
    pub fn retain_rows(&mut self, keep: impl Fn(u32) -> bool) {
        let dim = self.dim;
        let mut w = 0;
        for r in 0..self.indices.len() {
            if keep(self.indices[r]) {
                if w != r {
                    self.indices[w] = self.indices[r];
                    let (dst, src) = (w * dim, r * dim);
                    self.values.copy_within(src..src + dim, dst);
                }
                w += 1;
            }
        }
        self.indices.truncate(w);
        self.values.truncate(w * dim);
        self.slot.clear();
        for (pos, &idx) in self.indices.iter().enumerate() {
            self.slot.insert(idx, pos);
        }
    }

    /// Canonicalise: sort rows by index (stable layout for tests/serde).
    pub fn finalize(&mut self) {
        let dim = self.dim;
        let mut order: Vec<usize> = (0..self.indices.len()).collect();
        order.sort_by_key(|&i| self.indices[i]);
        let indices: Vec<u32> = order.iter().map(|&i| self.indices[i]).collect();
        let mut values = vec![0f32; self.values.len()];
        for (new, &old) in order.iter().enumerate() {
            values[new * dim..(new + 1) * dim]
                .copy_from_slice(&self.values[old * dim..(old + 1) * dim]);
        }
        self.indices = indices;
        self.values = values;
        self.slot.clear();
        for (pos, &idx) in self.indices.iter().enumerate() {
            self.slot.insert(idx, pos);
        }
    }

    /// Squared l2 norm of the whole sparse gradient.
    pub fn sq_norm(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Scale every stored value.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.values {
            *v *= s;
        }
    }

    /// Densify (tests / tiny tables only).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.num_rows * self.dim];
        for (i, &idx) in self.indices.iter().enumerate() {
            let dst = idx as usize * self.dim;
            for (o, v) in out[dst..dst + self.dim].iter_mut().zip(self.row(i)) {
                *o += v;
            }
        }
        out
    }

    /// Iterate `(row_id, row_values)`.
    pub fn iter_rows(&self) -> impl Iterator<Item = (u32, &[f32])> {
        self.indices
            .iter()
            .enumerate()
            .map(move |(i, &idx)| (idx, self.row(i)))
    }

    /// Mutable row access by slot position.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let d = self.dim;
        &mut self.values[i * d..(i + 1) * d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_repeated_rows() {
        let mut g = RowSparseGrad::new(10, 2);
        g.add_row(3, &[1.0, 2.0]);
        g.add_row(7, &[5.0, 5.0]);
        g.add_row(3, &[0.5, -1.0]);
        assert_eq!(g.nnz_rows(), 2);
        let dense = g.to_dense();
        assert_eq!(&dense[6..8], &[1.5, 1.0]);
        assert_eq!(&dense[14..16], &[5.0, 5.0]);
    }

    #[test]
    fn scaled_rows_and_norm() {
        let mut g = RowSparseGrad::new(4, 2);
        g.add_row_scaled(0, 0.5, &[2.0, 0.0]);
        g.add_row_scaled(0, 2.0, &[0.0, 1.0]);
        assert_eq!(g.nnz_rows(), 1);
        assert_eq!(g.row(0), &[1.0, 2.0]);
        assert!((g.sq_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn retain_filters_rows() {
        let mut g = RowSparseGrad::new(100, 1);
        for i in 0..10u32 {
            g.add_row(i, &[i as f32]);
        }
        g.retain_rows(|idx| idx % 2 == 0);
        assert_eq!(g.nnz_rows(), 5);
        let dense = g.to_dense();
        assert_eq!(dense[4], 4.0);
        assert_eq!(dense[5], 0.0);
        // accumulation still works after retain
        g.add_row(4, &[1.0]);
        assert_eq!(g.to_dense()[4], 5.0);
    }

    #[test]
    fn finalize_sorts() {
        let mut g = RowSparseGrad::new(10, 1);
        g.add_row(9, &[9.0]);
        g.add_row(1, &[1.0]);
        g.add_row(5, &[5.0]);
        g.finalize();
        assert_eq!(g.indices(), &[1, 5, 9]);
        assert_eq!(g.values(), &[1.0, 5.0, 9.0]);
        g.add_row(5, &[1.0]);
        assert_eq!(g.to_dense()[5], 6.0);
    }
}
