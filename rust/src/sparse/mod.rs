//! Row-sparse gradients, sparse/dense optimizers, noise injection, and the
//! Appendix-B.2 memory-efficient survivor sampler.
//!
//! This module is the mechanical heart of the paper's claim: the update path
//! of an embedding table must stay `O(nnz)` — gather/scatter, never a dense
//! `c×d` pass.  `RowSparseGrad` is the only gradient representation the
//! embedding hot path ever materialises; the dense path exists solely as the
//! DP-SGD baseline whose cost Table 4 measures.

mod grad;
mod noise;
mod optimizer;
mod survivor;

pub use grad::RowSparseGrad;
pub use noise::{add_dense_noise, add_row_noise, GradSizeMeter};
pub use optimizer::{DenseState, Optimizer, OptimizerKind};
pub use survivor::{survivors_dense, survivors_sparse, SurvivorStats};
