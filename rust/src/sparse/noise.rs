//! Gaussian noise injection — dense (vanilla DP-SGD, Eq. 1) and row-sparse
//! (Algorithm 1 line 9, noise only on surviving rows) — plus the
//! gradient-size meter that produces the paper's headline metric.

use crate::util::rng::Xoshiro256;

use super::grad::RowSparseGrad;

/// Vanilla DP-SGD: add `N(0, sigma²)` to *every* coordinate of a dense
/// gradient buffer.  Returns the number of noised coordinates (== len).
pub fn add_dense_noise(buf: &mut [f32], sigma: f64, rng: &mut Xoshiro256) -> usize {
    if sigma > 0.0 {
        // generate-and-add in chunks to stay cache-resident
        const CHUNK: usize = 4096;
        let mut noise = [0f32; CHUNK];
        let mut off = 0;
        while off < buf.len() {
            let n = CHUNK.min(buf.len() - off);
            rng.fill_gauss_f32(&mut noise[..n], sigma);
            for (b, z) in buf[off..off + n].iter_mut().zip(&noise[..n]) {
                *b += z;
            }
            off += n;
        }
    }
    buf.len()
}

/// Sparsity-preserving noise: add `N(0, sigma²)` only to the rows present in
/// the row-sparse gradient.  Returns the number of noised coordinates
/// (`nnz_rows * dim`).
pub fn add_row_noise(grad: &mut RowSparseGrad, sigma: f64, rng: &mut Xoshiro256) -> usize {
    let n = grad.nnz_coords();
    if sigma > 0.0 {
        for i in 0..grad.nnz_rows() {
            let row = grad.row_mut(i);
            let mut noise = vec![0f32; row.len()];
            rng.fill_gauss_f32(&mut noise, sigma);
            for (v, z) in row.iter_mut().zip(&noise) {
                *v += z;
            }
        }
    }
    n
}

/// Tracks the paper's "gradient size": the number of coordinates that
/// receive noise (and therefore must be written back densely) per step,
/// split into embedding vs dense-layer parts.
///
/// `reduction_factor` is `dense_baseline / measured` where the baseline is
/// what vanilla DP-SGD would noise: *every* embedding coordinate plus the
/// dense params — this is the quantity Figures 3–6 plot (e.g. `>10⁶×`).
#[derive(Clone, Debug, Default)]
pub struct GradSizeMeter {
    pub steps: u64,
    pub emb_coords: u64,
    pub dense_coords: u64,
    /// per-step dense-equivalent embedding coordinates (c_total * d style
    /// count: what DP-SGD would have noised)
    pub emb_dense_baseline: u64,
    pub dense_baseline: u64,
}

impl GradSizeMeter {
    pub fn record_step(&mut self, emb_coords: usize, dense_coords: usize) {
        self.steps += 1;
        self.emb_coords += emb_coords as u64;
        self.dense_coords += dense_coords as u64;
    }

    pub fn set_baselines(&mut self, emb_dense: usize, dense: usize) {
        self.emb_dense_baseline = emb_dense as u64;
        self.dense_baseline = dense as u64;
    }

    /// Mean noised embedding coordinates per step.
    pub fn emb_per_step(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.emb_coords as f64 / self.steps as f64
    }

    /// The paper's embedding-gradient-size reduction factor vs DP-SGD.
    pub fn reduction_factor(&self) -> f64 {
        let per_step = self.emb_per_step();
        if per_step == 0.0 {
            return f64::INFINITY;
        }
        self.emb_dense_baseline as f64 / per_step
    }

    /// Total (embedding + dense) reduction factor.
    pub fn total_reduction_factor(&self) -> f64 {
        let per_step =
            (self.emb_coords + self.dense_coords) as f64 / self.steps.max(1) as f64;
        if per_step == 0.0 {
            return f64::INFINITY;
        }
        (self.emb_dense_baseline + self.dense_baseline) as f64 / per_step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_noise_changes_every_coordinate() {
        let mut rng = Xoshiro256::seed_from(1);
        let mut buf = vec![0f32; 10_001];
        let n = add_dense_noise(&mut buf, 1.0, &mut rng);
        assert_eq!(n, 10_001);
        assert!(buf.iter().all(|&v| v != 0.0));
        let var: f64 =
            buf.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn zero_sigma_is_identity() {
        let mut rng = Xoshiro256::seed_from(2);
        let mut buf = vec![1f32; 64];
        add_dense_noise(&mut buf, 0.0, &mut rng);
        assert!(buf.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn row_noise_touches_only_present_rows() {
        let mut rng = Xoshiro256::seed_from(3);
        let mut g = RowSparseGrad::new(1000, 4);
        g.add_row(10, &[0.0; 4]);
        g.add_row(999, &[0.0; 4]);
        let n = add_row_noise(&mut g, 1.0, &mut rng);
        assert_eq!(n, 8);
        let dense = g.to_dense();
        let nz: usize = dense.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, 8); // only the two present rows got noise
    }

    #[test]
    fn meter_reduction_factor() {
        let mut m = GradSizeMeter::default();
        m.set_baselines(1_000_000, 100);
        m.record_step(10, 100);
        m.record_step(30, 100);
        assert_eq!(m.emb_per_step(), 20.0);
        assert_eq!(m.reduction_factor(), 50_000.0);
    }
}
