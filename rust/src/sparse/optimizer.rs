//! Optimizers with first-class sparse row updates.
//!
//! The whole point of the paper is that the embedding update must be a
//! scatter (`O(nnz)`), so the optimizer exposes two entry points per
//! parameter: [`Optimizer::dense_step`] for MLP/LoRA params and
//! [`Optimizer::sparse_step`] for embedding tables given a
//! [`RowSparseGrad`].  SGD and (sparse-slot) Adagrad are provided; Adagrad's
//! accumulator is updated only on touched rows, matching how production
//! sparse optimizers (e.g. TF `scatter_add`-based slots) behave.

use super::grad::RowSparseGrad;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    Adagrad,
}

impl std::str::FromStr for OptimizerKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sgd" => Ok(OptimizerKind::Sgd),
            "adagrad" => Ok(OptimizerKind::Adagrad),
            other => anyhow::bail!("unknown optimizer {other} (want sgd|adagrad)"),
        }
    }
}

/// Per-parameter optimizer state (Adagrad accumulator; empty for SGD).
#[derive(Clone, Debug, Default)]
pub struct DenseState {
    accum: Vec<f32>,
}

impl DenseState {
    /// Rehydrate from a raw accumulator (the engine's sharded store splits
    /// and re-joins state across shards).
    pub fn from_accum(accum: Vec<f32>) -> Self {
        DenseState { accum }
    }

    /// The raw accumulator; empty until the first Adagrad step touches the
    /// parameter.
    pub fn accum(&self) -> &[f32] {
        &self.accum
    }

    pub fn into_accum(self) -> Vec<f32> {
        self.accum
    }
}

#[derive(Clone, Debug)]
pub struct Optimizer {
    pub kind: OptimizerKind,
    pub lr: f32,
    pub adagrad_eps: f32,
}

impl Optimizer {
    pub fn sgd(lr: f32) -> Self {
        Optimizer { kind: OptimizerKind::Sgd, lr, adagrad_eps: 1e-8 }
    }

    pub fn adagrad(lr: f32) -> Self {
        Optimizer { kind: OptimizerKind::Adagrad, lr, adagrad_eps: 1e-8 }
    }

    pub fn new(kind: OptimizerKind, lr: f32) -> Self {
        Optimizer { kind, lr, adagrad_eps: 1e-8 }
    }

    /// Dense update: `param -= lr * grad` (optionally Adagrad-scaled).
    pub fn dense_step(&self, param: &mut [f32], grad: &[f32], state: &mut DenseState) {
        debug_assert_eq!(param.len(), grad.len());
        match self.kind {
            OptimizerKind::Sgd => {
                for (p, g) in param.iter_mut().zip(grad) {
                    *p -= self.lr * g;
                }
            }
            OptimizerKind::Adagrad => {
                if state.accum.len() != param.len() {
                    state.accum = vec![0f32; param.len()];
                }
                for ((p, g), a) in param.iter_mut().zip(grad).zip(&mut state.accum) {
                    *a += g * g;
                    *p -= self.lr * g / (a.sqrt() + self.adagrad_eps);
                }
            }
        }
    }

    /// Sparse update: scatter `-lr * grad_row` into the touched table rows
    /// only.  `state` (Adagrad) is likewise touched only on those rows.
    pub fn sparse_step(
        &self,
        table: &mut [f32],
        grad: &RowSparseGrad,
        state: &mut DenseState,
    ) {
        let d = grad.dim;
        match self.kind {
            OptimizerKind::Sgd => {
                for (row_id, row) in grad.iter_rows() {
                    let base = row_id as usize * d;
                    for (p, g) in table[base..base + d].iter_mut().zip(row) {
                        *p -= self.lr * g;
                    }
                }
            }
            OptimizerKind::Adagrad => {
                if state.accum.len() != table.len() {
                    state.accum = vec![0f32; table.len()];
                }
                for (row_id, row) in grad.iter_rows() {
                    let base = row_id as usize * d;
                    for ((p, g), a) in table[base..base + d]
                        .iter_mut()
                        .zip(row)
                        .zip(&mut state.accum[base..base + d])
                    {
                        *a += g * g;
                        *p -= self.lr * g / (a.sqrt() + self.adagrad_eps);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_equals_dense_sgd() {
        // Property: applying a row-sparse grad sparsely == densifying it and
        // applying densely.
        let mut g = RowSparseGrad::new(20, 3);
        g.add_row(2, &[1.0, -1.0, 0.5]);
        g.add_row(17, &[0.1, 0.2, 0.3]);
        g.add_row(2, &[1.0, 0.0, 0.0]);

        let opt = Optimizer::sgd(0.1);
        let mut a = vec![1f32; 60];
        let mut b = a.clone();
        let mut st_a = DenseState::default();
        let mut st_b = DenseState::default();
        opt.sparse_step(&mut a, &g, &mut st_a);
        opt.dense_step(&mut b, &g.to_dense(), &mut st_b);
        assert_eq!(a, b);
    }

    #[test]
    fn sparse_equals_dense_adagrad_on_touched_rows() {
        let mut g = RowSparseGrad::new(10, 2);
        g.add_row(1, &[0.5, 0.5]);
        g.add_row(9, &[1.0, -2.0]);

        let opt = Optimizer::adagrad(0.1);
        let mut a = vec![0.5f32; 20];
        let mut b = a.clone();
        let mut st_a = DenseState::default();
        let mut st_b = DenseState::default();
        opt.sparse_step(&mut a, &g, &mut st_a);
        // dense adagrad with the densified grad touches zero-grad rows with
        // g=0, which adds 0 to accumulators and 0 to params — identical.
        opt.dense_step(&mut b, &g.to_dense(), &mut st_b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-7);
        }
    }

    #[test]
    fn adagrad_shrinks_effective_lr() {
        let opt = Optimizer::adagrad(1.0);
        let mut p = vec![0f32; 1];
        let mut st = DenseState::default();
        opt.dense_step(&mut p, &[1.0], &mut st);
        let first = -p[0];
        opt.dense_step(&mut p, &[1.0], &mut st);
        let second = -p[0] - first;
        assert!(second < first, "{second} !< {first}");
    }

    #[test]
    fn untouched_rows_unmodified() {
        let mut g = RowSparseGrad::new(5, 2);
        g.add_row(0, &[1.0, 1.0]);
        let opt = Optimizer::sgd(1.0);
        let mut table = vec![7f32; 10];
        opt.sparse_step(&mut table, &g, &mut DenseState::default());
        assert_eq!(&table[2..], &[7f32; 8][..]);
        assert_eq!(&table[..2], &[6.0, 6.0]);
    }
}
