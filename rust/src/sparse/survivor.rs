//! Survivor selection on the noisy contribution map (Algorithm 1, lines 6–8)
//! in two implementations:
//!
//! * [`survivors_dense`] — the naive `O(c)` path: materialise the noisy map,
//!   threshold it.  This is the oracle.
//! * [`survivors_sparse`] — Appendix B.2: only the `nnz` non-zero counts get
//!   explicit Gaussian samples; the `c - nnz` zero-count coordinates can
//!   survive only as false positives, which occur i.i.d. with probability
//!   `p = Ψ(τ / (σ₁·C₁))`, so their indices are sampled directly by drawing
//!   `Geometric(p)` gaps.  Cost is `O(nnz + #false-positives)` — linear in
//!   the gradient, not the vocabulary.
//!
//! Both return the survivor row set; property tests check that the sparse
//! sampler matches the dense law (exact on non-zeros given shared noise,
//! χ²-consistent on false-positive counts).

use crate::util::rng::Xoshiro256;
use crate::util::stats::gauss_sf;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct SurvivorStats {
    /// rows with non-zero clipped counts that survived
    pub true_survivors: usize,
    /// rows with non-zero clipped counts that were suppressed
    pub suppressed: usize,
    /// zero-count rows that survived on noise alone
    pub false_positives: usize,
}

/// Naive `O(c)` reference: add `N(0, (σ₁C₁)²)` to every coordinate of the
/// dense count vector, keep those `≥ τ`.
pub fn survivors_dense(
    counts: &[f32],
    sigma1: f64,
    c1: f64,
    tau: f64,
    rng: &mut Xoshiro256,
) -> (Vec<u32>, SurvivorStats) {
    let scale = sigma1 * c1;
    let mut out = Vec::new();
    let mut stats = SurvivorStats::default();
    for (j, &v) in counts.iter().enumerate() {
        let noisy = v as f64 + rng.gauss() * scale;
        if noisy >= tau {
            out.push(j as u32);
            if v != 0.0 {
                stats.true_survivors += 1;
            } else {
                stats.false_positives += 1;
            }
        } else if v != 0.0 {
            stats.suppressed += 1;
        }
    }
    (out, stats)
}

/// Appendix-B.2 sampler over a *sparse* count representation
/// (`nonzero = [(row, count)]`, everything else zero, `num_rows` total).
///
/// Returned indices are sorted.  `nonzero` must be sorted by row id and
/// contain no duplicates (the contribution map builder guarantees this).
pub fn survivors_sparse(
    nonzero: &[(u32, f32)],
    num_rows: usize,
    sigma1: f64,
    c1: f64,
    tau: f64,
    rng: &mut Xoshiro256,
) -> (Vec<u32>, SurvivorStats) {
    let scale = sigma1 * c1;
    let mut stats = SurvivorStats::default();
    let mut survivors = Vec::with_capacity(nonzero.len());

    // Explicit samples for the non-zero counts.
    for &(row, v) in nonzero {
        let noisy = v as f64 + rng.gauss() * scale;
        if noisy >= tau {
            survivors.push(row);
            stats.true_survivors += 1;
        } else {
            stats.suppressed += 1;
        }
    }

    // False positives among the zero-count coordinates: each survives with
    // probability p = Ψ(τ / (σ₁C₁)); sample the survivor positions directly
    // via Geometric(p) gaps over the *virtual* array of zero coordinates,
    // then translate virtual positions to real row ids by skipping the
    // non-zero rows (two-pointer walk over the sorted nonzero ids).
    let p = if scale > 0.0 {
        gauss_sf(tau / scale)
    } else if tau <= 0.0 {
        1.0
    } else {
        0.0
    };
    let num_zero = num_rows - nonzero.len();
    if p > 0.0 && num_zero > 0 {
        let mut fp_virtual: Vec<u64> = Vec::new();
        if p >= 1.0 {
            fp_virtual.extend(0..num_zero as u64);
        } else {
            let mut pos: u64 = 0;
            loop {
                let gap = rng.geometric(p);
                pos += gap;
                if pos > num_zero as u64 {
                    break;
                }
                fp_virtual.push(pos - 1); // 0-based virtual index
            }
        }
        if !fp_virtual.is_empty() {
            // translate: virtual index v counts zero-coordinates only
            let mut nz_iter = nonzero.iter().map(|&(r, _)| r as u64).peekable();
            let mut skipped: u64 = 0; // non-zero rows passed so far
            let mut next_nz = nz_iter.next();
            for &v in &fp_virtual {
                // real position r satisfies: r - (#nonzero ids <= r) == v
                let mut r = v + skipped;
                while let Some(nz) = next_nz {
                    if nz <= r {
                        skipped += 1;
                        r += 1;
                        next_nz = nz_iter.next();
                    } else {
                        break;
                    }
                }
                survivors.push(r as u32);
                stats.false_positives += 1;
            }
        }
    }

    survivors.sort_unstable();
    (survivors, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sparse_counts(dense: &[f32]) -> Vec<(u32, f32)> {
        dense
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .map(|(i, &v)| (i as u32, v))
            .collect()
    }

    #[test]
    fn no_noise_is_exact_threshold() {
        let mut counts = vec![0f32; 100];
        counts[3] = 5.0;
        counts[10] = 1.0;
        counts[50] = 10.0;
        let mut rng = Xoshiro256::seed_from(1);
        let (s, st) = survivors_sparse(&sparse_counts(&counts), 100, 0.0, 1.0, 2.0, &mut rng);
        assert_eq!(s, vec![3, 50]);
        assert_eq!(st.false_positives, 0);
        assert_eq!(st.suppressed, 1);
    }

    #[test]
    fn tau_zero_no_noise_keeps_all_rows() {
        // τ ≤ 0 with σ=0 ⇒ every coordinate survives (noisy value 0 ≥ 0)
        let counts = vec![0f32; 10];
        let mut rng = Xoshiro256::seed_from(2);
        let (s, _) = survivors_sparse(&sparse_counts(&counts), 10, 0.0, 1.0, 0.0, &mut rng);
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn false_positive_rate_matches_gaussian_tail() {
        // all-zero counts: survivors are pure false positives with rate
        // p = Ψ(τ/(σ₁C₁)).
        let num_rows = 200_000;
        let sigma1 = 1.0;
        let c1 = 1.0;
        let tau = 2.0; // p ≈ 0.02275
        let p = gauss_sf(tau / (sigma1 * c1));
        let mut rng = Xoshiro256::seed_from(3);
        let (s, _) = survivors_sparse(&[], num_rows, sigma1, c1, tau, &mut rng);
        let want = p * num_rows as f64;
        let sd = (num_rows as f64 * p * (1.0 - p)).sqrt();
        assert!(
            (s.len() as f64 - want).abs() < 5.0 * sd,
            "got {} want {want}±{sd}",
            s.len()
        );
        // indices must be unique and in range
        let mut u = s.clone();
        u.dedup();
        assert_eq!(u.len(), s.len());
        assert!(s.iter().all(|&i| (i as usize) < num_rows));
    }

    #[test]
    fn sparse_skips_nonzero_rows_in_fp_translation() {
        // Dense rows 0..10 are non-zero with huge counts (always survive);
        // false positives must never collide with them in the output-dup
        // sense (a row can appear once only).
        let nonzero: Vec<(u32, f32)> = (0..10).map(|i| (i as u32, 1e6)).collect();
        let mut rng = Xoshiro256::seed_from(7);
        let (s, st) =
            survivors_sparse(&nonzero, 10_000, 10.0, 1.0, -50.0, &mut rng);
        // tau very negative => p ~ 1: everything survives exactly once
        assert_eq!(s.len(), 10_000);
        assert_eq!(st.true_survivors, 10);
        assert_eq!(st.false_positives, 9_990);
        let mut u = s.clone();
        u.dedup();
        assert_eq!(u.len(), s.len());
    }

    #[test]
    fn dense_and_sparse_agree_statistically() {
        // Same count vector, many trials: survival rate per class
        // (high-count / borderline / zero) should agree between the two
        // implementations within sampling error.
        let mut counts = vec![0f32; 5000];
        for i in 0..50 {
            counts[i * 100] = 3.0; // borderline at tau=3: P(survive)=0.5
        }
        let trials = 300;
        let (mut dense_tot, mut sparse_tot) = (0usize, 0usize);
        let (mut dense_fp, mut sparse_fp) = (0usize, 0usize);
        let nz = sparse_counts(&counts);
        for t in 0..trials {
            let mut r1 = Xoshiro256::seed_from(1000 + t);
            let mut r2 = Xoshiro256::seed_from(5000 + t);
            let (_, st_d) = survivors_dense(&counts, 1.0, 1.0, 3.0, &mut r1);
            let (_, st_s) = survivors_sparse(&nz, 5000, 1.0, 1.0, 3.0, &mut r2);
            dense_tot += st_d.true_survivors;
            sparse_tot += st_s.true_survivors;
            dense_fp += st_d.false_positives;
            sparse_fp += st_s.false_positives;
        }
        let n = (trials * 50) as f64;
        let d_rate = dense_tot as f64 / n;
        let s_rate = sparse_tot as f64 / n;
        assert!((d_rate - 0.5).abs() < 0.03, "dense borderline rate {d_rate}");
        assert!((s_rate - 0.5).abs() < 0.03, "sparse borderline rate {s_rate}");
        // zero-count false positives: p = psi(3) ≈ 1.35e-3 over 4950 rows
        let fp_want = gauss_sf(3.0) * 4950.0 * trials as f64;
        for (name, fp) in [("dense", dense_fp), ("sparse", sparse_fp)] {
            let got = fp as f64;
            assert!(
                (got - fp_want).abs() < 6.0 * fp_want.sqrt().max(3.0),
                "{name} fp {got} want {fp_want}"
            );
        }
    }
}
