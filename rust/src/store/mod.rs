//! Embedding-table storage backends behind the engine's `ShardedStore` seam.
//!
//! The paper's sparse select/scatter property — a training step touches only
//! the rows its batch presents — is what makes vocab ≫ RAM feasible: the
//! dense table never has to be resident.  This module provides the two
//! backends an embedding table can live in:
//!
//! * [`ShardedTable`] (`sharded.rs`) — the in-RAM default: contiguous
//!   row-range shards behind per-shard mutexes, unchanged from the original
//!   engine store.
//! * [`PagedTable`] (`paged.rs`) — file-backed rows in fixed-size row pages
//!   with an LRU page cache under a byte budget (`--store-budget-mb`), so a
//!   hundred-million-row table runs in a bounded memory footprint and sparse
//!   `select`/`scatter` touch only the pages holding present rows.
//!
//! [`TableStore`] is the seam: the engine, the gradient actors, and the
//! `ShardedStore` slots hold one of these per embedding table and dispatch
//! through it.  Both backends apply the optimizer through the *same*
//! per-coordinate [`Optimizer::sparse_step`]/[`Optimizer::dense_step`] code
//! on sub-ranges of the table, and SGD/Adagrad touch each coordinate
//! independently — so any partitioning (shards or pages) produces bitwise
//! identical values and accumulator state, and the engine's bit-exactness
//! invariants (`docs/CONCURRENCY.md`) are backend-independent.
//! `tests/store.rs` proves paged == sharded == flat byte-for-byte under the
//! in-repo property harness.

mod paged;
mod sharded;

pub use paged::{unique_path, PagedTable};
pub use sharded::{ShardedStore, ShardedTable};

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::sparse::{Optimizer, RowSparseGrad};
use crate::telemetry::Telemetry;

/// Target byte size of one page's value payload; the row count per page is
/// derived from the embedding dimension ([`default_page_rows`]).
pub const PAGE_BYTES_TARGET: usize = 64 * 1024;

/// Rows per page for an embedding dimension: ~[`PAGE_BYTES_TARGET`] of f32
/// values per page, at least one row.
pub fn default_page_rows(dim: usize) -> usize {
    (PAGE_BYTES_TARGET / (dim.max(1) * 4)).max(1)
}

/// Backend selection for the engine's embedding tables, resolved from the
/// run config (`--store-budget-mb` / `--store-dir`).
#[derive(Clone)]
pub struct StoreOptions {
    /// LRU page-cache budget in MiB; `0` keeps every table in RAM (the
    /// [`ShardedTable`] default).
    pub budget_mb: usize,
    /// Directory holding the page files; empty = the system temp dir.
    pub dir: String,
    /// Telemetry hub for the resident-page-bytes gauge (optional).
    pub tele: Option<Arc<Telemetry>>,
}

impl StoreOptions {
    /// The in-RAM default (today's behavior).
    pub fn ram() -> StoreOptions {
        StoreOptions { budget_mb: 0, dir: String::new(), tele: None }
    }

    /// The directory page files go in: `dir`, or the system temp dir when
    /// unset.
    pub fn resolve_dir(dir: &str) -> PathBuf {
        if dir.is_empty() {
            std::env::temp_dir()
        } else {
            PathBuf::from(dir)
        }
    }
}

/// One embedding table, in whichever backend the run selected.  All methods
/// take `&self` (interior mutability in both backends), so the table is
/// shared by reference across the worker scope exactly like before.
pub enum TableStore {
    /// In-RAM row-range shards (the default).
    Ram(ShardedTable),
    /// File-backed fixed-size row pages under an LRU byte budget.
    Paged(PagedTable),
}

impl TableStore {
    /// Total row count of the table.
    pub fn rows(&self) -> usize {
        match self {
            TableStore::Ram(t) => t.rows,
            TableStore::Paged(t) => t.rows(),
        }
    }

    /// Row width (embedding dimension).
    pub fn dim(&self) -> usize {
        match self {
            TableStore::Ram(t) => t.dim,
            TableStore::Paged(t) => t.dim(),
        }
    }

    /// Copy one row out (the `select` half: RowCache snapshot fills).  A
    /// paged-backend I/O failure is fatal — the callers' signatures are
    /// infallible by design (`RowCache::build` and the actor fetch path).
    pub fn read_row(&self, row: usize, out: &mut [f32]) {
        match self {
            TableStore::Ram(t) => t.read_row(row, out),
            TableStore::Paged(t) => t.read_row(row, out).expect("paged table I/O"),
        }
    }

    /// Scatter a row-sparse optimizer update (the `scatter` half).
    pub fn apply_sparse(&self, grad: &RowSparseGrad, opt: &Optimizer) -> Result<()> {
        match self {
            TableStore::Ram(t) => {
                t.apply_sparse(grad, opt);
                Ok(())
            }
            TableStore::Paged(t) => t.apply_sparse(grad, opt),
        }
    }

    /// Dense update over every row (the DP-SGD embedding baseline).
    pub fn apply_dense(&self, grad: &[f32], opt: &Optimizer) -> Result<()> {
        match self {
            TableStore::Ram(t) => {
                t.apply_dense(grad, opt);
                Ok(())
            }
            TableStore::Paged(t) => t.apply_dense(grad, opt),
        }
    }

    /// Reassemble `(values, adagrad accumulator)`; the accumulator is empty
    /// when the optimizer never materialised state (same contract for both
    /// backends).
    pub fn into_dense(self) -> Result<(Vec<f32>, Vec<f32>)> {
        match self {
            TableStore::Ram(t) => Ok(t.into_dense()),
            TableStore::Paged(t) => t.into_dense(),
        }
    }

    /// Backend name for bench rows / logs: `"ram"` or `"paged"`.
    pub fn backend_name(&self) -> &'static str {
        match self {
            TableStore::Ram(_) => "ram",
            TableStore::Paged(_) => "paged",
        }
    }
}
