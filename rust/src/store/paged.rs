//! File-backed paged embedding table: rows live in a page file on disk,
//! with an LRU page cache under a configurable byte budget.
//!
//! ## Page layout
//!
//! One file per table (`std::fs` only — `seek`/`read_exact`/`write_all`, no
//! mmap, no new deps):
//!
//! ```text
//! [header: 32 bytes]                magic u64 · version u32 · state u32 ·
//!                                   rows u64 · dim u32 · page_rows u32
//! [values region: rows·dim f32]     row-major, little-endian bit patterns
//! [accum region:  rows·dim f32]     Adagrad accumulator, same layout
//! ```
//!
//! The file is created at its full length with `set_len`, so untouched
//! regions are sparse holes that read back as `0.0` — a hundred-million-row
//! table costs disk only for the pages actually written.  Rows are grouped
//! into fixed-size pages of `page_rows` rows (the last page may be short);
//! a page is loaded on first touch, evicted least-recently-used when the
//! cache exceeds its page budget, and written back only if dirty.  The
//! budget is expressed in bytes and divided by the worst-case page cost
//! (values + accumulator), so resident cache bytes never exceed
//! `max(budget, one page)` — the telemetry resident-bytes gauge
//! ([`Telemetry::store_resident_max`]) tracks the high-water mark.
//!
//! ## Why select/scatter stay bit-identical
//!
//! Every update goes through the same [`Optimizer::sparse_step`] /
//! [`Optimizer::dense_step`] code as the in-RAM [`ShardedTable`], applied to
//! page-sized sub-ranges of the table.  SGD and Adagrad touch each
//! coordinate independently (the accumulator lazily zero-initialises, and a
//! page's never-written accum region reads as zeros), and a
//! [`RowSparseGrad`] holds each row at most once ([`RowSparseGrad::add_row`]
//! accumulates repeats into one entry before any apply), so regrouping the
//! rows by page cannot reorder anything the optimizer is sensitive to.  Any
//! partitioning of the table therefore produces bitwise identical values
//! and state — `tests/store.rs` proves paged == sharded == flat under the
//! in-repo property harness, across page sizes, budgets (including a single
//! page), and eviction-then-reread of dirty pages.
//!
//! ## Crash consistency
//!
//! The header `state` field is written as *open* at creation and marked
//! *clean* only by [`PagedTable::into_dense`] (which then removes the
//! file).  A process that dies mid-run (the actor fault tests) skips both,
//! so any page file found on disk in the open state is a crashed run whose
//! scatters may be partially applied — [`PagedTable::check_clean`] rejects
//! it instead of silently serving partial rows.
//!
//! [`ShardedTable`]: super::ShardedTable

use std::collections::{BTreeMap, HashMap};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::sparse::{DenseState, Optimizer, RowSparseGrad};
use crate::telemetry::Telemetry;

const MAGIC: u64 = 0x4547_4150_4550_4453; // le bytes: "SDPEPAGE"
const VERSION: u32 = 1;
const HEADER_BYTES: u64 = 32;
const STATE_CLEAN: u32 = 0;
const STATE_OPEN: u32 = 1;

static FILE_SEQ: AtomicU64 = AtomicU64::new(0);

/// A collision-free page-file path under `dir`: the label plus this
/// process's id plus a process-local sequence number, `.pages` extension.
pub fn unique_path(dir: &Path, label: &str) -> PathBuf {
    let seq = FILE_SEQ.fetch_add(1, Ordering::Relaxed);
    dir.join(format!("sde_{label}_{}_{seq}.pages", std::process::id()))
}

struct Page {
    /// rows `[idx·page_rows, hi)` of the table, row-major
    values: Vec<f32>,
    /// Adagrad accumulator for the same rows; empty until materialised
    state: DenseState,
    dirty: bool,
    last_used: u64,
}

struct Inner {
    file: File,
    pages: HashMap<usize, Page>,
    /// LRU clock: bumped on every page touch
    tick: u64,
    /// whether *any* page's accumulator has ever materialised — loads only
    /// read the accum region once this is set (before that the region is
    /// all holes and the in-RAM backend would report empty state too)
    any_state: bool,
    finalized: bool,
}

/// One embedding table backed by a page file on disk, behind a single lock
/// (page grouping keeps lock hold times to one optimizer apply per page).
pub struct PagedTable {
    rows: usize,
    dim: usize,
    page_rows: usize,
    n_pages: usize,
    budget_pages: usize,
    path: PathBuf,
    inner: Mutex<Inner>,
    tele: Option<Arc<Telemetry>>,
}

impl PagedTable {
    fn create(
        path: PathBuf,
        rows: usize,
        dim: usize,
        page_rows: usize,
        budget_bytes: usize,
        init: Option<Vec<f32>>,
    ) -> Result<PagedTable> {
        assert!(rows > 0 && dim > 0, "paged table must be non-empty");
        let page_rows = page_rows.clamp(1, rows);
        let n_pages = rows.div_ceil(page_rows);
        // worst-case resident cost of one page: values + accumulator
        let page_cost = page_rows * dim * 8;
        let budget_pages = (budget_bytes / page_cost).max(1);
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)
            .with_context(|| format!("creating page file {path:?}"))?;
        write_header(&mut file, STATE_OPEN, rows as u64, dim as u32, page_rows as u32)?;
        if let Some(values) = &init {
            assert_eq!(values.len(), rows * dim, "table shape mismatch");
            write_f32s(&mut file, HEADER_BYTES, values)?;
        }
        // full length up front: the untouched remainder (and the whole accum
        // region) stays a sparse hole reading back as zeros
        file.set_len(HEADER_BYTES + (rows * dim * 8) as u64)?;
        Ok(PagedTable {
            rows,
            dim,
            page_rows,
            n_pages,
            budget_pages,
            path,
            inner: Mutex::new(Inner {
                file,
                pages: HashMap::new(),
                tick: 0,
                any_state: false,
                finalized: false,
            }),
            tele: None,
        })
    }

    /// Create a page file holding `values` (row-major `rows × dim`).
    pub fn from_dense(
        path: PathBuf,
        rows: usize,
        dim: usize,
        values: Vec<f32>,
        page_rows: usize,
        budget_bytes: usize,
    ) -> Result<PagedTable> {
        Self::create(path, rows, dim, page_rows, budget_bytes, Some(values))
    }

    /// Create a zero-initialised table without materialising `rows × dim`
    /// floats anywhere — the file is one big hole (the `fullscale` harness
    /// opens its 10⁸-row table this way).
    pub fn create_zeroed(
        path: PathBuf,
        rows: usize,
        dim: usize,
        page_rows: usize,
        budget_bytes: usize,
    ) -> Result<PagedTable> {
        Self::create(path, rows, dim, page_rows, budget_bytes, None)
    }

    /// Report page loads/evictions to `tele`'s resident-store-bytes gauge.
    pub fn with_telemetry(mut self, tele: Arc<Telemetry>) -> PagedTable {
        self.tele = Some(tele);
        self
    }

    /// Reject a page file that was not cleanly closed: a header still in
    /// the *open* state means the writing process died mid-run and the
    /// file's scatters may be partially applied.
    pub fn check_clean(path: &Path) -> Result<()> {
        let mut file =
            File::open(path).with_context(|| format!("opening page file {path:?}"))?;
        let mut h = [0u8; HEADER_BYTES as usize];
        file.read_exact(&mut h)
            .with_context(|| format!("reading page-file header of {path:?}"))?;
        let magic = u64::from_le_bytes(h[0..8].try_into().unwrap());
        let version = u32::from_le_bytes(h[8..12].try_into().unwrap());
        let state = u32::from_le_bytes(h[12..16].try_into().unwrap());
        if magic != MAGIC {
            bail!("{path:?} is not a page file");
        }
        if version != VERSION {
            bail!("{path:?}: unsupported page-file version {version}");
        }
        if state != STATE_CLEAN {
            bail!(
                "{path:?} was not cleanly closed — the writing process died \
                 mid-run, so its scatters may be partially applied; discard it"
            );
        }
        Ok(())
    }

    /// Total row count of the table.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Row width (embedding dimension).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Rows per fixed-size page (the last page may be short).
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// Maximum pages the LRU cache may hold.
    pub fn budget_pages(&self) -> usize {
        self.budget_pages
    }

    /// Pages currently resident in the cache.
    pub fn resident_pages(&self) -> usize {
        self.inner.lock().unwrap().pages.len()
    }

    /// Bytes currently resident in the cache (values + materialised accum).
    pub fn resident_bytes(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner
            .pages
            .values()
            .map(|p| ((p.values.len() + p.state.accum().len()) * 4) as u64)
            .sum()
    }

    /// The page file's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    fn page_span(&self, idx: usize) -> (usize, usize) {
        let lo = idx * self.page_rows;
        (lo, (lo + self.page_rows).min(self.rows))
    }

    fn values_off(&self, row: usize) -> u64 {
        HEADER_BYTES + (row * self.dim * 4) as u64
    }

    fn accum_off(&self, row: usize) -> u64 {
        HEADER_BYTES + ((self.rows + row) * self.dim * 4) as u64
    }

    fn evict_lru(&self, inner: &mut Inner) -> Result<()> {
        let idx = *inner
            .pages
            .iter()
            .min_by_key(|(_, p)| p.last_used)
            .map(|(i, _)| i)
            .expect("evict on an empty page cache");
        let page = inner.pages.remove(&idx).unwrap();
        let bytes = ((page.values.len() + page.state.accum().len()) * 4) as u64;
        if page.dirty {
            let (lo, _) = self.page_span(idx);
            write_f32s(&mut inner.file, self.values_off(lo), &page.values)?;
            if !page.state.accum().is_empty() {
                write_f32s(&mut inner.file, self.accum_off(lo), page.state.accum())?;
            }
        }
        if let Some(t) = &self.tele {
            t.store_resident_sub(bytes);
        }
        Ok(())
    }

    fn load_page(&self, inner: &mut Inner, idx: usize) -> Result<()> {
        while inner.pages.len() >= self.budget_pages {
            self.evict_lru(inner)?;
        }
        let (lo, hi) = self.page_span(idx);
        let n = (hi - lo) * self.dim;
        let mut values = vec![0f32; n];
        read_f32s(&mut inner.file, self.values_off(lo), &mut values)?;
        let state = if inner.any_state {
            let mut accum = vec![0f32; n];
            read_f32s(&mut inner.file, self.accum_off(lo), &mut accum)?;
            DenseState::from_accum(accum)
        } else {
            DenseState::default()
        };
        let bytes = ((values.len() + state.accum().len()) * 4) as u64;
        inner.pages.insert(idx, Page { values, state, dirty: false, last_used: 0 });
        if let Some(t) = &self.tele {
            t.store_resident_add(bytes);
        }
        Ok(())
    }

    fn touch<'a>(&self, inner: &'a mut Inner, idx: usize) -> Result<&'a mut Page> {
        if !inner.pages.contains_key(&idx) {
            self.load_page(inner, idx)?;
        }
        inner.tick += 1;
        let tick = inner.tick;
        let page = inner.pages.get_mut(&idx).unwrap();
        page.last_used = tick;
        Ok(page)
    }

    fn apply_to_page(
        &self,
        inner: &mut Inner,
        idx: usize,
        f: impl FnOnce(&mut [f32], &mut DenseState),
    ) -> Result<()> {
        let grew = {
            let page = self.touch(inner, idx)?;
            let before = page.state.accum().len();
            f(&mut page.values, &mut page.state);
            page.dirty = true;
            page.state.accum().len() - before
        };
        if grew > 0 {
            inner.any_state = true;
            if let Some(t) = &self.tele {
                t.store_resident_add((grew * 4) as u64);
            }
        }
        Ok(())
    }

    /// Copy one row out (the `select` half), loading its page on a miss.
    pub fn read_row(&self, row: usize, out: &mut [f32]) -> Result<()> {
        debug_assert!(row < self.rows, "row {row} out of range");
        let idx = row / self.page_rows;
        let local = row - idx * self.page_rows;
        let d = self.dim;
        let mut inner = self.inner.lock().unwrap();
        let page = self.touch(&mut inner, idx)?;
        out.copy_from_slice(&page.values[local * d..(local + 1) * d]);
        Ok(())
    }

    /// Scatter a row-sparse optimizer update, touching only the pages
    /// holding present rows.  The gradient holds each row once (repeats are
    /// pre-accumulated by [`RowSparseGrad::add_row`]) and the optimizer
    /// treats rows independently, so the per-page
    /// [`Optimizer::sparse_step`] calls are bitwise identical to one flat
    /// application.
    pub fn apply_sparse(&self, grad: &RowSparseGrad, opt: &Optimizer) -> Result<()> {
        debug_assert_eq!(grad.dim, self.dim);
        let mut groups: BTreeMap<usize, RowSparseGrad> = BTreeMap::new();
        for (row, vals) in grad.iter_rows() {
            let idx = row as usize / self.page_rows;
            let local = row as usize - idx * self.page_rows;
            let (lo, hi) = self.page_span(idx);
            groups
                .entry(idx)
                .or_insert_with(|| {
                    RowSparseGrad::with_capacity(hi - lo, self.dim, grad.nnz_rows())
                })
                .add_row(local as u32, vals);
        }
        let mut inner = self.inner.lock().unwrap();
        for (idx, g) in &groups {
            self.apply_to_page(&mut inner, *idx, |values, state| {
                opt.sparse_step(values, g, state)
            })?;
        }
        Ok(())
    }

    /// Dense update over every row (the DP-SGD embedding baseline), page by
    /// page in row order.
    pub fn apply_dense(&self, grad: &[f32], opt: &Optimizer) -> Result<()> {
        assert_eq!(grad.len(), self.rows * self.dim);
        let d = self.dim;
        let mut inner = self.inner.lock().unwrap();
        for idx in 0..self.n_pages {
            let (lo, hi) = self.page_span(idx);
            self.apply_to_page(&mut inner, idx, |values, state| {
                opt.dense_step(values, &grad[lo * d..hi * d], state)
            })?;
        }
        Ok(())
    }

    /// Reassemble `(values, adagrad accumulator)` — disk regions overlaid
    /// with the resident pages — then mark the header clean and remove the
    /// page file.  The accumulator is empty when the optimizer never
    /// materialised state, matching the in-RAM backend's contract.
    pub fn into_dense(self) -> Result<(Vec<f32>, Vec<f32>)> {
        let n = self.rows * self.dim;
        let out = {
            let mut inner = self.inner.lock().unwrap();
            let mut values = vec![0f32; n];
            read_f32s(&mut inner.file, HEADER_BYTES, &mut values)?;
            let mut accum = if inner.any_state {
                let mut a = vec![0f32; n];
                read_f32s(&mut inner.file, self.accum_off(0), &mut a)?;
                a
            } else {
                Vec::new()
            };
            for (idx, page) in &inner.pages {
                let base = self.page_span(*idx).0 * self.dim;
                values[base..base + page.values.len()].copy_from_slice(&page.values);
                let acc = page.state.accum();
                if !acc.is_empty() {
                    accum[base..base + acc.len()].copy_from_slice(acc);
                }
            }
            if let Some(t) = &self.tele {
                let resident: u64 = inner
                    .pages
                    .values()
                    .map(|p| ((p.values.len() + p.state.accum().len()) * 4) as u64)
                    .sum();
                t.store_resident_sub(resident);
            }
            write_header_state(&mut inner.file, STATE_CLEAN)?;
            inner.finalized = true;
            (values, accum)
        };
        let _ = std::fs::remove_file(&self.path);
        Ok(out)
    }
}

impl Drop for PagedTable {
    fn drop(&mut self) {
        // best-effort cleanup on non-finalized drops (error paths); a hard
        // process death skips this, leaving the open-state file behind for
        // check_clean to reject
        let finalized = match self.inner.get_mut() {
            Ok(inner) => inner.finalized,
            Err(poisoned) => poisoned.into_inner().finalized,
        };
        if !finalized {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

fn write_header(
    file: &mut File,
    state: u32,
    rows: u64,
    dim: u32,
    page_rows: u32,
) -> Result<()> {
    let mut h = [0u8; HEADER_BYTES as usize];
    h[0..8].copy_from_slice(&MAGIC.to_le_bytes());
    h[8..12].copy_from_slice(&VERSION.to_le_bytes());
    h[12..16].copy_from_slice(&state.to_le_bytes());
    h[16..24].copy_from_slice(&rows.to_le_bytes());
    h[24..28].copy_from_slice(&dim.to_le_bytes());
    h[28..32].copy_from_slice(&page_rows.to_le_bytes());
    file.seek(SeekFrom::Start(0))?;
    file.write_all(&h)?;
    Ok(())
}

fn write_header_state(file: &mut File, state: u32) -> Result<()> {
    file.seek(SeekFrom::Start(12))?;
    file.write_all(&state.to_le_bytes())?;
    Ok(())
}

/// Write floats as little-endian bit patterns at `off`.
fn write_f32s(file: &mut File, off: u64, vals: &[f32]) -> Result<()> {
    file.seek(SeekFrom::Start(off))?;
    let mut buf = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    file.write_all(&buf)?;
    Ok(())
}

/// Read floats (little-endian bit patterns) at `off`; holes read as zeros.
fn read_f32s(file: &mut File, off: u64, out: &mut [f32]) -> Result<()> {
    file.seek(SeekFrom::Start(off))?;
    let mut buf = vec![0u8; out.len() * 4];
    file.read_exact(&mut buf)?;
    for (o, c) in out.iter_mut().zip(buf.chunks_exact(4)) {
        *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}
