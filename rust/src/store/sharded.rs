//! Sharded in-RAM parameter store for the async engine.
//!
//! Embedding tables are partitioned into contiguous **row-range shards**,
//! each behind its own `Mutex`, so sparse row updates apply concurrently
//! without contending on dense parameters (which each sit behind their own
//! lock and are only ever updated by the aggregation barrier).  Row-disjoint
//! updates commute bitwise — Adagrad/SGD touch each coordinate
//! independently — so shard-parallel application is deterministic no matter
//! how the scheduler interleaves shard locks; `tests/engine.rs` and
//! `tests/store.rs` check this under the in-repo property harness.
//!
//! [`ShardedStore`] also hosts the file-backed [`PagedTable`] backend:
//! when the run sets `--store-budget-mb`, each embedding slot holds a
//! [`TableStore::Paged`] instead of the in-RAM [`TableStore::Ram`]
//! (see [`ShardedStore::from_store_with`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::paged::unique_path;
use super::{default_page_rows, PagedTable, StoreOptions, TableStore};
use crate::coordinator::step::ParamSink;
use crate::models::{Param, ParamStore};
use crate::runtime::HostTensor;
use crate::sparse::{DenseState, Optimizer, RowSparseGrad};

/// Row count above which a sparse update fans out across shard threads.
/// Below it the per-thread spawn cost dominates (criteo-small steps touch a
/// few hundred rows; tab4-scale tables touch tens of thousands).
const PARALLEL_ROW_THRESHOLD: usize = 4096;

struct TableShard {
    /// rows `[shard_index * rows_per_shard, …)` of the table, row-major
    values: Vec<f32>,
    state: DenseState,
}

/// One embedding table split into row-range shards.
pub struct ShardedTable {
    /// total row count of the table
    pub rows: usize,
    /// row width (embedding dimension)
    pub dim: usize,
    rows_per_shard: usize,
    shards: Vec<Mutex<TableShard>>,
}

impl ShardedTable {
    /// Split a row-major dense table into `num_shards` contiguous row
    /// ranges (clamped to at most one shard per row).
    pub fn from_dense(
        rows: usize,
        dim: usize,
        mut values: Vec<f32>,
        num_shards: usize,
    ) -> ShardedTable {
        assert_eq!(values.len(), rows * dim, "table shape mismatch");
        let num_shards = num_shards.clamp(1, rows.max(1));
        let rows_per_shard = rows.div_ceil(num_shards);
        // Drain the input back to front: `split_off` moves one shard's rows
        // out, `shrink_to_fit` releases the emptied tail (in place for
        // large allocations), so peak extra memory is one shard — not the
        // second full copy a slice-and-`to_vec` split would transiently hold.
        let mut shards_rev = Vec::with_capacity(num_shards);
        let mut row = rows;
        while row > 0 {
            let lo = ((row - 1) / rows_per_shard) * rows_per_shard;
            let tail = values.split_off(lo * dim);
            values.shrink_to_fit();
            shards_rev.push(Mutex::new(TableShard {
                values: tail,
                state: DenseState::default(),
            }));
            row = lo;
        }
        shards_rev.reverse();
        ShardedTable { rows, dim, rows_per_shard, shards: shards_rev }
    }

    /// How many row-range shards the table was split into.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard_of(&self, row: usize) -> (usize, usize) {
        (row / self.rows_per_shard, row % self.rows_per_shard)
    }

    /// Copy one row out (the gradient workers' embedding lookup).
    pub fn read_row(&self, row: usize, out: &mut [f32]) {
        debug_assert!(row < self.rows, "row {row} out of range");
        let (si, local) = self.shard_of(row);
        let shard = self.shards[si].lock().unwrap();
        out.copy_from_slice(&shard.values[local * self.dim..(local + 1) * self.dim]);
    }

    fn apply_group(&self, shard_index: usize, grad: &RowSparseGrad, opt: &Optimizer) {
        let mut shard = self.shards[shard_index].lock().unwrap();
        let TableShard { values, state } = &mut *shard;
        opt.sparse_step(values, grad, state);
    }

    /// Scatter a row-sparse update.  Rows are grouped by shard; groups apply
    /// under their own locks — in parallel when the update is large enough.
    /// Safe to call concurrently from several threads.
    pub fn apply_sparse(&self, grad: &RowSparseGrad, opt: &Optimizer) {
        debug_assert_eq!(grad.dim, self.dim);
        // group rows by shard, re-indexed to shard-local row ids
        let mut groups: Vec<Option<RowSparseGrad>> = (0..self.shards.len()).map(|_| None).collect();
        let shard_rows = self.rows_per_shard;
        for (row, vals) in grad.iter_rows() {
            let (si, local) = self.shard_of(row as usize);
            groups[si]
                .get_or_insert_with(|| {
                    RowSparseGrad::with_capacity(shard_rows, self.dim, grad.nnz_rows())
                })
                .add_row(local as u32, vals);
        }
        let groups: Vec<(usize, RowSparseGrad)> = groups
            .into_iter()
            .enumerate()
            .filter_map(|(si, g)| g.map(|g| (si, g)))
            .collect();
        if grad.nnz_rows() >= PARALLEL_ROW_THRESHOLD && groups.len() > 1 {
            std::thread::scope(|scope| {
                for (si, g) in &groups {
                    scope.spawn(move || self.apply_group(*si, g, opt));
                }
            });
        } else {
            for (si, g) in &groups {
                self.apply_group(*si, g, opt);
            }
        }
    }

    /// Dense update over every row (the DP-SGD embedding baseline), shard by
    /// shard.
    pub fn apply_dense(&self, grad: &[f32], opt: &Optimizer) {
        assert_eq!(grad.len(), self.rows * self.dim);
        let d = self.dim;
        let per = self.rows_per_shard;
        if self.rows >= PARALLEL_ROW_THRESHOLD && self.shards.len() > 1 {
            std::thread::scope(|scope| {
                for (si, shard) in self.shards.iter().enumerate() {
                    let lo = si * per * d;
                    scope.spawn(move || {
                        let mut s = shard.lock().unwrap();
                        let TableShard { values, state } = &mut *s;
                        let hi = lo + values.len();
                        opt.dense_step(values, &grad[lo..hi], state);
                    });
                }
            });
        } else {
            for (si, shard) in self.shards.iter().enumerate() {
                let mut s = shard.lock().unwrap();
                let TableShard { values, state } = &mut *s;
                let lo = si * per * d;
                let hi = lo + values.len();
                opt.dense_step(values, &grad[lo..hi], state);
            }
        }
    }

    /// Reassemble `(values, adagrad accumulator)`; the accumulator is empty
    /// when no shard was ever touched by Adagrad.
    pub fn into_dense(self) -> (Vec<f32>, Vec<f32>) {
        let mut values = Vec::with_capacity(self.rows * self.dim);
        let mut accum = Vec::with_capacity(self.rows * self.dim);
        let mut any_state = false;
        for shard in self.shards {
            let shard = shard.into_inner().unwrap();
            let n = shard.values.len();
            values.extend_from_slice(&shard.values);
            let acc = shard.state.into_accum();
            if acc.is_empty() {
                accum.resize(accum.len() + n, 0.0);
            } else {
                any_state = true;
                accum.extend_from_slice(&acc);
            }
        }
        if !any_state {
            accum.clear();
        }
        (values, accum)
    }
}

struct DenseSlot {
    values: Vec<f32>,
    state: DenseState,
}

enum SlotBody {
    Dense(Mutex<DenseSlot>),
    Sharded(TableStore),
}

struct ParamSlot {
    name: String,
    trainable: bool,
    dims: Vec<usize>,
    body: SlotBody,
}

/// The engine's parameter store: embedding tables sharded (in RAM) or paged
/// (on disk), everything else behind per-parameter locks.  All methods take
/// `&self`; the store is shared by reference across the worker scope.
pub struct ShardedStore {
    model_name: String,
    kind: String,
    slots: Vec<ParamSlot>,
    /// Snapshot version: how many optimizer steps have been applied to the
    /// store.  The aggregation barrier bumps it once per applied step, and
    /// tags each step's read-only snapshot with the epoch it was taken at,
    /// so the bounded-staleness pipeline can report *exactly* how stale the
    /// parameters a step computed against were (`docs/CONCURRENCY.md`).
    epoch: AtomicU64,
}

impl ShardedStore {
    /// Partition a [`ParamStore`] with the in-RAM backend: parameters whose
    /// index is in `sharded_indices` (the embedding tables) get `num_shards`
    /// row shards.
    pub fn from_store(
        store: ParamStore,
        sharded_indices: &[usize],
        num_shards: usize,
    ) -> Result<ShardedStore> {
        Self::from_store_with(store, sharded_indices, num_shards, &StoreOptions::ram())
    }

    /// Partition a [`ParamStore`], choosing the embedding backend from
    /// `opts`: in-RAM row shards at the default budget 0, or file-backed
    /// pages under an LRU cache otherwise.  A non-zero budget is split
    /// evenly across the embedding tables; page files go in the resolved
    /// store dir and report to the resident-bytes gauge when a telemetry
    /// hub is attached.
    pub fn from_store_with(
        store: ParamStore,
        sharded_indices: &[usize],
        num_shards: usize,
        opts: &StoreOptions,
    ) -> Result<ShardedStore> {
        let model_name = store.model_name.clone();
        let kind = store.kind.clone();
        let per_table_budget =
            (opts.budget_mb * 1024 * 1024) / sharded_indices.len().max(1);
        let dir = StoreOptions::resolve_dir(&opts.dir);
        let mut slots = Vec::with_capacity(store.params.len());
        for (i, p) in store.params.into_iter().enumerate() {
            let Param { name, trainable, tensor, opt_state } = p;
            let dims = tensor.dims().to_vec();
            let values = tensor.into_f32()?;
            let body = if sharded_indices.contains(&i) {
                if dims.len() != 2 {
                    bail!("sharded param {name} must be 2-D, got {dims:?}");
                }
                if !opt_state.accum().is_empty() {
                    // Splitting a live accumulator across shards is not
                    // implemented; silently resetting it would break the
                    // bit-equivalence contract on warm starts.
                    bail!(
                        "sharded param {name} already has optimizer state; \
                         warm-starting the engine is not supported yet"
                    );
                }
                let table = if opts.budget_mb > 0 {
                    let mut t = PagedTable::from_dense(
                        unique_path(&dir, &format!("p{i}")),
                        dims[0],
                        dims[1],
                        values,
                        default_page_rows(dims[1]),
                        per_table_budget.max(1),
                    )?;
                    if let Some(tele) = &opts.tele {
                        t = t.with_telemetry(Arc::clone(tele));
                    }
                    TableStore::Paged(t)
                } else {
                    TableStore::Ram(ShardedTable::from_dense(
                        dims[0], dims[1], values, num_shards,
                    ))
                };
                SlotBody::Sharded(table)
            } else {
                SlotBody::Dense(Mutex::new(DenseSlot { values, state: opt_state }))
            };
            slots.push(ParamSlot { name, trainable, dims, body });
        }
        Ok(ShardedStore { model_name, kind, slots, epoch: AtomicU64::new(0) })
    }

    /// The store's snapshot version — the number of optimizer steps applied
    /// so far.  A snapshot taken at epoch `e` and consumed by step `t` is
    /// `t − e` steps stale (0 at the default `--engine-staleness 0`).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Advance the snapshot version by one applied step (called by the
    /// aggregation barrier after every `apply_update`).
    pub fn bump_epoch(&self) {
        self.epoch.fetch_add(1, Ordering::Release);
    }

    /// Number of parameter slots (same indexing as the source store).
    pub fn num_params(&self) -> usize {
        self.slots.len()
    }

    /// Row width (second dimension) of embedding parameter `index` — the
    /// buffer size a [`read_emb_row`](ShardedStore::read_emb_row) caller
    /// must provide, and what the engine's per-step row cache allocates.
    pub fn emb_row_dim(&self, index: usize) -> usize {
        self.slots[index].dims[1]
    }

    /// Embedding lookup for the gradient workers.
    pub fn read_emb_row(&self, param_index: usize, row: usize, out: &mut [f32]) {
        match &self.slots[param_index].body {
            SlotBody::Sharded(t) => t.read_row(row, out),
            SlotBody::Dense(m) => {
                let d = out.len();
                let s = m.lock().unwrap();
                out.copy_from_slice(&s.values[row * d..(row + 1) * d]);
            }
        }
    }

    /// Whether parameter `index` is trainable.  Frozen dense params never
    /// receive updates, so the engine snapshots them once per run instead
    /// of once per step (the NLU backbone is >99% of the dense bytes).
    pub fn is_trainable(&self, index: usize) -> bool {
        self.slots[index].trainable
    }

    /// Clone the current values of the dense (non-sharded) parameter
    /// `index` — the building block of the gradient workers' per-step
    /// read-only view.
    pub fn dense_values(&self, index: usize) -> Vec<f32> {
        match &self.slots[index].body {
            SlotBody::Dense(m) => m.lock().unwrap().values.clone(),
            SlotBody::Sharded(_) => panic!("dense_values on a sharded param"),
        }
    }

    /// Backend the embedding tables live in: `"ram"` or `"paged"` (the
    /// first sharded slot decides — backends are never mixed in one run).
    pub fn backend_name(&self) -> &'static str {
        for slot in &self.slots {
            if let SlotBody::Sharded(t) = &slot.body {
                return t.backend_name();
            }
        }
        "ram"
    }

    /// Reassemble a plain [`ParamStore`] (for evaluation / checkpointing).
    pub fn into_store(self) -> Result<ParamStore> {
        let mut params = Vec::with_capacity(self.slots.len());
        for slot in self.slots {
            let ParamSlot { name, trainable, dims, body } = slot;
            let (values, state) = match body {
                SlotBody::Dense(m) => {
                    let s = m.into_inner().unwrap();
                    (s.values, s.state)
                }
                SlotBody::Sharded(t) => {
                    let (values, accum) = t.into_dense()?;
                    (values, DenseState::from_accum(accum))
                }
            };
            params.push(Param {
                name,
                trainable,
                tensor: HostTensor::f32(dims, values),
                opt_state: state,
            });
        }
        Ok(ParamStore { model_name: self.model_name, kind: self.kind, params })
    }

    fn slot(&self, index: usize) -> Result<&ParamSlot> {
        self.slots
            .get(index)
            .with_context(|| format!("param index {index} out of range"))
    }
}

/// The aggregation barrier applies updates through the shared step code via
/// this sink; interior mutability makes `&ShardedStore` sufficient.
impl ParamSink for &ShardedStore {
    fn apply_sparse(
        &mut self,
        param_index: usize,
        grad: &RowSparseGrad,
        opt: &Optimizer,
    ) -> Result<()> {
        match &self.slot(param_index)?.body {
            SlotBody::Sharded(t) => t.apply_sparse(grad, opt),
            SlotBody::Dense(_) => {
                bail!("sparse update aimed at dense param #{param_index}")
            }
        }
    }

    fn apply_dense(&mut self, param_index: usize, grad: &[f32], opt: &Optimizer) -> Result<()> {
        match &self.slot(param_index)?.body {
            SlotBody::Sharded(t) => t.apply_dense(grad, opt),
            SlotBody::Dense(m) => {
                let mut s = m.lock().unwrap();
                let DenseSlot { values, state } = &mut *s;
                opt.dense_step(values, grad, state);
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_grad(rows: usize, dim: usize, nnz: usize, seed: u64) -> RowSparseGrad {
        let mut rng = crate::util::rng::Xoshiro256::seed_from(seed);
        let mut g = RowSparseGrad::new(rows, dim);
        for _ in 0..nnz {
            let r = rng.below(rows as u64) as u32;
            let vals: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
            g.add_row(r, &vals);
        }
        g
    }

    #[test]
    fn sharded_sparse_update_matches_flat() {
        for &shards in &[1usize, 3, 8, 64] {
            let (rows, dim) = (100, 4);
            let init: Vec<f32> = (0..rows * dim).map(|i| (i as f32 * 0.01).sin()).collect();
            let g = sample_grad(rows, dim, 40, 9);
            let opt = Optimizer::adagrad(0.1);

            let mut flat = init.clone();
            let mut state = DenseState::default();
            opt.sparse_step(&mut flat, &g, &mut state);

            let table = ShardedTable::from_dense(rows, dim, init, shards);
            table.apply_sparse(&g, &opt);
            let (values, accum) = table.into_dense();
            assert_eq!(values, flat, "shards={shards}");
            assert_eq!(accum.len(), rows * dim);
            assert_eq!(accum, state.accum().to_vec(), "adagrad state, shards={shards}");
        }
    }

    #[test]
    fn sharded_dense_update_matches_flat() {
        let (rows, dim) = (64, 3);
        let init = vec![0.5f32; rows * dim];
        let grad: Vec<f32> = (0..rows * dim).map(|i| (i % 7) as f32 * 0.1 - 0.3).collect();
        let opt = Optimizer::sgd(0.2);
        let mut flat = init.clone();
        opt.dense_step(&mut flat, &grad, &mut DenseState::default());
        let table = ShardedTable::from_dense(rows, dim, init, 5);
        table.apply_dense(&grad, &opt);
        assert_eq!(table.into_dense().0, flat);
    }

    #[test]
    fn read_row_roundtrip() {
        let (rows, dim) = (10, 3);
        let init: Vec<f32> = (0..rows * dim).map(|i| i as f32).collect();
        let table = ShardedTable::from_dense(rows, dim, init.clone(), 4);
        let mut out = vec![0f32; dim];
        for r in 0..rows {
            table.read_row(r, &mut out);
            assert_eq!(out, &init[r * dim..(r + 1) * dim]);
        }
    }

    #[test]
    fn untouched_shards_leave_state_empty() {
        let table = ShardedTable::from_dense(8, 2, vec![1.0; 16], 4);
        let g = sample_grad(8, 2, 0, 1); // empty grad
        table.apply_sparse(&g, &Optimizer::adagrad(0.1));
        let (values, accum) = table.into_dense();
        assert_eq!(values, vec![1.0; 16]);
        assert!(accum.is_empty(), "no shard touched ⇒ no state materialised");
    }

    /// Regression for the drain-based `from_dense`: shard contents must be
    /// the same contiguous row ranges the old slice-and-copy split produced,
    /// across even/uneven splits and shard counts exceeding the row count.
    #[test]
    fn from_dense_drain_preserves_shard_contents() {
        for &(rows, dim, shards) in
            &[(100usize, 4usize, 7usize), (12, 3, 4), (5, 2, 9), (1, 6, 3), (64, 1, 64)]
        {
            let init: Vec<f32> = (0..rows * dim).map(|i| (i as f32).cos()).collect();
            let table = ShardedTable::from_dense(rows, dim, init.clone(), shards);
            assert!(table.num_shards() <= shards.min(rows));
            let mut out = vec![0f32; dim];
            for r in 0..rows {
                table.read_row(r, &mut out);
                assert_eq!(out, &init[r * dim..(r + 1) * dim], "row {r}, shards={shards}");
            }
            let (values, accum) = table.into_dense();
            assert_eq!(values, init, "rows={rows} shards={shards}");
            assert!(accum.is_empty());
        }
    }
}
