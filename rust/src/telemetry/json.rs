//! Minimal JSON value type, writer, and parser (no serde in the offline
//! crate set).  This is the wire format of the telemetry JSONL sink and the
//! `BENCH_*.json` snapshots — small by design: objects keep insertion order
//! (stable diffs), numbers are `f64` (every value the telemetry emits fits
//! losslessly below 2^53), and non-finite numbers serialize as `null`.

use std::fmt;

use anyhow::{bail, Result};

/// A parsed or to-be-written JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any number (integers print without a fractional part)
    Num(f64),
    /// a string
    Str(String),
    /// an array
    Arr(Vec<Json>),
    /// an object — `(key, value)` pairs in insertion order
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// A number value; non-finite inputs become [`Json::Null`] (JSON has no
    /// representation for them).
    pub fn num(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(v)
        } else {
            Json::Null
        }
    }

    /// A string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The numeric value as a non-negative integer, if it is one exactly.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= 2f64.powi(53) => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The element list, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    /// Multi-line rendering with two-space indentation (the `BENCH_*.json`
    /// on-disk format — line-oriented for reviewable diffs).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    push_indent(out, depth + 1);
                    item.write_pretty(out, depth + 1);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                push_indent(out, depth);
                out.push(']');
            }
            Json::Obj(fields) if !fields.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in fields.iter().enumerate() {
                    push_indent(out, depth + 1);
                    out.push_str(&format!("{}: ", Json::Str(k.clone())));
                    v.write_pretty(out, depth + 1);
                    if i + 1 < fields.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                push_indent(out, depth);
                out.push('}');
            }
            other => out.push_str(&other.to_string()),
        }
    }
}

fn push_indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

impl fmt::Display for Json {
    /// Compact single-line rendering (the JSONL sink format).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            // Rust's f64 Display is the shortest representation that parses
            // back to the same bits, so Num round-trips exactly.
            Json::Num(v) if v.is_finite() => write!(f, "{v}"),
            Json::Num(_) => write!(f, "null"),
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(fields) => {
                write!(f, "{{")?;
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {}", c as char, self.i);
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i);
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.b.get(self.i) {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.i += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.b.get(self.i) == Some(&b']') {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.b.get(self.i) {
                        Some(b',') => {
                            self.i += 1;
                            self.skip_ws();
                        }
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => bail!("expected `,` or `]` at byte {}", self.i),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut fields = Vec::new();
                self.skip_ws();
                if self.b.get(self.i) == Some(&b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    self.skip_ws();
                    fields.push((k, self.value()?));
                    self.skip_ws();
                    match self.b.get(self.i) {
                        Some(b',') => {
                            self.i += 1;
                            self.skip_ws();
                        }
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => bail!("expected `,` or `}}` at byte {}", self.i),
                    }
                }
            }
            Some(c) if *c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected byte at {}", self.i),
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(&c) = self.b.get(self.i) {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let v: f64 = text
            .parse()
            .map_err(|_| anyhow::anyhow!("bad number `{text}` at byte {start}"))?;
        Ok(Json::Num(v))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            // strings are valid UTF-8 (the input is &str), so decode at the
            // char level past the escape handling
            let rest = std::str::from_utf8(&self.b[self.i..])?;
            let Some(c) = rest.chars().next() else {
                bail!("unterminated string");
            };
            self.i += c.len_utf8();
            match c {
                '"' => return Ok(out),
                '\\' => {
                    let Some(&e) = self.b.get(self.i) else {
                        bail!("unterminated escape");
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: require the low half
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("bad low surrogate at byte {}", self.i);
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            match char::from_u32(code) {
                                Some(c) => out.push(c),
                                None => bail!("bad \\u escape at byte {}", self.i),
                            }
                        }
                        other => bail!("bad escape `\\{}` ", other as char),
                    }
                }
                c => out.push(c),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("truncated \\u escape");
        }
        let text = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        self.i += 4;
        u32::from_str_radix(text, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u digits `{text}`"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compact() {
        let v = Json::Obj(vec![
            ("a".into(), Json::Num(1.0)),
            ("b".into(), Json::Num(-2.5)),
            ("c".into(), Json::str("x\"y\\z\nw")),
            ("d".into(), Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("e".into(), Json::Obj(vec![])),
            ("f".into(), Json::Num(1.2345678901234567e-9)),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::Obj(vec![
            ("rows".into(), Json::Arr(vec![Json::Num(3.0), Json::str("x")])),
            ("empty".into(), Json::Arr(vec![])),
        ]);
        let back = Json::parse(&v.pretty()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(60.0).to_string(), "60");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::num(f64::INFINITY), Json::Null);
        assert_eq!(Json::num(f64::NAN), Json::Null);
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#"{"s":"aA\né λ","t":"😀"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "aA\né λ");
        assert_eq!(v.get("t").unwrap().as_str().unwrap(), "😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n":3,"s":"x","a":[1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("n").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.get("missing").is_none());
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }
}
