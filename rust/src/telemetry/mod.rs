#![warn(missing_docs)]
//! Passive run telemetry: per-stage span timers, channel queue-depth gauges,
//! paper-semantic per-step metrics, and a JSONL sink.
//!
//! The subsystem is zero-dependency (no tracing/prometheus in the offline
//! crate set) and **strictly passive**: every probe is an atomic counter or a
//! monotonic-clock read.  Nothing here draws randomness, reorders reductions,
//! or alters the channel protocol, so the engine's three bit-exactness
//! invariants hold with telemetry enabled — the sync==async equality suite
//! runs with a live sink to enforce exactly that.
//!
//! Three layers of signal:
//!
//! * **Pipeline spans** ([`Stage`]) — wall time per engine stage (data-worker
//!   generate, channel send/recv waits, chunk compute, barrier
//!   collect/noise/scatter), accumulated into lock-free cells.
//! * **Queue gauges** ([`Queue`]) — instantaneous and high-water depth of the
//!   batch and task channels, for backpressure visibility.  Producers
//!   increment *before* a blocking send, so the depth counts in-flight plus
//!   blocked messages and never goes negative.
//! * **Paper gauges** ([`StepRecord`]) — unique rows touched, survivors after
//!   selection, per-step gradient-size reduction factor vs. the dense `V·d`
//!   baseline, and cumulative `(ε, δ)` spent.  Both trainers emit these from
//!   the shared step core, so two traces are comparable row-for-row.
//!
//! The JSONL schema and the span taxonomy are documented in
//! `docs/OBSERVABILITY.md`.  Bench snapshots (`BENCH_engine.json`) reuse the
//! same hand-rolled [`json::Json`] layer via [`BenchSnapshot`].

pub mod json;

use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use json::Json;

/// A pipeline stage measured by a [`Span`].
///
/// The first six stages only tick in the async engine (the sync trainer has
/// no channels); `ChunkCompute` through `Scatter` tick in both back ends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Data worker: synthesize one batch (example generation + encoding).
    DataGenerate,
    /// Data worker: blocking send of a batch into the bounded batch channel.
    DataSend,
    /// Step loop: blocking receive waiting for the next in-order batch.
    BatchWait,
    /// Step loop: build the read-only parameter snapshot (row cache + dense).
    Snapshot,
    /// Grad worker: blocking receive waiting for the next chunk task.
    TaskWait,
    /// Per-chunk backward pass (fixed 16-example reduction chunks).
    ChunkCompute,
    /// Step loop: merge chunk results in chunk order at the barrier.
    Collect,
    /// Assemble the merged chunks into a gradient bundle.
    Assemble,
    /// Survivor selection (FEST / AdaFEST / exponential mechanism).
    Select,
    /// Noise injection (dense or row-sparse Gaussian).
    Noise,
    /// Scatter: apply the noised update back into the parameter store.
    Scatter,
}

impl Stage {
    /// Number of stages (length of [`Stage::ALL`]).
    pub const COUNT: usize = 11;

    /// Every stage, in pipeline order.
    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::DataGenerate,
        Stage::DataSend,
        Stage::BatchWait,
        Stage::Snapshot,
        Stage::TaskWait,
        Stage::ChunkCompute,
        Stage::Collect,
        Stage::Assemble,
        Stage::Select,
        Stage::Noise,
        Stage::Scatter,
    ];

    /// Stable snake_case identifier used in the JSONL schema.
    pub fn name(self) -> &'static str {
        match self {
            Stage::DataGenerate => "data_generate",
            Stage::DataSend => "data_send",
            Stage::BatchWait => "batch_wait",
            Stage::Snapshot => "snapshot",
            Stage::TaskWait => "task_wait",
            Stage::ChunkCompute => "chunk_compute",
            Stage::Collect => "collect",
            Stage::Assemble => "assemble",
            Stage::Select => "select",
            Stage::Noise => "noise",
            Stage::Scatter => "scatter",
        }
    }
}

/// A channel whose depth is tracked by a gauge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Queue {
    /// The bounded batch channel (data workers → step loop).
    Batch,
    /// The unbounded chunk-task channel (step loop → grad workers).
    Task,
}

#[derive(Default)]
struct StageCell {
    nanos: AtomicU64,
    count: AtomicU64,
}

#[derive(Default)]
struct QueueGauge {
    depth: AtomicI64,
    max: AtomicI64,
}

impl QueueGauge {
    fn inc(&self) {
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.max.fetch_max(d, Ordering::Relaxed);
    }

    fn dec(&self) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
    }

    fn depth(&self) -> u64 {
        self.depth.load(Ordering::Relaxed).max(0) as u64
    }

    fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed).max(0) as u64
    }
}

struct SinkState {
    w: Option<BufWriter<File>>,
    /// `(nanos, count)` per stage at the previous record, for per-step deltas.
    last: [(u64, u64); Stage::COUNT],
}

/// Shared telemetry hub for one training run.
///
/// One instance lives in the step state and is shared (via `Arc`) with every
/// pipeline worker.  All mutation is through `&self` — relaxed atomics for
/// counters and a mutex only around the optional JSONL writer — so a single
/// hub can be probed concurrently from every thread of the engine.
pub struct Telemetry {
    stages: [StageCell; Stage::COUNT],
    batch_queue: QueueGauge,
    task_queue: QueueGauge,
    /// snapshot age (in applied steps) of the update most recently applied —
    /// the engine's `--engine-staleness` gauge; stays 0 on the sync path
    staleness: AtomicU64,
    staleness_max: AtomicU64,
    /// bytes currently resident in paged-store page caches (summed across
    /// every paged table reporting to this hub); stays 0 for in-RAM runs
    store_resident: AtomicU64,
    store_resident_max: AtomicU64,
    records: AtomicU64,
    started: Instant,
    sink: Mutex<SinkState>,
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new()
    }
}

impl Telemetry {
    /// A hub with no sink: counters and spans work, `record_step` only counts.
    pub fn new() -> Telemetry {
        Telemetry {
            stages: std::array::from_fn(|_| StageCell::default()),
            batch_queue: QueueGauge::default(),
            task_queue: QueueGauge::default(),
            staleness: AtomicU64::new(0),
            staleness_max: AtomicU64::new(0),
            store_resident: AtomicU64::new(0),
            store_resident_max: AtomicU64::new(0),
            records: AtomicU64::new(0),
            started: Instant::now(),
            sink: Mutex::new(SinkState {
                w: None,
                last: [(0, 0); Stage::COUNT],
            }),
        }
    }

    /// A hub that additionally streams JSONL to `path` (`None` → no sink,
    /// same as [`Telemetry::new`]).  The file is created eagerly so a bad
    /// path fails at startup, not mid-run.
    pub fn with_sink(path: Option<&str>) -> Result<Telemetry> {
        let tele = Telemetry::new();
        if let Some(path) = path {
            let file = File::create(path)
                .with_context(|| format!("creating metrics sink {path}"))?;
            tele.sink.lock().unwrap().w = Some(BufWriter::new(file));
        }
        Ok(tele)
    }

    /// Start a span for `stage`; elapsed wall time is added when the returned
    /// guard drops.
    #[must_use = "a span measures until dropped — bind it across the timed region"]
    pub fn span(&self, stage: Stage) -> Span<'_> {
        Span {
            tele: self,
            stage,
            t0: Instant::now(),
        }
    }

    /// Run `f` under a span for `stage` and return its result.
    pub fn time<T>(&self, stage: Stage, f: impl FnOnce() -> T) -> T {
        let _span = self.span(stage);
        f()
    }

    /// Add one completed occurrence of `stage` taking `nanos`.
    pub fn add_nanos(&self, stage: Stage, nanos: u64) {
        let cell = &self.stages[stage as usize];
        cell.nanos.fetch_add(nanos, Ordering::Relaxed);
        cell.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Merge pre-aggregated `(stage, nanos, count)` totals into this hub —
    /// how actor processes' stage timers land in the barrier's summary
    /// (`engine::actor` ships them back inside `DataDone` /
    /// `FinalizeResult` frames).  Unlike [`Telemetry::add_nanos`] this adds
    /// `count` occurrences, not one, so merged summaries keep the same
    /// per-step span arithmetic as in-process runs.
    pub fn merge_stage_totals(&self, totals: &[(Stage, u64, u64)]) {
        for &(stage, nanos, count) in totals {
            let cell = &self.stages[stage as usize];
            cell.nanos.fetch_add(nanos, Ordering::Relaxed);
            cell.count.fetch_add(count, Ordering::Relaxed);
        }
    }

    /// Accumulated `(nanos, count)` for `stage`.
    pub fn stage_total(&self, stage: Stage) -> (u64, u64) {
        let cell = &self.stages[stage as usize];
        (
            cell.nanos.load(Ordering::Relaxed),
            cell.count.load(Ordering::Relaxed),
        )
    }

    fn gauge(&self, q: Queue) -> &QueueGauge {
        match q {
            Queue::Batch => &self.batch_queue,
            Queue::Task => &self.task_queue,
        }
    }

    /// Note one message entering queue `q` (call *before* a blocking send).
    pub fn queue_inc(&self, q: Queue) {
        self.gauge(q).inc();
    }

    /// Note one message leaving queue `q` (call after a successful receive).
    pub fn queue_dec(&self, q: Queue) {
        self.gauge(q).dec();
    }

    /// Instantaneous depth of queue `q` (in-flight plus blocked producers).
    pub fn queue_depth(&self, q: Queue) -> u64 {
        self.gauge(q).depth()
    }

    /// High-water depth of queue `q` over the run so far.
    pub fn queue_max(&self, q: Queue) -> u64 {
        self.gauge(q).max()
    }

    /// Set the snapshot-age gauge: how many optimizer steps stale the
    /// parameters were that the update being applied was computed against
    /// (0 everywhere except the engine at `--engine-staleness > 0`).
    pub fn set_staleness(&self, steps: u64) {
        self.staleness.store(steps, Ordering::Relaxed);
        self.staleness_max.fetch_max(steps, Ordering::Relaxed);
    }

    /// Current value of the snapshot-age gauge.
    pub fn staleness(&self) -> u64 {
        self.staleness.load(Ordering::Relaxed)
    }

    /// High-water snapshot age over the run so far.
    pub fn staleness_max(&self) -> u64 {
        self.staleness_max.load(Ordering::Relaxed)
    }

    /// Note `bytes` entering a paged-store page cache (page load, or an
    /// accumulator materialising on a resident page).  Add/sub style rather
    /// than set so several paged tables aggregate into one gauge naturally.
    pub fn store_resident_add(&self, bytes: u64) {
        let now = self.store_resident.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.store_resident_max.fetch_max(now, Ordering::Relaxed);
    }

    /// Note `bytes` leaving a paged-store page cache (eviction or table
    /// teardown).  Saturates at zero, so a stray unbalanced call cannot
    /// wrap the gauge.
    pub fn store_resident_sub(&self, bytes: u64) {
        let _ = self.store_resident.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| Some(v.saturating_sub(bytes)),
        );
    }

    /// Bytes currently resident across every paged table reporting here.
    pub fn store_resident(&self) -> u64 {
        self.store_resident.load(Ordering::Relaxed)
    }

    /// High-water resident paged-store bytes over the run — what the
    /// `fullscale` harness asserts against the `--store-budget-mb` bound.
    pub fn store_resident_max(&self) -> u64 {
        self.store_resident_max.load(Ordering::Relaxed)
    }

    /// Number of step records emitted so far.
    pub fn records(&self) -> u64 {
        self.records.load(Ordering::Relaxed)
    }

    /// Wall seconds since this hub was created — the run's single clock.
    pub fn wall_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Emit one per-step record.  Always counts the step; with a sink, also
    /// writes a `"type":"step"` JSONL line carrying the paper gauges, the
    /// current queue depths, and per-stage `(nanos, count)` *deltas* since
    /// the previous record.
    pub fn record_step(&self, rec: &StepRecord) -> Result<()> {
        self.records.fetch_add(1, Ordering::Relaxed);
        let mut sink = self.sink.lock().unwrap();
        let state = &mut *sink;
        let Some(w) = state.w.as_mut() else {
            return Ok(());
        };
        let mut stages = Vec::new();
        for stage in Stage::ALL {
            let (nanos, count) = self.stage_total(stage);
            let (last_nanos, last_count) = state.last[stage as usize];
            state.last[stage as usize] = (nanos, count);
            if count > last_count || nanos > last_nanos {
                stages.push((
                    stage.name().to_string(),
                    Json::Obj(vec![
                        ("nanos".into(), Json::num((nanos - last_nanos) as f64)),
                        ("count".into(), Json::num((count - last_count) as f64)),
                    ]),
                ));
            }
        }
        let line = Json::Obj(vec![
            ("type".into(), Json::str("step")),
            ("step".into(), Json::num(rec.step as f64)),
            ("loss".into(), Json::num(rec.loss)),
            ("present_rows".into(), Json::num(rec.present_rows as f64)),
            (
                "survivors".into(),
                match rec.survivors {
                    Some(s) => Json::num(s as f64),
                    None => Json::Null,
                },
            ),
            (
                "emb_coords_noised".into(),
                Json::num(rec.emb_coords_noised as f64),
            ),
            (
                "dense_coords_noised".into(),
                Json::num(rec.dense_coords_noised as f64),
            ),
            ("reduction_factor".into(), Json::num(rec.reduction_factor)),
            ("eps_spent".into(), Json::num(rec.eps_spent)),
            ("delta".into(), Json::num(rec.delta)),
            (
                "batch_queue".into(),
                Json::num(self.queue_depth(Queue::Batch) as f64),
            ),
            (
                "task_queue".into(),
                Json::num(self.queue_depth(Queue::Task) as f64),
            ),
            ("staleness".into(), Json::num(rec.staleness as f64)),
            ("stages".into(), Json::Obj(stages)),
        ]);
        writeln!(w, "{line}").context("writing metrics step record")?;
        w.flush().context("flushing metrics sink")?;
        Ok(())
    }

    /// Snapshot the run totals into a [`RunSummary`].
    pub fn summary(&self, eps_spent: f64, delta: f64) -> RunSummary {
        RunSummary {
            kernel_backend: crate::kernels::backend().name().into(),
            steps: self.records(),
            wall_secs: self.wall_secs(),
            batch_queue_max: self.queue_max(Queue::Batch),
            task_queue_max: self.queue_max(Queue::Task),
            max_staleness: self.staleness_max(),
            max_store_resident_bytes: self.store_resident_max(),
            eps_spent,
            delta,
            stages: Stage::ALL
                .iter()
                .filter_map(|&stage| {
                    let (nanos, count) = self.stage_total(stage);
                    (count > 0).then_some(StageTotal { stage, nanos, count })
                })
                .collect(),
        }
    }

    /// Write a `"type":"summary"` JSONL line to the sink (no-op without one).
    pub fn write_summary(&self, summary: &RunSummary) -> Result<()> {
        let mut sink = self.sink.lock().unwrap();
        let Some(w) = sink.w.as_mut() else {
            return Ok(());
        };
        writeln!(w, "{}", summary.to_json()).context("writing metrics summary")?;
        w.flush().context("flushing metrics sink")?;
        Ok(())
    }
}

/// RAII timer for one occurrence of a [`Stage`]; accumulates on drop.
pub struct Span<'a> {
    tele: &'a Telemetry,
    stage: Stage,
    t0: Instant,
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        self.tele
            .add_nanos(self.stage, self.t0.elapsed().as_nanos() as u64);
    }
}

/// Monotonic stopwatch — the one clock for ad-hoc wall timing, so harness
/// rows and telemetry traces are measured identically.
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Stopwatch {
        Stopwatch { t0: Instant::now() }
    }

    /// Seconds elapsed since [`Stopwatch::start`].
    pub fn elapsed_secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

/// Paper-semantic gauges for one optimizer step, emitted identically by the
/// sync trainer and the async engine from the shared step core.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    /// 1-based step index.
    pub step: u64,
    /// Mean training loss of the step's batch.
    pub loss: f64,
    /// Unique embedding rows touched by the batch (before selection).
    pub present_rows: u64,
    /// Rows surviving FEST/AdaFEST/exponential selection; `None` for
    /// algorithms without a selection stage.
    pub survivors: Option<u64>,
    /// Embedding coordinates that received noise this step.
    pub emb_coords_noised: u64,
    /// Dense-layer coordinates that received noise this step.
    pub dense_coords_noised: u64,
    /// This step's gradient-size reduction vs. the dense `V·d` baseline
    /// (infinite when nothing was noised, serialized as `null`).
    pub reduction_factor: f64,
    /// Cumulative privacy ε spent through this step (closed-form bound).
    pub eps_spent: f64,
    /// The δ at which `eps_spent` is stated.
    pub delta: f64,
    /// Snapshot age (applied steps) of the parameters this step's gradients
    /// were computed against — 0 on the sync path and at the engine's
    /// default `--engine-staleness 0`.
    pub staleness: u64,
}

/// Per-stage accumulated totals inside a [`RunSummary`].
#[derive(Clone, Copy, Debug)]
pub struct StageTotal {
    /// Which stage.
    pub stage: Stage,
    /// Total wall nanoseconds across all occurrences.
    pub nanos: u64,
    /// Number of occurrences.
    pub count: u64,
}

/// End-of-run telemetry totals, returned from both trainers inside
/// `TrainOutcome` and written as the final JSONL `"type":"summary"` line.
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    /// Number of optimizer steps recorded.
    pub steps: u64,
    /// Wall seconds from step-state creation to summary capture.
    pub wall_secs: f64,
    /// High-water depth of the batch channel (0 for the sync trainer).
    pub batch_queue_max: u64,
    /// High-water depth of the chunk-task channel (0 for the sync trainer).
    pub task_queue_max: u64,
    /// High-water snapshot age over the run — bounded by the engine's
    /// `--engine-staleness` window, 0 everywhere else.
    pub max_staleness: u64,
    /// High-water resident paged-store page-cache bytes — bounded by
    /// `--store-budget-mb` (plus at most one page per table when the budget
    /// is below one page), 0 for in-RAM runs.
    pub max_store_resident_bytes: u64,
    /// Cumulative privacy ε spent over the run (closed-form bound).
    pub eps_spent: f64,
    /// The δ at which `eps_spent` is stated.
    pub delta: f64,
    /// Kernel backend the run computed with (`"scalar"` / `"simd"`),
    /// captured from the trainer's scoped selection at summary time.
    /// Empty in a defaulted summary that never saw a run.
    pub kernel_backend: String,
    /// Accumulated `(nanos, count)` per stage that ever ticked.
    pub stages: Vec<StageTotal>,
}

impl RunSummary {
    /// Total for one stage, if it ticked during the run.
    pub fn stage(&self, stage: Stage) -> Option<&StageTotal> {
        self.stages.iter().find(|t| t.stage == stage)
    }

    /// The JSON object written as the `"type":"summary"` JSONL line.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("type".into(), Json::str("summary")),
            ("steps".into(), Json::num(self.steps as f64)),
            ("wall_secs".into(), Json::num(self.wall_secs)),
            (
                "batch_queue_max".into(),
                Json::num(self.batch_queue_max as f64),
            ),
            (
                "task_queue_max".into(),
                Json::num(self.task_queue_max as f64),
            ),
            (
                "max_staleness".into(),
                Json::num(self.max_staleness as f64),
            ),
            (
                "max_store_resident_bytes".into(),
                Json::num(self.max_store_resident_bytes as f64),
            ),
            ("eps_spent".into(), Json::num(self.eps_spent)),
            ("delta".into(), Json::num(self.delta)),
            (
                "kernel_backend".into(),
                Json::str(self.kernel_backend.clone()),
            ),
            (
                "stages".into(),
                Json::Obj(
                    self.stages
                        .iter()
                        .map(|t| {
                            (
                                t.stage.name().to_string(),
                                Json::Obj(vec![
                                    ("nanos".into(), Json::num(t.nanos as f64)),
                                    ("count".into(), Json::num(t.count as f64)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Current `BENCH_*.json` schema version; bump on any breaking field change.
/// (v4 added the per-row `kernel_backend` label for the scalar-vs-SIMD
/// rows; v3 added the per-row `store` backend label for the paged-store
/// rows; v2 added the per-row `staleness` field for the
/// `--engine-staleness` sweep.)
pub const BENCH_SCHEMA_VERSION: u64 = 4;

/// One sync/async throughput row inside a [`BenchSnapshot`].
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRow {
    /// Execution path label (`"sync"` or `"async"`).
    pub path: String,
    /// Gradient workers used (1 for the sync path).
    pub grad_workers: u64,
    /// `--engine-staleness` window the row ran with (0 for the sync path
    /// and the bit-exact async rows).
    pub staleness: u64,
    /// Embedding-table store backend the row ran against (`"ram"` for the
    /// in-memory shards, `"paged"` for the file-backed page cache).
    pub store: String,
    /// Kernel backend the row ran on (`"scalar"` / `"simd"`).
    pub kernel_backend: String,
    /// Wall seconds for the timed run.
    pub secs: f64,
    /// Optimizer steps per second.
    pub steps_per_sec: f64,
    /// Speedup vs. the sync baseline row.
    pub speedup: f64,
}

/// The tracked perf snapshot written by the engine throughput bench and the
/// CI bench smoke (`BENCH_engine.json`).  Hand-rolled JSON round-trip keeps
/// the on-disk schema stable across PRs.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchSnapshot {
    /// Schema version ([`BENCH_SCHEMA_VERSION`]).
    pub schema_version: u64,
    /// Bench target name (e.g. `"engine_throughput"`).
    pub bench: String,
    /// Model manifest the bench ran on.
    pub model: String,
    /// Training algorithm under test.
    pub algorithm: String,
    /// Steps per timed run.
    pub steps: u64,
    /// Where the numbers came from (e.g. the CI job) — snapshots from
    /// different machines are not comparable, so this is part of the record.
    pub provenance: String,
    /// Timing rows; empty when the snapshot is a placeholder awaiting CI.
    pub rows: Vec<BenchRow>,
}

impl BenchSnapshot {
    /// The snapshot as a JSON value.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            (
                "schema_version".into(),
                Json::num(self.schema_version as f64),
            ),
            ("bench".into(), Json::str(self.bench.clone())),
            ("model".into(), Json::str(self.model.clone())),
            ("algorithm".into(), Json::str(self.algorithm.clone())),
            ("steps".into(), Json::num(self.steps as f64)),
            ("provenance".into(), Json::str(self.provenance.clone())),
            (
                "rows".into(),
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::Obj(vec![
                                ("path".into(), Json::str(r.path.clone())),
                                (
                                    "grad_workers".into(),
                                    Json::num(r.grad_workers as f64),
                                ),
                                ("staleness".into(), Json::num(r.staleness as f64)),
                                ("store".into(), Json::str(r.store.clone())),
                                (
                                    "kernel_backend".into(),
                                    Json::str(r.kernel_backend.clone()),
                                ),
                                ("secs".into(), Json::num(r.secs)),
                                ("steps_per_sec".into(), Json::num(r.steps_per_sec)),
                                ("speedup".into(), Json::num(r.speedup)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Multi-line rendering for the checked-in file (trailing newline).
    pub fn to_json_pretty(&self) -> String {
        let mut s = self.to_json().pretty();
        s.push('\n');
        s
    }

    /// Parse and validate a snapshot (inverse of [`BenchSnapshot::to_json`]).
    pub fn parse(text: &str) -> Result<BenchSnapshot> {
        let v = Json::parse(text)?;
        let field = |k: &str| v.get(k).with_context(|| format!("missing field `{k}`"));
        let str_field = |k: &str| -> Result<String> {
            Ok(field(k)?
                .as_str()
                .with_context(|| format!("field `{k}` is not a string"))?
                .to_string())
        };
        let u64_field = |j: &Json, k: &str| -> Result<u64> {
            j.get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("field `{k}` is not a non-negative integer"))
        };
        let f64_field = |j: &Json, k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("field `{k}` is not a number"))
        };
        let schema_version = u64_field(&v, "schema_version")?;
        if schema_version != BENCH_SCHEMA_VERSION {
            bail!(
                "unsupported bench schema version {schema_version} \
                 (expected {BENCH_SCHEMA_VERSION})"
            );
        }
        let mut rows = Vec::new();
        for row in field("rows")?
            .as_arr()
            .context("field `rows` is not an array")?
        {
            rows.push(BenchRow {
                path: row
                    .get("path")
                    .and_then(Json::as_str)
                    .context("row field `path` is not a string")?
                    .to_string(),
                grad_workers: u64_field(row, "grad_workers")?,
                staleness: u64_field(row, "staleness")?,
                store: row
                    .get("store")
                    .and_then(Json::as_str)
                    .context("row field `store` is not a string")?
                    .to_string(),
                kernel_backend: row
                    .get("kernel_backend")
                    .and_then(Json::as_str)
                    .context("row field `kernel_backend` is not a string")?
                    .to_string(),
                secs: f64_field(row, "secs")?,
                steps_per_sec: f64_field(row, "steps_per_sec")?,
                speedup: f64_field(row, "speedup")?,
            });
        }
        Ok(BenchSnapshot {
            schema_version,
            bench: str_field("bench")?,
            model: str_field("model")?,
            algorithm: str_field("algorithm")?,
            steps: u64_field(&v, "steps")?,
            provenance: str_field("provenance")?,
            rows,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_accumulate_nanos_and_counts() {
        let tele = Telemetry::new();
        for _ in 0..3 {
            let _span = tele.span(Stage::Select);
            std::hint::black_box(());
        }
        tele.add_nanos(Stage::Select, 1_000);
        let (nanos, count) = tele.stage_total(Stage::Select);
        assert_eq!(count, 4);
        assert!(nanos >= 1_000);
        assert_eq!(tele.stage_total(Stage::Noise), (0, 0));
    }

    #[test]
    fn queue_gauges_track_depth_and_high_water() {
        let tele = Telemetry::new();
        tele.queue_inc(Queue::Batch);
        tele.queue_inc(Queue::Batch);
        tele.queue_dec(Queue::Batch);
        assert_eq!(tele.queue_depth(Queue::Batch), 1);
        assert_eq!(tele.queue_max(Queue::Batch), 2);
        // the other gauge is independent
        assert_eq!(tele.queue_depth(Queue::Task), 0);
        // a stray extra dec clamps at zero on read
        tele.queue_dec(Queue::Batch);
        tele.queue_dec(Queue::Batch);
        assert_eq!(tele.queue_depth(Queue::Batch), 0);
        assert_eq!(tele.queue_max(Queue::Batch), 2);
    }

    fn record(step: u64) -> StepRecord {
        StepRecord {
            step,
            loss: 0.5,
            present_rows: 40,
            survivors: Some(30),
            emb_coords_noised: 240,
            dense_coords_noised: 100,
            reduction_factor: 1.0e6,
            eps_spent: 0.25,
            delta: 1e-6,
            staleness: 0,
        }
    }

    #[test]
    fn staleness_gauge_tracks_current_and_high_water() {
        let tele = Telemetry::new();
        assert_eq!(tele.staleness(), 0);
        tele.set_staleness(2);
        tele.set_staleness(1);
        assert_eq!(tele.staleness(), 1);
        assert_eq!(tele.staleness_max(), 2);
        assert_eq!(tele.summary(0.0, 0.0).max_staleness, 2);
    }

    #[test]
    fn store_resident_gauge_tracks_bytes_and_high_water() {
        let tele = Telemetry::new();
        assert_eq!(tele.store_resident(), 0);
        tele.store_resident_add(4096);
        tele.store_resident_add(4096);
        tele.store_resident_sub(4096);
        assert_eq!(tele.store_resident(), 4096);
        assert_eq!(tele.store_resident_max(), 8192);
        // a stray unbalanced sub saturates instead of wrapping
        tele.store_resident_sub(1 << 40);
        assert_eq!(tele.store_resident(), 0);
        assert_eq!(tele.summary(0.0, 0.0).max_store_resident_bytes, 8192);
    }

    #[test]
    fn sinkless_record_step_only_counts() {
        let tele = Telemetry::new();
        tele.record_step(&record(1)).unwrap();
        tele.record_step(&record(2)).unwrap();
        assert_eq!(tele.records(), 2);
        let s = tele.summary(0.25, 1e-6);
        assert_eq!(s.steps, 2);
        assert!(s.wall_secs >= 0.0);
        // the summary stamps the live backend selection; other tests in
        // this binary may hold a ScopedConfig, so only pin the domain
        assert!(s.kernel_backend == "scalar" || s.kernel_backend == "simd");
    }

    #[test]
    fn sink_writes_parseable_jsonl_with_stage_deltas() {
        let path = std::env::temp_dir().join(format!(
            "telemetry_sink_test_{}.jsonl",
            std::process::id()
        ));
        let path_str = path.to_str().unwrap();
        let tele = Telemetry::with_sink(Some(path_str)).unwrap();
        tele.add_nanos(Stage::Select, 500);
        tele.record_step(&record(1)).unwrap();
        tele.add_nanos(Stage::Select, 700);
        tele.add_nanos(Stage::Noise, 100);
        tele.record_step(&record(2)).unwrap();
        tele.write_summary(&tele.summary(0.25, 1e-6)).unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let lines: Vec<Json> =
            text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].get("type").unwrap().as_str(), Some("step"));
        assert_eq!(lines[0].get("step").unwrap().as_u64(), Some(1));
        assert_eq!(lines[0].get("loss").unwrap().as_f64(), Some(0.5));
        assert_eq!(lines[0].get("staleness").unwrap().as_u64(), Some(0));
        // first record carries the first 500ns; second only the 700ns delta
        let sel = |l: &Json| {
            l.get("stages")
                .unwrap()
                .get("select")
                .unwrap()
                .get("nanos")
                .unwrap()
                .as_u64()
                .unwrap()
        };
        assert_eq!(sel(&lines[0]), 500);
        assert_eq!(sel(&lines[1]), 700);
        assert!(lines[0].get("stages").unwrap().get("noise").is_none());
        assert!(lines[1].get("stages").unwrap().get("noise").is_some());
        assert_eq!(lines[2].get("type").unwrap().as_str(), Some("summary"));
        assert_eq!(lines[2].get("steps").unwrap().as_u64(), Some(2));
        assert_eq!(lines[2].get("eps_spent").unwrap().as_f64(), Some(0.25));
    }

    #[test]
    fn summary_reports_only_ticked_stages() {
        let tele = Telemetry::new();
        tele.add_nanos(Stage::ChunkCompute, 10);
        tele.add_nanos(Stage::ChunkCompute, 20);
        let s = tele.summary(0.0, 0.0);
        assert_eq!(s.stages.len(), 1);
        let total = s.stage(Stage::ChunkCompute).unwrap();
        assert_eq!((total.nanos, total.count), (30, 2));
        assert!(s.stage(Stage::Noise).is_none());
    }

    #[test]
    fn infinite_reduction_factor_serializes_as_null() {
        let path = std::env::temp_dir().join(format!(
            "telemetry_inf_test_{}.jsonl",
            std::process::id()
        ));
        let tele = Telemetry::with_sink(path.to_str()).unwrap();
        let mut rec = record(1);
        rec.reduction_factor = f64::INFINITY;
        rec.survivors = None;
        tele.record_step(&rec).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let line = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(line.get("reduction_factor"), Some(&Json::Null));
        assert_eq!(line.get("survivors"), Some(&Json::Null));
    }

    fn sample_snapshot() -> BenchSnapshot {
        BenchSnapshot {
            schema_version: BENCH_SCHEMA_VERSION,
            bench: "engine_throughput".into(),
            model: "criteo-small".into(),
            algorithm: "dp-adafest".into(),
            steps: 60,
            provenance: "unit-test".into(),
            rows: vec![
                BenchRow {
                    path: "sync".into(),
                    grad_workers: 1,
                    staleness: 0,
                    store: "ram".into(),
                    kernel_backend: "scalar".into(),
                    secs: 12.5,
                    steps_per_sec: 4.8,
                    speedup: 1.0,
                },
                BenchRow {
                    path: "async".into(),
                    grad_workers: 4,
                    staleness: 0,
                    store: "ram".into(),
                    kernel_backend: "scalar".into(),
                    secs: 4.25,
                    steps_per_sec: 14.1,
                    speedup: 2.94,
                },
                BenchRow {
                    path: "async".into(),
                    grad_workers: 4,
                    staleness: 2,
                    store: "paged".into(),
                    kernel_backend: "simd".into(),
                    secs: 3.4,
                    steps_per_sec: 17.6,
                    speedup: 3.67,
                },
            ],
        }
    }

    #[test]
    fn bench_snapshot_roundtrip() {
        let snap = sample_snapshot();
        assert_eq!(BenchSnapshot::parse(&snap.to_json_pretty()).unwrap(), snap);
        assert_eq!(
            BenchSnapshot::parse(&snap.to_json().to_string()).unwrap(),
            snap
        );
    }

    #[test]
    fn bench_snapshot_rejects_other_schema_versions() {
        let mut snap = sample_snapshot();
        snap.schema_version = BENCH_SCHEMA_VERSION + 1;
        let err = BenchSnapshot::parse(&snap.to_json_pretty()).unwrap_err();
        assert!(err.to_string().contains("schema version"));
    }

    #[test]
    fn bench_snapshot_accepts_empty_rows() {
        let mut snap = sample_snapshot();
        snap.rows.clear();
        assert_eq!(BenchSnapshot::parse(&snap.to_json_pretty()).unwrap(), snap);
    }
}
