//! Micro-benchmark harness (criterion is not in the offline crate set).
//!
//! Protocol per benchmark: warm up until ~`warmup` has elapsed, then run
//! `samples` timed iterations batched to at least `min_batch_time`, and
//! report median / p10 / p90 of the per-iteration time.  Used by every
//! `[[bench]]` target (`cargo bench` runs them with `--bench`).

use std::time::{Duration, Instant};

use super::stats;

pub struct Bencher {
    pub warmup: Duration,
    pub samples: usize,
    pub min_batch_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            samples: 15,
            min_batch_time: Duration::from_millis(20),
        }
    }
}

pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn per_iter_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<48} median {:>12} p10 {:>12} p90 {:>12} ({} it/sample)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.p10),
            fmt_dur(self.p90),
            self.iters_per_sample,
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

impl Bencher {
    /// Benchmark `f`, preventing dead-code elimination via the returned value.
    pub fn bench<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup and batch-size calibration.
        let mut iters: u64 = 1;
        let warm_start = Instant::now();
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed();
            if warm_start.elapsed() >= self.warmup && dt >= self.min_batch_time {
                break;
            }
            if dt < self.min_batch_time {
                iters = (iters * 2).min(1 << 30);
            }
        }
        // Timed samples.
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            median: Duration::from_secs_f64(stats::median(&per_iter)),
            p10: Duration::from_secs_f64(stats::percentile(&per_iter, 10.0)),
            p90: Duration::from_secs_f64(stats::percentile(&per_iter, 90.0)),
            iters_per_sample: iters,
        };
        println!("{result}");
        result
    }

    /// One-shot timing for expensive end-to-end runs (no batching).
    pub fn once<T, F: FnOnce() -> T>(&self, name: &str, f: F) -> (T, Duration) {
        let t0 = Instant::now();
        let out = std::hint::black_box(f());
        let dt = t0.elapsed();
        println!("{:<48} once   {:>12}", name, fmt_dur(dt));
        (out, dt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            samples: 5,
            min_batch_time: Duration::from_micros(200),
        };
        // memory-bound workload: cannot be closed-form folded by LLVM
        let data: Vec<u64> = (0..4096).map(|i| std::hint::black_box(i)).collect();
        let r = b.bench("vec-sum", || {
            data.iter().map(|&x| std::hint::black_box(x)).sum::<u64>()
        });
        assert!(r.median.as_nanos() > 0);
        assert!(r.p10 <= r.p90);
    }
}
