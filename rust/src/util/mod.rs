//! Shared utilities: RNG substrate, tiny statistics helpers, and the
//! micro-benchmark harness used by `cargo bench` (the offline crate set has
//! no criterion; `bench::Bencher` reproduces the warmup/median protocol).

pub mod bench;
pub mod rng;
pub mod stats;

pub use rng::Xoshiro256;
