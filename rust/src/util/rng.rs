//! Self-contained RNG substrate (the offline crate set has no `rand`).
//!
//! * [`Xoshiro256`] — xoshiro256++ (Blackman & Vigna), seeded via SplitMix64.
//! * Gaussian sampling — polar Box–Muller with a cached spare, plus a
//!   vectorised fill path used by the dense-noise benchmark (Table 4's
//!   "generate a dense tensor of Gaussian noise each step" cost).
//! * Gumbel and Geometric samplers — needed by the one-shot DP top-k
//!   mechanism (Algorithm 2) and the memory-efficient survivor sampler
//!   (Appendix B.2) respectively.

#[inline(always)]
fn o_write(o: &mut f32, v: f64) {
    *o = v as f32;
}

/// Precomputed 128-layer ziggurat tables for the standard normal
/// (Marsaglia & Tsang 2000).
struct Ziggurat {
    kn: [u32; 128],
    wn: [f64; 128],
    fn_: [f64; 128],
}

fn ziggurat_tables() -> &'static Ziggurat {
    use std::sync::OnceLock;
    static TABLES: OnceLock<Ziggurat> = OnceLock::new();
    TABLES.get_or_init(|| {
        const M1: f64 = 2147483648.0; // 2^31
        let mut dn: f64 = 3.442619855899;
        let tn0 = dn;
        let vn: f64 = 9.91256303526217e-3;
        let mut kn = [0u32; 128];
        let mut wn = [0f64; 128];
        let mut fn_ = [0f64; 128];
        let q = vn / (-0.5 * dn * dn).exp();
        kn[0] = ((dn / q) * M1) as u32;
        kn[1] = 0;
        wn[0] = q / M1;
        wn[127] = dn / M1;
        fn_[0] = 1.0;
        fn_[127] = (-0.5 * dn * dn).exp();
        let mut tn = tn0;
        for i in (1..=126).rev() {
            dn = (-2.0 * (vn / dn + (-0.5 * dn * dn).exp()).ln()).sqrt();
            kn[i + 1] = ((dn / tn) * M1) as u32;
            tn = dn;
            fn_[i] = (-0.5 * dn * dn).exp();
            wn[i] = dn / M1;
        }
        Ziggurat { kn, wn, fn_ }
    })
}

/// SplitMix64 — used only to expand seeds.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Not cryptographically secure — fine for simulation;
/// a production DP deployment would swap in a CSPRNG here (this type is
/// the single substitution boundary).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
    spare_gauss: Option<f64>,
}

impl Xoshiro256 {
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Xoshiro256 { s, spare_gauss: None }
    }

    /// Derive an independent stream (for per-feature / per-step substreams).
    pub fn fork(&mut self, tag: u64) -> Self {
        Xoshiro256::seed_from(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn uniform_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's method without the rejection refinement — bias is
        // negligible for n ≪ 2^64 (we use it for indices and permutations).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via polar Box–Muller with a cached spare.
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.spare_gauss.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare_gauss = Some(v * m);
                return u * m;
            }
        }
    }

    /// Fill a slice with N(0, sigma^2) noise — the dense-noise hot path
    /// (vanilla DP-SGD generates c·d of these per step; Table 4's cost).
    ///
    /// Uses the 128-layer Marsaglia–Tsang ziggurat (§Perf: ~6x over the
    /// Box–Muller path this replaced; one u32 + one compare + one multiply
    /// on the ~98.8% fast path).
    pub fn fill_gauss_f32(&mut self, out: &mut [f32], sigma: f64) {
        let zig = ziggurat_tables();
        let s = sigma;
        let mut buf: u64 = 0;
        let mut have: u32 = 0;
        for o in out.iter_mut() {
            // draw a u32, two per u64
            if have == 0 {
                buf = self.next_u64();
                have = 2;
            }
            let hz = buf as u32 as i32;
            buf >>= 32;
            have -= 1;
            let iz = (hz & 127) as usize;
            let az = (hz as i64).unsigned_abs() as u64;
            if az < zig.kn[iz] as u64 {
                o_write(o, hz as f64 * zig.wn[iz] * s);
            } else {
                o_write(o, self.gauss_zig_slow(hz, iz, zig) * s);
            }
        }
    }

    /// Ziggurat slow path: tail (iz == 0) or wedge rejection.
    #[cold]
    fn gauss_zig_slow(&mut self, mut hz: i32, mut iz: usize, zig: &Ziggurat) -> f64 {
        const R: f64 = 3.442619855899; // ziggurat tail start
        loop {
            let x = hz as f64 * zig.wn[iz];
            if iz == 0 {
                // tail sampling (Marsaglia)
                loop {
                    let x = -self.uniform_open().ln() / R;
                    let y = -self.uniform_open().ln();
                    if y + y > x * x {
                        return if hz > 0 { R + x } else { -(R + x) };
                    }
                }
            }
            if zig.fn_[iz] + self.uniform() * (zig.fn_[iz - 1] - zig.fn_[iz])
                < (-0.5 * x * x).exp()
            {
                return x;
            }
            hz = (self.next_u64() as u32) as i32;
            iz = (hz & 127) as usize;
            let az = (hz as i64).unsigned_abs() as u64;
            if az < zig.kn[iz] as u64 {
                return hz as f64 * zig.wn[iz];
            }
        }
    }

    /// Standard Gumbel(β) sample: `-β·ln(-ln U)` (DP top-k, Algorithm 2).
    #[inline]
    pub fn gumbel(&mut self, beta: f64) -> f64 {
        -beta * (-self.uniform_open().ln()).ln()
    }

    /// Geometric(p) on {1, 2, ...}: number of Bernoulli(p) trials up to and
    /// including the first success (Appendix B.2 survivor gaps).
    ///
    /// Uses `ln_1p(-p)` — the naive `ln(1-p)` rounds to exactly 0.0 for
    /// p ≲ 1e-16, which would turn "almost never" into "every trial".
    #[inline]
    pub fn geometric(&mut self, p: f64) -> u64 {
        if p >= 1.0 {
            return 1;
        }
        if p <= 0.0 {
            return u64::MAX;
        }
        let u = self.uniform_open();
        let denom = (-p).ln_1p(); // ln(1-p), accurate for tiny p
        let g = (u.ln() / denom).ceil();
        if g >= u64::MAX as f64 {
            u64::MAX
        } else {
            g.max(1.0) as u64
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Xoshiro256::seed_from(42);
        let mut b = Xoshiro256::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Xoshiro256::seed_from(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Xoshiro256::seed_from(3);
        let n = 200_000;
        let (mut m1, mut m2, mut m4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            m1 += g;
            m2 += g * g;
            m4 += g * g * g * g;
        }
        let nf = n as f64;
        assert!((m1 / nf).abs() < 0.02);
        assert!((m2 / nf - 1.0).abs() < 0.03);
        assert!((m4 / nf - 3.0).abs() < 0.15); // kurtosis of N(0,1)
    }

    #[test]
    fn fill_gauss_matches_scalar_moments() {
        let mut r = Xoshiro256::seed_from(9);
        let mut buf = vec![0f32; 100_001]; // odd length exercises the tail
        r.fill_gauss_f32(&mut buf, 2.0);
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        let var: f64 =
            buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn geometric_mean_is_one_over_p() {
        let mut r = Xoshiro256::seed_from(11);
        for &p in &[0.9, 0.5, 0.1, 0.01] {
            let n = 50_000;
            let s: u64 = (0..n).map(|_| r.geometric(p)).sum();
            let mean = s as f64 / n as f64;
            assert!(
                (mean - 1.0 / p).abs() < 0.1 / p,
                "p={p} mean={mean} want {}",
                1.0 / p
            );
        }
    }

    #[test]
    fn geometric_tiny_p_does_not_degenerate() {
        // regression: ln(1-p) == 0.0 for p < 1e-16 made every trial a
        // "success"; with ln_1p the first gap is astronomically large.
        let mut r = Xoshiro256::seed_from(23);
        for _ in 0..100 {
            let g = r.geometric(1e-30);
            assert!(g > 1_000_000_000, "gap {g} far too small for p=1e-30");
        }
        assert_eq!(r.geometric(0.0), u64::MAX);
    }

    #[test]
    fn gumbel_location_scale() {
        let mut r = Xoshiro256::seed_from(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.gumbel(2.0)).sum::<f64>() / n as f64;
        // E[Gumbel(beta)] = gamma * beta, gamma ≈ 0.5772
        assert!((mean - 2.0 * 0.5772).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = Xoshiro256::seed_from(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
