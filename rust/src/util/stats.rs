//! Tiny statistics helpers used by metrics, tests, and the bench harness.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Standard normal survival function Ψ(t) = P[Z ≥ t].
///
/// Used by the Appendix-B.2 survivor sampler (false-positive probability
/// Ψ(τ/(σ₁C₁))) and by PLD discretisation. Implemented via `erfc` with the
/// Abramowitz–Stegun 7.1.26-style rational approximation refined by one
/// Newton step — max abs error < 3e-13 on [-8, 8], plenty below DP deltas.
pub fn gauss_sf(t: f64) -> f64 {
    0.5 * erfc(t / std::f64::consts::SQRT_2)
}

/// Standard normal CDF.
pub fn gauss_cdf(t: f64) -> f64 {
    0.5 * erfc(-t / std::f64::consts::SQRT_2)
}

/// Complementary error function — the classic Chebyshev-fitted rational
/// approximation (Numerical Recipes §6.2): *fractional* error < 1.2e-7
/// everywhere, so deep tails (DP deltas around 1e-9) keep ~7 significant
/// digits of relative accuracy, which is far below accounting grid error.
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Natural log of the standard normal pdf.
pub fn log_gauss_pdf(x: f64, sigma: f64) -> f64 {
    let z = x / sigma;
    -0.5 * z * z - sigma.ln() - 0.5 * (2.0 * std::f64::consts::PI).ln()
}

/// log(exp(a) + exp(b)) without overflow.
pub fn log_add_exp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_and_median() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn gauss_sf_known_values() {
        // Φ(0)=0.5, Ψ(1.644853..)≈0.05, Ψ(2.326..)≈0.01
        // (the Chebyshev fit is good to ~1.2e-7 fractionally)
        assert!((gauss_sf(0.0) - 0.5).abs() < 1e-7);
        assert!((gauss_sf(1.6448536269514722) - 0.05).abs() < 1e-6);
        assert!((gauss_sf(2.3263478740408408) - 0.01).abs() < 1e-6);
        assert!((gauss_sf(-1.0) - (1.0 - gauss_sf(1.0))).abs() < 1e-9);
    }

    #[test]
    fn gauss_sf_deep_tail_monotone() {
        let mut prev = 1.0;
        for i in 0..80 {
            let t = i as f64 * 0.1;
            let v = gauss_sf(t);
            assert!(v <= prev + 1e-12, "not monotone at t={t}");
            assert!(v >= 0.0);
            prev = v;
        }
        // tail magnitude sanity: Ψ(6) ≈ 9.87e-10
        let v6 = gauss_sf(6.0);
        assert!(v6 > 1e-10 && v6 < 1e-8, "psi(6)={v6}");
    }

    #[test]
    fn log_add_exp_basic() {
        assert!((log_add_exp(0.0, 0.0) - std::f64::consts::LN_2).abs() < 1e-12);
        assert_eq!(log_add_exp(f64::NEG_INFINITY, 3.0), 3.0);
        let big = log_add_exp(1000.0, 1000.0);
        assert!((big - (1000.0 + std::f64::consts::LN_2)).abs() < 1e-9);
    }
}
