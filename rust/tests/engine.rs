//! Async-engine integration tests over the built-in reference runtime (no
//! AOT artifacts needed):
//!
//! * sync-vs-async **exact** equivalence (loss history, utility, noised
//!   coordinate counts) across worker/shard/microbatch settings — on both
//!   the pCTR tower and the native NLU transformer;
//! * the noise-draw-order invariant (a `ParamStore` sink and a sharded sink
//!   consume the identical RNG stream and produce identical parameters);
//! * sharded-store concurrent-update correctness under the in-repo property
//!   harness;
//! * channel shutdown / no-deadlock at degenerate configurations (under a
//!   hard watchdog so a regression fails in bounded time);
//! * the `--engine-staleness` window: `k = 0` bit-identical through the
//!   versioned-snapshot dispatch path (outcomes AND final params), `k > 0`
//!   terminating with observed staleness exactly `min(k, steps − 1)` and
//!   loss still descending (`docs/CONCURRENCY.md`);
//! * multi-process mode (`--engine-processes`): the actor fleet over unix
//!   sockets is bit-identical to the sync trainer AND the in-process async
//!   engine — outcomes and final parameters — on both workloads, at
//!   several process/shard splits, including `--stream`
//!   (`docs/ENGINE.md`; the fault-injection side lives in
//!   `tests/engine_fault.rs`);
//! * the paged-store backend (`--store-budget-mb`): file-backed tables are
//!   bit-identical to the in-RAM shards on every path above, in-process
//!   and per-actor, including `--stream` with reselection counts (the
//!   table-level property suite lives in `tests/store.rs`).

mod support;

use support::{
    assert_outcomes_identical, assert_params_identical, assert_streaming_identical, gen_cfg,
    streaming_cfg, sync_streaming, text_cfg, tiny_cfg, tiny_nlu_cfg,
};

use sparse_dp_emb::config::RunConfig;
use sparse_dp_emb::coordinator::step::{GradBundle, StepState};
use sparse_dp_emb::coordinator::{Algorithm, Trainer};
use sparse_dp_emb::data::{CriteoConfig, SynthCriteo, SynthText, TextConfig, TRAIN_DAYS};
use sparse_dp_emb::engine::{self, ShardedStore, ShardedTable};
use sparse_dp_emb::models::ParamStore;
use sparse_dp_emb::proptest::{check, ensure, usize_in};
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::selection::FrequencySource;
use sparse_dp_emb::sparse::{DenseState, Optimizer, RowSparseGrad};
use sparse_dp_emb::util::rng::Xoshiro256;

#[test]
fn sync_and_async_outcomes_match_exactly() {
    let rt = Runtime::builtin();
    for algo in [Algorithm::NonPrivate, Algorithm::DpSgd, Algorithm::DpAdaFest] {
        let cfg = tiny_cfg(algo);
        let gcfg = gen_cfg(&rt, &cfg);

        let gen = SynthCriteo::new(gcfg.clone());
        let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
        let sync_out = trainer.run_pctr(&gen).unwrap();
        assert!(sync_out.loss_history.iter().all(|l| l.is_finite()), "{algo:?}");

        let async_out = engine::run_pctr(&cfg, &rt, gcfg).unwrap();
        assert_outcomes_identical(&sync_out, &async_out, &format!("{algo:?}"));
    }
}

#[test]
fn async_outcome_is_invariant_to_engine_knobs() {
    let rt = Runtime::builtin();
    let base = tiny_cfg(Algorithm::DpAdaFest);
    let gcfg = gen_cfg(&rt, &base);
    let reference = engine::run_pctr(&base, &rt, gcfg.clone()).unwrap();
    // (grad workers, data workers, channel depth, shards, microbatch chunks)
    for (gw, dw, depth, shards, mb) in [(1, 1, 1, 1, 1), (3, 2, 2, 7, 2), (8, 4, 16, 64, 100)] {
        let mut cfg = base.clone();
        cfg.engine.grad_workers = gw;
        cfg.engine.data_workers = dw;
        cfg.engine.channel_depth = depth;
        cfg.engine.shards = shards;
        cfg.engine.microbatch_chunks = mb;
        let out = engine::run_pctr(&cfg, &rt, gcfg.clone()).unwrap();
        assert_outcomes_identical(
            &reference,
            &out,
            &format!("engine knobs ({gw},{dw},{depth},{shards},{mb})"),
        );
    }
}

#[test]
fn sync_and_async_nlu_outcomes_match_exactly() {
    // the acceptance bar of the native transformer executor: train and
    // train-async produce bit-identical outcomes on the text workload
    let rt = Runtime::builtin();
    for algo in [Algorithm::NonPrivate, Algorithm::DpSgd, Algorithm::DpAdaFest] {
        let cfg = tiny_nlu_cfg(algo);
        let tcfg = text_cfg(&rt, &cfg);

        let gen = SynthText::new(tcfg.clone());
        let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
        let sync_out = trainer.run_text(&gen).unwrap();
        assert!(sync_out.loss_history.iter().all(|l| l.is_finite()), "{algo:?}");

        let async_out = engine::run_text(&cfg, &rt, tcfg).unwrap();
        assert_outcomes_identical(&sync_out, &async_out, &format!("nlu {algo:?}"));
    }
}

#[test]
fn async_nlu_outcome_is_invariant_to_engine_knobs() {
    let rt = Runtime::builtin();
    let base = tiny_nlu_cfg(Algorithm::DpAdaFest);
    let tcfg = text_cfg(&rt, &base);
    let reference = engine::run_text(&base, &rt, tcfg.clone()).unwrap();
    for (gw, dw, depth, shards, mb) in [(1, 1, 1, 1, 1), (3, 2, 2, 7, 2), (8, 4, 16, 64, 100)] {
        let mut cfg = base.clone();
        cfg.engine.grad_workers = gw;
        cfg.engine.data_workers = dw;
        cfg.engine.channel_depth = depth;
        cfg.engine.shards = shards;
        cfg.engine.microbatch_chunks = mb;
        let out = engine::run_text(&cfg, &rt, tcfg.clone()).unwrap();
        assert_outcomes_identical(
            &reference,
            &out,
            &format!("nlu engine knobs ({gw},{dw},{depth},{shards},{mb})"),
        );
    }
}

#[test]
fn sync_and_async_match_exactly_with_threaded_kernels() {
    // The threaded-kernel acceptance bar: a serial sync run and an async
    // run with the executor-kernel fan-out forced on (kernel_threads = 3,
    // par-min-work floor 0 so even nlu-tiny-sized tiles split across
    // threads) must agree bit-for-bit on outcomes AND final parameters —
    // parallel output tiling never reorders an accumulation chain.
    use sparse_dp_emb::kernels;
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            kernels::set_threads(1);
            kernels::set_par_min_work(kernels::DEFAULT_PAR_MIN_WORK);
        }
    }
    let _restore = Restore;
    let rt = Runtime::builtin();
    for model in ["nlu-tiny", "nlu-tiny-lora4"] {
        let mut cfg = tiny_nlu_cfg(Algorithm::DpAdaFest);
        cfg.model = model.into();
        cfg.steps = 3;
        let tcfg = text_cfg(&rt, &cfg);

        // serial reference (kernel_threads defaults to 1)
        kernels::set_par_min_work(kernels::DEFAULT_PAR_MIN_WORK);
        let gen = SynthText::new(tcfg.clone());
        let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
        let sync_out = trainer.run_text(&gen).unwrap();

        // Threaded async: every kernel call fans its output rows out.  The
        // knobs are process-wide and every sibling test's Trainer::new /
        // engine run scopes the thread count to 1 for its own duration, so
        // a racing test can snap this run back to serial mid-way — which
        // would be bit-identical and silently gut the threaded coverage.
        // Nothing in this process ever writes 3 except this test, and the
        // engine's `ScopedConfig` restores the *pre-run* value on exit —
        // so we pre-set 3 before each attempt: `threads() == 3` after the
        // run then proves no sibling's restore landed mid-way (and the
        // pool counter proves fan-outs happened); otherwise a race
        // interfered — retry.
        let mut c = cfg.clone();
        c.engine.kernel_threads = 3;
        c.engine.grad_workers = 2;
        c.engine.shards = 4;
        let mut attempt = 0;
        let (async_out, async_store) = loop {
            kernels::set_threads(3);
            kernels::set_par_min_work(0);
            let before = kernels::fan_out_count();
            let res = engine::run_with_params(&c, &rt).unwrap();
            if kernels::fan_out_count() > before && kernels::threads() == 3 {
                break res;
            }
            attempt += 1;
            assert!(attempt < 20, "kernel fan-out never engaged across 20 runs");
        };
        let what = format!("{model} threaded kernels");
        assert_outcomes_identical(&sync_out, &async_out, &what);
        assert_params_identical(&trainer.store, &async_store, &what);
    }
}

#[test]
fn sync_and_async_lora_outcomes_and_params_match_exactly() {
    // The acceptance bar of the native LoRA-on-embedding executor: on the
    // Table-1 rank models, `train` and `train-async` produce bit-identical
    // outcomes AND bit-identical final parameters — the sharded A factor,
    // the dense B factor, the head — at several worker/shard settings.
    let rt = Runtime::builtin();
    for model in ["nlu-tiny-lora4", "nlu-tiny-lora16"] {
        for algo in [Algorithm::DpSgd, Algorithm::DpAdaFest] {
            let mut cfg = tiny_nlu_cfg(algo);
            cfg.model = model.into();
            let tcfg = text_cfg(&rt, &cfg);

            let gen = SynthText::new(tcfg.clone());
            let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
            let sync_out = trainer.run_text(&gen).unwrap();
            assert!(sync_out.loss_history.iter().all(|l| l.is_finite()), "{model} {algo:?}");

            for (gw, dw, shards, mb) in [(1, 1, 1, 1), (4, 2, 16, 2)] {
                let mut c = cfg.clone();
                c.engine.grad_workers = gw;
                c.engine.data_workers = dw;
                c.engine.shards = shards;
                c.engine.microbatch_chunks = mb;
                let (async_out, async_store) = engine::run_with_params(&c, &rt).unwrap();
                let what = format!("{model} {algo:?} ({gw},{dw},{shards},{mb})");
                assert_outcomes_identical(&sync_out, &async_out, &what);
                assert_params_identical(&trainer.store, &async_store, &what);
            }
        }
    }
}

#[test]
fn lora_reduction_baseline_counts_adapter_coords() {
    // On a LoRA model the dense-DP-SGD baseline of the reduction factor is
    // the adapter size (V·r rows of A), not the (V·d) table — under plain
    // DP-SGD every A coordinate is noised each step, so the factor is 1.
    let rt = Runtime::builtin();
    let mut cfg = tiny_nlu_cfg(Algorithm::DpSgd);
    cfg.model = "nlu-tiny-lora4".into();
    cfg.steps = 2;
    let out = engine::run(&cfg, &rt).unwrap();
    let model = rt.manifest.model("nlu-tiny-lora4").unwrap();
    let store = ParamStore::init(model, cfg.seed).unwrap();
    let a_coords = store.get("emb_lora_a").unwrap().num_elements();
    assert!(
        (out.emb_grad_coords_per_step - a_coords as f64).abs() < 1.0,
        "dense noise must cover exactly the A factor: {} vs {}",
        out.emb_grad_coords_per_step,
        a_coords
    );
    assert!((out.reduction_factor - 1.0).abs() < 1e-9);
}

#[test]
fn generic_engine_run_matches_sync_on_both_kinds() {
    // engine::run derives the data source from the manifest exactly like
    // the sync CLI path, for pctr and nlu alike
    let rt = Runtime::builtin();

    let cfg = tiny_cfg(Algorithm::DpAdaFest);
    let gen = SynthCriteo::new(gen_cfg(&rt, &cfg));
    let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
    let sync_out = trainer.run_pctr(&gen).unwrap();
    let async_out = engine::run(&cfg, &rt).unwrap();
    assert_outcomes_identical(&sync_out, &async_out, "engine::run pctr");

    let cfg = tiny_nlu_cfg(Algorithm::DpAdaFest);
    let gen = SynthText::new(text_cfg(&rt, &cfg));
    let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
    let sync_out = trainer.run_text(&gen).unwrap();
    let async_out = engine::run(&cfg, &rt).unwrap();
    assert_outcomes_identical(&sync_out, &async_out, "engine::run nlu");
}

#[test]
fn noise_draw_order_is_worker_count_invariant() {
    // The documented invariant from coordinator::step: consuming an
    // identical GradBundle through a ParamStore sink and through a sharded
    // sink must draw the same noise stream (RNG states end equal) and
    // produce bitwise-equal parameters.
    let rt = Runtime::builtin();
    let model = rt.manifest.model("criteo-tiny").unwrap();
    let cfg = tiny_cfg(Algorithm::DpAdaFest);
    let store_a = ParamStore::init(model, cfg.seed).unwrap();
    let store_b = ParamStore::init(model, cfg.seed).unwrap();
    let mut state_a = StepState::new(cfg.clone(), model, &store_a).unwrap();
    let mut state_b = StepState::new(cfg, model, &store_b).unwrap();

    let bundle = |state: &StepState| -> GradBundle {
        let mut rng = Xoshiro256::seed_from(99);
        let total: usize = state.emb_tables.iter().map(|t| t.vocab).sum();
        let mut counts = vec![0f32; total];
        let mut table_grads = Vec::new();
        for t in &state.emb_tables {
            let mut g = RowSparseGrad::new(t.vocab, t.dim);
            for _ in 0..8 {
                let row = rng.below(t.vocab as u64) as u32;
                let vals: Vec<f32> = (0..t.dim).map(|_| rng.gauss() as f32).collect();
                g.add_row(row, &vals);
                counts[t.row_offset + row as usize] += 1.0;
            }
            table_grads.push(g);
        }
        GradBundle { loss: 0.7, table_grads, counts: Some(counts), dense_grads: vec![] }
    };

    let mut sink_a = store_a;
    let bundle_a = bundle(&state_a);
    let stats_a = state_a.apply_update(bundle_a, &mut sink_a).unwrap();

    let emb_params: Vec<usize> =
        state_b.emb_tables.iter().map(|t| t.param_index).collect();
    let sharded = ShardedStore::from_store(store_b, &emb_params, 5).unwrap();
    let bundle_b = bundle(&state_b);
    let stats_b = {
        let mut sink = &sharded;
        state_b.apply_update(bundle_b, &mut sink).unwrap()
    };

    assert_eq!(stats_a.emb_coords_noised, stats_b.emb_coords_noised);
    assert_eq!(stats_a.survivors, stats_b.survivors);
    // identical post-update RNG state ⇒ identical draw counts and order
    assert_eq!(state_a.rng.next_u64(), state_b.rng.next_u64());
    // identical parameters, coordinate for coordinate
    let back = sharded.into_store().unwrap();
    assert_params_identical(&sink_a, &back, "sharded sink");
}

#[test]
fn prop_sharded_concurrent_disjoint_updates_match_sequential() {
    // Row-disjoint updates applied concurrently from several threads must
    // equal one sequential application (rows commute coordinate-wise).
    check("sharded concurrent == sequential", 40, |rng| {
        let rows = usize_in(rng, 8, 200);
        let dim = usize_in(rng, 1, 8);
        let shards = usize_in(rng, 1, 9);
        let threads = usize_in(rng, 2, 5);
        let init: Vec<f32> = (0..rows * dim).map(|_| rng.gauss() as f32).collect();

        // one grad split into row-disjoint per-thread parts
        let mut full = RowSparseGrad::new(rows, dim);
        let mut parts: Vec<RowSparseGrad> =
            (0..threads).map(|_| RowSparseGrad::new(rows, dim)).collect();
        for row in 0..rows {
            if rng.uniform() < 0.4 {
                let vals: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
                full.add_row(row as u32, &vals);
                parts[row % threads].add_row(row as u32, &vals);
            }
        }
        let opt = Optimizer::adagrad(0.05);

        let mut flat = init.clone();
        let mut st = DenseState::default();
        opt.sparse_step(&mut flat, &full, &mut st);

        let table = ShardedTable::from_dense(rows, dim, init, shards);
        std::thread::scope(|scope| {
            for part in &parts {
                let (t, o) = (&table, &opt);
                scope.spawn(move || t.apply_sparse(part, o));
            }
        });
        let (values, _) = table.into_dense();
        ensure(
            values == flat,
            format!("mismatch at rows={rows} dim={dim} shards={shards}"),
        )
    });
}

#[test]
fn engine_handles_degenerate_configs_without_deadlock() {
    // Hard watchdog: a shutdown regression here must fail in bounded time,
    // not hang the suite (the multi-process analogue with killed actor
    // children lives in tests/engine_fault.rs).
    support::watchdog(120, "degenerate engine configs", || {
        let rt = Runtime::builtin();

        // zero steps: nothing to train, eval only
        let mut cfg = tiny_cfg(Algorithm::NonPrivate);
        cfg.steps = 0;
        let out = engine::run_pctr(&cfg, &rt, gen_cfg(&rt, &cfg)).unwrap();
        assert!(out.loss_history.is_empty());

        // one step, minimal channel, more workers than work
        let mut cfg = tiny_cfg(Algorithm::NonPrivate);
        cfg.steps = 1;
        cfg.eval_batches = 1;
        cfg.engine.grad_workers = 8;
        cfg.engine.data_workers = 6;
        cfg.engine.channel_depth = 1;
        let out = engine::run_pctr(&cfg, &rt, gen_cfg(&rt, &cfg)).unwrap();
        assert_eq!(out.loss_history.len(), 1);

        // unknown model errors cleanly instead of hanging
        let mut cfg = tiny_cfg(Algorithm::NonPrivate);
        cfg.model = "no-such-model".into();
        let vocabs = vec![8usize];
        assert!(engine::run_pctr(&cfg, &rt, CriteoConfig::new(vocabs, 1)).is_err());
    });
}

#[test]
fn engine_rejects_mismatched_generator_geometry() {
    // grad workers bypass Runtime::execute's shape checks, so the engine
    // must validate generator geometry up front instead of silently
    // scattering gradients onto wrong rows
    let rt = Runtime::builtin();
    let nlu = tiny_nlu_cfg(Algorithm::NonPrivate);
    let wrong_seq = TextConfig::new(512, 16, 2, 1); // nlu-tiny has seq_len 12
    assert!(engine::run_text(&nlu, &rt, wrong_seq).is_err());
    let wrong_vocab = TextConfig::new(256, 12, 2, 1); // nlu-tiny has vocab 512
    assert!(engine::run_text(&nlu, &rt, wrong_vocab).is_err());
    let pctr = tiny_cfg(Algorithm::NonPrivate);
    let wrong_features = CriteoConfig::new(vec![8, 8], 1); // criteo-tiny has 4
    assert!(engine::run_pctr(&pctr, &rt, wrong_features).is_err());
}

// ---- bounded staleness (`--engine-staleness`) ----

#[test]
fn staleness_zero_is_bit_identical_on_outcomes_and_params() {
    // The explicit default window must reproduce the sync trainer bit for
    // bit through the versioned-snapshot dispatch path — outcomes AND final
    // parameters — on both the pCTR tower and a Table-1 LoRA rank model, at
    // non-default worker settings.
    let rt = Runtime::builtin();

    let mut cfg = tiny_cfg(Algorithm::DpAdaFest);
    cfg.engine.staleness = 0;
    cfg.engine.grad_workers = 3;
    cfg.engine.data_workers = 2;
    cfg.engine.shards = 7;
    let gen = SynthCriteo::new(gen_cfg(&rt, &cfg));
    let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
    let sync_out = trainer.run_pctr(&gen).unwrap();
    let (async_out, async_store) = engine::run_with_params(&cfg, &rt).unwrap();
    assert_outcomes_identical(&sync_out, &async_out, "staleness 0 pctr");
    assert_eq!(async_out.telemetry.max_staleness, 0, "k=0 must never observe staleness");
    assert_params_identical(&trainer.store, &async_store, "staleness 0 pctr");

    let mut cfg = tiny_nlu_cfg(Algorithm::DpAdaFest);
    cfg.model = "nlu-tiny-lora4".into();
    cfg.engine.staleness = 0;
    cfg.engine.grad_workers = 4;
    cfg.engine.shards = 16;
    let gen = SynthText::new(text_cfg(&rt, &cfg));
    let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
    let sync_out = trainer.run_text(&gen).unwrap();
    let (async_out, async_store) = engine::run_with_params(&cfg, &rt).unwrap();
    assert_outcomes_identical(&sync_out, &async_out, "staleness 0 lora4");
    assert_eq!(async_out.telemetry.max_staleness, 0, "k=0 must never observe staleness");
    assert_params_identical(&trainer.store, &async_store, "staleness 0 lora4");
}

#[test]
fn staleness_window_bounds_observed_staleness_and_still_learns() {
    // k > 0 relaxes bit-exactness but the pipeline must stay correct: the
    // run terminates, losses are finite, and the high-water snapshot age is
    // exactly min(k, steps − 1).  That value is deterministic, not a race:
    // the barrier drains to exactly k in-flight steps after every dispatch
    // regardless of worker speed, so step t is applied at age min(t, k).
    // NonPrivate SGD must also still descend on stale gradients.
    let rt = Runtime::builtin();
    let mut cfg = tiny_cfg(Algorithm::NonPrivate);
    cfg.steps = 24;
    cfg.engine.staleness = 2;
    cfg.engine.grad_workers = 4;
    let out = engine::run_pctr(&cfg, &rt, gen_cfg(&rt, &cfg)).unwrap();
    assert_eq!(out.loss_history.len(), 24);
    assert!(out.loss_history.iter().all(|l| l.is_finite()));
    assert_eq!(out.telemetry.max_staleness, 2);
    let (first, second) = out.loss_history.split_at(12);
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    assert!(
        mean(second) < mean(first),
        "loss did not go downhill under staleness: {:?}",
        out.loss_history
    );

    // a window larger than the run clamps at steps − 1 (every later step
    // reads the initial parameters; nothing is ever collected before drain)
    let mut cfg = tiny_cfg(Algorithm::DpAdaFest);
    cfg.steps = 3;
    cfg.engine.staleness = 16;
    let out = engine::run_pctr(&cfg, &rt, gen_cfg(&rt, &cfg)).unwrap();
    assert_eq!(out.loss_history.len(), 3);
    assert!(out.loss_history.iter().all(|l| l.is_finite()));
    assert_eq!(out.telemetry.max_staleness, 2);
}

#[test]
fn streaming_with_staleness_window_runs_and_bounds_staleness() {
    // k > 0 on the §4.3 protocol: periods and reselections are schedule-
    // driven and the barrier drains the window at every reselection
    // boundary, so the reselection count is unchanged and no step's update
    // crosses a boundary — only the parameters read are stale.
    let rt = Runtime::builtin();
    let mut cfg = streaming_cfg(Algorithm::DpFest, FrequencySource::Streaming, 4);
    cfg.engine.staleness = 2;
    cfg.engine.grad_workers = 4;
    let gcfg = gen_cfg(&rt, &cfg).with_drift();
    let out = engine::run_streaming(&cfg, &rt, gcfg, 2).unwrap();
    assert_eq!(out.outcome.loss_history.len(), 18);
    assert!(out.outcome.loss_history.iter().all(|l| l.is_finite()));
    assert_eq!(out.per_day_auc.len(), 6);
    assert_eq!(out.reselections, TRAIN_DAYS.div_ceil(4));
    assert!(out.outcome.telemetry.max_staleness <= 2);
}

// ---- streaming (§4.3) mode ----

#[test]
fn streaming_sync_and_async_match_for_all_frequency_sources() {
    // The acceptance bar of the engine's streaming mode: for every
    // FrequencySource, `run_streaming` reproduces the sync StreamingTrainer
    // bit for bit — per-day AUCs, reselection count, loss history, final
    // utility — at more than one worker/shard configuration.
    let rt = Runtime::builtin();
    for source in [
        FrequencySource::FirstDay,
        FrequencySource::AllDays,
        FrequencySource::Streaming,
    ] {
        let cfg = streaming_cfg(Algorithm::DpFest, source, 4);
        let gcfg = gen_cfg(&rt, &cfg).with_drift();
        let sync_out = sync_streaming(&cfg, &rt, &gcfg);
        assert!(sync_out.outcome.loss_history.iter().all(|l| l.is_finite()));
        assert_eq!(sync_out.per_day_auc.len(), 6);
        // reselection budget: frozen sources select once; streaming
        // reselects at every period boundary, ceil(18/4) = 5 times
        let expected = match source {
            FrequencySource::Streaming => TRAIN_DAYS.div_ceil(4),
            _ => 1,
        };
        assert_eq!(sync_out.reselections, expected, "{source:?}: reselections");
        for (gw, dw, shards) in [(1, 1, 1), (4, 2, 16)] {
            let mut c = cfg.clone();
            c.engine.grad_workers = gw;
            c.engine.data_workers = dw;
            c.engine.shards = shards;
            let async_out = engine::run_streaming(&c, &rt, gcfg.clone(), 2).unwrap();
            assert_streaming_identical(
                &sync_out,
                &async_out,
                &format!("{source:?} ({gw},{dw},{shards})"),
            );
        }
    }
}

#[test]
fn streaming_async_invariant_to_period_and_engine_knobs() {
    // DP-AdaFEST+ is the strictest case: periodic FEST Gumbel draws at the
    // barrier interleave with per-batch contribution-map noise, so any
    // drift in the streaming schedule shows up immediately.
    let rt = Runtime::builtin();
    for period in [1usize, 6] {
        let cfg = streaming_cfg(Algorithm::DpAdaFestPlus, FrequencySource::Streaming, period);
        let gcfg = gen_cfg(&rt, &cfg).with_drift();
        let sync_out = sync_streaming(&cfg, &rt, &gcfg);
        assert_eq!(sync_out.reselections, TRAIN_DAYS.div_ceil(period));
        for (gw, dw, depth, shards, mb) in [(2, 2, 1, 7, 2), (6, 3, 16, 64, 100)] {
            let mut c = cfg.clone();
            c.engine.grad_workers = gw;
            c.engine.data_workers = dw;
            c.engine.channel_depth = depth;
            c.engine.shards = shards;
            c.engine.microbatch_chunks = mb;
            let async_out = engine::run_streaming(&c, &rt, gcfg.clone(), 2).unwrap();
            assert_streaming_identical(
                &sync_out,
                &async_out,
                &format!("period {period} ({gw},{dw},{depth},{shards},{mb})"),
            );
        }
    }
}

#[test]
fn streaming_without_fest_never_reselects_and_still_matches() {
    // DP-SGD on the time axis (the Table-5 setting): no reselection events,
    // but the day-ordered batch streams and per-day eval must still agree.
    // `steps` is deliberately not a multiple of 18: both executors must
    // round to whole days (18 streamed steps) and re-calibrate σ for the
    // streamed step count, identically.
    let rt = Runtime::builtin();
    let mut cfg = streaming_cfg(Algorithm::DpSgd, FrequencySource::Streaming, 2);
    cfg.steps = 20; // -> 1 step/day, 18 streamed steps
    let gcfg = gen_cfg(&rt, &cfg).with_drift();
    let sync_out = sync_streaming(&cfg, &rt, &gcfg);
    assert_eq!(sync_out.reselections, 0);
    assert_eq!(sync_out.outcome.loss_history.len(), 18);
    let async_out = engine::run_streaming(&cfg, &rt, gcfg, 2).unwrap();
    assert_streaming_identical(&sync_out, &async_out, "dp-sgd streaming");
}

#[test]
fn fest_preselection_paths_agree() {
    // DP-AdaFEST+ exercises fest_select (Gumbel draws from the shared RNG
    // stream) plus per-batch filtering — the strictest equivalence case.
    let rt = Runtime::builtin();
    let mut cfg = tiny_cfg(Algorithm::DpAdaFestPlus);
    cfg.fest_top_k = 64;
    cfg.steps = 4;
    let gcfg = gen_cfg(&rt, &cfg);
    let gen = SynthCriteo::new(gcfg.clone());
    let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
    let sync_out = trainer.run_pctr(&gen).unwrap();
    let async_out = engine::run_pctr(&cfg, &rt, gcfg).unwrap();
    assert_outcomes_identical(&sync_out, &async_out, "DpAdaFestPlus");
}

// ---- multi-process mode (`--engine-processes`) ----

/// The three-way bit-exactness bar on one config: sync trainer ==
/// in-process async == multi-process actor fleet — plus the paged-store
/// backend (`--store-budget-mb`) on both async paths — on outcomes AND
/// final parameters, at each `(processes, shards, data actors)` split.
/// Run under a watchdog — a wire-protocol regression must fail in bounded
/// time, not hang the suite.
fn three_way_multi_process(cfg: RunConfig, what: &'static str) {
    support::use_cli_actor_exe();
    support::watchdog(300, what, move || {
        let rt = Runtime::builtin();
        let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
        let sync_out = match rt.manifest.model(&cfg.model).unwrap().kind.as_str() {
            "pctr" => {
                let gen = SynthCriteo::new(gen_cfg(&rt, &cfg));
                trainer.run_pctr(&gen).unwrap()
            }
            _ => {
                let gen = SynthText::new(text_cfg(&rt, &cfg));
                trainer.run_text(&gen).unwrap()
            }
        };
        let (async_out, async_store) = engine::run_with_params(&cfg, &rt).unwrap();
        assert_outcomes_identical(&sync_out, &async_out, &format!("{what}: in-process"));
        assert_params_identical(&trainer.store, &async_store, &format!("{what}: in-process"));

        // paged-store backend in-process: file-backed tables at a 1 MiB
        // page-cache budget must reproduce the in-RAM shards bit for bit
        // (and the resident-bytes gauge must have seen pages move)
        let mut c = cfg.clone();
        c.store_budget_mb = 1;
        let (paged_out, paged_store) = engine::run_with_params(&c, &rt).unwrap();
        assert_outcomes_identical(&sync_out, &paged_out, &format!("{what}: paged"));
        assert_params_identical(&trainer.store, &paged_store, &format!("{what}: paged"));
        assert!(
            paged_out.telemetry.max_store_resident_bytes > 0,
            "{what}: paged run never reported resident page bytes"
        );

        // (gradient actor processes, shards per actor table, data actors)
        for (procs, shards, data) in [(2, 2, 2), (3, 1, 1)] {
            let mut c = cfg.clone();
            c.engine.processes = procs;
            c.engine.shards = shards;
            c.engine.data_workers = data;
            let (mp_out, mp_store) = engine::run_with_params(&c, &rt).unwrap();
            let label = format!("{what}: {procs} procs, {shards} shards, {data} data");
            assert_outcomes_identical(&sync_out, &mp_out, &label);
            assert_params_identical(&trainer.store, &mp_store, &label);
            assert_outcomes_identical(&async_out, &mp_out, &format!("{label} vs async"));
            assert_params_identical(&async_store, &mp_store, &format!("{label} vs async"));
        }

        // paged tables inside the actor fleet: each gradient actor pages
        // only its own contiguous row range, same bit-exactness bar
        let mut c = cfg.clone();
        c.engine.processes = 2;
        c.store_budget_mb = 1;
        let (mp_out, mp_store) = engine::run_with_params(&c, &rt).unwrap();
        assert_outcomes_identical(&sync_out, &mp_out, &format!("{what}: mp paged"));
        assert_params_identical(&trainer.store, &mp_store, &format!("{what}: mp paged"));
    });
}

#[test]
fn multi_process_pctr_dp_sgd_matches_sync_and_async_exactly() {
    three_way_multi_process(tiny_cfg(Algorithm::DpSgd), "mp criteo DpSgd");
}

#[test]
fn multi_process_pctr_dp_ada_fest_matches_sync_and_async_exactly() {
    three_way_multi_process(tiny_cfg(Algorithm::DpAdaFest), "mp criteo DpAdaFest");
}

#[test]
fn multi_process_lora_dp_sgd_matches_sync_and_async_exactly() {
    let mut cfg = tiny_nlu_cfg(Algorithm::DpSgd);
    cfg.model = "nlu-tiny-lora4".into();
    three_way_multi_process(cfg, "mp lora4 DpSgd");
}

#[test]
fn multi_process_lora_dp_ada_fest_matches_sync_and_async_exactly() {
    let mut cfg = tiny_nlu_cfg(Algorithm::DpAdaFest);
    cfg.model = "nlu-tiny-lora4".into();
    three_way_multi_process(cfg, "mp lora4 DpAdaFest");
}

#[test]
fn multi_process_streaming_matches_sync_and_counts_reselections() {
    // `--stream --engine-processes`: per-batch frequency counts and the
    // PriorPass warmup batches ride the wire from the data actors, the
    // barrier still drives every DP-FEST reselection — the streaming
    // outcome, per-day AUCs, and reselection count are bit-identical to
    // the sync StreamingTrainer.
    support::use_cli_actor_exe();
    support::watchdog(300, "mp streaming", || {
        let rt = Runtime::builtin();
        let cfg = streaming_cfg(Algorithm::DpFest, FrequencySource::Streaming, 4);
        let gcfg = gen_cfg(&rt, &cfg).with_drift();
        let sync_out = sync_streaming(&cfg, &rt, &gcfg);
        assert_eq!(sync_out.reselections, TRAIN_DAYS.div_ceil(4));
        for (procs, shards, data) in [(2, 4, 2), (3, 1, 1)] {
            let mut c = cfg.clone();
            c.engine.processes = procs;
            c.engine.shards = shards;
            c.engine.data_workers = data;
            let mp_out = engine::run_streaming(&c, &rt, gcfg.clone(), 2).unwrap();
            assert_streaming_identical(
                &sync_out,
                &mp_out,
                &format!("mp streaming ({procs},{shards},{data})"),
            );
        }

        // PriorPass over the wire: a frozen frequency source's warmup pass
        // is generated by the data actors too (sequence keys ahead of the
        // training steps), and the single barrier-side selection matches.
        let cfg = streaming_cfg(Algorithm::DpFest, FrequencySource::FirstDay, 4);
        let gcfg = gen_cfg(&rt, &cfg).with_drift();
        let sync_out = sync_streaming(&cfg, &rt, &gcfg);
        assert_eq!(sync_out.reselections, 1);
        let mut c = cfg.clone();
        c.engine.processes = 2;
        c.engine.data_workers = 2;
        let mp_out = engine::run_streaming(&c, &rt, gcfg, 2).unwrap();
        assert_streaming_identical(&sync_out, &mp_out, "mp streaming FirstDay prior");
    });
}

#[test]
fn paged_store_streaming_matches_sync_and_counts_reselections() {
    // `--stream` on the paged backend: DP-FEST reselections rebuild the
    // RowCache from file-backed tables, and the whole §4.3 protocol stays
    // bit-identical to the sync StreamingTrainer — at a 1 MiB budget that
    // forces eviction traffic and at one comfortably holding every page.
    let rt = Runtime::builtin();
    let cfg = streaming_cfg(Algorithm::DpFest, FrequencySource::Streaming, 4);
    let gcfg = gen_cfg(&rt, &cfg).with_drift();
    let sync_out = sync_streaming(&cfg, &rt, &gcfg);
    assert_eq!(sync_out.reselections, TRAIN_DAYS.div_ceil(4));
    for budget_mb in [1usize, 64] {
        let mut c = cfg.clone();
        c.store_budget_mb = budget_mb;
        c.engine.grad_workers = 4;
        c.engine.data_workers = 2;
        let paged_out = engine::run_streaming(&c, &rt, gcfg.clone(), 2).unwrap();
        assert_streaming_identical(
            &sync_out,
            &paged_out,
            &format!("paged streaming (budget {budget_mb} MiB)"),
        );
        assert_eq!(paged_out.reselections, TRAIN_DAYS.div_ceil(4));
    }
}

#[test]
fn multi_process_staleness_window_still_terminates_and_learns() {
    // `--engine-staleness` composes with `--engine-processes`: the barrier
    // pipelines k steps ahead over the sockets, the run terminates, and the
    // observed snapshot age hits exactly min(k, steps − 1) — the FIFO
    // scatter-before-fetch ordering holds at any window.
    support::use_cli_actor_exe();
    support::watchdog(300, "mp staleness", || {
        let rt = Runtime::builtin();
        let mut cfg = tiny_cfg(Algorithm::NonPrivate);
        cfg.steps = 12;
        cfg.engine.staleness = 2;
        cfg.engine.processes = 2;
        let out = engine::run_pctr(&cfg, &rt, gen_cfg(&rt, &cfg)).unwrap();
        assert_eq!(out.loss_history.len(), 12);
        assert!(out.loss_history.iter().all(|l| l.is_finite()));
        assert_eq!(out.telemetry.max_staleness, 2);
    });
}
