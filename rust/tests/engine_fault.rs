//! Fault injection for the multi-process engine: abort one actor process
//! mid-run (a hard `process::exit`, no shutdown protocol) and prove the
//! barrier surfaces an error in **bounded time** — no deadlock, no hung
//! channel waits, and no orphaned actor processes left behind.
//!
//! This lives in its own test binary on purpose: the fault spec set by
//! `engine::actor::set_fault` is process-global (it rides the environment
//! of every actor child spawned from this process afterwards), so it must
//! never share a binary with the healthy multi-process runs in
//! `tests/engine.rs` / `tests/telemetry.rs`.  For the same reason all
//! fault scenarios run sequentially inside ONE `#[test]`.

mod support;

use sparse_dp_emb::coordinator::Algorithm;
use sparse_dp_emb::engine;
use sparse_dp_emb::engine::actor::set_fault;
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::store::PagedTable;

/// Assert no live actor child survived the failed run.  `ActorSet::drop`
/// kills and reaps every child on the error path, so the kernel's
/// child list for this process must be empty again.  (If this kernel was
/// built without `CONFIG_PROC_CHILDREN` the probe files don't exist and
/// the check degrades to a no-op rather than a false failure.)
fn assert_no_actor_children(what: &str) {
    let mut children = Vec::new();
    if let Ok(tasks) = std::fs::read_dir("/proc/self/task") {
        for task in tasks.flatten() {
            let path = task.path().join("children");
            if let Ok(list) = std::fs::read_to_string(path) {
                children.extend(list.split_whitespace().map(str::to_owned));
            }
        }
    }
    assert!(
        children.is_empty(),
        "{what}: orphaned child processes after the failed run: {children:?}"
    );
}

#[test]
fn killed_actor_processes_fail_the_run_in_bounded_time() {
    support::use_cli_actor_exe();

    // --- Scenario 1: a gradient actor dies mid-run ------------------------
    // `grad:0:2` aborts gradient actor 0 right after its second ChunkResult
    // frame.  On criteo-tiny each of the two actors owns one reduction
    // chunk per step, so the abort races the barrier's next interaction
    // with the dead peer: the error surfaces either from a read side
    // ("… terminated …" via the reader threads / the aggregation barrier's
    // worker-down poll) or from a write to the closed socket (the
    // "… gradient actor" context on FetchRows/Scatter/StepData sends).
    // Both are bounded-time and attribute the death to a gradient actor.
    set_fault("grad:0:2");
    let err = support::watchdog(120, "grad-actor death", || {
        let mut cfg = support::tiny_cfg(Algorithm::DpSgd);
        cfg.engine.processes = 2;
        cfg.engine.data_workers = 1;
        let rt = Runtime::builtin();
        engine::run_with_params(&cfg, &rt)
    })
    .expect_err("a dead gradient actor must fail the run, not hang it");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("gradient actor") || msg.contains("gradient worker"),
        "grad-actor death surfaced an unrelated error: {msg}"
    );
    assert_no_actor_children("grad-actor death");

    // --- Scenario 2: a data actor dies mid-sequence -----------------------
    // With two data actors, actor 0 owns steps 0, 2, 4, …; `data:0:1`
    // aborts it right after its first batch, so step 2 never arrives.  The
    // batch stream's watchdog must convert the missing producer into an
    // error instead of blocking on the channel forever.
    set_fault("data:0:1");
    let err = support::watchdog(120, "data-actor death", || {
        let mut cfg = support::tiny_cfg(Algorithm::DpSgd);
        cfg.engine.processes = 2;
        cfg.engine.data_workers = 2;
        let rt = Runtime::builtin();
        engine::run_with_params(&cfg, &rt)
    })
    .expect_err("a dead data actor must fail the run, not hang it");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("terminated before producing step"),
        "data-actor death surfaced an unrelated error: {msg}"
    );
    assert_no_actor_children("data-actor death");

    // --- Scenario 3: a gradient actor dies mid-scatter, paged store -------
    // Same `grad:0:2` abort, but with the file-backed paged store live
    // (`store_budget_mb = 1`) and the page files routed to a dedicated
    // directory.  The killed actor (`process::exit`) and its SIGKILLed
    // sibling both skip `Drop`, so their page files survive with the
    // open-state header — and `PagedTable::check_clean` must reject every
    // one of them on reopen: a dead writer means its scatters may be
    // partially applied, and reusing such a file would corrupt the table
    // silently.  (The coordinator's own tables unwind normally on the
    // error path and remove their files.)
    let dir = std::env::temp_dir().join(format!("sde_fault_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    set_fault("grad:0:2");
    let err = support::watchdog(120, "paged grad-actor death", || {
        let mut cfg = support::tiny_cfg(Algorithm::DpSgd);
        cfg.engine.processes = 2;
        cfg.engine.data_workers = 1;
        cfg.store_budget_mb = 1;
        cfg.store_dir = dir.to_string_lossy().into_owned();
        let rt = Runtime::builtin();
        engine::run_with_params(&cfg, &rt)
    })
    .expect_err("a dead gradient actor must fail the paged run, not hang it");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("gradient actor") || msg.contains("gradient worker"),
        "paged grad-actor death surfaced an unrelated error: {msg}"
    );
    let leftover: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|e| e == "pages"))
        .collect();
    assert!(
        !leftover.is_empty(),
        "the killed actor should have left its page files behind in {}",
        dir.display()
    );
    for path in &leftover {
        let err = PagedTable::check_clean(path)
            .expect_err("a crashed writer's page file must be rejected on reopen");
        assert!(
            format!("{err:#}").contains("not cleanly closed"),
            "wrong rejection for {}: {err:#}",
            path.display()
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
    assert_no_actor_children("paged grad-actor death");
}
