//! Executability smoke for the revived `fullscale` harness: the `--fast`
//! sweep must run end to end (Table-3 gradient-size half + the paged-store
//! Zipf throughput half) and land its `"store": "paged"` rows in the bench
//! snapshot.  `BENCH_OUT` is pointed at a scratch file so the test never
//! touches the tracked `BENCH_engine.json`; this test binary holds exactly
//! one test, so the process-wide env var cannot race another thread.

mod support;

use sparse_dp_emb::coordinator::Algorithm;
use sparse_dp_emb::harness;
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::store::unique_path;
use sparse_dp_emb::telemetry::{BenchSnapshot, BENCH_SCHEMA_VERSION};

#[test]
fn fullscale_fast_runs_and_writes_paged_bench_rows() {
    let bench_path = unique_path(&std::env::temp_dir(), "bench_smoke");
    let bench_path = bench_path.with_extension("json");
    std::env::set_var("BENCH_OUT", &bench_path);

    let cfg = support::tiny_cfg(Algorithm::DpAdaFest); // fullscale only reads seed + store knobs
    let rt = Runtime::builtin();
    support::watchdog(300, "fullscale --fast", move || {
        harness::run_experiment("fullscale", &cfg, &rt, true)
    })
    .expect("fullscale --fast must run end to end");

    let text = std::fs::read_to_string(&bench_path).expect("fullscale wrote no bench snapshot");
    // the exact assertion CI makes against the tracked snapshot
    assert!(text.contains("\"store\": \"paged\""), "no paged rows in: {text}");
    let snap = BenchSnapshot::parse(&text).expect("snapshot must round-trip");
    assert_eq!(snap.schema_version, BENCH_SCHEMA_VERSION);
    for label in ["paged-scatter", "paged-select"] {
        let row = snap
            .rows
            .iter()
            .find(|r| r.path == label)
            .unwrap_or_else(|| panic!("missing {label} row"));
        assert_eq!(row.store, "paged");
        assert!(row.secs > 0.0 && row.steps_per_sec > 0.0, "degenerate {label} timing");
    }

    std::env::remove_var("BENCH_OUT");
    std::fs::remove_file(&bench_path).unwrap();
}
